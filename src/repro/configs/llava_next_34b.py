"""LLaVA-NeXT-34B [vlm] — LM backbone only; the anyres vision tower is a
STUB: ``input_specs`` provides precomputed patch/text embeddings
[batch, seq, d_model].  [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    frontend="vision_anyres",
    frontend_dim=7168,
    rope_theta=5_000_000.0,
)
