"""Model / shape / run configuration."""
from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    SHAPES,
    ModelConfig,
    ParallelConfig,
    RunConfig,
    ShapeConfig,
    reduced,
)
