"""MusicGen-medium [audio] — decoder-only transformer over EnCodec tokens.
The EnCodec frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings [batch, seq, d_model]; the backbone predicts codebook tokens
(vocab 2048).  [arXiv:2306.05284; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,     # MHA
    d_ff=6144,
    gated_mlp=False,     # classic GELU MLP
    vocab_size=2048,
    frontend="audio_codec",
    frontend_dim=1536,
    rope_theta=10_000.0,
)
