"""Llama-4 Maverick 400B-A17B [moe] — 128 experts top-1, alternating MoE
layers with an always-on shared expert (early-fusion multimodal backbone;
text path modelled here).  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    experts_per_token=1,
    moe_layer_period=2,      # alternating dense / MoE
    shared_expert=True,
    rope_theta=500_000.0,
)
