"""Config system: model/shape/mesh/run dataclasses.

Every assigned architecture is a `ModelConfig`; every assigned input shape is
a `ShapeConfig`.  The registry (`configs/registry.py`) resolves ``--arch`` /
``--shape`` strings to these objects.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-style backbone configuration (all 10 assigned archs fit)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    vocab_size: int
    # --- attention ---
    num_heads: int = 0           # 0 => attention-free (pure SSM)
    num_kv_heads: int = 0        # GQA KV heads
    head_dim: int = 0            # 0 => d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_window: int = 0         # 0 => full causal; >0 => sliding window
    # --- MLP / MoE ---
    d_ff: int = 0
    gated_mlp: bool = True       # SwiGLU (3 mats) vs classic MLP (2 mats)
    num_experts: int = 0         # 0 => dense MLP
    experts_per_token: int = 0
    moe_layer_period: int = 1    # 1 => every layer MoE; 2 => alternating (llama4)
    shared_expert: bool = False  # llama4-style always-on shared expert
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0           # N: state dimension per group; 0 => no SSM
    ssm_heads: int = 0           # number of SSD heads (derived if 0)
    ssm_head_dim: int = 64       # P: channels per SSD head
    ssm_groups: int = 1          # B/C groups (shared across heads in a group)
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    # --- hybrid (hymba): attention and SSM in parallel within one block ---
    hybrid: bool = False
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # modality frontend stub: if set, inputs are precomputed embeddings
    # of shape [batch, seq, frontend_dim] instead of token ids.
    frontend: Optional[str] = None   # None | "audio_codec" | "vision_anyres"
    frontend_dim: int = 0

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.ssm_state > 0 and not self.hybrid and self.num_heads == 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def has_attention(self) -> bool:
        return self.num_heads > 0

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def resolved_ssm_heads(self) -> int:
        if not self.has_ssm:
            return 0
        if self.ssm_heads:
            return self.ssm_heads
        return self.d_inner // self.ssm_head_dim

    @property
    def supports_long_context(self) -> bool:
        """True iff decode memory is O(1) in context length (SSM state and/or
        sliding-window KV) — required for the long_500k shape."""
        if self.is_ssm:
            return True
        if self.has_ssm and (self.attn_window > 0 or not self.has_attention):
            return True
        return False

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        """Which layers are MoE layers."""
        if not self.is_moe:
            return tuple(False for _ in range(self.num_layers))
        return tuple(
            (i % self.moe_layer_period) == (self.moe_layer_period - 1)
            for i in range(self.num_layers)
        )

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd
        total = v * d                      # embedding
        if not self.tie_embeddings:
            total += v * d                 # LM head
        total += d                         # final norm
        mask = self.moe_layer_mask()
        for i in range(self.num_layers):
            blk = 2 * d                    # two RMSNorm scales
            if self.has_attention:
                blk += d * (n_q + 2 * n_kv) + n_q * d      # qkv + o
                if self.qkv_bias:
                    blk += n_q + 2 * n_kv
            if self.has_ssm:
                di = self.d_inner
                nh = self.resolved_ssm_heads
                g = self.ssm_groups
                blk += d * (2 * di + 2 * g * self.ssm_state + nh)   # in_proj(x,z,B,C,dt)
                blk += (di + 2 * g * self.ssm_state) * self.ssm_conv_width  # conv(x,B,C)
                blk += 2 * nh + di                                   # A, D, norm
                blk += di * d                                        # out_proj
            n_mlp_mats = 3 if self.gated_mlp else 2
            if self.is_moe and mask[i]:
                blk += self.num_experts * n_mlp_mats * d * f
                if self.shared_expert:
                    blk += n_mlp_mats * d * f
                blk += d * self.num_experts  # router
            elif f > 0:
                blk += n_mlp_mats * d * f    # MLP
            total += blk
        return total

    def num_active_params(self) -> int:
        """Active (per-token) parameter count — MoE counts top-k experts."""
        if not self.is_moe:
            return self.num_params()
        d, f = self.d_model, self.d_ff
        full = self.num_params()
        mask = self.moe_layer_mask()
        n_moe_layers = sum(mask)
        n_mlp_mats = 3 if self.gated_mlp else 2
        inactive = (
            n_moe_layers
            * (self.num_experts - self.experts_per_token)
            * n_mlp_mats * d * f
        )
        return full - inactive


# ---------------------------------------------------------------------------
# Input-shape configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Parallelism / run configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelConfig:
    """How a model is laid out on the mesh.

    Axes: ``pod`` (optional outer DP), ``data`` (DP/FSDP), ``model`` (TP/EP).
    """

    fsdp: bool = True            # shard params over "data" too (ZeRO-3)
    remat: str = "block"         # "block" | "save_mixer" — checkpoint policy
    attn_impl: str = "blocked"   # "blocked" | "pairs" (causal block skipping)
    tp_reduce_bf16: bool = False # explicit bf16 TP down-proj reductions
    expert_axis: str = "model"   # EP placement for MoE
    seq_shard_decode: bool = True  # shard long decode contexts over "model"
    # PFAIT monitor defaults for training
    monitor_mode: str = "pfait"
    monitor_staleness: int = 2


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    seed: int = 0
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1_000
    microbatch: int = 0          # 0 => no grad accumulation


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    defaults = dict(
        num_layers=2,
        d_model=64,
        vocab_size=256,
    )
    if cfg.num_heads:
        defaults.update(num_heads=4, num_kv_heads=max(1, 4 * cfg.num_kv_heads // max(cfg.num_heads, 1)), head_dim=16)
    if cfg.d_ff:
        defaults.update(d_ff=128)
    if cfg.num_experts:
        defaults.update(num_experts=4, experts_per_token=min(cfg.experts_per_token, 2))
    if cfg.ssm_state:
        defaults.update(ssm_state=8, ssm_head_dim=16)
    if cfg.attn_window:
        defaults.update(attn_window=32)
    if cfg.frontend_dim:
        defaults.update(frontend_dim=32)
    defaults.update(overrides)
    return dataclasses.replace(cfg, **defaults)
