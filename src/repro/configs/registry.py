"""Architecture / shape registry — resolves ``--arch`` and ``--shape``."""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs.base import (
    ALL_SHAPES,
    SHAPES,
    ModelConfig,
    ShapeConfig,
)
from repro.configs import (
    qwen2_5_32b,
    deepseek_7b,
    qwen2_1_5b,
    starcoder2_3b,
    llama4_maverick_400b_a17b,
    grok1_314b,
    musicgen_medium,
    llava_next_34b,
    mamba2_130m,
    hymba_1_5b,
)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen2_5_32b,
        deepseek_7b,
        qwen2_1_5b,
        starcoder2_3b,
        llama4_maverick_400b_a17b,
        grok1_314b,
        musicgen_medium,
        llava_next_34b,
        mamba2_130m,
        hymba_1_5b,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def cell_is_runnable(arch: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch × shape) cell is defined.

    ``long_500k`` needs sub-quadratic attention / O(1) decode state — it is
    skipped (documented N/A) for pure full-attention archs.
    """
    if shape.name == "long_500k" and not arch.supports_long_context:
        return False, "long_500k skipped: full-attention arch (quadratic/unbounded KV)"
    return True, ""


def all_cells(include_skipped: bool = False) -> List[Tuple[ModelConfig, ShapeConfig, bool, str]]:
    out = []
    for arch in ARCHS.values():
        for shape in ALL_SHAPES:
            ok, why = cell_is_runnable(arch, shape)
            if ok or include_skipped:
                out.append((arch, shape, ok, why))
    return out
