"""Hymba-1.5B [hybrid] — parallel attention + mamba heads inside each block,
sliding-window attention (constant-memory decode).  [arXiv:2411.13676; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    hybrid=True,
    attn_window=2048,     # sliding window => O(1) decode memory
    rope_theta=10_000.0,
)
