"""Event-driven asynchronous-iterations engine (paper model (2), §2.1).

This is the *faithful* reproduction substrate: ``p`` simulated processes
free-run local relaxation sweeps at heterogeneous speeds, exchange interface
data over FIFO or non-FIFO channels with random delays, and a pluggable
detection protocol (core/protocols.py) decides termination — exactly the
execution model of the paper's MPI experiments, with the physical platform
replaced by controllable delay distributions and virtual time.

The numerical work per sweep is delegated to a ``DecomposedProblem``
(solvers/partition.py) whose math runs in numpy/JAX; the engine itself is
pure host-side discrete-event simulation (heapq), since protocol logic is
inherently sequential message processing.

**Fused hot path** (``EngineConfig.fused``, default on): when the problem
implements the optional ``update_with_residual(i, x_i, deps) -> (x_new,
r_i)`` extension, the engine prefers it over the ``update`` +
``local_residual`` pair — one ghost assembly and a shared off-diagonal
apply per sweep instead of two full passes.  ``r_i`` is then the residual
of the *pre-sweep* state (the relaxation's free by-product), one sweep
staler than the legacy post-update evaluation — the same staleness the
detection protocols already absorb from the network.  Additionally the
engine asks the protocol (``wants_residual`` hook, default True) whether it
will consume ``r_i`` this iteration and skips residual evaluation entirely
when not — PFAIT never consumes per-iteration residuals (it samples live
state during reductions), and the snapshot protocols stop consuming them
once a worker's record is taken/confirmed.  Protocols receive ``r_i = NaN``
for iterations they declared unused.  ``fused=False`` restores the exact
seed behaviour (benchmarks/bench_fused.py measures the head-to-head).

**Reliability lab hooks**: ``EngineConfig.scenario`` attaches a composable
adversarial-platform scenario (core/scenarios.py) that shapes every sampled
delay, drops/spikes individual messages, slows workers persistently, or
pauses them mid-run; ``AsyncEngine(..., recorder=)`` attaches a trace
recorder (core/reliability.py) observing sweeps, sends/drops, and the
detection instant — the substrate of the false/late-detection oracle.  All
randomness (block-buffered delay draws + scenario effect draws) comes from
the engine's single RNG stream, so a run remains a pure function of
``EngineConfig.seed``.

Measured outputs per run (the paper's reported quantities):
  * ``r_star``  — final exact residual r(x̄) at the instant every process
                  has stopped (Tables 1, 3, 4),
  * ``wtime``   — virtual wall-clock time at full stop (Tables 2, 5),
  * ``k_max``   — max local iteration count over processes (Tables 2, 5),
  * message/byte accounting per message kind (protocol overhead analysis).
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Protocol as TProtocol, Sequence, Tuple

import numpy as np

from repro.core.residual import combine_contributions


# ---------------------------------------------------------------------------
# Problem interface
# ---------------------------------------------------------------------------


class DecomposedProblem(TProtocol):
    """A fixed-point problem x = f(x) decomposed over p workers."""

    p: int
    ord: float  # residual norm order (2.0 or inf)

    def neighbors(self, i: int) -> Sequence[int]: ...

    def init_local(self, i: int) -> np.ndarray: ...

    def update(self, i: int, x_i: np.ndarray, deps: Dict[int, np.ndarray]) -> np.ndarray:
        """One local relaxation sweep using the current dependency view."""
        ...

    def interface(self, i: int, x_i: np.ndarray, j: int) -> np.ndarray:
        """The interface data neighbour j needs from i."""
        ...

    def local_residual(self, i: int, x_i: np.ndarray, deps: Dict[int, np.ndarray]) -> float:
        """r_i — this worker's pre-σ residual contribution w.r.t. its view."""
        ...

    def exact_residual(self, xs: Sequence[np.ndarray]) -> float:
        """r(x̄) for the assembled global vector (ground truth)."""
        ...

    # Optional extension (fused hot path — see module docstring): one sweep
    # with the pre-sweep residual as a by-product.  Must satisfy
    #   update_with_residual(i, x, deps)
    #     == (update(i, x, deps), local_residual(i, x, deps))
    # with r_i None when need_residual=False.  The engine feature-detects it.
    #
    # def update_with_residual(self, i, x_i, deps, need_residual=True):
    #     ...


# ---------------------------------------------------------------------------
# Delay models
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DelayModel:
    """Random delay with scale ``base`` and a jitter floor.

    ``dist`` picks the family:
      * ``lognormal`` — median ``base``, dispersion ``sigma``.  Stable
        single-site platforms (the paper's SGI ICE X) have small sigma;
        unstable/multi-site ones have large sigma.
      * ``pareto``    — ``base·(1 + Pareto(shape))``: heavy tail with index
        ``shape`` (≤ 2 ⇒ infinite variance — grid/WAN-like spikes).
      * ``fixed``     — deterministic ``base`` (hand-built oracle traces).

    Parameters are validated here, at construction: a bad sigma/shape used
    to surface only mid-run as a numpy error deep inside
    ``AsyncEngine.run``.
    """

    base: float
    sigma: float = 0.25
    floor: float = 1e-6
    dist: str = "lognormal"
    shape: float = 1.5  # pareto tail index (dist="pareto" only)

    _DISTS = ("lognormal", "pareto", "fixed")

    def __post_init__(self):
        if not (math.isfinite(self.base) and self.base > 0.0):
            raise ValueError(f"DelayModel.base={self.base} must be finite > 0")
        if not (math.isfinite(self.sigma) and self.sigma >= 0.0):
            raise ValueError(
                f"DelayModel.sigma={self.sigma} must be finite >= 0")
        if not (math.isfinite(self.floor) and self.floor >= 0.0):
            raise ValueError(
                f"DelayModel.floor={self.floor} must be finite >= 0")
        if self.dist not in self._DISTS:
            raise ValueError(
                f"DelayModel.dist={self.dist!r} not in {self._DISTS}")
        if self.dist == "pareto" and not (
                math.isfinite(self.shape) and self.shape > 0.0):
            raise ValueError(
                f"DelayModel.shape={self.shape} must be finite > 0")

    def sample(self, rng: np.random.Generator, n: Optional[int] = None):
        if n is None:  # scalar fast path — the engine hot loop draws ~4/sweep
            if self.dist == "lognormal":
                s = self.base * rng.lognormal(mean=0.0, sigma=self.sigma)
            elif self.dist == "pareto":
                s = self.base * (1.0 + rng.pareto(self.shape))
            else:  # fixed
                s = self.base
            return max(s, self.floor)
        if self.dist == "lognormal":
            s = self.base * rng.lognormal(mean=0.0, sigma=self.sigma, size=n)
        elif self.dist == "pareto":
            s = self.base * (1.0 + rng.pareto(self.shape, size=n))
        else:
            s = np.full(n, self.base)
        return np.maximum(s, self.floor)


class _BufferedSampler:
    """Block-buffered scalar draws from a ``DelayModel``.

    The engine's hot loop draws ~4 scalar delays per sweep; one vectorised
    draw of ``block`` samples amortises numpy's per-call dispatch ~10×.
    Draws still come from the engine's single RNG stream (a refill consumes
    ``block`` generator variates at once), so a run remains a pure function
    of ``EngineConfig.seed`` — the values are the model's distribution
    exactly, only the stream's *interleaving* with other consumers differs
    from scalar draws.
    """

    __slots__ = ("model", "rng", "block", "_buf", "_pos")

    def __init__(self, model: DelayModel, rng: np.random.Generator,
                 block: int = 1024):
        self.model = model
        self.rng = rng
        self.block = block
        self._buf = model.sample(rng, block)
        self._pos = 0

    def __call__(self) -> float:
        pos = self._pos
        if pos == self.block:
            self._buf = self.model.sample(self.rng, self.block)
            pos = 0
        self._pos = pos + 1
        return float(self._buf[pos])


@dataclass(frozen=True)
class EngineConfig:
    compute: DelayModel                    # per-sweep compute duration
    channel: DelayModel                    # per-message network delay
    fifo: bool = False                     # FIFO channel delivery
    hop_latency: float = 5e-5              # reduction/broadcast per-hop latency
    het_factor: float = 0.3                # per-process speed heterogeneity
    max_time: float = 1e9
    max_iters: int = 200_000
    seed: int = 0
    fused: bool = True                     # prefer update_with_residual + skip
                                           # residuals the protocol won't read
    scenario: Optional[Any] = None         # core.scenarios.Scenario — adversarial
                                           # platform effects (None = plain)


# paper-flavoured presets.  Delays are scaled so that interface data and
# reduction rounds span a few sweeps (the paper's runs at 15–20k iterations
# have reductions spanning dozens of iterations — same relative staleness at
# our reduced iteration counts), which is what makes PFAIT's inconsistency
# observable while snapshot records stay consistent.
def stable_platform(compute_base: float = 1e-3) -> EngineConfig:
    """Single-site HPC platform (paper's setting): tight delay distribution."""
    return EngineConfig(
        compute=DelayModel(compute_base, sigma=0.15),
        channel=DelayModel(compute_base * 1.5, sigma=0.4),
        hop_latency=compute_base,
        het_factor=0.15,
    )


def unstable_platform(compute_base: float = 1e-3) -> EngineConfig:
    """Heavy-tailed delays / strong heterogeneity (grid-like)."""
    return EngineConfig(
        compute=DelayModel(compute_base, sigma=0.8),
        channel=DelayModel(compute_base * 3.0, sigma=1.2),
        hop_latency=2 * compute_base,
        het_factor=0.8,
    )


def heavy_tail_platform(compute_base: float = 1e-3) -> EngineConfig:
    """Pareto channel latency (tail index 1.2 ⇒ infinite variance): steady
    compute, but occasional message delays orders of magnitude above the
    median — the WAN/grid regime of the reliability lab."""
    return EngineConfig(
        compute=DelayModel(compute_base, sigma=0.2),
        channel=DelayModel(compute_base * 2.0, dist="pareto", shape=1.2),
        hop_latency=compute_base,
        het_factor=0.3,
    )


PLATFORMS = {
    "stable": stable_platform,
    "unstable": unstable_platform,
    "heavy_tail": heavy_tail_platform,
}


# ---------------------------------------------------------------------------
# Messages
# ---------------------------------------------------------------------------


@dataclass(slots=True)
class Msg:
    src: int
    dst: int
    kind: str          # "data" | "marker" | "snap2" | "snap5" | "confirm5"
    payload: Any = None
    round: int = 0
    send_time: float = 0.0
    nbytes: int = 0


@dataclass
class RunResult:
    terminated: bool
    detect_time: float
    wtime: float
    k_max: int
    k_min: int
    r_star: float
    detected_residual: float
    msg_counts: Dict[str, int]
    msg_bytes: Dict[str, int]
    reductions: int
    protocol: str
    msg_dropped: Dict[str, int] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class AsyncEngine:
    """Discrete-event simulator of asynchronous iterations + detection."""

    def __init__(self, problem: DecomposedProblem, cfg: EngineConfig, protocol,
                 recorder=None):
        self.problem = problem
        self.cfg = cfg
        self.protocol = protocol
        self.scenario = cfg.scenario       # core.scenarios.Scenario | None
        self.recorder = recorder           # core.reliability.TraceRecorder | None
        # per-hook scenario dispatch: skip the per-event call entirely when
        # no effect shapes that hook (identity hooks draw no RNG, so this
        # cannot change a run).  Pruning applies ONLY to the stock
        # effect-composition dispatchers — a Scenario subclass (or
        # duck-typed object) overriding a hook method itself is always
        # called, whatever its effects tuple says.
        sc = cfg.scenario

        def _sc_for(hook: str, effects_attr: str):
            if sc is None:
                return None
            from repro.core.scenarios import Scenario

            if getattr(type(sc), hook, None) is not getattr(Scenario, hook):
                return sc  # custom hook implementation: never prune
            return sc if getattr(sc, effects_attr, True) else None

        self._sc_channel = _sc_for("channel_delay", "channel_effects")
        self._sc_compute = _sc_for("compute_delay", "compute_effects")
        self._sc_pause = _sc_for("paused_until", "pause_effects")
        # send-event observer, resolved once: recorders in lite mode
        # (record_sends=False) skip the per-message callback entirely
        self._send_observer = (
            recorder.on_send
            if recorder is not None and getattr(recorder, "record_sends", True)
            else None)
        self.rng = np.random.default_rng(cfg.seed)
        p = problem.p
        self.p = p
        # fused hot path: feature-detect the optional problem/protocol hooks
        self._use_fused = cfg.fused and callable(
            getattr(problem, "update_with_residual", None)
        )
        self._wants_residual = getattr(protocol, "wants_residual", None)
        # per-process state
        self.x: List[np.ndarray] = [problem.init_local(i) for i in range(p)]
        self.deps: List[Dict[int, np.ndarray]] = [dict() for _ in range(p)]
        # plain lists, not ndarrays: the event loop reads these hundreds of
        # thousands of times per run and numpy scalar indexing costs ~5× a
        # list index
        self.k: List[int] = [0] * p
        self.speed = (1.0 + cfg.het_factor * self.rng.random(p)).tolist()
        self.stop_time: List[float] = [math.inf] * p
        self._stop_max = math.inf   # max(stop_time), set once at terminate
        # block-buffered scalar delay draws (hot loop; see _BufferedSampler)
        self._draw_compute = _BufferedSampler(cfg.compute, self.rng)
        self._draw_channel = _BufferedSampler(cfg.channel, self.rng)
        # seed dependency views with initial interfaces (standard: x^0 known)
        for i in range(p):
            for j in problem.neighbors(i):
                self.deps[i][j] = problem.interface(j, self.x[j], i)
        # -- dynamic membership (core.scenarios crash/join/restart) --------
        # Timelines are static (declared at scenario construction), so the
        # member/checkpoint events below are scheduled once here, consume no
        # RNG draws, and leave non-membership runs event-identical.
        self.active: List[bool] = [True] * p
        self.membership_changes = 0
        member_events: Tuple[Tuple[float, str, int], ...] = ()
        if sc is not None and getattr(sc, "elastic", False):
            member_events = sc.membership_events()
            for t_ev, kind_ev, w in member_events:
                if not 0 <= w < p:
                    raise ValueError(
                        f"membership event {kind_ev!r} targets worker {w} "
                        f"outside 0..{p - 1}")
            for w in sc.initially_inactive():
                self.active[w] = False
        self._has_membership = bool(member_events)
        # readmission times per worker (parked compute chains resume there)
        self._resume_at: Dict[int, List[float]] = {}
        # periodic state snapshots backing "restore" events
        self._ckpt_state: List[Optional[Tuple]] = [None] * p
        # event queue
        self._heap: List[Tuple[float, int, str, Any]] = []
        self._counter = itertools.count()
        self._fifo_last: Dict[Tuple[int, int], float] = {}
        if self._has_membership:
            for t_ev, kind_ev, w in member_events:
                if kind_ev in ("join", "restore"):
                    self._resume_at.setdefault(w, []).append(t_ev)
            restores = [t_ev for t_ev, k_ev, _ in member_events
                        if k_ev == "restore"]
            every = getattr(sc, "checkpoint_every", None)
            if restores and every:
                # snapshots are only consumed by restores — schedule the
                # bounded prefix of the cadence, keeping the heap drainable
                n_ckpt = int(math.floor(max(restores) / every)) + 1
                for m in range(1, n_ckpt + 1):
                    self.schedule(m * every, "ckpt", None)
            for t_ev, kind_ev, w in member_events:
                self.schedule(t_ev, "member", (kind_ev, w))
        # accounting
        self.msg_counts: Dict[str, int] = {}
        self.msg_bytes: Dict[str, int] = {}
        self.msg_dropped: Dict[str, int] = {}
        self.reductions_started = 0
        # termination
        self.detect_time: Optional[float] = None
        self.detected_residual: float = float("inf")
        self.now = 0.0
        self._exhaust_deadline: Optional[float] = None

    # -- event plumbing ----------------------------------------------------
    def schedule(self, t: float, kind: str, payload: Any = None) -> None:
        heapq.heappush(self._heap, (t, next(self._counter), kind, payload))

    def send(self, msg: Msg, t: float) -> None:
        """Send a message over channel (src→dst) honouring FIFO-ness.

        With a scenario attached, the sampled delay passes through
        ``scenario.channel_delay`` — which may inflate it (bursts, tail
        spikes) or return None to drop the message entirely (lossy
        channels).  Dropped messages are accounted in ``msg_dropped`` and
        never delivered."""
        delay = self._draw_channel()
        if self._sc_channel is not None:
            shaped = self._sc_channel.channel_delay(t, msg.kind, delay, self.rng)
            if shaped is None:
                msg.send_time = t
                self.msg_dropped[msg.kind] = self.msg_dropped.get(msg.kind, 0) + 1
                if self._send_observer is not None:
                    self._send_observer(self, msg, t, None)
                return
            delay = float(shaped)
        deliver = t + delay
        if self.cfg.fifo:
            key = (msg.src, msg.dst)
            deliver = max(deliver, self._fifo_last.get(key, 0.0) + 1e-12)
            self._fifo_last[key] = deliver
        msg.send_time = t
        if msg.nbytes == 0:
            p = msg.payload
            if isinstance(p, np.ndarray):
                msg.nbytes = p.nbytes
            else:
                msg.nbytes = int(np.asarray(p).nbytes) if p is not None else 16
        self.msg_counts[msg.kind] = self.msg_counts.get(msg.kind, 0) + 1
        self.msg_bytes[msg.kind] = self.msg_bytes.get(msg.kind, 0) + msg.nbytes
        if self._send_observer is not None:
            self._send_observer(self, msg, t, deliver)
        self.schedule(deliver, "deliver", msg)

    def _send_data(self, i: int, j: int, t: float) -> None:
        """Data-message send with the payload built lazily *after* the drop
        decision: under lossy scenarios (interface blackout drops every data
        message) the interface extraction and Msg construction of a dropped
        message are pure overhead — the engine never counts their bytes.
        Draw order (delay, then scenario) matches ``send`` exactly."""
        delay = self._draw_channel()
        if self._sc_channel is not None:
            shaped = self._sc_channel.channel_delay(t, "data", delay, self.rng)
            if shaped is None:
                self.msg_dropped["data"] = self.msg_dropped.get("data", 0) + 1
                if self._send_observer is not None:
                    self._send_observer(
                        self, Msg(src=i, dst=j, kind="data", send_time=t),
                        t, None)
                return
            delay = float(shaped)
        deliver = t + delay
        if self.cfg.fifo:
            key = (i, j)
            deliver = max(deliver, self._fifo_last.get(key, 0.0) + 1e-12)
            self._fifo_last[key] = deliver
        payload = self.problem.interface(i, self.x[i], j)
        msg = Msg(src=i, dst=j, kind="data", payload=payload,
                  send_time=t, nbytes=payload.nbytes)
        self.msg_counts["data"] = self.msg_counts.get("data", 0) + 1
        self.msg_bytes["data"] = self.msg_bytes.get("data", 0) + payload.nbytes
        if self._send_observer is not None:
            self._send_observer(self, msg, t, deliver)
        self.schedule(deliver, "deliver", msg)

    # -- reduction service ---------------------------------------------------
    def start_reduction(
        self,
        sample_fn: Callable[[int, float], float],
        on_complete: Callable[[np.ndarray, float], None],
        t: float,
    ) -> None:
        """Non-blocking tree reduction: contribution of worker i is sampled at
        a staggered time (this is the PFAIT inconsistency), completion fires
        2·ceil(log2 p)·hop after the last contribution.

        Under dynamic membership the reduction spans the workers active at
        *launch* (offset draws still cover all p slots, so the RNG stream is
        membership-independent): a worker that crashes before its sample
        time contributes NaN (the combiner skips it), one that joins
        mid-reduction waits for the next launch."""
        self.reductions_started += 1
        offsets = self.cfg.channel.sample(self.rng, self.p)
        if self._sc_channel is not None:
            # collectives are lossless-but-slow: scenario effects shape the
            # staggered sampling offsets (kind="reduce") but never drop them
            offsets = np.array([
                shaped if (shaped := self._sc_channel.channel_delay(
                    t, "reduce", float(o), self.rng)) is not None else float(o)
                for o in offsets
            ])
        sample_times = t + offsets
        contribs = np.full(self.p, np.nan)
        active = self.active
        participants = [i for i in range(self.p) if active[i]]
        if not participants:
            return  # empty membership: nothing to reduce, never completes
        remaining = [len(participants)]

        def make_sampler(i, ts):
            def fire(_):
                if active[i]:
                    contribs[i] = sample_fn(i, ts)
                remaining[0] -= 1
                if remaining[0] == 0:
                    done_t = float(max(
                        float(sample_times[j]) for j in participants
                    )) + 2 * math.ceil(
                        math.log2(max(self.p, 2))
                    ) * self.cfg.hop_latency
                    self.schedule(done_t, "callback", lambda tt: on_complete(contribs, tt))

            return fire

        for i in participants:
            self.schedule(float(sample_times[i]), "callback", make_sampler(i, float(sample_times[i])))

    # -- termination ---------------------------------------------------------
    def terminate(self, t: float, detected_residual: float) -> None:
        if self.detect_time is not None:
            return
        self.detect_time = t
        self.detected_residual = detected_residual
        if self.recorder is not None:
            self.recorder.on_detect(self, t, detected_residual)
        bcast = math.ceil(math.log2(max(self.p, 2))) * self.cfg.hop_latency
        for i in range(self.p):
            self.stop_time[i] = t + bcast + self._draw_channel()
        self._stop_max = max(self.stop_time)

    # -- main loop -------------------------------------------------------------
    def run(self) -> RunResult:
        cfg = self.cfg
        for i in range(self.p):
            if not self.active[i]:
                continue  # late joiners sweep from their admission event
            dt = self._draw_compute() * self.speed[i]
            if self._sc_compute is not None:
                dt = self._sc_compute.compute_delay(0.0, i, dt, self.rng)
            self.schedule(dt, "compute", i)
        self.protocol.on_start(self, 0.0)

        # hot-loop locals: the dispatcher pops hundreds of thousands of
        # events per run, and attribute lookups at that rate are a
        # measurable slice of every reliability-matrix cell
        heap = self._heap
        heappop_, heappush_ = heapq.heappop, heapq.heappush
        counter = self._counter
        k, x, deps, stop_time = self.k, self.x, self.deps, self.stop_time
        speed = self.speed
        problem = self.problem
        neighbors = [problem.neighbors(i) for i in range(self.p)]
        max_iters, max_time = cfg.max_iters, cfg.max_time
        use_fused, wants_residual = self._use_fused, self._wants_residual
        update_with_residual = getattr(problem, "update_with_residual", None)
        update, local_residual = problem.update, problem.local_residual
        protocol = self.protocol
        on_iteration, on_data, on_message = (
            protocol.on_iteration, protocol.on_data, protocol.on_message)
        recorder = self.recorder
        sc_pause, sc_compute = self._sc_pause, self._sc_compute
        draw_compute = self._draw_compute
        send_data = self._send_data
        rng = self.rng
        nan = float("nan")
        active = self.active  # mutated in place by _apply_membership

        while heap:
            t, _, kind, payload = heappop_(heap)
            self.now = t
            if t > max_time:
                break
            if self.detect_time is not None and t > self._stop_max:
                break
            if (self._exhaust_deadline is not None
                    and self.detect_time is None
                    and t > self._exhaust_deadline):
                # every worker hit max_iters and no detection fired within
                # the grace window: the state is frozen, so endlessly
                # relaunching reductions (PFAIT) would never terminate —
                # return undetected instead of hanging
                break
            if kind == "compute":
                i = payload
                if not active[i]:
                    # crashed worker: park the compute chain at its next
                    # readmission (restore/join) time, or sever it for good
                    # — parking keeps readmitted workers on ONE chain (no
                    # duplicate scheduling, no extra RNG draws)
                    for rt in self._resume_at.get(i, ()):
                        if rt > t:
                            heappush_(heap, (rt, next(counter), "compute", i))
                            break
                    continue
                if sc_pause is not None:
                    resume = sc_pause.paused_until(t, i)
                    if resume is not None and resume > t:
                        # mid-run pause: the sweep that would have started
                        # now is deferred to the resume time
                        heappush_(heap, (resume, next(counter), "compute", i))
                        continue
                if t > stop_time[i] or k[i] >= max_iters:
                    if (k[i] >= max_iters
                            and self._exhaust_deadline is None
                            and min(kk for kk, al in zip(k, active)
                                    if al) >= max_iters):
                        # grace: let in-flight data drain + a few reduction
                        # rounds sample the final (now frozen) state (over
                        # the *active* membership — a crashed worker's
                        # frozen counter must not block exhaustion)
                        self._exhaust_deadline = t + 100 * (
                            cfg.channel.base + cfg.hop_latency
                        )
                    continue
                if use_fused:
                    need_r = (wants_residual is None
                              or wants_residual(self, i))
                    x[i], r_i = update_with_residual(
                        i, x[i], deps[i], need_residual=need_r
                    )
                    if r_i is None:
                        r_i = nan  # protocol declared it unused
                else:
                    x[i] = update(i, x[i], deps[i])
                    r_i = local_residual(i, x[i], deps[i])
                k[i] += 1
                for j in neighbors[i]:
                    send_data(i, j, t)
                if recorder is not None:
                    recorder.on_sweep(self, t, i)
                on_iteration(self, i, t, r_i)
                dt = draw_compute() * speed[i]
                if sc_compute is not None:
                    dt = sc_compute.compute_delay(t, i, dt, rng)
                heappush_(heap, (t + dt, next(counter), "compute", i))
            elif kind == "deliver":
                msg: Msg = payload
                if not active[msg.dst]:
                    continue  # messages to crashed/absent workers are lost
                if msg.kind == "data":
                    if t <= stop_time[msg.dst]:
                        deps[msg.dst][msg.src] = msg.payload
                        on_data(self, msg, t)
                else:
                    on_message(self, msg, t)
            elif kind == "callback":
                payload(t)
            elif kind == "member":
                self._apply_membership(payload, t)
            elif kind == "ckpt":
                self._take_checkpoint(t)

        wtime = self._stop_max if self.detect_time is not None else self.now
        r_star = self.problem.exact_residual(self.x)
        result = RunResult(
            terminated=self.detect_time is not None,
            detect_time=self.detect_time if self.detect_time is not None else float("inf"),
            wtime=wtime,
            k_max=int(max(self.k)),
            k_min=int(min(self.k)),
            r_star=float(r_star),
            detected_residual=float(self.detected_residual),
            msg_counts=dict(self.msg_counts),
            msg_bytes=dict(self.msg_bytes),
            reductions=self.reductions_started,
            protocol=type(self.protocol).__name__,
            msg_dropped=dict(self.msg_dropped),
        )
        if self.recorder is not None:
            self.recorder.on_finish(self, result)
        return result

    # -- dynamic membership -------------------------------------------------
    def _apply_membership(self, ev: Tuple[str, int], t: float) -> None:
        kind, w = ev
        if kind == "crash":
            if not self.active[w]:
                return
            self.active[w] = False
        else:  # "join" | "restore"
            if self.active[w]:
                return
            if kind == "restore":
                snap = self._ckpt_state[w]
                if snap is not None:
                    x_w, deps_w, _k_w = snap
                    self.x[w] = np.array(x_w, copy=True)
                    self.deps[w] = {j: np.array(a, copy=True)
                                    for j, a in deps_w.items()}
                else:
                    # crashed before the first snapshot: cold restart from
                    # the initial state (x^0 + t=0 interface views)
                    self.x[w] = self.problem.init_local(w)
                    self.deps[w] = {
                        j: self.problem.interface(
                            j, self.problem.init_local(j), w)
                        for j in self.problem.neighbors(w)}
            self.active[w] = True
            if kind == "join":
                # late joiner: no compute chain exists yet — start one.
                # (A restored worker's chain was parked by the event loop
                # and resumes at this instant on its own.)
                dt = self._draw_compute() * self.speed[w]
                if self._sc_compute is not None:
                    dt = self._sc_compute.compute_delay(t, w, dt, self.rng)
                self.schedule(t + dt, "compute", w)
        self.membership_changes += 1
        if self.recorder is not None:
            hook = getattr(self.recorder, "on_membership", None)
            if hook is not None:
                hook(self, t, kind, w)
        hook = getattr(self.protocol, "on_membership", None)
        if hook is not None:
            hook(self, t, kind, w)

    def _take_checkpoint(self, t: float) -> None:
        for i in range(self.p):
            if self.active[i]:
                self._ckpt_state[i] = (
                    np.array(self.x[i], copy=True),
                    {j: np.array(a, copy=True)
                     for j, a in self.deps[i].items()},
                    self.k[i],
                )

    def active_workers(self) -> List[int]:
        return [i for i in range(self.p) if self.active[i]]

    def exact_active_residual(self, xs: Optional[Sequence] = None) -> float:
        """Exact residual of the *active* subsystem: contributions from
        active workers only, with fresh interface views assembled from
        ``xs`` (default: live state) for active neighbours.  This is the
        ground truth a detection claim is scored against once the
        membership has changed — inactive blocks are boundary data, not
        unknowns (dynamic asynchronous iterations converge to the fixed
        point of the active subsystem).

        An *inactive* neighbour's boundary value is the receiver's frozen
        delivered view (``deps[i][j]``), not ``interface(x_j)``: over
        non-FIFO channels the dead worker's final interface message can be
        overtaken by an older one, so the survivors' fixed point is defined
        by what was actually delivered — the dead block's final state is
        unobservable to any detector, oracle included."""
        xs = self.x if xs is None else xs
        prob = self.problem
        active = self.active
        contribs = []
        for i in range(self.p):
            if not active[i]:
                continue
            deps_i = {j: (prob.interface(j, xs[j], i) if active[j]
                          else self.deps[i][j])
                      for j in prob.neighbors(i)}
            contribs.append(prob.local_residual(i, xs[i], deps_i))
        return float(combine_contributions(contribs, prob.ord))

    # convenience for protocols
    def live_local_residual(self, i: int) -> float:
        fast = getattr(self.problem, "local_residual_fast", None)
        if self._use_fused and callable(fast):
            return fast(i, self.x[i], self.deps[i])
        return self.problem.local_residual(i, self.x[i], self.deps[i])
