"""Threshold-calibration methodology (paper §4.2).

PFAIT has no correctness protocol: its safety comes from a *margin* between
the detection threshold ε and the desired precision ε̃, calibrated from the
observed stability of the platform.  The paper's recipe:

1. run the solver repeatedly on a small/cheap instance with ε = ε̃ and
   observe the distribution of final exact residuals r*;
2. compute the worst overshoot ratio ρ = max r* / ε;
3. pick the margin as the next power of ten ≥ ρ·s (safety factor s) —
   decade steps, because the paper found *intermediate* thresholds (4e-7)
   behave less predictably than decade thresholds (1e-7);
4. production runs use ε = ε̃ / margin.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Sequence


@dataclass(frozen=True)
class CalibrationReport:
    eps_probe: float
    residuals: tuple
    min_r: float
    max_r: float
    overshoot: float          # max r* / ε_probe
    margin: float             # recommended ε̃ / ε
    eps_production: float     # ε for the target ε̃


def calibrate_margin(
    solve: Callable[[float], float],
    eps_tilde: float,
    runs: int = 5,
    safety: float = 2.0,
) -> CalibrationReport:
    """Run ``solve(eps) -> final exact residual`` repeatedly at ε = ε̃ and
    derive the production threshold (decade-quantised margin)."""
    rs = [float(solve(eps_tilde)) for _ in range(runs)]
    max_r = max(rs)
    overshoot = max_r / eps_tilde
    margin = decade_margin(overshoot * safety)
    return CalibrationReport(
        eps_probe=eps_tilde,
        residuals=tuple(rs),
        min_r=min(rs),
        max_r=max_r,
        overshoot=overshoot,
        margin=margin,
        eps_production=eps_tilde / margin,
    )


def decade_margin(ratio: float) -> float:
    """Smallest power of ten ≥ ratio (and ≥ 1)."""
    if ratio <= 1.0:
        return 1.0
    return 10.0 ** math.ceil(math.log10(ratio))


def stability_band(residuals: Sequence[float], eps: float) -> tuple:
    """The paper's platform-stability summary: (min r*−ε, max r*−ε)."""
    rs = list(residuals)
    return (min(rs) - eps, max(rs) - eps)


# ---------------------------------------------------------------------------
# Oracle scoring (stochastic / ML residual traces)
# ---------------------------------------------------------------------------


def oracle_detect_step(residuals: Sequence[float], eps: float):
    """First index where the exact residual trace crosses below ε — the
    step a synchronized eval would have stopped at — or None if it never
    does.  This is the ground truth an asynchronous detection step is
    scored against."""
    for k, r in enumerate(residuals):
        if float(r) < eps:
            return k
    return None


def detection_consistent(
    detected_step: int,
    residuals: Sequence[float],
    eps: float,
    factor: float = 10.0,
) -> bool:
    """Decade-consistency of a detection against an exact residual trace.

    Stochastic residuals (minibatch SGD) wander within a band rather than
    decrease monotonically, so exact step equality with the synchronized
    oracle is the wrong test.  The paper's decade convention instead asks
    that at the detected step the *true* residual was already within one
    decade of ε: r_exact[min(k, end)] < factor·ε, and that the oracle
    crossing exists at all (no false detection on a non-converging run).
    """
    oracle = oracle_detect_step(residuals, eps)
    if oracle is None:
        return False
    if detected_step is None:
        return False
    k = min(int(detected_step), len(residuals) - 1)
    return float(residuals[k]) < factor * eps
