"""Faithful event-level detection protocols (paper §3 + refs [12, 15, 6]).

Each protocol plugs into ``core.async_engine.AsyncEngine`` via four hooks:

    on_start(engine, t)            — simulation begins
    on_iteration(engine, i, t, r)  — worker i finished a sweep, local residual r
    on_data(engine, msg, t)        — a computation message was delivered
    on_message(engine, msg, t)     — a protocol message was delivered

Implemented protocols:

* ``PFAIT``             — the paper: successive non-blocking reductions over
                          live local residuals; zero protocol messages.
* ``NFAIS2``            — SB96-style snapshot [15]/[12]: snapshot messages
                          *carry interface data* → consistent records, exact
                          residual of the snapshot vector; O(n) msg bytes.
* ``NFAIS5``            — approximate snapshot [12]: empty snapshot messages
                          record last-delivered dependencies; persistence m +
                          confirmation phase; O(1) msg bytes, residual exact
                          up to (1+c(p,m))ε.
* ``ExactSnapshotFIFO`` — Chandy–Lamport marker protocol [6] adapted to
                          asynchronous iterations [12]; requires FIFO
                          channels; consistent cut → exact residual.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.async_engine import AsyncEngine, Msg
from repro.core.residual import combine_contributions


class BaseProtocol:
    name = "base"
    #: what a detection *claims* (the reliability oracle scores against it):
    #: "live"     — the live global residual is < ε (PFAIT samples live
    #:              state; NFAIS5 records approximate, data-free views),
    #: "recorded" — a recorded consistent global vector has residual < ε
    #:              (NFAIS2 / Chandy–Lamport carry or pin the actual data;
    #:              the certified solution is the record, not whatever the
    #:              live state drifts to before the stop broadcast lands).
    claim = "live"

    def __init__(self, eps: float, ord: float = 2.0):
        self.eps = float(eps)
        self.ord = ord

    def recorded_vector(self):
        """The recorded global vector backing a "recorded" claim (list of
        per-worker blocks), or None when the protocol has no record."""
        return None

    def on_start(self, eng: AsyncEngine, t: float) -> None:  # pragma: no cover
        pass

    def on_iteration(self, eng: AsyncEngine, i: int, t: float, r_i: float) -> None:
        pass

    def on_data(self, eng: AsyncEngine, msg: Msg, t: float) -> None:
        pass

    def on_message(self, eng: AsyncEngine, msg: Msg, t: float) -> None:
        pass

    def wants_residual(self, eng: AsyncEngine, i: int) -> bool:
        """Will ``on_iteration`` consume ``r_i`` for worker i this iteration?
        The engine's fused path skips residual evaluation when False (the
        protocol then receives ``r_i = NaN``)."""
        return True

    def on_membership(self, eng: AsyncEngine, t: float, kind: str,
                      worker: int) -> None:
        """The participant set changed (kind ∈ {"crash", "join", "restore"},
        core.scenarios membership primitives).  Default: no bookkeeping —
        PFAIT's reductions are re-launched over the live active set anyway;
        snapshot protocols override to invalidate their records (a record
        quorum taken over the old membership certifies the wrong system)."""
        pass

    # shared helper: tree-reduction completion latency
    def _reduce_latency(self, eng: AsyncEngine) -> float:
        return 2 * math.ceil(math.log2(max(eng.p, 2))) * eng.cfg.hop_latency

    # shared helper: residual of a *complete* recorded view (snapshot
    # reduce paths guarantee every neighbour is present, so the problem's
    # buffered fast path is valid; gated on cfg.fused so the unfused
    # baseline keeps the seed code path)
    def _record_residual(self, eng: AsyncEngine, i: int, own, deps) -> float:
        if eng.cfg.fused:
            fast = getattr(eng.problem, "local_residual_fast", None)
            if fast is not None:
                return fast(i, own, deps)
        return eng.problem.local_residual(i, own, deps)

    # shared helper for the snapshot protocols under dynamic membership: a
    # crashed neighbour sends no snapshot message, but its interface is
    # frozen boundary data — complete the record with the current delivered
    # view (identical to the frozen worker's last sent interface once
    # in-flight messages drain).  No-op (returns the record unchanged) when
    # every neighbour is active.
    def _record_deps_with_boundary(self, eng: AsyncEngine, i: int) -> Dict:
        deps = self.rec_deps[i]
        missing = [j for j in eng.problem.neighbors(i)
                   if j not in deps and not eng.active[j]]
        if not missing:
            return deps
        out = dict(deps)
        for j in missing:
            out[j] = eng.deps[i][j]
        return out


# ---------------------------------------------------------------------------
# PFAIT — the paper's protocol-free termination
# ---------------------------------------------------------------------------


class PFAIT(BaseProtocol):
    """Successive non-blocking reductions of free-running local residuals.

    Contributions are sampled at staggered times from each worker's *live*
    state (stale dependency views included) — the source of the detection
    inconsistency the paper calibrates away with the ε-margin.
    """

    name = "pfait"

    def wants_residual(self, eng: AsyncEngine, i: int) -> bool:
        # Zero per-iteration detection work: contributions are sampled from
        # live state by the reduction service, never from on_iteration.
        return False

    def on_start(self, eng: AsyncEngine, t: float) -> None:
        self._gen = 0
        self._launch(eng, t)

    def on_membership(self, eng: AsyncEngine, t: float, kind: str,
                      worker: int) -> None:
        # In-flight reductions carry samples of *pre-change* state.  For a
        # crash that is harmless (the survivors' residuals keep shrinking),
        # but a join or checkpoint-restore makes convergence non-monotone:
        # a reduction whose samples all predate a rollback would certify a
        # state that no longer exists (observed as a false detection on the
        # crash_restart scenario).  Membership changes are engine-visible
        # events, so the honest semantics is the elastic one: discard every
        # chain sampled under the old membership and relaunch fresh.
        self._gen += 1
        self._launch(eng, t)

    def _launch(self, eng: AsyncEngine, t: float) -> None:
        if eng.detect_time is not None:
            return
        gen = self._gen

        def complete(contribs: np.ndarray, tc: float) -> None:
            if gen != self._gen:
                return  # superseded chain: sampled under old membership
            # NaN slots are workers outside the membership at launch or
            # crashed before their sample time — the reduction spans the
            # remaining participants (protocol-free: no bookkeeping, the
            # next launch simply covers the new active set)
            vals = contribs[~np.isnan(contribs)]
            if vals.size == 0:
                self._launch(eng, tc)
                return
            g = combine_contributions(vals, self.ord)
            if g < self.eps:
                eng.terminate(tc, g)
            else:
                self._launch(eng, tc)

        eng.start_reduction(
            sample_fn=lambda i, ts: eng.live_local_residual(i),
            on_complete=complete,
            t=t,
        )


# ---------------------------------------------------------------------------
# Modified recursive doubling — decentralised reduction protocol baseline
# ---------------------------------------------------------------------------


class RecursiveDoublingProtocol(BaseProtocol):
    """Modified recursive doubling (Zou & Magoulès 2019): the protocol-based
    alternative the shard runtime benchmarks PFAIT against on device.

    Workers run free-running reduction *epochs* over the butterfly: in round
    r of an epoch, worker i exchanges its partial residual sum with partner
    ``i XOR 2^r``; after log2(p) rounds every worker holds the epoch's
    global sum and checks it against ε *independently* (no root, no
    broadcast tree).  Contributions are sampled from live state at each
    worker's epoch start — staggered like PFAIT's, so the detection claim
    is "live" and the ε-margin methodology applies unchanged.  Unlike PFAIT
    the reduction itself is carried by point-to-point protocol messages
    (p·log2(p) per epoch), which is exactly the overhead the paper's
    protocol-free detection removes.

    Requires a power-of-two worker count (the classic butterfly); the
    on-device twin lives in ``runtime/shard_runtime.py`` (``rdoubling``).
    """

    name = "rdub"

    #: sentinel "rounds" for the non-power-of-two remainder fold: at epoch
    #: start an extra rank pre-combines its contribution into a butterfly
    #: participant (FOLD) and receives the epoch total back (RESULT) — the
    #: classic MPI reduce trick that generalises the butterfly to any
    #: membership size after a crash/join
    FOLD = -1
    RESULT = -2

    def __init__(self, eps: float, ord: float = 2.0):
        super().__init__(eps, ord)

    def wants_residual(self, eng: AsyncEngine, i: int) -> bool:
        # like PFAIT: contributions are sampled from live state at epoch
        # starts, never from per-iteration residuals
        return False

    def _acc(self, a: float, b: float) -> float:
        return max(a, b) if math.isinf(self.ord) else a + b

    @staticmethod
    def _geometry(m: int) -> Tuple[int, int, int, int]:
        """(m, q, rounds, rem): q = largest power of two ≤ m runs the
        butterfly; rem = m − q extra ranks fold into ranks 0..rem−1."""
        q = 1 << (m.bit_length() - 1)
        return m, q, q.bit_length() - 1, m - q

    def on_start(self, eng: AsyncEngine, t: float) -> None:
        p = eng.p
        if p & (p - 1):
            raise ValueError(
                f"RecursiveDoublingProtocol requires a power-of-two worker "
                f"count, got p={p}")
        # epoch/round messages are stamped with a membership generation:
        # a crash/join bumps it and restarts every epoch over the new
        # member list, so stragglers from the old geometry are discarded
        self.generation = 0
        self.members: Tuple[int, ...] = tuple(eng.active_workers())
        self._geom = self._geometry(max(len(self.members), 1))
        self.epoch = [0] * p
        self.rnd = [0] * p
        self.partial = [0.0] * p
        self.folded = [True] * p
        # out-of-order buffer: partner partials keyed by (epoch, round) —
        # bounded, because a partner cannot advance a round without our
        # reply for the previous one
        self.pending: List[Dict[Tuple[int, int], float]] = [
            dict() for _ in range(p)]
        for i in self.members:
            self._begin_epoch(eng, i, t)

    def on_membership(self, eng: AsyncEngine, t: float, kind: str,
                      worker: int) -> None:
        if eng.detect_time is not None:
            return
        self.generation += 1
        self.members = tuple(eng.active_workers())
        for buf in self.pending:
            buf.clear()
        if not self.members:
            return
        self._geom = self._geometry(len(self.members))
        # epoch counters restart from a common base: workers completed
        # *different* epoch counts in the old generation, and partners key
        # buffered partials by (epoch, round) — mismatched absolute counters
        # would deadlock the new butterfly (the generation stamp already
        # quarantines every old-geometry message)
        self.epoch = [0] * eng.p
        for i in self.members:
            self._begin_epoch(eng, i, t)

    def _begin_epoch(self, eng: AsyncEngine, i: int, t: float) -> None:
        self.partial[i] = eng.live_local_residual(i)
        self.rnd[i] = 0
        eng.reductions_started += 1
        m, q, rounds, rem = self._geom
        if m == 1:
            # the local contribution is the global sum; re-check at
            # reduction cadence instead of recursing at frozen virtual time
            gen = self.generation
            g = combine_contributions([self.partial[i]], self.ord)
            if g < self.eps:
                eng.terminate(t, g)
            else:
                def again(tt, _i=i, _gen=gen):
                    if _gen == self.generation and eng.detect_time is None:
                        self._begin_epoch(eng, _i, tt)
                eng.schedule(t + 2 * eng.cfg.hop_latency, "callback", again)
            return
        r = self.members.index(i)
        if r >= q:
            # extra rank: fold into the partner, await the epoch RESULT
            eng.send(
                Msg(src=i, dst=self.members[r - q], kind="rdub",
                    payload=(self.generation, self.epoch[i], self.FOLD,
                             self.partial[i])),
                t,
            )
            return
        self.folded[i] = (r + q >= m)  # no extra rank folds into us
        if self.folded[i]:
            self._send_round(eng, i, t)
        self._advance(eng, i, t)

    def _send_round(self, eng: AsyncEngine, i: int, t: float) -> None:
        r_idx = self.members.index(i)
        rnd = self.rnd[i]
        eng.send(
            Msg(src=i, dst=self.members[r_idx ^ (1 << rnd)], kind="rdub",
                payload=(self.generation, self.epoch[i], rnd,
                         self.partial[i])),
            t,
        )

    def on_message(self, eng: AsyncEngine, msg: Msg, t: float) -> None:
        if msg.kind != "rdub" or eng.detect_time is not None:
            return
        gen, e, r, val = msg.payload
        if int(gen) != self.generation:
            return  # pre-membership-change straggler: geometry is gone
        i = msg.dst
        if int(r) == self.RESULT:
            # epoch total delivered back to an extra (folded-in) rank:
            # decide independently, like every butterfly participant
            if int(e) != self.epoch[i]:
                return
            g = combine_contributions([float(val)], self.ord)
            if g < self.eps:
                eng.terminate(t, g)
            else:
                self.epoch[i] += 1
                self._begin_epoch(eng, i, t)
            return
        self.pending[i][(int(e), int(r))] = float(val)
        self._advance(eng, i, t)

    def _advance(self, eng: AsyncEngine, i: int, t: float) -> None:
        if not self.folded[i]:
            val = self.pending[i].pop((self.epoch[i], self.FOLD), None)
            if val is None:
                return
            self.partial[i] = self._acc(self.partial[i], val)
            self.folded[i] = True
            self._send_round(eng, i, t)  # round 0 waits for the fold
        while eng.detect_time is None:
            m, q, rounds, rem = self._geom
            val = self.pending[i].pop((self.epoch[i], self.rnd[i]), None)
            if val is None:
                return
            self.partial[i] = self._acc(self.partial[i], val)
            self.rnd[i] += 1
            if self.rnd[i] < rounds:
                self._send_round(eng, i, t)
                continue
            # epoch complete: every worker holds the global sum and decides
            r_idx = self.members.index(i)
            if r_idx < rem:
                eng.send(
                    Msg(src=i, dst=self.members[r_idx + q], kind="rdub",
                        payload=(self.generation, self.epoch[i], self.RESULT,
                                 self.partial[i])),
                    t,
                )
            g = combine_contributions([self.partial[i]], self.ord)
            if g < self.eps:
                eng.terminate(t, g)
                return
            self.epoch[i] += 1
            self._begin_epoch(eng, i, t)
            return


# ---------------------------------------------------------------------------
# NFAIS2 — snapshot carrying interface data (consistent records)
# ---------------------------------------------------------------------------


class NFAIS2(BaseProtocol):
    """On local convergence: record own component, send snapshot messages
    *containing the interface data* (protocol 2 of [12], after [15]).

    The recorded global vector is consistent by construction, so the reduced
    residual equals r(x̄_snapshot) exactly — at the cost of O(interface)
    snapshot bytes.
    """

    name = "nfais2"
    claim = "recorded"

    def __init__(self, eps: float, ord: float = 2.0):
        super().__init__(eps, ord)
        self.round = 0
        self._reset_round_state = True

    def recorded_vector(self):
        active = getattr(self, "_active", None)
        if active is None:
            active = [True] * len(self.rec_own)
        if any(self.rec_own[i] is None
               for i in range(len(self.rec_own)) if active[i]):
            return None
        # holes (None) are workers outside the membership — the oracle
        # substitutes their frozen live blocks (boundary data, not claims)
        return list(self.rec_own)

    def on_start(self, eng: AsyncEngine, t: float) -> None:
        p = eng.p
        self.rec_own: List[Optional[np.ndarray]] = [None] * p
        self.rec_deps: List[Dict[int, np.ndarray]] = [dict() for _ in range(p)]
        self._active = list(eng.active)
        self._reducing = False

    def on_membership(self, eng: AsyncEngine, t: float, kind: str,
                      worker: int) -> None:
        # any membership change invalidates the round: a quorum over the
        # old member set would certify a system that no longer exists
        self._active = list(eng.active)
        self._new_round()

    def _new_round(self) -> None:
        self.round += 1
        for i in range(len(self.rec_own)):
            self.rec_own[i] = None
            self.rec_deps[i] = dict()
        self._reducing = False

    def wants_residual(self, eng: AsyncEngine, i: int) -> bool:
        return self.rec_own[i] is None  # recorded workers stop checking r_i

    def on_iteration(self, eng: AsyncEngine, i: int, t: float, r_i: float) -> None:
        if eng.detect_time is not None:
            return
        if r_i < self.eps and self.rec_own[i] is None:
            self.rec_own[i] = np.array(eng.x[i], copy=True)
            for j in eng.problem.neighbors(i):
                eng.send(
                    Msg(src=i, dst=j, kind="snap2",
                        payload=eng.problem.interface(i, eng.x[i], j),
                        round=self.round),
                    t,
                )
            self._maybe_reduce(eng, t)

    def on_message(self, eng: AsyncEngine, msg: Msg, t: float) -> None:
        if msg.kind != "snap2" or msg.round != self.round:
            return
        self.rec_deps[msg.dst][msg.src] = msg.payload
        self._maybe_reduce(eng, t)

    def _ready(self, eng: AsyncEngine, i: int) -> bool:
        # a snapshot message can only ever arrive from an *active*
        # neighbour; a crashed one's interface is frozen boundary data,
        # merged at reduce time (_record_deps_with_boundary)
        return self.rec_own[i] is not None and all(
            j in self.rec_deps[i] or not eng.active[j]
            for j in eng.problem.neighbors(i)
        )

    def _maybe_reduce(self, eng: AsyncEngine, t: float) -> None:
        if self._reducing or eng.detect_time is not None:
            return
        members = eng.active_workers()
        if not members or not all(self._ready(eng, i) for i in members):
            return
        self._reducing = True
        contribs = np.array(
            [
                self._record_residual(eng, i, self.rec_own[i],
                                      self._record_deps_with_boundary(eng, i))
                for i in members
            ]
        )
        eng.reductions_started += 1
        g = combine_contributions(contribs, self.ord)
        tc = t + self._reduce_latency(eng)
        rnd = self.round

        def complete(tt: float) -> None:
            if self.round != rnd:
                return  # membership change invalidated this quorum mid-reduce
            if g < self.eps:
                eng.terminate(tt, g)
            else:
                self._new_round()

        eng.schedule(tc, "callback", complete)


# ---------------------------------------------------------------------------
# NFAIS5 — approximate snapshot, empty messages + confirmation (O(1) bytes)
# ---------------------------------------------------------------------------


class NFAIS5(BaseProtocol):
    """Protocol 5 of [12]: local convergence persisting m iterations triggers
    an *empty* snapshot message; receivers record the last-delivered
    dependency on that link; a confirmation after m further iterations
    validates that local convergence persisted.  Records are only
    approximately consistent — residual guaranteed up to (1+c(p,m))ε."""

    name = "nfais5"

    def __init__(self, eps: float, ord: float = 2.0, m: int = 4):
        super().__init__(eps, ord)
        self.m = int(m)
        self.round = 0

    def on_start(self, eng: AsyncEngine, t: float) -> None:
        p = eng.p
        self.rec_own: List[Optional[np.ndarray]] = [None] * p
        self.rec_deps: List[Dict[int, np.ndarray]] = [dict() for _ in range(p)]
        self.consec = np.zeros(p, dtype=np.int64)   # consecutive sub-ε sweeps
        self.supp = np.full(p, -1, dtype=np.int64)  # supplementary counter
        self.confirmed = np.zeros(p, dtype=bool)
        self._active = list(eng.active)
        self._reducing = False

    def on_membership(self, eng: AsyncEngine, t: float, kind: str,
                      worker: int) -> None:
        self._active = list(eng.active)
        self._new_round()

    def _new_round(self) -> None:
        self.round += 1
        p = len(self.rec_own)
        for i in range(p):
            self.rec_own[i] = None
            self.rec_deps[i] = dict()
        self.supp[:] = -1
        self.confirmed[:] = False
        # Require m *fresh* sub-ε sweeps before re-recording: confirmed
        # workers stop evaluating r_i (wants_residual), so their counter is
        # frozen — carrying it into the next round would let a worker
        # re-record off stale persistence.
        self.consec[:] = 0
        self._reducing = False

    def wants_residual(self, eng: AsyncEngine, i: int) -> bool:
        # confirmed workers are done checking local convergence this round
        return not (self.rec_own[i] is not None and self.confirmed[i])

    def on_iteration(self, eng: AsyncEngine, i: int, t: float, r_i: float) -> None:
        if eng.detect_time is not None:
            return
        if math.isnan(r_i):
            return  # skipped evaluation (wants_residual was False): freeze
        below = r_i < self.eps
        self.consec[i] = self.consec[i] + 1 if below else 0

        if not below and self.rec_own[i] is not None and not self.confirmed[i]:
            # convergence lost inside the confirmation window → snapshot invalid
            for j in eng.problem.neighbors(i):
                eng.send(Msg(src=i, dst=j, kind="confirm5", payload=False,
                             round=self.round), t)
            self._new_round()
            return

        if self.rec_own[i] is None and self.consec[i] >= self.m:
            # record + empty snapshot messages
            self.rec_own[i] = np.array(eng.x[i], copy=True)
            self.supp[i] = 0
            for j in eng.problem.neighbors(i):
                eng.send(Msg(src=i, dst=j, kind="snap5", round=self.round), t)
            self._maybe_reduce(eng, t)
        elif self.rec_own[i] is not None and not self.confirmed[i]:
            self.supp[i] += 1
            if self.supp[i] >= self.m:
                # persistent → confirm
                self.confirmed[i] = True
                for j in eng.problem.neighbors(i):
                    eng.send(Msg(src=i, dst=j, kind="confirm5", payload=True,
                                 round=self.round), t)
                self._maybe_reduce(eng, t)

    def on_message(self, eng: AsyncEngine, msg: Msg, t: float) -> None:
        if msg.round != self.round:
            return
        if msg.kind == "snap5":
            dep = eng.deps[msg.dst].get(msg.src)
            if dep is not None:
                self.rec_deps[msg.dst][msg.src] = np.array(dep, copy=True)
            self._maybe_reduce(eng, t)
        elif msg.kind == "confirm5" and msg.payload is False:
            if self.round == msg.round:
                self._new_round()

    def _ready(self, eng: AsyncEngine, i: int) -> bool:
        return (
            self.rec_own[i] is not None
            and self.confirmed[i]
            and all(j in self.rec_deps[i] or not eng.active[j]
                    for j in eng.problem.neighbors(i))
        )

    def _maybe_reduce(self, eng: AsyncEngine, t: float) -> None:
        if self._reducing or eng.detect_time is not None:
            return
        members = eng.active_workers()
        if not members or not all(self._ready(eng, i) for i in members):
            return
        self._reducing = True
        contribs = np.array(
            [
                self._record_residual(eng, i, self.rec_own[i],
                                      self._record_deps_with_boundary(eng, i))
                for i in members
            ]
        )
        eng.reductions_started += 1
        g = combine_contributions(contribs, self.ord)
        tc = t + self._reduce_latency(eng)
        rnd = self.round

        def complete(tt: float) -> None:
            if self.round != rnd:
                return  # membership change invalidated this quorum mid-reduce
            if g < self.eps:
                eng.terminate(tt, g)
            else:
                self._new_round()

        eng.schedule(tc, "callback", complete)


# ---------------------------------------------------------------------------
# Exact snapshot over FIFO channels (Chandy–Lamport markers)
# ---------------------------------------------------------------------------


class ExactSnapshotFIFO(BaseProtocol):
    """Marker-based snapshot [6] adapted to asynchronous iterations [12]:
    record on local convergence OR first marker of the round; on marker
    reception record the last dependency delivered on that link.  FIFO
    delivery makes the cut consistent → the reduced residual is exact."""

    name = "exact_snapshot"
    claim = "recorded"

    def __init__(self, eps: float, ord: float = 2.0):
        super().__init__(eps, ord)
        self.round = 0

    def recorded_vector(self):
        active = getattr(self, "_active", None)
        if active is None:
            active = [True] * len(self.rec_own)
        if any(self.rec_own[i] is None
               for i in range(len(self.rec_own)) if active[i]):
            return None
        return list(self.rec_own)

    def on_start(self, eng: AsyncEngine, t: float) -> None:
        if not eng.cfg.fifo:
            raise ValueError("ExactSnapshotFIFO requires cfg.fifo=True")
        p = eng.p
        self.rec_own: List[Optional[np.ndarray]] = [None] * p
        self.rec_deps: List[Dict[int, np.ndarray]] = [dict() for _ in range(p)]
        self._active = list(eng.active)
        self._reducing = False

    def on_membership(self, eng: AsyncEngine, t: float, kind: str,
                      worker: int) -> None:
        self._active = list(eng.active)
        self._new_round()

    def _new_round(self) -> None:
        self.round += 1
        for i in range(len(self.rec_own)):
            self.rec_own[i] = None
            self.rec_deps[i] = dict()
        self._reducing = False

    def _record_and_mark(self, eng: AsyncEngine, i: int, t: float) -> None:
        self.rec_own[i] = np.array(eng.x[i], copy=True)
        for j in eng.problem.neighbors(i):
            eng.send(Msg(src=i, dst=j, kind="marker", round=self.round), t)

    def wants_residual(self, eng: AsyncEngine, i: int) -> bool:
        return self.rec_own[i] is None

    def on_iteration(self, eng: AsyncEngine, i: int, t: float, r_i: float) -> None:
        if eng.detect_time is not None:
            return
        if r_i < self.eps and self.rec_own[i] is None:
            self._record_and_mark(eng, i, t)
            self._maybe_reduce(eng, t)

    def on_message(self, eng: AsyncEngine, msg: Msg, t: float) -> None:
        if msg.kind != "marker" or msg.round != self.round:
            return
        i = msg.dst
        if self.rec_own[i] is None:
            self._record_and_mark(eng, i, t)
        dep = eng.deps[i].get(msg.src)
        if dep is not None:
            self.rec_deps[i][msg.src] = np.array(dep, copy=True)
        self._maybe_reduce(eng, t)

    def _ready(self, eng: AsyncEngine, i: int) -> bool:
        return self.rec_own[i] is not None and all(
            j in self.rec_deps[i] or not eng.active[j]
            for j in eng.problem.neighbors(i)
        )

    def _maybe_reduce(self, eng: AsyncEngine, t: float) -> None:
        if self._reducing or eng.detect_time is not None:
            return
        members = eng.active_workers()
        if not members or not all(self._ready(eng, i) for i in members):
            return
        self._reducing = True
        contribs = np.array(
            [
                self._record_residual(eng, i, self.rec_own[i],
                                      self._record_deps_with_boundary(eng, i))
                for i in members
            ]
        )
        eng.reductions_started += 1
        g = combine_contributions(contribs, self.ord)
        tc = t + self._reduce_latency(eng)
        rnd = self.round

        def complete(tt: float) -> None:
            if self.round != rnd:
                return  # membership change invalidated this quorum mid-reduce
            if g < self.eps:
                eng.terminate(tt, g)
            else:
                self._new_round()

        eng.schedule(tc, "callback", complete)


PROTOCOLS = {
    "pfait": PFAIT,
    "rdub": RecursiveDoublingProtocol,
    "nfais2": NFAIS2,
    "nfais5": NFAIS5,
    "exact_snapshot": ExactSnapshotFIFO,
}
