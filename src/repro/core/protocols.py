"""Faithful event-level detection protocols (paper §3 + refs [12, 15, 6]).

Each protocol plugs into ``core.async_engine.AsyncEngine`` via four hooks:

    on_start(engine, t)            — simulation begins
    on_iteration(engine, i, t, r)  — worker i finished a sweep, local residual r
    on_data(engine, msg, t)        — a computation message was delivered
    on_message(engine, msg, t)     — a protocol message was delivered

Implemented protocols:

* ``PFAIT``             — the paper: successive non-blocking reductions over
                          live local residuals; zero protocol messages.
* ``NFAIS2``            — SB96-style snapshot [15]/[12]: snapshot messages
                          *carry interface data* → consistent records, exact
                          residual of the snapshot vector; O(n) msg bytes.
* ``NFAIS5``            — approximate snapshot [12]: empty snapshot messages
                          record last-delivered dependencies; persistence m +
                          confirmation phase; O(1) msg bytes, residual exact
                          up to (1+c(p,m))ε.
* ``ExactSnapshotFIFO`` — Chandy–Lamport marker protocol [6] adapted to
                          asynchronous iterations [12]; requires FIFO
                          channels; consistent cut → exact residual.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.async_engine import AsyncEngine, Msg
from repro.core.residual import combine_contributions


class BaseProtocol:
    name = "base"
    #: what a detection *claims* (the reliability oracle scores against it):
    #: "live"     — the live global residual is < ε (PFAIT samples live
    #:              state; NFAIS5 records approximate, data-free views),
    #: "recorded" — a recorded consistent global vector has residual < ε
    #:              (NFAIS2 / Chandy–Lamport carry or pin the actual data;
    #:              the certified solution is the record, not whatever the
    #:              live state drifts to before the stop broadcast lands).
    claim = "live"

    def __init__(self, eps: float, ord: float = 2.0):
        self.eps = float(eps)
        self.ord = ord

    def recorded_vector(self):
        """The recorded global vector backing a "recorded" claim (list of
        per-worker blocks), or None when the protocol has no record."""
        return None

    def on_start(self, eng: AsyncEngine, t: float) -> None:  # pragma: no cover
        pass

    def on_iteration(self, eng: AsyncEngine, i: int, t: float, r_i: float) -> None:
        pass

    def on_data(self, eng: AsyncEngine, msg: Msg, t: float) -> None:
        pass

    def on_message(self, eng: AsyncEngine, msg: Msg, t: float) -> None:
        pass

    def wants_residual(self, eng: AsyncEngine, i: int) -> bool:
        """Will ``on_iteration`` consume ``r_i`` for worker i this iteration?
        The engine's fused path skips residual evaluation when False (the
        protocol then receives ``r_i = NaN``)."""
        return True

    # shared helper: tree-reduction completion latency
    def _reduce_latency(self, eng: AsyncEngine) -> float:
        return 2 * math.ceil(math.log2(max(eng.p, 2))) * eng.cfg.hop_latency

    # shared helper: residual of a *complete* recorded view (snapshot
    # reduce paths guarantee every neighbour is present, so the problem's
    # buffered fast path is valid; gated on cfg.fused so the unfused
    # baseline keeps the seed code path)
    def _record_residual(self, eng: AsyncEngine, i: int, own, deps) -> float:
        if eng.cfg.fused:
            fast = getattr(eng.problem, "local_residual_fast", None)
            if fast is not None:
                return fast(i, own, deps)
        return eng.problem.local_residual(i, own, deps)


# ---------------------------------------------------------------------------
# PFAIT — the paper's protocol-free termination
# ---------------------------------------------------------------------------


class PFAIT(BaseProtocol):
    """Successive non-blocking reductions of free-running local residuals.

    Contributions are sampled at staggered times from each worker's *live*
    state (stale dependency views included) — the source of the detection
    inconsistency the paper calibrates away with the ε-margin.
    """

    name = "pfait"

    def wants_residual(self, eng: AsyncEngine, i: int) -> bool:
        # Zero per-iteration detection work: contributions are sampled from
        # live state by the reduction service, never from on_iteration.
        return False

    def on_start(self, eng: AsyncEngine, t: float) -> None:
        self._launch(eng, t)

    def _launch(self, eng: AsyncEngine, t: float) -> None:
        if eng.detect_time is not None:
            return

        def complete(contribs: np.ndarray, tc: float) -> None:
            g = combine_contributions(contribs, self.ord)
            if g < self.eps:
                eng.terminate(tc, g)
            else:
                self._launch(eng, tc)

        eng.start_reduction(
            sample_fn=lambda i, ts: eng.live_local_residual(i),
            on_complete=complete,
            t=t,
        )


# ---------------------------------------------------------------------------
# Modified recursive doubling — decentralised reduction protocol baseline
# ---------------------------------------------------------------------------


class RecursiveDoublingProtocol(BaseProtocol):
    """Modified recursive doubling (Zou & Magoulès 2019): the protocol-based
    alternative the shard runtime benchmarks PFAIT against on device.

    Workers run free-running reduction *epochs* over the butterfly: in round
    r of an epoch, worker i exchanges its partial residual sum with partner
    ``i XOR 2^r``; after log2(p) rounds every worker holds the epoch's
    global sum and checks it against ε *independently* (no root, no
    broadcast tree).  Contributions are sampled from live state at each
    worker's epoch start — staggered like PFAIT's, so the detection claim
    is "live" and the ε-margin methodology applies unchanged.  Unlike PFAIT
    the reduction itself is carried by point-to-point protocol messages
    (p·log2(p) per epoch), which is exactly the overhead the paper's
    protocol-free detection removes.

    Requires a power-of-two worker count (the classic butterfly); the
    on-device twin lives in ``runtime/shard_runtime.py`` (``rdoubling``).
    """

    name = "rdub"

    def __init__(self, eps: float, ord: float = 2.0):
        super().__init__(eps, ord)

    def wants_residual(self, eng: AsyncEngine, i: int) -> bool:
        # like PFAIT: contributions are sampled from live state at epoch
        # starts, never from per-iteration residuals
        return False

    def on_start(self, eng: AsyncEngine, t: float) -> None:
        p = eng.p
        if p & (p - 1):
            raise ValueError(
                f"RecursiveDoublingProtocol requires a power-of-two worker "
                f"count, got p={p}")
        self.rounds = max(p.bit_length() - 1, 0)  # log2 p
        self.epoch = [0] * p
        self.rnd = [0] * p
        self.partial = [0.0] * p
        # out-of-order buffer: partner partials keyed by (epoch, round) —
        # bounded, because a partner cannot advance a round without our
        # reply for the previous one
        self.pending: List[Dict[Tuple[int, int], float]] = [
            dict() for _ in range(p)]
        for i in range(p):
            self._begin_epoch(eng, i, t)

    def _begin_epoch(self, eng: AsyncEngine, i: int, t: float) -> None:
        self.partial[i] = eng.live_local_residual(i)
        self.rnd[i] = 0
        eng.reductions_started += 1
        if self.rounds == 0:
            # p = 1: the local contribution is the global sum; re-check at
            # reduction cadence instead of recursing at frozen virtual time
            g = combine_contributions([self.partial[i]], self.ord)
            if g < self.eps:
                eng.terminate(t, g)
            else:
                eng.schedule(t + 2 * eng.cfg.hop_latency, "callback",
                             lambda tt: self._begin_epoch(eng, i, tt))
            return
        self._send_round(eng, i, t)

    def _send_round(self, eng: AsyncEngine, i: int, t: float) -> None:
        r = self.rnd[i]
        eng.send(
            Msg(src=i, dst=i ^ (1 << r), kind="rdub",
                payload=(self.epoch[i], r, self.partial[i])),
            t,
        )

    def on_message(self, eng: AsyncEngine, msg: Msg, t: float) -> None:
        if msg.kind != "rdub" or eng.detect_time is not None:
            return
        e, r, val = msg.payload
        self.pending[msg.dst][(int(e), int(r))] = float(val)
        self._advance(eng, msg.dst, t)

    def _advance(self, eng: AsyncEngine, i: int, t: float) -> None:
        while eng.detect_time is None:
            val = self.pending[i].pop((self.epoch[i], self.rnd[i]), None)
            if val is None:
                return
            self.partial[i] = (
                max(self.partial[i], val) if math.isinf(self.ord)
                else self.partial[i] + val)
            self.rnd[i] += 1
            if self.rnd[i] < self.rounds:
                self._send_round(eng, i, t)
                continue
            # epoch complete: every worker holds the global sum and decides
            g = combine_contributions([self.partial[i]], self.ord)
            if g < self.eps:
                eng.terminate(t, g)
                return
            self.epoch[i] += 1
            self._begin_epoch(eng, i, t)


# ---------------------------------------------------------------------------
# NFAIS2 — snapshot carrying interface data (consistent records)
# ---------------------------------------------------------------------------


class NFAIS2(BaseProtocol):
    """On local convergence: record own component, send snapshot messages
    *containing the interface data* (protocol 2 of [12], after [15]).

    The recorded global vector is consistent by construction, so the reduced
    residual equals r(x̄_snapshot) exactly — at the cost of O(interface)
    snapshot bytes.
    """

    name = "nfais2"
    claim = "recorded"

    def __init__(self, eps: float, ord: float = 2.0):
        super().__init__(eps, ord)
        self.round = 0
        self._reset_round_state = True

    def recorded_vector(self):
        if any(r is None for r in self.rec_own):
            return None
        return list(self.rec_own)

    def on_start(self, eng: AsyncEngine, t: float) -> None:
        p = eng.p
        self.rec_own: List[Optional[np.ndarray]] = [None] * p
        self.rec_deps: List[Dict[int, np.ndarray]] = [dict() for _ in range(p)]
        self._reducing = False

    def _new_round(self) -> None:
        self.round += 1
        for i in range(len(self.rec_own)):
            self.rec_own[i] = None
            self.rec_deps[i] = dict()
        self._reducing = False

    def wants_residual(self, eng: AsyncEngine, i: int) -> bool:
        return self.rec_own[i] is None  # recorded workers stop checking r_i

    def on_iteration(self, eng: AsyncEngine, i: int, t: float, r_i: float) -> None:
        if eng.detect_time is not None:
            return
        if r_i < self.eps and self.rec_own[i] is None:
            self.rec_own[i] = np.array(eng.x[i], copy=True)
            for j in eng.problem.neighbors(i):
                eng.send(
                    Msg(src=i, dst=j, kind="snap2",
                        payload=eng.problem.interface(i, eng.x[i], j),
                        round=self.round),
                    t,
                )
            self._maybe_reduce(eng, t)

    def on_message(self, eng: AsyncEngine, msg: Msg, t: float) -> None:
        if msg.kind != "snap2" or msg.round != self.round:
            return
        self.rec_deps[msg.dst][msg.src] = msg.payload
        self._maybe_reduce(eng, t)

    def _ready(self, eng: AsyncEngine, i: int) -> bool:
        return self.rec_own[i] is not None and all(
            j in self.rec_deps[i] for j in eng.problem.neighbors(i)
        )

    def _maybe_reduce(self, eng: AsyncEngine, t: float) -> None:
        if self._reducing or eng.detect_time is not None:
            return
        if not all(self._ready(eng, i) for i in range(eng.p)):
            return
        self._reducing = True
        contribs = np.array(
            [
                self._record_residual(eng, i, self.rec_own[i], self.rec_deps[i])
                for i in range(eng.p)
            ]
        )
        eng.reductions_started += 1
        g = combine_contributions(contribs, self.ord)
        tc = t + self._reduce_latency(eng)

        def complete(tt: float) -> None:
            if g < self.eps:
                eng.terminate(tt, g)
            else:
                self._new_round()

        eng.schedule(tc, "callback", complete)


# ---------------------------------------------------------------------------
# NFAIS5 — approximate snapshot, empty messages + confirmation (O(1) bytes)
# ---------------------------------------------------------------------------


class NFAIS5(BaseProtocol):
    """Protocol 5 of [12]: local convergence persisting m iterations triggers
    an *empty* snapshot message; receivers record the last-delivered
    dependency on that link; a confirmation after m further iterations
    validates that local convergence persisted.  Records are only
    approximately consistent — residual guaranteed up to (1+c(p,m))ε."""

    name = "nfais5"

    def __init__(self, eps: float, ord: float = 2.0, m: int = 4):
        super().__init__(eps, ord)
        self.m = int(m)
        self.round = 0

    def on_start(self, eng: AsyncEngine, t: float) -> None:
        p = eng.p
        self.rec_own: List[Optional[np.ndarray]] = [None] * p
        self.rec_deps: List[Dict[int, np.ndarray]] = [dict() for _ in range(p)]
        self.consec = np.zeros(p, dtype=np.int64)   # consecutive sub-ε sweeps
        self.supp = np.full(p, -1, dtype=np.int64)  # supplementary counter
        self.confirmed = np.zeros(p, dtype=bool)
        self._reducing = False

    def _new_round(self) -> None:
        self.round += 1
        p = len(self.rec_own)
        for i in range(p):
            self.rec_own[i] = None
            self.rec_deps[i] = dict()
        self.supp[:] = -1
        self.confirmed[:] = False
        # Require m *fresh* sub-ε sweeps before re-recording: confirmed
        # workers stop evaluating r_i (wants_residual), so their counter is
        # frozen — carrying it into the next round would let a worker
        # re-record off stale persistence.
        self.consec[:] = 0
        self._reducing = False

    def wants_residual(self, eng: AsyncEngine, i: int) -> bool:
        # confirmed workers are done checking local convergence this round
        return not (self.rec_own[i] is not None and self.confirmed[i])

    def on_iteration(self, eng: AsyncEngine, i: int, t: float, r_i: float) -> None:
        if eng.detect_time is not None:
            return
        if math.isnan(r_i):
            return  # skipped evaluation (wants_residual was False): freeze
        below = r_i < self.eps
        self.consec[i] = self.consec[i] + 1 if below else 0

        if not below and self.rec_own[i] is not None and not self.confirmed[i]:
            # convergence lost inside the confirmation window → snapshot invalid
            for j in eng.problem.neighbors(i):
                eng.send(Msg(src=i, dst=j, kind="confirm5", payload=False,
                             round=self.round), t)
            self._new_round()
            return

        if self.rec_own[i] is None and self.consec[i] >= self.m:
            # record + empty snapshot messages
            self.rec_own[i] = np.array(eng.x[i], copy=True)
            self.supp[i] = 0
            for j in eng.problem.neighbors(i):
                eng.send(Msg(src=i, dst=j, kind="snap5", round=self.round), t)
            self._maybe_reduce(eng, t)
        elif self.rec_own[i] is not None and not self.confirmed[i]:
            self.supp[i] += 1
            if self.supp[i] >= self.m:
                # persistent → confirm
                self.confirmed[i] = True
                for j in eng.problem.neighbors(i):
                    eng.send(Msg(src=i, dst=j, kind="confirm5", payload=True,
                                 round=self.round), t)
                self._maybe_reduce(eng, t)

    def on_message(self, eng: AsyncEngine, msg: Msg, t: float) -> None:
        if msg.round != self.round:
            return
        if msg.kind == "snap5":
            dep = eng.deps[msg.dst].get(msg.src)
            if dep is not None:
                self.rec_deps[msg.dst][msg.src] = np.array(dep, copy=True)
            self._maybe_reduce(eng, t)
        elif msg.kind == "confirm5" and msg.payload is False:
            if self.round == msg.round:
                self._new_round()

    def _ready(self, eng: AsyncEngine, i: int) -> bool:
        return (
            self.rec_own[i] is not None
            and self.confirmed[i]
            and all(j in self.rec_deps[i] for j in eng.problem.neighbors(i))
        )

    def _maybe_reduce(self, eng: AsyncEngine, t: float) -> None:
        if self._reducing or eng.detect_time is not None:
            return
        if not all(self._ready(eng, i) for i in range(eng.p)):
            return
        self._reducing = True
        contribs = np.array(
            [
                self._record_residual(eng, i, self.rec_own[i], self.rec_deps[i])
                for i in range(eng.p)
            ]
        )
        eng.reductions_started += 1
        g = combine_contributions(contribs, self.ord)
        tc = t + self._reduce_latency(eng)

        def complete(tt: float) -> None:
            if g < self.eps:
                eng.terminate(tt, g)
            else:
                self._new_round()

        eng.schedule(tc, "callback", complete)


# ---------------------------------------------------------------------------
# Exact snapshot over FIFO channels (Chandy–Lamport markers)
# ---------------------------------------------------------------------------


class ExactSnapshotFIFO(BaseProtocol):
    """Marker-based snapshot [6] adapted to asynchronous iterations [12]:
    record on local convergence OR first marker of the round; on marker
    reception record the last dependency delivered on that link.  FIFO
    delivery makes the cut consistent → the reduced residual is exact."""

    name = "exact_snapshot"
    claim = "recorded"

    def __init__(self, eps: float, ord: float = 2.0):
        super().__init__(eps, ord)
        self.round = 0

    def recorded_vector(self):
        if any(r is None for r in self.rec_own):
            return None
        return list(self.rec_own)

    def on_start(self, eng: AsyncEngine, t: float) -> None:
        if not eng.cfg.fifo:
            raise ValueError("ExactSnapshotFIFO requires cfg.fifo=True")
        p = eng.p
        self.rec_own: List[Optional[np.ndarray]] = [None] * p
        self.rec_deps: List[Dict[int, np.ndarray]] = [dict() for _ in range(p)]
        self._reducing = False

    def _new_round(self) -> None:
        self.round += 1
        for i in range(len(self.rec_own)):
            self.rec_own[i] = None
            self.rec_deps[i] = dict()
        self._reducing = False

    def _record_and_mark(self, eng: AsyncEngine, i: int, t: float) -> None:
        self.rec_own[i] = np.array(eng.x[i], copy=True)
        for j in eng.problem.neighbors(i):
            eng.send(Msg(src=i, dst=j, kind="marker", round=self.round), t)

    def wants_residual(self, eng: AsyncEngine, i: int) -> bool:
        return self.rec_own[i] is None

    def on_iteration(self, eng: AsyncEngine, i: int, t: float, r_i: float) -> None:
        if eng.detect_time is not None:
            return
        if r_i < self.eps and self.rec_own[i] is None:
            self._record_and_mark(eng, i, t)
            self._maybe_reduce(eng, t)

    def on_message(self, eng: AsyncEngine, msg: Msg, t: float) -> None:
        if msg.kind != "marker" or msg.round != self.round:
            return
        i = msg.dst
        if self.rec_own[i] is None:
            self._record_and_mark(eng, i, t)
        dep = eng.deps[i].get(msg.src)
        if dep is not None:
            self.rec_deps[i][msg.src] = np.array(dep, copy=True)
        self._maybe_reduce(eng, t)

    def _ready(self, eng: AsyncEngine, i: int) -> bool:
        return self.rec_own[i] is not None and all(
            j in self.rec_deps[i] for j in eng.problem.neighbors(i)
        )

    def _maybe_reduce(self, eng: AsyncEngine, t: float) -> None:
        if self._reducing or eng.detect_time is not None:
            return
        if not all(self._ready(eng, i) for i in range(eng.p)):
            return
        self._reducing = True
        contribs = np.array(
            [
                self._record_residual(eng, i, self.rec_own[i], self.rec_deps[i])
                for i in range(eng.p)
            ]
        )
        eng.reductions_started += 1
        g = combine_contributions(contribs, self.ord)
        tc = t + self._reduce_latency(eng)

        def complete(tt: float) -> None:
            if g < self.eps:
                eng.terminate(tt, g)
            else:
                self._new_round()

        eng.schedule(tc, "callback", complete)


PROTOCOLS = {
    "pfait": PFAIT,
    "rdub": RecursiveDoublingProtocol,
    "nfais2": NFAIS2,
    "nfais5": NFAIS5,
    "exact_snapshot": ExactSnapshotFIFO,
}
