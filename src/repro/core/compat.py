"""jax API compat shims.

The repo targets current jax, but several deployment targets still run
0.4.x where ``jax.shard_map``, ``jax.sharding.AxisType`` and
``jax.lax.axis_size`` don't exist yet.  Everything version-dependent goes
through here so call sites stay clean.
"""
from __future__ import annotations

import jax


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` (new, check_vma) or ``jax.experimental.shard_map``
    (0.4.x, check_rep) with replication checking off either way."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` across versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer releases."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def axis_size_compat(a: str):
    """Static mesh-axis size inside shard_map bodies across versions."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)  # older jax: statically-known collective
