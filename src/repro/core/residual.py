"""Distributed residual-error evaluation (paper §2.2).

A residual function ``r`` is distributed as ``r(x) = σ(r_1(x), …, r_p(x))``
where each ``r_i`` is local to one worker and ``σ`` is a reduction.  For the
l-norms of the paper,

    r(x) = ‖x − f(x)‖_l,   r_i = (‖·‖^(i))^l,   σ(α) = (Σ α_j)^(1/l),

and for the max-norm σ is the plain max.  These helpers work both on plain
arrays (host / simulator) and inside ``shard_map`` bodies via
``jax.lax.psum`` / ``jax.lax.pmax``.
"""
from __future__ import annotations

from typing import Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

Ord = Union[int, float, str]


def _as_ord(ord: Ord) -> float:
    if ord in ("inf", "max", np.inf, float("inf")):
        return float("inf")
    return float(ord)


def local_contribution(diff: jax.Array, ord: Ord = 2) -> jax.Array:
    """``r_i``: the local, *pre-reduction* contribution of one worker.

    For finite l this is ``Σ|d|^l`` (NOT the root — roots commute with the
    global reduction only if taken after σ); for l=∞ it is ``max|d|``.
    """
    lp = _as_ord(ord)
    a = jnp.abs(diff.astype(jnp.float32))
    if np.isinf(lp):
        return jnp.max(a) if a.size else jnp.float32(0)
    if lp == 2.0:
        return jnp.sum(a * a)
    return jnp.sum(a**lp)


def sigma(contributions: jax.Array, ord: Ord = 2) -> jax.Array:
    """``σ``: reduce a vector of local contributions to the global residual."""
    lp = _as_ord(ord)
    c = jnp.asarray(contributions)
    if np.isinf(lp):
        return jnp.max(c)
    s = jnp.sum(c)
    if lp == 2.0:
        return jnp.sqrt(s)
    return s ** (1.0 / lp)


def psum_sigma(contribution: jax.Array, axis_names, ord: Ord = 2) -> jax.Array:
    """σ over mesh axes, for use inside ``shard_map`` — the SPMD analogue of
    the paper's (non-blocking) reduction operation."""
    lp = _as_ord(ord)
    if np.isinf(lp):
        return jax.lax.pmax(contribution, axis_names)
    s = jax.lax.psum(contribution, axis_names)
    if lp == 2.0:
        return jnp.sqrt(s)
    return s ** (1.0 / lp)


def global_residual(x: jax.Array, fx: jax.Array, ord: Ord = 2) -> jax.Array:
    """Reference (non-distributed) residual ``‖x − f(x)‖_l``."""
    lp = _as_ord(ord)
    d = jnp.abs((x - fx).astype(jnp.float32))
    if np.isinf(lp):
        return jnp.max(d)
    if lp == 2.0:
        return jnp.sqrt(jnp.sum(d * d))
    return jnp.sum(d**lp) ** (1.0 / lp)


def combine_contributions(parts: Sequence[float], ord: Ord = 2) -> float:
    """Host-side σ for the event simulator."""
    lp = _as_ord(ord)
    arr = np.asarray(parts, dtype=np.float64)
    if np.isinf(lp):
        return float(arr.max()) if arr.size else 0.0
    s = float(arr.sum())
    if lp == 2.0:
        return float(np.sqrt(s))
    return float(s ** (1.0 / lp))
