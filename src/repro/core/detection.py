"""TPU-native convergence detection — the paper's contribution as a
composable JAX module.

The paper terminates an asynchronous iterative process from the result of
*successive non-blocking reduction operations* over free-running local
residual contributions (PFAIT), instead of running a snapshot protocol.

On an SPMD machine the analogue of a non-blocking ``MPI_Iallreduce`` is a
**pipelined stale reduction**: the while-loop carry holds a ring buffer of
``K+1`` global-residual scalars; the reduction "launched" at iteration ``k``
is only *consumed* (compared against ε) at iteration ``k+K``.  Because
nothing reads the psum result for K iterations, XLA is free to schedule the
8-byte collective concurrently with the next sweeps' compute — detection
leaves the critical path exactly as in the paper.  ``K = 0`` recovers the
classical blocking (synchronous) detection.

Four modes, mirroring the paper's head-to-head:

* ``sync``    — blocking exact reduction every check (baseline),
* ``pfait``   — the paper: stale reduction + tightened threshold ε = ε̃/margin,
* ``nfais2``  — candidate from the stale reduction must persist, then a
                *blocking exact verification* runs (emulates the snapshot
                protocol that carries interface data: exactness paid with a
                synchronisation),
* ``nfais5``  — candidate must persist m checks, then be *confirmed* after m
                further checks (no data verification; emulates the O(1)
                approximate snapshot, guarantee factor (1+c(p,m))).

All functions are jittable and usable inside ``lax.while_loop`` bodies under
``shard_map`` (pass ``axis_names``) or outside (pass ``axis_names=None`` and
pre-reduced contributions).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import residual as res

MODES = ("sync", "pfait", "nfais2", "nfais5")


@dataclass(frozen=True)
class MonitorConfig:
    """Static configuration of one convergence monitor.

    ``mode`` selects the detection protocol (``MODES``); ``eps`` is the
    already-tightened detection threshold ε (for PFAIT, ε̃/margin — see
    ``for_mode``); ``eps_tilde`` the user-facing target precision ε̃;
    ``staleness`` the reduction pipeline depth K (checks see a value K
    steps old — 0 means blocking); ``persistence`` the NFAIS repeat count
    m; ``ord`` the residual norm order l (σ applies the matching root to
    the reduced contribution sum); ``check_every`` the reduction cadence.
    """

    mode: str = "pfait"
    eps: float = 1e-6            # detection threshold ε (already tightened)
    eps_tilde: float = 1e-6      # desired precision ε̃ (NFAIS2 verifies this)
    staleness: int = 2           # K — reduction pipeline depth (0 = blocking)
    persistence: int = 4         # m — NFAIS persistence checks
    ord: float = 2.0             # residual norm order (2 or inf)
    check_every: int = 1         # reduce every C iterations

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.mode == "sync" and self.staleness != 0:
            object.__setattr__(self, "staleness", 0)

    @property
    def ring_len(self) -> int:
        """Staleness ring depth: K in-flight reductions + the visible slot."""
        return self.staleness + 1


class MonitorState(NamedTuple):
    """Carried through the solver's ``lax.while_loop``."""

    ring: jax.Array          # f32[K+1] — in-flight reduction results
    step: jax.Array          # i32 — checks performed
    persist: jax.Array       # i32 — consecutive sub-ε checks (NFAIS)
    phase: jax.Array         # i32 — NFAIS5: 0 monitor, 1 confirm window
    confirm_at: jax.Array    # i32 — NFAIS5: step at which to confirm
    converged: jax.Array     # bool
    detected_residual: jax.Array  # f32 — the (stale) residual that fired
    verifications: jax.Array      # i32 — NFAIS2 blocking verifications paid


def init_state(cfg: MonitorConfig) -> MonitorState:
    """Fresh monitor state: ring primed to +inf (nothing visible yet)."""
    return MonitorState(
        ring=jnp.full((cfg.ring_len,), jnp.inf, dtype=jnp.float32),
        step=jnp.zeros((), jnp.int32),
        persist=jnp.zeros((), jnp.int32),
        phase=jnp.zeros((), jnp.int32),
        confirm_at=jnp.full((), jnp.iinfo(jnp.int32).max, jnp.int32),
        converged=jnp.zeros((), jnp.bool_),
        detected_residual=jnp.full((), jnp.inf, jnp.float32),
        verifications=jnp.zeros((), jnp.int32),
    )


def _push_ring(ring: jax.Array, value: jax.Array, step: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Insert the freshly-launched reduction; read the one launched K ago.

    The ring is a circular buffer indexed by ``step mod (K+1)``: position
    ``step % L`` currently holds the value launched ``K+1`` steps ago (its
    result was consumed last step), so we read *then* overwrite.
    """
    L = ring.shape[0]
    idx = jnp.mod(step, L)
    nxt = jnp.mod(step + 1, L) if L > 1 else idx
    # value launched at (step - K) sits at (step+1) mod L ... for L==1 it is
    # the current value (blocking).
    visible = ring[nxt] if L > 1 else value
    ring = ring.at[idx].set(value)
    return ring, visible


def step(
    cfg: MonitorConfig,
    state: MonitorState,
    local_contribution: jax.Array,
    axis_names=None,
    exact_residual_fn: Optional[Callable[[], jax.Array]] = None,
) -> MonitorState:
    """One detection check.

    ``local_contribution`` — this worker's ``r_i`` (pre-σ, see residual.py);
    if ``axis_names`` is None it must already be globally reduced *per-l*
    contribution sum (simulator / single-host use).

    ``exact_residual_fn`` — NFAIS2 only: a thunk evaluating the *exact*
    current global residual (blocking).  Evaluated lazily under ``lax.cond``
    so the synchronisation is paid only when a candidate fires.
    """
    if axis_names is not None:
        g = res.psum_sigma(local_contribution, axis_names, cfg.ord)
    else:
        g = res.sigma(local_contribution, cfg.ord)
    g = g.astype(jnp.float32)

    ring, visible = _push_ring(state.ring, g, state.step)
    below = visible < cfg.eps

    if cfg.mode in ("sync", "pfait"):
        converged = state.converged | below
        detected = jnp.where(
            state.converged, state.detected_residual, jnp.where(below, visible, jnp.inf)
        )
        return state._replace(
            ring=ring,
            step=state.step + 1,
            converged=converged,
            detected_residual=detected,
        )

    persist = jnp.where(below, state.persist + 1, 0)

    if cfg.mode == "nfais2":
        candidate = persist >= cfg.persistence
        fire = candidate & ~state.converged

        def verify(_):
            """NFAIS2 verification: exact residual if a verifier exists."""
            if exact_residual_fn is None:
                # No verifier supplied: fall back to the stale value (the
                # caller accepts NFAIS5-like semantics).
                return visible
            return exact_residual_fn().astype(jnp.float32)

        exact = jax.lax.cond(fire, verify, lambda _: jnp.float32(jnp.inf), operand=None)
        verified = exact < cfg.eps_tilde
        converged = state.converged | (fire & verified)
        return state._replace(
            ring=ring,
            step=state.step + 1,
            persist=jnp.where(fire & ~verified, 0, persist),
            converged=converged,
            detected_residual=jnp.where(
                state.converged, state.detected_residual, jnp.where(fire & verified, exact, jnp.inf)
            ),
            verifications=state.verifications + fire.astype(jnp.int32),
        )

    # nfais5 — two-phase persistence confirmation
    candidate = (persist >= cfg.persistence) & (state.phase == 0)
    phase = jnp.where(candidate, 1, state.phase)
    confirm_at = jnp.where(candidate, state.step + cfg.persistence, state.confirm_at)
    confirming = (state.phase == 1) & (state.step >= state.confirm_at)
    confirmed = confirming & below & (persist >= 2 * cfg.persistence)
    failed = confirming & ~confirmed
    converged = state.converged | confirmed
    return state._replace(
        ring=ring,
        step=state.step + 1,
        persist=persist,
        phase=jnp.where(failed | confirmed, 0, phase),
        confirm_at=jnp.where(failed | confirmed, jnp.iinfo(jnp.int32).max, confirm_at),
        converged=converged,
        detected_residual=jnp.where(
            state.converged, state.detected_residual, jnp.where(confirmed, visible, jnp.inf)
        ),
    )


def should_stop(state: MonitorState) -> jax.Array:
    """Loop predicate: True once the monitor has certified detection."""
    return state.converged


# ---------------------------------------------------------------------------
# Threshold selection (paper §4.2 methodology)
# ---------------------------------------------------------------------------


def pfait_threshold(eps_tilde: float, margin: float = 10.0) -> float:
    """PFAIT's tightened threshold ε = ε̃ / margin.

    The paper calibrates the margin from platform stability runs; 10 was the
    value that made every large-problem run satisfy ``r* < ε̃`` (§4.2,
    Tables 4–5).  See ``core.termination.calibrate_margin``.
    """
    return eps_tilde / margin


def for_mode(mode: str, eps_tilde: float, margin: float = 10.0, **kw) -> MonitorConfig:
    """Monitor config for a protocol head-to-head at target precision ε̃."""
    eps = pfait_threshold(eps_tilde, margin) if mode == "pfait" else eps_tilde
    return MonitorConfig(mode=mode, eps=eps, eps_tilde=eps_tilde, **kw)


# ---------------------------------------------------------------------------
# Batched detection sweeps — one jitted program over (seed × K × m × ε)
# ---------------------------------------------------------------------------
#
# ``step`` monitors ONE configuration; parameter studies (the recursive-
# doubling sweeps of Zou & Magoulès, the campaign's detection grids) need
# thousands.  ``batched_monitor`` vmaps a staleness-*dynamic* reimplementation
# of the same update over every lane: the ring buffer is padded to the grid's
# max K+1 and indexed ``step mod (K_lane+1)``, so lanes with different
# pipeline depths share one scan.  Verdicts are bitwise-identical to running
# ``step`` per configuration (tests/test_batched.py proves it) because every
# lane performs the same float ops in the same order — the padding slots are
# simply never read.
#
# NFAIS2 lanes use the verifier-free fallback semantics of ``step`` with
# ``exact_residual_fn=None`` (the candidate's stale value stands in for the
# blocking verification — a batched program cannot pause one lane to
# synchronise), which ``step`` documents as NFAIS5-like acceptance.


class BatchedVerdict(NamedTuple):
    """Per-lane outcome, shaped [S, E, K, M] (seed × ε × staleness × m)."""

    converged: jax.Array          # bool — detection fired within T checks
    detect_step: jax.Array        # i32 — first firing check (-1 if never)
    detected_residual: jax.Array  # f32 — the (stale) residual that fired
    verifications: jax.Array      # i32 — NFAIS2 verification count


class _LaneState(NamedTuple):
    ring: jax.Array
    step: jax.Array
    persist: jax.Array
    phase: jax.Array
    confirm_at: jax.Array
    converged: jax.Array
    detected: jax.Array
    verifications: jax.Array
    detect_step: jax.Array


def _sigma_lane(c: jax.Array, ord: float) -> jax.Array:
    """Elementwise σ of an already-reduced contribution (res.sigma on a
    scalar): identity for l=∞, the l-th root otherwise."""
    if np.isinf(ord):
        return c
    if ord == 2.0:
        return jnp.sqrt(c)
    return c ** (1.0 / ord)


def _lane_step(mode: str, s: _LaneState, g: jax.Array, eps: jax.Array,
               eps_tilde: jax.Array, K: jax.Array, m: jax.Array) -> _LaneState:
    """``step`` with traced (per-lane) ε, ε̃, K, m.  Mirrors the per-run
    update line by line; K is dynamic via mod-(K+1) ring indexing."""
    L = K + 1
    idx = jnp.mod(s.step, L)
    nxt = jnp.mod(s.step + 1, L)
    visible = jnp.where(K == 0, g, s.ring[nxt])
    ring = s.ring.at[idx].set(g)
    below = visible < eps
    inf = jnp.float32(jnp.inf)

    if mode in ("sync", "pfait"):
        converged = s.converged | below
        detected = jnp.where(
            s.converged, s.detected, jnp.where(below, visible, inf)
        )
        return s._replace(
            ring=ring, step=s.step + 1, converged=converged,
            detected=detected,
            detect_step=jnp.where(
                converged & ~s.converged, s.step, s.detect_step),
        )

    persist = jnp.where(below, s.persist + 1, 0)

    if mode == "nfais2":
        candidate = persist >= m
        fire = candidate & ~s.converged
        exact = jnp.where(fire, visible, inf)   # verifier-free fallback
        verified = exact < eps_tilde
        converged = s.converged | (fire & verified)
        return s._replace(
            ring=ring, step=s.step + 1,
            persist=jnp.where(fire & ~verified, 0, persist),
            converged=converged,
            detected=jnp.where(
                s.converged, s.detected,
                jnp.where(fire & verified, exact, inf)),
            verifications=s.verifications + fire.astype(jnp.int32),
            detect_step=jnp.where(
                converged & ~s.converged, s.step, s.detect_step),
        )

    # nfais5 — two-phase persistence confirmation
    candidate = (persist >= m) & (s.phase == 0)
    phase = jnp.where(candidate, 1, s.phase)
    confirm_at = jnp.where(candidate, s.step + m, s.confirm_at)
    confirming = (s.phase == 1) & (s.step >= s.confirm_at)
    confirmed = confirming & below & (persist >= 2 * m)
    failed = confirming & ~confirmed
    converged = s.converged | confirmed
    intmax = jnp.int32(jnp.iinfo(jnp.int32).max)
    return s._replace(
        ring=ring, step=s.step + 1, persist=persist,
        phase=jnp.where(failed | confirmed, 0, phase),
        confirm_at=jnp.where(failed | confirmed, intmax, confirm_at),
        converged=converged,
        detected=jnp.where(
            s.converged, s.detected, jnp.where(confirmed, visible, inf)),
        detect_step=jnp.where(
            converged & ~s.converged, s.step, s.detect_step),
    )


@partial(jax.jit, static_argnames=("mode", "ord"))
def _batched_scan(mode: str, contribs, eps_l, epst_l, K_l, m_l, ring0,
                  ord: float = 2.0) -> _LaneState:
    S = contribs.shape[0]
    nlanes = eps_l.shape[0]
    zero_i = jnp.zeros((S, nlanes), jnp.int32)
    state = _LaneState(
        ring=jnp.broadcast_to(ring0, (S, nlanes) + ring0.shape).astype(
            jnp.float32),
        step=zero_i,
        persist=zero_i,
        phase=zero_i,
        confirm_at=jnp.full((S, nlanes), jnp.iinfo(jnp.int32).max, jnp.int32),
        converged=jnp.zeros((S, nlanes), jnp.bool_),
        detected=jnp.full((S, nlanes), jnp.inf, jnp.float32),
        verifications=zero_i,
        detect_step=jnp.full((S, nlanes), -1, jnp.int32),
    )
    lane = partial(_lane_step, mode)
    # vmap lanes (params vary, g shared), then seeds (g varies, params shared)
    lanes = jax.vmap(lane, in_axes=(0, None, 0, 0, 0, 0))
    seeds = jax.vmap(lanes, in_axes=(0, 0, None, None, None, None))

    def body(s, g_t):
        g = _sigma_lane(g_t.astype(jnp.float32), ord)
        return seeds(s, g, eps_l, epst_l, K_l, m_l), None

    state, _ = jax.lax.scan(body, state, jnp.asarray(contribs).T)
    return state


def batched_monitor(mode: str, contribs, eps, staleness, persistence,
                    ord: float = 2.0, eps_tilde=None) -> BatchedVerdict:
    """Run the detection monitor over a full (seed × ε × K × m) grid in one
    jitted device program.

    ``contribs`` — f32[S, T]: per-seed series of already globally-reduced
    contribution sums, one per check (the ``axis_names=None`` convention of
    ``step``).  ``eps`` [E], ``staleness`` [K] and ``persistence`` [M] are
    1-D parameter grids (staleness must be concrete — the ring is padded to
    its max).  ``eps_tilde`` defaults to ``eps`` (the non-PFAIT convention
    of ``for_mode``).

    Returns a ``BatchedVerdict`` of [S, E, K, M] arrays whose entries are
    bitwise-identical to running the per-config ``step`` loop.
    """
    if mode not in MODES:
        raise ValueError(f"mode {mode!r} not in {MODES}")
    eps = np.asarray(eps, dtype=np.float32).reshape(-1)
    epst = (np.asarray(eps_tilde, dtype=np.float32).reshape(-1)
            if eps_tilde is not None else eps)
    if epst.shape != eps.shape:
        raise ValueError("eps_tilde grid must match eps grid")
    stal = np.asarray(staleness, dtype=np.int32).reshape(-1)
    if mode == "sync":
        stal = np.zeros_like(stal)  # MonitorConfig forces K=0 for sync
    pers = np.asarray(persistence, dtype=np.int32).reshape(-1)
    E, K, M = eps.size, stal.size, pers.size
    eps_g, stal_g, pers_g = np.meshgrid(eps, stal, pers, indexing="ij")
    epst_g = np.broadcast_to(epst[:, None, None], eps_g.shape)
    ring0 = jnp.full((int(stal.max()) + 1,), jnp.inf, dtype=jnp.float32)
    state = _batched_scan(
        mode, jnp.asarray(contribs, dtype=jnp.float32),
        jnp.asarray(eps_g.reshape(-1)), jnp.asarray(epst_g.reshape(-1)),
        jnp.asarray(stal_g.reshape(-1)), jnp.asarray(pers_g.reshape(-1)),
        ring0, ord=float(ord),
    )
    S = np.asarray(contribs).shape[0]
    shape = (S, E, K, M)
    return BatchedVerdict(
        converged=state.converged.reshape(shape),
        detect_step=state.detect_step.reshape(shape),
        detected_residual=state.detected.reshape(shape),
        verifications=state.verifications.reshape(shape),
    )


# ---------------------------------------------------------------------------
# Lane lifecycle — pack / retire / refill without recompiling
# ---------------------------------------------------------------------------
#
# ``batched_monitor`` assumes every lane starts at step 0 and runs the same
# T checks — fine for parameter studies, wrong for a *service* where tenants
# arrive continuously and converge at different steps.  The lane-lifecycle
# API below exposes the same per-lane update (``_lane_step``) as a resident
# state that a server advances chunk by chunk:
#
# * ``init_lanes``        — fresh [L]-shaped lane states (ring padded to the
#   service's max K+1; padding slots are never read, so per-lane verdicts
#   stay bitwise-identical to a solo ``batched_monitor`` run),
# * ``reset_lanes``       — re-initialise a masked subset of lanes (retire a
#   converged tenant, admit the next one) with pure ``where`` ops: shapes
#   never change, so the compiled executable is reused as-is,
# * ``make_lane_runner``  — fuse a batched problem step with the monitor
#   update into one jitted chunk program ``(X, ops, state, ε, ε̃, K, m) →
#   (X', state', contribs[L, chunk])``.  Compiling this ONCE per
#   (family, shape-bucket, mode) signature is what makes a multi-tenant
#   detection service pay compilation per *signature*, not per tenant
#   (``launch/serve.py``).


#: public alias — the per-lane monitor state carried by the lane runner
LaneState = _LaneState


def init_lanes(nlanes: int, ring_len: int) -> _LaneState:
    """Fresh monitor state for ``nlanes`` independent detection lanes.

    ``ring_len`` must be ≥ the largest per-lane ``K + 1`` the lanes will
    ever be configured with; oversizing it only pads (padding slots are
    never read — see ``batched_monitor``'s bitwise-parity note).
    """
    if nlanes < 1 or ring_len < 1:
        raise ValueError(f"need nlanes>=1, ring_len>=1, got {nlanes}/{ring_len}")
    zero_i = jnp.zeros((nlanes,), jnp.int32)
    return _LaneState(
        ring=jnp.full((nlanes, ring_len), jnp.inf, jnp.float32),
        step=zero_i,
        persist=zero_i,
        phase=zero_i,
        confirm_at=jnp.full((nlanes,), jnp.iinfo(jnp.int32).max, jnp.int32),
        converged=jnp.zeros((nlanes,), jnp.bool_),
        detected=jnp.full((nlanes,), jnp.inf, jnp.float32),
        verifications=zero_i,
        detect_step=jnp.full((nlanes,), -1, jnp.int32),
    )


def lane_step_batched(mode: str, state: _LaneState, g: jax.Array,
                      eps: jax.Array, eps_tilde: jax.Array,
                      K: jax.Array, m: jax.Array) -> _LaneState:
    """One monitor check on every lane: ``_lane_step`` vmapped over [L].

    ``g`` — per-lane σ-applied global residual ([L], f32); the parameter
    arrays are per-lane (traced, so mixed-ε/K/m lanes share one program).
    """
    return jax.vmap(partial(_lane_step, mode))(state, g, eps, eps_tilde, K, m)


def reset_lanes(state: _LaneState, mask: jax.Array) -> _LaneState:
    """Re-initialise the lanes where ``mask`` is True (retire + refill).

    Pure ``where`` ops on every field — shapes are unchanged, so a jitted
    caller never recompiles; untouched lanes carry their state bitwise.
    """
    mask = jnp.asarray(mask, jnp.bool_)
    col = mask[:, None]
    zero_i = jnp.zeros_like(state.step)
    return _LaneState(
        ring=jnp.where(col, jnp.inf, state.ring),
        step=jnp.where(mask, 0, state.step),
        persist=jnp.where(mask, 0, state.persist),
        phase=jnp.where(mask, 0, state.phase),
        confirm_at=jnp.where(mask, jnp.iinfo(jnp.int32).max, state.confirm_at),
        converged=jnp.where(mask, False, state.converged),
        detected=jnp.where(mask, jnp.inf, state.detected),
        verifications=jnp.where(mask, 0, state.verifications),
        detect_step=jnp.where(mask, -1, state.detect_step),
    )


def make_lane_runner(mode: str, step_fn, chunk: int, ord: float = 2.0):
    """Build the jitted chunk executable of a lane bucket.

    ``step_fn(X, ops) -> (X_next, contrib[L])`` — a batched problem step
    (the solvers' ``update_with_residual_batched`` closed over a shared
    geometry instance, with the per-lane operands passed as the ``ops``
    pytree so refilling a lane swaps array *rows*, never shapes).

    Returns ``run(X, ops, state, eps, eps_tilde, K, m) -> (X', state',
    contribs[L, chunk])`` where ``contribs`` is the raw (pre-σ) per-lane
    contribution series of the chunk — feeding a tenant's recorded series
    back through ``batched_monitor`` reproduces its verdict bitwise, and
    the σ-applied series is the exact-residual trace the oracle scores
    (the batched step is synchronous, so the contribution IS the true
    residual of the lane's input state).
    """
    if mode not in MODES:
        raise ValueError(f"mode {mode!r} not in {MODES}")
    if chunk < 1:
        raise ValueError(f"chunk={chunk} must be >= 1")
    use_ord = float(ord)

    def run(X, ops, state, eps, eps_tilde, K, m):
        """One chunk: scan the fused solve+monitor step over all lanes."""

        def body(carry, _):
            """One device step: problem update, σ, per-lane monitor check."""
            Xc, s = carry
            Xn, contrib = step_fn(Xc, ops)
            c32 = contrib.astype(jnp.float32)
            g = _sigma_lane(c32, use_ord)
            s = lane_step_batched(mode, s, g, eps, eps_tilde, K, m)
            return (Xn, s), c32

        (X, state), cs = jax.lax.scan(body, (X, state), None,
                                      length=int(chunk))
        return X, state, cs.T

    return jax.jit(run)


def contribution_series(step_fn, x0, T: int) -> jax.Array:
    """[S, T] pre-sweep contribution series from a batched problem step.

    ``step_fn(X) -> (X_next, contrib[S])`` — e.g. the problems'
    ``update_with_residual_batched`` — scanned T times in one program.
    """

    def body(X, _):
        """One synchronous batched step; emits the pre-step contribution."""
        Xn, c = step_fn(X)
        return Xn, c

    _, cs = jax.lax.scan(body, x0, None, length=int(T))
    return cs.T
