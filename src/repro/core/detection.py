"""TPU-native convergence detection — the paper's contribution as a
composable JAX module.

The paper terminates an asynchronous iterative process from the result of
*successive non-blocking reduction operations* over free-running local
residual contributions (PFAIT), instead of running a snapshot protocol.

On an SPMD machine the analogue of a non-blocking ``MPI_Iallreduce`` is a
**pipelined stale reduction**: the while-loop carry holds a ring buffer of
``K+1`` global-residual scalars; the reduction "launched" at iteration ``k``
is only *consumed* (compared against ε) at iteration ``k+K``.  Because
nothing reads the psum result for K iterations, XLA is free to schedule the
8-byte collective concurrently with the next sweeps' compute — detection
leaves the critical path exactly as in the paper.  ``K = 0`` recovers the
classical blocking (synchronous) detection.

Four modes, mirroring the paper's head-to-head:

* ``sync``    — blocking exact reduction every check (baseline),
* ``pfait``   — the paper: stale reduction + tightened threshold ε = ε̃/margin,
* ``nfais2``  — candidate from the stale reduction must persist, then a
                *blocking exact verification* runs (emulates the snapshot
                protocol that carries interface data: exactness paid with a
                synchronisation),
* ``nfais5``  — candidate must persist m checks, then be *confirmed* after m
                further checks (no data verification; emulates the O(1)
                approximate snapshot, guarantee factor (1+c(p,m))).

All functions are jittable and usable inside ``lax.while_loop`` bodies under
``shard_map`` (pass ``axis_names``) or outside (pass ``axis_names=None`` and
pre-reduced contributions).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import residual as res

MODES = ("sync", "pfait", "nfais2", "nfais5")


@dataclass(frozen=True)
class MonitorConfig:
    mode: str = "pfait"
    eps: float = 1e-6            # detection threshold ε (already tightened)
    eps_tilde: float = 1e-6      # desired precision ε̃ (NFAIS2 verifies this)
    staleness: int = 2           # K — reduction pipeline depth (0 = blocking)
    persistence: int = 4         # m — NFAIS persistence checks
    ord: float = 2.0             # residual norm order (2 or inf)
    check_every: int = 1         # reduce every C iterations

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.mode == "sync" and self.staleness != 0:
            object.__setattr__(self, "staleness", 0)

    @property
    def ring_len(self) -> int:
        return self.staleness + 1


class MonitorState(NamedTuple):
    """Carried through the solver's ``lax.while_loop``."""

    ring: jax.Array          # f32[K+1] — in-flight reduction results
    step: jax.Array          # i32 — checks performed
    persist: jax.Array       # i32 — consecutive sub-ε checks (NFAIS)
    phase: jax.Array         # i32 — NFAIS5: 0 monitor, 1 confirm window
    confirm_at: jax.Array    # i32 — NFAIS5: step at which to confirm
    converged: jax.Array     # bool
    detected_residual: jax.Array  # f32 — the (stale) residual that fired
    verifications: jax.Array      # i32 — NFAIS2 blocking verifications paid


def init_state(cfg: MonitorConfig) -> MonitorState:
    return MonitorState(
        ring=jnp.full((cfg.ring_len,), jnp.inf, dtype=jnp.float32),
        step=jnp.zeros((), jnp.int32),
        persist=jnp.zeros((), jnp.int32),
        phase=jnp.zeros((), jnp.int32),
        confirm_at=jnp.full((), jnp.iinfo(jnp.int32).max, jnp.int32),
        converged=jnp.zeros((), jnp.bool_),
        detected_residual=jnp.full((), jnp.inf, jnp.float32),
        verifications=jnp.zeros((), jnp.int32),
    )


def _push_ring(ring: jax.Array, value: jax.Array, step: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Insert the freshly-launched reduction; read the one launched K ago.

    The ring is a circular buffer indexed by ``step mod (K+1)``: position
    ``step % L`` currently holds the value launched ``K+1`` steps ago (its
    result was consumed last step), so we read *then* overwrite.
    """
    L = ring.shape[0]
    idx = jnp.mod(step, L)
    nxt = jnp.mod(step + 1, L) if L > 1 else idx
    # value launched at (step - K) sits at (step+1) mod L ... for L==1 it is
    # the current value (blocking).
    visible = ring[nxt] if L > 1 else value
    ring = ring.at[idx].set(value)
    return ring, visible


def step(
    cfg: MonitorConfig,
    state: MonitorState,
    local_contribution: jax.Array,
    axis_names=None,
    exact_residual_fn: Optional[Callable[[], jax.Array]] = None,
) -> MonitorState:
    """One detection check.

    ``local_contribution`` — this worker's ``r_i`` (pre-σ, see residual.py);
    if ``axis_names`` is None it must already be globally reduced *per-l*
    contribution sum (simulator / single-host use).

    ``exact_residual_fn`` — NFAIS2 only: a thunk evaluating the *exact*
    current global residual (blocking).  Evaluated lazily under ``lax.cond``
    so the synchronisation is paid only when a candidate fires.
    """
    if axis_names is not None:
        g = res.psum_sigma(local_contribution, axis_names, cfg.ord)
    else:
        g = res.sigma(local_contribution, cfg.ord)
    g = g.astype(jnp.float32)

    ring, visible = _push_ring(state.ring, g, state.step)
    below = visible < cfg.eps

    if cfg.mode in ("sync", "pfait"):
        converged = state.converged | below
        detected = jnp.where(
            state.converged, state.detected_residual, jnp.where(below, visible, jnp.inf)
        )
        return state._replace(
            ring=ring,
            step=state.step + 1,
            converged=converged,
            detected_residual=detected,
        )

    persist = jnp.where(below, state.persist + 1, 0)

    if cfg.mode == "nfais2":
        candidate = persist >= cfg.persistence
        fire = candidate & ~state.converged

        def verify(_):
            if exact_residual_fn is None:
                # No verifier supplied: fall back to the stale value (the
                # caller accepts NFAIS5-like semantics).
                return visible
            return exact_residual_fn().astype(jnp.float32)

        exact = jax.lax.cond(fire, verify, lambda _: jnp.float32(jnp.inf), operand=None)
        verified = exact < cfg.eps_tilde
        converged = state.converged | (fire & verified)
        return state._replace(
            ring=ring,
            step=state.step + 1,
            persist=jnp.where(fire & ~verified, 0, persist),
            converged=converged,
            detected_residual=jnp.where(
                state.converged, state.detected_residual, jnp.where(fire & verified, exact, jnp.inf)
            ),
            verifications=state.verifications + fire.astype(jnp.int32),
        )

    # nfais5 — two-phase persistence confirmation
    candidate = (persist >= cfg.persistence) & (state.phase == 0)
    phase = jnp.where(candidate, 1, state.phase)
    confirm_at = jnp.where(candidate, state.step + cfg.persistence, state.confirm_at)
    confirming = (state.phase == 1) & (state.step >= state.confirm_at)
    confirmed = confirming & below & (persist >= 2 * cfg.persistence)
    failed = confirming & ~confirmed
    converged = state.converged | confirmed
    return state._replace(
        ring=ring,
        step=state.step + 1,
        persist=persist,
        phase=jnp.where(failed | confirmed, 0, phase),
        confirm_at=jnp.where(failed | confirmed, jnp.iinfo(jnp.int32).max, confirm_at),
        converged=converged,
        detected_residual=jnp.where(
            state.converged, state.detected_residual, jnp.where(confirmed, visible, jnp.inf)
        ),
    )


def should_stop(state: MonitorState) -> jax.Array:
    return state.converged


# ---------------------------------------------------------------------------
# Threshold selection (paper §4.2 methodology)
# ---------------------------------------------------------------------------


def pfait_threshold(eps_tilde: float, margin: float = 10.0) -> float:
    """PFAIT's tightened threshold ε = ε̃ / margin.

    The paper calibrates the margin from platform stability runs; 10 was the
    value that made every large-problem run satisfy ``r* < ε̃`` (§4.2,
    Tables 4–5).  See ``core.termination.calibrate_margin``.
    """
    return eps_tilde / margin


def for_mode(mode: str, eps_tilde: float, margin: float = 10.0, **kw) -> MonitorConfig:
    """Monitor config for a protocol head-to-head at target precision ε̃."""
    eps = pfait_threshold(eps_tilde, margin) if mode == "pfait" else eps_tilde
    return MonitorConfig(mode=mode, eps=eps, eps_tilde=eps_tilde, **kw)
