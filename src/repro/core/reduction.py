"""Reduction-mode registry — the single source of truth for how a device
runtime produces its global residual.

Before this module the three on-device reduction strategies lived as
``"blocking"/"nonblocking"/"rdoubling"`` string literals scattered across
``runtime/shard_runtime.py``, ``runtime/train_async.py``,
``runtime/elastic.py`` and every benchmark that drives them, each site
re-deriving the same facts (does this mode force the monitor's staleness to
zero?  does it need a power-of-two butterfly?).  ``ReductionMode`` records
those facts once, mirroring ``benchmarks.common.make_protocol``'s registry
for the event-level protocols:

* ``blocking``    — barrier semantics: the reduction is consumed the same
  step it is launched (monitor K forced to 0) and detection pays an extra
  exact residual pass on the critical path.
* ``nonblocking`` — the paper: the contribution is a free by-product, the
  collective is in flight for K checks, detection leaves the critical path.
* ``rdoubling``   — modified recursive doubling (Zou & Magoulès): one
  XOR-partner butterfly round per outer step; a global value completes
  every log2(p) steps, so the mode carries its own pipeline staleness
  (monitor K forced to 0) and requires a power-of-two shard count.

Configs validate through ``get_reduction`` at construction; topology facts
(``rounds_per_value``, ``usable_shard_count``) feed ``shrink_to_fit`` and
the trace replayer (``sim/replay.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple


@dataclass(frozen=True)
class ReductionMode:
    """Static facts about one on-device reduction strategy."""

    name: str
    barrier: bool                  # consumed the same step it is launched
    forces_zero_staleness: bool    # monitor K forced to 0
    requires_power_of_two: bool    # butterfly partner geometry
    topology: str                  # "flat" (psum/pmax) | "butterfly"
    extra_residual_pass: bool      # detection work on the critical path

    def rounds_per_value(self, p: int) -> int:
        """Outer steps between completed global values at shard count p
        (the mode's built-in pipeline staleness; 1 = every step)."""
        if self.topology == "butterfly":
            if p & (p - 1):
                raise ValueError(
                    f"{self.name} requires a power-of-two shard count, "
                    f"got {p}")
            return max(p.bit_length() - 1, 1)
        return 1

    def usable_shard_count(self, p: int) -> bool:
        """Can the mode run on p shards at all?"""
        return not (self.requires_power_of_two and p & (p - 1))


REDUCTION_MODES: Dict[str, ReductionMode] = {
    m.name: m
    for m in (
        ReductionMode(name="blocking", barrier=True,
                      forces_zero_staleness=True,
                      requires_power_of_two=False, topology="flat",
                      extra_residual_pass=True),
        ReductionMode(name="nonblocking", barrier=False,
                      forces_zero_staleness=False,
                      requires_power_of_two=False, topology="flat",
                      extra_residual_pass=False),
        ReductionMode(name="rdoubling", barrier=False,
                      forces_zero_staleness=True,
                      requires_power_of_two=True, topology="butterfly",
                      extra_residual_pass=False),
    )
}

#: canonical mode-name tuple (the old ``shard_runtime.REDUCTIONS``)
REDUCTIONS: Tuple[str, ...] = tuple(REDUCTION_MODES)


def get_reduction(name: str) -> ReductionMode:
    """Registry lookup; raises the construction-time validation error every
    runtime config shares."""
    try:
        return REDUCTION_MODES[name]
    except KeyError:
        raise ValueError(
            f"reduction {name!r} not in {REDUCTIONS}") from None
