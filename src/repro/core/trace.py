"""Structured event traces — one schema for the event engine and the
device runtimes, serialized to JSONL.

The observability gap this closes: the event simulator could already
record rich per-message logs (``core.reliability.TraceRecorder``), but in
its own ad-hoc tuple format, and the device runtimes recorded nothing
beyond a residual array — so nothing downstream (replay, calibration,
cost models) could consume "a run" uniformly.  This module defines the
common schema and the emitters on both sides.

**Schema** (``repro-trace/1``).  A trace is a header plus a flat event
list.  Serialized as JSONL: line 1 is the header object, every further
line one event object.  Events carry four fixed keys plus free scalar
payload fields::

    {"kind": <EVENT_KINDS>, "t": float, "w": int worker (-1 global),
     "step": int iteration/round (-1 n/a), ...payload}

Kinds: ``sweep`` (one local sweep batch), ``halo`` (interface exchange),
``reduce`` (reduction-round send/recv; payload ``residual`` carries the
launched global value), ``detect`` (detection claim), ``member``
(membership change), ``segment`` (device wall segment), ``finish``.

**Emitters.**

* ``EngineTraceObserver`` — an ``AsyncEngine(..., recorder=)`` observer
  (same hook protocol as ``TraceRecorder``) emitting schema events with
  virtual timestamps.
* ``trace_from_shard_run`` / ``trace_from_train_run`` — adapters for the
  jitted device loops.  A ``lax.while_loop`` body cannot timestamp its own
  events, so the honest granularity is the run's wall segments plus the
  recorded launched-residual series: per-step timestamps are interpolated
  from the measured wall and marked ``synthetic_t`` in the header.
* ``trace_from_elastic_report`` — segment-level trace of the elastic
  control loop (real per-segment boundaries, crash/join/restart events).

``sim/replay.py`` consumes these traces; ``sim/calibrate.py`` fits delay
models from them.
"""
from __future__ import annotations

import json
import hashlib
from typing import Any, Dict, Iterable, List, Optional

SCHEMA = "repro-trace/1"

EVENT_KINDS = ("sweep", "halo", "reduce", "detect", "member", "segment",
               "finish")

_REQUIRED = ("kind", "t", "w", "step")


def event(kind: str, t: float, w: int = -1, step: int = -1,
          **payload: Any) -> Dict[str, Any]:
    """One schema event (validated at construction)."""
    if kind not in EVENT_KINDS:
        raise ValueError(f"event kind {kind!r} not in {EVENT_KINDS}")
    # payload keys cannot shadow the schema keys: they are named
    # parameters, so Python rejects duplicates before we see them
    ev = {"kind": kind, "t": float(t), "w": int(w), "step": int(step)}
    ev.update(payload)
    return ev


class Trace:
    """Header + event list; JSONL round-trip; content fingerprint."""

    def __init__(self, source: str, p: int,
                 meta: Optional[Dict[str, Any]] = None):
        self.header: Dict[str, Any] = {
            "schema": SCHEMA,
            "source": str(source),
            "p": int(p),
            "meta": dict(meta or {}),
        }
        self.events: List[Dict[str, Any]] = []

    # -- construction -------------------------------------------------------
    def append(self, ev: Dict[str, Any]) -> None:
        self.events.append(ev)

    def add(self, kind: str, t: float, w: int = -1, step: int = -1,
            **payload: Any) -> None:
        self.events.append(event(kind, t, w, step, **payload))

    # -- access -------------------------------------------------------------
    @property
    def p(self) -> int:
        return int(self.header["p"])

    @property
    def source(self) -> str:
        return str(self.header["source"])

    @property
    def meta(self) -> Dict[str, Any]:
        return self.header["meta"]

    def events_of(self, kind: str) -> List[Dict[str, Any]]:
        if kind not in EVENT_KINDS:
            raise ValueError(f"event kind {kind!r} not in {EVENT_KINDS}")
        return [e for e in self.events if e["kind"] == kind]

    def residual_series(self) -> List[float]:
        """Launched global-residual series indexed by outer step.

        Steps with no finite reduce value (e.g. recursive doubling's first
        log2(p)-1 rounds, before any butterfly epoch completes) hold +inf —
        the same "no value visible yet" convention as the device ring.
        """
        ev = [e for e in self.events_of("reduce") if "residual" in e]
        if not ev:
            return []
        n = max(e["step"] for e in ev) + 1
        out = [float("inf")] * n
        for e in ev:
            if e["step"] >= 0:
                out[e["step"]] = float(e["residual"])
        return out

    # -- serialization ------------------------------------------------------
    def dumps(self) -> str:
        lines = [json.dumps(self.header, sort_keys=True)]
        lines += [json.dumps(e, sort_keys=True) for e in self.events]
        return "\n".join(lines) + "\n"

    def dump(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.dumps())

    @classmethod
    def loads(cls, text: str) -> "Trace":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty trace")
        header = json.loads(lines[0])
        if header.get("schema") != SCHEMA:
            raise ValueError(
                f"unknown trace schema {header.get('schema')!r} "
                f"(expected {SCHEMA!r})")
        tr = cls(header.get("source", "?"), header.get("p", 0),
                 header.get("meta"))
        tr.header = header
        tr.events = [json.loads(ln) for ln in lines[1:]]
        return tr

    @classmethod
    def load(cls, path) -> "Trace":
        with open(path) as f:
            return cls.loads(f.read())

    def fingerprint(self) -> str:
        """Deterministic digest of header + events (replay identity)."""
        h = hashlib.sha256()
        h.update(json.dumps(self.header, sort_keys=True).encode())
        for e in self.events:
            h.update(json.dumps(e, sort_keys=True).encode())
        return h.hexdigest()

    def validate(self) -> None:
        """Raise ValueError on the first schema violation."""
        if self.header.get("schema") != SCHEMA:
            raise ValueError(f"bad schema {self.header.get('schema')!r}")
        if not isinstance(self.header.get("p"), int) or self.header["p"] < 1:
            raise ValueError(f"bad worker count p={self.header.get('p')!r}")
        if "source" not in self.header:
            raise ValueError("header missing 'source'")
        for i, e in enumerate(self.events):
            for k in _REQUIRED:
                if k not in e:
                    raise ValueError(f"event {i} missing key {k!r}: {e}")
            if e["kind"] not in EVENT_KINDS:
                raise ValueError(f"event {i} kind {e['kind']!r} unknown")
            if not isinstance(e["w"], int) or not isinstance(e["step"], int):
                raise ValueError(f"event {i} w/step must be int: {e}")
            t = e["t"]
            if not isinstance(t, (int, float)) or t != t:
                raise ValueError(f"event {i} bad timestamp {t!r}")


def validate_trace(tr: Trace) -> bool:
    """Boolean form of ``Trace.validate`` (benchmark acceptance checks)."""
    try:
        tr.validate()
        return True
    except ValueError:
        return False


# ---------------------------------------------------------------------------
# Event-engine emitter (AsyncEngine observer)
# ---------------------------------------------------------------------------


class EngineTraceObserver:
    """``AsyncEngine(..., recorder=)`` observer emitting schema events.

    Same hook protocol as ``core.reliability.TraceRecorder`` (the engine
    feature-detects ``record_sends`` exactly the same way) but the output
    is a schema ``Trace`` any downstream consumer understands.  Virtual
    timestamps are the engine's own event clock — nothing synthetic here.
    """

    def __init__(self, p: int, record_sends: bool = True,
                 meta: Optional[Dict[str, Any]] = None):
        self.record_sends = bool(record_sends)
        self.trace = Trace("engine", p, meta)

    # -- engine hooks -------------------------------------------------------
    def on_sweep(self, eng, t: float, i: int) -> None:
        self.trace.add("sweep", t, w=i, step=int(eng.k[i]))

    def on_send(self, eng, msg, t: float, deliver) -> None:
        kind = "halo" if msg.kind == "data" else "reduce"
        self.trace.add(kind, t, w=int(msg.src), step=int(msg.round),
                       dst=int(msg.dst), msg=str(msg.kind),
                       deliver=(None if deliver is None else float(deliver)),
                       dropped=deliver is None)

    def on_membership(self, eng, t: float, kind: str, worker: int) -> None:
        self.trace.add("member", t, w=int(worker), change=str(kind))

    def on_detect(self, eng, t: float, detected: float) -> None:
        self.trace.add("detect", t, residual=float(detected))

    def on_finish(self, eng, result) -> None:
        self.trace.add("finish", float(eng.now),
                       terminated=bool(result.terminated),
                       k_max=int(result.k_max), k_min=int(result.k_min))


# ---------------------------------------------------------------------------
# Device-runtime adapters
# ---------------------------------------------------------------------------


def _series_prefix(trace_arr, limit: int) -> List[float]:
    """Raw launched-residual prefix, step-indexed (non-finite kept)."""
    import numpy as np

    arr = np.asarray(trace_arr, dtype=np.float64)[:max(limit, 0)]
    return [float(v) for v in arr]


def trace_from_shard_run(result, cfg, p: int, wall_s: float,
                         source: str = "shard",
                         meta: Optional[Dict[str, Any]] = None) -> Trace:
    """Schema trace of one device shard run.

    ``result`` — a ``ShardRunResult``/``TrainRunResult``; ``cfg`` the
    (per-runtime) config it ran under.  Per-step timestamps are the
    measured wall interpolated uniformly over the outer steps (the jitted
    while_loop admits no finer observation) — ``synthetic_t`` marks them.
    """
    import numpy as np

    from repro.core.reduction import get_reduction
    from repro.runtime.shard_runtime import _per_shard

    outer = int(getattr(result, "outer_iters", getattr(result, "rounds", 0)))
    tlen = int(getattr(cfg, "trace_len", 0))
    series = _series_prefix(result.trace, min(outer, max(tlen, 1)))
    mode = get_reduction(cfg.reduction)
    mon = cfg.effective_monitor()
    inner_field = getattr(cfg, "inner_sweeps", getattr(cfg, "inner_steps", 1))
    delay_field = getattr(cfg, "halo_delay", getattr(cfg, "view_delay", 0))
    inner = _per_shard(inner_field, p, "inner").tolist()
    delay = _per_shard(delay_field, p, "delay").tolist()
    lag = _per_shard(cfg.contrib_lag, p, "contrib_lag").tolist()
    mesh_shape = tuple(getattr(cfg, "mesh_shape", None) or (p,))
    # per-worker exchanged faces ((label, peer) pairs) on multi-axis meshes —
    # the 1-D pencil keeps its historical single halo event per worker
    faces: List[List] = [[] for _ in range(p)]
    if len(mesh_shape) > 1:
        import math

        from repro.solvers.partition import MeshPartition

        # face topology is n-independent; any n each axis divides will do
        part = MeshPartition(math.lcm(*mesh_shape), mesh_shape)
        faces = [[(part.face(w, j), j) for j in part.neighbors(w)]
                 for w in range(p)]
    header_meta = {
        "reduction": cfg.reduction,
        "topology": mode.topology,
        "mesh_shape": list(mesh_shape),
        "monitor": {
            "mode": mon.mode, "eps": float(mon.eps),
            "eps_tilde": float(mon.eps_tilde),
            "staleness": int(mon.staleness),
            "persistence": int(mon.persistence), "ord": float(mon.ord),
            "check_every": int(mon.check_every),
        },
        "inner_sweeps": inner,
        "halo_delay": delay,
        "contrib_lag": lag,
        "wall_s": float(wall_s),
        "outer_iters": outer,
        "converged": bool(result.converged),
        "synthetic_t": True,
    }
    header_meta.update(meta or {})
    tr = Trace(source, p, header_meta)
    steps = len(series)
    dt = float(wall_s) / max(outer, 1)
    rpv = mode.rounds_per_value(p)
    for k in range(steps):
        t = (k + 1) * dt
        for w in range(p):
            tr.add("sweep", t, w=w, step=k, inner=inner[w])
            if faces[w]:
                for label, peer in faces[w]:
                    tr.add("halo", t, w=w, step=k, delay=delay[w],
                           face=label, peer=peer)
            else:
                tr.add("halo", t, w=w, step=k, delay=delay[w])
        if np.isfinite(series[k]):
            tr.add("reduce", t, step=k, residual=series[k], lag=max(lag),
                   rounds_per_value=rpv)
    if bool(result.converged) and outer > 0:
        tr.add("detect", wall_s, step=outer - 1,
               residual=float(result.residual))
    tr.add("finish", wall_s, step=max(outer - 1, -1),
           terminated=bool(result.converged))
    return tr


def trace_from_train_run(result, cfg, p: int, wall_s: float,
                         meta: Optional[Dict[str, Any]] = None) -> Trace:
    """``trace_from_shard_run`` for the data-parallel training loop."""
    return trace_from_shard_run(result, cfg, p, wall_s, source="train",
                                meta=meta)


def trace_from_elastic_report(report, cfg, p0: int,
                              segment_walls: Optional[Iterable[float]] = None,
                              meta: Optional[Dict[str, Any]] = None) -> Trace:
    """Segment-level trace of the elastic control loop.

    Segment boundaries and membership events are real (host-side) control
    plane observations; ``segment_walls`` (per-segment wall seconds, when
    the driver measured them) become the segment timestamps, else the
    virtual one-unit-per-segment clock is used.
    """
    walls = list(segment_walls or [])
    header_meta = {
        "reduction": cfg.reduction,
        "segments_run": int(report.segments_run),
        "restarts": int(report.restarts),
        "stall_segments": int(report.stall_segments),
        "converged": bool(report.converged),
        "mesh_history": [[int(s), int(pc)] for s, pc in report.mesh_history],
        "synthetic_t": not walls,
    }
    header_meta.update(meta or {})
    tr = Trace("elastic", p0, header_meta)

    def t_of(seg: int) -> float:
        if walls:
            return float(sum(walls[:seg + 1]))
        return float(seg + 1)

    for seg in range(int(report.segments_run)):
        tr.add("segment", t_of(seg), step=seg,
               wall_s=(walls[seg] if seg < len(walls) else 1.0))
    for seg, kind, detail in report.events:
        if kind in ("crash", "join", "restart"):
            tr.add("member", t_of(int(seg)), step=int(seg),
                   change=str(kind), detail=str(detail))
        elif kind == "detect":
            tr.add("detect", t_of(int(seg)), step=int(seg),
                   residual=(float(report.detected_residual)
                             if report.detected_residual is not None
                             else None))
    tr.add("finish", t_of(int(report.segments_run) - 1),
           step=int(report.segments_run) - 1,
           terminated=bool(report.converged))
    return tr
