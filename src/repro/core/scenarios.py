"""Composable adversarial platform scenarios for the async engine.

The paper's reliability claim (a protocol-free reduction yields a usable
global residual) is platform-dependent: Zou & Magoulès (arXiv:1907.01201)
show detection quality degrades with network regime.  This module turns the
engine's two hand-picked presets (``stable_platform`` / ``unstable_platform``)
into a *scenario algebra*: small frozen effect objects that transform the
engine's sampled delays, drop or spike individual messages, slow workers
persistently, or pause them mid-run — composed into a ``Scenario`` attached
to ``EngineConfig.scenario``.

Effects see every draw the engine makes and may consume additional draws
from the engine's single RNG stream, so a run is a pure function of
``EngineConfig.seed`` — the property the replay trace / false-detection
oracle in ``core.reliability`` relies on.

Hook contract (all optional, defaults are identity):

* ``channel(t, kind, delay, rng)`` → transformed delay, or ``None`` to drop
  the message (collective/reduction draws use ``kind="reduce"`` and are
  never dropped — a tree reduction is modelled as lossless-but-slow);
* ``compute(t, worker, delay, rng)`` → transformed sweep duration;
* ``paused_until(t, worker)`` → resume time if the worker is paused at
  ``t``, else ``None``.

``standard_scenarios()`` is the matrix the reliability lab sweeps:
benchmarks/reliability_matrix.py runs {PFAIT, NFAIS2, NFAIS5,
ExactSnapshotFIFO} × {convdiff, pagerank} × these scenarios and scores
each cell with the oracle.  Scenarios containing a lossy effect violate the
Chandy–Lamport precondition (reliable channels), so ``ExactSnapshotFIFO``
cells are reported as ``precondition_violated`` instead of run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Effect algebra
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Effect:
    """Identity platform effect; subclasses override the hooks they shape."""

    #: effects that may lose messages set this True (CL precondition check)
    lossy = False

    def channel(self, t: float, kind: str, delay: float,
                rng: np.random.Generator) -> Optional[float]:
        return delay

    def compute(self, t: float, worker: int, delay: float,
                rng: np.random.Generator) -> float:
        return delay

    def paused_until(self, t: float, worker: int) -> Optional[float]:
        return None

    # -- dynamic membership (static timelines, declared at construction) ---
    def membership_events(self) -> Tuple[Tuple[float, str, int], ...]:
        """``(t, kind, worker)`` membership transitions this effect injects
        (kind ∈ {"crash", "join", "restore"}).  Static by design: the engine
        schedules them as ordinary heap events at construction, so they
        consume no RNG draws and a run stays a pure function of the seed."""
        return ()

    def initially_inactive(self) -> Tuple[int, ...]:
        """Workers that start outside the membership (late joiners)."""
        return ()

    #: CheckpointRestart overrides this (as a real field) with its snapshot
    #: cadence; a plain class attribute here so asdict()/describe() of the
    #: existing effects is unchanged
    checkpoint_every = None


@dataclass(frozen=True)
class TailSpike(Effect):
    """Occasional huge per-message latency (non-FIFO channels reorder)."""

    prob: float = 0.1
    mult: float = 10.0
    kinds: Optional[Tuple[str, ...]] = None  # None = every message kind

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"TailSpike.prob={self.prob} not in [0, 1]")
        if self.mult < 1.0:
            raise ValueError(f"TailSpike.mult={self.mult} must be >= 1")

    def channel(self, t, kind, delay, rng):
        if self.kinds is not None and kind not in self.kinds:
            return delay
        return delay * self.mult if rng.random() < self.prob else delay


@dataclass(frozen=True)
class JitterBurst(Effect):
    """Correlated jitter: periodic windows where *every* channel (including
    reduction hops' staggered sampling) slows by ``mult`` simultaneously —
    the cross-channel correlation a per-message lognormal cannot produce."""

    period: float = 0.04
    duration: float = 0.01
    mult: float = 25.0
    phase: float = 0.0

    def __post_init__(self):
        if self.period <= 0.0:
            raise ValueError(f"JitterBurst.period={self.period} must be > 0")
        if not 0.0 < self.duration <= self.period:
            raise ValueError(
                f"JitterBurst.duration={self.duration} not in (0, period]")
        if self.mult < 1.0:
            raise ValueError(f"JitterBurst.mult={self.mult} must be >= 1")

    def channel(self, t, kind, delay, rng):
        if ((t - self.phase) % self.period) < self.duration:
            return delay * self.mult
        return delay


@dataclass(frozen=True)
class DropMessages(Effect):
    """Lossy channels: drop matching messages with probability ``prob``
    from time ``after`` on.  ``prob=1.0, after=t0`` is the *interface
    blackout* — dependency views freeze, every worker converges to its own
    frozen-BC subproblem, and protocols that trust live local residuals
    (PFAIT, NFAIS5) false-detect while data-carrying snapshots (NFAIS2)
    merely never fire."""

    prob: float = 0.2
    kinds: Tuple[str, ...] = ("data",)
    after: float = 0.0

    lossy = True

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"DropMessages.prob={self.prob} not in [0, 1]")
        if self.after < 0.0:
            raise ValueError(f"DropMessages.after={self.after} must be >= 0")

    def channel(self, t, kind, delay, rng):
        if kind in self.kinds and t >= self.after and rng.random() < self.prob:
            return None
        return delay


@dataclass(frozen=True)
class Straggler(Effect):
    """Persistently slow workers (the fault_tolerance.StragglerPolicy
    target): every sweep of the listed workers takes ``factor×`` longer."""

    workers: Tuple[int, ...] = (0,)
    factor: float = 8.0

    def __post_init__(self):
        if self.factor < 1.0:
            raise ValueError(f"Straggler.factor={self.factor} must be >= 1")

    def compute(self, t, worker, delay, rng):
        return delay * self.factor if worker in self.workers else delay


@dataclass(frozen=True)
class Pause(Effect):
    """Mid-run worker pause/resume: the worker performs no sweeps during
    [at, at+duration) (its in-flight messages still deliver).  The
    HeartbeatMonitor wiring in ``core.reliability`` detects the silence."""

    worker: int = 0
    at: float = 0.02
    duration: float = 0.05

    def __post_init__(self):
        if self.at < 0.0:
            raise ValueError(f"Pause.at={self.at} must be >= 0")
        if self.duration <= 0.0:
            raise ValueError(f"Pause.duration={self.duration} must be > 0")

    def paused_until(self, t, worker):
        if worker == self.worker and self.at <= t < self.at + self.duration:
            return self.at + self.duration
        return None


# ---------------------------------------------------------------------------
# Dynamic membership primitives (crash / join / checkpoint-restart)
# ---------------------------------------------------------------------------
#
# Unlike Pause, these change the *participant set* itself (Daggitt &
# Griffin's dynamic asynchronous iterations): a crashed worker performs no
# further sweeps, sends nothing, loses every message addressed to it, and is
# excluded from reductions and snapshot quorums; a joiner starts outside the
# membership (its block frozen at x^0) and is admitted mid-run; a
# checkpoint-restart crashes a worker and later re-admits it from the
# engine's periodic state snapshots — the event-level twin of the device
# runtime's crash → heartbeat-detect → restore → resume loop.


@dataclass(frozen=True)
class WorkerCrash(Effect):
    """Worker ``worker`` fail-stops at ``at`` and never returns."""

    worker: int = 0
    at: float = 0.05

    def __post_init__(self):
        if self.worker < 0:
            raise ValueError(f"WorkerCrash.worker={self.worker} must be >= 0")
        if self.at < 0.0:
            raise ValueError(f"WorkerCrash.at={self.at} must be >= 0")

    def membership_events(self):
        return ((self.at, "crash", self.worker),)


@dataclass(frozen=True)
class WorkerJoin(Effect):
    """Worker ``worker`` starts *outside* the membership and is admitted at
    ``at`` (elastic scale-up).  Its block stays frozen at the initial state
    until then — neighbours keep iterating against the x^0 interface they
    were seeded with, exactly as if the joiner's slot were a cold replica."""

    worker: int = 0
    at: float = 0.05

    def __post_init__(self):
        if self.worker < 0:
            raise ValueError(f"WorkerJoin.worker={self.worker} must be >= 0")
        if self.at < 0.0:
            raise ValueError(f"WorkerJoin.at={self.at} must be >= 0")

    def membership_events(self):
        return ((self.at, "join", self.worker),)

    def initially_inactive(self):
        return (self.worker,)


@dataclass(frozen=True)
class CheckpointRestart(Effect):
    """Worker ``worker`` crashes at ``at`` and is re-admitted after
    ``downtime`` from the most recent periodic state snapshot (the engine
    checkpoints every ``checkpoint_every`` of virtual time while any restart
    effect is attached).  Progress since that snapshot is rolled back — the
    recovery-cost regime the device runtime pays in real iterations."""

    worker: int = 0
    at: float = 0.05
    downtime: float = 0.05
    checkpoint_every: float = 0.02

    def __post_init__(self):
        if self.worker < 0:
            raise ValueError(
                f"CheckpointRestart.worker={self.worker} must be >= 0")
        if self.at < 0.0:
            raise ValueError(f"CheckpointRestart.at={self.at} must be >= 0")
        if self.downtime <= 0.0:
            raise ValueError(
                f"CheckpointRestart.downtime={self.downtime} must be > 0")
        if self.checkpoint_every <= 0.0:
            raise ValueError(
                f"CheckpointRestart.checkpoint_every="
                f"{self.checkpoint_every} must be > 0")

    def membership_events(self):
        return ((self.at, "crash", self.worker),
                (self.at + self.downtime, "restore", self.worker))


# ---------------------------------------------------------------------------
# Composition
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Scenario:
    """An ordered composition of effects (applied left to right).

    Effects that leave a hook at the ``Effect`` identity are pruned from
    that hook's dispatch list at construction (identity hooks draw nothing
    from the RNG, so pruning cannot change a run) — the engine consults
    ``channel_effects`` / ``compute_effects`` / ``pause_effects`` to skip
    per-event scenario calls entirely on hooks no effect shapes.
    """

    name: str = "baseline"
    effects: Tuple[Effect, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "channel_effects", tuple(
            e for e in self.effects if type(e).channel is not Effect.channel))
        object.__setattr__(self, "compute_effects", tuple(
            e for e in self.effects if type(e).compute is not Effect.compute))
        object.__setattr__(self, "pause_effects", tuple(
            e for e in self.effects
            if type(e).paused_until is not Effect.paused_until))
        object.__setattr__(self, "membership_effects", tuple(
            e for e in self.effects
            if type(e).membership_events is not Effect.membership_events))

    @property
    def lossy(self) -> bool:
        return any(e.lossy for e in self.effects)

    @property
    def elastic(self) -> bool:
        """True when any effect changes the participant set mid-run."""
        return bool(self.membership_effects)

    def membership_events(self) -> Tuple[Tuple[float, str, int], ...]:
        """Time-sorted ``(t, kind, worker)`` transitions over all effects."""
        out = []
        for e in self.membership_effects:
            out.extend(e.membership_events())
        return tuple(sorted(out))

    def initially_inactive(self) -> Tuple[int, ...]:
        out = set()
        for e in self.membership_effects:
            out.update(e.initially_inactive())
        return tuple(sorted(out))

    @property
    def checkpoint_every(self) -> Optional[float]:
        """Tightest snapshot cadence any restart effect requires (None when
        no effect restores from checkpoints)."""
        cadences = [e.checkpoint_every for e in self.membership_effects
                    if e.checkpoint_every is not None]
        return min(cadences) if cadences else None

    def channel_delay(self, t: float, kind: str, delay: float,
                      rng: np.random.Generator) -> Optional[float]:
        for e in self.channel_effects:
            delay = e.channel(t, kind, delay, rng)
            if delay is None:
                return None
        return delay

    def compute_delay(self, t: float, worker: int, delay: float,
                      rng: np.random.Generator) -> float:
        for e in self.compute_effects:
            delay = e.compute(t, worker, delay, rng)
        return delay

    def paused_until(self, t: float, worker: int) -> Optional[float]:
        resume = None
        for e in self.pause_effects:
            r = e.paused_until(t, worker)
            if r is not None:
                resume = r if resume is None else max(resume, r)
        return resume

    def describe(self) -> Dict:
        return {
            "name": self.name,
            "lossy": self.lossy,
            "effects": [
                {"kind": type(e).__name__, **dataclasses.asdict(e)}
                for e in self.effects
            ],
        }


# ---------------------------------------------------------------------------
# The reliability-lab scenario matrix
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ScenarioSpec:
    """A scenario plus the platform preset it runs on (``platform`` is a
    key into ``core.async_engine`` preset factories: stable | unstable |
    heavy_tail)."""

    name: str
    platform: str
    scenario: Scenario

    @property
    def lossy(self) -> bool:
        return self.scenario.lossy


def standard_scenarios(base: float = 1e-3) -> Dict[str, ScenarioSpec]:
    """The ~8-regime sweep of the reliability matrix.  ``base`` is the
    platform compute_base; every time constant scales with it so the
    scenarios stress the same *relative* regimes at any simulation scale."""

    def spec(name, platform, *effects):
        return ScenarioSpec(name, platform, Scenario(name, tuple(effects)))

    return {
        # the paper's own two regimes, as baselines for the oracle
        "stable": spec("stable", "stable"),
        "unstable": spec("unstable", "unstable"),
        # heavy-tailed channel latency (Pareto tail index 1.2: occasional
        # delays orders of magnitude above the median)
        "heavy_tail": spec("heavy_tail", "heavy_tail"),
        # correlated jitter bursts: all channels ×30 for a quarter of
        # every 40-sweep window
        "burst": spec("burst", "stable",
                      JitterBurst(period=40 * base, duration=10 * base,
                                  mult=30.0)),
        # lossy + reordering channels (CL precondition violated)
        "drop_reorder": spec("drop_reorder", "stable",
                             DropMessages(prob=0.25, kinds=("data",)),
                             TailSpike(prob=0.15, mult=12.0,
                                       kinds=("data",))),
        # one worker persistently 10× slower
        "straggler": spec("straggler", "stable",
                          Straggler(workers=(0,), factor=10.0)),
        # mid-run pause/resume of one worker
        "pause_resume": spec("pause_resume", "stable",
                             Pause(worker=1, at=50 * base,
                                   duration=200 * base)),
        # interface blackout: data messages stop entirely after 30 sweeps'
        # worth of time — the constructed PFAIT false-detection regime
        "blackout": spec("blackout", "stable",
                         DropMessages(prob=1.0, kinds=("data",),
                                      after=30 * base)),
    }


def elastic_scenarios(base: float = 1e-3) -> Dict[str, ScenarioSpec]:
    """The dynamic-membership sweep (benchmarks/bench_elastic.py): crash,
    join, checkpoint-restart and their compositions, all on the stable
    platform so any detection failure is attributable to the membership
    change itself.  Worker indices assume p >= 4 (the lab's standard
    decomposition)."""

    def spec(name, platform, *effects):
        return ScenarioSpec(name, platform, Scenario(name, tuple(effects)))

    # Timings are calibrated against the detection times of the benchmark
    # lane (convdiff n=12 p=4 rho=0.9, eps=1e-6 at the problem's max-norm):
    # with no faults every protocol detects at t ≈ (92–122)·base, so every
    # event below lands at t < 90·base — each scenario's full membership
    # sequence is guaranteed to be *in effect before any detection fires*,
    # which is what makes the matrix a test of the protocols' bookkeeping
    # rather than of event/detection racing.
    return {
        # fail-stop early (before any protocol has converged once)
        "crash_early": spec("crash_early", "stable",
                            WorkerCrash(worker=2, at=30 * base)),
        # fail-stop late (snapshot rounds already in flight, detection near)
        "crash_late": spec("crash_late", "stable",
                           WorkerCrash(worker=1, at=80 * base)),
        # two staggered crashes: membership shrinks twice (4 → 3 → 2)
        "crash_two": spec("crash_two", "stable",
                          WorkerCrash(worker=2, at=40 * base),
                          WorkerCrash(worker=0, at=80 * base)),
        # elastic scale-up: worker 3's block stays frozen at x^0 until
        # admission — survivors converge toward the wrong (frozen-BC) fixed
        # point first, then must re-converge with the joiner
        "join_late": spec("join_late", "stable",
                          WorkerJoin(worker=3, at=60 * base)),
        # crash + checkpoint-restart: progress since the last periodic
        # snapshot is rolled back on re-admission.  Downtime is short
        # enough that the restore lands *before* the survivors' detection
        # fires — the protocols must carry their bookkeeping through the
        # full crash → restore → re-converge cycle
        "crash_restart": spec("crash_restart", "stable",
                              CheckpointRestart(worker=1, at=40 * base,
                                                downtime=40 * base,
                                                checkpoint_every=20 * base)),
        # churn: a join and an independent checkpoint-restart overlap
        "churn": spec("churn", "stable",
                      WorkerJoin(worker=3, at=40 * base),
                      CheckpointRestart(worker=1, at=60 * base,
                                        downtime=40 * base,
                                        checkpoint_every=20 * base)),
    }


def scenario_registry(base: float = 1e-3) -> Dict[str, ScenarioSpec]:
    """Merged lookup: the reliability matrix's standard regimes plus the
    elastic membership sweep (names are disjoint by construction)."""
    out = standard_scenarios(base)
    out.update(elastic_scenarios(base))
    return out
