"""Replay traces + the false/late-detection oracle (paper §4 reliability).

The paper's reliability metric compares the residual a protocol *detected*
against the exact residual of the assembled iterate — detection is
**false** when the protocol claims r < ε while the true state is far above
it, and merely **late** when the claim is sound but fires long after the
true residual first crossed ε.  The seed code could only observe ``r_star``
(the exact residual at full stop); this module records enough during a run
to score both failure modes per run:

* ``TraceRecorder`` — an engine observer (``AsyncEngine(..., recorder=)``)
  that logs every sweep/send/drop/detect event with virtual timestamps,
  samples the exact residual trajectory every ``residual_stride`` sweeps,
  and captures ``r(x̄)`` at the detection instant.  The event log is a pure
  function of ``EngineConfig.seed`` (the engine, its block-buffered delay
  draws, and scenario effects all consume one RNG stream), so two runs with
  identical configs produce byte-identical traces — ``fingerprint()`` is
  the determinism check and the replay key.
* ``detection_report`` — the oracle: detected ε vs. true residual at
  detection time (false detection at ``factor×`` disagreement), plus
  detection latency overhead against the first trajectory crossing.
* ``platform_health`` — replays the sweep trace through the runtime's
  HeartbeatMonitor/StragglerPolicy (runtime/fault_tolerance.py), closing
  the loop between simulated scenarios and the production policies.

A note on *which* protocols may false-detect: PFAIT samples live local
residuals against stale dependency views, and NFAIS5 records last-delivered
dependencies — both trust the network to keep mixing interface data, so a
frozen/lossy platform can starve them into agreeing on a wrong answer.
NFAIS2 snapshot messages carry the interface data itself and
ExactSnapshotFIFO cuts are consistent by construction (given its reliable
FIFO precondition) — their detected residual is exact for the recorded
vector, so they can be late or undetected but never false *about that
record* (``BaseProtocol.claim == "recorded"``; the oracle recomputes the
record's residual independently).  The **live** state at the detection
instant is a different quantity for every protocol: under heavy-tailed
delays an ancient in-flight interface delivery can transiently spike
``r(x̄)`` at any stopping instant — reported as ``overshoot`` but only
scored as a false detection for the live-claim protocols.
"""
from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple


from repro.core.async_engine import AsyncEngine, EngineConfig, Msg, RunResult
from repro.runtime.fault_tolerance import PlatformHealth, health_from_sweeps


# ---------------------------------------------------------------------------
# Trace recording
# ---------------------------------------------------------------------------


class TraceRecorder:
    """Engine observer: event log + exact-residual trajectory samples.

    ``residual_stride``: sample ``problem.exact_residual`` every N-th sweep
    event (0 disables trajectory sampling; the detection-instant capture
    always happens).  Sampling is O(global grid) — affordable at lab scale,
    and it reads engine state without perturbing the RNG stream, so traces
    with and without sampling are event-identical.

    ``record_sends``: log per-message send/drop events (the full replay
    trace).  Campaign matrix runs pass False — the oracle and the platform
    health replay only consume sweep/detect events, and skipping the ~4
    send appends per sweep is a measurable slice of a cell.  Fingerprints
    of traces with different ``record_sends`` are incomparable.
    """

    def __init__(self, residual_stride: int = 0, record_sends: bool = True):
        self.residual_stride = int(residual_stride)
        self.record_sends = bool(record_sends)
        self.events: List[Tuple] = []
        self.residual_samples: List[Tuple[float, float]] = []
        self.detect: Optional[Tuple[float, float]] = None   # (t, detected ε)
        self.true_at_detect: Optional[float] = None          # r(x̄) at detect
        self.certified_at_detect: Optional[float] = None     # r(record) if any
        self.active_at_detect: Optional[float] = None        # r restricted to
        #                                     the active membership at detect
        self.membership: List[Tuple[float, str, int]] = []   # (t, kind, worker)
        self.claim: str = "live"                             # protocol claim
        self.result: Optional[RunResult] = None
        self._sweeps = 0

    # -- engine hooks -------------------------------------------------------
    def on_sweep(self, eng: AsyncEngine, t: float, i: int) -> None:
        self.events.append(("sweep", t, i, int(eng.k[i])))
        self._sweeps += 1
        if self.residual_stride and self._sweeps % self.residual_stride == 0:
            self.residual_samples.append(
                (t, float(eng.problem.exact_residual(eng.x))))

    def on_send(self, eng: AsyncEngine, msg: Msg, t: float,
                deliver: Optional[float]) -> None:
        # deliver=None marks a scenario-dropped message
        if self.record_sends:
            self.events.append(("send", t, msg.src, msg.dst, msg.kind,
                                deliver))

    def on_membership(self, eng: AsyncEngine, t: float, kind: str,
                      worker: int) -> None:
        self.membership.append((t, kind, worker))
        self.events.append(("member", t, kind, worker))

    def on_detect(self, eng: AsyncEngine, t: float, detected: float) -> None:
        self.detect = (t, float(detected))
        self.true_at_detect = float(eng.problem.exact_residual(eng.x))
        self.claim = getattr(eng.protocol, "claim", "live")
        elastic = bool(getattr(eng, "membership_changes", 0)) or not all(
            getattr(eng, "active", [True]))
        if elastic:
            # ground truth under dynamic membership: the active subsystem's
            # residual (inactive blocks are frozen boundary data — Daggitt &
            # Griffin's dynamic-iteration fixed point), which is what any
            # claim made by the surviving membership is actually about
            self.active_at_detect = float(eng.exact_active_residual())
        rec = getattr(eng.protocol, "recorded_vector", lambda: None)()
        if rec is not None:
            if elastic:
                # holes in the record are inactive workers: substitute
                # their frozen live blocks and score the active subsystem
                # of the assembled vector
                assembled = [r if r is not None else eng.x[i]
                             for i, r in enumerate(rec)]
                self.certified_at_detect = float(
                    eng.exact_active_residual(xs=assembled))
            else:
                self.certified_at_detect = float(
                    eng.problem.exact_residual(rec))
        self.events.append(("detect", t, float(detected), self.true_at_detect,
                            self.certified_at_detect))

    def on_finish(self, eng: AsyncEngine, result: RunResult) -> None:
        self.result = result
        # claim is also captured here so UNDETECTED runs still report the
        # protocol's claim kind (on_detect never fired for them)
        self.claim = getattr(eng.protocol, "claim", "live")
        self.events.append(("finish", eng.now, result.terminated,
                            result.k_max, result.k_min))

    # -- trace identity -----------------------------------------------------
    def sweep_events(self) -> List[Tuple[float, int]]:
        return [(e[1], e[2]) for e in self.events if e[0] == "sweep"]

    def fingerprint(self) -> str:
        """Deterministic digest of the full event log (replay identity)."""
        h = hashlib.sha256()
        for e in self.events:
            h.update(repr(e).encode())
        return h.hexdigest()


# ---------------------------------------------------------------------------
# The oracle
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DetectionReport:
    """Per-run reliability verdict (paper §4's metric, per run)."""

    terminated: bool
    eps: float
    detected_residual: float      # the protocol's claim (inf if undetected)
    true_at_detect: float         # r(x̄) at the detection instant (inf if n/a)
    overshoot: float              # true_at_detect / eps (inf if undetected)
    false_detection: bool         # the protocol's *claim* was > factor·ε off
    factor: float                 # the disagreement factor used
    t_detect: float
    t_first_below: Optional[float]   # first trajectory sample with r ≤ ε
    latency_overhead: Optional[float]  # t_detect − t_first_below (late-ness)
    claim: str = "live"           # what was scored: live state or a record
    certified_residual: Optional[float] = None  # r(recorded vector) if any
    membership_changes: int = 0   # crash/join/restore events during the run
    active_residual: Optional[float] = None  # r of the active subsystem at
    #                               detect (None when membership never changed)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def detection_report(rec: TraceRecorder, eps: float,
                     factor: float = 10.0) -> DetectionReport:
    """Score one recorded run.

    ``factor`` separates *false* detection from the benign overshoot the
    paper's ε-margin already budgets for: a detection is false when the
    residual backing the protocol's claim exceeds ``factor·ε`` at the
    detection instant (a decade, matching the paper's decade-quantised
    margins) — i.e. no reasonable margin policy around ε would have
    absorbed the error.

    Which residual backs the claim depends on the protocol
    (``BaseProtocol.claim``): PFAIT and NFAIS5 assert the *live* state is
    converged, so they are scored against ``r(x̄)`` at the detection
    instant.  NFAIS2 and the Chandy–Lamport snapshot certify a *recorded
    consistent vector* (whose data they carry/pin) — they are scored
    against the independently recomputed residual of that record.  The live
    ``overshoot`` is still reported for every protocol: under heavy-tailed
    delays an ancient in-flight interface delivery can transiently spike
    the live residual at any stopping instant, for any protocol — that is
    a platform property, not a detection lie (see EXPERIMENTS.md).
    """
    eps = float(eps)
    t_first = next((t for t, r in rec.residual_samples if r <= eps), None)
    n_member = len(rec.membership)
    if rec.detect is None:
        return DetectionReport(
            terminated=False, eps=eps,
            detected_residual=float("inf"), true_at_detect=float("inf"),
            overshoot=float("inf"), false_detection=False, factor=factor,
            t_detect=float("inf"), t_first_below=t_first,
            latency_overhead=None, claim=rec.claim,
            membership_changes=n_member,
        )
    t_detect, claimed = rec.detect
    true_r = float(rec.true_at_detect)
    certified = rec.certified_at_detect
    active_r = rec.active_at_detect
    if rec.claim == "recorded" and certified is not None:
        scored = float(certified)
    elif active_r is not None:
        # dynamic membership: a live claim is made by (and about) the
        # active subsystem — inactive blocks are boundary data, not part
        # of the converging system
        scored = float(active_r)
    else:
        scored = true_r
    return DetectionReport(
        terminated=True, eps=eps,
        detected_residual=claimed, true_at_detect=true_r,
        overshoot=true_r / eps,
        false_detection=(claimed < eps and scored > factor * eps),
        factor=factor,
        t_detect=t_detect, t_first_below=t_first,
        latency_overhead=(t_detect - t_first) if t_first is not None else None,
        claim=rec.claim,
        certified_residual=(float(certified) if certified is not None
                            else None),
        membership_changes=n_member,
        active_residual=(float(active_r) if active_r is not None else None),
    )


def nfais5_slack(p: int, m: int) -> float:
    """The (1 + c(p, m)) slack of NFAIS5's approximate-snapshot guarantee
    ([12], protocol 5): records lag true interfaces by at most m sweeps of
    sub-ε drift per worker, so the detected residual undershoots the true
    snapshot residual by at most ~p/m worker-contributions of size ε.
    Conservative calibration for this implementation's lab scales."""
    return 1.0 + p / max(float(m), 1.0)


# ---------------------------------------------------------------------------
# Traced runs / replay
# ---------------------------------------------------------------------------


def run_traced(
    make_problem: Callable[[], "object"],
    cfg: EngineConfig,
    make_protocol: Callable[["object"], "object"],
    residual_stride: int = 0,
    record_sends: bool = True,
) -> Tuple[RunResult, TraceRecorder]:
    """One fully-recorded engine run.  Factories (not instances) so the
    caller can re-invoke for an exact replay: same cfg.seed ⇒ identical
    trace fingerprint."""
    problem = make_problem()
    rec = TraceRecorder(residual_stride=residual_stride,
                        record_sends=record_sends)
    eng = AsyncEngine(problem, cfg, make_protocol(problem), recorder=rec)
    return eng.run(), rec


def replay_matches(
    make_problem: Callable[[], "object"],
    cfg: EngineConfig,
    make_protocol: Callable[["object"], "object"],
    residual_stride: int = 0,
) -> bool:
    """Run twice from the same seed and compare trace fingerprints — the
    determinism invariant every oracle verdict rests on."""
    _, a = run_traced(make_problem, cfg, make_protocol, residual_stride)
    _, b = run_traced(make_problem, cfg, make_protocol, residual_stride)
    return a.fingerprint() == b.fingerprint()


def platform_health(rec: TraceRecorder, p: int,
                    compute_base: float) -> PlatformHealth:
    """Diagnose the platform from the sweep trace via the runtime's
    fault-tolerance policies (heartbeat timeout = 20 sweep periods)."""
    return health_from_sweeps(rec.sweep_events(), p,
                              timeout=20.0 * compute_base)
