"""The paper's contribution: distributed asynchronous convergence detection.

* ``residual``      — distributed residual evaluation r = σ(r_1, …, r_p)
* ``detection``     — TPU-native ConvergenceMonitor (SYNC/PFAIT/NFAIS modes)
* ``async_engine``  — event-driven asynchronous-iterations simulator
* ``protocols``     — faithful event-level protocols (PFAIT, NFAIS2, NFAIS5,
                      Chandy–Lamport exact snapshot)
* ``termination``   — ε-threshold calibration methodology (paper §4.2)
* ``scenarios``     — composable adversarial platform effects (reliability lab)
* ``reliability``   — replay traces + false/late-detection oracle
* ``reduction``     — registry of on-device reduction modes (topology facts)
* ``trace``         — common structured event-trace schema (JSONL)
"""
from repro.core import residual, termination  # noqa: F401
from repro.core.detection import MonitorConfig, MonitorState, for_mode, init_state  # noqa: F401
from repro.core.reduction import REDUCTIONS, ReductionMode, get_reduction  # noqa: F401
from repro.core.trace import Trace, EngineTraceObserver  # noqa: F401
