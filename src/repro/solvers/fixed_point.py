"""Distributed fixed-point driver — the TPU-native production path.

The paper's runtime, mapped to an SPMD pod:

* the (x, y) process grid of the paper becomes the ``(data, model)`` device
  mesh (one subdomain per chip, full z-pencil local — paper §4.1);
* interface messages become ``lax.ppermute`` halo exchanges;
* asynchronous iterations become *communication-avoiding bounded-delay*
  iterations: ``inner_sweeps`` local sweeps between halo exchanges
  (``inner_sweeps = 1`` ≡ synchronous; ``> 1`` ≡ model (2) with
  ``τ ≥ k − inner_sweeps``);
* the paper's non-blocking residual reduction becomes the K-stale pipelined
  reduction of ``core.detection`` — the loop predicate reads the global
  residual launched K outer iterations earlier, so the scalar all-reduce
  overlaps sweep compute instead of fencing it.

``solve_sharded``/``make_sharded_solver`` build the shard_map program;
``solve_single`` is the 1-device reference used by tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import detection
from repro.core import residual as res
from repro.solvers import gauss_seidel, jacobi
from repro.solvers.convdiff import Stencil


class SolveResult(NamedTuple):
    x: jax.Array                 # solution (global layout as input)
    residual: jax.Array          # residual that fired detection (stale)
    outer_iters: jax.Array       # outer iterations executed
    converged: jax.Array


@dataclass(frozen=True)
class SolverConfig:
    stencil: Stencil
    monitor: detection.MonitorConfig
    inner_sweeps: int = 1        # bounded-delay asynchrony (s)
    max_outer: int = 10_000
    sweep: str = "hybrid"        # "hybrid" (RB-GS interior) | "jacobi"
    use_kernel: bool = False     # dispatch sweeps to the Pallas jacobi3d kernel


# ---------------------------------------------------------------------------
# Halo exchange
# ---------------------------------------------------------------------------


def _shift(x: jax.Array, axis_name: str, up: bool, axis_size: int) -> jax.Array:
    """ppermute a face to the next (+1) or previous (−1) rank along an axis;
    edge ranks receive zeros (homogeneous Dirichlet BC)."""
    if up:
        perm = [(i, i + 1) for i in range(axis_size - 1)]
    else:
        perm = [(i + 1, i) for i in range(axis_size - 1)]
    return jax.lax.ppermute(x, axis_name, perm)


def halo_exchange(x: jax.Array, ax_x: str, ax_y: str, nx: int, ny: int):
    """Exchange the 4 (x,y) faces of a (bx, by, bz) block. Returns ghosts
    (xm, xp, ym, yp), each a face plane from the corresponding neighbour."""
    gxm = _shift(x[-1, :, :], ax_x, up=True, axis_size=nx)   # from rank-1's x+ face
    gxp = _shift(x[0, :, :], ax_x, up=False, axis_size=nx)   # from rank+1's x- face
    gym = _shift(x[:, -1, :], ax_y, up=True, axis_size=ny)
    gyp = _shift(x[:, 0, :], ax_y, up=False, axis_size=ny)
    return gxm, gxp, gym, gyp


def ghosted(x: jax.Array, ghosts) -> jax.Array:
    """Assemble the (bx+2, by+2, bz+2) ghosted block (z ghosts = BC = 0)."""
    gxm, gxp, gym, gyp = ghosts
    bx, by, bz = x.shape
    g = jnp.zeros((bx + 2, by + 2, bz + 2), x.dtype)
    g = g.at[1:-1, 1:-1, 1:-1].set(x)
    g = g.at[0, 1:-1, 1:-1].set(gxm)
    g = g.at[-1, 1:-1, 1:-1].set(gxp)
    g = g.at[1:-1, 0, 1:-1].set(gym)
    g = g.at[1:-1, -1, 1:-1].set(gyp)
    return g


def _zero_ghosts(x: jax.Array):
    bx, by, bz = x.shape
    z = jnp.zeros
    return (
        z((by, bz), x.dtype), z((by, bz), x.dtype),
        z((bx, bz), x.dtype), z((bx, bz), x.dtype),
    )


# ---------------------------------------------------------------------------
# Sweeps
# ---------------------------------------------------------------------------


def _sweep_block(cfg: SolverConfig, g: jax.Array, b: jax.Array, ox, oy) -> jax.Array:
    if cfg.use_kernel:
        from repro.kernels.jacobi3d import ops as jac_ops

        return jac_ops.sweep(cfg.stencil, g, b, sweep=cfg.sweep, ox=ox, oy=oy)
    if cfg.sweep == "jacobi":
        return jacobi.jacobi_sweep(cfg.stencil, g, b)
    return gauss_seidel.redblack_gs_sweep(cfg.stencil, g, b, ox, oy)


def _local_contribution(cfg: SolverConfig, g: jax.Array, b: jax.Array) -> jax.Array:
    if cfg.use_kernel:
        from repro.kernels.jacobi3d import ops as jac_ops

        return jac_ops.residual_contribution(cfg.stencil, g, b, ord=cfg.monitor.ord)
    r = jacobi.residual_block(cfg.stencil, g, b)
    return res.local_contribution(r, cfg.monitor.ord)


# ---------------------------------------------------------------------------
# Distributed solve (shard_map over the production mesh)
# ---------------------------------------------------------------------------


def make_sharded_solver(cfg: SolverConfig, mesh: Mesh, ax_x: str = "data", ax_y: str = "model"):
    """Build a jit-able ``solve(x0, b) -> SolveResult`` over ``mesh``.

    ``x0, b`` are global (n, n, n) arrays sharded P(ax_x, ax_y, None). On a
    multi-pod mesh pass composite axes, e.g. ax_x=("pod", "data")."""
    ax_x_t = ax_x if isinstance(ax_x, tuple) else (ax_x,)
    ax_y_t = ax_y if isinstance(ax_y, tuple) else (ax_y,)
    nx = int(np.prod([mesh.shape[a] for a in ax_x_t]))
    ny = int(np.prod([mesh.shape[a] for a in ax_y_t]))
    axis_names = ax_x_t + ax_y_t
    mon_cfg = cfg.monitor

    def local_solve(x0, b):
        def body_fn(state):
            x, ghosts, mon, k = state
            bx, by, _ = x.shape
            ox = _linear_index(ax_x_t) * bx
            oy = _linear_index(ax_y_t) * by
            for _ in range(cfg.inner_sweeps):
                x = _sweep_block(cfg, ghosted(x, ghosts), b, ox, oy)
            ghosts = halo_exchange(x, ax_x_t, ax_y_t, nx, ny)
            contrib = _local_contribution(cfg, ghosted(x, ghosts), b)
            exact_fn = lambda: res.psum_sigma(contrib, axis_names, mon_cfg.ord)
            mon = detection.step(mon_cfg, mon, contrib, axis_names=axis_names,
                                 exact_residual_fn=exact_fn)
            return x, ghosts, mon, k + 1

        def cond_fn(state):
            _, _, mon, k = state
            return (~mon.converged) & (k < cfg.max_outer)

        ghosts = halo_exchange(x0, ax_x_t, ax_y_t, nx, ny)
        mon = detection.init_state(mon_cfg)
        x, _, mon, k = jax.lax.while_loop(
            cond_fn, body_fn, (x0, ghosts, mon, jnp.zeros((), jnp.int32))
        )
        return SolveResult(
            x=x, residual=mon.detected_residual, outer_iters=k, converged=mon.converged
        )

    spec = P(ax_x, ax_y, None)
    sharded = jax.shard_map(
        local_solve,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=SolveResult(x=spec, residual=P(), outer_iters=P(), converged=P()),
        check_vma=False,
    )
    return sharded


def _linear_index(axis_names: Tuple[str, ...]):
    """Linear rank along possibly-composite mesh axes."""
    idx = jnp.zeros((), jnp.int32)
    for a in axis_names:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


# ---------------------------------------------------------------------------
# Single-device reference (tests / examples)
# ---------------------------------------------------------------------------


def solve_single(cfg: SolverConfig, b: jax.Array, x0: Optional[jax.Array] = None) -> SolveResult:
    """p = 1 solve (no mesh): ghosts are the physical boundary (zeros)."""
    if x0 is None:
        x0 = jnp.zeros_like(b)
    mon_cfg = cfg.monitor

    def body_fn(state):
        x, mon, k = state
        for _ in range(cfg.inner_sweeps):
            x = _sweep_block(cfg, ghosted(x, _zero_ghosts(x)), b, 0, 0)
        g = ghosted(x, _zero_ghosts(x))
        contrib = _local_contribution(cfg, g, b)
        exact_fn = lambda: res.sigma(contrib, mon_cfg.ord)
        mon = detection.step(mon_cfg, mon, contrib, axis_names=None,
                             exact_residual_fn=exact_fn)
        return x, mon, k + 1

    def cond_fn(state):
        _, mon, k = state
        return (~mon.converged) & (k < cfg.max_outer)

    mon = detection.init_state(mon_cfg)
    x, mon, k = jax.lax.while_loop(cond_fn, body_fn, (x0, mon, jnp.zeros((), jnp.int32)))
    return SolveResult(x=x, residual=mon.detected_residual, outer_iters=k, converged=mon.converged)
