"""Distributed fixed-point driver — the TPU-native production path.

The paper's runtime, mapped to an SPMD pod:

* the (x, y) process grid of the paper becomes the ``(data, model)`` device
  mesh (one subdomain per chip, full z-pencil local — paper §4.1);
* interface messages become ``lax.ppermute`` halo exchanges;
* asynchronous iterations become *communication-avoiding bounded-delay*
  iterations: ``inner_sweeps`` local sweeps between halo exchanges
  (``inner_sweeps = 1`` ≡ synchronous; ``> 1`` ≡ model (2) with
  ``τ ≥ k − inner_sweeps``);
* the paper's non-blocking residual reduction becomes the K-stale pipelined
  reduction of ``core.detection`` — the loop predicate reads the global
  residual launched K outer iterations earlier, so the scalar all-reduce
  overlaps sweep compute instead of fencing it;
* the residual itself is a *by-product of the sweep* (``fuse_residual``,
  default on): the last inner sweep of each outer iteration returns its
  local contribution fused, so one outer iteration performs exactly one
  ghost assembly + one grid pass — no residual-only second pass.  The
  contribution therefore measures the state *before* that sweep with
  *pre-exchange* ghosts (one sweep + one exchange staler than the seed's
  post-exchange evaluation) — precisely the kind of staleness the paper's
  protocol-free detection absorbs; NFAIS2's exact verification still
  recomputes a fresh post-exchange residual under its ``lax.cond``.
  ``fuse_residual=False`` restores the unfused two-pass baseline (used by
  benchmarks/bench_fused.py for the head-to-head).

``solve_sharded``/``make_sharded_solver`` build the shard_map program;
``solve_single`` is the 1-device reference used by tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import detection
from repro.core import residual as res
from repro.core.compat import axis_size_compat, shard_map_compat as _shard_map
from repro.solvers import gauss_seidel, jacobi
from repro.solvers.convdiff import Stencil


class SolveResult(NamedTuple):
    x: jax.Array                 # solution (global layout as input)
    residual: jax.Array          # residual that fired detection (stale)
    outer_iters: jax.Array       # outer iterations executed
    converged: jax.Array


@dataclass(frozen=True)
class SolverConfig:
    stencil: Stencil
    monitor: detection.MonitorConfig
    inner_sweeps: int = 1        # bounded-delay asynchrony (s)
    max_outer: int = 10_000
    sweep: str = "hybrid"        # "hybrid" (RB-GS interior) | "jacobi"
    use_kernel: bool = False     # dispatch sweeps to the Pallas jacobi3d kernel
    fuse_residual: bool = True   # residual as sweep by-product (no 2nd pass)


# ---------------------------------------------------------------------------
# Halo exchange
# ---------------------------------------------------------------------------


def _shift(x: jax.Array, axis_name: str, up: bool, axis_size: int) -> jax.Array:
    """ppermute a face to the next (+1) or previous (−1) rank along an axis;
    edge ranks receive zeros (homogeneous Dirichlet BC)."""
    if up:
        perm = [(i, i + 1) for i in range(axis_size - 1)]
    else:
        perm = [(i + 1, i) for i in range(axis_size - 1)]
    return jax.lax.ppermute(x, axis_name, perm)


def halo_exchange(x: jax.Array, ax_x: str, ax_y: str, nx: int, ny: int):
    """Exchange the 4 (x,y) faces of a (bx, by, bz) block. Returns ghosts
    (xm, xp, ym, yp), each a face plane from the corresponding neighbour."""
    gxm = _shift(x[-1, :, :], ax_x, up=True, axis_size=nx)   # from rank-1's x+ face
    gxp = _shift(x[0, :, :], ax_x, up=False, axis_size=nx)   # from rank+1's x- face
    gym = _shift(x[:, -1, :], ax_y, up=True, axis_size=ny)
    gyp = _shift(x[:, 0, :], ax_y, up=False, axis_size=ny)
    return gxm, gxp, gym, gyp


def ghosted(x: jax.Array, ghosts) -> jax.Array:
    """Assemble the (bx+2, by+2, bz+2) ghosted block (z ghosts = BC = 0)."""
    gxm, gxp, gym, gyp = ghosts
    bx, by, bz = x.shape
    g = jnp.zeros((bx + 2, by + 2, bz + 2), x.dtype)
    g = g.at[1:-1, 1:-1, 1:-1].set(x)
    g = g.at[0, 1:-1, 1:-1].set(gxm)
    g = g.at[-1, 1:-1, 1:-1].set(gxp)
    g = g.at[1:-1, 0, 1:-1].set(gym)
    g = g.at[1:-1, -1, 1:-1].set(gyp)
    return g


def ghosted6(x: jax.Array, ghosts) -> jax.Array:
    """Assemble the (bx+2, by+2, bz+2) ghosted block from six face planes
    ``(gxm, gxp, gym, gyp, gzm, gzp)`` — the multi-axis mesh runtime's
    assembly, where any of x/y/z may be partitioned.  Unpartitioned or
    boundary faces pass the zero Dirichlet plane; corners/edges stay zero
    (the 7-point stencil never reads them)."""
    gxm, gxp, gym, gyp, gzm, gzp = ghosts
    bx, by, bz = x.shape
    g = jnp.zeros((bx + 2, by + 2, bz + 2), x.dtype)
    g = g.at[1:-1, 1:-1, 1:-1].set(x)
    g = g.at[0, 1:-1, 1:-1].set(gxm)
    g = g.at[-1, 1:-1, 1:-1].set(gxp)
    g = g.at[1:-1, 0, 1:-1].set(gym)
    g = g.at[1:-1, -1, 1:-1].set(gyp)
    g = g.at[1:-1, 1:-1, 0].set(gzm)
    g = g.at[1:-1, 1:-1, -1].set(gzp)
    return g


def _zero_ghosts(x: jax.Array):
    bx, by, bz = x.shape
    z = jnp.zeros
    return (
        z((by, bz), x.dtype), z((by, bz), x.dtype),
        z((bx, bz), x.dtype), z((bx, bz), x.dtype),
    )


# ---------------------------------------------------------------------------
# Sweeps
# ---------------------------------------------------------------------------


def _sweep_block(cfg: SolverConfig, x: jax.Array, ghosts, b: jax.Array, ox, oy) -> jax.Array:
    """One sweep, contribution discarded (inner sweeps that don't feed
    detection — the fused partials are dead code XLA eliminates)."""
    if cfg.use_kernel:
        from repro.kernels.jacobi3d import ops as jac_ops

        return jac_ops.sweep(cfg.stencil, x, ghosts, b, sweep=cfg.sweep,
                             ox=ox, oy=oy)
    g = ghosted(x, ghosts)
    if cfg.sweep == "jacobi":
        return jacobi.jacobi_sweep(cfg.stencil, g, b)
    return gauss_seidel.redblack_gs_sweep(cfg.stencil, g, b, ox, oy)


def _sweep_with_contribution(cfg: SolverConfig, x: jax.Array, ghosts,
                             b: jax.Array, ox, oy):
    """The fused hot path: ``(new_x, contrib)`` from one ghost assembly and
    one grid pass.  ``contrib`` is the pre-σ residual contribution of the
    *input* state (see module docstring for the staleness semantics)."""
    if cfg.use_kernel:
        from repro.kernels.jacobi3d import ops as jac_ops

        return jac_ops.sweep_with_contribution(
            cfg.stencil, x, ghosts, b, sweep=cfg.sweep, ox=ox, oy=oy,
            ord=cfg.monitor.ord)
    g = ghosted(x, ghosts)
    if cfg.sweep == "jacobi":
        new, r = jacobi.jacobi_sweep_residual(cfg.stencil, g, b)
    else:
        new, r = gauss_seidel.redblack_gs_sweep_residual(cfg.stencil, g, b, ox, oy)
    return new, res.local_contribution(r, cfg.monitor.ord)


def _local_contribution(cfg: SolverConfig, g: jax.Array, b: jax.Array) -> jax.Array:
    """Residual-only pass (unfused baseline + NFAIS2 exact verification)."""
    if cfg.use_kernel:
        from repro.kernels.jacobi3d import ops as jac_ops

        return jac_ops.residual_contribution(cfg.stencil, g, b, ord=cfg.monitor.ord)
    r = jacobi.residual_block(cfg.stencil, g, b)
    return res.local_contribution(r, cfg.monitor.ord)


def _outer_iteration(cfg: SolverConfig, x, ghosts, b, ox, oy):
    """Shared outer-iteration kernel for both drivers: ``inner_sweeps``
    sweeps, the last one fused with the detection contribution, then a
    residual-only pass only when ``fuse_residual`` is off."""
    if cfg.fuse_residual:
        for s in range(cfg.inner_sweeps - 1):
            x = _sweep_block(cfg, x, ghosts, b, ox, oy)
        x, contrib = _sweep_with_contribution(cfg, x, ghosts, b, ox, oy)
        return x, contrib
    for _ in range(cfg.inner_sweeps):
        x = _sweep_block(cfg, x, ghosts, b, ox, oy)
    return x, None




# ---------------------------------------------------------------------------
# Distributed solve (shard_map over the production mesh)
# ---------------------------------------------------------------------------


def make_sharded_solver(cfg: SolverConfig, mesh: Mesh, ax_x: str = "data", ax_y: str = "model"):
    """Build a jit-able ``solve(x0, b) -> SolveResult`` over ``mesh``.

    ``x0, b`` are global (n, n, n) arrays sharded P(ax_x, ax_y, None). On a
    multi-pod mesh pass composite axes, e.g. ax_x=("pod", "data")."""
    ax_x_t = ax_x if isinstance(ax_x, tuple) else (ax_x,)
    ax_y_t = ax_y if isinstance(ax_y, tuple) else (ax_y,)
    nx = int(np.prod([mesh.shape[a] for a in ax_x_t]))
    ny = int(np.prod([mesh.shape[a] for a in ax_y_t]))
    axis_names = ax_x_t + ax_y_t
    mon_cfg = cfg.monitor

    def local_solve(x0, b):
        def body_fn(state):
            x, ghosts, mon, k = state
            bx, by, _ = x.shape
            ox = _linear_index(ax_x_t) * bx
            oy = _linear_index(ax_y_t) * by
            x, contrib = _outer_iteration(cfg, x, ghosts, b, ox, oy)
            ghosts = halo_exchange(x, ax_x_t, ax_y_t, nx, ny)
            if contrib is None:  # unfused baseline: post-exchange second pass
                contrib = _local_contribution(cfg, ghosted(x, ghosts), b)
                def exact_fn(c=contrib):
                    return res.psum_sigma(c, axis_names, mon_cfg.ord)
            else:
                # fused contrib is one sweep stale; NFAIS2's exact
                # verification must measure the fresh post-exchange state
                # (paid lazily under its lax.cond).
                def exact_fn(x=x, ghosts=ghosts):
                    return res.psum_sigma(
                        _local_contribution(cfg, ghosted(x, ghosts), b),
                        axis_names, mon_cfg.ord)
            mon = detection.step(mon_cfg, mon, contrib, axis_names=axis_names,
                                 exact_residual_fn=exact_fn)
            return x, ghosts, mon, k + 1

        def cond_fn(state):
            _, _, mon, k = state
            return (~mon.converged) & (k < cfg.max_outer)

        ghosts = halo_exchange(x0, ax_x_t, ax_y_t, nx, ny)
        mon = detection.init_state(mon_cfg)
        x, _, mon, k = jax.lax.while_loop(
            cond_fn, body_fn, (x0, ghosts, mon, jnp.zeros((), jnp.int32))
        )
        return SolveResult(
            x=x, residual=mon.detected_residual, outer_iters=k, converged=mon.converged
        )

    spec = P(ax_x, ax_y, None)
    return _shard_map(
        local_solve,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=SolveResult(x=spec, residual=P(), outer_iters=P(), converged=P()),
    )


def _linear_index(axis_names: Tuple[str, ...]):
    """Linear rank along possibly-composite mesh axes."""
    idx = jnp.zeros((), jnp.int32)
    for a in axis_names:
        idx = idx * axis_size_compat(a) + jax.lax.axis_index(a)
    return idx


# ---------------------------------------------------------------------------
# Single-device reference (tests / examples)
# ---------------------------------------------------------------------------


def solve_single(cfg: SolverConfig, b: jax.Array, x0: Optional[jax.Array] = None) -> SolveResult:
    """p = 1 solve (no mesh): ghosts are the physical boundary (zeros)."""
    if x0 is None:
        x0 = jnp.zeros_like(b)
    mon_cfg = cfg.monitor

    def body_fn(state):
        x, mon, k = state
        x, contrib = _outer_iteration(cfg, x, _zero_ghosts(x), b, 0, 0)
        if contrib is None:  # unfused baseline: residual-only second pass
            contrib = _local_contribution(cfg, ghosted(x, _zero_ghosts(x)), b)
            def exact_fn(c=contrib):
                return res.sigma(c, mon_cfg.ord)
        else:
            def exact_fn(x=x):
                return res.sigma(
                    _local_contribution(cfg, ghosted(x, _zero_ghosts(x)), b),
                    mon_cfg.ord)
        mon = detection.step(mon_cfg, mon, contrib, axis_names=None,
                             exact_residual_fn=exact_fn)
        return x, mon, k + 1

    def cond_fn(state):
        _, mon, k = state
        return (~mon.converged) & (k < cfg.max_outer)

    mon = detection.init_state(mon_cfg)
    x, mon, k = jax.lax.while_loop(cond_fn, body_fn, (x0, mon, jnp.zeros((), jnp.int32)))
    return SolveResult(x=x, residual=mon.detected_residual, outer_iters=k, converged=mon.converged)
