"""Fixed-point solver substrate (the paper's experimental setting)."""
from repro.solvers.convdiff import ConvDiffProblem, Stencil, make_rhs  # noqa: F401
from repro.solvers.pagerank import PageRankProblem  # noqa: F401
from repro.solvers.mlfixed import MLFixedPointProblem  # noqa: F401
from repro.solvers.fixed_point import (  # noqa: F401
    SolveResult,
    SolverConfig,
    make_sharded_solver,
    solve_single,
)
