"""Red-black Gauss–Seidel sweep — pure-jnp, globally-aligned checkerboard.

Ghost planes stay frozen during the sweep, so interface nodes relax
Jacobi-style against the last received neighbour data while interior nodes
see same-sweep updates — the paper's hybrid relaxation (§4.1)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.solvers.convdiff import Stencil
from repro.solvers.jacobi import offdiag_apply


def parity_mask(shape, ox, oy, oz=0):
    bx, by, bz = shape
    ix = jnp.arange(bx)[:, None, None] + ox
    iy = jnp.arange(by)[None, :, None] + oy
    iz = jnp.arange(bz)[None, None, :] + oz
    return (ix + iy + iz) % 2


def redblack_gs_sweep(st: Stencil, g: jnp.ndarray, b: jnp.ndarray, ox, oy, oz=0) -> jnp.ndarray:
    """One red-black GS sweep on a ghosted block; returns the new interior.

    ``ox, oy, oz`` are global offsets (static ints or traced scalars)
    aligning the checkerboard across subdomains (``oz`` matters only on
    z-partitioned meshes; the historical 2-D callers leave it 0).  (The unused residual below is dead
    code XLA eliminates — sweep-only callers pay nothing for the fusion.)"""
    new, _ = redblack_gs_sweep_residual(st, g, b, ox, oy, oz)
    return new


def redblack_gs_sweep_residual(st: Stencil, g: jnp.ndarray, b: jnp.ndarray, ox, oy, oz=0):
    """Fused hybrid sweep + pre-sweep residual.

    The first color's off-diagonal apply doubles as the residual term, so
    the detection layer's residual is a by-product of the relaxation instead
    of a second pass: returns ``(new_interior, r)`` with ``r = b − A x_in``
    (residual of the *input* state — one sweep staler than a post-sweep
    evaluation, which the asynchronous detection layer tolerates by design).
    """
    parity = parity_mask(b.shape, ox, oy, oz)
    inner = g[1:-1, 1:-1, 1:-1]
    off0 = offdiag_apply(st, g)
    r = b - (st.diag * inner + off0)
    # color 0 (even parity): Jacobi update against the frozen view
    upd0 = jnp.where(parity == 0, (b - off0) / st.diag, inner)
    # Rebuild the ghosted block instead of updating g in place: an in-place
    # dynamic-update-slice would force XLA to copy g (it is still live for
    # the residual), and only the 6 ghost faces are ever read again —
    # corners/edges are dead.
    g2 = jnp.zeros_like(g)
    g2 = g2.at[1:-1, 1:-1, 1:-1].set(upd0)
    g2 = g2.at[0, 1:-1, 1:-1].set(g[0, 1:-1, 1:-1])
    g2 = g2.at[-1, 1:-1, 1:-1].set(g[-1, 1:-1, 1:-1])
    g2 = g2.at[1:-1, 0, 1:-1].set(g[1:-1, 0, 1:-1])
    g2 = g2.at[1:-1, -1, 1:-1].set(g[1:-1, -1, 1:-1])
    g2 = g2.at[1:-1, 1:-1, 0].set(g[1:-1, 1:-1, 0])
    g2 = g2.at[1:-1, 1:-1, -1].set(g[1:-1, 1:-1, -1])
    # color 1 (odd): sees same-sweep color-0 values + frozen ghosts
    new1 = (b - offdiag_apply(st, g2)) / st.diag
    return jnp.where(parity == 1, new1, upd0), r
