"""Red-black Gauss–Seidel sweep — pure-jnp, globally-aligned checkerboard.

Ghost planes stay frozen during the sweep, so interface nodes relax
Jacobi-style against the last received neighbour data while interior nodes
see same-sweep updates — the paper's hybrid relaxation (§4.1)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.solvers.convdiff import Stencil
from repro.solvers.jacobi import offdiag_apply


def parity_mask(shape, ox, oy, oz=0):
    bx, by, bz = shape
    ix = jnp.arange(bx)[:, None, None] + ox
    iy = jnp.arange(by)[None, :, None] + oy
    iz = jnp.arange(bz)[None, None, :] + oz
    return (ix + iy + iz) % 2


def redblack_gs_sweep(st: Stencil, g: jnp.ndarray, b: jnp.ndarray, ox, oy) -> jnp.ndarray:
    """One red-black GS sweep on a ghosted block; returns the new interior.

    ``ox, oy`` are global offsets (static ints or traced scalars) aligning
    the checkerboard across subdomains."""
    parity = parity_mask(b.shape, ox, oy)
    for color in (0, 1):
        new = (b - offdiag_apply(st, g)) / st.diag
        inner = g[1:-1, 1:-1, 1:-1]
        g = g.at[1:-1, 1:-1, 1:-1].set(jnp.where(parity == color, new, inner))
    return g[1:-1, 1:-1, 1:-1]
