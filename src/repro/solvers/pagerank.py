"""Sparse PageRank / D-iteration fixed point as a second problem family.

The conv-diff substrate (solvers/convdiff.py) has a *symmetric* 4-neighbour
dependency structure — every worker talks to every neighbour in both
directions with equal-size interfaces.  Detection reliability is easier
there than the general asynchronous-iterations setting (Hong's D-iteration
work, arXiv:1202.3108): web-graph fixed points have hub-skewed, *directed*
dependencies, so some workers feed many others while consuming almost
nothing, and interface sizes differ per direction.

This module implements

    x = d · P x + (1 − d)/n · 1,        0 < d < 1,  P column-stochastic,

decomposed over ``p`` contiguous node blocks, as a
``core.async_engine.DecomposedProblem``.  The random graph is hub-biased
(Zipf-weighted targets), so the block dependency graph is genuinely
asymmetric: ``interface(i, x_i, j)`` returns exactly the components of
block i that block j's rows reference — possibly the empty array when j
never reads from i (the engine still exchanges messages both ways, as a
real sparse solver's symmetrised communicator would).

The iteration contracts in l1 with factor d per sweep (column-stochastic
P), so the natural residual order is ``ord=1``; contributions follow the
repo convention (core/residual.py): Σ|r|^l pre-reduction for finite l,
max|r| for l=∞.  The fused ``update_with_residual`` extension is free
here: the D-iteration residual *is* the update difference f(x) − x.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


class PageRankProblem:
    """Damped PageRank over a random hub-biased directed graph."""

    def __init__(
        self,
        n: int = 256,
        p: int = 4,
        damping: float = 0.85,
        avg_deg: float = 6.0,
        hub_skew: float = 0.8,
        ord: float = 1.0,
        seed: int = 0,
    ):
        if n % p:
            raise ValueError(f"n={n} not divisible by p={p}")
        if not 0.0 < damping < 1.0:
            raise ValueError(f"damping={damping} must be in (0, 1)")
        self.n = n
        self.p = p
        self.d = float(damping)
        self.ord = float(ord)
        self.block = n // p
        rng = np.random.default_rng(seed)

        # hub-biased directed graph: targets drawn Zipf-weighted toward
        # low-indexed nodes, so block 0 is everyone's dependency while the
        # tail blocks are mostly read-only consumers (asymmetry).
        w = 1.0 / (np.arange(n) + 1.0) ** hub_skew
        w /= w.sum()
        cols: List[np.ndarray] = []       # per source node: its out-targets
        for j in range(n):
            deg = 1 + int(rng.poisson(max(avg_deg - 1.0, 0.0)))
            deg = min(deg, n - 1)
            targets = rng.choice(n, size=deg, replace=False, p=w)
            targets = targets[targets != j]
            if targets.size == 0:  # no dangling columns: keep P stochastic
                targets = np.array([(j + 1) % n])
            cols.append(np.unique(targets))

        # block-compressed column storage: for each (dst block i, src block
        # j) the needed source components and the dense compressed operator
        # W[i][j] : (block, |support(i←j)|), plus the diagonal block A_ii.
        blk = self.block
        def owner(node):
            return node // blk
        entries: Dict[tuple, List[tuple]] = {}
        for j, targets in enumerate(cols):
            val = 1.0 / targets.size
            for r in targets:
                entries.setdefault((owner(r), owner(j)), []).append(
                    (r % blk, j % blk, val))
        self._W: List[Dict[int, np.ndarray]] = [dict() for _ in range(p)]
        self._supp: List[Dict[int, np.ndarray]] = [dict() for _ in range(p)]
        self._A: List[np.ndarray] = [np.zeros((blk, blk)) for _ in range(p)]
        for (bi, bj), es in entries.items():
            if bi == bj:
                for r, c, v in es:
                    self._A[bi][r, c] += v
                continue
            support = np.unique(np.array([c for _, c, _ in es]))
            pos = {c: k for k, c in enumerate(support)}
            W = np.zeros((blk, support.size))
            for r, c, v in es:
                W[r, pos[c]] += v
            # support(i←j): which of j's components i reads
            self._supp[bj].setdefault(bi, support)
            self._W[bi][bj] = W
        self._neighbors: List[List[int]] = []
        for i in range(p):
            nb = set(self._W[i]) | set(self._supp[i])
            nb.discard(i)
            self._neighbors.append(sorted(nb))
        self.v = (1.0 - self.d) / n  # uniform teleport component
        # packed per-worker operator for the hot `_apply` path: one
        # (blk, blk + Σ|support|) matrix [A_i | W_ij …] against the
        # concatenated [x_i; deps…] replaces the per-neighbour matvec loop
        # (the engine delivers every dependency at init, so the packed view
        # is almost always complete; partial snapshot views fall back)
        self._packed_js: List[List[int]] = [sorted(self._W[i])
                                            for i in range(p)]
        self._packed_M: List[np.ndarray] = [
            np.concatenate([self._A[i]] + [self._W[i][j]
                                           for j in self._packed_js[i]],
                           axis=1)
            for i in range(p)
        ]
        # preallocated packed input [x_i; deps…] + per-neighbour slot
        # slices: two small copies per neighbour beat a fresh concatenate
        # in the sweep hot loop
        self._packed_buf: List[np.ndarray] = []
        self._packed_slots: List[List[tuple]] = []
        for i in range(p):
            slots, pos = [], blk
            for j in self._packed_js[i]:
                w = self._W[i][j].shape[1]
                slots.append((j, slice(pos, pos + w)))
                pos += w
            self._packed_buf.append(np.empty(pos))
            self._packed_slots.append(slots)
        self._P_dense: Optional[np.ndarray] = None  # lazy (exact_residual)

    # -- DecomposedProblem interface ----------------------------------------
    def neighbors(self, i: int) -> List[int]:
        return self._neighbors[i]

    def init_local(self, i: int) -> np.ndarray:
        return np.full(self.block, 1.0 / self.n)

    def _apply(self, i: int, x_i: np.ndarray,
               deps: Dict[int, np.ndarray]) -> np.ndarray:
        """f_i(x): d · (row-block of P x) + teleport."""
        buf = self._packed_buf[i]
        buf[: self.block] = x_i
        for j, slot in self._packed_slots[i]:
            dep = deps.get(j)
            if dep is None:
                break
            buf[slot] = dep
        else:
            return self.d * (self._packed_M[i] @ buf) + self.v
        # partial view (snapshot records mid-round): per-neighbour fallback
        y = self._A[i] @ x_i
        for j, W in self._W[i].items():
            dep = deps.get(j)
            if dep is not None and dep.size:
                y += W @ dep
        return self.d * y + self.v

    def update(self, i: int, x_i: np.ndarray,
               deps: Dict[int, np.ndarray]) -> np.ndarray:
        return self._apply(i, x_i, deps)

    def update_with_residual(self, i: int, x_i: np.ndarray,
                             deps: Dict[int, np.ndarray],
                             need_residual: bool = True):
        """Fused sweep + residual: the D-iteration residual is exactly the
        update difference, so fusion costs nothing extra."""
        x_new = self._apply(i, x_i, deps)
        if not need_residual:
            return x_new, None
        return x_new, self._contribution(x_new - x_i)

    def interface(self, i: int, x_i: np.ndarray, j: int) -> np.ndarray:
        supp = self._supp[i].get(j)
        if supp is None:
            return np.empty(0)  # j never reads from i (asymmetric edge)
        return x_i.take(supp)   # fresh array — the reference escapes

    def _contribution(self, r: np.ndarray) -> float:
        if np.isinf(self.ord):
            return float(np.max(np.abs(r))) if r.size else 0.0
        if self.ord == 1.0:     # |r|¹ — skip the generic power (hot path)
            return float(np.abs(r).sum())
        if self.ord == 2.0:
            return float(r @ r)
        return float(np.sum(np.abs(r) ** self.ord))

    def local_residual(self, i: int, x_i: np.ndarray,
                       deps: Dict[int, np.ndarray]) -> float:
        return self._contribution(self._apply(i, x_i, deps) - x_i)

    def to_dense(self) -> np.ndarray:
        """Dense column-stochastic P assembled from the block storage
        (cached; used by ``exact_residual`` and the batched device path)."""
        if self._P_dense is None:
            P = np.zeros((self.n, self.n))
            blk = self.block
            for i in range(self.p):
                rows = slice(i * blk, (i + 1) * blk)
                P[rows, rows] = self._A[i]
                for j, W in self._W[i].items():
                    P[rows, j * blk + self._supp[j][i]] = W
            self._P_dense = P
        return self._P_dense

    def exact_residual(self, xs: Sequence[np.ndarray]) -> float:
        """r(x̄) via one dense matvec — mathematically identical to the
        per-block contribution sum (Σ_blocks Σ|r_block|^l)^{1/l}, an order
        of magnitude cheaper per trajectory sample."""
        x = self.assemble(xs)
        r = self.d * (self.to_dense() @ x) + self.v - x
        if np.isinf(self.ord):
            return float(np.max(np.abs(r)))
        if self.ord == 1.0:
            return float(np.abs(r).sum())
        return float(np.sum(np.abs(r) ** self.ord) ** (1.0 / self.ord))

    # -- batched device path -------------------------------------------------
    def update_with_residual_batched(self, X, P=None):
        """Synchronous global D-iteration step + pre-step residual
        contribution for a batch of lanes, as one jittable device program.

        ``X`` — [B, n] lane states; ``P`` — optional dense operator, [n, n]
        (defaults to this instance's) or [B, n, n] for seed-batched graphs.
        Returns ``(X_next, contrib[B])``; the contribution is the update
        difference under the repo convention (Σ|r|^l for finite l, max|r|
        for l=∞) — the same fused by-product ``update_with_residual``
        yields per worker.
        """
        import jax.numpy as jnp

        P = jnp.asarray(self.to_dense() if P is None else P)
        if P.ndim == 2:
            Y = self.d * (X @ P.T) + self.v
        else:
            Y = self.d * jnp.einsum("bij,bj->bi", P, X) + self.v
        R = Y - X
        if np.isinf(self.ord):
            contrib = jnp.max(jnp.abs(R), axis=1)
        else:
            contrib = jnp.sum(jnp.abs(R) ** self.ord, axis=1)
        return Y, contrib

    def lane_x0(self) -> np.ndarray:
        """Canonical initial state of one detection-service lane (f32)."""
        return np.full((self.n,), 1.0 / self.n, np.float32)

    def lane_operands(self) -> dict:
        """This instance's per-lane operands for the batched step.

        Only the graph operator is seeded; the teleport term ``v`` and the
        damping are shape-bucket constants shared from any instance (see
        ``update_with_residual_batched``).  Used by ``launch/serve.py`` and
        the ``detection_grid`` campaign cells.
        """
        return {"P": np.asarray(self.to_dense(), np.float32)}

    # -- helpers -------------------------------------------------------------
    def assemble(self, xs: Sequence[np.ndarray]) -> np.ndarray:
        return np.concatenate(list(xs))

    def solve_reference(self, tol: float = 1e-14,
                        max_iter: int = 10_000) -> np.ndarray:
        """Synchronous power iteration to high precision (test oracle)."""
        xs = [self.init_local(i) for i in range(self.p)]
        for _ in range(max_iter):
            deps = [
                {j: self.interface(j, xs[j], i) for j in self.neighbors(i)}
                for i in range(self.p)
            ]
            new = [self._apply(i, xs[i], deps[i]) for i in range(self.p)]
            delta = max(float(np.max(np.abs(a - b))) for a, b in zip(new, xs))
            xs = new
            if delta < tol:
                break
        return self.assemble(xs)
