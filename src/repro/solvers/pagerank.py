"""Sparse PageRank / D-iteration fixed point as a second problem family.

The conv-diff substrate (solvers/convdiff.py) has a *symmetric* 4-neighbour
dependency structure — every worker talks to every neighbour in both
directions with equal-size interfaces.  Detection reliability is easier
there than the general asynchronous-iterations setting (Hong's D-iteration
work, arXiv:1202.3108): web-graph fixed points have hub-skewed, *directed*
dependencies, so some workers feed many others while consuming almost
nothing, and interface sizes differ per direction.

This module implements

    x = d · P x + (1 − d)/n · 1,        0 < d < 1,  P column-stochastic,

decomposed over ``p`` contiguous node blocks, as a
``core.async_engine.DecomposedProblem``.  The random graph is hub-biased
(Zipf-weighted targets), so the block dependency graph is genuinely
asymmetric: ``interface(i, x_i, j)`` returns exactly the components of
block i that block j's rows reference — possibly the empty array when j
never reads from i (the engine still exchanges messages both ways, as a
real sparse solver's symmetrised communicator would).

The iteration contracts in l1 with factor d per sweep (column-stochastic
P), so the natural residual order is ``ord=1``; contributions follow the
repo convention (core/residual.py): Σ|r|^l pre-reduction for finite l,
max|r| for l=∞.  The fused ``update_with_residual`` extension is free
here: the D-iteration residual *is* the update difference f(x) − x.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


class PageRankProblem:
    """Damped PageRank over a random hub-biased directed graph."""

    def __init__(
        self,
        n: int = 256,
        p: int = 4,
        damping: float = 0.85,
        avg_deg: float = 6.0,
        hub_skew: float = 0.8,
        ord: float = 1.0,
        seed: int = 0,
    ):
        if n % p:
            raise ValueError(f"n={n} not divisible by p={p}")
        if not 0.0 < damping < 1.0:
            raise ValueError(f"damping={damping} must be in (0, 1)")
        self.n = n
        self.p = p
        self.d = float(damping)
        self.ord = float(ord)
        self.block = n // p
        rng = np.random.default_rng(seed)

        # hub-biased directed graph: targets drawn Zipf-weighted toward
        # low-indexed nodes, so block 0 is everyone's dependency while the
        # tail blocks are mostly read-only consumers (asymmetry).
        w = 1.0 / (np.arange(n) + 1.0) ** hub_skew
        w /= w.sum()
        cols: List[np.ndarray] = []       # per source node: its out-targets
        for j in range(n):
            deg = 1 + int(rng.poisson(max(avg_deg - 1.0, 0.0)))
            deg = min(deg, n - 1)
            targets = rng.choice(n, size=deg, replace=False, p=w)
            targets = targets[targets != j]
            if targets.size == 0:  # no dangling columns: keep P stochastic
                targets = np.array([(j + 1) % n])
            cols.append(np.unique(targets))

        # block-compressed column storage: for each (dst block i, src block
        # j) the needed source components and the dense compressed operator
        # W[i][j] : (block, |support(i←j)|), plus the diagonal block A_ii.
        blk = self.block
        owner = lambda node: node // blk
        entries: Dict[tuple, List[tuple]] = {}
        for j, targets in enumerate(cols):
            val = 1.0 / targets.size
            for r in targets:
                entries.setdefault((owner(r), owner(j)), []).append(
                    (r % blk, j % blk, val))
        self._W: List[Dict[int, np.ndarray]] = [dict() for _ in range(p)]
        self._supp: List[Dict[int, np.ndarray]] = [dict() for _ in range(p)]
        self._A: List[np.ndarray] = [np.zeros((blk, blk)) for _ in range(p)]
        for (bi, bj), es in entries.items():
            if bi == bj:
                for r, c, v in es:
                    self._A[bi][r, c] += v
                continue
            support = np.unique(np.array([c for _, c, _ in es]))
            pos = {c: k for k, c in enumerate(support)}
            W = np.zeros((blk, support.size))
            for r, c, v in es:
                W[r, pos[c]] += v
            # support(i←j): which of j's components i reads
            self._supp[bj].setdefault(bi, support)
            self._W[bi][bj] = W
        self._neighbors: List[List[int]] = []
        for i in range(p):
            nb = set(self._W[i]) | set(self._supp[i])
            nb.discard(i)
            self._neighbors.append(sorted(nb))
        self.v = (1.0 - self.d) / n  # uniform teleport component

    # -- DecomposedProblem interface ----------------------------------------
    def neighbors(self, i: int) -> List[int]:
        return self._neighbors[i]

    def init_local(self, i: int) -> np.ndarray:
        return np.full(self.block, 1.0 / self.n)

    def _apply(self, i: int, x_i: np.ndarray,
               deps: Dict[int, np.ndarray]) -> np.ndarray:
        """f_i(x): d · (row-block of P x) + teleport."""
        y = self._A[i] @ x_i
        for j, W in self._W[i].items():
            dep = deps.get(j)
            if dep is not None and dep.size:
                y += W @ dep
        return self.d * y + self.v

    def update(self, i: int, x_i: np.ndarray,
               deps: Dict[int, np.ndarray]) -> np.ndarray:
        return self._apply(i, x_i, deps)

    def update_with_residual(self, i: int, x_i: np.ndarray,
                             deps: Dict[int, np.ndarray],
                             need_residual: bool = True):
        """Fused sweep + residual: the D-iteration residual is exactly the
        update difference, so fusion costs nothing extra."""
        x_new = self._apply(i, x_i, deps)
        if not need_residual:
            return x_new, None
        return x_new, self._contribution(x_new - x_i)

    def interface(self, i: int, x_i: np.ndarray, j: int) -> np.ndarray:
        supp = self._supp[i].get(j)
        if supp is None:
            return np.empty(0)  # j never reads from i (asymmetric edge)
        return x_i[supp].copy()

    def _contribution(self, r: np.ndarray) -> float:
        if np.isinf(self.ord):
            return float(np.max(np.abs(r))) if r.size else 0.0
        return float(np.sum(np.abs(r) ** self.ord))

    def local_residual(self, i: int, x_i: np.ndarray,
                       deps: Dict[int, np.ndarray]) -> float:
        return self._contribution(self._apply(i, x_i, deps) - x_i)

    def exact_residual(self, xs: Sequence[np.ndarray]) -> float:
        deps_full = [
            {j: xs[j][self._supp[j][i]] for j in self.neighbors(i)
             if i in self._supp[j]}
            for i in range(self.p)
        ]
        contribs = [self.local_residual(i, xs[i], deps_full[i])
                    for i in range(self.p)]
        if np.isinf(self.ord):
            return float(max(contribs))
        return float(sum(contribs) ** (1.0 / self.ord))

    # -- helpers -------------------------------------------------------------
    def assemble(self, xs: Sequence[np.ndarray]) -> np.ndarray:
        return np.concatenate(list(xs))

    def solve_reference(self, tol: float = 1e-14,
                        max_iter: int = 10_000) -> np.ndarray:
        """Synchronous power iteration to high precision (test oracle)."""
        xs = [self.init_local(i) for i in range(self.p)]
        for _ in range(max_iter):
            deps = [
                {j: self.interface(j, xs[j], i) for j in self.neighbors(i)}
                for i in range(self.p)
            ]
            new = [self._apply(i, xs[i], deps[i]) for i in range(self.p)]
            delta = max(float(np.max(np.abs(a - b))) for a, b in zip(new, xs))
            xs = new
            if delta < tol:
                break
        return self.assemble(xs)
