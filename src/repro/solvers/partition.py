"""Domain decomposition for the paper's convection–diffusion experiment.

The cubic domain is partitioned into a ``px × py`` grid in the (x, y)-plane;
each subdomain keeps the whole z-interval (paper §4.1).  Workers are numbered
row-major; neighbours are the 4-neighbourhood in the (x, y) process grid.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Tuple


def process_grid(p: int) -> Tuple[int, int]:
    """Factor p into the most-square (px, py) grid (paper uses 2-D grids)."""
    best = (p, 1)
    for px in range(1, int(math.isqrt(p)) + 1):
        if p % px == 0:
            best = (p // px, px)
    return best


@dataclass(frozen=True)
class GridPartition:
    """Partition of an ``n × n × n`` interior grid over a ``px × py`` grid."""

    n: int
    px: int
    py: int

    def __post_init__(self):
        if self.n % self.px or self.n % self.py:
            raise ValueError(f"n={self.n} not divisible by ({self.px},{self.py})")

    @property
    def p(self) -> int:
        return self.px * self.py

    @property
    def block(self) -> Tuple[int, int, int]:
        return (self.n // self.px, self.n // self.py, self.n)

    def coords(self, i: int) -> Tuple[int, int]:
        return divmod(i, self.py)

    def rank(self, cx: int, cy: int) -> int:
        return cx * self.py + cy

    def neighbors(self, i: int) -> List[int]:
        cx, cy = self.coords(i)
        out = []
        if cx > 0:
            out.append(self.rank(cx - 1, cy))
        if cx < self.px - 1:
            out.append(self.rank(cx + 1, cy))
        if cy > 0:
            out.append(self.rank(cx, cy - 1))
        if cy < self.py - 1:
            out.append(self.rank(cx, cy + 1))
        return out

    def side(self, i: int, j: int) -> str:
        """Which face of subdomain i touches neighbour j: x-|x+|y-|y+."""
        (cx, cy), (dx, dy) = self.coords(i), self.coords(j)
        if dx == cx - 1 and dy == cy:
            return "x-"
        if dx == cx + 1 and dy == cy:
            return "x+"
        if dx == cx and dy == cy - 1:
            return "y-"
        if dx == cx and dy == cy + 1:
            return "y+"
        raise ValueError(f"{j} is not a neighbour of {i}")

    def offsets(self, i: int) -> Tuple[int, int]:
        cx, cy = self.coords(i)
        bx, by, _ = self.block
        return (cx * bx, cy * by)
