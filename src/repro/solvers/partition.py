"""Domain decomposition for the paper's convection–diffusion experiment.

Two partitioners live here:

* ``GridPartition`` — the paper's fixed ``px × py`` (x, y)-plane grid with
  the whole z-interval local (§4.1); kept verbatim for the event-sim and
  bench drivers that predate pluggable meshes.
* ``MeshPartition`` — the pluggable 1-D/2-D/3-D shard-mesh contract the
  device runtime consumes (Hydra-style: a partition yields per-shard block
  specs, face-neighbour topology, and the double-buffer space the stale
  halo ring needs).  ``launch.mesh.make_shard_mesh`` builds the matching
  device mesh from ``MeshPartition.shape``;
  ``runtime.shard_runtime.make_convdiff_runtime`` consumes blocks, faces,
  and offsets.

Workers are numbered row-major; neighbours are the face adjacency of the
process grid (the 7-point stencil exchanges faces only — no edges/corners).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple


def process_grid(p: int) -> Tuple[int, int]:
    """Factor p into the most-square (px, py) grid (paper uses 2-D grids)."""
    best = (p, 1)
    for px in range(1, int(math.isqrt(p)) + 1):
        if p % px == 0:
            best = (p // px, px)
    return best


@dataclass(frozen=True)
class GridPartition:
    """Partition of an ``n × n × n`` interior grid over a ``px × py`` grid."""

    n: int
    px: int
    py: int

    def __post_init__(self):
        if self.n % self.px or self.n % self.py:
            raise ValueError(f"n={self.n} not divisible by ({self.px},{self.py})")

    @property
    def p(self) -> int:
        """Total subdomain count px x py."""
        return self.px * self.py

    @property
    def block(self) -> Tuple[int, int, int]:
        """Per-subdomain block extents (x, y, full z pencil)."""
        return (self.n // self.px, self.n // self.py, self.n)

    def coords(self, i: int) -> Tuple[int, int]:
        """Row-major (cx, cy) grid coordinates of rank i."""
        return divmod(i, self.py)

    def rank(self, cx: int, cy: int) -> int:
        """Row-major rank of grid coordinates (cx, cy)."""
        return cx * self.py + cy

    def neighbors(self, i: int) -> List[int]:
        """Face-adjacent ranks of subdomain i (4-neighbourhood)."""
        cx, cy = self.coords(i)
        out = []
        if cx > 0:
            out.append(self.rank(cx - 1, cy))
        if cx < self.px - 1:
            out.append(self.rank(cx + 1, cy))
        if cy > 0:
            out.append(self.rank(cx, cy - 1))
        if cy < self.py - 1:
            out.append(self.rank(cx, cy + 1))
        return out

    def side(self, i: int, j: int) -> str:
        """Which face of subdomain i touches neighbour j: x-|x+|y-|y+."""
        (cx, cy), (dx, dy) = self.coords(i), self.coords(j)
        if dx == cx - 1 and dy == cy:
            return "x-"
        if dx == cx + 1 and dy == cy:
            return "x+"
        if dx == cx and dy == cy - 1:
            return "y-"
        if dx == cx and dy == cy + 1:
            return "y+"
        raise ValueError(f"{j} is not a neighbour of {i}")

    def offsets(self, i: int) -> Tuple[int, int]:
        """Global (x, y) grid offsets of subdomain i's block origin."""
        cx, cy = self.coords(i)
        bx, by, _ = self.block
        return (cx * bx, cy * by)


# ---------------------------------------------------------------------------
# Pluggable 1-D/2-D/3-D shard-mesh partitioner (device-runtime contract)
# ---------------------------------------------------------------------------

#: face labels per grid axis, (minus, plus) — the exchange/event vocabulary
FACES = (("x-", "x+"), ("y-", "y+"), ("z-", "z+"))


@dataclass(frozen=True)
class MeshPartition:
    """Partition of an ``n × n × n`` grid over a 1-D/2-D/3-D process mesh.

    ``shape`` is ``(px,)``, ``(px, py)``, or ``(px, py, pz)``: grid axis d
    is split into ``shape[d]`` equal slabs; axes beyond ``len(shape)`` stay
    whole (a 1-D partition is the runtime's historical x-pencil).  This is
    the partitioner contract the shard runtime builds against: per-shard
    block specs (``block``/``block_spec``), face-neighbour topology
    (``neighbors``/``face``), and the double-buffer space of the stale halo
    ring (``face_shapes``/``ring_slots``/``buffer_elems``).
    """

    n: int
    shape: Tuple[int, ...]

    def __post_init__(self):
        shape = tuple(int(s) for s in self.shape)
        object.__setattr__(self, "shape", shape)
        if not 1 <= len(shape) <= 3:
            raise ValueError(f"mesh shape {shape} must be 1-D, 2-D, or 3-D")
        if any(s < 1 for s in shape):
            raise ValueError(f"mesh shape {shape} must be >= 1 per axis")
        for s in shape:
            if self.n % s:
                raise ValueError(
                    f"n={self.n} not divisible by mesh shape {shape}")

    # -- basic facts --------------------------------------------------------
    @property
    def ndim(self) -> int:
        """Partitioned mesh dimensionality (1, 2 or 3)."""
        return len(self.shape)

    @property
    def p(self) -> int:
        """Total shard count (product of the mesh shape)."""
        return int(math.prod(self.shape))

    @property
    def full_shape(self) -> Tuple[int, int, int]:
        """``shape`` padded with trailing 1s to the three grid axes."""
        return tuple(self.shape) + (1,) * (3 - self.ndim)

    @property
    def block(self) -> Tuple[int, int, int]:
        """Per-shard block extents along the three grid axes."""
        return tuple(self.n // s for s in self.full_shape)

    def block_spec(self, i: int) -> Tuple[Tuple[int, int], ...]:
        """Per-axis ``(offset, extent)`` of shard i's block (the Hydra-style
        per-shard task spec)."""
        off = self.offsets(i)
        return tuple(zip(off, self.block))

    # -- rank <-> coords (row-major, matching the device-mesh layout) -------
    def coords(self, i: int) -> Tuple[int, ...]:
        """Row-major mesh coordinates of rank i."""
        if not 0 <= i < self.p:
            raise ValueError(f"rank {i} out of range for p={self.p}")
        out = []
        for s in reversed(self.shape):
            i, c = divmod(i, s)
            out.append(c)
        return tuple(reversed(out))

    def rank(self, *coords: int) -> int:
        """Row-major rank of the given mesh coordinates."""
        if len(coords) != self.ndim:
            raise ValueError(f"expected {self.ndim} coords, got {coords}")
        r = 0
        for c, s in zip(coords, self.shape):
            if not 0 <= c < s:
                raise ValueError(f"coords {coords} out of mesh {self.shape}")
            r = r * s + c
        return r

    def offsets(self, i: int) -> Tuple[int, int, int]:
        """Global grid offsets of shard i's block origin."""
        c = self.coords(i) + (0,) * (3 - self.ndim)
        return tuple(cd * bd for cd, bd in zip(c, self.block))

    # -- face-neighbour topology --------------------------------------------
    def neighbors(self, i: int) -> List[int]:
        """Face-adjacent ranks of shard i across every mesh axis."""
        c = self.coords(i)
        out = []
        for d in range(self.ndim):
            for step in (-1, +1):
                cd = c[d] + step
                if 0 <= cd < self.shape[d]:
                    out.append(self.rank(*(c[:d] + (cd,) + c[d + 1:])))
        return out

    def face(self, i: int, j: int) -> str:
        """Which face of shard i touches neighbour j (``FACES`` labels)."""
        ci, cj = self.coords(i), self.coords(j)
        diff = [b - a for a, b in zip(ci, cj)]
        for d, dd in enumerate(diff):
            if dd in (-1, +1) and all(o == 0 for k, o in enumerate(diff)
                                      if k != d):
                return FACES[d][0 if dd == -1 else 1]
        raise ValueError(f"{j} is not a face neighbour of {i}")

    # -- double-buffer space (the stale halo ring) ---------------------------
    def face_shapes(self) -> Dict[str, Tuple[int, int]]:
        """Shape of each exchanged face plane, keyed by ``FACES`` label.
        Every mesh axis exchanges both its faces (size-1 axes receive the
        zero Dirichlet plane from the empty permutation — same buffers)."""
        bx, by, bz = self.block
        plane = {0: (by, bz), 1: (bx, bz), 2: (bx, by)}
        out = {}
        for d in range(self.ndim):
            for label in FACES[d]:
                out[label] = plane[d]
        return out

    def ring_slots(self, max_delay: int) -> int:
        """Ring length the stale-halo buffer needs: the consuming shard
        reads the view from ``delay`` exchanges ago while the exchange of
        step k+1 lands — ``max_delay + 1`` slots, double-buffered minimum 2
        when the runtime overlaps the exchange behind the interior sweep."""
        if max_delay < 0:
            raise ValueError(f"max_delay={max_delay} must be >= 0")
        return max(int(max_delay) + 1, 2)

    def buffer_elems(self, max_delay: int = 0) -> int:
        """Total per-shard halo double-buffer space, in elements."""
        slots = self.ring_slots(max_delay)
        return slots * sum(a * b for a, b in self.face_shapes().values())
