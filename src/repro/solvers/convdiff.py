"""3-D convection–diffusion problem (paper §4.1).

    ∂u/∂t − ν Δu + a·∇u = s   on [0,1]³, homogeneous Dirichlet BC.

Backward-Euler + centred finite differences give, per time step, a sparse
linear system ``A x = b`` with the 7-point stencil

    diag       : 1/dt + 6ν/h²
    x∓ /y∓ /z∓ : −ν/h² ∓ a_d/(2h)      (d = x, y, z)

solved by relaxation: Jacobi at subdomain interfaces (ghost planes frozen to
the last received neighbour data) and red-black Gauss–Seidel at interior
nodes — exactly the paper's scheme.  The Jacobi iteration matrix has
spectral radius ρ ≈ (6ν/h²)/(1/dt + 6ν/h²) < 1, so ``dt`` directly
controls the contraction rate; ``for_contraction`` picks dt for a target ρ.

``ConvDiffProblem`` implements ``core.async_engine.DecomposedProblem`` for
the event-level simulator (numpy).  The pure stencil helpers are shared with
the JAX distributed solver (solvers/fixed_point.py) and the Pallas kernel
oracle (kernels/jacobi3d/ref.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.solvers.partition import GridPartition, process_grid


@dataclass(frozen=True)
class Stencil:
    """7-point convection–diffusion stencil coefficients."""

    diag: float
    xm: float
    xp: float
    ym: float
    yp: float
    zm: float
    zp: float

    @staticmethod
    def convdiff(n: int, nu: float, a: Tuple[float, float, float], dt: float) -> "Stencil":
        h = 1.0 / (n + 1)
        d = nu / h**2
        cx, cy, cz = (ai / (2 * h) for ai in a)
        return Stencil(
            diag=1.0 / dt + 6.0 * d,
            xm=-d - cx, xp=-d + cx,
            ym=-d - cy, yp=-d + cy,
            zm=-d - cz, zp=-d + cz,
        )

    @staticmethod
    def for_contraction(n: int, nu: float, a: Tuple[float, float, float], rho: float) -> "Stencil":
        """Pick dt so the Jacobi spectral-radius proxy 6ν/h² / diag = rho."""
        h = 1.0 / (n + 1)
        d = nu / h**2
        inv_dt = 6.0 * d * (1.0 - rho) / rho
        return Stencil.convdiff(n, nu, a, dt=1.0 / inv_dt)

    def offdiag_apply(self, g: np.ndarray) -> np.ndarray:
        """Σ_offdiag a_ij x_j over a ghosted block g[(bx+2, by+2, bz+2)]."""
        return (
            self.xm * g[:-2, 1:-1, 1:-1]
            + self.xp * g[2:, 1:-1, 1:-1]
            + self.ym * g[1:-1, :-2, 1:-1]
            + self.yp * g[1:-1, 2:, 1:-1]
            + self.zm * g[1:-1, 1:-1, :-2]
            + self.zp * g[1:-1, 1:-1, 2:]
        )

    def residual_block(self, g: np.ndarray, b: np.ndarray) -> np.ndarray:
        """b − A x over a ghosted block (rows owned by the block)."""
        return b - (self.diag * g[1:-1, 1:-1, 1:-1] + self.offdiag_apply(g))

    def jacobi_sweep(self, g: np.ndarray, b: np.ndarray) -> np.ndarray:
        """One Jacobi sweep: returns the new interior block (no ghosts)."""
        return (b - self.offdiag_apply(g)) / self.diag

    def redblack_gs_sweep(self, g: np.ndarray, b: np.ndarray, ox: int, oy: int) -> np.ndarray:
        """One red-black Gauss–Seidel sweep (ghost planes frozen — the
        interface stays Jacobi w.r.t. neighbour data).  ``ox, oy`` are the
        block's global offsets so the checkerboard is globally aligned."""
        bx, by, bz = b.shape
        ix = np.arange(bx)[:, None, None] + ox
        iy = np.arange(by)[None, :, None] + oy
        iz = np.arange(bz)[None, None, :]
        parity = (ix + iy + iz) % 2
        for color in (0, 1):
            new = (b - self.offdiag_apply(g)) / self.diag
            mask = parity == color
            inner = g[1:-1, 1:-1, 1:-1]
            g[1:-1, 1:-1, 1:-1] = np.where(mask, new, inner)
        return g[1:-1, 1:-1, 1:-1]


def make_rhs(n: int, seed: int = 0, kind: str = "smooth") -> np.ndarray:
    """Right-hand side b = u_prev/dt + s on the n³ interior grid."""
    if kind == "const":
        return np.ones((n, n, n))
    rng = np.random.default_rng(seed)
    xs = np.linspace(0, 1, n + 2)[1:-1]
    X, Y, Z = np.meshgrid(xs, xs, xs, indexing="ij")
    b = (
        np.sin(np.pi * X) * np.sin(np.pi * Y) * np.sin(np.pi * Z)
        + 0.3 * np.sin(2 * np.pi * X) * np.cos(np.pi * Z)
    )
    return b + 0.05 * rng.standard_normal((n, n, n))


class ConvDiffProblem:
    """Paper experiment as a ``DecomposedProblem`` for the event simulator."""

    def __init__(
        self,
        n: int = 24,
        p: int = 4,
        nu: float = 1.0,
        a: Tuple[float, float, float] = (1.0, 1.0, 1.0),
        rho: float = 0.95,
        ord: float = float("inf"),
        seed: int = 0,
        sweep: str = "hybrid",  # "hybrid" (paper: GS interior) | "jacobi"
    ):
        px, py = process_grid(p)
        self.part = GridPartition(n=n, px=px, py=py)
        self.p = self.part.p
        self.n = n
        self.ord = ord
        self.sweep = sweep
        self.st = Stencil.for_contraction(n, nu, a, rho)
        self.b_global = make_rhs(n, seed)
        bx, by, bz = self.part.block
        self._b: List[np.ndarray] = []
        for i in range(self.p):
            ox, oy = self.part.offsets(i)
            self._b.append(self.b_global[ox : ox + bx, oy : oy + by, :])

    # -- DecomposedProblem interface ----------------------------------------
    def neighbors(self, i: int) -> List[int]:
        return self.part.neighbors(i)

    def init_local(self, i: int) -> np.ndarray:
        bx, by, bz = self.part.block
        return np.zeros((bx, by, bz))

    def _ghosted(self, i: int, x_i: np.ndarray, deps: Dict[int, np.ndarray]) -> np.ndarray:
        bx, by, bz = self.part.block
        g = np.zeros((bx + 2, by + 2, bz + 2))
        g[1:-1, 1:-1, 1:-1] = x_i
        for j in self.part.neighbors(i):
            side = self.part.side(i, j)
            dep = deps.get(j)
            if dep is None:
                continue
            if side == "x-":
                g[0, 1:-1, 1:-1] = dep
            elif side == "x+":
                g[-1, 1:-1, 1:-1] = dep
            elif side == "y-":
                g[1:-1, 0, 1:-1] = dep
            else:
                g[1:-1, -1, 1:-1] = dep
        return g

    def update(self, i: int, x_i: np.ndarray, deps: Dict[int, np.ndarray]) -> np.ndarray:
        g = self._ghosted(i, x_i, deps)
        if self.sweep == "jacobi":
            return self.st.jacobi_sweep(g, self._b[i])
        ox, oy = self.part.offsets(i)
        return self.st.redblack_gs_sweep(g, self._b[i], ox, oy)

    def interface(self, i: int, x_i: np.ndarray, j: int) -> np.ndarray:
        side = self.part.side(i, j)  # face of i facing j
        if side == "x-":
            return np.array(x_i[0, :, :], copy=True)
        if side == "x+":
            return np.array(x_i[-1, :, :], copy=True)
        if side == "y-":
            return np.array(x_i[:, 0, :], copy=True)
        return np.array(x_i[:, -1, :], copy=True)

    def local_residual(self, i: int, x_i: np.ndarray, deps: Dict[int, np.ndarray]) -> float:
        g = self._ghosted(i, x_i, deps)
        r = self.st.residual_block(g, self._b[i])
        if np.isinf(self.ord):
            return float(np.max(np.abs(r)))
        return float(np.sum(r * r))

    def exact_residual(self, xs: Sequence[np.ndarray]) -> float:
        u = self.assemble(xs)
        g = np.zeros((self.n + 2,) * 3)
        g[1:-1, 1:-1, 1:-1] = u
        r = self.st.residual_block(g, self.b_global)
        if np.isinf(self.ord):
            return float(np.max(np.abs(r)))
        return float(np.sqrt(np.sum(r * r)))

    # -- helpers -------------------------------------------------------------
    def assemble(self, xs: Sequence[np.ndarray]) -> np.ndarray:
        bx, by, _ = self.part.block
        u = np.zeros((self.n, self.n, self.n))
        for i in range(self.p):
            ox, oy = self.part.offsets(i)
            u[ox : ox + bx, oy : oy + by, :] = xs[i]
        return u

    def solve_reference(self, tol: float = 1e-12, max_iter: int = 100_000) -> np.ndarray:
        """Sequential Jacobi to high precision (test oracle)."""
        g = np.zeros((self.n + 2,) * 3)
        for _ in range(max_iter):
            new = self.st.jacobi_sweep(g, self.b_global)
            delta = np.max(np.abs(new - g[1:-1, 1:-1, 1:-1]))
            g[1:-1, 1:-1, 1:-1] = new
            if delta < tol:
                break
        return g[1:-1, 1:-1, 1:-1]
