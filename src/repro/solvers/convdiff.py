"""3-D convection–diffusion problem (paper §4.1).

    ∂u/∂t − ν Δu + a·∇u = s   on [0,1]³, homogeneous Dirichlet BC.

Backward-Euler + centred finite differences give, per time step, a sparse
linear system ``A x = b`` with the 7-point stencil

    diag       : 1/dt + 6ν/h²
    x∓ /y∓ /z∓ : −ν/h² ∓ a_d/(2h)      (d = x, y, z)

solved by relaxation: Jacobi at subdomain interfaces (ghost planes frozen to
the last received neighbour data) and red-black Gauss–Seidel at interior
nodes — exactly the paper's scheme.  The Jacobi iteration matrix has
spectral radius ρ ≈ (6ν/h²)/(1/dt + 6ν/h²) < 1, so ``dt`` directly
controls the contraction rate; ``for_contraction`` picks dt for a target ρ.

``ConvDiffProblem`` implements ``core.async_engine.DecomposedProblem`` for
the event-level simulator (numpy).  The pure stencil helpers are shared with
the JAX distributed solver (solvers/fixed_point.py) and the Pallas kernel
oracle (kernels/jacobi3d/ref.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.solvers.partition import GridPartition, process_grid


@dataclass(frozen=True)
class Stencil:
    """7-point convection–diffusion stencil coefficients."""

    diag: float
    xm: float
    xp: float
    ym: float
    yp: float
    zm: float
    zp: float

    def __post_init__(self):
        # cached off-diagonal coefficient vector: offdiag_apply contracts the
        # 6 stacked neighbour planes in one einsum instead of 11 elementwise
        # passes — ~2× faster at event-sim block sizes, where per-call numpy
        # overhead dominates the hot loop.
        object.__setattr__(
            self, "_offc",
            np.array([self.xm, self.xp, self.ym, self.yp, self.zm, self.zp]),
        )

    @staticmethod
    def convdiff(n: int, nu: float, a: Tuple[float, float, float], dt: float) -> "Stencil":
        h = 1.0 / (n + 1)
        d = nu / h**2
        cx, cy, cz = (ai / (2 * h) for ai in a)
        return Stencil(
            diag=1.0 / dt + 6.0 * d,
            xm=-d - cx, xp=-d + cx,
            ym=-d - cy, yp=-d + cy,
            zm=-d - cz, zp=-d + cz,
        )

    @staticmethod
    def for_contraction(n: int, nu: float, a: Tuple[float, float, float], rho: float) -> "Stencil":
        """Pick dt so the Jacobi spectral-radius proxy 6ν/h² / diag = rho."""
        h = 1.0 / (n + 1)
        d = nu / h**2
        inv_dt = 6.0 * d * (1.0 - rho) / rho
        return Stencil.convdiff(n, nu, a, dt=1.0 / inv_dt)

    def offdiag_apply(self, g: np.ndarray, scratch: np.ndarray = None,
                      out: np.ndarray = None) -> np.ndarray:
        """Σ_offdiag a_ij x_j over a ghosted block g[(bx+2, by+2, bz+2)].

        ``scratch`` — optional preallocated (6, bx, by, bz) plane stack and
        ``out`` — optional result buffer: hot-loop callers (the event
        simulator runs this tens of thousands of times on tiny blocks, where
        ``np.stack``'s allocation dominates) pass per-problem buffers.
        """
        planes = (
            g[:-2, 1:-1, 1:-1], g[2:, 1:-1, 1:-1],
            g[1:-1, :-2, 1:-1], g[1:-1, 2:, 1:-1],
            g[1:-1, 1:-1, :-2], g[1:-1, 1:-1, 2:],
        )
        if scratch is None:
            s = np.stack(planes)
        else:
            for k in range(6):
                np.copyto(scratch[k], planes[k])
            s = scratch
        return np.einsum("c,cxyz->xyz", self._offc, s, out=out)

    def residual_block(self, g: np.ndarray, b: np.ndarray,
                       scratch: np.ndarray = None) -> np.ndarray:
        """b − A x over a ghosted block (rows owned by the block)."""
        return b - (self.diag * g[1:-1, 1:-1, 1:-1]
                    + self.offdiag_apply(g, scratch=scratch))

    def jacobi_sweep(self, g: np.ndarray, b: np.ndarray) -> np.ndarray:
        """One Jacobi sweep: returns the new interior block (no ghosts)."""
        return (b - self.offdiag_apply(g)) / self.diag

    @staticmethod
    def parity_mask(shape: Tuple[int, int, int], ox: int, oy: int) -> np.ndarray:
        """Globally-aligned checkerboard: True where (ix+iy+iz) is odd."""
        bx, by, bz = shape
        ix = np.arange(bx)[:, None, None] + ox
        iy = np.arange(by)[None, :, None] + oy
        iz = np.arange(bz)[None, None, :]
        return ((ix + iy + iz) % 2).astype(bool)

    def redblack_gs_sweep(self, g: np.ndarray, b: np.ndarray, ox: int, oy: int,
                          parity: np.ndarray = None) -> np.ndarray:
        """One red-black Gauss–Seidel sweep (ghost planes frozen — the
        interface stays Jacobi w.r.t. neighbour data).  ``ox, oy`` are the
        block's global offsets so the checkerboard is globally aligned.

        ``parity`` — optional cached ``parity_mask(b.shape, ox, oy)`` (True =
        odd/second color); callers in hot loops should pass it to avoid
        rebuilding the index grids every sweep.  The off-diagonal apply for
        the first color doubles as the pre-sweep residual term, so fused
        callers (``redblack_gs_sweep_residual``) pay no extra stencil pass.
        """
        new, _ = self.redblack_gs_sweep_residual(g, b, ox, oy, parity=parity,
                                                 need_residual=False)
        return new

    def redblack_gs_sweep_residual(self, g: np.ndarray, b: np.ndarray,
                                   ox: int, oy: int,
                                   parity: np.ndarray = None,
                                   need_residual: bool = True):
        """Fused hybrid sweep: one RB-GS sweep plus (optionally) the residual
        of the *input* state, sharing the first off-diagonal apply.

        Returns ``(new_interior, r)`` where ``r = b − A x_in`` (the pre-sweep
        residual block; ``None`` when ``need_residual`` is False).  ``g`` is
        mutated in place (interior only) exactly like ``redblack_gs_sweep``.
        """
        if parity is None:
            parity = self.parity_mask(b.shape, ox, oy)
        inner = g[1:-1, 1:-1, 1:-1]
        off = self.offdiag_apply(g)
        r = (b - (self.diag * inner + off)) if need_residual else None
        # color 0 (even): Jacobi update against the frozen view
        np.copyto(inner, (b - off) / self.diag, where=~parity)
        # color 1 (odd): sees same-sweep color-0 updates + frozen ghosts
        np.copyto(inner, (b - self.offdiag_apply(g)) / self.diag, where=parity)
        return inner, r


def make_rhs(n: int, seed: int = 0, kind: str = "smooth") -> np.ndarray:
    """Right-hand side b = u_prev/dt + s on the n³ interior grid."""
    if kind == "const":
        return np.ones((n, n, n))
    rng = np.random.default_rng(seed)
    xs = np.linspace(0, 1, n + 2)[1:-1]
    X, Y, Z = np.meshgrid(xs, xs, xs, indexing="ij")
    b = (
        np.sin(np.pi * X) * np.sin(np.pi * Y) * np.sin(np.pi * Z)
        + 0.3 * np.sin(2 * np.pi * X) * np.cos(np.pi * Z)
    )
    return b + 0.05 * rng.standard_normal((n, n, n))


class ConvDiffProblem:
    """Paper experiment as a ``DecomposedProblem`` for the event simulator."""

    def __init__(
        self,
        n: int = 24,
        p: int = 4,
        nu: float = 1.0,
        a: Tuple[float, float, float] = (1.0, 1.0, 1.0),
        rho: float = 0.95,
        ord: float = float("inf"),
        seed: int = 0,
        sweep: str = "hybrid",  # "hybrid" (paper: GS interior) | "jacobi"
    ):
        px, py = process_grid(p)
        self.part = GridPartition(n=n, px=px, py=py)
        self.p = self.part.p
        self.n = n
        self.ord = ord
        self.sweep = sweep
        self.st = Stencil.for_contraction(n, nu, a, rho)
        self.b_global = make_rhs(n, seed)
        bx, by, bz = self.part.block
        self._b: List[np.ndarray] = []
        # Per-worker preallocated ghost buffers + cached checkerboard masks
        # for the fused ``update_with_residual`` path: the seed code allocated
        # and zero-filled a fresh (bx+2)(by+2)(bz+2) array twice per sweep
        # (once in ``update``, once in ``local_residual``).  Domain-boundary
        # ghost faces are zero (Dirichlet BC) and stay zero; neighbour faces
        # are overwritten on every fill, so the buffer never needs re-zeroing.
        self._gbuf: List[np.ndarray] = []
        self._parity: List[np.ndarray] = []
        self._faces: List[List[Tuple[int, Tuple]]] = []  # (neighbour, face slice)
        self._neighbors: List[List[int]] = []
        self._iface: List[Dict[int, Tuple]] = []  # j -> face slice of x_i
        _face_ix = {"x-": (0, slice(1, -1), slice(1, -1)),
                    "x+": (-1, slice(1, -1), slice(1, -1)),
                    "y-": (slice(1, -1), 0, slice(1, -1)),
                    "y+": (slice(1, -1), -1, slice(1, -1))}
        _x_face = {"x-": (0, slice(None), slice(None)),
                   "x+": (-1, slice(None), slice(None)),
                   "y-": (slice(None), 0, slice(None)),
                   "y+": (slice(None), -1, slice(None))}
        # checkerboard-slice machinery (satellite of the fused hot path):
        # per worker and per color, the flat ghost-buffer indices of that
        # color's cells and of their 6 neighbours, so one fancy gather + one
        # (6,)·(6,m) matvec replaces a full-grid off-diagonal pass — the
        # sweep touches exactly the half-grid it updates.
        self._cidx: List[Tuple[np.ndarray, np.ndarray]] = []
        self._cnidx: List[Tuple[np.ndarray, np.ndarray]] = []
        self._cb: List[Tuple[np.ndarray, np.ndarray]] = []
        self._cpos0: List[np.ndarray] = []   # color-0 positions in block order
        self._bflat: List[np.ndarray] = []   # contiguous flat rhs per worker
        sx, sy = (by + 2) * (bz + 2), bz + 2
        noffs = np.array([-sx, sx, -sy, sy, -1, 1])  # xm xp ym yp zm zp
        ixg = np.arange(bx)[:, None, None]
        iyg = np.arange(by)[None, :, None]
        izg = np.arange(bz)[None, None, :]
        flat = ((ixg + 1) * (by + 2) + (iyg + 1)) * (bz + 2) + (izg + 1)
        # every interior cell's 6 neighbour flat indices (shared by all
        # workers — block shapes are uniform): one fancy gather + one
        # (6,)·(6, n_block) matvec is the fastest full off-diagonal apply
        # at event-sim block sizes.  The gather/result scratch buffers kill
        # the per-sweep allocations (~30% of the sweep at n=12 blocks);
        # calls are serialised within a simulator process and every result
        # is consumed before the next sweep.
        self._nidx_full = flat.ravel()[None, :] + noffs[:, None]
        nblock = bx * by * bz
        self._take6 = np.empty((6, nblock))          # full 6-plane gather
        self._take6h = np.empty((6, (nblock + 1) // 2))  # half-grid gather
        self._offbuf = np.empty(nblock)              # full off-diag result
        self._rbuf = np.empty(nblock)                # pre-sweep residual
        for i in range(self.p):
            ox, oy = self.part.offsets(i)
            self._b.append(self.b_global[ox : ox + bx, oy : oy + by, :])
            self._gbuf.append(np.zeros((bx + 2, by + 2, bz + 2)))
            self._parity.append(Stencil.parity_mask((bx, by, bz), ox, oy))
            self._neighbors.append(self.part.neighbors(i))
            self._faces.append([(j, _face_ix[self.part.side(i, j)])
                                for j in self._neighbors[i]])
            self._iface.append({j: _x_face[self.part.side(i, j)]
                                for j in self._neighbors[i]})
            par = self._parity[i]
            idx = tuple(flat[m] for m in (~par, par))
            self._cidx.append(idx)
            self._cnidx.append(tuple(c[None, :] + noffs[:, None] for c in idx))
            self._cb.append(tuple(self._b[i][m] for m in (~par, par)))
            self._cpos0.append(np.flatnonzero(~par.ravel()))
            self._bflat.append(np.ascontiguousarray(self._b[i]).reshape(-1))

    # -- DecomposedProblem interface ----------------------------------------
    def neighbors(self, i: int) -> List[int]:
        return self._neighbors[i]

    def init_local(self, i: int) -> np.ndarray:
        bx, by, bz = self.part.block
        return np.zeros((bx, by, bz))

    def _ghosted(self, i: int, x_i: np.ndarray, deps: Dict[int, np.ndarray]) -> np.ndarray:
        bx, by, bz = self.part.block
        g = np.zeros((bx + 2, by + 2, bz + 2))
        g[1:-1, 1:-1, 1:-1] = x_i
        for j in self.part.neighbors(i):
            side = self.part.side(i, j)
            dep = deps.get(j)
            if dep is None:
                continue
            if side == "x-":
                g[0, 1:-1, 1:-1] = dep
            elif side == "x+":
                g[-1, 1:-1, 1:-1] = dep
            elif side == "y-":
                g[1:-1, 0, 1:-1] = dep
            else:
                g[1:-1, -1, 1:-1] = dep
        return g

    def update(self, i: int, x_i: np.ndarray, deps: Dict[int, np.ndarray]) -> np.ndarray:
        g = self._ghosted(i, x_i, deps)
        if self.sweep == "jacobi":
            return self.st.jacobi_sweep(g, self._b[i])
        ox, oy = self.part.offsets(i)
        return self.st.redblack_gs_sweep(g, self._b[i], ox, oy,
                                         parity=self._parity[i])

    def _fill_ghost(self, i: int, x_i: np.ndarray,
                    deps: Dict[int, np.ndarray]) -> np.ndarray:
        """Assemble the ghosted view in the worker's preallocated buffer
        (no allocation, no zero-fill — see __init__)."""
        g = self._gbuf[i]
        g[1:-1, 1:-1, 1:-1] = x_i
        for j, face in self._faces[i]:
            dep = deps.get(j)
            if dep is not None:
                g[face] = dep
        return g

    def update_with_residual(self, i: int, x_i: np.ndarray,
                             deps: Dict[int, np.ndarray],
                             need_residual: bool = True):
        """Fused sweep + residual — one ghost assembly, shared off-diagonal.

        Returns ``(x_new, r_i)`` with ``x_new == update(i, x_i, deps)`` and
        ``r_i == local_residual(i, x_i, deps)``: the residual is the one of
        the *input* state (the by-product of the relaxation), one sweep
        staler than the seed engine's post-update evaluation — the staleness
        every detection protocol here already tolerates.  ``r_i`` is None
        when ``need_residual`` is False (protocol won't consume it).
        """
        st = self.st
        g = self._fill_ghost(i, x_i, deps)
        gf = g.reshape(-1)
        coefs, inv_diag = st._offc, 1.0 / st.diag
        if self.sweep == "jacobi":
            bflat = self._bflat[i]
            np.take(gf, self._nidx_full, out=self._take6)
            off = np.matmul(coefs, self._take6, out=self._offbuf)
            r = (bflat - st.diag * x_i.reshape(-1) - off) if need_residual \
                else None
            x_new = ((bflat - off) * inv_diag).reshape(x_i.shape)
        elif not need_residual:
            # checkerboard-slice sweep: per color, one fancy gather of the
            # 6 neighbour planes + one matvec — touches only the half-grid
            # being updated (the PFAIT hot path: no residual consumer).
            for c in (0, 1):
                take = np.take(gf, self._cnidx[i][c],
                               out=self._take6h[:, : self._cidx[i][c].size])
                off_c = coefs @ take
                gf[self._cidx[i][c]] = (self._cb[i][c] - off_c) * inv_diag
            return g[1:-1, 1:-1, 1:-1].copy(), None
        else:
            # fused hybrid sweep, all flat: ONE full off-diagonal gather
            # (doubles as the pre-sweep residual term and color 0's Jacobi
            # view), then a half-grid gather for color 1 — instead of the
            # two full applies ``Stencil.redblack_gs_sweep_residual`` pays.
            bflat = self._bflat[i]
            np.take(gf, self._nidx_full, out=self._take6)
            off = np.matmul(coefs, self._take6, out=self._offbuf)
            # r = b − diag·x − off, allocation-free (reduced to a scalar
            # before the buffer is reused)
            r = np.multiply(x_i.reshape(-1), st.diag, out=self._rbuf)
            np.subtract(bflat, r, out=r)
            r -= off
            # color 0 (even): Jacobi against the frozen view
            pos0 = self._cpos0[i]
            gf[self._cidx[i][0]] = (self._cb[i][0] - off[pos0]) * inv_diag
            # color 1 (odd): sees same-sweep color-0 updates + frozen ghosts
            take = np.take(gf, self._cnidx[i][1],
                           out=self._take6h[:, : self._cidx[i][1].size])
            off_c = coefs @ take
            gf[self._cidx[i][1]] = (self._cb[i][1] - off_c) * inv_diag
            x_new = g[1:-1, 1:-1, 1:-1].copy()  # buffer reused next sweep
        if not need_residual:
            return x_new, None
        if np.isinf(self.ord):
            return x_new, float(np.max(np.abs(r)))
        return x_new, float(np.sum(r * r))

    def local_residual_fast(self, i: int, x_i: np.ndarray,
                            deps: Dict[int, np.ndarray]) -> float:
        """``local_residual`` via the preallocated ghost buffer and the flat
        gather apply (used by the engine's reduction sampling on the fused
        path — PFAIT samples it at every staggered reduction slot)."""
        g = self._fill_ghost(i, x_i, deps)
        off = self.st._offc @ g.reshape(-1).take(self._nidx_full)
        r = self._bflat[i] - self.st.diag * x_i.reshape(-1) - off
        if np.isinf(self.ord):
            return float(np.max(np.abs(r)))
        return float(np.sum(r * r))

    def interface(self, i: int, x_i: np.ndarray, j: int) -> np.ndarray:
        """Face of i facing j.  A copy, deliberately: the reference escapes
        into deps / in-flight messages / snapshot records, and a view would
        pin the whole retired (bx,by,bz) block alive per dependency (~5×
        simulator peak memory at paper-scale n).  The cached face slice
        still skips the seed's per-call ``part.side`` lookup."""
        return np.ascontiguousarray(x_i[self._iface[i][j]])

    def local_residual(self, i: int, x_i: np.ndarray, deps: Dict[int, np.ndarray]) -> float:
        g = self._ghosted(i, x_i, deps)
        r = self.st.residual_block(g, self._b[i])
        if np.isinf(self.ord):
            return float(np.max(np.abs(r)))
        return float(np.sum(r * r))

    def exact_residual(self, xs: Sequence[np.ndarray]) -> float:
        # preallocated global ghost grid + plane scratch: the reliability
        # lab samples the exact trajectory every residual_stride sweeps, so
        # this runs ~10³ times per traced run (ghost faces are Dirichlet
        # zeros and stay zero; the interior is fully overwritten each call)
        g = getattr(self, "_gexact", None)
        if g is None:
            g = self._gexact = np.zeros((self.n + 2,) * 3)
            self._sexact = np.empty((6, self.n, self.n, self.n))
        bx, by, _ = self.part.block
        u = g[1:-1, 1:-1, 1:-1]
        for i in range(self.p):
            ox, oy = self.part.offsets(i)
            u[ox : ox + bx, oy : oy + by, :] = xs[i]
        r = self.st.residual_block(g, self.b_global, scratch=self._sexact)
        if np.isinf(self.ord):
            return float(np.max(np.abs(r)))
        return float(np.sqrt(np.sum(r * r)))

    # -- batched device path -------------------------------------------------
    def update_with_residual_batched(self, X, b=None):
        """Synchronous global sweep + pre-sweep residual contribution for a
        whole batch of lanes, as one jittable device program.

        ``X`` — f32/f64[B, n, n, n] lane states (B = seeds or restarts);
        ``b`` — optional rhs, [n, n, n] or [B, n, n, n] (defaults to this
        instance's; pass a stacked array for seed-batched lanes).  Returns
        ``(X_next, contrib[B])`` with the same fused semantics as
        ``update_with_residual``: the contribution is the residual of the
        *input* state under the repo convention (max|r| for ord=∞, Σr²
        otherwise).  ``sweep`` follows the instance: one Jacobi sweep, or
        the hybrid red-black GS pair of half-sweeps.  Composes with
        ``jax.lax.scan`` / ``core.detection.contribution_series`` so whole
        (seed × K × m × ε) detection grids run as single programs.
        """
        import jax.numpy as jnp

        st = self.st
        if b is None:
            b = self.b_global
        b = jnp.asarray(b)

        def offdiag(Xp):
            g = jnp.pad(Xp, ((0, 0), (1, 1), (1, 1), (1, 1)))
            return (st.xm * g[:, :-2, 1:-1, 1:-1]
                    + st.xp * g[:, 2:, 1:-1, 1:-1]
                    + st.ym * g[:, 1:-1, :-2, 1:-1]
                    + st.yp * g[:, 1:-1, 2:, 1:-1]
                    + st.zm * g[:, 1:-1, 1:-1, :-2]
                    + st.zp * g[:, 1:-1, 1:-1, 2:])

        off = offdiag(X)
        r = b - (st.diag * X + off)
        if self.sweep == "jacobi":
            X_next = (b - off) / st.diag
        else:
            n = X.shape[-1]
            ix = jnp.arange(X.shape[1])[:, None, None]
            iy = jnp.arange(X.shape[2])[None, :, None]
            iz = jnp.arange(n)[None, None, :]
            parity = ((ix + iy + iz) % 2).astype(bool)
            even = jnp.where(~parity, (b - off) / st.diag, X)
            X_next = jnp.where(parity, (b - offdiag(even)) / st.diag, even)
        if np.isinf(self.ord):
            contrib = jnp.max(jnp.abs(r), axis=(1, 2, 3))
        else:
            contrib = jnp.sum(r * r, axis=(1, 2, 3))
        return X_next, contrib

    def lane_x0(self) -> np.ndarray:
        """Canonical initial state of one detection-service lane (f32)."""
        return np.zeros((self.n, self.n, self.n), np.float32)

    def lane_operands(self) -> dict:
        """This instance's per-lane operands for the batched step.

        Stacking these dicts over lanes (one seeded instance per lane) and
        passing them as ``update_with_residual_batched(X, **stacked)``
        gives every lane its own rhs while the stencil — seed-independent
        geometry — is shared from any instance of the same shape bucket.
        Used by ``launch/serve.py`` and the ``detection_grid`` campaign
        cells.
        """
        return {"b": np.asarray(self.b_global, np.float32)}

    # -- helpers -------------------------------------------------------------
    def assemble(self, xs: Sequence[np.ndarray]) -> np.ndarray:
        bx, by, _ = self.part.block
        u = np.zeros((self.n, self.n, self.n))
        for i in range(self.p):
            ox, oy = self.part.offsets(i)
            u[ox : ox + bx, oy : oy + by, :] = xs[i]
        return u

    def solve_reference(self, tol: float = 1e-12, max_iter: int = 100_000) -> np.ndarray:
        """Sequential Jacobi to high precision (test oracle)."""
        g = np.zeros((self.n + 2,) * 3)
        for _ in range(max_iter):
            new = self.st.jacobi_sweep(g, self.b_global)
            delta = np.max(np.abs(new - g[1:-1, 1:-1, 1:-1]))
            g[1:-1, 1:-1, 1:-1] = new
            if delta < tol:
                break
        return g[1:-1, 1:-1, 1:-1]
