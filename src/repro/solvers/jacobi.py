"""Jacobi relaxation sweeps — pure-jnp (shared by the distributed solver and
as oracle for the Pallas jacobi3d kernel)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.solvers.convdiff import Stencil


def offdiag_apply(st: Stencil, g: jnp.ndarray) -> jnp.ndarray:
    """Σ_offdiag a_ij x_j over a ghosted block g[(bx+2, by+2, bz+2)]."""
    return (
        st.xm * g[:-2, 1:-1, 1:-1]
        + st.xp * g[2:, 1:-1, 1:-1]
        + st.ym * g[1:-1, :-2, 1:-1]
        + st.yp * g[1:-1, 2:, 1:-1]
        + st.zm * g[1:-1, 1:-1, :-2]
        + st.zp * g[1:-1, 1:-1, 2:]
    )


def jacobi_sweep(st: Stencil, g: jnp.ndarray, b: jnp.ndarray, omega: float = 1.0) -> jnp.ndarray:
    """One (weighted) Jacobi sweep; returns the new interior block."""
    new = (b - offdiag_apply(st, g)) / st.diag
    if omega == 1.0:
        return new
    return (1.0 - omega) * g[1:-1, 1:-1, 1:-1] + omega * new


def jacobi_sweep_residual(st: Stencil, g: jnp.ndarray, b: jnp.ndarray):
    """Fused sweep + pre-sweep residual, sharing the off-diagonal apply.

    Returns ``(new_interior, r)`` with ``r = b − A x_in`` — the residual of
    the *input* state, the free by-product of the relaxation (equivalently
    ``diag · (new − x_in)``)."""
    off = offdiag_apply(st, g)
    r = b - (st.diag * g[1:-1, 1:-1, 1:-1] + off)
    return (b - off) / st.diag, r


def residual_block(st: Stencil, g: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """b − A x over the rows owned by the ghosted block."""
    return b - (st.diag * g[1:-1, 1:-1, 1:-1] + offdiag_apply(st, g))
