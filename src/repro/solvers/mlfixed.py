"""ML fixed-point problem family: asynchronous gradient descent as the
paper's iterative process.

El-Baz's line of work ("unbounded delays … for Convex Optimization
Problems and Machine Learning", PAPERS.md) treats asynchronous SGD on a
strongly-convex objective as exactly the fixed-point setting the detection
paper assumes: the map

    f(x) = x − γ ∇F(x)

is a contraction for γ < 2/L (L the gradient's Lipschitz constant), its
fixed point is the empirical risk minimiser, and the natural residual is
the *update difference* f(x) − x = −γ∇F(x) — the gradient norm in
disguise.  That makes the whole detection stack (event-sim protocols, the
reliability oracle, elastic scenarios, batched detection grids) apply to
ML training runs with **zero** monitor changes.

Two strongly-convex tasks, both on synthetic data with a planted model:

* ``lstsq``    — ridge least squares, F(x) = ‖Ax−y‖²/(2m) + λ‖x‖²/2.
  The gradient is affine (Hx − c with H = AᵀA/m + λI), so the async
  iteration is *linear* — the same class as ConvDiff/PageRank but with a
  dense, ill-conditioned coupling instead of a stencil/graph.
* ``logistic`` — ℓ2-regularised logistic regression,
  F(x) = Σ softplus(−s_k·a_kᵀx)/m + λ‖x‖²/2, s ∈ {−1,+1}.  Non-linear
  gradients: the contraction factor varies over the trajectory, which is
  the stochastic-residual regime the oracle-scoring helpers in
  ``core.termination`` exist for.

Decomposition is **parameter-blocked** (async block-Jacobi gradient
descent): worker i owns coordinate block x_i and needs every other
worker's block to evaluate its gradient slice, so the dependency graph is
all-to-all — the data-parallel "parameter exchange" communication pattern,
and the densest block graph of the three families (ConvDiff: 2·dim
neighbours; PageRank: hub-skewed sparse; here: complete).

Residual convention follows core/residual.py: the fused
``update_with_residual`` returns the pre-σ contribution Σ|r|^l (max|r|
for l=∞) of r = −γ∇_i F at the worker's current *view*, and
``exact_residual`` scores the assembled iterate — the synchronized-eval
oracle an async training loop never pays for.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


def _sigmoid(z: np.ndarray) -> np.ndarray:
    # numerically stable logistic function (no overflow for |z| large)
    out = np.empty_like(z)
    pos = z >= 0
    out[pos] = 1.0 / (1.0 + np.exp(-z[pos]))
    ez = np.exp(z[~pos])
    out[~pos] = ez / (1.0 + ez)
    return out


class MLFixedPointProblem:
    """Gradient descent on a strongly-convex ML objective as a
    ``core.async_engine.DecomposedProblem``."""

    TASKS = ("lstsq", "logistic")

    def __init__(
        self,
        n: int = 32,
        p: int = 4,
        m_rows: int = 192,
        task: str = "lstsq",
        gamma: float = None,
        l2: float = 1e-2,
        cond: float = 20.0,
        noise: float = 0.05,
        ord: float = 2.0,
        seed: int = 0,
    ):
        if n % p:
            raise ValueError(f"n={n} not divisible by p={p}")
        if task not in self.TASKS:
            raise ValueError(f"task {task!r} not in {self.TASKS}")
        if m_rows < n:
            raise ValueError(f"m_rows={m_rows} < n={n}: need an "
                             "overdetermined design for a unique minimiser")
        if l2 < 0.0:
            raise ValueError(f"l2={l2} must be >= 0")
        if cond < 1.0:
            raise ValueError(f"cond={cond} must be >= 1")
        self.n = n
        self.p = p
        self.m = m_rows
        self.task = task
        self.l2 = float(l2)
        self.ord = float(ord)
        self.block = n // p
        rng = np.random.default_rng(seed)

        # design matrix with controlled conditioning: Gaussian columns
        # scaled geometrically so eig(AᵀA/m) spans ~cond² before the ridge
        col_scale = cond ** (-np.arange(n) / max(n - 1, 1))
        self.A = rng.standard_normal((m_rows, n)) * col_scale
        self.x_true = rng.standard_normal(n)
        z = self.A @ self.x_true
        if task == "lstsq":
            self.y = z + noise * rng.standard_normal(m_rows)
            self.H = self.A.T @ self.A / m_rows + self.l2 * np.eye(n)
            self.c = self.A.T @ self.y / m_rows
            ev = np.linalg.eigvalsh(self.H)
            self.L = float(ev[-1])
            self.mu = float(ev[0])
        else:
            # planted labels s ∈ {−1,+1}; Bernoulli flips keep the problem
            # realisable but not separable (bounded minimiser even at λ→0)
            prob1 = _sigmoid(z)
            self.s = np.where(rng.random(m_rows) < prob1, 1.0, -1.0)
            self.y = self.s
            # L = eigmax(AᵀA)/(4m) + λ (logistic curvature bound σ' ≤ 1/4)
            sv = np.linalg.svd(self.A, compute_uv=False)[0]
            self.L = float(sv * sv / (4.0 * m_rows) + self.l2)
            self.mu = self.l2
        if gamma is None:
            gamma = 1.0 / self.L     # safe step: contraction factor 1 − μ/L
        if not 0.0 < gamma * self.L < 2.0:
            raise ValueError(
                f"gamma={gamma:g} outside the contraction range "
                f"(0, 2/L) = (0, {2.0 / self.L:g})")
        self.gamma = float(gamma)
        # per-block gradient slices of the lstsq affine map (hot path)
        if task == "lstsq":
            blk = self.block
            self._Hrows = [self.H[i * blk:(i + 1) * blk] for i in range(p)]
            self._crows = [self.c[i * blk:(i + 1) * blk] for i in range(p)]
        self._Acols = [self.A[:, i * self.block:(i + 1) * self.block]
                       for i in range(p)]

    # -- DecomposedProblem interface ----------------------------------------
    def neighbors(self, i: int) -> List[int]:
        # all-to-all: every block's gradient couples every other block
        return [j for j in range(self.p) if j != i]

    def init_local(self, i: int) -> np.ndarray:
        # x0 = 0: a worker's view of an undelivered neighbour block is the
        # init value, so missing deps assemble to the correct async view
        return np.zeros(self.block)

    def interface(self, i: int, x_i: np.ndarray, j: int) -> np.ndarray:
        return x_i.copy()   # parameter exchange: the whole block escapes

    def _assemble_view(self, i: int, x_i: np.ndarray,
                       deps: Dict[int, np.ndarray]) -> np.ndarray:
        blk = self.block
        x = np.zeros(self.n)
        x[i * blk:(i + 1) * blk] = x_i
        for j, dep in deps.items():
            if dep is not None and dep.size:
                x[j * blk:(j + 1) * blk] = dep
        return x

    def _grad_block(self, i: int, x: np.ndarray) -> np.ndarray:
        """∇_i F at the assembled view ``x``."""
        blk = self.block
        if self.task == "lstsq":
            return self._Hrows[i] @ x - self._crows[i]
        margin = self.s * (self.A @ x)
        w = -self.s * _sigmoid(-margin)      # d softplus(−s·z)/dz
        return (self._Acols[i].T @ w) / self.m \
            + self.l2 * x[i * blk:(i + 1) * blk]

    def update(self, i: int, x_i: np.ndarray,
               deps: Dict[int, np.ndarray]) -> np.ndarray:
        x = self._assemble_view(i, x_i, deps)
        return x_i - self.gamma * self._grad_block(i, x)

    def update_with_residual(self, i: int, x_i: np.ndarray,
                             deps: Dict[int, np.ndarray],
                             need_residual: bool = True):
        """Fused sweep + residual: the update difference IS −γ·∇_i F, so
        the residual contribution is a by-product of the gradient step."""
        x = self._assemble_view(i, x_i, deps)
        g = self._grad_block(i, x)
        x_new = x_i - self.gamma * g
        if not need_residual:
            return x_new, None
        return x_new, self._contribution(-self.gamma * g)

    def _contribution(self, r: np.ndarray) -> float:
        if np.isinf(self.ord):
            return float(np.max(np.abs(r))) if r.size else 0.0
        if self.ord == 2.0:
            return float(r @ r)
        if self.ord == 1.0:
            return float(np.abs(r).sum())
        return float(np.sum(np.abs(r) ** self.ord))

    def local_residual(self, i: int, x_i: np.ndarray,
                       deps: Dict[int, np.ndarray]) -> float:
        x = self._assemble_view(i, x_i, deps)
        return self._contribution(-self.gamma * self._grad_block(i, x))

    def grad(self, x: np.ndarray) -> np.ndarray:
        """Full gradient ∇F(x) (oracle / reference path)."""
        if self.task == "lstsq":
            return self.H @ x - self.c
        margin = self.s * (self.A @ x)
        w = -self.s * _sigmoid(-margin)
        return self.A.T @ w / self.m + self.l2 * x

    def objective(self, x: np.ndarray) -> float:
        if self.task == "lstsq":
            r = self.A @ x - self.y
            return float(r @ r / (2 * self.m) + self.l2 * (x @ x) / 2)
        margin = self.s * (self.A @ x)
        return float(np.logaddexp(0.0, -margin).sum() / self.m
                     + self.l2 * (x @ x) / 2)

    def exact_residual(self, xs: Sequence[np.ndarray]) -> float:
        """σ-reduced norm of the update difference −γ∇F(x̄): the
        synchronized-eval ground truth the async monitor replaces."""
        r = -self.gamma * self.grad(self.assemble(xs))
        if np.isinf(self.ord):
            return float(np.max(np.abs(r)))
        if self.ord == 1.0:
            return float(np.abs(r).sum())
        return float(np.sum(np.abs(r) ** self.ord) ** (1.0 / self.ord))

    # -- batched device path -------------------------------------------------
    def update_with_residual_batched(self, X, H=None, c=None, A=None,
                                     s=None, gamma=None):
        """Synchronous global GD step + pre-step residual contribution for
        a batch of lanes, as one jittable device program.

        ``X`` — [B, n] lane states.  For seed-batched problems pass stacked
        operators: lstsq ``H`` [B, n, n] + ``c`` [B, n]; logistic ``A``
        [B, m, n] + ``s`` [B, m]; plus per-lane ``gamma`` [B] (each seed's
        1/L differs).  Defaults evaluate this instance on every lane.
        Returns ``(X_next, contrib[B])`` under the repo contribution
        convention — the same by-product ``update_with_residual`` yields
        per worker.
        """
        import jax.numpy as jnp

        g = jnp.asarray(self.gamma if gamma is None else gamma)
        g = g[..., None] if g.ndim else g
        if self.task == "lstsq":
            H = jnp.asarray(self.H if H is None else H)
            c = jnp.asarray(self.c if c is None else c)
            G = (X @ H.T if H.ndim == 2
                 else jnp.einsum("bij,bj->bi", H, X)) - c
        else:
            A = jnp.asarray(self.A if A is None else A)
            s = jnp.asarray(self.s if s is None else s)
            import jax.nn

            Z = X @ A.T if A.ndim == 2 else jnp.einsum("bmn,bn->bm", A, X)
            W = -s * jax.nn.sigmoid(-s * Z)
            G = ((W @ A) / self.m if A.ndim == 2
                 else jnp.einsum("bm,bmn->bn", W, A) / self.m) + self.l2 * X
        R = -g * G
        Y = X + R
        if np.isinf(self.ord):
            contrib = jnp.max(jnp.abs(R), axis=-1)
        else:
            contrib = jnp.sum(jnp.abs(R) ** self.ord, axis=-1)
        return Y, contrib

    def lane_x0(self) -> np.ndarray:
        """Canonical initial state of one detection-service lane (f32)."""
        return np.zeros((self.n,), np.float32)

    def lane_operands(self) -> dict:
        """This instance's per-lane operands for the batched step.

        The seeded data matrices and the per-seed safe step size γ are
        per-lane; ``m_rows`` and ``l2`` are shape-bucket constants shared
        from any instance.  Used by ``launch/serve.py`` and the
        ``detection_grid`` campaign cells.
        """
        if self.task == "lstsq":
            return {"H": np.asarray(self.H, np.float32),
                    "c": np.asarray(self.c, np.float32),
                    "gamma": np.float32(self.gamma)}
        return {"A": np.asarray(self.A, np.float32),
                "s": np.asarray(self.s, np.float32),
                "gamma": np.float32(self.gamma)}

    # -- helpers -------------------------------------------------------------
    def assemble(self, xs: Sequence[np.ndarray]) -> np.ndarray:
        return np.concatenate(list(xs))

    def split(self, x: np.ndarray) -> List[np.ndarray]:
        blk = self.block
        return [x[i * blk:(i + 1) * blk].copy() for i in range(self.p)]

    def solve_reference(self, tol: float = 1e-14,
                        max_iter: int = 200_000) -> np.ndarray:
        """Minimiser to high precision (test / oracle path): closed form
        for lstsq, full-batch GD for logistic."""
        if self.task == "lstsq":
            return np.linalg.solve(self.H, self.c)
        x = np.zeros(self.n)
        for _ in range(max_iter):
            g = self.grad(x)
            x = x - self.gamma * g
            if float(np.max(np.abs(g))) < tol:
                break
        return x
