"""Pure-jnp oracle for the residual_norm kernel."""
from __future__ import annotations

import jax.numpy as jnp


def diff_norm_partials_ref(a, b, block: int = 65536, linf: bool = True):
    # difference in the wider of (operand dtype, f32), then cast — mirrors
    # the kernel (see residual_norm._kernel): wide inputs must not quantise
    # small update differences to zero before they are reduced
    ct = jnp.promote_types(a.dtype, jnp.float32)
    df = (a.reshape(-1).astype(ct) - b.reshape(-1).astype(ct)).astype(
        jnp.float32)
    n = df.shape[0]
    block = min(block, n)
    pad = (-n) % block
    if pad:
        df = jnp.pad(df, (0, pad))
    d = df.reshape(-1, block)
    if linf:
        return jnp.max(jnp.abs(d), axis=1)
    return jnp.sum(d * d, axis=1)
