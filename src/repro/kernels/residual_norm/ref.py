"""Pure-jnp oracle for the residual_norm kernel."""
from __future__ import annotations

import jax.numpy as jnp


def diff_norm_partials_ref(a, b, block: int = 65536, linf: bool = True):
    af = a.reshape(-1).astype(jnp.float32)
    bf = b.reshape(-1).astype(jnp.float32)
    n = af.shape[0]
    block = min(block, n)
    pad = (-n) % block
    if pad:
        af = jnp.pad(af, (0, pad))
        bf = jnp.pad(bf, (0, pad))
    d = (af - bf).reshape(-1, block)
    if linf:
        return jnp.max(jnp.abs(d), axis=1)
    return jnp.sum(d * d, axis=1)
