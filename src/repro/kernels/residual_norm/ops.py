"""jit'd wrapper: fused ‖a−b‖_l with platform dispatch."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.residual_norm.ref import diff_norm_partials_ref
from repro.kernels.residual_norm.residual_norm import diff_norm_partials


def diff_norm(a: jax.Array, b: jax.Array, ord: float = float("inf"),
              interpret: Optional[bool] = None) -> jax.Array:
    """‖a − b‖_ord, computed blockwise (kernel on TPU, jnp elsewhere)."""
    linf = np.isinf(ord)
    on_tpu = jax.default_backend() == "tpu"
    use_interp = False if interpret is None else interpret
    if on_tpu or use_interp:
        parts = diff_norm_partials(a, b, linf=linf, interpret=use_interp)
    else:
        parts = diff_norm_partials_ref(a, b, linf=linf)
    if linf:
        return jnp.max(parts)
    return jnp.sqrt(jnp.sum(parts))


def update_contribution(new: jax.Array, old: jax.Array,
                        ord: float = 2.0, scale: float = 1.0,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Pre-σ local contribution of ``r = scale · (new − old)``.

    The shard runtime's detection hot path: for relaxations whose residual
    is the update difference (Jacobi: ``r = diag·(x⁺ − x)``; D-iteration:
    ``r = f(x) − x``), the contribution is a fused diff-norm of the two
    states — exactly the kernel's access pattern, with the constant factor
    hoisted out of the reduction (``|scale|^l · Σ|Δ|^l`` for finite l,
    ``|scale| · max|Δ|`` for l = ∞).  Kernel partials on TPU (l ∈ {2, ∞});
    pure-jnp partials elsewhere; generic l falls back to core.residual.
    """
    from repro.core import residual as res

    linf = np.isinf(ord)
    s = abs(float(scale))
    on_tpu = jax.default_backend() == "tpu"
    use_interp = False if interpret is None else interpret
    if (linf or float(ord) == 2.0) and (on_tpu or use_interp):
        parts = diff_norm_partials(new, old, linf=linf, interpret=use_interp)
        if linf:
            return s * jnp.max(parts)
        return jnp.float32(s * s) * jnp.sum(parts)
    if linf or float(ord) == 2.0:
        # off TPU the blockwise partials buy nothing (XLA fuses the flat
        # reduction; the reshape/partial machinery measurably hurts inside
        # while_loop bodies) — same reduction, scale still hoisted
        contrib = res.local_contribution(new - old, ord)
        return (s if linf else jnp.float32(s * s)) * contrib
    return res.local_contribution(scale * (new - old), ord)
