"""jit'd wrapper: fused ‖a−b‖_l with platform dispatch."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.residual_norm.ref import diff_norm_partials_ref
from repro.kernels.residual_norm.residual_norm import diff_norm_partials


def diff_norm(a: jax.Array, b: jax.Array, ord: float = float("inf"),
              interpret: Optional[bool] = None) -> jax.Array:
    """‖a − b‖_ord, computed blockwise (kernel on TPU, jnp elsewhere)."""
    linf = np.isinf(ord)
    on_tpu = jax.default_backend() == "tpu"
    use_interp = False if interpret is None else interpret
    if on_tpu or use_interp:
        parts = diff_norm_partials(a, b, linf=linf, interpret=use_interp)
    else:
        parts = diff_norm_partials_ref(a, b, linf=linf)
    if linf:
        return jnp.max(parts)
    return jnp.sqrt(jnp.sum(parts))
