"""Fused diff-norm partial reduction — Pallas TPU.

The detection layer's hot path: ``r_i = ‖a − b‖_l`` (l ∈ {2, ∞}) evaluated
every outer iteration.  Unfused XLA does subtract → abs/square → reduce as
separate HBM passes at production sizes; this kernel streams both operands
through VMEM tiles once and emits per-tile partials (σ is applied by the
wrapper / the mesh reduction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, out_ref, *, linf: bool):
    # subtract in the wider of (operand dtype, f32), cast the *difference*:
    # narrow tiles (bf16) still upcast before differencing, while wide
    # inputs (the x64 host path, via interpret mode) keep update
    # differences far below the states' f32 resolution from quantising to
    # zero — the shard runtime detects on ‖x⁺ − x‖ at thresholds
    # ~1e-7 · diag⁻¹ relative to the state
    ct = jnp.promote_types(a_ref.dtype, jnp.float32)
    d = (a_ref[...].astype(ct) - b_ref[...].astype(ct)).astype(jnp.float32)
    if linf:
        out_ref[0] = jnp.max(jnp.abs(d))
    else:
        out_ref[0] = jnp.sum(d * d)


@functools.partial(jax.jit, static_argnames=("block", "linf", "interpret"))
def diff_norm_partials(
    a: jax.Array,
    b: jax.Array,
    block: int = 65536,
    linf: bool = True,
    interpret: bool = False,
):
    """Flattens inputs, returns per-block partials [nblocks] (f32)."""
    af = a.reshape(-1)
    bf = b.reshape(-1)
    n = af.shape[0]
    block = min(block, n)
    pad = (-n) % block
    if pad:
        af = jnp.pad(af, (0, pad))
        bf = jnp.pad(bf, (0, pad))  # equal padding → zero diff
    nblk = af.shape[0] // block
    return pl.pallas_call(
        functools.partial(_kernel, linf=linf),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nblk,), jnp.float32),
        interpret=interpret,
    )(af, bf)
