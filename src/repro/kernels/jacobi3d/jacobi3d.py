"""Fused 7-point convection–diffusion sweep + local residual norm — Pallas TPU.

The paper's hot loop.  GPU implementations make two passes over the grid
(relaxation sweep, then residual norm for the detection layer); on TPU we
tile the (x, y) plane with the full z-pencil resident (the paper's
decomposition keeps z local, §4.1) and produce BOTH the swept block and the
block's residual-norm partial in one HBM pass — the stencil is memory-bound,
so fusing the detection pass is a ~2× traffic saving (validated in
EXPERIMENTS.md §Perf).

Halo handling: the ghosted input stays in HBM (``memory_space=ANY``) and
each (x, y) tile loads its overlapping ``(tx+2, ty+2, bz+2)`` window with an
explicit ``pl.load`` + ``pl.ds`` (windowed DMA) — overlapping reads are not
expressible with non-overlapping ``BlockSpec`` tiling.  Outputs use regular
blocked specs.  The z-pencil (last dim, padded grid) keeps lane dimension
≥ 128 for VPU efficiency at production sizes (bz = n + 2 ≥ 514).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces (fall back gracefully off-TPU)
    from jax.experimental.pallas import tpu as pltpu

    _ANY = pltpu.ANY
except Exception:  # pragma: no cover
    _ANY = None


def _kernel(g_ref, b_ref, coef_ref, new_ref, res_ref, *, op: str, linf: bool,
            tx: int, ty: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    bz2 = g_ref.shape[2]
    # windowed load of the ghosted tile (overlapping halo window)
    g = pl.load(
        g_ref,
        (pl.ds(i * tx, tx + 2), pl.ds(j * ty, ty + 2), pl.ds(0, bz2)),
    )
    b = b_ref[...]
    c = coef_ref[...]
    diag, xm, xp, ym, yp, zm, zp = c[0], c[1], c[2], c[3], c[4], c[5], c[6]
    off = (
        xm * g[:-2, 1:-1, 1:-1]
        + xp * g[2:, 1:-1, 1:-1]
        + ym * g[1:-1, :-2, 1:-1]
        + yp * g[1:-1, 2:, 1:-1]
        + zm * g[1:-1, 1:-1, :-2]
        + zp * g[1:-1, 1:-1, 2:]
    )
    r = b - (diag * g[1:-1, 1:-1, 1:-1] + off)
    if op == "sweep":
        new_ref[...] = (b - off) / diag
    else:  # residual-only pass keeps the field unchanged
        new_ref[...] = g[1:-1, 1:-1, 1:-1]
    if linf:
        res_ref[0, 0] = jnp.max(jnp.abs(r)).astype(jnp.float32)
    else:
        res_ref[0, 0] = jnp.sum((r * r).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("tile", "op", "linf", "interpret"))
def fused_sweep_residual(
    g: jax.Array,              # [(bx+2), (by+2), (bz+2)] ghosted block
    b: jax.Array,              # [bx, by, bz]
    stencil_coefs: jax.Array,  # [7] (diag, xm, xp, ym, yp, zm, zp)
    tile: Tuple[int, int] = (8, 128),
    op: str = "sweep",
    linf: bool = True,
    interpret: bool = False,
):
    """Returns (new_block [bx,by,bz], residual partials [nx, ny])."""
    bx, by, bz = b.shape
    tx, ty = min(tile[0], bx), min(tile[1], by)
    assert bx % tx == 0 and by % ty == 0, (bx, by, tx, ty)
    nx, ny = bx // tx, by // ty
    coefs = stencil_coefs.astype(b.dtype)

    new, res = pl.pallas_call(
        functools.partial(_kernel, op=op, linf=linf, tx=tx, ty=ty),
        grid=(nx, ny),
        in_specs=[
            pl.BlockSpec(memory_space=_ANY),       # ghosted field stays in HBM
            pl.BlockSpec((tx, ty, bz), lambda i, j: (i, j, 0)),
            pl.BlockSpec(memory_space=_ANY),       # 7 scalars
        ],
        out_specs=[
            pl.BlockSpec((tx, ty, bz), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bx, by, bz), b.dtype),
            jax.ShapeDtypeStruct((nx, ny), jnp.float32),
        ],
        interpret=interpret,
    )(g, b, coefs)
    return new, res
