"""Fused 7-point convection–diffusion sweep + local residual norm — Pallas TPU.

The paper's hot loop.  GPU implementations make two passes over the grid
(relaxation sweep, then residual norm for the detection layer); on TPU we
tile the (x, y) plane with the full z-pencil resident (the paper's
decomposition keeps z local, §4.1) and produce BOTH the swept block and the
block's residual-norm partial in one HBM pass — the stencil is memory-bound,
so fusing the detection pass is a ~2× traffic saving (validated in
EXPERIMENTS.md §Perf).  Two sweep flavours are fused:

* ``fused_sweep_residual``       — Jacobi sweep (±1 halo window);
* ``fused_rbgs_sweep_residual``  — the paper's hybrid red-black GS sweep
  (±2 halo window: each tile recomputes its ring's color-0 updates locally,
  so the two-color dependency never crosses tiles and the sweep stays a
  single grid pass).

Both report the residual of the *input* state (``b − A x_in``), i.e. the
detection contribution is one sweep staler than a dedicated post-sweep pass
— exactly the trade the paper's protocol-free detection is built to absorb.

Halo handling: the ghosted input stays in HBM (``memory_space=ANY``) and
each (x, y) tile loads its overlapping ``(tx+2, ty+2, bz+2)`` window with an
explicit ``pl.load`` + ``pl.ds`` (windowed DMA) — overlapping reads are not
expressible with non-overlapping ``BlockSpec`` tiling.  Outputs use regular
blocked specs.  The z-pencil (last dim, padded grid) keeps lane dimension
≥ 128 for VPU efficiency at production sizes (bz = n + 2 ≥ 514).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces (fall back gracefully off-TPU)
    from jax.experimental.pallas import tpu as pltpu

    _ANY = pltpu.ANY
except Exception:  # pragma: no cover
    _ANY = None


def _stencil_off(w, xm, xp, ym, yp, zm, zp):
    """Off-diagonal apply over a ghosted window: (sx, sy, sz) → (sx−2, sy−2, sz−2)."""
    return (
        xm * w[:-2, 1:-1, 1:-1]
        + xp * w[2:, 1:-1, 1:-1]
        + ym * w[1:-1, :-2, 1:-1]
        + yp * w[1:-1, 2:, 1:-1]
        + zm * w[1:-1, 1:-1, :-2]
        + zp * w[1:-1, 1:-1, 2:]
    )


def _kernel(g_ref, b_ref, coef_ref, new_ref, res_ref, *, op: str, linf: bool,
            tx: int, ty: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    bz2 = g_ref.shape[2]
    # windowed load of the ghosted tile (overlapping halo window)
    g = pl.load(
        g_ref,
        (pl.ds(i * tx, tx + 2), pl.ds(j * ty, ty + 2), pl.ds(0, bz2)),
    )
    b = b_ref[...]
    c = coef_ref[...]
    diag, xm, xp, ym, yp, zm, zp = c[0], c[1], c[2], c[3], c[4], c[5], c[6]
    off = _stencil_off(g, xm, xp, ym, yp, zm, zp)
    r = b - (diag * g[1:-1, 1:-1, 1:-1] + off)
    if op == "sweep":
        new_ref[...] = (b - off) / diag
    else:  # residual-only pass keeps the field unchanged
        new_ref[...] = g[1:-1, 1:-1, 1:-1]
    if linf:
        res_ref[0, 0] = jnp.max(jnp.abs(r)).astype(jnp.float32)
    else:
        res_ref[0, 0] = jnp.sum((r * r).astype(jnp.float32))


def _rbgs_kernel(g_ref, b_ref, coef_ref, oxy_ref, new_ref, res_ref, *,
                 linf: bool, tx: int, ty: int, bx: int, by: int):
    """Single-pass hybrid red-black GS sweep fused with the pre-sweep residual.

    Input is the twice-padded ghosted block (±2 halo in x/y so the tile can
    redo its ring's color-0 updates instead of waiting on neighbour tiles —
    cross-tile color-1 dependencies become local recompute) and the ±1
    zero-padded rhs.  The residual shares the first off-diagonal apply, so
    the whole hybrid sweep + detection contribution is one HBM pass."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    bz2 = g_ref.shape[2]
    bz = bz2 - 2
    w = pl.load(
        g_ref,
        (pl.ds(i * tx, tx + 4), pl.ds(j * ty, ty + 4), pl.ds(0, bz2)),
    )
    bw = pl.load(
        b_ref,
        (pl.ds(i * tx, tx + 2), pl.ds(j * ty, ty + 2), pl.ds(0, bz)),
    )
    c = coef_ref[...]
    diag, xm, xp, ym, yp, zm, zp = c[0], c[1], c[2], c[3], c[4], c[5], c[6]
    off_w = _stencil_off(w, xm, xp, ym, yp, zm, zp)    # (tx+2, ty+2, bz)
    x_w = w[1:-1, 1:-1, 1:-1]                          # matching centres
    # block coords of window positions (−1 … t+0/+1) → checkerboard + realness
    shp = (tx + 2, ty + 2, bz)
    gx = jax.lax.broadcasted_iota(jnp.int32, shp, 0) + i * tx - 1
    gy = jax.lax.broadcasted_iota(jnp.int32, shp, 1) + j * ty - 1
    gz = jax.lax.broadcasted_iota(jnp.int32, shp, 2)
    parity = jnp.mod(gx + gy + gz + oxy_ref[0], 2)
    real = (gx >= 0) & (gx < bx) & (gy >= 0) & (gy < by)
    # color 0 over tile + ring (ghost ring stays frozen via the real mask)
    upd0 = jnp.where((parity == 0) & real, (bw - off_w) / diag, x_w)
    w1 = w.at[1:-1, 1:-1, 1:-1].set(upd0)
    # color 1 on the tile proper, seeing same-sweep color-0 values
    off1 = _stencil_off(w1, xm, xp, ym, yp, zm, zp)[1:-1, 1:-1, :]
    b_t = bw[1:-1, 1:-1, :]
    new1 = (b_t - off1) / diag
    new_ref[...] = jnp.where(parity[1:-1, 1:-1, :] == 1, new1,
                             upd0[1:-1, 1:-1, :])
    r = b_t - (diag * x_w[1:-1, 1:-1, :] + off_w[1:-1, 1:-1, :])
    if linf:
        res_ref[0, 0] = jnp.max(jnp.abs(r)).astype(jnp.float32)
    else:
        res_ref[0, 0] = jnp.sum((r * r).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("tile", "linf", "interpret"))
def fused_rbgs_sweep_residual(
    g2: jax.Array,             # [(bx+4), (by+4), (bz+2)] twice-padded block
    b2: jax.Array,             # [bx+2, by+2, bz] rhs, zero-padded ±1 in x/y
    stencil_coefs: jax.Array,  # [7] (diag, xm, xp, ym, yp, zm, zp)
    oxy: jax.Array,            # i32 scalar: ox + oy (global checkerboard phase)
    tile: Tuple[int, int] = (8, 128),
    linf: bool = True,
    interpret: bool = False,
):
    """Hybrid RB-GS sweep + pre-sweep residual partials in one grid pass.

    Returns ``(new_block [bx,by,bz], residual partials [nx, ny])`` where the
    partials reduce ``b − A x_in`` (the *input* state's residual — the free
    by-product of the relaxation)."""
    bx, by = b2.shape[0] - 2, b2.shape[1] - 2
    bz = b2.shape[2]
    tx, ty = min(tile[0], bx), min(tile[1], by)
    assert bx % tx == 0 and by % ty == 0, (bx, by, tx, ty)
    nx, ny = bx // tx, by // ty
    coefs = stencil_coefs.astype(b2.dtype)
    oxy_arr = jnp.asarray(oxy, jnp.int32).reshape((1,))

    new, res = pl.pallas_call(
        functools.partial(_rbgs_kernel, linf=linf, tx=tx, ty=ty, bx=bx, by=by),
        grid=(nx, ny),
        in_specs=[
            pl.BlockSpec(memory_space=_ANY),       # ghosted field stays in HBM
            pl.BlockSpec(memory_space=_ANY),       # padded rhs (windowed load)
            pl.BlockSpec(memory_space=_ANY),       # 7 scalars
            pl.BlockSpec(memory_space=_ANY),       # checkerboard phase
        ],
        out_specs=[
            pl.BlockSpec((tx, ty, bz), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bx, by, bz), b2.dtype),
            jax.ShapeDtypeStruct((nx, ny), jnp.float32),
        ],
        interpret=interpret,
    )(g2, b2, coefs, oxy_arr)
    return new, res


@functools.partial(jax.jit, static_argnames=("tile", "op", "linf", "interpret"))
def fused_sweep_residual(
    g: jax.Array,              # [(bx+2), (by+2), (bz+2)] ghosted block
    b: jax.Array,              # [bx, by, bz]
    stencil_coefs: jax.Array,  # [7] (diag, xm, xp, ym, yp, zm, zp)
    tile: Tuple[int, int] = (8, 128),
    op: str = "sweep",
    linf: bool = True,
    interpret: bool = False,
):
    """Returns (new_block [bx,by,bz], residual partials [nx, ny])."""
    bx, by, bz = b.shape
    tx, ty = min(tile[0], bx), min(tile[1], by)
    assert bx % tx == 0 and by % ty == 0, (bx, by, tx, ty)
    nx, ny = bx // tx, by // ty
    coefs = stencil_coefs.astype(b.dtype)

    new, res = pl.pallas_call(
        functools.partial(_kernel, op=op, linf=linf, tx=tx, ty=ty),
        grid=(nx, ny),
        in_specs=[
            pl.BlockSpec(memory_space=_ANY),       # ghosted field stays in HBM
            pl.BlockSpec((tx, ty, bz), lambda i, j: (i, j, 0)),
            pl.BlockSpec(memory_space=_ANY),       # 7 scalars
        ],
        out_specs=[
            pl.BlockSpec((tx, ty, bz), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bx, by, bz), b.dtype),
            jax.ShapeDtypeStruct((nx, ny), jnp.float32),
        ],
        interpret=interpret,
    )(g, b, coefs)
    return new, res
