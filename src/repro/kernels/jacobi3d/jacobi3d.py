"""Fused 7-point convection–diffusion sweep + local residual norm — Pallas TPU.

The paper's hot loop.  GPU implementations make two passes over the grid
(relaxation sweep, then residual norm for the detection layer); on TPU we
tile the (x, y) plane with the full z-pencil resident (the paper's
decomposition keeps z local, §4.1) and produce BOTH the swept block and the
block's residual-norm partial in one HBM pass — the stencil is memory-bound,
so fusing the detection pass is a ~2× traffic saving (validated in
EXPERIMENTS.md §Perf).  Two sweep flavours are fused:

* ``fused_sweep_residual``       — Jacobi sweep (±1 halo window);
* ``fused_rbgs_sweep_residual``  — the paper's hybrid red-black GS sweep
  (±2 halo window: each tile recomputes its ring's color-0 updates locally,
  so the two-color dependency never crosses tiles and the sweep stays a
  single grid pass).

Both report the residual of the *input* state (``b − A x_in``), i.e. the
detection contribution is one sweep staler than a dedicated post-sweep pass
— exactly the trade the paper's protocol-free detection is built to absorb.

Halo handling: the ghosted input stays in HBM (``memory_space=ANY``) and
each (x, y) tile loads its overlapping ``(tx+2, ty+2, bz+2)`` window with an
explicit ``pl.load`` + ``pl.ds`` (windowed DMA) — overlapping reads are not
expressible with non-overlapping ``BlockSpec`` tiling.  Outputs use regular
blocked specs.  The z-pencil (last dim, padded grid) keeps lane dimension
≥ 128 for VPU efficiency at production sizes (bz = n + 2 ≥ 514).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces (fall back gracefully off-TPU)
    from jax.experimental.pallas import tpu as pltpu

    _ANY = pltpu.ANY
except Exception:  # pragma: no cover
    _ANY = None


def _stencil_off(w, xm, xp, ym, yp, zm, zp):
    """Off-diagonal apply over a ghosted window: (sx, sy, sz) → (sx−2, sy−2, sz−2)."""
    return (
        xm * w[:-2, 1:-1, 1:-1]
        + xp * w[2:, 1:-1, 1:-1]
        + ym * w[1:-1, :-2, 1:-1]
        + yp * w[1:-1, 2:, 1:-1]
        + zm * w[1:-1, 1:-1, :-2]
        + zp * w[1:-1, 1:-1, 2:]
    )


def _kernel(g_ref, b_ref, coef_ref, new_ref, res_ref, *, op: str, linf: bool,
            tx: int, ty: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    bz2 = g_ref.shape[2]
    # windowed load of the ghosted tile (overlapping halo window)
    g = pl.load(
        g_ref,
        (pl.ds(i * tx, tx + 2), pl.ds(j * ty, ty + 2), pl.ds(0, bz2)),
    )
    b = b_ref[...]
    c = coef_ref[...]
    diag, xm, xp, ym, yp, zm, zp = c[0], c[1], c[2], c[3], c[4], c[5], c[6]
    off = _stencil_off(g, xm, xp, ym, yp, zm, zp)
    r = b - (diag * g[1:-1, 1:-1, 1:-1] + off)
    if op == "sweep":
        new_ref[...] = (b - off) / diag
    else:  # residual-only pass keeps the field unchanged
        new_ref[...] = g[1:-1, 1:-1, 1:-1]
    if linf:
        res_ref[0, 0] = jnp.max(jnp.abs(r)).astype(jnp.float32)
    else:
        res_ref[0, 0] = jnp.sum((r * r).astype(jnp.float32))


def _rbgs_kernel(g_ref, b_ref, coef_ref, oxy_ref, new_ref, res_ref, *,
                 linf: bool, tx: int, ty: int, bx: int, by: int):
    """Single-pass hybrid red-black GS sweep fused with the pre-sweep residual.

    Input is the twice-padded ghosted block (±2 halo in x/y so the tile can
    redo its ring's color-0 updates instead of waiting on neighbour tiles —
    cross-tile color-1 dependencies become local recompute) and the ±1
    zero-padded rhs.  The residual shares the first off-diagonal apply, so
    the whole hybrid sweep + detection contribution is one HBM pass."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    bz2 = g_ref.shape[2]
    bz = bz2 - 2
    w = pl.load(
        g_ref,
        (pl.ds(i * tx, tx + 4), pl.ds(j * ty, ty + 4), pl.ds(0, bz2)),
    )
    bw = pl.load(
        b_ref,
        (pl.ds(i * tx, tx + 2), pl.ds(j * ty, ty + 2), pl.ds(0, bz)),
    )
    c = coef_ref[...]
    diag, xm, xp, ym, yp, zm, zp = c[0], c[1], c[2], c[3], c[4], c[5], c[6]
    off_w = _stencil_off(w, xm, xp, ym, yp, zm, zp)    # (tx+2, ty+2, bz)
    x_w = w[1:-1, 1:-1, 1:-1]                          # matching centres
    # block coords of window positions (−1 … t+0/+1) → checkerboard + realness
    shp = (tx + 2, ty + 2, bz)
    gx = jax.lax.broadcasted_iota(jnp.int32, shp, 0) + i * tx - 1
    gy = jax.lax.broadcasted_iota(jnp.int32, shp, 1) + j * ty - 1
    gz = jax.lax.broadcasted_iota(jnp.int32, shp, 2)
    parity = jnp.mod(gx + gy + gz + oxy_ref[0], 2)
    real = (gx >= 0) & (gx < bx) & (gy >= 0) & (gy < by)
    # color 0 over tile + ring (ghost ring stays frozen via the real mask)
    upd0 = jnp.where((parity == 0) & real, (bw - off_w) / diag, x_w)
    w1 = w.at[1:-1, 1:-1, 1:-1].set(upd0)
    # color 1 on the tile proper, seeing same-sweep color-0 values
    off1 = _stencil_off(w1, xm, xp, ym, yp, zm, zp)[1:-1, 1:-1, :]
    b_t = bw[1:-1, 1:-1, :]
    new1 = (b_t - off1) / diag
    new_ref[...] = jnp.where(parity[1:-1, 1:-1, :] == 1, new1,
                             upd0[1:-1, 1:-1, :])
    r = b_t - (diag * x_w[1:-1, 1:-1, :] + off_w[1:-1, 1:-1, :])
    if linf:
        res_ref[0, 0] = jnp.max(jnp.abs(r)).astype(jnp.float32)
    else:
        res_ref[0, 0] = jnp.sum((r * r).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("tile", "linf", "interpret"))
def fused_rbgs_sweep_residual(
    g2: jax.Array,             # [(bx+4), (by+4), (bz+2)] twice-padded block
    b2: jax.Array,             # [bx+2, by+2, bz] rhs, zero-padded ±1 in x/y
    stencil_coefs: jax.Array,  # [7] (diag, xm, xp, ym, yp, zm, zp)
    oxy: jax.Array,            # i32 scalar: ox + oy (global checkerboard phase)
    tile: Tuple[int, int] = (8, 128),
    linf: bool = True,
    interpret: bool = False,
):
    """Hybrid RB-GS sweep + pre-sweep residual partials in one grid pass.

    Returns ``(new_block [bx,by,bz], residual partials [nx, ny])`` where the
    partials reduce ``b − A x_in`` (the *input* state's residual — the free
    by-product of the relaxation)."""
    bx, by = b2.shape[0] - 2, b2.shape[1] - 2
    bz = b2.shape[2]
    tx, ty = min(tile[0], bx), min(tile[1], by)
    assert bx % tx == 0 and by % ty == 0, (bx, by, tx, ty)
    nx, ny = bx // tx, by // ty
    coefs = stencil_coefs.astype(b2.dtype)
    oxy_arr = jnp.asarray(oxy, jnp.int32).reshape((1,))

    new, res = pl.pallas_call(
        functools.partial(_rbgs_kernel, linf=linf, tx=tx, ty=ty, bx=bx, by=by),
        grid=(nx, ny),
        in_specs=[
            pl.BlockSpec(memory_space=_ANY),       # ghosted field stays in HBM
            pl.BlockSpec(memory_space=_ANY),       # padded rhs (windowed load)
            pl.BlockSpec(memory_space=_ANY),       # 7 scalars
            pl.BlockSpec(memory_space=_ANY),       # checkerboard phase
        ],
        out_specs=[
            pl.BlockSpec((tx, ty, bz), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bx, by, bz), b2.dtype),
            jax.ShapeDtypeStruct((nx, ny), jnp.float32),
        ],
        interpret=interpret,
    )(g2, b2, coefs, oxy_arr)
    return new, res


# ---------------------------------------------------------------------------
# Halo-consuming flavours: explicit face buffers for all partitioned faces
# ---------------------------------------------------------------------------
#
# The multi-axis shard runtime exchanges up to six face planes (x/y/z may
# all be partitioned) and hands them to the kernel as-is — no host-side
# ghost assembly, no assumption that y/z are contiguous.  Each tile builds
# its ghosted window in-register: the core tile plus thin clamped loads of
# the neighbouring rows/columns of the *unghosted* block, with the halo
# plane substituted wherever the window crosses the block boundary.
# Diagonal window corners stay zero for the ±1 window (the 7-point star
# never reads them); the ±2 RB-GS window picks its in-block corner cells
# explicitly (they feed the ring's colour-0 recompute on interior tiles).


def _pick_row(x_ref, hxm_ref, hxp_ref, q, y0, ny, bx, bz, dtype):
    """(1, ny, bz) window row at global row ``q``, cols ``[y0, y0+ny)``:
    an in-block row of x, the x∓ halo plane at q == -1/bx, zeros beyond."""
    loaded = pl.load(x_ref, (pl.ds(jnp.clip(q, 0, bx - 1), 1),
                             pl.ds(y0, ny), pl.ds(0, bz)))
    hm = pl.load(hxm_ref, (pl.ds(y0, ny), pl.ds(0, bz)))[None]
    hp = pl.load(hxp_ref, (pl.ds(y0, ny), pl.ds(0, bz)))[None]
    v = jnp.where(q == -1, hm.astype(dtype),
                  jnp.where(q == bx, hp.astype(dtype), loaded))
    return jnp.where((q < -1) | (q > bx), jnp.zeros_like(v), v)


def _pick_col(x_ref, hym_ref, hyp_ref, q, x0, nx, by, bz, dtype):
    """(nx, 1, bz) window column at global col ``q``, rows ``[x0, x0+nx)``."""
    loaded = pl.load(x_ref, (pl.ds(x0, nx),
                             pl.ds(jnp.clip(q, 0, by - 1), 1), pl.ds(0, bz)))
    hm = pl.load(hym_ref, (pl.ds(x0, nx), pl.ds(0, bz)))[:, None]
    hp = pl.load(hyp_ref, (pl.ds(x0, nx), pl.ds(0, bz)))[:, None]
    v = jnp.where(q == -1, hm.astype(dtype),
                  jnp.where(q == by, hp.astype(dtype), loaded))
    return jnp.where((q < -1) | (q > by), jnp.zeros_like(v), v)


def _pick_cell(x_ref, halo_refs, qx, qy, bx, by, bz, dtype):
    """(1, 1, bz) window cell at global (qx, qy): in-block x, the face halo
    when exactly one coordinate is a ghost, zero otherwise (both-ghost
    diagonal cells are arithmetically dead in both kernels)."""
    hxm_ref, hxp_ref, hym_ref, hyp_ref = halo_refs
    loaded = pl.load(x_ref, (pl.ds(jnp.clip(qx, 0, bx - 1), 1),
                             pl.ds(jnp.clip(qy, 0, by - 1), 1), pl.ds(0, bz)))
    hxm = pl.load(hxm_ref, (pl.ds(jnp.clip(qy, 0, by - 1), 1),
                            pl.ds(0, bz)))[None]
    hxp = pl.load(hxp_ref, (pl.ds(jnp.clip(qy, 0, by - 1), 1),
                            pl.ds(0, bz)))[None]
    hym = pl.load(hym_ref, (pl.ds(jnp.clip(qx, 0, bx - 1), 1),
                            pl.ds(0, bz)))[:, None]
    hyp = pl.load(hyp_ref, (pl.ds(jnp.clip(qx, 0, bx - 1), 1),
                            pl.ds(0, bz)))[:, None]
    in_x = (qx >= 0) & (qx < bx)
    in_y = (qy >= 0) & (qy < by)
    v = jnp.where(in_x & in_y, loaded, jnp.zeros_like(loaded))
    v = jnp.where((qx == -1) & in_y, hxm.astype(dtype), v)
    v = jnp.where((qx == bx) & in_y, hxp.astype(dtype), v)
    v = jnp.where((qy == -1) & in_x, hym.astype(dtype), v)
    v = jnp.where((qy == by) & in_x, hyp.astype(dtype), v)
    return v


def _pick_zplane(gz_ref, qx, nx, qy, ny, bx, by):
    """(nx, ny) window of a z halo plane at rows/cols from (qx, qy); zeros
    where the window leaves the block (ghost rows' z-corners are dead)."""
    v = pl.load(gz_ref, (pl.ds(jnp.clip(qx, 0, bx - nx), nx),
                         pl.ds(jnp.clip(qy, 0, by - ny), ny)))
    ok = (qx >= 0) & (qx + nx <= bx) & (qy >= 0) & (qy + ny <= by)
    return jnp.where(ok, v, jnp.zeros_like(v))


def _halo_window(x_ref, halo_refs, i, j, tx, ty, bx, by, bz, pad, dtype):
    """Assemble the (tx+2·pad, ty+2·pad, bz+2) ghosted window of tile
    (i, j) from the unghosted block + six face planes.  ``pad=1`` is the
    Jacobi ±1 window; ``pad=2`` the RB-GS ±2 window (its outermost frame
    carries real in-block values where they exist — interior tiles consume
    them through the ring's colour-0 recompute — and dead zeros/halos at
    the block edge, which the kernel's ``real`` mask freezes)."""
    hxm, hxp, hym, hyp, hzm, hzp = halo_refs
    x0, y0 = i * tx, j * ty

    def zrow(qx, qy0, ny):
        zm = _pick_zplane(hzm, qx, 1, qy0, ny, bx, by)[:, :, None]
        zp = _pick_zplane(hzp, qx, 1, qy0, ny, bx, by)[:, :, None]
        return zm.astype(dtype), zp.astype(dtype)

    def row_slab(qx):
        """(1, ty + 2·pad, bz + 2) full-width window row at global row qx."""
        core = _pick_row(x_ref, hxm, hxp, qx, y0, ty, bx, bz, dtype)
        zm, zp = zrow(qx, y0, ty)
        parts = [jnp.concatenate([zm, core, zp], axis=2)]
        for dq in range(1, pad + 1):
            for side, qy in ((0, y0 - dq), (1, y0 + ty + dq - 1)):
                cell = _pick_cell(x_ref, (hxm, hxp, hym, hyp), qx, qy,
                                  bx, by, bz, dtype)
                czm = _pick_zplane(hzm, qx, 1, qy, 1, bx, by)[:, :, None]
                czp = _pick_zplane(hzp, qx, 1, qy, 1, bx, by)[:, :, None]
                cz = jnp.concatenate([czm.astype(dtype), cell,
                                      czp.astype(dtype)], axis=2)
                parts = [cz] + parts if side == 0 else parts + [cz]
        return jnp.concatenate(parts, axis=1)

    # middle slab: the core tile, y-extended by pad picked columns per side
    core = pl.load(x_ref, (pl.ds(x0, tx), pl.ds(y0, ty), pl.ds(0, bz)))
    zm = _pick_zplane(hzm, x0, tx, y0, ty, bx, by)[:, :, None].astype(dtype)
    zp = _pick_zplane(hzp, x0, tx, y0, ty, bx, by)[:, :, None].astype(dtype)
    mid_parts = [jnp.concatenate([zm, core, zp], axis=2)]
    for dq in range(1, pad + 1):
        for side, qy in ((0, y0 - dq), (1, y0 + ty + dq - 1)):
            col = _pick_col(x_ref, hym, hyp, qy, x0, tx, by, bz, dtype)
            czm = _pick_zplane(hzm, x0, tx, qy, 1, bx, by)[:, :, None]
            czp = _pick_zplane(hzp, x0, tx, qy, 1, bx, by)[:, :, None]
            cz = jnp.concatenate([czm.astype(dtype), col,
                                  czp.astype(dtype)], axis=2)
            mid_parts = [cz] + mid_parts if side == 0 else mid_parts + [cz]
    mid = jnp.concatenate(mid_parts, axis=1)

    slabs = [mid]
    for dq in range(1, pad + 1):
        slabs = [row_slab(x0 - dq)] + slabs + [row_slab(x0 + tx + dq - 1)]
    return jnp.concatenate(slabs, axis=0)


def _halo_kernel(x_ref, hxm, hxp, hym, hyp, hzm, hzp, b_ref, coef_ref,
                 new_ref, res_ref, *, op: str, linf: bool, tx: int, ty: int,
                 bx: int, by: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    bz = x_ref.shape[2]
    g = _halo_window(x_ref, (hxm, hxp, hym, hyp, hzm, hzp), i, j, tx, ty,
                     bx, by, bz, pad=1, dtype=x_ref.dtype)
    b = b_ref[...]
    c = coef_ref[...]
    diag, xm, xp, ym, yp, zm, zp = c[0], c[1], c[2], c[3], c[4], c[5], c[6]
    off = _stencil_off(g, xm, xp, ym, yp, zm, zp)
    r = b - (diag * g[1:-1, 1:-1, 1:-1] + off)
    if op == "sweep":
        new_ref[...] = (b - off) / diag
    else:
        new_ref[...] = g[1:-1, 1:-1, 1:-1]
    if linf:
        res_ref[0, 0] = jnp.max(jnp.abs(r)).astype(jnp.float32)
    else:
        res_ref[0, 0] = jnp.sum((r * r).astype(jnp.float32))


def _rbgs_halo_kernel(x_ref, hxm, hxp, hym, hyp, hzm, hzp, b2_ref, coef_ref,
                      oxyz_ref, new_ref, res_ref, *, linf: bool, tx: int,
                      ty: int, bx: int, by: int):
    """The ±2-window hybrid RB-GS sweep over an unghosted block + six face
    buffers — the same single-pass recompute scheme as ``_rbgs_kernel``,
    with the window assembled in-register instead of pre-ghosted."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    bz = x_ref.shape[2]
    w = _halo_window(x_ref, (hxm, hxp, hym, hyp, hzm, hzp), i, j, tx, ty,
                     bx, by, bz, pad=2, dtype=x_ref.dtype)
    bw = pl.load(b2_ref, (pl.ds(i * tx, tx + 2), pl.ds(j * ty, ty + 2),
                          pl.ds(0, bz)))
    c = coef_ref[...]
    diag, xm, xp, ym, yp, zm, zp = c[0], c[1], c[2], c[3], c[4], c[5], c[6]
    off_w = _stencil_off(w, xm, xp, ym, yp, zm, zp)    # (tx+2, ty+2, bz)
    x_w = w[1:-1, 1:-1, 1:-1]
    shp = (tx + 2, ty + 2, bz)
    gx = jax.lax.broadcasted_iota(jnp.int32, shp, 0) + i * tx - 1
    gy = jax.lax.broadcasted_iota(jnp.int32, shp, 1) + j * ty - 1
    gz = jax.lax.broadcasted_iota(jnp.int32, shp, 2)
    parity = jnp.mod(gx + gy + gz + oxyz_ref[0], 2)
    real = (gx >= 0) & (gx < bx) & (gy >= 0) & (gy < by)
    upd0 = jnp.where((parity == 0) & real, (bw - off_w) / diag, x_w)
    w1 = w.at[1:-1, 1:-1, 1:-1].set(upd0)
    off1 = _stencil_off(w1, xm, xp, ym, yp, zm, zp)[1:-1, 1:-1, :]
    b_t = bw[1:-1, 1:-1, :]
    new1 = (b_t - off1) / diag
    new_ref[...] = jnp.where(parity[1:-1, 1:-1, :] == 1, new1,
                             upd0[1:-1, 1:-1, :])
    r = b_t - (diag * x_w[1:-1, 1:-1, :] + off_w[1:-1, 1:-1, :])
    if linf:
        res_ref[0, 0] = jnp.max(jnp.abs(r)).astype(jnp.float32)
    else:
        res_ref[0, 0] = jnp.sum((r * r).astype(jnp.float32))


def _halo6(halos, b_like):
    """Normalise the six face planes to the block dtype (zero planes for
    unpartitioned/boundary faces are the caller's contract)."""
    gxm, gxp, gym, gyp, gzm, gzp = halos
    return tuple(h.astype(b_like.dtype) for h in
                 (gxm, gxp, gym, gyp, gzm, gzp))


@functools.partial(jax.jit, static_argnames=("tile", "op", "linf", "interpret"))
def fused_sweep_residual_halo(
    x: jax.Array,              # [bx, by, bz] unghosted block
    halos,                     # 6 face planes (gxm, gxp, gym, gyp, gzm, gzp)
    b: jax.Array,              # [bx, by, bz]
    stencil_coefs: jax.Array,  # [7] (diag, xm, xp, ym, yp, zm, zp)
    tile: Tuple[int, int] = (8, 128),
    op: str = "sweep",
    linf: bool = True,
    interpret: bool = False,
):
    """Jacobi sweep + input-state residual partials from an unghosted block
    and explicit halo buffers for every partitioned face — no host-side
    ghost assembly (one fewer HBM materialisation of the (bx+2)³ array).

    Returns ``(new_block [bx,by,bz], residual partials [nx, ny])``."""
    bx, by, bz = b.shape
    tx, ty = min(tile[0], bx), min(tile[1], by)
    assert bx % tx == 0 and by % ty == 0, (bx, by, tx, ty)
    nx, ny = bx // tx, by // ty
    coefs = stencil_coefs.astype(b.dtype)
    faces = _halo6(halos, b)

    new, res = pl.pallas_call(
        functools.partial(_halo_kernel, op=op, linf=linf, tx=tx, ty=ty,
                          bx=bx, by=by),
        grid=(nx, ny),
        in_specs=[pl.BlockSpec(memory_space=_ANY)] * 7 + [
            pl.BlockSpec((tx, ty, bz), lambda i, j: (i, j, 0)),
            pl.BlockSpec(memory_space=_ANY),       # 7 scalars
        ],
        out_specs=[
            pl.BlockSpec((tx, ty, bz), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bx, by, bz), b.dtype),
            jax.ShapeDtypeStruct((nx, ny), jnp.float32),
        ],
        interpret=interpret,
    )(x, *faces, b, coefs)
    return new, res


@functools.partial(jax.jit, static_argnames=("tile", "linf", "interpret"))
def fused_rbgs_sweep_residual_halo(
    x: jax.Array,              # [bx, by, bz] unghosted block
    halos,                     # 6 face planes (gxm, gxp, gym, gyp, gzm, gzp)
    b: jax.Array,              # [bx, by, bz]
    stencil_coefs: jax.Array,  # [7] (diag, xm, xp, ym, yp, zm, zp)
    oxyz: jax.Array,           # i32 scalar: ox + oy + oz (checkerboard phase)
    tile: Tuple[int, int] = (8, 128),
    linf: bool = True,
    interpret: bool = False,
):
    """Hybrid RB-GS sweep + pre-sweep residual partials from an unghosted
    block and explicit halo buffers (the halo-consuming twin of
    ``fused_rbgs_sweep_residual``)."""
    bx, by, bz = b.shape
    tx, ty = min(tile[0], bx), min(tile[1], by)
    assert bx % tx == 0 and by % ty == 0, (bx, by, tx, ty)
    nx, ny = bx // tx, by // ty
    coefs = stencil_coefs.astype(b.dtype)
    faces = _halo6(halos, b)
    b2 = jnp.pad(b, ((1, 1), (1, 1), (0, 0)))
    oxyz_arr = jnp.asarray(oxyz, jnp.int32).reshape((1,))

    new, res = pl.pallas_call(
        functools.partial(_rbgs_halo_kernel, linf=linf, tx=tx, ty=ty,
                          bx=bx, by=by),
        grid=(nx, ny),
        in_specs=[pl.BlockSpec(memory_space=_ANY)] * 10,
        out_specs=[
            pl.BlockSpec((tx, ty, bz), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bx, by, bz), b.dtype),
            jax.ShapeDtypeStruct((nx, ny), jnp.float32),
        ],
        interpret=interpret,
    )(x, *faces, b2, coefs, oxyz_arr)
    return new, res


@functools.partial(jax.jit, static_argnames=("tile", "op", "linf", "interpret"))
def fused_sweep_residual(
    g: jax.Array,              # [(bx+2), (by+2), (bz+2)] ghosted block
    b: jax.Array,              # [bx, by, bz]
    stencil_coefs: jax.Array,  # [7] (diag, xm, xp, ym, yp, zm, zp)
    tile: Tuple[int, int] = (8, 128),
    op: str = "sweep",
    linf: bool = True,
    interpret: bool = False,
):
    """Returns (new_block [bx,by,bz], residual partials [nx, ny])."""
    bx, by, bz = b.shape
    tx, ty = min(tile[0], bx), min(tile[1], by)
    assert bx % tx == 0 and by % ty == 0, (bx, by, tx, ty)
    nx, ny = bx // tx, by // ty
    coefs = stencil_coefs.astype(b.dtype)

    new, res = pl.pallas_call(
        functools.partial(_kernel, op=op, linf=linf, tx=tx, ty=ty),
        grid=(nx, ny),
        in_specs=[
            pl.BlockSpec(memory_space=_ANY),       # ghosted field stays in HBM
            pl.BlockSpec((tx, ty, bz), lambda i, j: (i, j, 0)),
            pl.BlockSpec(memory_space=_ANY),       # 7 scalars
        ],
        out_specs=[
            pl.BlockSpec((tx, ty, bz), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bx, by, bz), b.dtype),
            jax.ShapeDtypeStruct((nx, ny), jnp.float32),
        ],
        interpret=interpret,
    )(g, b, coefs)
    return new, res
