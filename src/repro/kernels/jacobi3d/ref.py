"""Pure-jnp oracle for the jacobi3d kernel."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def residual_partials(r, tile: Tuple[int, int] = (8, 128), linf: bool = True):
    """Per-(x,y)-tile residual partials of a residual block, mirroring the
    kernel's [nx, ny] output layout."""
    bx, by, _ = r.shape
    tx, ty = min(tile[0], bx), min(tile[1], by)
    nx, ny = bx // tx, by // ty
    rt = r.reshape(nx, tx, ny, ty, -1)
    if linf:
        return jnp.max(jnp.abs(rt), axis=(1, 3, 4)).astype(jnp.float32)
    return jnp.sum((rt * rt).astype(jnp.float32), axis=(1, 3, 4))


def ghosted6_ref(x, halos):
    """(bx+2, by+2, bz+2) ghosted block from six face planes (the
    halo-consuming kernels' window semantics, assembled whole)."""
    gxm, gxp, gym, gyp, gzm, gzp = halos
    bx, by, bz = x.shape
    g = jnp.zeros((bx + 2, by + 2, bz + 2), x.dtype)
    g = g.at[1:-1, 1:-1, 1:-1].set(x)
    g = g.at[0, 1:-1, 1:-1].set(gxm)
    g = g.at[-1, 1:-1, 1:-1].set(gxp)
    g = g.at[1:-1, 0, 1:-1].set(gym)
    g = g.at[1:-1, -1, 1:-1].set(gyp)
    g = g.at[1:-1, 1:-1, 0].set(gzm)
    g = g.at[1:-1, 1:-1, -1].set(gzp)
    return g


def fused_sweep_residual_halo_ref(x, halos, b, coefs,
                                  tile: Tuple[int, int] = (8, 128),
                                  op: str = "sweep", linf: bool = True):
    """Oracle for ``fused_sweep_residual_halo`` (assemble-then-sweep)."""
    return fused_sweep_residual_ref(ghosted6_ref(x, halos), b, coefs,
                                    tile=tile, op=op, linf=linf)


def fused_sweep_residual_ref(g, b, coefs, tile: Tuple[int, int] = (8, 128),
                             op: str = "sweep", linf: bool = True):
    diag, xm, xp, ym, yp, zm, zp = [coefs[i] for i in range(7)]
    off = (
        xm * g[:-2, 1:-1, 1:-1]
        + xp * g[2:, 1:-1, 1:-1]
        + ym * g[1:-1, :-2, 1:-1]
        + yp * g[1:-1, 2:, 1:-1]
        + zm * g[1:-1, 1:-1, :-2]
        + zp * g[1:-1, 1:-1, 2:]
    )
    r = b - (diag * g[1:-1, 1:-1, 1:-1] + off)
    new = (b - off) / diag if op == "sweep" else g[1:-1, 1:-1, 1:-1]
    return new, residual_partials(r, tile=tile, linf=linf)
