"""jit'd dispatch wrapper for the jacobi3d kernel.

``sweep``/``residual_contribution`` are the entry points used by
``solvers.fixed_point`` when ``SolverConfig.use_kernel`` is set; they fall
back to the pure-jnp path (ref) off-TPU so the distributed driver runs
everywhere.  ``interpret`` can be forced for validation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.jacobi3d.jacobi3d import fused_sweep_residual
from repro.kernels.jacobi3d.ref import fused_sweep_residual_ref
from repro.solvers.convdiff import Stencil


def _coefs(st: Stencil) -> jnp.ndarray:
    return jnp.asarray([st.diag, st.xm, st.xp, st.ym, st.yp, st.zm, st.zp])


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sweep_and_residual(
    st: Stencil,
    g: jax.Array,
    b: jax.Array,
    tile: Tuple[int, int] = (8, 128),
    linf: bool = True,
    interpret: Optional[bool] = None,
):
    """Fused sweep + residual partials; returns (new_block, partials)."""
    use_interp = (not _on_tpu()) if interpret is None else interpret
    if use_interp and not _on_tpu():
        # off-TPU default: the jnp oracle (identical math, XLA-fused)
        return fused_sweep_residual_ref(g, b, _coefs(st), tile=tile, linf=linf)
    return fused_sweep_residual(g, b, _coefs(st), tile=tile, op="sweep",
                                linf=linf, interpret=use_interp)


def sweep(st: Stencil, g: jax.Array, b: jax.Array, sweep: str = "jacobi",
          ox=0, oy=0, tile: Tuple[int, int] = (8, 128)):
    """Sweep-only entry used by solvers.fixed_point (Jacobi flavour)."""
    new, _ = sweep_and_residual(st, g, b, tile=tile)
    return new


def residual_contribution(st: Stencil, g: jax.Array, b: jax.Array,
                          ord: float = float("inf"),
                          tile: Tuple[int, int] = (8, 128)):
    linf = np.isinf(ord)
    if _on_tpu():
        _, parts = fused_sweep_residual(g, b, _coefs(st), tile=tile,
                                        op="residual", linf=linf)
    else:
        _, parts = fused_sweep_residual_ref(g, b, _coefs(st), tile=tile,
                                            op="residual", linf=linf)
    return jnp.max(parts) if linf else jnp.sum(parts)
