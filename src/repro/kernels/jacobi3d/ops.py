"""jit'd dispatch wrapper for the jacobi3d kernel.

``sweep``/``sweep_with_contribution``/``residual_contribution`` are the entry
points used by ``solvers.fixed_point`` when ``SolverConfig.use_kernel`` is
set; they fall back to the pure-jnp path off-TPU so the distributed driver
runs everywhere.  ``interpret`` can be forced for validation.

Each entry does its own ghost assembly from ``(x, ghosts)`` — the Jacobi
kernel wants the ±1 ghosted layout, the hybrid RB-GS kernel the ±2 one — so
a caller pays exactly one assembly per sweep.  ``sweep_with_contribution``
is the fused hot path: one assembly + one grid pass yields both the swept
block and the detection layer's local contribution (the residual of the
*input* state, see kernels/jacobi3d/jacobi3d.py).

``PASS_COUNTS`` counts trace-time invocations per entry kind so tests can
assert the solver drivers lower to the expected number of grid passes (in
particular: no residual-only second pass on the fused path).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.jacobi3d.jacobi3d import (
    fused_rbgs_sweep_residual,
    fused_rbgs_sweep_residual_halo,
    fused_sweep_residual,
    fused_sweep_residual_halo,
)
from repro.kernels.jacobi3d.ref import fused_sweep_residual_ref, residual_partials
from repro.solvers import gauss_seidel
from repro.solvers.convdiff import Stencil

# trace-time grid-pass instrumentation (see module docstring)
PASS_COUNTS: Dict[str, int] = {"sweep": 0, "fused": 0, "residual": 0}


def reset_pass_counts() -> None:
    for k in PASS_COUNTS:
        PASS_COUNTS[k] = 0


def _coefs(st: Stencil) -> jnp.ndarray:
    return jnp.asarray([st.diag, st.xm, st.xp, st.ym, st.yp, st.zm, st.zp])


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Ghost assembly (z ghosts = Dirichlet BC = 0)
# ---------------------------------------------------------------------------


def ghost_pad1(x: jax.Array, ghosts) -> jax.Array:
    """(bx+2, by+2, bz+2) ghosted block from interior + 4 (x,y) face planes
    (the driver's canonical assembly — one definition, shared)."""
    from repro.solvers.fixed_point import ghosted  # function-level: no cycle

    return ghosted(x, ghosts)


def ghost_pad2(x: jax.Array, ghosts) -> jax.Array:
    """(bx+4, by+4, bz+2) twice-padded block for the RB-GS kernel: ghosts sit
    one ring in; the outermost ring is never consumed (masked in-kernel)."""
    gxm, gxp, gym, gyp = ghosts
    bx, by, bz = x.shape
    g = jnp.zeros((bx + 4, by + 4, bz + 2), x.dtype)
    g = g.at[2:-2, 2:-2, 1:-1].set(x)
    g = g.at[1, 2:-2, 1:-1].set(gxm)
    g = g.at[-2, 2:-2, 1:-1].set(gxp)
    g = g.at[2:-2, 1, 1:-1].set(gym)
    g = g.at[2:-2, -2, 1:-1].set(gyp)
    return g


def _pad_b(b: jax.Array) -> jax.Array:
    return jnp.pad(b, ((1, 1), (1, 1), (0, 0)))


# ---------------------------------------------------------------------------
# Fused sweep + residual partials (single implementation, two public faces)
# ---------------------------------------------------------------------------


def _sweep_impl(st, x, ghosts, b, sweep, ox, oy, tile, linf, interpret):
    """One relaxation sweep fused with the input-state residual partials."""
    use_interp = (not _on_tpu()) if interpret is None else interpret
    if sweep == "jacobi":
        g = ghost_pad1(x, ghosts)
        if use_interp and not _on_tpu():
            # off-TPU default: the jnp oracle (identical math, XLA-fused)
            return fused_sweep_residual_ref(g, b, _coefs(st), tile=tile, linf=linf)
        return fused_sweep_residual(g, b, _coefs(st), tile=tile, op="sweep",
                                    linf=linf, interpret=use_interp)
    # hybrid red-black GS
    if use_interp and not _on_tpu():
        g = ghost_pad1(x, ghosts)
        new, r = gauss_seidel.redblack_gs_sweep_residual(st, g, b, ox, oy)
        return new, residual_partials(r, tile=tile, linf=linf)
    g2 = ghost_pad2(x, ghosts)
    oxy = jnp.asarray(ox, jnp.int32) + jnp.asarray(oy, jnp.int32)
    return fused_rbgs_sweep_residual(g2, _pad_b(b), _coefs(st), oxy,
                                     tile=tile, linf=linf, interpret=use_interp)


def sweep(st: Stencil, x: jax.Array, ghosts, b: jax.Array,
          sweep: str = "jacobi", ox=0, oy=0,
          tile: Tuple[int, int] = (8, 128),
          interpret: Optional[bool] = None) -> jax.Array:
    """Sweep-only entry (inner sweeps that don't feed detection).  The unused
    residual partials are dead code XLA eliminates."""
    PASS_COUNTS["sweep"] += 1
    new, _ = _sweep_impl(st, x, ghosts, b, sweep, ox, oy, tile, True, interpret)
    return new


def sweep_with_contribution(st: Stencil, x: jax.Array, ghosts, b: jax.Array,
                            sweep: str = "jacobi", ox=0, oy=0,
                            ord: float = float("inf"),
                            tile: Tuple[int, int] = (8, 128),
                            interpret: Optional[bool] = None):
    """Fused hot path: ``(new_block, contrib)`` in one assembly + one pass.

    ``contrib`` is the pre-σ local contribution (max|r| for l∞, Σr² for l2)
    of the *input* state's residual — one sweep staler than a dedicated
    post-sweep pass, which the detection layer tolerates by design."""
    PASS_COUNTS["fused"] += 1
    linf = np.isinf(ord)
    new, parts = _sweep_impl(st, x, ghosts, b, sweep, ox, oy, tile, linf,
                             interpret)
    return new, (jnp.max(parts) if linf else jnp.sum(parts))


def _sweep_halo_impl(st, x, halos, b, sweep, ox, oy, oz, tile, linf,
                     interpret):
    """Halo-consuming twin of ``_sweep_impl``: unghosted block + six
    explicit face planes (multi-axis shard meshes — any of x/y/z may be
    partitioned).  Off-TPU the jnp path assembles ``ghosted6`` and runs the
    same solver math the single-device reference uses (bitwise parity of
    the 1-shard mesh); on TPU the halo kernels skip the assembly."""
    from repro.solvers.fixed_point import ghosted6  # function-level: no cycle

    use_interp = (not _on_tpu()) if interpret is None else interpret
    if sweep == "jacobi":
        if use_interp and not _on_tpu():
            from repro.solvers import jacobi

            new, r = jacobi.jacobi_sweep_residual(st, ghosted6(x, halos), b)
            return new, residual_partials(r, tile=tile, linf=linf)
        return fused_sweep_residual_halo(x, halos, b, _coefs(st), tile=tile,
                                         op="sweep", linf=linf,
                                         interpret=use_interp)
    if use_interp and not _on_tpu():
        new, r = gauss_seidel.redblack_gs_sweep_residual(
            st, ghosted6(x, halos), b, ox, oy, oz)
        return new, residual_partials(r, tile=tile, linf=linf)
    oxyz = (jnp.asarray(ox, jnp.int32) + jnp.asarray(oy, jnp.int32)
            + jnp.asarray(oz, jnp.int32))
    return fused_rbgs_sweep_residual_halo(x, halos, b, _coefs(st), oxyz,
                                          tile=tile, linf=linf,
                                          interpret=use_interp)


def sweep_halo(st: Stencil, x: jax.Array, halos, b: jax.Array,
               sweep: str = "jacobi", ox=0, oy=0, oz=0,
               tile: Tuple[int, int] = (8, 128),
               interpret: Optional[bool] = None) -> jax.Array:
    """Halo-buffer sweep-only entry (dead partials XLA eliminates)."""
    PASS_COUNTS["sweep"] += 1
    new, _ = _sweep_halo_impl(st, x, halos, b, sweep, ox, oy, oz, tile, True,
                              interpret)
    return new


def sweep_with_contribution_halo(st: Stencil, x: jax.Array, halos,
                                 b: jax.Array, sweep: str = "jacobi",
                                 ox=0, oy=0, oz=0, ord: float = float("inf"),
                                 tile: Tuple[int, int] = (8, 128),
                                 interpret: Optional[bool] = None):
    """Fused halo-buffer hot path: ``(new_block, contrib)`` in one pass."""
    PASS_COUNTS["fused"] += 1
    linf = np.isinf(ord)
    new, parts = _sweep_halo_impl(st, x, halos, b, sweep, ox, oy, oz, tile,
                                  linf, interpret)
    return new, (jnp.max(parts) if linf else jnp.sum(parts))


def residual_contribution_halo(st: Stencil, x: jax.Array, halos,
                               b: jax.Array, ord: float = float("inf"),
                               tile: Tuple[int, int] = (8, 128),
                               interpret: Optional[bool] = None):
    """Residual-only pass from an unghosted block + six face planes
    (blocking mode's barrier pass and NFAIS2's exact verification)."""
    PASS_COUNTS["residual"] += 1
    linf = np.isinf(ord)
    use_interp = (not _on_tpu()) if interpret is None else interpret
    if use_interp and not _on_tpu():
        from repro.solvers import jacobi
        from repro.solvers.fixed_point import ghosted6

        r = jacobi.residual_block(st, ghosted6(x, halos), b)
        parts = residual_partials(r, tile=tile, linf=linf)
    else:
        _, parts = fused_sweep_residual_halo(x, halos, b, _coefs(st),
                                             tile=tile, op="residual",
                                             linf=linf, interpret=use_interp)
    return jnp.max(parts) if linf else jnp.sum(parts)


def residual_contribution(st: Stencil, g: jax.Array, b: jax.Array,
                          ord: float = float("inf"),
                          tile: Tuple[int, int] = (8, 128),
                          interpret: Optional[bool] = None):
    """Residual-only pass over a ±1 ghosted block (unfused baseline path and
    NFAIS2's exact verification)."""
    PASS_COUNTS["residual"] += 1
    linf = np.isinf(ord)
    use_interp = (not _on_tpu()) if interpret is None else interpret
    if use_interp and not _on_tpu():
        _, parts = fused_sweep_residual_ref(g, b, _coefs(st), tile=tile,
                                            op="residual", linf=linf)
    else:
        _, parts = fused_sweep_residual(g, b, _coefs(st), tile=tile,
                                        op="residual", linf=linf,
                                        interpret=use_interp)
    return jnp.max(parts) if linf else jnp.sum(parts)
