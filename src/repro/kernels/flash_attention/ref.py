"""Pure-jnp oracle for the flash attention kernel (naive, O(S²) memory)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """q [BH, Sq, H], k/v [BN, Skv, H] → [BH, Sq, H]."""
    BH, Sq, H = q.shape
    BN, Skv, _ = k.shape
    rep = BH // BN
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=0)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=0)
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32), kf) / math.sqrt(H)
    q_pos = jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= kv_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkh->bqh", p, vf)
    return out.astype(q.dtype)
