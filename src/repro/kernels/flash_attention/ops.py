"""jit'd wrapper: model-layout ⇄ kernel-layout dispatch for flash attention.

``flash_attention`` accepts the model's grouped GQA layout
(q [B,S,N,P,H], k/v [B,S,N,H]) and dispatches to the Pallas kernel on TPU
(or interpret mode when forced), falling back to the blocked pure-jnp
implementation elsewhere.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_flat


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(
    q: jax.Array,   # [B, S, N, P, H]
    k: jax.Array,   # [B, S, N, H]
    v: jax.Array,   # [B, S, N, H]
    causal: bool = True,
    window: int = 0,
    interpret: Optional[bool] = None,
):
    B, S, N, P, H = q.shape
    use_interp = False if interpret is None else interpret
    if not _on_tpu() and not use_interp:
        from repro.models.attention import attention_fwd

        return attention_fwd(q, k, v, causal=causal, window=window)
    qf = jnp.moveaxis(q, 1, 3).reshape(B * N * P, S, H)   # [B,N,P,S,H] → rows
    kf = jnp.moveaxis(k, 1, 2).reshape(B * N, S, H)
    vf = jnp.moveaxis(v, 1, 2).reshape(B * N, S, H)
    out = flash_attention_flat(qf, kf, vf, causal=causal, window=window,
                               interpret=use_interp)
    out = out.reshape(B, N, P, S, H)
    return jnp.moveaxis(out, 3, 1)  # [B,S,N,P,H]
