"""Blocked online-softmax (flash) attention — Pallas TPU.

Grid: (B·N·P heads, q-blocks); each program streams kv-blocks with windowed
``pl.load`` from HBM, keeping the f32 (m, l, acc) accumulators in registers/
VMEM across the inner ``fori_loop``.  MXU-aligned 128×head_dim tiles.

Causal **block skipping**: the kv loop runs only over blocks intersecting
the causal (and sliding-window) band of the current q-block — the pure-jnp
path computes all S² scores and masks, so the kernel does ~2× less work at
train_4k and ~S/window less with a window (see EXPERIMENTS.md §Perf).

GQA is expressed by the wrapper: q heads are flattened to B·N·P rows while
k/v keep B·N rows; the kernel maps q-row → kv-row by integer division.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _ANY = pltpu.ANY
except Exception:  # pragma: no cover
    _ANY = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, block_q: int,
            block_kv: int, causal: bool, window: int, q_per_kv: int,
            seq_kv: int):
    bh = pl.program_id(0)
    iq = pl.program_id(1)
    kv_row = bh // q_per_kv
    q = q_ref[0].astype(jnp.float32) * scale          # [bq, H]
    H = q.shape[-1]
    q_start = iq * block_q
    q_pos = q_start + jax.lax.iota(jnp.int32, block_q)

    n_kv = seq_kv // block_kv
    if causal:
        hi = jnp.minimum((q_start + block_q - 1) // block_kv + 1, n_kv)
    else:
        hi = n_kv
    if window > 0:
        lo = jnp.maximum((q_start - window + 1) // block_kv, 0)
    else:
        lo = 0

    def body(jb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (kv_row, pl.ds(jb * block_kv, block_kv),
                            pl.ds(0, H))).astype(jnp.float32)
        v = pl.load(v_ref, (kv_row, pl.ds(jb * block_kv, block_kv),
                            pl.ds(0, H))).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bkv]
        kv_pos = jb * block_kv + jax.lax.iota(jnp.int32, block_kv)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= kv_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ()))
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, H), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention_flat(
    q: jax.Array,   # [BH, Sq, H]  (BH = B·N·P)
    k: jax.Array,   # [BN, Skv, H]
    v: jax.Array,   # [BN, Skv, H]
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
):
    BH, Sq, H = q.shape
    BN, Skv, _ = k.shape
    assert BH % BN == 0
    q_per_kv = BH // BN
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0
    scale = 1.0 / math.sqrt(H)

    return pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, block_q=block_q, block_kv=block_kv,
            causal=causal, window=window, q_per_kv=q_per_kv, seq_kv=Skv,
        ),
        grid=(BH, Sq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, H), lambda bh, iq: (bh, iq, 0)),
            pl.BlockSpec(memory_space=_ANY),
            pl.BlockSpec(memory_space=_ANY),
        ],
        out_specs=pl.BlockSpec((1, block_q, H), lambda bh, iq: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, H), q.dtype),
        interpret=interpret,
    )(q, k, v)
