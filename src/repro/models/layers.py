"""Common layers: norms, RoPE, MLPs, embeddings — pure-jnp, dtype-explicit.

Parameter pytrees are plain dicts; initializers take an rng key and return
arrays in the config dtype.  All code paths work under jit / scan / shard_map.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


def ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exps = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exps)  # [hd/2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, hd] (hd trailing), positions: broadcastable to [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, f: int, gated: bool, dtype) -> Dict[str, jax.Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    p = {
        "w1": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
        "w2": (jax.random.normal(k2, (f, d)) * s_out).astype(dtype),
    }
    if gated:
        p["w3"] = (jax.random.normal(k3, (d, f)) * s_in).astype(dtype)
    return p


def mlp_apply(p: Dict[str, jax.Array], x: jax.Array, gated: bool, constrain=None,
              tp_reduce=None) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["w1"])
    if gated:
        h = jax.nn.silu(h) * jnp.einsum("...d,df->...f", x, p["w3"])
    else:
        h = jax.nn.gelu(h)
    if constrain is not None:
        h = constrain(h)
    if tp_reduce is not None:
        return tp_reduce(h, p["w2"])
    return jnp.einsum("...f,fd->...d", h, p["w2"])


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def embed_lookup(emb: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(emb, tokens, axis=0)


def lm_head(x: jax.Array, w: jax.Array) -> jax.Array:
    """x [..., d] × w [vocab, d] → logits [..., vocab] (f32)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32), w.astype(jnp.float32))
