"""Mixture-of-Experts with explicit expert parallelism (shard_map + a2a).

Layout
------
Experts are sharded over the ``model`` axis.  When ``E < tp`` (grok-1: 8
experts on a 16-wide axis) each expert is split into ``r = tp/E`` *virtual
experts* along d_ff — an exact decomposition of the gated FFN (the partial
down-projections sum), so every device owns ``ps = E_v/tp ≥ 1`` expert
shards.  Tokens are sequence-split across the model axis, routed top-k,
packed into per-(rank, slot) capacity buffers, exchanged with a single
``all_to_all``, transformed, and returned with a second ``all_to_all``.

FSDP: expert weights are additionally sharded over the ``data`` axis on
d_model and all-gathered per layer inside the block (transient), so resident
parameter memory scales with the full mesh.

Everything is static-shape (capacity-based, dropped tokens contribute zero)
and differentiable — a2a transposes to a2a.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ceil_to


@dataclass(frozen=True)
class MoEPlan:
    num_experts: int       # E (logical)
    top_k: int
    tp: int
    d_model: int
    d_ff: int              # logical per-expert width
    capacity_factor: float = 1.0

    @property
    def virt_per_expert(self) -> int:
        return max(1, self.tp // self.num_experts) if self.num_experts < self.tp else 1

    @property
    def virtual_experts(self) -> int:
        return self.num_experts * self.virt_per_expert

    @property
    def d_ff_virtual(self) -> int:
        return self.d_ff // self.virt_per_expert

    @property
    def per_rank_slots(self) -> int:
        return self.virtual_experts // self.tp

    @property
    def kr(self) -> int:
        return self.top_k * self.virt_per_expert

    def capacity(self, tokens_per_rank: int) -> int:
        c = math.ceil(self.capacity_factor * tokens_per_rank * self.kr / self.virtual_experts)
        return max(1, c)


def plan_moe(cfg, tp: int, capacity_factor: float = 1.0) -> MoEPlan:
    if cfg.num_experts >= tp and cfg.num_experts % tp:
        raise ValueError(f"num_experts={cfg.num_experts} not divisible by tp={tp}")
    if cfg.num_experts < tp and tp % cfg.num_experts:
        raise ValueError(f"tp={tp} not divisible by num_experts={cfg.num_experts}")
    if cfg.num_experts < tp and cfg.d_ff % (tp // cfg.num_experts):
        raise ValueError("d_ff not divisible by virtual split")
    return MoEPlan(
        num_experts=cfg.num_experts, top_k=cfg.experts_per_token, tp=tp,
        d_model=cfg.d_model, d_ff=cfg.d_ff, capacity_factor=capacity_factor,
    )


def moe_init(key, plan: MoEPlan, gated: bool, dtype) -> Dict[str, jax.Array]:
    """Virtual-expert-layout weights: w1/w3 [Ev, D, Fv], w2 [Ev, Fv, D]."""
    kr, k1, k2, k3 = jax.random.split(key, 4)
    Ev, D, Fv = plan.virtual_experts, plan.d_model, plan.d_ff_virtual
    s_in, s_out = 1.0 / math.sqrt(D), 1.0 / math.sqrt(plan.d_ff)
    p = {
        "router": (jax.random.normal(kr, (D, plan.num_experts)) * s_in).astype(jnp.float32),
        "w1": (jax.random.normal(k1, (Ev, D, Fv)) * s_in).astype(dtype),
        "w2": (jax.random.normal(k2, (Ev, Fv, D)) * s_out).astype(dtype),
    }
    if gated:
        p["w3"] = (jax.random.normal(k3, (Ev, D, Fv)) * s_in).astype(dtype)
    return p


# ---------------------------------------------------------------------------
# Routing / packing (runs per model-rank on its token slice)
# ---------------------------------------------------------------------------


def _route_and_pack(tokens, router_w, plan: MoEPlan, capacity: int, valid_mask):
    """tokens [t, D] → (send [Ev, C, D], combine info).

    combine info: slots [t, kr], pos [t, kr], weights [t, kr] (0 if dropped).
    """
    t, D = tokens.shape
    Ev, r, kr = plan.virtual_experts, plan.virt_per_expert, plan.kr
    logits = jnp.einsum("td,de->te", tokens.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, plan.top_k)           # [t, k]
    # virtual expansion: expert e → slots e*r .. e*r+r-1, same weight each
    slots = (topi[:, :, None] * r + jnp.arange(r)[None, None, :]).reshape(t, kr)
    weights = jnp.repeat(topv, r, axis=-1)                   # [t, kr]
    weights = weights * valid_mask[:, None]
    # capacity positions: order entries by (slot, token) and count
    flat_slot = slots.reshape(-1)                            # [t*kr]
    active = (weights.reshape(-1) > 0.0)
    onehot = jax.nn.one_hot(flat_slot, Ev, dtype=jnp.int32) * active[:, None].astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot                # count before me
    flat_pos = jnp.sum(pos * onehot, axis=1)                 # [t*kr]
    keep = active & (flat_pos < capacity)
    # scatter into [Ev, C+1, D]; dropped entries go to the overflow row C
    sp = jnp.where(keep, flat_pos, capacity)
    token_rep = jnp.repeat(tokens, kr, axis=0)               # [t*kr, D]
    send = jnp.zeros((Ev, capacity + 1, D), tokens.dtype)
    send = send.at[flat_slot, sp].add(token_rep, mode="drop")
    send = send[:, :capacity, :]
    pos2 = flat_pos.reshape(t, kr)
    w2 = jnp.where(keep.reshape(t, kr), weights, 0.0)
    aux = _load_balance_loss(probs, topi, plan)
    return send, (slots, pos2, w2), aux


def _load_balance_loss(probs, topi, plan: MoEPlan):
    """Switch-style aux loss: E · Σ_e f_e · P_e (per-rank partial)."""
    E = plan.num_experts
    f = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    pmean = jnp.mean(probs, axis=0)
    return E * jnp.sum(f * pmean)


def _unpack_combine(out_buf, info, capacity: int):
    """out_buf [Ev, C, D] + combine info → token outputs [t, D]."""
    slots, pos, w = info
    t, kr = slots.shape
    pos_c = jnp.minimum(pos, capacity - 1)
    gathered = out_buf[slots.reshape(-1), pos_c.reshape(-1)].reshape(t, kr, -1)
    return jnp.einsum("tkd,tk->td", gathered.astype(jnp.float32), w).astype(out_buf.dtype)


# ---------------------------------------------------------------------------
# The shard_map MoE block
# ---------------------------------------------------------------------------


def moe_block_local(
    x_block: jax.Array,          # [b, S, D] — this data-shard's tokens (replicated over model)
    weights: Dict[str, jax.Array],  # sharded leaves (see specs in model.py)
    plan: MoEPlan,
    gated: bool,
    model_axis: str = "model",
    fsdp_axis: Optional[str] = "data",
):
    """Body to run under shard_map.  Returns (y_block [b,S,D], aux_loss)."""
    b, S, D = x_block.shape
    tp = plan.tp
    rank = jax.lax.axis_index(model_axis)
    tokens_all = x_block.reshape(b * S, D)
    T = b * S
    t_pad = ceil_to(max(T, tp), tp)
    tpr = t_pad // tp  # tokens per model-rank
    pad = t_pad - T
    if pad:
        tokens_all = jnp.pad(tokens_all, ((0, pad), (0, 0)))
    my = jax.lax.dynamic_slice_in_dim(tokens_all, rank * tpr, tpr, axis=0)
    valid = (rank * tpr + jnp.arange(tpr)) < T

    C = plan.capacity(tpr)
    send, info, aux = _route_and_pack(my, weights["router"], plan, C, valid.astype(jnp.float32))
    ps = plan.per_rank_slots
    send = send.reshape(tp, ps, C, D)
    recv = jax.lax.all_to_all(send, model_axis, split_axis=0, concat_axis=0, tiled=False)
    # recv [tp(src), ps, C, D]; local expert shards [ps, D, Fv/fsdp]
    w1, w2, w3 = weights["w1"], weights["w2"], weights.get("w3")
    if fsdp_axis is not None:
        # Expert-TP over the fsdp axis: d_ff is sharded over "data", so we
        # all-gather *tokens* (cheap) instead of expert *weights* (huge),
        # compute the partial FFN on the local d_ff slice, and psum-scatter
        # the partial down-projections back.  Exact for (gated) MLPs.
        xg = jax.lax.all_gather(recv, fsdp_axis, axis=0, tiled=True)  # [dp·tp, ps, C, D]
        h = jnp.einsum("xpcd,pdf->xpcf", xg, w1)
        if gated:
            h = jax.nn.silu(h) * jnp.einsum("xpcd,pdf->xpcf", xg, w3)
        else:
            h = jax.nn.gelu(h)
        out_partial = jnp.einsum("xpcf,pfd->xpcd", h, w2)
        out = jax.lax.psum_scatter(out_partial, fsdp_axis, scatter_dimension=0, tiled=True)
    else:
        h = jnp.einsum("xpcd,pdf->xpcf", recv, w1)
        if gated:
            h = jax.nn.silu(h) * jnp.einsum("xpcd,pdf->xpcf", recv, w3)
        else:
            h = jax.nn.gelu(h)
        out = jnp.einsum("xpcf,pfd->xpcd", h, w2)
    back = jax.lax.all_to_all(out, model_axis, split_axis=0, concat_axis=0, tiled=False)
    y_my = _unpack_combine(back.reshape(plan.virtual_experts, C, D), info, C)
    # reassemble the full token set on every model-rank
    y_all = jax.lax.all_gather(y_my, model_axis, axis=0, tiled=True)  # [t_pad, D]
    y = y_all[:T].reshape(b, S, D)
    aux = jax.lax.psum(aux, model_axis) / tp
    return y, aux


def moe_apply(
    x: jax.Array,
    weights: Dict[str, jax.Array],
    plan: MoEPlan,
    gated: bool,
    mesh,
    dp_axes: Tuple[str, ...],
    model_axis: str = "model",
    fsdp_axis: Optional[str] = "data",
):
    """shard_map wrapper usable inside a jit'd/scanned transformer block."""
    from jax.sharding import PartitionSpec as P

    x_spec = P(dp_axes, None, None)
    # expert dim over "model" (EP); d_ff over "data" (expert-TP = FSDP-free
    # storage scaling without per-layer weight gathers)
    w_specs = {
        "router": P(None, None),
        "w1": P(model_axis, None, fsdp_axis),
        "w2": P(model_axis, fsdp_axis, None),
    }
    if gated:
        w_specs["w3"] = P(model_axis, None, fsdp_axis)

    fn = partial(
        moe_block_local, plan=plan, gated=gated,
        model_axis=model_axis, fsdp_axis=fsdp_axis,
    )
    from repro.core.compat import shard_map_compat

    return shard_map_compat(
        fn, mesh=mesh,
        in_specs=(x_spec, w_specs),
        out_specs=(x_spec, P()),
    )(x, weights)
