"""Decoder backbone assembly: scan-over-layers, remat, heterogeneous blocks.

One scan step covers ``moe_layer_period`` consecutive layers (llama4
alternates dense/MoE), so parameter stacks have leading dim
``L / period`` and compile time is O(1) in depth.  Block families:

  dense/audio/vlm : [norm → attn → +res] [norm → mlp → +res]
  moe             : same, MLP replaced by MoE (+ optional shared expert)
  ssm             : [norm → mamba2 → +res]
  hybrid (hymba)  : [norm → attn ∥ mamba2 → mean → +res] [norm → mlp → +res]
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _ckpt_name

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import AttentionPlan, plan_attention
from repro.models.moe import MoEPlan, plan_moe
from repro.models.ssm import SSMPlan, plan_ssm


@dataclass(frozen=True)
class ModelPlan:
    cfg: ModelConfig
    tp: int
    attn: Optional[AttentionPlan]
    moe: Optional[MoEPlan]
    ssm: Optional[SSMPlan]
    vocab_padded: int

    @property
    def period(self) -> int:
        return self.cfg.moe_layer_period if self.cfg.is_moe else 1

    @property
    def scan_steps(self) -> int:
        return self.cfg.num_layers // self.period


def make_plan(cfg: ModelConfig, tp: int = 1, capacity_factor: float = 1.0) -> ModelPlan:
    attn = (
        plan_attention(cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, tp)
        if cfg.has_attention
        else None
    )
    moe = plan_moe(cfg, tp, capacity_factor) if cfg.is_moe else None
    ssm = plan_ssm(cfg, tp) if cfg.has_ssm else None
    vocab_padded = L.ceil_to(cfg.vocab_size, max(256, tp))
    return ModelPlan(cfg=cfg, tp=tp, attn=attn, moe=moe, ssm=ssm, vocab_padded=vocab_padded)


# ---------------------------------------------------------------------------
# Per-layer (sub-block) params
# ---------------------------------------------------------------------------


def _sublayer_init(key, plan: ModelPlan, is_moe_layer: bool, dtype) -> Dict[str, Any]:
    cfg = plan.cfg
    keys = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": L.rmsnorm_init(cfg.d_model, dtype)}
    if cfg.has_attention:
        p["attn"] = attn_mod.attn_init(keys[0], cfg.d_model, plan.attn, cfg.qkv_bias, dtype)
    if cfg.has_ssm:
        p["ssm"] = ssm_mod.ssm_init(keys[1], plan.ssm, dtype)
    if cfg.d_ff > 0:
        p["ln2"] = L.rmsnorm_init(cfg.d_model, dtype)
        if is_moe_layer:
            p["moe"] = moe_mod.moe_init(keys[2], plan.moe, cfg.gated_mlp, dtype)
            if cfg.shared_expert:
                p["shared"] = L.mlp_init(keys[3], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
        else:
            p["mlp"] = L.mlp_init(keys[4], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    return p


def init_params(key, plan: ModelPlan) -> Dict[str, Any]:
    cfg = plan.cfg
    dtype = L.dtype_of(cfg.dtype)
    k_emb, k_head, k_layers, k_fn = jax.random.split(key, 4)
    params: Dict[str, Any] = {"final_norm": L.rmsnorm_init(cfg.d_model, dtype)}
    if cfg.frontend is None:
        params["embed"] = L.embed_init(k_emb, plan.vocab_padded, cfg.d_model, dtype)
    else:
        # modality-frontend stub: precomputed frame/patch embeddings enter
        # through a learned adapter projection
        params["frontend_proj"] = (
            jax.random.normal(k_emb, (cfg.frontend_dim, cfg.d_model))
            / (cfg.frontend_dim ** 0.5)
        ).astype(dtype)
    if not cfg.tie_embeddings or cfg.frontend is not None:
        params["lm_head"] = L.embed_init(k_head, plan.vocab_padded, cfg.d_model, dtype)

    mask = cfg.moe_layer_mask()
    period, steps = plan.period, plan.scan_steps

    def unit_init(k):
        ks = jax.random.split(k, period)
        return tuple(
            _sublayer_init(ks[j], plan, mask[j], dtype) for j in range(period)
        )

    unit_keys = jax.random.split(k_layers, steps)
    stacked = jax.vmap(unit_init)(unit_keys)  # leaves get leading [steps]
    params["layers"] = stacked
    return params


# ---------------------------------------------------------------------------
# Sub-block application
# ---------------------------------------------------------------------------


class LayerCtx(NamedTuple):
    """Static context threaded through the scan body."""
    plan: ModelPlan
    mode: str                     # "train" | "prefill" | "decode"
    window: int
    use_kernel: bool
    mesh: Any                     # None on single device
    dp_axes: Tuple[str, ...]
    block_kv: int = 1024
    ssd_chunk: int = 128
    ring: bool = False            # ring KV cache (long-context decode)
    # sharding constraints (identity when mesh is None):
    #   c_act  — activations [B, S, D]           → P(dp, None, None)
    #   c_head — per-head tensors [B,S,N,(P),H]  → P(dp, None, "model", …)
    #   c_ffn  — hidden [B, S, F] / [B, S, di]   → P(dp, None, "model")
    c_act: Any = None
    c_head: Any = None
    c_ffn: Any = None
    attn_impl: str = "blocked"   # "blocked" | "pairs" (causal block skip)
    tp_reduce: Any = None        # explicit bf16 TP reduction (tp_reduce.py)
    remat: str = "block"         # "block" | "save_mixer"


def _attn_sublayer(p, x, ctx: LayerCtx, positions, cache, cache_len):
    cfg = ctx.plan.cfg
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    kv_cache = None
    if ctx.mode == "decode":
        kv_cache = (cache["k"], cache["v"])
    y, (k_new, v_new) = attn_mod.attn_apply(
        p["attn"], h, ctx.plan.attn, cfg.rope_theta, positions,
        causal=True, window=ctx.window, block_kv=ctx.block_kv,
        use_kernel=ctx.use_kernel, cache=kv_cache, cache_len=cache_len,
        ring=ctx.ring, constrain=ctx.c_head, impl=ctx.attn_impl,
        tp_reduce=ctx.tp_reduce,
    )
    new_cache = None
    if ctx.mode == "decode":
        # attn_apply already wrote the new token into the cache
        new_cache = {"k": k_new, "v": v_new}
    elif ctx.mode == "prefill":
        new_cache = {"k": k_new, "v": v_new}
    return y, new_cache


def _ssm_sublayer(p, x, ctx: LayerCtx, cache):
    y, new_cache = ssm_mod.ssm_apply(
        p["ssm"], x, ctx.plan.ssm, chunk=ctx.ssd_chunk,
        cache=cache, norm_eps=ctx.plan.cfg.norm_eps, constrain=ctx.c_ffn,
    )
    if ctx.mode == "train":
        new_cache = None
    return y, new_cache


def _mixer_sublayer(p, x, ctx: LayerCtx, positions, cache, cache_len):
    """Attention / SSM / hybrid mixer with residual."""
    cfg = ctx.plan.cfg
    new_cache: Dict[str, Any] = {}
    if cfg.hybrid:
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        ya, kv = _attn_sublayer({"ln1": p["ln1"], "attn": p["attn"]}, x, ctx,
                                positions, cache.get("kv") if cache else None, cache_len)
        ys, sc = _ssm_sublayer(p, h, ctx, cache.get("ssm") if cache else None)
        y = 0.5 * (ya + ys)
        if kv is not None:
            new_cache["kv"] = kv
        if sc is not None:
            new_cache["ssm"] = sc
    elif cfg.has_attention:
        y, kv = _attn_sublayer(p, x, ctx, positions,
                               cache.get("kv") if cache else None, cache_len)
        if kv is not None:
            new_cache["kv"] = kv
    else:
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        y, sc = _ssm_sublayer(p, h, ctx, cache.get("ssm") if cache else None)
        if sc is not None:
            new_cache["ssm"] = sc
    out = x + y
    out = _ckpt_name(out, "mixer_out")
    return out, (new_cache or None)


def _ffn_sublayer(p, x, ctx: LayerCtx):
    """MLP / MoE with residual; returns (x, aux_loss)."""
    cfg = ctx.plan.cfg
    if cfg.d_ff == 0:
        return x, jnp.zeros((), jnp.float32)
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        if ctx.mesh is not None:
            y, aux = moe_mod.moe_apply(
                h, p["moe"], ctx.plan.moe, cfg.gated_mlp, ctx.mesh,
                dp_axes=ctx.dp_axes,
            )
        else:
            y, aux = moe_local_reference(h, p["moe"], ctx.plan.moe, cfg.gated_mlp)
        if "shared" in p:
            y = y + L.mlp_apply(p["shared"], h, cfg.gated_mlp, constrain=ctx.c_ffn,
                                tp_reduce=ctx.tp_reduce)
    else:
        y = L.mlp_apply(p["mlp"], h, cfg.gated_mlp, constrain=ctx.c_ffn,
                        tp_reduce=ctx.tp_reduce)
    return x + y, aux


def moe_local_reference(x, weights, plan: MoEPlan, gated: bool):
    """Dense one-hot MoE (oracle / single-device smoke path)."""
    B, S, D = x.shape
    t = x.reshape(-1, D)
    logits = jnp.einsum("td,de->te", t.astype(jnp.float32), weights["router"])
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, plan.top_k)
    Ev, r = plan.virtual_experts, plan.virt_per_expert
    h1 = jnp.einsum("td,edf->tef", t, weights["w1"])
    if gated:
        h = jax.nn.silu(h1) * jnp.einsum("td,edf->tef", t, weights["w3"])
    else:
        h = jax.nn.gelu(h1)
    out_e = jnp.einsum("tef,efd->ted", h, weights["w2"])  # [t, Ev, D]
    # combine: each selected logical expert e contributes its r virtual slices
    slots = (topi[:, :, None] * r + jnp.arange(r)[None, None, :]).reshape(t.shape[0], -1)
    w = jnp.repeat(topv, r, axis=-1)
    sel = jnp.take_along_axis(out_e, slots[:, :, None], axis=1)  # [t, kr, D]
    y = jnp.einsum("tkd,tk->td", sel.astype(jnp.float32), w)
    aux = _local_aux(probs, topi, plan)
    return y.reshape(B, S, D).astype(x.dtype), aux


def _local_aux(probs, topi, plan: MoEPlan):
    E = plan.num_experts
    f = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    return E * jnp.sum(f * jnp.mean(probs, axis=0))


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------


def forward(
    params: Dict[str, Any],
    inputs: jax.Array,            # tokens [B,S] int32 or embeds [B,S,D]
    plan: ModelPlan,
    ctx: LayerCtx,
    cache: Any = None,            # stacked [steps, ...] pytree or None
    cache_len: Optional[jax.Array] = None,
):
    """Returns (logits, new_cache, aux_losses)."""
    cfg = plan.cfg
    if cfg.frontend is None:
        x = L.embed_lookup(params["embed"], inputs)
    else:
        x = jnp.einsum(
            "bsf,fd->bsd", inputs.astype(L.dtype_of(cfg.dtype)), params["frontend_proj"]
        )
    B, S = x.shape[:2]
    if ctx.mode == "decode":
        positions = cache_len + jnp.arange(S)
    else:
        positions = jnp.arange(S)

    period = plan.period

    def unit_apply(x, unit_params, unit_cache):
        new_caches = []
        aux_total = jnp.zeros((), jnp.float32)
        for j in range(period):
            p = unit_params[j]
            c = unit_cache[j] if unit_cache is not None else None
            x, nc = _mixer_sublayer(p, x, ctx, positions, c, cache_len)
            x, aux = _ffn_sublayer(p, x, ctx)
            aux_total = aux_total + aux
            new_caches.append(nc)
        return x, tuple(new_caches), aux_total

    if ctx.mode == "train":
        if ctx.remat == "save_mixer":
            # keep the post-mixer residual: the bwd replay skips the mixer
            # (and its TP all-reduce) entirely — §Perf iteration
            unit_fn = jax.checkpoint(
                unit_apply,
                policy=jax.checkpoint_policies.save_only_these_names("mixer_out"),
            )
        else:
            unit_fn = jax.checkpoint(unit_apply)
    else:
        unit_fn = unit_apply

    def scan_body(x, xs):
        unit_params, unit_cache = xs
        if ctx.c_act is not None:
            x = ctx.c_act(x)
        x, new_cache, aux = unit_fn(x, unit_params, unit_cache)
        return x, (new_cache, aux)

    if ctx.c_act is not None:
        x = ctx.c_act(x)
    cache_xs = cache if cache is not None else _none_cache(plan)
    x, (new_cache, auxs) = jax.lax.scan(
        scan_body, x, (params["layers"], cache_xs)
    )
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if ctx.c_act is not None:
        x = ctx.c_act(x)
    head = params.get("lm_head", params.get("embed"))
    return x, head, new_cache, jnp.sum(auxs)


def _none_cache(plan: ModelPlan):
    """Scan xs placeholder when no cache is threaded (None per unit layer)."""
    return tuple(None for _ in range(plan.period))


def logits_for(x: jax.Array, head: jax.Array) -> jax.Array:
    return L.lm_head(x, head)
