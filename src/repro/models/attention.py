"""GQA attention with TP-aware head planning, blocked (flash-style) softmax,
sliding windows, and KV-cache decode.

TP planning
-----------
The production mesh fixes the tensor-parallel width (model axis = 16), but
the assigned archs have head counts like 40/25/24 that don't divide it.  We
plan a *slot layout* that preserves the GQA q→kv mapping exactly:

  * kv groups are padded to ``G2`` = the smallest divisor of tp ≥ G (or a
    multiple of tp when G ≥ tp) and replicated ``repl = tp/G2`` times so
    every device owns exactly one kv slot;
  * q heads are padded per-group to ``qpg2`` (multiple of repl) and laid out
    as ``[slots, q_per_slot]`` so each q head shares a device with (a copy
    of) its own kv group — attention never communicates across devices.

Replicated kv slots are *stored* separately (so each device projects only
its slot) and kept numerically tied by summing replica gradients after the
backward pass (``models.model.apply_grad_fixups``).  Padded q heads are
neutralised by zero (and grad-masked) rows in the output projection.

The blocked attention (``attention_fwd``) is a pure-jnp online-softmax scan
over KV blocks — memory O(S·block) instead of O(S²); it is also the oracle
for the Pallas flash kernel (kernels/flash_attention).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, ceil_to


# ---------------------------------------------------------------------------
# TP head planning
# ---------------------------------------------------------------------------


def _smallest_divisor_geq(n: int, g: int) -> int:
    for d in range(g, n + 1):
        if n % d == 0:
            return d
    return n


@dataclass(frozen=True)
class AttentionPlan:
    num_heads: int       # original H
    num_kv_heads: int    # original G
    head_dim: int
    tp: int
    groups: int          # G2 (padded kv groups)
    q_per_group: int     # qpg2 (padded q heads per group)
    kv_repl: int         # copies of each kv group

    @property
    def slots(self) -> int:
        return self.groups * self.kv_repl

    @property
    def q_per_slot(self) -> int:
        return self.q_per_group // self.kv_repl

    @property
    def q_heads_padded(self) -> int:
        return self.groups * self.q_per_group

    def orig_qpg(self) -> int:
        return self.num_heads // self.num_kv_heads

    def q_slot_pos(self, h: int) -> Tuple[int, int]:
        """(slot, pos) of original q head h."""
        g, q = divmod(h, self.orig_qpg())
        return g * self.kv_repl + q // self.q_per_slot, q % self.q_per_slot

    def kv_slot_group(self, s: int) -> int:
        """Original kv group whose copy lives in slot s (or -1 if padded)."""
        g = s // self.kv_repl
        return g if g < self.num_kv_heads else -1


def plan_attention(num_heads: int, num_kv_heads: int, head_dim: int, tp: int) -> AttentionPlan:
    if num_heads % num_kv_heads:
        raise ValueError("num_heads must be a multiple of num_kv_heads")
    g, qpg = num_kv_heads, num_heads // num_kv_heads
    if g >= tp:
        g2, repl = ceil_to(g, tp), 1
        qpg2 = qpg
    else:
        g2 = _smallest_divisor_geq(tp, g)
        repl = tp // g2
        qpg2 = ceil_to(qpg, repl)
    return AttentionPlan(
        num_heads=num_heads, num_kv_heads=num_kv_heads, head_dim=head_dim,
        tp=tp, groups=g2, q_per_group=qpg2, kv_repl=repl,
    )


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def attn_init(key, d_model: int, plan: AttentionPlan, qkv_bias: bool, dtype) -> Dict[str, jax.Array]:
    """Padded/replicated slot-layout weights.

    wq [D, S, P, H], wk/wv [D, S, H], wo [S, P, H, D].  Replica slots hold
    identical kv weights; padded q positions have zero wo rows (grad-masked).
    """
    kq, kk, kv, ko = jax.random.split(key, 4)
    hd, S, P = plan.head_dim, plan.slots, plan.q_per_slot
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(plan.num_heads * hd)
    wq = jax.random.normal(kq, (d_model, S, P, hd)) * s_in
    # base kv per original group, tiled into slots
    wk_g = jax.random.normal(kk, (d_model, plan.groups, hd)) * s_in
    wv_g = jax.random.normal(kv, (d_model, plan.groups, hd)) * s_in
    wk = jnp.repeat(wk_g, plan.kv_repl, axis=1)
    wv = jnp.repeat(wv_g, plan.kv_repl, axis=1)
    wo = jax.random.normal(ko, (S, P, hd, d_model)) * s_out
    wo = wo * q_valid_mask(plan)[..., None, None]  # zero padded rows
    p = {"wq": wq.astype(dtype), "wk": wk.astype(dtype), "wv": wv.astype(dtype),
         "wo": wo.astype(dtype)}
    if qkv_bias:
        p["bq"] = jnp.zeros((S, P, hd), dtype)
        p["bk"] = jnp.zeros((S, hd), dtype)
        p["bv"] = jnp.zeros((S, hd), dtype)
    return p


def q_valid_mask(plan: AttentionPlan) -> jnp.ndarray:
    """[slots, q_per_slot] — 1 where an original q head lives."""
    m = np.zeros((plan.slots, plan.q_per_slot), np.float32)
    for h in range(plan.num_heads):
        s, p = plan.q_slot_pos(h)
        m[s, p] = 1.0
    return jnp.asarray(m)


# ---------------------------------------------------------------------------
# Blocked online-softmax attention (flash-style, pure jnp)
# ---------------------------------------------------------------------------


NEG_INF = -1e30


def attention_fwd(
    q: jax.Array,              # [B, Sq, N, P, H]
    k: jax.Array,              # [B, Skv, N, H]
    v: jax.Array,              # [B, Skv, N, H]
    causal: bool = True,
    window: int = 0,           # 0 = full; >0 = sliding window
    block_kv: int = 1024,
    q_offset: int = 0,         # position offset of q within the kv timeline
) -> jax.Array:
    """Online-softmax over KV blocks; returns [B, Sq, N, P, H] (q dtype)."""
    B, Sq, N, P, H = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(H)
    qf = (q * scale).astype(jnp.float32)
    block_kv = min(block_kv, Skv)
    nblk = (Skv + block_kv - 1) // block_kv
    pad = nblk * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block_kv, N, H).astype(jnp.float32)
    vb = v.reshape(B, nblk, block_kv, N, H).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(Sq)

    def scan_body(carry, blk):
        m, lsum, acc = carry
        kblk, vblk, blk_idx = blk
        kv_pos = blk_idx * block_kv + jnp.arange(block_kv)
        s = jnp.einsum("bqnph,bknh->bnpqk", qf, kblk)  # [B,N,P,Sq,block]
        mask = kv_pos[None, :] <= (q_pos[:, None] if causal else jnp.full((Sq, 1), Skv))
        if window:
            mask &= kv_pos[None, :] > (q_pos[:, None] - window)
        mask &= (kv_pos < Skv)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = lsum * alpha + pexp.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bnpqk,bknh->bnpqh", pexp, vblk)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, N, P, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, N, P, Sq), jnp.float32)
    acc0 = jnp.zeros((B, N, P, Sq, H), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)  # [nblk, B, block, N, H]
    vb_t = jnp.moveaxis(vb, 1, 0)
    (m, lsum, acc), _ = jax.lax.scan(
        scan_body, (m0, l0, acc0), (kb_t, vb_t, jnp.arange(nblk))
    )
    out = acc / jnp.maximum(lsum[..., None], 1e-30)
    return jnp.moveaxis(out, 3, 1).astype(q.dtype)  # [B,Sq,N,P,H]


def attention_fwd_pairs(
    q: jax.Array,              # [B, Sq, N, P, H]
    k: jax.Array,              # [B, Skv, N, H]
    v: jax.Array,              # [B, Skv, N, H]
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_kv: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    """Causal **block-skipping** online softmax (beyond-paper §Perf opt).

    ``attention_fwd`` streams every kv-block for every q position — the
    causal mask zeroes half the scores but the work and the HBM traffic for
    the score blocks are still paid.  Here we scan over the *static list of
    (q-block, kv-block) pairs inside the causal/window band* (≈ upper half /
    band of the grid), updating per-q-block (m, l, acc) accumulator slices
    in place.  FLOPs and score-traffic drop ~2× for causal training shapes
    (more with a window) while remaining reverse-differentiable — the pair
    list is static, unlike a dynamic-bound kv loop.
    """
    B, Sq, N, P, H = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(H)
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0
    nq, nk = Sq // block_q, Skv // block_kv

    pairs = []
    for i in range(nq):
        q_lo = q_offset + i * block_q
        q_hi = q_lo + block_q - 1
        for j in range(nk):
            kv_lo, kv_hi = j * block_kv, (j + 1) * block_kv - 1
            if causal and kv_lo > q_hi:
                continue  # entirely above the diagonal
            if window > 0 and kv_hi <= q_lo - window:
                continue  # entirely outside the window band
            pairs.append((i, j))
    pi = jnp.asarray([p[0] for p in pairs], jnp.int32)
    pj = jnp.asarray([p[1] for p in pairs], jnp.int32)

    qf = (jnp.moveaxis(q, 1, 3).astype(jnp.float32) * scale)  # [B,N,P,Sq,H]
    kf = jnp.moveaxis(k, 1, 2).astype(jnp.float32)            # [B,N,Skv,H]
    vf = jnp.moveaxis(v, 1, 2).astype(jnp.float32)

    def body(carry, pij):
        m, lsum, acc = carry
        i, j = pij
        qb = jax.lax.dynamic_slice_in_dim(qf, i * block_q, block_q, axis=3)
        kb = jax.lax.dynamic_slice_in_dim(kf, j * block_kv, block_kv, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(vf, j * block_kv, block_kv, axis=2)
        s = jnp.einsum("bnpqh,bnkh->bnpqk", qb, kb)
        q_pos = q_offset + i * block_q + jnp.arange(block_q)
        kv_pos = j * block_kv + jnp.arange(block_kv)
        mask = jnp.ones((block_q, block_kv), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= kv_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_old = jax.lax.dynamic_slice_in_dim(m, i * block_q, block_q, axis=3)
        l_old = jax.lax.dynamic_slice_in_dim(lsum, i * block_q, block_q, axis=3)
        a_old = jax.lax.dynamic_slice_in_dim(acc, i * block_q, block_q, axis=3)
        m_new = jnp.maximum(m_old, s.max(axis=-1))
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_old * alpha + p.sum(axis=-1)
        a_new = a_old * alpha[..., None] + jnp.einsum("bnpqk,bnkh->bnpqh", p, vb)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, i * block_q, axis=3)
        lsum = jax.lax.dynamic_update_slice_in_dim(lsum, l_new, i * block_q, axis=3)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new, i * block_q, axis=3)
        return (m, lsum, acc), None

    m0 = jnp.full((B, N, P, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, N, P, Sq), jnp.float32)
    acc0 = jnp.zeros((B, N, P, Sq, H), jnp.float32)
    (m, lsum, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (pi, pj))
    out = acc / jnp.maximum(lsum[..., None], 1e-30)
    return jnp.moveaxis(out, 3, 1).astype(q.dtype)


def mha_reference(q, k, v, causal=True, window=0, q_offset=0):
    """Naive reference (small shapes only)."""
    B, Sq, N, P, H = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqnph,bknh->bnpqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(H)
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    mask = kv_pos[None, :] <= (q_pos[:, None] if causal else jnp.full((Sq, 1), Skv))
    if window:
        mask &= kv_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnpqk,bknh->bnpqh", p, v.astype(jnp.float32))
    return jnp.moveaxis(out, 3, 1).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode (KV cache) attention
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array,          # [B, 1, N, P, H]
    k_cache: jax.Array,    # [B, Scache, N, H]
    v_cache: jax.Array,    # [B, Scache, N, H]
    cache_len: jax.Array,  # [] or [B] — number of valid cache entries
    window: int = 0,
    ring: bool = False,    # ring buffer (valid entries wrap around)
) -> jax.Array:
    B, _, N, P, H = q.shape
    S = k_cache.shape[1]
    s = jnp.einsum("bqnph,bknh->bnpqk", q.astype(jnp.float32), k_cache.astype(jnp.float32))
    s = s / math.sqrt(H)
    pos = jnp.arange(S)
    cl = jnp.asarray(cache_len)
    cl = cl[:, None] if cl.ndim else cl[None, None]
    if ring:
        valid = pos[None, :] < jnp.minimum(cl, S)   # whole ring valid once full
    else:
        valid = pos[None, :] < cl
        if window:
            valid &= pos[None, :] >= (cl - window)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnpqk,bknh->bnpqh", p, v_cache.astype(jnp.float32))
    return jnp.moveaxis(out, 3, 1).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block (projection + rope + core + output)
# ---------------------------------------------------------------------------


def attn_apply(
    p: Dict[str, jax.Array],
    x: jax.Array,              # [B, S, D]
    plan: AttentionPlan,
    rope_theta: float,
    positions: jax.Array,      # [S] absolute positions
    causal: bool = True,
    window: int = 0,
    block_kv: int = 1024,
    use_kernel: bool = False,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # decode: (k,v) caches
    cache_len: Optional[jax.Array] = None,
    ring: bool = False,
    constrain=None,   # sharding constraint for per-head tensors
    impl: str = "blocked",   # "blocked" | "pairs" (causal block skipping)
    tp_reduce=None,   # explicit bf16 TP reduction for the o-proj
):
    """Returns (out [B,S,D], new_kv) where new_kv = (k, v) of this call."""
    q = jnp.einsum("bsd,dnph->bsnph", x, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if constrain is not None:
        q, k, v = constrain(q), constrain(k), constrain(v)
    # rope over the sequence axis (axis 1): move it last
    q = apply_rope(jnp.moveaxis(q, 1, -2), positions, rope_theta)
    q = jnp.moveaxis(q, -2, 1)
    k = apply_rope(jnp.moveaxis(k, 1, -2), positions, rope_theta)
    k = jnp.moveaxis(k, -2, 1)

    if cache is not None:
        # write the new token's k/v first (causal: a token attends to itself)
        k_cache, v_cache = cache
        S_max = k_cache.shape[1]
        pos = (cache_len % S_max) if ring else jnp.minimum(cache_len, S_max - 1)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, axis=1)
        out = decode_attention(q, k_cache, v_cache, cache_len + 1, window=window, ring=ring)
        return jnp.einsum("bsnph,nphd->bsd", out, p["wo"]), (k_cache, v_cache)
    elif use_kernel:
        from repro.kernels.flash_attention import ops as flash_ops

        out = flash_ops.flash_attention(q, k, v, causal=causal, window=window)
    elif impl == "pairs":
        out = attention_fwd_pairs(q, k, v, causal=causal, window=window)
    else:
        out = attention_fwd(q, k, v, causal=causal, window=window, block_kv=block_kv)
    if constrain is not None:
        out = constrain(out)
    if tp_reduce is not None:
        B_, S_ = out.shape[:2]
        o2 = out.reshape(B_, S_, -1)                       # [B,S,N·P·H]
        w2 = p["wo"].reshape(-1, p["wo"].shape[-1])        # [N·P·H, D]
        y = tp_reduce(o2, w2)
    else:
        y = jnp.einsum("bsnph,nphd->bsd", out, p["wo"])
    return y, (k, v)
