"""Model substrate: decoder backbones for the 10 assigned architectures."""
from repro.models.model import Model, TrainState  # noqa: F401
from repro.models.transformer import ModelPlan, make_plan  # noqa: F401
