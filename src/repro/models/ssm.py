"""Mamba2 (SSD — state-space duality) blocks, TPU-shaped.

Train/prefill uses the chunked SSD algorithm: quadratic attention-like math
inside Q-sized chunks (MXU-friendly batched matmuls) + a tiny sequential
scan over chunk states — O(S·Q) memory instead of O(S²) and no
per-timestep recurrence.  Decode is the O(1) state update.

Head padding mirrors attention: SSD heads are padded to a multiple of the
TP width; padded heads are neutralised by zero (grad-masked) out-proj rows.
Weights are stored stream-split (z, x, B, C, dt separately) so each stream
gets its natural sharding (heads over model axis; B/C replicated — they are
per-group, groups=1 in the assigned archs).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ceil_to, rmsnorm


@dataclass(frozen=True)
class SSMPlan:
    d_model: int
    heads: int            # original nh
    heads_padded: int
    head_dim: int         # P
    state: int            # N
    groups: int
    conv_width: int
    tp: int

    @property
    def d_inner(self) -> int:
        return self.heads_padded * self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.groups * self.state


def plan_ssm(cfg, tp: int) -> SSMPlan:
    nh = cfg.resolved_ssm_heads
    return SSMPlan(
        d_model=cfg.d_model,
        heads=nh,
        heads_padded=ceil_to(nh, tp),
        head_dim=cfg.ssm_head_dim,
        state=cfg.ssm_state,
        groups=cfg.ssm_groups,
        conv_width=cfg.ssm_conv_width,
        tp=tp,
    )


def ssm_init(key, plan: SSMPlan, dtype) -> Dict[str, jax.Array]:
    ks = jax.random.split(key, 8)
    D, di = plan.d_model, plan.d_inner
    gn = plan.groups * plan.state
    s = 1.0 / math.sqrt(D)
    p = {
        "w_z": (jax.random.normal(ks[0], (D, di)) * s).astype(dtype),
        "w_x": (jax.random.normal(ks[1], (D, di)) * s).astype(dtype),
        "w_B": (jax.random.normal(ks[2], (D, gn)) * s).astype(dtype),
        "w_C": (jax.random.normal(ks[3], (D, gn)) * s).astype(dtype),
        "w_dt": (jax.random.normal(ks[4], (D, plan.heads_padded)) * s).astype(dtype),
        "conv_x": (jax.random.normal(ks[5], (plan.conv_width, di)) * 0.2).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], (plan.conv_width, gn)) * 0.2).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (plan.conv_width, gn)) * 0.2).astype(dtype),
        "A_log": jnp.zeros((plan.heads_padded,), jnp.float32),
        "D_skip": jnp.ones((plan.heads_padded,), jnp.float32),
        "dt_bias": jnp.zeros((plan.heads_padded,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": (
            jax.random.normal(jax.random.fold_in(key, 9), (di, D)) / math.sqrt(di)
        ).astype(dtype),
    }
    # neutralise padded heads in the output projection
    p["out_proj"] = (
        p["out_proj"] * head_valid_mask(plan).repeat(plan.head_dim)[:, None]
    ).astype(dtype)
    return p


def head_valid_mask(plan: SSMPlan) -> jnp.ndarray:
    m = np.zeros((plan.heads_padded,), np.float32)
    m[: plan.heads] = 1.0
    return jnp.asarray(m)


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------


def causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """x [B,S,C], w [W,C] depthwise causal conv.  With ``state`` [B,W-1,C]
    (decode or chunk-continuation), prepends it; returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    new_state = xp[:, -(W - 1) :, :] if W > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return jax.nn.silu(y), new_state


# ---------------------------------------------------------------------------
# Chunked SSD (train / prefill)
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,      # [B,S,nh,P]
    dt: jax.Array,     # [B,S,nh]   (post-softplus)
    A: jax.Array,      # [nh]       (negative)
    Bm: jax.Array,     # [B,S,G,N]
    Cm: jax.Array,     # [B,S,G,N]
    chunk: int = 128,
    h0: Optional[jax.Array] = None,  # [B,nh,P,N] initial state
):
    """Returns (y [B,S,nh,P], h_final [B,nh,P,N])."""
    Bsz, S, nh, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    chunk = min(chunk, S)
    assert S % chunk == 0, "sequence must be a multiple of the SSD chunk"
    nc = S // chunk
    rep = nh // G

    xf = x.astype(jnp.float32).reshape(Bsz, nc, chunk, nh, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, chunk, nh)
    Bf = Bm.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N)
    Cf = Cm.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N)
    Bh = jnp.repeat(Bf, rep, axis=3)  # [B,nc,Q,nh,N]
    Ch = jnp.repeat(Cf, rep, axis=3)

    dA = dtf * A[None, None, None, :]                 # [B,nc,Q,nh], ≤ 0
    cum = jnp.cumsum(dA, axis=2)                      # within-chunk cumulative
    total = cum[:, :, -1, :]                          # [B,nc,nh]
    xb = xf * dtf[..., None]                          # dt-scaled input

    # --- intra-chunk (quadratic, masked) ---
    # scores[t,s] = (C_t·B_s) exp(cum_t − cum_s), s ≤ t
    cb = jnp.einsum("bcthn,bcshn->bchts", Ch, Bh)     # [B,nc,nh,Q,Q]
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [B,nc,Qt,Qs,nh]
    decay = jnp.moveaxis(decay, -1, 2)                # [B,nc,nh,Qt,Qs]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    scores = jnp.where(mask[None, None, None], cb * decay, 0.0)
    y_intra = jnp.einsum("bchts,bcshp->bcthp", scores, xb)

    # --- chunk states ---
    dec_end = jnp.exp(total[:, :, None, :] - cum)     # [B,nc,Q,nh]
    S_c = jnp.einsum("bcshn,bcshp,bcsh->bchpn", Bh, xb, dec_end)  # [B,nc,nh,P,N]

    # --- inter-chunk scan ---
    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, P, N), jnp.float32)

    def scan_fn(h, inp):
        s_c, tot = inp
        h_prev = h
        h = jnp.exp(tot)[:, :, None, None] * h + s_c
        return h, h_prev

    (h_final, h_prevs) = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)             # [B,nc,nh,P,N] — state entering chunk

    # --- inter-chunk contribution ---
    y_inter = jnp.einsum("bcthn,bchpn,bcth->bcthp", Ch, h_prevs, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bsz, S, nh, P)
    return y.astype(x.dtype), h_final


def ssd_decode_step(
    x: jax.Array,     # [B,nh,P]
    dt: jax.Array,    # [B,nh]
    A: jax.Array,     # [nh]
    Bm: jax.Array,    # [B,G,N]
    Cm: jax.Array,    # [B,G,N]
    h: jax.Array,     # [B,nh,P,N]
):
    nh, G = x.shape[1], Bm.shape[1]
    rep = nh // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    da = jnp.exp(dt.astype(jnp.float32) * A[None, :])             # [B,nh]
    upd = jnp.einsum("bhn,bhp,bh->bhpn", Bh, x.astype(jnp.float32), dt.astype(jnp.float32))
    h_new = da[:, :, None, None] * h + upd
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h_new)
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# Full mamba2 block
# ---------------------------------------------------------------------------


class SSMCache(NamedTuple):
    h: jax.Array          # [B, nh, P, N] f32
    conv_x: jax.Array     # [B, W-1, d_inner]
    conv_B: jax.Array     # [B, W-1, G·N]
    conv_C: jax.Array     # [B, W-1, G·N]


def ssm_cache_init(plan: SSMPlan, batch: int, dtype) -> SSMCache:
    W = plan.conv_width
    gn = plan.groups * plan.state
    return SSMCache(
        h=jnp.zeros((batch, plan.heads_padded, plan.head_dim, plan.state), jnp.float32),
        conv_x=jnp.zeros((batch, W - 1, plan.d_inner), dtype),
        conv_B=jnp.zeros((batch, W - 1, gn), dtype),
        conv_C=jnp.zeros((batch, W - 1, gn), dtype),
    )


def ssm_apply(
    p: Dict[str, jax.Array],
    x: jax.Array,                       # [B,S,D]
    plan: SSMPlan,
    chunk: int = 128,
    cache: Optional[SSMCache] = None,   # decode (S==1) or continuation
    norm_eps: float = 1e-5,
    constrain=None,   # sharding constraint for [B,S,d_inner] tensors
):
    """Returns (y [B,S,D], new_cache)."""
    B, S, D = x.shape
    nh, P, N, G = plan.heads_padded, plan.head_dim, plan.state, plan.groups
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"])
    xs = jnp.einsum("bsd,di->bsi", x, p["w_x"])
    if constrain is not None:
        z, xs = constrain(z), constrain(xs)
    Bs = jnp.einsum("bsd,dg->bsg", x, p["w_B"])
    Cs = jnp.einsum("bsd,dg->bsg", x, p["w_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    cx = cache.conv_x if cache is not None else None
    cB = cache.conv_B if cache is not None else None
    cC = cache.conv_C if cache is not None else None
    xs, ncx = causal_conv(xs, p["conv_x"], cx)
    Bs, ncB = causal_conv(Bs, p["conv_B"], cB)
    Cs, ncC = causal_conv(Cs, p["conv_C"], cC)

    xh = xs.reshape(B, S, nh, P)
    Bm = Bs.reshape(B, S, G, N)
    Cm = Cs.reshape(B, S, G, N)

    if S == 1 and cache is not None:
        y, h_new = ssd_decode_step(xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0], cache.h)
        y = y[:, None]
    else:
        h0 = cache.h if cache is not None else None
        y, h_new = ssd_chunked(xh, dt, A, Bm, Cm, chunk=chunk, h0=h0)

    y = y + p["D_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(B, S, nh * P)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    new_cache = SSMCache(h=h_new, conv_x=ncx, conv_B=ncB, conv_C=ncC)
    return out, new_cache
