"""Explicit bf16 tensor-parallel reductions (beyond-paper §Perf opt).

GSPMD reduces TP dot partial-sums in the dot's f32 accumulation type — on
the wire that doubles every activation all-reduce.  For the two
down-projections (attention output, MLP down) we instead run the dot inside
a tiny shard_map and ``psum`` the **bf16** partials explicitly: within-chip
accumulation stays f32 (inside the dot), but the cross-chip payload is bf16.

Enabled by ``ParallelConfig.tp_reduce_bf16``; the baseline keeps the
GSPMD-implicit (f32-wire) reduction so both variants are measurable.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def tp_matmul_psum(
    h: jax.Array,        # [B, S, F] activations, F sharded over "model"
    w: jax.Array,        # [F, D] weight, F sharded over "model"
    mesh,
    dp_axes: Tuple[str, ...],
    model_axis: str = "model",
) -> jax.Array:
    """h @ w with an explicit bf16 all-reduce over the model axis."""

    def body(h_blk, w_blk):
        partial_out = jnp.einsum("bsf,fd->bsd", h_blk, w_blk)
        return jax.lax.psum(partial_out.astype(jnp.bfloat16), model_axis)

    from repro.core.compat import shard_map_compat

    return shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(P(dp_axes, None, model_axis), P(model_axis, None)),
        out_specs=P(dp_axes, None, None),
    )(h, w.astype(jnp.bfloat16))
