"""Top-level model API: build, shard, train, serve.

``Model`` ties together the backbone (models/transformer.py), the TP/EP
plans, PartitionSpecs for every parameter/cache leaf, the chunked
cross-entropy loss, gradient fix-ups (kv-replica tying, padding masks), and
the jit-able ``train_step`` / ``prefill`` / ``decode_step`` functions that
launch/dryrun.py lowers on the production meshes.

Convergence-detection integration (the paper's technique): the train step
carries a ``core.detection.MonitorState`` — the training-loss reduction is
pushed through the K-stale ring exactly like the solver residual, so the
stop-decision never fences the step. The host polls the on-device
``converged`` flag asynchronously (see launch/train.py).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.core import detection
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import ssm as ssm_mod
from repro.models.transformer import LayerCtx, forward, init_params, make_plan
from repro.optim.adamw import AdamState, AdamW, apply_updates, global_norm


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    monitor: detection.MonitorState
    step: jax.Array


class Model:
    def __init__(
        self,
        cfg: ModelConfig,
        mesh: Optional[Mesh] = None,
        parallel: ParallelConfig = ParallelConfig(),
        capacity_factor: float = 1.0,
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.parallel = parallel
        tp = int(mesh.shape["model"]) if mesh is not None else 1
        self.plan = make_plan(cfg, tp, capacity_factor)
        if mesh is not None:
            self.dp_axes = tuple(a for a in mesh.axis_names if a != "model")
        else:
            self.dp_axes = ()
        self._fsdp = "data" if (parallel.fsdp and mesh is not None) else None

    # ------------------------------------------------------------------
    # Params
    # ------------------------------------------------------------------
    def init(self, key) -> Any:
        return init_params(key, self.plan)

    def _sublayer_specs(self, is_moe_layer: bool) -> Dict[str, Any]:
        cfg, d = self.cfg, self._fsdp
        sp: Dict[str, Any] = {"ln1": P(None, None)}
        if cfg.has_attention:
            a = {
                "wq": P(None, d, "model", None, None),
                "wk": P(None, d, "model", None),
                "wv": P(None, d, "model", None),
                "wo": P(None, "model", None, None, d),
            }
            if cfg.qkv_bias:
                a.update(bq=P(None, "model", None, None), bk=P(None, "model", None),
                         bv=P(None, "model", None))
            sp["attn"] = a
        if cfg.has_ssm:
            sp["ssm"] = {
                "w_z": P(None, d, "model"),
                "w_x": P(None, d, "model"),
                "w_B": P(None, d, None),
                "w_C": P(None, d, None),
                "w_dt": P(None, d, "model"),
                "conv_x": P(None, None, "model"),
                "conv_B": P(None, None, None),
                "conv_C": P(None, None, None),
                "A_log": P(None, "model"),
                "D_skip": P(None, "model"),
                "dt_bias": P(None, "model"),
                "norm": P(None, "model"),
                "out_proj": P(None, "model", d),
            }
        if cfg.d_ff > 0:
            sp["ln2"] = P(None, None)
            mlp = {"w1": P(None, d, "model"), "w2": P(None, "model", d)}
            if cfg.gated_mlp:
                mlp["w3"] = P(None, d, "model")
            if is_moe_layer:
                # EP over model, expert-TP over data on d_ff (see moe.py)
                moe = {
                    "router": P(None, None, None),
                    "w1": P(None, "model", None, d),
                    "w2": P(None, "model", d, None),
                }
                if cfg.gated_mlp:
                    moe["w3"] = P(None, "model", None, d)
                sp["moe"] = moe
                if cfg.shared_expert:
                    sp["shared"] = dict(mlp)
            else:
                sp["mlp"] = dict(mlp)
        return sp

    def param_specs(self) -> Any:
        cfg = self.cfg
        mask = cfg.moe_layer_mask()
        period = self.plan.period
        specs: Dict[str, Any] = {"final_norm": P(None)}
        if cfg.frontend is None:
            # vocab-sharded: GSPMD lowers the lookup to clamp+mask+all-reduce
            # (the robust path), and the tied LM head needs no reshard
            specs["embed"] = P("model", None)
        else:
            specs["frontend_proj"] = P(None, "model")
        if not cfg.tie_embeddings or cfg.frontend is not None:
            # vocab-sharded, D replicated: the loss einsum then needs no
            # collective at all (batch over dp × vocab over model)
            specs["lm_head"] = P("model", None)
        specs["layers"] = tuple(self._sublayer_specs(mask[j]) for j in range(period))
        return specs

    def param_shardings(self) -> Any:
        assert self.mesh is not None
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self.param_specs(),
            is_leaf=lambda x: isinstance(x, P),
        )

    # ------------------------------------------------------------------
    # Gradient fix-ups: tie kv replicas, mask padded heads/vocab
    # ------------------------------------------------------------------
    def apply_grad_fixups(self, grads: Any) -> Any:
        cfg, plan = self.cfg, self.plan
        if cfg.has_attention and plan.attn is not None:
            ap = plan.attn
            qmask = attn_mod.q_valid_mask(ap)

            def fix_unit(unit):
                unit = dict(unit)
                a = dict(unit["attn"])
                if ap.kv_repl > 1:
                    for w in ("wk", "wv"):
                        g = a[w]  # [steps, D, slots, H]
                        s = g.shape
                        gg = g.reshape(s[0], s[1], ap.groups, ap.kv_repl, s[3])
                        gg = jnp.broadcast_to(
                            jnp.sum(gg, axis=3, keepdims=True), gg.shape
                        )
                        a[w] = gg.reshape(s)
                    for bname in ("bk", "bv"):
                        if bname in a:
                            g = a[bname]  # [steps, slots, H]
                            s = g.shape
                            gg = g.reshape(s[0], ap.groups, ap.kv_repl, s[2])
                            gg = jnp.broadcast_to(jnp.sum(gg, 2, keepdims=True), gg.shape)
                            a[bname] = gg.reshape(s)
                a["wo"] = a["wo"] * qmask[None, :, :, None, None]
                unit["attn"] = a
                return unit

            grads = dict(grads)
            grads["layers"] = tuple(fix_unit(u) if "attn" in u else u for u in grads["layers"])
        if cfg.has_ssm and plan.ssm is not None:
            hmask = ssm_mod.head_valid_mask(plan.ssm).repeat(plan.ssm.head_dim)

            def fix_ssm(unit):
                unit = dict(unit)
                s = dict(unit["ssm"])
                s["out_proj"] = s["out_proj"] * hmask[None, :, None]
                unit["ssm"] = s
                return unit

            grads = dict(grads)
            grads["layers"] = tuple(fix_ssm(u) if "ssm" in u else u for u in grads["layers"])
        # padded vocab rows
        for k in ("embed", "lm_head"):
            if isinstance(grads, dict) and k in grads:
                vmask = (jnp.arange(self.plan.vocab_padded) < cfg.vocab_size)
                grads[k] = grads[k] * vmask[:, None].astype(grads[k].dtype)
        return grads

    # ------------------------------------------------------------------
    # Forward / loss
    # ------------------------------------------------------------------
    def _ctx(self, mode: str, ring: bool = False) -> LayerCtx:
        c_act = c_head = c_ffn = None
        if self.mesh is not None:
            mesh, dp = self.mesh, self.dp_axes

            def _c(x, *lead):
                spec = P(*lead, *([None] * (x.ndim - len(lead))))
                return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

            def c_act(x):
                return _c(x, dp, None)

            def c_head(x):
                return _c(x, dp, None, "model")

            c_ffn = c_head
        return LayerCtx(
            plan=self.plan,
            mode=mode,
            window=self.cfg.attn_window,
            use_kernel=False,
            mesh=self.mesh,
            dp_axes=self.dp_axes,
            ring=ring,
            c_act=c_act,
            c_head=c_head,
            c_ffn=c_ffn,
            attn_impl=self.parallel.attn_impl,
            tp_reduce=self._tp_reduce() if mode == "train" else None,
            remat=self.parallel.remat,
        )

    def _tp_reduce(self):
        if not self.parallel.tp_reduce_bf16 or self.mesh is None:
            return None
        from functools import partial as _partial

        from repro.models.tp_reduce import tp_matmul_psum

        return _partial(tp_matmul_psum, mesh=self.mesh, dp_axes=self.dp_axes)

    def _constrain(self, x, spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def loss_fn(self, params, batch, seq_chunk: int = 512):
        """Chunked softmax cross-entropy; returns (loss, metrics)."""
        cfg = self.cfg
        inputs = batch["inputs"]
        labels = batch["labels"]
        x, head, _, aux = forward(params, inputs, self.plan, self._ctx("train"))
        # vocab-sharded head → the loss einsum needs no collectives (matters
        # for tied embeddings, which are stored D-sharded for the lookup)
        head = self._constrain(head, P("model", None))
        B, S, D = x.shape
        seq_chunk = min(seq_chunk, S)
        assert S % seq_chunk == 0
        nchunk = S // seq_chunk
        xc = x.reshape(B, nchunk, seq_chunk, D)
        lc = labels.reshape(B, nchunk, seq_chunk)
        vocab = cfg.vocab_size
        vpad = self.plan.vocab_padded

        @jax.checkpoint
        def chunk_nll(xb, lb):
            logits = L.lm_head(xb, head)  # [B, c, Vpad] f32
            logits = self._constrain(logits, P(self.dp_axes or None, None, "model"))
            vmask = jnp.arange(vpad) < vocab
            logits = jnp.where(vmask, logits, -1e30)
            lse = jax.nn.logsumexp(logits, axis=-1)
            # gold logit via masked reduction — stays sharded over vocab
            # (take_along_axis would force an all-gather of the logits)
            sel = jnp.arange(vpad)[None, None, :] == lb[..., None]
            gold = jnp.sum(jnp.where(sel, logits, 0.0), axis=-1)
            valid = lb >= 0   # -1 = no target (sequence wraparound)
            return (jnp.sum(jnp.where(valid, lse - gold, 0.0)),
                    jnp.sum(valid.astype(jnp.float32)))

        def scan_body(carry, idx):
            tot, cnt = carry
            nll, n = chunk_nll(xc[:, idx], lc[:, idx])
            return (tot + nll, cnt + n), None

        (total, ntok), _ = jax.lax.scan(
            scan_body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            jnp.arange(nchunk))
        ntok = jnp.maximum(ntok, 1.0)
        loss = total / ntok
        if cfg.is_moe:
            loss = loss + 0.01 * aux / max(self.cfg.num_layers, 1)
        return loss, {"nll": total / ntok, "aux": aux}

    # ------------------------------------------------------------------
    # Train step
    # ------------------------------------------------------------------
    def make_train_step(
        self,
        optimizer: AdamW,
        monitor: Optional[detection.MonitorConfig] = None,
        microbatches: int = 1,
        accum_dtype: Optional[str] = None,   # None → f32; "bfloat16" for 100B+
        monitor_metric: str = "loss",   # loss | update_norm | grad_norm
    ):
        if monitor_metric not in ("loss", "update_norm", "grad_norm"):
            raise ValueError(f"unknown monitor_metric {monitor_metric!r}")
        monitor = monitor or detection.MonitorConfig(
            mode=self.parallel.monitor_mode,
            eps=1e-2, eps_tilde=1e-2, ord=1.0,
            staleness=self.parallel.monitor_staleness,
        )
        adt = jnp.dtype(accum_dtype) if accum_dtype else jnp.float32

        def grads_of(params, batch):
            (loss, metrics), grads = jax.value_and_grad(self.loss_fn, has_aux=True)(
                params, batch
            )
            return loss, metrics, grads

        def train_step(state: TrainState, batch):
            if microbatches <= 1:
                loss, metrics, grads = grads_of(state.params, batch)
            else:
                # gradient accumulation: scan over microbatches so live
                # activations scale with B/microbatches
                mb = jax.tree.map(
                    lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                        + x.shape[1:]),
                    batch,
                )
                gsum0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), state.params)

                def micro(carry, b):
                    gsum, lsum = carry
                    loss, _, grads = grads_of(state.params, b)
                    gsum = jax.tree.map(lambda a, g: a + g.astype(adt), gsum, grads)
                    return (gsum, lsum + loss), None

                (gsum, lsum), _ = jax.lax.scan(
                    micro, (gsum0, jnp.zeros((), jnp.float32)), mb
                )
                grads = jax.tree.map(lambda g: (g / microbatches), gsum)
                loss = lsum / microbatches
                metrics = {}
            grads = self.apply_grad_fixups(grads)
            updates, opt, gnorm = optimizer.update(grads, state.opt, state.params)
            params = apply_updates(state.params, updates)
            # PFAIT: push the (already globally-reduced) convergence metric
            # through the K-stale ring; converged flag is read by the host
            # asynchronously.  update_norm is the fixed-point residual
            # ‖x_{k+1} − x_k‖ (free by-product of the step, the paper's
            # convention); grad_norm/loss are the classic ML criteria.
            if monitor_metric == "update_norm":
                contribution = global_norm(updates)
            elif monitor_metric == "grad_norm":
                contribution = gnorm
            else:
                contribution = loss
            mon = detection.step(monitor, state.monitor, contribution,
                                 axis_names=None)
            metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                           converged=mon.converged)
            return TrainState(params=params, opt=opt, monitor=mon,
                              step=state.step + 1), metrics

        return train_step, monitor

    def init_train_state(self, key, optimizer: AdamW,
                         monitor: Optional[detection.MonitorConfig] = None) -> TrainState:
        params = self.init(key)
        monitor = monitor or detection.MonitorConfig(
            mode=self.parallel.monitor_mode, eps=1e-2, eps_tilde=1e-2,
            ord=1.0, staleness=self.parallel.monitor_staleness,
        )
        return TrainState(
            params=params,
            opt=optimizer.init(params),
            monitor=detection.init_state(monitor),
            step=jnp.zeros((), jnp.int32),
        )

    def train_state_specs(self, optimizer: AdamW) -> Any:
        ps = self.param_specs()
        return TrainState(
            params=ps,
            opt=AdamState(step=P(), m=ps, v=ps),
            monitor=jax.tree.map(lambda _: P(), detection.init_state(
                detection.MonitorConfig(mode="pfait", eps=1.0, eps_tilde=1.0))),
            step=P(),
        )

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def cache_struct(self, batch: int, max_len: int, ring: bool = False,
                     as_struct: bool = True):
        """Stacked decode-cache pytree ([steps] leading) of zeros or
        ShapeDtypeStructs."""
        cfg, plan = self.cfg, self.plan
        dtype = L.dtype_of(cfg.dtype)
        steps, period = plan.scan_steps, plan.period
        S_kv = min(max_len, cfg.attn_window) if (ring and cfg.attn_window) else max_len

        def mk(shape, dt):
            if as_struct:
                return jax.ShapeDtypeStruct((steps,) + shape, dt)
            return jnp.zeros((steps,) + shape, dt)

        unit = []
        for _ in range(period):
            entry: Dict[str, Any] = {}
            if cfg.has_attention:
                ap = plan.attn
                entry["kv"] = {
                    "k": mk((batch, S_kv, ap.slots, ap.head_dim), dtype),
                    "v": mk((batch, S_kv, ap.slots, ap.head_dim), dtype),
                }
            if cfg.has_ssm:
                sp = plan.ssm
                gn = sp.groups * sp.state
                entry["ssm"] = ssm_mod.SSMCache(
                    h=mk((batch, sp.heads_padded, sp.head_dim, sp.state), jnp.float32),
                    conv_x=mk((batch, sp.conv_width - 1, sp.d_inner), dtype),
                    conv_B=mk((batch, sp.conv_width - 1, gn), dtype),
                    conv_C=mk((batch, sp.conv_width - 1, gn), dtype),
                )
            unit.append(entry)
        return tuple(unit)

    def cache_specs(self, batch_shardable: bool = True) -> Any:
        cfg = self.cfg
        dp = self.dp_axes if batch_shardable else None

        def kv_spec():
            return {"k": P(None, dp, None, "model", None),
                    "v": P(None, dp, None, "model", None)}

        unit = []
        for _ in range(self.plan.period):
            entry: Dict[str, Any] = {}
            if cfg.has_attention:
                entry["kv"] = kv_spec()
            if cfg.has_ssm:
                entry["ssm"] = ssm_mod.SSMCache(
                    h=P(None, dp, "model", None, None),
                    conv_x=P(None, dp, None, "model"),
                    conv_B=P(None, dp, None, None),
                    conv_C=P(None, dp, None, None),
                )
            unit.append(entry)
        return tuple(unit)

    def make_prefill(self):
        """prefill(params, inputs) → (last-position logits, cache)."""

        def prefill(params, inputs):
            x, head, cache, _ = forward(params, inputs, self.plan, self._ctx("prefill"))
            head = self._constrain(head, P("model", None))
            logits = L.lm_head(x[:, -1:], head)
            return logits, cache

        return prefill

    def make_decode_step(self, ring: bool = False):
        """decode(params, cache, tokens [B,1] or embeds, cache_len) →
        (logits [B,1,V], new_cache)."""

        def decode(params, cache, tokens, cache_len):
            x, head, new_cache, _ = forward(
                params, tokens, self.plan, self._ctx("decode", ring=ring),
                cache=cache, cache_len=cache_len,
            )
            head = self._constrain(head, P("model", None))
            logits = L.lm_head(x, head)
            return logits, new_cache

        return decode

    # ------------------------------------------------------------------
    # Input specs (dry-run stand-ins)
    # ------------------------------------------------------------------
    def batch_spec(self, shape: ShapeConfig) -> P:
        B = shape.global_batch
        ndev = int(np.prod([self.mesh.shape[a] for a in self.dp_axes])) if self.mesh else 1
        return P(self.dp_axes if (ndev > 1 and B % ndev == 0) else None)

    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStructs (+ PartitionSpecs) for the step the shape implies."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        bspec = self.batch_spec(shape)
        bp = bspec[0] if len(bspec) else None
        out: Dict[str, Any] = {}
        if shape.kind == "train":
            if cfg.frontend is None:
                out["inputs"] = (jax.ShapeDtypeStruct((B, S), jnp.int32), P(bp, None))
            else:
                out["inputs"] = (
                    jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), L.dtype_of(cfg.dtype)),
                    P(bp, None, None),
                )
            out["labels"] = (jax.ShapeDtypeStruct((B, S), jnp.int32), P(bp, None))
        elif shape.kind == "prefill":
            if cfg.frontend is None:
                out["inputs"] = (jax.ShapeDtypeStruct((B, S), jnp.int32), P(bp, None))
            else:
                out["inputs"] = (
                    jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), L.dtype_of(cfg.dtype)),
                    P(bp, None, None),
                )
        else:  # decode
            ring = shape.name == "long_500k" and cfg.attn_window > 0
            if cfg.frontend is None:
                out["inputs"] = (jax.ShapeDtypeStruct((B, 1), jnp.int32), P(bp, None))
            else:
                out["inputs"] = (
                    jax.ShapeDtypeStruct((B, 1, cfg.frontend_dim), L.dtype_of(cfg.dtype)),
                    P(bp, None, None),
                )
            cache = self.cache_struct(B, S, ring=ring, as_struct=True)
            cspecs = self.cache_specs(batch_shardable=(bp is not None))
            out["cache"] = (cache, cspecs)
            out["cache_len"] = (jax.ShapeDtypeStruct((), jnp.int32), P())
        return out
