"""Asynchronous data-parallel training runtime — SGD convergence certified
by the protocol-free non-blocking residual.

This is the ML half of the tentpole: each mesh shard is a *data-parallel
worker* holding a full parameter replica and a row shard of the training
set (``solvers/mlfixed.py`` tasks: ridge least squares or ℓ2-regularised
logistic regression).  Per exchange round, shard i

1. consumes the **stale** parameter average from ``view_delay[i]`` rounds
   ago (the delayed all-reduce of async data parallelism),
2. runs ``inner_steps[i]`` **heterogeneous local SGD steps** on its own
   rows, rotating deterministically through ``num_batches`` minibatches
   (seeded-deterministic stochastic gradients — same spec, same run),
3. publishes its new replica into the next average.

Formally this is the lifted fixed-point map of El-Baz's asynchronous
convex-optimization setting: the state is the replica stack
X = (x_1 … x_p), worker i's update is T_i(X) = LocalSGD_i^{s_i}(mean(X)),
and the natural residual is the **update difference** T_i(X) − x_i — it
vanishes exactly when training has converged (replicas consistent, mean
at the local-SGD fixed point), and near consensus it tracks γ‖∇F‖.  So
global convergence is certified by the *unchanged* ``core.detection``
monitor fed through the shard runtime's reduction modes:

* ``blocking``    — the synchronized-eval baseline: every round pays an
  *extra* evaluation pass of the worker map from the fresh average (the
  cost the paper's technique removes), psum consumed the same round, K
  forced 0.
* ``nonblocking`` — the paper: the contribution is the free by-product of
  the SGD step already taken (no eval pass), lanes k-lagged, the monitor
  consumes the reduction launched K rounds earlier.
* ``rdoubling``   — modified recursive doubling over the same lanes.

NFAIS2's blocking verification evaluates the deterministic full-batch
residual (the synchronized eval), paid lazily only when a candidate
fires.  Host-side oracles (``exact_train_residual``, ``reference_trace``)
reproduce the same map synchronously in numpy; ``core.termination``'s
``oracle_detect_step`` scores the async detection against them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import detection
from repro.core import residual as res
from repro.core.compat import shard_map_compat as _shard_map
from repro.core.reduction import get_reduction
from repro.runtime.shard_runtime import (
    _butterfly_rounds,
    _butterfly_step,
    _per_shard,
    _preduce,
    _ring_fill,
    _ring_read,
    _ring_write,
)
from repro.solvers.mlfixed import MLFixedPointProblem, _sigmoid

P = jax.sharding.PartitionSpec


@dataclass(frozen=True)
class TrainAsyncConfig:
    """Asynchrony knobs of the data-parallel loop (per-shard fields accept
    a scalar or a length-p sequence, like ``ShardRuntimeConfig``)."""

    monitor: detection.MonitorConfig
    reduction: str = "nonblocking"   # blocking | nonblocking | rdoubling
    inner_steps: Union[int, Sequence[int]] = 1   # local SGD steps / round
    view_delay: Union[int, Sequence[int]] = 0    # staleness of the average
    contrib_lag: Union[int, Sequence[int]] = 0   # reduction-lane age
    num_batches: int = 1             # minibatch rotation per shard
    gamma: Optional[float] = None    # None → safe_gamma(problem, p, nb)
    max_rounds: int = 10_000
    trace_len: int = 0               # >0: record launched residuals
    axis: str = "shard"

    def __post_init__(self):
        get_reduction(self.reduction)  # registry validation at construction
        if self.num_batches < 1:
            raise ValueError(f"num_batches={self.num_batches} must be >= 1")

    def effective_monitor(self) -> detection.MonitorConfig:
        """Same convention as the shard runtime: blocking consumes its
        reduction immediately and recursive doubling pipelines internally,
        so both force the monitor's K to 0."""
        if get_reduction(self.reduction).forces_zero_staleness \
                and self.monitor.staleness:
            return dataclasses.replace(self.monitor, staleness=0)
        return self.monitor


class TrainRunResult(NamedTuple):
    x: jax.Array              # [p, n] final per-shard parameter replicas
    residual: jax.Array       # the (possibly stale) residual that fired
    rounds: jax.Array         # exchange rounds performed
    converged: jax.Array
    local_steps: jax.Array    # [p] per-shard SGD step counts
    verifications: jax.Array  # NFAIS2 synchronized evals paid
    loss: jax.Array           # final full-data objective Σ_i F_i(x_i)
    trace: jax.Array          # [trace_len] launched global residual / round


# ---------------------------------------------------------------------------
# Step size (host-side): every worker's every minibatch map must contract
# ---------------------------------------------------------------------------


def _shard_rows(problem: MLFixedPointProblem, p: int):
    if problem.m % p:
        raise ValueError(f"m_rows={problem.m} not divisible by p={p}")
    m_loc = problem.m // p
    return [(problem.A[i * m_loc:(i + 1) * m_loc],
             problem.y[i * m_loc:(i + 1) * m_loc]) for i in range(p)]


def safe_gamma(problem: MLFixedPointProblem, p: int,
               num_batches: int = 1) -> float:
    """Largest-curvature-safe step: 1 / max over (shard, minibatch) of the
    local gradient's Lipschitz bound, so every local map is a contraction
    (lstsq: eigmax(A_bᵀA_b/m_b) + λ; logistic: the σ'≤1/4 bound)."""
    L = 0.0
    for A_loc, _ in _shard_rows(problem, p):
        m_loc = A_loc.shape[0]
        if m_loc % num_batches:
            raise ValueError(
                f"local rows {m_loc} not divisible by "
                f"num_batches={num_batches}")
        mb = m_loc // num_batches
        for b in range(num_batches):
            Ab = A_loc[b * mb:(b + 1) * mb]
            sv = np.linalg.svd(Ab, compute_uv=False)[0]
            if problem.task == "lstsq":
                L = max(L, sv * sv / mb + problem.l2)
            else:
                L = max(L, sv * sv / (4.0 * mb) + problem.l2)
    return 1.0 / L


# ---------------------------------------------------------------------------
# Device loop
# ---------------------------------------------------------------------------


def make_train_runtime(problem: MLFixedPointProblem, cfg: TrainAsyncConfig,
                       mesh):
    """Build ``run(X0, A, y) -> TrainRunResult`` over a 1-D shard mesh.

    .. deprecated:: Prefer ``repro.runtime.api.run_train`` (unified
       ``RuntimeConfig``/``RunReport`` surface).  This builder remains the
       compatibility shim the unified API routes through — signature and
       return type are frozen.

    ``X0`` — [p, n] replica stack sharded ``P(axis, None)``; ``A`` — the
    [m, n] design row-sharded ``P(axis, None)``; ``y`` — [m] targets
    (lstsq) or ±1 labels (logistic) sharded ``P(axis)``.
    """
    axis = cfg.axis
    p = mesh.shape[axis]
    mon_cfg = cfg.effective_monitor()
    ord_ = mon_cfg.ord
    if problem.m % p:
        raise ValueError(f"m_rows={problem.m} not divisible by p={p}")
    m_loc = problem.m // p
    if m_loc % cfg.num_batches:
        raise ValueError(f"local rows {m_loc} not divisible by "
                         f"num_batches={cfg.num_batches}")
    mb = m_loc // cfg.num_batches
    nb = cfg.num_batches
    inner = _per_shard(cfg.inner_steps, p, "inner_steps")
    if (inner < 1).any():
        raise ValueError("inner_steps must be >= 1 per shard")
    delay = _per_shard(cfg.view_delay, p, "view_delay")
    lag = _per_shard(cfg.contrib_lag, p, "contrib_lag")
    if cfg.reduction == "blocking" and (delay.any() or lag.any()):
        raise ValueError("blocking mode is the synchronized reference: "
                         "view_delay and contrib_lag must be 0")
    if cfg.reduction == "rdoubling":
        _butterfly_rounds(p)
    gamma = float(cfg.gamma if cfg.gamma is not None
                  else safe_gamma(problem, p, nb))
    l2 = problem.l2
    task = problem.task
    Lv = int(delay.max()) + 1
    Lc = int(lag.max()) + 1
    tlen = max(int(cfg.trace_len), 1)

    def grad_at(A_rows, y_rows, x):
        """Local-data gradient normalised by its own row count + full λ
        (so the mean over shards of local gradients is ∇F)."""
        if task == "lstsq":
            return A_rows.T @ (A_rows @ x - y_rows) / A_rows.shape[0] \
                + l2 * x
        w = -y_rows * jax.nn.sigmoid(-y_rows * (A_rows @ x))
        return A_rows.T @ w / A_rows.shape[0] + l2 * x

    def loss_at(A_rows, y_rows, x):
        """Local objective share F_i (Σ_i F_i = F at consensus)."""
        if task == "lstsq":
            r = A_rows @ x - y_rows
            return r @ r / (2.0 * problem.m) + l2 * (x @ x) / (2.0 * p)
        margin = y_rows * (A_rows @ x)
        return jnp.sum(jnp.logaddexp(0.0, -margin)) / problem.m \
            + l2 * (x @ x) / (2.0 * p)

    def loop(X0, A_loc, y_loc):
        rank = jax.lax.axis_index(axis)
        my_inner = jnp.asarray(inner)[rank]
        my_delay = jnp.asarray(delay)[rank]
        my_lag = jnp.asarray(lag)[rank]
        x0 = X0[0]   # [1, n] shard block → [n] replica

        def sgd_steps(x_start, k, steps):
            """``steps`` local minibatch steps; the batch counter keeps
            rotating across rounds (phase k·steps + t mod nb)."""
            def stepf(t, x):
                b = jnp.mod(k * steps + t, nb)
                rows = jax.lax.dynamic_slice_in_dim(A_loc, b * mb, mb, 0)
                tgt = jax.lax.dynamic_slice_in_dim(y_loc, b * mb, mb, 0)
                return x - gamma * grad_at(rows, tgt, x)
            return jax.lax.fori_loop(0, steps, stepf, x_start)

        def body(state):
            x, vring, cring, partial, visible, mon, trace, k = state
            view = _ring_read(vring, k - my_delay)   # stale average
            x_new = sgd_steps(view, k, my_inner)
            fresh = jax.lax.pmean(x_new, axis)
            vring = _ring_write(vring, fresh, k + 1)

            if cfg.reduction == "blocking":
                # synchronized-eval baseline: an extra evaluation pass of
                # the worker map from the fresh average, every round, on
                # the critical path (the map itself — same minibatch
                # schedule — so its fixed point is the one being monitored)
                contrib = res.local_contribution(
                    sgd_steps(fresh, k + 1, my_inner) - x_new, ord_)
            else:
                # the paper: the update difference is already in hand
                contrib = res.local_contribution(x_new - x, ord_)
            cring = _ring_write(cring, contrib, k)
            lane = _ring_read(cring, k - my_lag)

            if cfg.reduction == "rdoubling":
                partial, visible = _butterfly_step(
                    lane, partial, visible, k, p, axis, ord_)
                g_pre = visible
            else:
                g_pre = _preduce(lane, axis, ord_)

            trace = trace.at[jnp.minimum(k, tlen - 1)].set(
                jnp.where(k < tlen,
                          res.sigma(g_pre, ord_).astype(jnp.float32),
                          trace[jnp.minimum(k, tlen - 1)]))

            def exact_fn(x_new=x_new, fresh=fresh, k=k):
                # NFAIS2 verification: blocking synchronized eval of the
                # lifted residual at the fresh state
                return res.psum_sigma(
                    res.local_contribution(
                        sgd_steps(fresh, k + 1, my_inner) - x_new, ord_),
                    axis, ord_)

            mon = detection.step(mon_cfg, mon, g_pre, axis_names=None,
                                 exact_residual_fn=exact_fn)
            return x_new, vring, cring, partial, visible, mon, trace, k + 1

        def cond(state):
            mon, k = state[5], state[7]
            return (~mon.converged) & (k < cfg.max_rounds)

        mean0 = jax.lax.pmean(x0, axis)
        state0 = (
            x0,
            _ring_fill(mean0, Lv),
            jnp.full((Lc,), jnp.inf, jnp.float32),
            jnp.full((), jnp.inf, jnp.float32),   # butterfly partial
            jnp.full((), jnp.inf, jnp.float32),   # butterfly visible
            detection.init_state(mon_cfg),
            jnp.full((tlen,), jnp.inf, jnp.float32),
            jnp.zeros((), jnp.int32),
        )
        x, _, _, _, _, mon, trace, k = jax.lax.while_loop(cond, body, state0)
        loss = jax.lax.psum(loss_at(A_loc, y_loc, x), axis)
        return TrainRunResult(
            x=x[None],
            residual=mon.detected_residual,
            rounds=k,
            converged=mon.converged,
            local_steps=(k * my_inner)[None],
            verifications=mon.verifications,
            loss=loss,
            trace=trace,
        )

    row_spec = P(axis, None)
    out_specs = TrainRunResult(
        x=row_spec, residual=P(), rounds=P(), converged=P(),
        local_steps=P(axis), verifications=P(), loss=P(), trace=P(),
    )
    return _shard_map(loop, mesh=mesh,
                      in_specs=(row_spec, row_spec, P(axis)),
                      out_specs=out_specs)


def init_replicas(problem: MLFixedPointProblem, p: int) -> np.ndarray:
    """Zero-initialised replica stack [p, n] (matches ``init_local``)."""
    return np.zeros((p, problem.n))


# ---------------------------------------------------------------------------
# Host-side oracles (numpy): the synchronized eval the async loop replaces
# ---------------------------------------------------------------------------


def _np_grad(A_rows, y_rows, x, task, l2):
    if task == "lstsq":
        return A_rows.T @ (A_rows @ x - y_rows) / A_rows.shape[0] + l2 * x
    w = -y_rows * _sigmoid(-y_rows * (A_rows @ x))
    return A_rows.T @ w / A_rows.shape[0] + l2 * x


def _np_contrib(r, ord_):
    if np.isinf(ord_):
        return float(np.max(np.abs(r)))
    return float(np.sum(np.abs(r) ** ord_))


def _np_sigma(c, ord_):
    if np.isinf(ord_):
        return float(c)
    return float(c ** (1.0 / ord_))


def exact_train_residual(problem: MLFixedPointProblem, X: np.ndarray,
                         inner_steps, gamma: float, ord: float = 2.0,
                         num_batches: int = 1, phase: int = 0) -> float:
    """Exact lifted residual at replica stack ``X`` [p, n]: one
    deterministic application of every worker's map (same minibatch
    schedule, rotation phase ``phase``) from the fresh average — the
    ground truth a synchronized eval would compute, and exactly what
    NFAIS2's verifier evaluates on device.  ``num_batches=1`` is the
    full-batch special case."""
    X = np.asarray(X, dtype=np.float64)
    p = X.shape[0]
    inner = np.broadcast_to(np.asarray(inner_steps, np.int64), (p,))
    shards = _shard_rows(problem, p)
    m_loc = problem.m // p
    if m_loc % num_batches:
        raise ValueError(f"local rows {m_loc} not divisible by "
                         f"num_batches={num_batches}")
    mb = m_loc // num_batches
    mean = X.mean(axis=0)
    total = 0.0 if not np.isinf(ord) else -np.inf
    for i in range(p):
        A_loc, y_loc = shards[i]
        xi = mean.copy()
        s = int(inner[i])
        for t in range(s):
            b = (phase * s + t) % num_batches
            rows = A_loc[b * mb:(b + 1) * mb]
            tgt = y_loc[b * mb:(b + 1) * mb]
            xi = xi - gamma * _np_grad(rows, tgt, xi, problem.task,
                                       problem.l2)
        c = _np_contrib(xi - X[i], ord)
        total = max(total, c) if np.isinf(ord) else total + c
    return _np_sigma(total, ord)


def reference_trace(problem: MLFixedPointProblem, p: int,
                    inner_steps, num_batches: int, gamma: float,
                    rounds: int, ord: float = 2.0):
    """Synchronous (zero-delay) trajectory of the same map, minibatch
    rotation included: returns ``(X_final, residuals[rounds])`` where
    entry k is the monitored residual σ(Σ_i ‖T_i(X_k) − x_i‖^l) the
    blocking device run reproduces round for round."""
    inner = np.broadcast_to(np.asarray(inner_steps, np.int64), (p,))
    shards = _shard_rows(problem, p)
    m_loc = problem.m // p
    if m_loc % num_batches:
        raise ValueError(f"local rows {m_loc} not divisible by "
                         f"num_batches={num_batches}")
    mb = m_loc // num_batches
    X = np.zeros((p, problem.n))
    out = np.empty(rounds)
    for k in range(rounds):
        mean = X.mean(axis=0)
        X_new = np.empty_like(X)
        total = 0.0 if not np.isinf(ord) else -np.inf
        for i in range(p):
            A_loc, y_loc = shards[i]
            xi = mean.copy()
            s = int(inner[i])
            for t in range(s):
                b = (k * s + t) % num_batches
                rows = A_loc[b * mb:(b + 1) * mb]
                tgt = y_loc[b * mb:(b + 1) * mb]
                xi = xi - gamma * _np_grad(rows, tgt, xi, problem.task,
                                           problem.l2)
            X_new[i] = xi
            c = _np_contrib(xi - X[i], ord)
            total = max(total, c) if np.isinf(ord) else total + c
        out[k] = _np_sigma(total, ord)
        X = X_new
    return X, out
