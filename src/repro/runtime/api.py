"""Unified runtime API — one config, one report, three runtimes.

The three device runtimes grew three drifted entrypoints: the shard solver
returns a ``ShardRunResult`` NamedTuple from a ``ShardRuntimeConfig``, the
training loop a ``TrainRunResult`` from a ``TrainAsyncConfig`` (same knobs,
renamed fields), and the elastic driver an ``ElasticReport`` from a pile of
keyword arguments.  This module is the common contract on top:

* ``RuntimeConfig``  — one frozen config carrying the union of the
  asynchrony knobs, validated once (reduction through the
  ``core.reduction`` registry) and converted to the per-runtime configs by
  ``to_shard_config()`` / ``to_train_config()``.
* ``RunReport``      — one result dataclass every entrypoint returns:
  residual history, detection step, wall segments, schema trace handle
  (``core.trace``), membership log, solution, and the raw per-runtime
  result for anything not lifted.
* ``run_shard`` / ``run_train`` / ``run_elastic`` — the entrypoints.
  Trace recording attaches here (``record_trace=True``), not through
  per-runtime kwargs.

The historical entrypoints (``shard_runtime.make_runtime``,
``train_async.make_train_runtime``, ``elastic.run_elastic``) remain as thin
deprecation shims with unchanged signatures and return types — this module
routes through them, and ``tests/test_runtime_api.py`` proves the results
bitwise-match.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import detection
from repro.core.reduction import get_reduction
from repro.core.trace import (
    Trace,
    trace_from_elastic_report,
    trace_from_shard_run,
    trace_from_train_run,
)

#: trace_len used when ``record_trace=True`` and the user left trace_len=0
DEFAULT_TRACE_LEN = 512


@dataclass(frozen=True)
class RuntimeConfig:
    """The union of the three runtimes' asynchrony knobs.

    Per-shard fields (``inner_sweeps``/``halo_delay``/``contrib_lag``)
    accept a scalar or a length-p sequence exactly like the per-runtime
    configs.  Fields a runtime does not use are ignored by its converter
    (``num_batches``/``gamma`` are training-only; ``sweep`` is
    convdiff-only).
    """

    monitor: detection.MonitorConfig
    reduction: str = "nonblocking"
    inner_sweeps: Union[int, Sequence[int]] = 1
    halo_delay: Union[int, Sequence[int]] = 0
    contrib_lag: Union[int, Sequence[int]] = 0
    max_outer: int = 10_000
    trace_len: int = 0
    axis: str = "shard"
    sweep: str = "jacobi"            # convdiff only
    mesh_shape: Optional[Tuple[int, ...]] = None  # convdiff only: (px[,py[,pz]])
    overlap: bool = False            # convdiff only: comm-overlapped exchange
    num_batches: int = 1             # training only
    gamma: Optional[float] = None    # training only (None → safe_gamma)
    record_trace: bool = False       # attach a schema Trace to the report

    def __post_init__(self):
        get_reduction(self.reduction)  # registry validation at construction
        if self.max_outer < 1:
            raise ValueError(f"max_outer={self.max_outer} must be >= 1")

    def _trace_len(self) -> int:
        if self.record_trace and not self.trace_len:
            return min(DEFAULT_TRACE_LEN, self.max_outer)
        return int(self.trace_len)

    def to_shard_config(self):
        """The equivalent ``ShardRuntimeConfig``."""
        from repro.runtime.shard_runtime import ShardRuntimeConfig

        return ShardRuntimeConfig(
            monitor=self.monitor, reduction=self.reduction,
            inner_sweeps=self.inner_sweeps, halo_delay=self.halo_delay,
            contrib_lag=self.contrib_lag, max_outer=self.max_outer,
            trace_len=self._trace_len(), sweep=self.sweep, axis=self.axis,
            mesh_shape=self.mesh_shape, overlap=self.overlap)

    def to_train_config(self):
        """The equivalent ``TrainAsyncConfig`` (inner_sweeps→inner_steps,
        halo_delay→view_delay, max_outer→max_rounds)."""
        from repro.runtime.train_async import TrainAsyncConfig

        return TrainAsyncConfig(
            monitor=self.monitor, reduction=self.reduction,
            inner_steps=self.inner_sweeps, view_delay=self.halo_delay,
            contrib_lag=self.contrib_lag, num_batches=self.num_batches,
            gamma=self.gamma, max_rounds=self.max_outer,
            trace_len=self._trace_len(), axis=self.axis)


@dataclass
class RunReport:
    """What every unified entrypoint returns."""

    converged: bool
    detected_residual: Optional[float]
    detect_step: Optional[int]           # outer step the claim fired at
    outer_iters: int
    residual_history: np.ndarray         # launched residuals (finite prefix)
    wall_segments: List[Tuple[str, float]]   # [(name, seconds)]
    trace: Optional[Trace]               # schema trace (record_trace=True)
    membership_log: List[Tuple[int, str, str]]   # (segment, kind, detail)
    x: Any                               # final solution (runtime's layout)
    raw: Any = field(repr=False, default=None)   # the per-runtime result

    @property
    def wall_s(self) -> float:
        """Total wall seconds across all measured run segments."""
        return float(sum(s for _, s in self.wall_segments))


@dataclass
class TenantReport:
    """Per-tenant outcome of one detection-service solve (``launch/serve.py``).

    ``status`` is the tenant's terminal state: ``"served"`` (detection
    fired), ``"timeout"`` (step budget exhausted without detection),
    ``"rejected"`` (failed admission validation — ``error``/``reason``
    carry the structured cause), or ``"shed"`` (still queued when the
    service shut down without drain).  Tick fields are in service ticks
    (one tick = one ``chunk`` of device steps per lane bucket) and are
    deterministic for a seeded load; ``detect_step`` is the lane-local
    check index, bitwise-comparable to a solo ``detection.batched_monitor``
    run over the same contribution series.
    """

    tenant: str
    status: str
    family: str = ""
    mode: str = ""
    eps_tilde: float = float("nan")
    converged: bool = False
    detect_step: Optional[int] = None
    detected_residual: Optional[float] = None
    steps: int = 0                       # device steps executed
    arrival_tick: int = 0
    admit_tick: Optional[int] = None
    done_tick: Optional[int] = None
    queue_wait_ticks: Optional[int] = None
    ttd_ticks: Optional[int] = None      # time-to-detection, arrival → done
    oracle_step: Optional[int] = None    # first true crossing below ε̃
    false_detection: bool = False
    signature: str = ""                  # executable key (warm-sharing id)
    error: Optional[str] = None          # rejection code
    reason: Optional[str] = None         # rejection detail


@dataclass
class ServeReport(RunReport):
    """Service-level ``RunReport`` of a multi-tenant detection campaign.

    The inherited fields take their service-level meaning: ``converged``
    is True iff every admitted tenant's detection fired (no timeouts),
    ``outer_iters`` counts service ticks, ``wall_segments`` holds the
    single ``("serve", seconds)`` segment, and ``x``/``trace`` are unused
    (the per-tenant solutions stay on device; residual series live on the
    ``TenantReport``\\ s).  ``queue_wait_ticks``/``ttd_ticks`` are
    nearest-rank p50/p95/p99 percentile dicts over served tenants —
    deterministic, so CI exact-gates them (``check_regression.py
    serve_smoke``).
    """

    tenants: List[TenantReport] = field(default_factory=list)
    served: int = 0
    rejected: int = 0
    shed: int = 0
    timeouts: int = 0
    false_detections: int = 0
    compile_count: int = 0               # distinct lane executables built
    warm_hits: int = 0                   # admissions served by a live/warm executable
    ticks: int = 0
    queue_wait_ticks: Dict[str, float] = field(default_factory=dict)
    ttd_ticks: Dict[str, float] = field(default_factory=dict)
    throughput: Dict[str, float] = field(default_factory=dict)


def _history(trace_arr, outer: int, tlen: int) -> np.ndarray:
    arr = np.asarray(trace_arr, dtype=np.float64)[:min(outer, max(tlen, 1))]
    return arr[np.isfinite(arr)]


def _detect_step(converged: bool, outer: int) -> Optional[int]:
    return outer - 1 if converged and outer > 0 else None


# ---------------------------------------------------------------------------
# Entrypoints
# ---------------------------------------------------------------------------


def run_shard(family: str, cfg: RuntimeConfig, mesh, n: int, x0, arg, *,
              stencil=None, damping: float = 0.85,
              timing_runs: int = 0) -> RunReport:
    """Build, place, and run the asynchronous shard solver; one call.

    ``x0``/``arg`` may be host arrays — they are placed with the family's
    sharding on ``mesh``.  Wall segments: ``build`` (jit + placement,
    includes compile), ``run`` (a second, compiled execution — the
    steady-state cost replay calibrates against), and ``timing_runs``
    further ``rerun`` executions of the same compiled program (benchmarks
    separate calibration runs from scoring runs without recompiling).
    """
    import jax
    from jax.sharding import NamedSharding

    from repro.runtime.shard_runtime import make_runtime, mesh_state_spec

    scfg = cfg.to_shard_config()
    axes = tuple(getattr(mesh, "axis_names", (cfg.axis,)))
    p = int(np.prod([mesh.shape[a] for a in axes]))
    xspec = mesh_state_spec(family, mesh)
    aspec = _shard_arg_spec(family, mesh, cfg.axis)
    t0 = time.perf_counter()
    run = jax.jit(make_runtime(family, scfg, mesh, n,
                               stencil=stencil, damping=damping))
    x_dev = jax.device_put(np.asarray(x0), NamedSharding(mesh, xspec))
    a_dev = jax.device_put(np.asarray(arg), NamedSharding(mesh, aspec))
    jax.block_until_ready(run(x_dev, a_dev))   # compile + first execution
    t1 = time.perf_counter()
    result = jax.block_until_ready(run(x_dev, a_dev))
    t2 = time.perf_counter()
    segments = [("build", t1 - t0), ("run", t2 - t1)]
    segments += _timed_reruns(run, (x_dev, a_dev), timing_runs)
    return _shard_report(result, scfg, p, segments, source="shard")


def run_train(problem, cfg: RuntimeConfig, mesh, X0, A, y,
              timing_runs: int = 0) -> RunReport:
    """Unified entrypoint of the asynchronous data-parallel training loop."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.runtime.train_async import make_train_runtime

    tcfg = cfg.to_train_config()
    axis = cfg.axis
    p = mesh.shape[axis]
    row = P(axis, None)
    t0 = time.perf_counter()
    run = jax.jit(make_train_runtime(problem, tcfg, mesh))
    X_dev = jax.device_put(np.asarray(X0), NamedSharding(mesh, row))
    A_dev = jax.device_put(np.asarray(A), NamedSharding(mesh, row))
    y_dev = jax.device_put(np.asarray(y), NamedSharding(mesh, P(axis)))
    jax.block_until_ready(run(X_dev, A_dev, y_dev))
    t1 = time.perf_counter()
    result = jax.block_until_ready(run(X_dev, A_dev, y_dev))
    t2 = time.perf_counter()
    segments = [("build", t1 - t0), ("run", t2 - t1)]
    segments += _timed_reruns(run, (X_dev, A_dev, y_dev), timing_runs)
    return _shard_report(result, tcfg, p, segments, source="train")


def _timed_reruns(run, args, timing_runs: int) -> List[Tuple[str, float]]:
    import jax

    out = []
    for _ in range(max(int(timing_runs), 0)):
        t0 = time.perf_counter()
        jax.block_until_ready(run(*args))
        out.append(("rerun", time.perf_counter() - t0))
    return out


def run_elastic(family: str, cfg: RuntimeConfig, n: int, x0, arg, plan,
                ckpt_dir: str, **knobs) -> RunReport:
    """Unified entrypoint of the elastic fault-injected driver.

    ``knobs`` pass through to ``elastic.run_elastic`` (``p0``,
    ``segment_len``, ``ckpt_every``, ``heartbeat_timeout``,
    ``max_segments``, ``straggler_policy``, ``keep``, ``stencil``,
    ``damping``).  ``cfg.max_outer`` is owned by the driver's segmentation,
    as before.
    """
    from repro.runtime import elastic as _elastic

    scfg = cfg.to_shard_config()
    t0 = time.perf_counter()
    report = _elastic.run_elastic(family, scfg, n, x0, arg, plan, ckpt_dir,
                                  **knobs)
    t1 = time.perf_counter()
    p0 = report.mesh_history[0][1] if report.mesh_history else 1
    tr = None
    if cfg.record_trace:
        tr = trace_from_elastic_report(report, scfg, p0)
        tr.validate()
    return RunReport(
        converged=bool(report.converged),
        detected_residual=report.detected_residual,
        detect_step=(report.outer_iters - 1 if report.converged else None),
        outer_iters=int(report.outer_iters),
        residual_history=np.asarray(
            [] if report.detected_residual is None
            else [report.detected_residual], dtype=np.float64),
        wall_segments=[("elastic", t1 - t0)],
        trace=tr,
        membership_log=list(report.events),
        x=report.x,
        raw=report,
    )


# ---------------------------------------------------------------------------
# Internals
# ---------------------------------------------------------------------------


def _shard_arg_spec(family: str, mesh, axis: str):
    from jax.sharding import PartitionSpec as P

    if family == "convdiff":
        from repro.runtime.shard_runtime import mesh_state_spec

        return mesh_state_spec(family, mesh)   # b shards exactly like x
    if family == "pagerank":
        return P(axis, None)
    from repro.runtime.shard_runtime import FAMILIES

    raise KeyError(f"family {family!r} not in {FAMILIES}")


def _shard_report(result, rcfg, p: int, segments, source: str) -> RunReport:
    outer = int(getattr(result, "outer_iters", getattr(result, "rounds", 0)))
    converged = bool(result.converged)
    # the trace's wall is the steady-state execution, not the compile: cost
    # calibration must see the cost a long run actually pays per step
    named = dict(segments)
    wall = float(named.get("run", sum(s for _, s in segments)))
    record = rcfg.trace_len > 0
    tr = None
    if record:
        if source == "train":
            tr = trace_from_train_run(result, rcfg, p, wall)
        else:
            tr = trace_from_shard_run(result, rcfg, p, wall)
        tr.validate()
    return RunReport(
        converged=converged,
        detected_residual=float(result.residual) if converged else None,
        detect_step=_detect_step(converged, outer),
        outer_iters=outer,
        residual_history=_history(result.trace, outer, rcfg.trace_len),
        wall_segments=list(segments),
        trace=tr,
        membership_log=[],
        x=result.x,
        raw=result,
    )
