"""Fault tolerance & straggler mitigation — the paper's insight applied to
the training runtime.

The PFAIT principle (decisions from *stale, non-blocking* global knowledge,
made safe by a calibrated margin) shapes three runtime policies:

* ``HeartbeatMonitor`` — workers are declared failed from *stale* heartbeat
  views (no global barrier to agree on liveness); the margin is the timeout.
* ``StragglerPolicy``  — per-step durations feed a rolling quantile; a
  worker is a straggler when it exceeds ``factor × p50`` for ``persistence``
  consecutive windows (the NFAIS-style persistence check avoids flapping).
* ``RestartPlan``      — deterministic restart recipe: restore from the
  last committed checkpoint, rebuild the mesh from surviving workers
  (elastic.py), resume the data stream at the checkpoint step (the pipeline
  is keyed by step, so no replay bookkeeping is needed).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class HeartbeatMonitor:
    """Stale-view failure detector (virtual-time friendly for tests)."""

    timeout: float = 30.0
    _last: Dict[int, float] = field(default_factory=dict)

    def register(self, workers: Sequence[int], t: float) -> None:
        """Enroll workers at ``t`` without a beat: a worker that crashes
        before its first heartbeat must still be declared failed once the
        timeout elapses (registration is the virtual beat at enrollment).
        Already-beating workers are left untouched."""
        for w in workers:
            self._last.setdefault(int(w), t)

    def beat(self, worker: int, t: float) -> None:
        self._last[worker] = t

    def failed(self, t: float) -> List[int]:
        return [w for w, lt in self._last.items() if t - lt > self.timeout]

    def alive(self, t: float) -> List[int]:
        return [w for w, lt in self._last.items() if t - lt <= self.timeout]


@dataclass
class StragglerPolicy:
    """Persistence-filtered relative-slowness detector."""

    factor: float = 2.0
    persistence: int = 3
    window: int = 32
    _hist: Dict[int, List[float]] = field(default_factory=dict)
    _count: Dict[int, int] = field(default_factory=dict)

    def record(self, worker: int, duration: float) -> None:
        h = self._hist.setdefault(worker, [])
        h.append(duration)
        if len(h) > self.window:
            h.pop(0)

    def check(self) -> List[int]:
        """Returns workers flagged as persistent stragglers."""
        if not self._hist:
            return []
        medians = {w: float(np.median(h)) for w, h in self._hist.items() if h}
        global_p50 = float(np.median(list(medians.values())))
        out = []
        for w, m in medians.items():
            if m > self.factor * global_p50:
                self._count[w] = self._count.get(w, 0) + 1
            else:
                self._count[w] = 0
            if self._count.get(w, 0) >= self.persistence:
                out.append(w)
        return out


@dataclass(frozen=True)
class PlatformHealth:
    """Post-hoc platform diagnosis from an engine sweep trace (the
    reliability lab's wiring of the runtime policies into the simulator):
    workers that went silent past the heartbeat timeout (scenario pauses /
    crashes) and workers flagged as persistent stragglers."""

    silent_workers: Tuple[int, ...]
    stragglers: Tuple[int, ...]
    max_silence: float            # longest inter-sweep gap observed (any worker)


def health_from_sweeps(
    sweeps: Sequence[Tuple[float, int]],
    p: int,
    timeout: float,
    straggler_factor: float = 3.0,
    straggler_persistence: int = 3,
    check_every: int = 64,
) -> PlatformHealth:
    """Replay ``(t, worker)`` sweep events through the HeartbeatMonitor +
    StragglerPolicy semantics, exactly as a production control loop would
    consume live heartbeats — but offline, against a recorded trace.

    The replay is vectorised (the event-by-event loop was ~10% of a
    reliability-matrix cell): verdicts are identical to feeding the events
    one at a time through the dataclass policies above, which remain the
    live-control-loop API.
    """
    if not sweeps:
        return PlatformHealth(silent_workers=(), stragglers=(),
                              max_silence=0.0)
    times = np.asarray([t for t, _ in sweeps], dtype=np.float64)
    workers = np.asarray([w for _, w in sweeps], dtype=np.int64)
    n = times.shape[0]

    # -- heartbeat replay ---------------------------------------------------
    # At every event the monitor checks t − last_beat[w] > timeout for ALL
    # workers before the sweeping worker beats.  Event times are
    # non-decreasing, so within one inter-beat segment of worker w the check
    # is tightest at the last event of the segment: w is silent iff some
    # consecutive-beat gap (with a virtual beat at t=0) exceeds timeout, or
    # the trace outlives w's final beat by more than timeout.
    silent = []
    max_gap = 0.0
    beat_idx = [np.flatnonzero(workers == w) for w in range(p)]
    for w in range(p):
        beats = np.concatenate([[0.0], times[beat_idx[w]]])
        gaps = np.diff(beats)
        own_gap = float(gaps.max()) if gaps.size else 0.0
        # max_silence mirrors the loop replay: only gaps observed at w's own
        # sweeps count (the tail after the final beat is a *failed* check,
        # not a recorded gap)
        max_gap = max(max_gap, own_gap)
        if own_gap > timeout or times[-1] - beats[-1] > timeout:
            silent.append(w)

    # -- straggler replay ---------------------------------------------------
    # StragglerPolicy keeps the last `window` inter-sweep gaps per worker and
    # is checked every `check_every` events plus once at the end; a worker is
    # flagged after `persistence` consecutive over-median checks.
    window = StragglerPolicy.window
    gap_seq = [np.diff(np.concatenate([[0.0], times[beat_idx[w]]]))
               for w in range(p)]
    # number of gaps worker w has recorded after the first k+1 events:
    # cumulative count of w's occurrences
    counts = np.zeros((p, n), dtype=np.int64)
    for w in range(p):
        counts[w] = np.cumsum(workers == w)
    check_points = list(range(check_every - 1, n, check_every)) + [n - 1]
    straggle = set()
    consec = np.zeros(p, dtype=np.int64)
    for idx in check_points:
        have = counts[:, idx]
        if not have.any():
            continue
        medians = np.full(p, np.nan)
        for w in range(p):
            c = have[w]
            if c:
                medians[w] = np.median(gap_seq[w][max(0, c - window):c])
        seen = ~np.isnan(medians)
        global_p50 = float(np.median(medians[seen]))
        over = seen & (medians > straggler_factor * global_p50)
        # workers with no recorded gap yet have over=False and a counter
        # that is still 0, so the reset below cannot differ from the
        # event-by-event policy (which never touched them)
        consec = np.where(over, consec + 1, 0)
        straggle.update(int(w) for w in np.flatnonzero(
            seen & (consec >= straggler_persistence)))
    return PlatformHealth(
        silent_workers=tuple(sorted(silent)),
        stragglers=tuple(sorted(straggle)),
        max_silence=float(max_gap),
    )


@dataclass(frozen=True)
class RestartPlan:
    checkpoint_step: int
    surviving_workers: Tuple[int, ...]
    new_mesh_shape: Tuple[int, ...]
    data_resume_step: int

    @property
    def world_size(self) -> int:
        return int(np.prod(self.new_mesh_shape))


def plan_restart(
    checkpoint_step: Optional[int],
    workers: Sequence[int],
    failed: Sequence[int],
    model_axis: int = 16,
) -> RestartPlan:
    """Shrink-to-fit elastic restart: drop failed workers, re-factor the
    data axis, resume data at the checkpoint step."""
    survivors = tuple(sorted(set(workers) - set(failed)))
    n = len(survivors)
    if n == 0:
        raise RuntimeError("no survivors to restart with")
    # model axis is fixed by the parallelism plan; data axis shrinks
    data = max(n // model_axis, 1)
    usable = data * model_axis if n >= model_axis else n
    step = checkpoint_step or 0
    return RestartPlan(
        checkpoint_step=step,
        surviving_workers=survivors[:usable],
        new_mesh_shape=(data, model_axis) if n >= model_axis else (1, n),
        data_resume_step=step,
    )
