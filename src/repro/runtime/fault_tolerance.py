"""Fault tolerance & straggler mitigation — the paper's insight applied to
the training runtime.

The PFAIT principle (decisions from *stale, non-blocking* global knowledge,
made safe by a calibrated margin) shapes three runtime policies:

* ``HeartbeatMonitor`` — workers are declared failed from *stale* heartbeat
  views (no global barrier to agree on liveness); the margin is the timeout.
* ``StragglerPolicy``  — per-step durations feed a rolling quantile; a
  worker is a straggler when it exceeds ``factor × p50`` for ``persistence``
  consecutive windows (the NFAIS-style persistence check avoids flapping).
* ``RestartPlan``      — deterministic restart recipe: restore from the
  last committed checkpoint, rebuild the mesh from surviving workers
  (elastic.py), resume the data stream at the checkpoint step (the pipeline
  is keyed by step, so no replay bookkeeping is needed).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class HeartbeatMonitor:
    """Stale-view failure detector (virtual-time friendly for tests)."""

    timeout: float = 30.0
    _last: Dict[int, float] = field(default_factory=dict)

    def beat(self, worker: int, t: float) -> None:
        self._last[worker] = t

    def failed(self, t: float) -> List[int]:
        return [w for w, lt in self._last.items() if t - lt > self.timeout]

    def alive(self, t: float) -> List[int]:
        return [w for w, lt in self._last.items() if t - lt <= self.timeout]


@dataclass
class StragglerPolicy:
    """Persistence-filtered relative-slowness detector."""

    factor: float = 2.0
    persistence: int = 3
    window: int = 32
    _hist: Dict[int, List[float]] = field(default_factory=dict)
    _count: Dict[int, int] = field(default_factory=dict)

    def record(self, worker: int, duration: float) -> None:
        h = self._hist.setdefault(worker, [])
        h.append(duration)
        if len(h) > self.window:
            h.pop(0)

    def check(self) -> List[int]:
        """Returns workers flagged as persistent stragglers."""
        if not self._hist:
            return []
        medians = {w: float(np.median(h)) for w, h in self._hist.items() if h}
        global_p50 = float(np.median(list(medians.values())))
        out = []
        for w, m in medians.items():
            if m > self.factor * global_p50:
                self._count[w] = self._count.get(w, 0) + 1
            else:
                self._count[w] = 0
            if self._count.get(w, 0) >= self.persistence:
                out.append(w)
        return out


@dataclass(frozen=True)
class PlatformHealth:
    """Post-hoc platform diagnosis from an engine sweep trace (the
    reliability lab's wiring of the runtime policies into the simulator):
    workers that went silent past the heartbeat timeout (scenario pauses /
    crashes) and workers flagged as persistent stragglers."""

    silent_workers: Tuple[int, ...]
    stragglers: Tuple[int, ...]
    max_silence: float            # longest inter-sweep gap observed (any worker)


def health_from_sweeps(
    sweeps: Sequence[Tuple[float, int]],
    p: int,
    timeout: float,
    straggler_factor: float = 3.0,
    straggler_persistence: int = 3,
    check_every: int = 64,
) -> PlatformHealth:
    """Replay ``(t, worker)`` sweep events through HeartbeatMonitor +
    StragglerPolicy, exactly as a production control loop would consume
    live heartbeats — but offline, against a recorded trace."""
    hb = HeartbeatMonitor(timeout=timeout)
    sp = StragglerPolicy(factor=straggler_factor,
                         persistence=straggler_persistence)
    for w in range(p):
        hb.beat(w, 0.0)
    last = {w: 0.0 for w in range(p)}
    silent, straggle = set(), set()
    max_gap = 0.0
    for idx, (t, w) in enumerate(sweeps):
        gap = t - last[w]
        max_gap = max(max_gap, gap)
        sp.record(w, gap)
        silent.update(hb.failed(t))
        hb.beat(w, t)
        last[w] = t
        if idx % check_every == check_every - 1:
            straggle.update(sp.check())
    straggle.update(sp.check())
    return PlatformHealth(
        silent_workers=tuple(sorted(silent)),
        stragglers=tuple(sorted(straggle)),
        max_silence=float(max_gap),
    )


@dataclass(frozen=True)
class RestartPlan:
    checkpoint_step: int
    surviving_workers: Tuple[int, ...]
    new_mesh_shape: Tuple[int, ...]
    data_resume_step: int

    @property
    def world_size(self) -> int:
        return int(np.prod(self.new_mesh_shape))


def plan_restart(
    checkpoint_step: Optional[int],
    workers: Sequence[int],
    failed: Sequence[int],
    model_axis: int = 16,
) -> RestartPlan:
    """Shrink-to-fit elastic restart: drop failed workers, re-factor the
    data axis, resume data at the checkpoint step."""
    survivors = tuple(sorted(set(workers) - set(failed)))
    n = len(survivors)
    if n == 0:
        raise RuntimeError("no survivors to restart with")
    # model axis is fixed by the parallelism plan; data axis shrinks
    data = max(n // model_axis, 1)
    usable = data * model_axis if n >= model_axis else n
    step = checkpoint_step or 0
    return RestartPlan(
        checkpoint_step=step,
        surviving_workers=survivors[:usable],
        new_mesh_shape=(data, model_axis) if n >= model_axis else (1, n),
        data_resume_step=step,
    )
