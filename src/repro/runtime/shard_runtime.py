"""Device-resident asynchronous shard runtime — the paper's execution model
on real JAX shards.

Everything before this layer *simulates* the paper's claim: the event
engine (core/async_engine.py) replays asynchronous iterations in virtual
time, and the sharded driver (solvers/fixed_point.py) runs lockstep SPMD
with a pipelined reduction.  This module closes the gap: a shard_map
program where each mesh shard owns a block of the ConvDiff/PageRank state
and the *ingredients of asynchrony are explicit, per-shard quantities*:

* **heterogeneous progress** — shard i performs ``inner_sweeps[i]`` local
  sweeps per exchange (its own iteration count; the bounded-delay model (2)
  of the paper with per-process rates),
* **stale halos** — every exchange lands in a ring of delayed neighbour
  buffers; shard i *consumes* the view from ``halo_delay[i]`` exchanges ago
  (bounded staleness τ ≤ max delay),
* **k-lagged reduction lanes** — in non-blocking mode shard i's reduction
  contribution is its local residual from ``contrib_lag[i]`` checks ago:
  contributions enter the collective at staggered ages, exactly the
  inconsistency of the paper's free-running ``MPI_Iallreduce``.

The global residual is produced three ways, all routed through the same
``core.detection`` monitor (so the existing monitors and the reliability
oracle score them unchanged — the monitor receives a pre-σ reduced scalar
via ``axis_names=None``):

* ``blocking``    — barrier semantics: an *extra* residual-only pass over
  the fresh post-exchange state (detection work on the critical path), the
  psum consumed the same step, monitor staleness forced to 0.  With
  ``halo_delay = 0`` and uniform sweeps this is the synchronous reference:
  its residual trajectory matches the sharded driver to float tolerance.
* ``nonblocking`` — the paper: the contribution is the *free by-product* of
  the last inner sweep (zero extra passes), lanes are k-lagged, and the
  monitor consumes the reduction launched K checks earlier
  (``MonitorConfig.staleness``), leaving detection off the critical path.
* ``rdoubling``   — protocol-based on-device baseline (modified recursive
  doubling, Zou & Magoulès 2019; event-level twin in
  ``core.protocols.RecursiveDoublingProtocol``): one butterfly round per
  outer step over XOR partners via ``ppermute``; a global value completes
  every log2(p) steps and is consumed with that staleness.

``benchmarks/bench_shard_runtime.py`` measures the three against each other
(wall-time + HLO traffic) and the ``shard-runtime`` CI lane gates the
result; ``tests/test_shard_runtime.py`` holds the parity proofs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import detection
from repro.core import residual as res
from repro.core.compat import shard_map_compat as _shard_map
from repro.core.reduction import REDUCTIONS, get_reduction
from repro.kernels.jacobi3d import ops as jac_ops
from repro.kernels.residual_norm import ops as rn_ops
from repro.solvers import gauss_seidel, jacobi
from repro.solvers.convdiff import Stencil
from repro.solvers.fixed_point import _shift, ghosted, ghosted6
from repro.solvers.partition import MeshPartition

P = jax.sharding.PartitionSpec

# REDUCTIONS is re-exported above from repro.core.reduction — the registry is
# the single source of truth; historical importers of
# ``shard_runtime.REDUCTIONS`` keep working.


def _per_shard(v: Union[int, Sequence[int]], p: int, name: str,
               mesh_shape: Optional[Tuple[int, ...]] = None) -> np.ndarray:
    """Broadcast/validate a per-shard config field: a scalar broadcasts over
    all ``p`` shards (row-major over the mesh axes); a sequence must match
    the *total* shard count of the mesh, whatever its dimensionality."""
    arr = np.full(p, v, dtype=np.int32) if np.isscalar(v) else \
        np.asarray(v, dtype=np.int32)
    if arr.shape != (p,):
        where = (f" — mesh shape {tuple(mesh_shape)} has {p} shards total, "
                 "row-major" if mesh_shape is not None else "")
        raise ValueError(
            f"{name} must be a scalar or length-{p}{where}, got {arr.shape}")
    if (arr < 0).any():
        raise ValueError(f"{name} must be >= 0, got {arr.tolist()}")
    return arr


@dataclass(frozen=True)
class ShardRuntimeConfig:
    """Configuration of the asynchronous shard loop (per-shard fields accept
    a scalar or a length-p sequence)."""

    monitor: detection.MonitorConfig
    reduction: str = "nonblocking"   # blocking | nonblocking | rdoubling
    inner_sweeps: Union[int, Sequence[int]] = 1   # per-shard sweeps/exchange
    halo_delay: Union[int, Sequence[int]] = 0     # per-shard neighbour-view age
    contrib_lag: Union[int, Sequence[int]] = 0    # per-shard reduction-lane age
    max_outer: int = 10_000
    trace_len: int = 0               # >0: record the launched-residual series
    sweep: str = "jacobi"            # convdiff only: "jacobi" | "hybrid"
    axis: str = "shard"
    mesh_shape: Optional[Tuple[int, ...]] = None  # (px[,py[,pz]]); None = 1-D
    overlap: bool = False            # comm/compute-overlapped halo exchange

    def __post_init__(self):
        get_reduction(self.reduction)  # registry validation at construction
        if self.sweep not in ("jacobi", "hybrid"):
            raise ValueError(f"sweep {self.sweep!r} not in ('jacobi', 'hybrid')")
        if self.mesh_shape is not None:
            shape = tuple(int(s) for s in self.mesh_shape)
            if not 1 <= len(shape) <= 3 or any(s < 1 for s in shape):
                raise ValueError(
                    f"mesh_shape {self.mesh_shape!r} must be a tuple of 1-3 "
                    "positive ints (px,), (px, py) or (px, py, pz)")
            object.__setattr__(self, "mesh_shape", shape)
        if self.overlap:
            if self.sweep != "jacobi":
                raise ValueError(
                    "overlap=True requires sweep='jacobi': the red-black "
                    "ordering serializes face updates behind the colour "
                    "pass, so there is no independent slab to ship early")
            if self.reduction == "blocking":
                raise ValueError(
                    "overlap=True is incompatible with the blocking barrier "
                    "reference (its exact pass already serializes the step)")

    def effective_monitor(self) -> detection.MonitorConfig:
        """Monitor as the runtime runs it: blocking consumes its reduction
        immediately and recursive doubling carries its own log2(p)-step
        pipeline, so both force the monitor's K to 0; non-blocking keeps the
        configured staleness (the in-flight window)."""
        if get_reduction(self.reduction).forces_zero_staleness \
                and self.monitor.staleness:
            return dataclasses.replace(self.monitor, staleness=0)
        return self.monitor


class ShardRunResult(NamedTuple):
    x: jax.Array              # solution, global layout as input
    residual: jax.Array       # the (possibly stale) residual that fired
    outer_iters: jax.Array    # exchanges performed
    converged: jax.Array
    local_sweeps: jax.Array   # [p] per-shard sweep counts (heterogeneous)
    verifications: jax.Array  # NFAIS2 blocking verifications paid
    trace: jax.Array          # [trace_len] launched global residual per step


class _ShardProblem(NamedTuple):
    """Local view of one shard's problem inside the shard_map body."""

    exchange: Callable      # x_block -> ghosts pytree (the per-step collective)
    sweep: Callable         # (x_block, ghosts) -> x_block'
    sweep_contrib: Callable  # (x_block, ghosts) -> (x_block', pre-σ contrib)
    exact_contrib: Callable  # (x_block, ghosts) -> pre-σ contrib of x_block
    # comm-overlapped final step: (x, ghosts) -> (x', contrib, fresh ghosts).
    # The fresh faces are recomputed as thin slabs *before* the full-block
    # fused sweep, so the ppermute exchange is independent of it and XLA can
    # run the collective while the interior sweeps (None: no overlap).
    fused_step: Optional[Callable] = None


# ---------------------------------------------------------------------------
# Ring buffers (delayed neighbour views / k-lagged lanes)
# ---------------------------------------------------------------------------


def _ring_write(ring, value, step: jax.Array):
    """Write ``value`` at slot ``step mod L`` of every leaf (L = leading dim)."""
    return jax.tree_util.tree_map(
        lambda r, v: jax.lax.dynamic_update_index_in_dim(
            r, v.astype(r.dtype), jnp.mod(step, r.shape[0]), 0),
        ring, value)


def _ring_read(ring, step: jax.Array):
    """Read slot ``max(step, 0) mod L`` of every leaf."""
    idx = jnp.maximum(step, 0)
    return jax.tree_util.tree_map(
        lambda r: jax.lax.dynamic_index_in_dim(
            r, jnp.mod(idx, r.shape[0]), 0, keepdims=False),
        ring)


def _ring_fill(value, length: int):
    """A ring pre-filled with ``value`` in every slot (valid initial views
    for any delay)."""
    return jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (length,) + v.shape), value)


# ---------------------------------------------------------------------------
# Reductions
# ---------------------------------------------------------------------------


def _preduce(contribution: jax.Array, axis: str, ord: float) -> jax.Array:
    """Pre-σ global reduction of local contributions (psum / pmax) — σ is
    applied by ``detection.step`` itself under its ``axis_names=None``
    convention, so the monitor code path is byte-identical to the
    simulator's."""
    if np.isinf(ord):
        return jax.lax.pmax(contribution, axis)
    return jax.lax.psum(contribution, axis)


def _butterfly_rounds(p: int) -> int:
    if p & (p - 1):
        raise ValueError(f"rdoubling requires a power-of-two shard count, got {p}")
    return max(p.bit_length() - 1, 0)


def _butterfly_step(lane, partial, visible, k, p: int, axis: str, ord: float):
    """One round of the modified recursive-doubling reduction: round
    ``k mod log2(p)`` exchanges partials with the XOR partner; a completed
    global value becomes visible every log2(p) steps (the protocol's
    built-in staleness)."""
    rounds = _butterfly_rounds(p)
    if rounds == 0:  # single shard: the lane is the global value
        return lane, lane
    r = jnp.mod(k, rounds)
    base = jnp.where(r == 0, lane, partial)   # fresh epoch samples the lane

    def make_round(rr: int):
        perm = [(i, i ^ (1 << rr)) for i in range(p)]
        return lambda v: jax.lax.ppermute(v, axis, perm)

    recv = jax.lax.switch(r, [make_round(rr) for rr in range(rounds)], base)
    total = jnp.maximum(base, recv) if np.isinf(ord) else base + recv
    visible = jnp.where(r == rounds - 1, total, visible)
    return total, visible


# ---------------------------------------------------------------------------
# Generic asynchronous shard loop
# ---------------------------------------------------------------------------


def _make_loop(cfg: ShardRuntimeConfig, prob: _ShardProblem, p: int,
               rank_fn: Callable[[], jax.Array],
               axes: Optional[Tuple[str, ...]] = None,
               mesh_shape: Optional[Tuple[int, ...]] = None):
    mon_cfg = cfg.effective_monitor()
    ord_ = mon_cfg.ord
    inner = _per_shard(cfg.inner_sweeps, p, "inner_sweeps", mesh_shape)
    if (inner < 1).any():
        raise ValueError("inner_sweeps must be >= 1 per shard")
    delay = _per_shard(cfg.halo_delay, p, "halo_delay", mesh_shape)
    lag = _per_shard(cfg.contrib_lag, p, "contrib_lag", mesh_shape)
    if cfg.reduction == "blocking" and (delay.any() or lag.any()):
        raise ValueError("blocking mode is the synchronous barrier reference: "
                         "halo_delay and contrib_lag must be 0")
    if cfg.reduction == "rdoubling":
        _butterfly_rounds(p)  # validate early, outside the traced body
    Lg = int(delay.max()) + 1
    if prob.fused_step is not None:
        # double-buffered halo ring: the exchange writes slot k+1 while the
        # fused sweep still reads slot k-delay — distinct slots, so the
        # collective never aliases the buffer the kernel is consuming
        Lg = max(Lg, 2)
    Lc = int(lag.max()) + 1
    tlen = max(int(cfg.trace_len), 1)
    # collectives take a single axis name (historical 1-D mesh) or the tuple
    # of all shard axes (multi-axis mesh: reduce over the whole shard space)
    axis = cfg.axis if axes is None else axes

    def loop(x0, *problem_args):
        rank = rank_fn()
        my_inner = jnp.asarray(inner)[rank]
        my_delay = jnp.asarray(delay)[rank]
        my_lag = jnp.asarray(lag)[rank]

        def body(state):
            x, gring, cring, partial, visible, mon, trace, k = state
            ghosts = _ring_read(gring, k - my_delay)

            def plain(_, xx):
                return prob.sweep(xx, ghosts, *problem_args)

            if cfg.reduction == "blocking":
                x = jax.lax.fori_loop(0, my_inner, plain, x)
                contrib = None
                fresh = prob.exchange(x)
            elif prob.fused_step is not None:
                # comm-overlapped step: thin face slabs are swept first and
                # shipped, then the full block sweeps against the *landed*
                # ghosts — the collective and the interior pass commute
                x = jax.lax.fori_loop(0, my_inner - 1, plain, x)
                x, contrib, fresh = prob.fused_step(x, ghosts, *problem_args)
            else:
                x = jax.lax.fori_loop(0, my_inner - 1, plain, x)
                x, contrib = prob.sweep_contrib(x, ghosts, *problem_args)
                fresh = prob.exchange(x)

            gring = _ring_write(gring, fresh, k + 1)
            if contrib is None:
                # barrier mode: detection pays a residual-only pass over the
                # fresh post-exchange state, every check
                contrib = prob.exact_contrib(x, fresh, *problem_args)
            cring = _ring_write(cring, contrib, k)
            lane = _ring_read(cring, k - my_lag)

            if cfg.reduction == "rdoubling":
                partial, visible = _butterfly_step(
                    lane, partial, visible, k, p, axis, ord_)
                g_pre = visible
            else:
                g_pre = _preduce(lane, axis, ord_)

            trace = trace.at[jnp.minimum(k, tlen - 1)].set(
                jnp.where(k < tlen, res.sigma(g_pre, ord_).astype(jnp.float32),
                          trace[jnp.minimum(k, tlen - 1)]))

            def exact_fn(x=x, fresh=fresh):
                # NFAIS2's verification: a *blocking* exact reduction of the
                # fresh state, paid lazily under the monitor's lax.cond
                return res.psum_sigma(
                    prob.exact_contrib(x, fresh, *problem_args), axis, ord_)

            mon = detection.step(mon_cfg, mon, g_pre, axis_names=None,
                                 exact_residual_fn=exact_fn)
            return x, gring, cring, partial, visible, mon, trace, k + 1

        def cond(state):
            mon, k = state[5], state[7]
            return (~mon.converged) & (k < cfg.max_outer)

        ghosts0 = prob.exchange(x0)
        state0 = (
            x0,
            _ring_fill(ghosts0, Lg),
            jnp.full((Lc,), jnp.inf, jnp.float32),
            jnp.full((), jnp.inf, jnp.float32),   # butterfly partial
            jnp.full((), jnp.inf, jnp.float32),   # butterfly visible
            detection.init_state(mon_cfg),
            jnp.full((tlen,), jnp.inf, jnp.float32),
            jnp.zeros((), jnp.int32),
        )
        x, _, _, _, _, mon, trace, k = jax.lax.while_loop(cond, body, state0)
        return ShardRunResult(
            x=x,
            residual=mon.detected_residual,
            outer_iters=k,
            converged=mon.converged,
            local_sweeps=(k * my_inner)[None],
            verifications=mon.verifications,
            trace=trace,
        )

    return loop


def _result_specs(cfg: ShardRuntimeConfig, x_spec,
                  axes: Optional[Tuple[str, ...]] = None) -> ShardRunResult:
    # local_sweeps is [p] with one entry per shard: on a multi-axis mesh the
    # per-shard scalars concatenate row-major over the tuple of shard axes
    sweeps_spec = P(cfg.axis) if axes is None else P(axes)
    return ShardRunResult(
        x=x_spec, residual=P(), outer_iters=P(), converged=P(),
        local_sweeps=sweeps_spec, verifications=P(), trace=P(),
    )


# ---------------------------------------------------------------------------
# ConvDiff shards (1-D pencils or 2-D/3-D blocks, stale-halo exchange)
# ---------------------------------------------------------------------------


def _make_convdiff_mesh_runtime(cfg: ShardRuntimeConfig, mesh, stencil:
                                Stencil, n: int):
    """Multi-axis (or comm-overlapped) convdiff runtime.

    The grid tiles by ``solvers.partition.MeshPartition`` over the mesh's
    shard axes; each shard owns an ``n/px × n/py × n/pz`` block and
    exchanges one face plane per partitioned direction per outer step
    (faces on unpartitioned directions are the physical boundary, ghost
    value 0).  Sweeps route through the halo-consuming jacobi3d entries
    (``ops.sweep_halo``/``sweep_with_contribution_halo``) which keep the
    single-HBM-pass fused sweep+residual, so ``core.detection`` and every
    reduction consume the same free by-product as the 1-D path.

    With ``cfg.overlap`` the final sweep of each outer step is the
    comm-overlapped ``fused_step``: the *new* face values are recomputed
    early as thickness-1 slabs (bitwise-identical to the faces the full
    sweep produces — same inputs, same operation order), the ``ppermute``
    is issued on those slabs against ring slot k+1, and the full fused
    sweep+residual then runs against the landed slot k-delay ghosts with
    no data dependence on the in-flight collective.
    """
    axes = tuple(mesh.axis_names)
    shape = tuple(int(mesh.shape[a]) for a in axes)
    part = MeshPartition(n, shape)
    p = part.p
    ndim = part.ndim
    block = tuple(n // s for s in part.full_shape)   # (bx, by, bz)
    parted = tuple(d for d in range(ndim) if shape[d] > 1)
    plane = {0: (block[1], block[2]), 1: (block[0], block[2]),
             2: (block[0], block[1])}
    st = stencil
    ord_ = cfg.monitor.ord
    if cfg.overlap:
        for d in parted:
            if block[d] < 2:
                raise ValueError(
                    "overlap=True needs block extent >= 2 on every "
                    f"partitioned axis: mesh {shape} at n={n} gives "
                    f"block {block}")

    def _face(x, d, last):
        return jax.lax.index_in_dim(x, x.shape[d] - 1 if last else 0, d,
                                    keepdims=False)

    def _ship(faces):
        """ppermute each partitioned direction's (minus, plus) face pair to
        the respective neighbours; edge shards receive zeros (Dirichlet)."""
        out = []
        for d in parted:
            fm, fp = faces[d]
            gm = _shift(fp, axes[d], up=True, axis_size=shape[d])
            gp = _shift(fm, axes[d], up=False, axis_size=shape[d])
            out.append((gm, gp))
        return tuple(out)

    def exchange(x):
        return _ship({d: (_face(x, d, False), _face(x, d, True))
                      for d in parted})

    def _halos6(x, faces):
        """Six face planes for the halo-consuming sweeps: exchanged ghosts
        on partitioned directions, zeros (physical BC) elsewhere."""
        h, fi = [], 0
        for d in range(3):
            if d in parted:
                gm, gp = faces[fi]
                fi += 1
            else:
                gm = gp = jnp.zeros(plane[d], x.dtype)
            h.extend((gm, gp))
        return tuple(h)

    def _offsets():
        return tuple(
            jax.lax.axis_index(axes[d]) * block[d] if d < ndim else 0
            for d in range(3))

    def sweep(x, faces, b):
        h = _halos6(x, faces)
        if cfg.sweep == "jacobi":
            return jac_ops.sweep_halo(st, x, h, b)
        ox, oy, oz = _offsets()
        return jac_ops.sweep_halo(st, x, h, b, sweep="hybrid",
                                  ox=ox, oy=oy, oz=oz)

    def sweep_contrib(x, faces, b):
        h = _halos6(x, faces)
        ox, oy, oz = _offsets() if cfg.sweep == "hybrid" else (0, 0, 0)
        return jac_ops.sweep_with_contribution_halo(
            st, x, h, b, sweep=cfg.sweep, ox=ox, oy=oy, oz=oz, ord=ord_)

    def exact_contrib(x, faces, b):
        return jac_ops.residual_contribution_halo(st, x, _halos6(x, faces),
                                                  b, ord=ord_)

    def _face_sweep(x, h6, b, d, last):
        """The new values of one face of the block, as the full Jacobi sweep
        will produce them, from a thickness-1 slab: same stencil inputs in
        the same operation order, so the result is bitwise-identical to the
        corresponding face of ``sweep(x, ...)`` — cheap enough to compute
        *before* the full sweep and hand to the exchange."""
        idx = x.shape[d] - 1 if last else 0
        slab = jax.lax.slice_in_dim(x, idx, idx + 1, axis=d)
        b_slab = jax.lax.slice_in_dim(b, idx, idx + 1, axis=d)
        sg = []
        for e in range(3):
            if e == d:
                # along the face normal: one side is the landed ghost, the
                # other the adjacent in-block plane (block extent >= 2)
                gm = h6[2 * d] if not last else \
                    jax.lax.index_in_dim(x, idx - 1, d, keepdims=False)
                gp = jax.lax.index_in_dim(x, idx + 1, d, keepdims=False) \
                    if not last else h6[2 * d + 1]
            else:
                # transverse: the block's e-ghost planes restricted to the
                # slab's row (axis d sits at position d or d-1 of the plane)
                pos = d if d < e else d - 1
                gm = jax.lax.slice_in_dim(h6[2 * e], idx, idx + 1, axis=pos)
                gp = jax.lax.slice_in_dim(h6[2 * e + 1], idx, idx + 1,
                                          axis=pos)
            sg.extend((gm, gp))
        new_slab = jacobi.jacobi_sweep(st, ghosted6(slab, tuple(sg)), b_slab)
        return jnp.squeeze(new_slab, axis=d)

    def fused_step(x, faces, b):
        h = _halos6(x, faces)
        fresh = _ship({d: (_face_sweep(x, h, b, d, False),
                           _face_sweep(x, h, b, d, True)) for d in parted})
        new, contrib = jac_ops.sweep_with_contribution_halo(
            st, x, h, b, sweep="jacobi", ord=ord_)
        return new, contrib, fresh

    def rank_fn():
        r = jnp.zeros((), jnp.int32)
        for d in range(ndim):
            r = r * shape[d] + jax.lax.axis_index(axes[d])
        return r

    prob = _ShardProblem(exchange, sweep, sweep_contrib, exact_contrib,
                         fused_step if cfg.overlap else None)
    loop = _make_loop(cfg, prob, p, rank_fn, axes=axes, mesh_shape=shape)
    spec = P(*axes, *([None] * (3 - ndim)))
    return _shard_map(loop, mesh=mesh, in_specs=(spec, spec),
                      out_specs=_result_specs(cfg, spec, axes=axes))


def make_convdiff_runtime(cfg: ShardRuntimeConfig, mesh, stencil: Stencil,
                          n: int):
    """Build ``run(x0, b) -> ShardRunResult`` over a shard mesh.

    ``x0, b`` are global (n, n, n) arrays sharded over the mesh's shard
    axes.  On the historical 1-D mesh each shard owns an x-pencil of
    ``n // p`` planes and exchanges its two x-faces per outer step (y/z
    faces are the physical boundary); that path is kept byte-identical in
    lowering (the HBM-exact CI gate pins it).  A multi-axis mesh — or
    ``cfg.overlap`` — routes to the block-decomposed mesh runtime.
    """
    axes = tuple(getattr(mesh, "axis_names", (cfg.axis,)))
    if cfg.mesh_shape is not None:
        mshape = tuple(int(mesh.shape[a]) for a in axes)
        if cfg.mesh_shape != mshape:
            raise ValueError(
                f"cfg.mesh_shape {cfg.mesh_shape} does not match the mesh's "
                f"shard axes {dict(zip(axes, mshape))}")
    if len(axes) > 1 or cfg.overlap:
        return _make_convdiff_mesh_runtime(cfg, mesh, stencil, n)
    axis = cfg.axis
    p = mesh.shape[axis]
    if n % p:
        raise ValueError(f"n={n} not divisible by shard count p={p}")
    bx = n // p
    st = stencil
    ord_ = cfg.monitor.ord

    def exchange(x):
        gxm = _shift(x[-1, :, :], axis, up=True, axis_size=p)
        gxp = _shift(x[0, :, :], axis, up=False, axis_size=p)
        return gxm, gxp

    def _ghosted(x, ghosts):
        gxm, gxp = ghosts
        zero = jnp.zeros((x.shape[0], x.shape[2]), x.dtype)
        return ghosted(x, (gxm, gxp, zero, zero))  # y ghosts = BC = 0

    def _offsets():
        return jax.lax.axis_index(axis) * bx, 0

    def sweep(x, ghosts, b):
        g = _ghosted(x, ghosts)
        if cfg.sweep == "jacobi":
            return jacobi.jacobi_sweep(st, g, b)
        ox, oy = _offsets()
        return gauss_seidel.redblack_gs_sweep(st, g, b, ox, oy)

    def sweep_contrib(x, ghosts, b):
        g = _ghosted(x, ghosts)
        if cfg.sweep == "jacobi":
            new = jacobi.jacobi_sweep(st, g, b)
            # Jacobi residual is the update difference scaled by the
            # diagonal: fused diff-norm via the residual_norm kernel ops
            return new, rn_ops.update_contribution(new, x, ord=ord_,
                                                   scale=st.diag)
        ox, oy = _offsets()
        new, r = gauss_seidel.redblack_gs_sweep_residual(st, g, b, ox, oy)
        return new, res.local_contribution(r, ord_)

    def exact_contrib(x, ghosts, b):
        return res.local_contribution(
            jacobi.residual_block(st, _ghosted(x, ghosts), b), ord_)

    prob = _ShardProblem(exchange, sweep, sweep_contrib, exact_contrib)
    loop = _make_loop(cfg, prob, p, lambda: jax.lax.axis_index(axis))
    spec = P(axis, None, None)
    return _shard_map(loop, mesh=mesh, in_specs=(spec, spec),
                      out_specs=_result_specs(cfg, spec))


# ---------------------------------------------------------------------------
# PageRank shards (row blocks, stale all-gathered state views)
# ---------------------------------------------------------------------------


def make_pagerank_runtime(cfg: ShardRuntimeConfig, mesh, n: int,
                          damping: float = 0.85):
    """Build ``run(x0, P_dense) -> ShardRunResult`` over a 1-D shard mesh.

    ``x0`` is the global (n,) state sharded ``P(axis)``; ``P_dense`` the
    (n, n) column-stochastic operator sharded by rows ``P(axis, None)``.
    The "halo" is the full state view assembled by all-gather; staleness
    delays the *consumed* view, while a shard's own block is always
    current (the asynchronous-iterations convention).
    """
    if len(getattr(mesh, "axis_names", (cfg.axis,))) != 1:
        raise ValueError(
            "pagerank shards are 1-D row blocks; got mesh axes "
            f"{tuple(mesh.axis_names)} — multi-axis meshes are convdiff-only")
    if cfg.overlap:
        raise ValueError("overlap=True is convdiff-only (pagerank has no "
                         "halo ring: its exchange is an all-gather)")
    axis = cfg.axis
    p = mesh.shape[axis]
    if n % p:
        raise ValueError(f"n={n} not divisible by shard count p={p}")
    nb = n // p
    d = float(damping)
    v = (1.0 - d) / n
    ord_ = cfg.monitor.ord

    def exchange(x):
        return jax.lax.all_gather(x, axis, tiled=True)

    def _own_current(x, view):
        start = jax.lax.axis_index(axis) * nb
        return jax.lax.dynamic_update_slice(view, x.astype(view.dtype),
                                            (start,))

    def sweep(x, view, P_rows):
        return d * (P_rows @ _own_current(x, view)) + v

    def sweep_contrib(x, view, P_rows):
        new = sweep(x, view, P_rows)
        # D-iteration residual = the update difference (scale 1)
        return new, rn_ops.update_contribution(new, x, ord=ord_)

    def exact_contrib(x, view, P_rows):
        return res.local_contribution(sweep(x, view, P_rows) - x, ord_)

    prob = _ShardProblem(exchange, sweep, sweep_contrib, exact_contrib)
    loop = _make_loop(cfg, prob, p, lambda: jax.lax.axis_index(axis))
    return _shard_map(loop, mesh=mesh, in_specs=(P(axis), P(axis, None)),
                      out_specs=_result_specs(cfg, P(axis)))


# ---------------------------------------------------------------------------
# Family dispatch (benchmarks + the elastic restart driver)
# ---------------------------------------------------------------------------


FAMILIES = ("convdiff", "pagerank")


def make_runtime(family: str, cfg: ShardRuntimeConfig, mesh, n: int, *,
                 stencil: Optional[Stencil] = None, damping: float = 0.85):
    """``run(x0, problem_arg) -> ShardRunResult`` for a problem family.

    .. deprecated:: Prefer ``repro.runtime.api.run_shard`` (unified
       ``RuntimeConfig``/``RunReport`` surface).  This builder remains the
       compatibility shim the unified API routes through — signature and
       return type are frozen.

    One entry point for every caller that must rebuild the runtime against
    a *changing* mesh (the elastic driver re-invokes it after each
    remesh — per-shard config fields must then be scalars, since a
    length-p sequence is pinned to the old shard count)."""
    if family == "convdiff":
        if stencil is None:
            raise ValueError("convdiff runtime requires stencil=")
        return make_convdiff_runtime(cfg, mesh, stencil, n)
    if family == "pagerank":
        return make_pagerank_runtime(cfg, mesh, n, damping)
    raise KeyError(f"family {family!r} not in {FAMILIES}")


def state_spec(family: str, axis: str = "shard") -> P:
    """PartitionSpec of the solution array on a 1-D shard mesh."""
    if family == "convdiff":
        return P(axis, None, None)
    if family == "pagerank":
        return P(axis)
    raise KeyError(f"family {family!r} not in {FAMILIES}")


def mesh_state_spec(family: str, mesh) -> P:
    """PartitionSpec of the solution array on any shard mesh (1-D, 2-D or
    3-D): one spec dim per shard axis, trailing dims replicated."""
    axes = tuple(mesh.axis_names)
    if family == "convdiff":
        return P(*axes, *([None] * (3 - len(axes))))
    if family == "pagerank":
        if len(axes) != 1:
            raise ValueError(f"pagerank shards are 1-D; got axes {axes}")
        return P(axes[0])
    raise KeyError(f"family {family!r} not in {FAMILIES}")


# ---------------------------------------------------------------------------
# Synchronous references (parity oracles — tests/benchmarks)
# ---------------------------------------------------------------------------


def convdiff_reference_trace(stencil: Stencil, b: jax.Array, steps: int,
                             ord: float = 2.0,
                             x0: Optional[jax.Array] = None) -> jax.Array:
    """Global synchronous Jacobi trajectory: entry k is the exact residual
    after k+1 sweeps — what the blocking runtime must reproduce."""
    x = jnp.zeros_like(b) if x0 is None else x0

    def step(x, _):
        zero = (jnp.zeros((b.shape[1], b.shape[2]), b.dtype),) * 2
        zy = (jnp.zeros((x.shape[0], b.shape[2]), b.dtype),) * 2
        g = ghosted(x, zero + zy)
        x = jacobi.jacobi_sweep(stencil, g, b)
        g = ghosted(x, zero + zy)
        r = res.local_contribution(
            jacobi.residual_block(stencil, g, b), ord)
        return x, res.sigma(r, ord).astype(jnp.float32)

    _, trace = jax.lax.scan(step, x, None, length=steps)
    return trace


def pagerank_reference_trace(P_dense: jax.Array, n: int, steps: int,
                             damping: float = 0.85,
                             ord: float = 1.0) -> jax.Array:
    """Global synchronous D-iteration trajectory (post-step residuals)."""
    d = float(damping)
    v = (1.0 - d) / n
    x = jnp.full((n,), 1.0 / n, P_dense.dtype)

    def step(x, _):
        x = d * (P_dense @ x) + v
        r = res.local_contribution(d * (P_dense @ x) + v - x, ord)
        return x, res.sigma(r, ord).astype(jnp.float32)

    _, trace = jax.lax.scan(step, x, None, length=steps)
    return trace
