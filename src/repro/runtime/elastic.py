"""Elastic re-meshing + the fault-injected shard-runtime driver.

Two layers:

* **Mesh surgery** (`remesh` / `validate_specs` / `reshard`): rebuild a mesh
  after membership changes and reshard a (topology-free) checkpoint onto it.
  The checkpoint stores host arrays (checkpoint/checkpointer.py); resharding
  is a ``device_put`` with the new mesh's shardings, so scale-up/down only
  requires that the new mesh's axes still divide the sharded dims —
  validated here before any data movement.

* **Elastic control loop** (`run_elastic`): the crash → detect → restart →
  resume cycle for the device-resident asynchronous shard runtime
  (runtime/shard_runtime.py).  The solve is split into fixed-length
  *segments* (one virtual time unit each); between segments the control
  plane runs the production fault-tolerance policies **live**:

    1. every alive shard heartbeats (`HeartbeatMonitor`) and reports its
       segment duration (`StragglerPolicy`) — a shard killed by the
       `FaultPlan` stops beating, and because the SPMD collective cannot
       complete without it, the *whole job stalls* (no iterations happen)
       until the failure is detected;
    2. once the heartbeat timeout elapses, `plan_restart` drops the dead
       shards, `shrink_to_fit` picks the largest usable shard count, and
       the last committed checkpoint restores onto the shrunk mesh
       (`Checkpointer.restore` + the new mesh's shardings) — rolling back
       to the checkpointed outer iteration;
    3. the runtime is rebuilt against the new mesh with the **unchanged
       detection monitor** and iteration resumes.  Late joiners scale the
       mesh back up from *live* state (a host gather + reshard — no
       rollback, nothing to restore).

  Crash detection is therefore paid in stalled segments and rolled-back
  iterations — exactly the recovery cost ``benchmarks/bench_elastic.py``
  reports next to each protocol's detection reliability.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def remesh(n_devices: int, model_axis: int, devices=None) -> Mesh:
    """Largest (data, model) mesh that fits n_devices."""
    data = max(n_devices // model_axis, 1)
    model = model_axis if n_devices >= model_axis else n_devices
    devs = (devices or jax.devices())[: data * model]
    arr = np.asarray(devs).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def validate_specs(tree_struct: Any, specs: Any, mesh: Mesh) -> bool:
    """Check every sharded dim divides on the new mesh."""
    ok = True

    def chk(s, spec):
        nonlocal ok
        if not isinstance(spec, P):
            return
        for dim, names in zip(s.shape, tuple(spec) + (None,) * (len(s.shape) - len(spec))):
            if names is None:
                continue
            names_t = names if isinstance(names, tuple) else (names,)
            size = int(np.prod([mesh.shape[n] for n in names_t]))
            if dim % size:
                ok = False

    jax.tree.map(chk, tree_struct, specs,
                 is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
    return ok


def reshard(tree: Any, specs: Any, mesh: Mesh) -> Any:
    """Place host (or differently-sharded) arrays onto ``mesh``."""
    return jax.tree.map(
        lambda x, spec: jax.device_put(np.asarray(jax.device_get(x)),
                                       NamedSharding(mesh, spec)),
        tree, specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Elastic shard-runtime control loop
# ---------------------------------------------------------------------------


def shrink_to_fit(n: int, survivors: int, reduction: str = "nonblocking") -> int:
    """Largest shard count ≤ ``survivors`` the runtime can actually use:
    it must divide the block dimension ``n``, and the reduction mode's
    topology facts (``core.reduction``) must admit it — recursive doubling
    needs a power-of-two butterfly (the event-level protocol folds
    remainders; the device twin keeps the classic geometry)."""
    from repro.core.reduction import get_reduction

    mode = get_reduction(reduction)   # validates the name too
    if survivors < 1:
        raise ValueError("no survivors to fit a mesh to")
    for p in range(min(int(survivors), int(n)), 0, -1):
        if n % p:
            continue
        if not mode.usable_shard_count(p):
            continue
        return p
    raise ValueError(f"no usable shard count for n={n}, "
                     f"survivors={survivors}, reduction={reduction!r}")


@dataclass(frozen=True)
class FaultPlan:
    """Deterministic fault schedule, in segment indices (virtual time).

    ``crash_at[w] = s``  — worker w dies *during* segment s: the segment's
                           collective never completes (its work is lost)
                           and w never heartbeats again.
    ``join_at[w] = s``   — standby worker w becomes available at the end of
                           segment s (hot scale-up from live state).
    ``slow[w] = f``      — worker w's reported segment duration is scaled
                           by f (feeds the straggler policy; pure
                           control-plane signal on an emulated mesh).
    """

    crash_at: Mapping[int, int] = field(default_factory=dict)
    join_at: Mapping[int, int] = field(default_factory=dict)
    slow: Mapping[int, float] = field(default_factory=dict)

    def __post_init__(self):
        for w, s in {**self.crash_at, **self.join_at}.items():
            if w < 0 or s < 0:
                raise ValueError(f"fault plan entry ({w}: {s}) must be >= 0")
        both = set(self.crash_at) & set(self.join_at)
        for w in both:
            if self.join_at[w] <= self.crash_at[w]:
                raise ValueError(
                    f"worker {w} rejoins at segment {self.join_at[w]} but "
                    f"only crashes at {self.crash_at[w]} — repair must "
                    "follow the crash")


@dataclass
class ElasticReport:
    """Outcome + recovery accounting of one elastic run."""

    converged: bool
    detected_residual: Optional[float]
    outer_iters: int              # surviving outer iterations at the end
    segments_run: int
    restarts: int
    stall_segments: int           # segments lost to undetected-crash stalls
    lost_iters: int               # iterations rolled back to checkpoints
    detect_latency: List[float]   # segments from each crash to its detection
    checkpoint_saves: int
    mesh_history: List[Tuple[int, int]]   # (segment, shard count) changes
    stragglers_flagged: List[int]
    members_final: Tuple[int, ...]
    x: np.ndarray                 # final global solution (host)
    events: List[Tuple[int, str, str]] = field(default_factory=list)


def _arg_spec(family: str, axis: str) -> P:
    if family == "convdiff":
        return P(axis, None, None)
    return P(axis, None)  # pagerank row-blocked operator


def run_elastic(
    family: str,
    cfg,                       # ShardRuntimeConfig (scalar per-shard fields)
    n: int,
    x0: np.ndarray,
    arg: np.ndarray,           # convdiff: rhs b | pagerank: dense operator
    plan: FaultPlan,
    ckpt_dir: str,
    *,
    stencil=None,
    damping: float = 0.85,
    p0: Optional[int] = None,
    segment_len: int = 40,
    ckpt_every: int = 2,
    heartbeat_timeout: float = 2.2,
    max_segments: int = 80,
    straggler_policy=None,
    keep: int = 3,
) -> ElasticReport:
    """Run the asynchronous shard runtime to convergence through the fault
    plan.

    .. deprecated:: Prefer ``repro.runtime.api.run_elastic`` (unified
       ``RuntimeConfig``/``RunReport`` surface, schema-trace attachment).
       This driver remains the compatibility shim the unified API routes
       through — signature and ``ElasticReport`` return type are frozen.

    See the module docstring for the control-loop semantics; notable
    contracts:

    * per-shard config fields must be scalars (the shard count changes
      mid-run, so a length-p sequence cannot follow the mesh);
    * ``cfg.max_outer`` is ignored — the driver owns segmentation
      (``segment_len`` outers per segment, ``max_segments`` budget);
    * the detection monitor config is reused unchanged across restarts
      (its device state re-initialises inside each rebuilt program — the
      in-flight reduction pipeline of a dead collective is not salvageable,
      but the *policy* that decides termination never changes);
    * a committed checkpoint of the initial state is written synchronously
      before the first segment, so recovery is always possible.
    """
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.launch.mesh import make_shard_mesh
    from repro.runtime.fault_tolerance import (
        HeartbeatMonitor, StragglerPolicy, plan_restart)
    from repro.runtime.shard_runtime import make_runtime, state_spec

    for name in ("inner_sweeps", "halo_delay", "contrib_lag"):
        if not np.isscalar(getattr(cfg, name)):
            raise ValueError(
                f"elastic runs need scalar {name} (shard count changes)")
    n_dev = len(jax.devices())
    p0 = int(p0 if p0 is not None else n_dev)
    if shrink_to_fit(n, p0, cfg.reduction) != p0:
        raise ValueError(f"initial shard count p0={p0} unusable for n={n}, "
                         f"reduction={cfg.reduction!r}")
    axis = cfg.axis
    xspec = state_spec(family, axis)
    aspec = _arg_spec(family, axis)
    x_host = np.asarray(x0)
    arg_host = np.asarray(arg)

    ck = Checkpointer(ckpt_dir, keep=keep)
    hb = HeartbeatMonitor(timeout=float(heartbeat_timeout))
    strag = straggler_policy or StragglerPolicy()
    members: Tuple[int, ...] = tuple(range(p0))
    hb.register(members, 0.0)
    dead: set = set()
    flagged: set = set()
    report = ElasticReport(
        converged=False, detected_residual=None, outer_iters=0,
        segments_run=0, restarts=0, stall_segments=0, lost_iters=0,
        detect_latency=[], checkpoint_saves=0, mesh_history=[],
        stragglers_flagged=[], members_final=members, x=x_host)
    crash_seen: Dict[int, int] = {}     # worker -> segment its crash landed

    cfg_seg = dataclasses.replace(cfg, max_outer=int(segment_len))
    compiled: Dict[int, Callable] = {}

    def build(p_cur: int, seg: int):
        """(Re)build the runtime + device placement for ``p_cur`` shards."""
        mesh = make_shard_mesh(p_cur)
        if p_cur not in compiled:
            compiled[p_cur] = jax.jit(make_runtime(
                family, cfg_seg, mesh, n, stencil=stencil, damping=damping))
        x_dev = jax.device_put(x_host, NamedSharding(mesh, xspec))
        arg_dev = jax.device_put(arg_host, NamedSharding(mesh, aspec))
        report.mesh_history.append((seg, p_cur))
        return compiled[p_cur], x_dev, arg_dev

    p_cur = p0
    run, x_dev, arg_dev = build(p_cur, 0)
    ck.save(x_dev, step=0, blocking=True)   # recovery floor
    report.checkpoint_saves += 1
    outer_done = 0

    for seg in range(int(max_segments)):
        report.segments_run = seg + 1
        t_end = float(seg + 1)
        for w in members:
            if w not in dead and plan.crash_at.get(w) == seg:
                dead.add(w)
                crash_seen[w] = seg
                report.events.append((seg, "crash", f"worker {w}"))
        stalled = any(w in dead for w in members[:p_cur])
        if not stalled:
            r = run(x_dev, arg_dev)
            x_dev = r.x
            outer_done += int(r.outer_iters)
            if bool(r.converged):
                report.converged = True
                report.detected_residual = float(r.residual)
                report.events.append((seg, "detect", f"g={r.residual:.3e}"))
                break
        else:
            report.stall_segments += 1
        # -- live control plane: heartbeats + straggler quantiles ----------
        for w in members:
            if w not in dead:
                hb.beat(w, t_end)
                strag.record(w, float(plan.slow.get(w, 1.0)))
        flagged.update(strag.check())
        failed = [w for w in hb.failed(t_end) if w in members]
        if failed:
            ck.wait()                     # flush (and surface) async saves
            step = ck.latest_step() or 0
            rplan = plan_restart(step, workers=members, failed=failed,
                                 model_axis=1)
            members = rplan.surviving_workers
            report.lost_iters += max(outer_done - step, 0)
            for w in failed:
                report.detect_latency.append(
                    t_end - float(crash_seen.get(w, seg)))
            outer_done = step
            p_cur = shrink_to_fit(n, min(len(members), n_dev),
                                  cfg.reduction)
            restored, _ = ck.restore(
                step, like=0,
                shardings=NamedSharding(make_shard_mesh(p_cur), xspec))
            x_host = np.asarray(jax.device_get(restored))
            run, x_dev, arg_dev = build(p_cur, seg + 1)
            report.restarts += 1
            report.events.append(
                (seg, "restart", f"survivors={members} p={p_cur} "
                                 f"rollback_to={step}"))
            continue
        joining = tuple(sorted(
            w for w, s in plan.join_at.items()
            if s <= seg and w not in members
            and (w not in dead or s > plan.crash_at.get(w, -1))))
        if joining and not stalled:
            dead -= set(joining)          # a repaired worker rejoins clean
            members = tuple(sorted(set(members) | set(joining)))
            hb.register(joining, t_end)
            # workers beyond the host's device count stay spares: members
            # for the control plane, not shards of the mesh
            p_new = shrink_to_fit(n, min(len(members), n_dev),
                                  cfg.reduction)
            report.events.append(
                (seg, "join", f"workers {joining} p={p_cur}->{p_new}"))
            if p_new != p_cur:
                # hot scale-up: gather live state, reshard, keep iterating
                x_host = np.asarray(jax.device_get(x_dev))
                p_cur = p_new
                run, x_dev, arg_dev = build(p_cur, seg + 1)
        if not stalled and (seg + 1) % int(ckpt_every) == 0:
            ck.save(x_dev, step=outer_done)       # async
            report.checkpoint_saves += 1

    ck.wait()
    report.outer_iters = outer_done
    report.members_final = members
    report.stragglers_flagged = sorted(flagged)
    report.x = np.asarray(jax.device_get(x_dev))
    return report
