"""Elastic re-meshing: rebuild a mesh after membership changes and reshard
a (topology-free) checkpoint onto it.

The checkpoint stores host arrays (checkpoint/checkpointer.py); resharding
is a ``device_put`` with the new mesh's shardings, so scale-up/down only
requires that the new mesh's model axis still divides the sharded dims —
validated here before any data movement.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def remesh(n_devices: int, model_axis: int, devices=None) -> Mesh:
    """Largest (data, model) mesh that fits n_devices."""
    data = max(n_devices // model_axis, 1)
    model = model_axis if n_devices >= model_axis else n_devices
    devs = (devices or jax.devices())[: data * model]
    arr = np.asarray(devs).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def validate_specs(tree_struct: Any, specs: Any, mesh: Mesh) -> bool:
    """Check every sharded dim divides on the new mesh."""
    ok = True

    def chk(s, spec):
        nonlocal ok
        if not isinstance(spec, P):
            return
        for dim, names in zip(s.shape, tuple(spec) + (None,) * (len(s.shape) - len(spec))):
            if names is None:
                continue
            names_t = names if isinstance(names, tuple) else (names,)
            size = int(np.prod([mesh.shape[n] for n in names_t]))
            if dim % size:
                ok = False

    jax.tree.map(chk, tree_struct, specs,
                 is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
    return ok


def reshard(tree: Any, specs: Any, mesh: Mesh) -> Any:
    """Place host (or differently-sharded) arrays onto ``mesh``."""
    return jax.tree.map(
        lambda x, spec: jax.device_put(np.asarray(jax.device_get(x)),
                                       NamedSharding(mesh, spec)),
        tree, specs,
        is_leaf=lambda x: isinstance(x, P),
    )
