"""Async checkpointing with elastic (topology-changing) restore.

Layout per step::

    <dir>/step_000120/
        manifest.json     # step, leaf paths, shapes/dtypes, tree structure
        leaf_00000.npy …  # one array per pytree leaf (host-gathered)
        _COMMITTED        # written last — partial checkpoints are ignored

Saves run on a background thread over a host snapshot (``jax.device_get``
happens synchronously — cheap relative to a step — and serialization runs
async), so training never blocks on the filesystem.  ``restore`` reshapes
onto *any* mesh via ``jax.device_put`` with the target shardings — the
checkpoint is topology-free (elastic restarts, DESIGN §5).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# custom (ml_dtypes) dtypes don't round-trip through np.save; store them as
# same-width uint views with the logical dtype recorded in the manifest
_VIEW_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray):
    name = arr.dtype.name
    if name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[name]), name
    return arr, name


def _decode(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _VIEW_DTYPES:
        return arr.view(getattr(ml_dtypes, name))
    return arr


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:06d}")


def _parse_step(name: str) -> Optional[int]:
    """Step number of a ``step_NNNNNN`` directory name, or None for
    anything malformed (stray files, ``step_`` without digits, tmp dirs) —
    a foreign file in the checkpoint dir must not crash GC or discovery."""
    if not name.startswith("step_") or name.endswith(".tmp"):
        return None
    suffix = name[len("step_"):]
    return int(suffix) if suffix.isdigit() else None


class Checkpointer:
    def __init__(self, base_dir: str, keep: int = 3):
        self.base = base_dir
        self.keep = keep
        os.makedirs(base_dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, state: Any, step: int, blocking: bool = False) -> None:
        """Snapshot to host, then serialize asynchronously."""
        self.wait()  # at most one in-flight save
        leaves, treedef = jax.tree.flatten(state)
        host_leaves = [np.asarray(jax.device_get(leaf)) for leaf in leaves]
        treedef_repr = str(treedef)

        def write():
            d = _step_dir(self.base, step)
            tmp = d + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "n_leaves": len(host_leaves),
                        "treedef": treedef_repr,
                        "leaves": []}
            for i, arr in enumerate(host_leaves):
                name = f"leaf_{i:05d}.npy"
                raw, dtype_name = _encode(arr)
                np.save(os.path.join(tmp, name), raw)
                manifest["leaves"].append(
                    {"file": name, "shape": list(arr.shape), "dtype": dtype_name}
                )
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
                f.write("ok")
            if os.path.exists(d):
                shutil.rmtree(d)
            os.rename(tmp, d)
            self._gc()

        if blocking:
            write()
        else:
            def guarded():
                try:
                    write()
                except BaseException as exc:  # noqa: BLE001 — repropagated
                    self._error = exc

            self._thread = threading.Thread(target=guarded, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        """Join the in-flight save; a failure on the background thread is
        re-raised here (or from the next ``save``, which waits first) —
        never silently reported as committed."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            exc = self._error
            self._error = None
            raise RuntimeError("async checkpoint save failed") from exc

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.base):
            step = _parse_step(name)
            d = os.path.join(self.base, name)
            if step is not None and os.path.exists(os.path.join(d, "_COMMITTED")):
                steps.append(step)
        return max(steps) if steps else None

    def restore(self, step: Optional[int] = None, like: Any = None,
                shardings: Any = None) -> Any:
        """Restore a pytree; ``like`` provides the treedef (required),
        ``shardings`` (optional) places leaves onto the current mesh —
        the checkpoint itself is topology-free."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.base}")
        d = _step_dir(self.base, step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = [
            _decode(np.load(os.path.join(d, leaf["file"])), leaf["dtype"])
            for leaf in manifest["leaves"]
        ]
        if like is None:
            return arrays, step
        leaves_like, treedef = jax.tree.flatten(like)
        assert len(arrays) == len(leaves_like), "tree structure changed"
        if shardings is not None:
            sh_leaves = jax.tree.leaves(shardings,
                                        is_leaf=lambda x: hasattr(x, "spec"))
            arrays = [jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)]
        else:
            arrays = [jax.numpy.asarray(a) for a in arrays]
        return jax.tree.unflatten(treedef, arrays), step

    def _gc(self) -> None:
        steps = sorted(
            s for n in os.listdir(self.base)
            if (s := _parse_step(n)) is not None
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(_step_dir(self.base, s), ignore_errors=True)
