"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; tests and benchmarks see the real (single) device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from repro.core.compat import make_mesh_compat as compat_make_mesh  # re-export


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over the locally-available devices (tests / examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return compat_make_mesh((data, model_axis), ("data", "model"))


def make_shard_mesh(n_shards: Optional[int] = None, axis: str = "shard"):
    """1-D mesh for the asynchronous shard runtime
    (runtime/shard_runtime.py): one block owner per device along ``axis``.

    Unlike the production meshes this may use a *prefix* of the available
    devices (a 2-shard runtime on a 4-device host is a valid experiment),
    so it builds ``jax.sharding.Mesh`` directly instead of going through
    ``make_mesh`` — which binds every device.
    """
    devices = jax.devices()
    n = len(devices) if n_shards is None else int(n_shards)
    if n < 1:
        raise ValueError(f"n_shards={n} must be >= 1")
    if n > len(devices):
        raise ValueError(
            f"n_shards={n} exceeds the {len(devices)} available devices "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count before "
            "the first jax import to emulate more)")
    return jax.sharding.Mesh(np.asarray(devices[:n]), (axis,))


def shard_axis_of(mesh) -> str:
    """The (single) axis of a shard-runtime mesh."""
    if len(mesh.axis_names) != 1:
        raise ValueError(f"expected a 1-D shard mesh, got axes {mesh.axis_names}")
    return mesh.axis_names[0]


def dp_axes_of(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")
