"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; tests and benchmarks see the real (single) device.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.core.compat import make_mesh_compat as compat_make_mesh  # re-export


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over the locally-available devices (tests / examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return compat_make_mesh((data, model_axis), ("data", "model"))


def dp_axes_of(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")
