"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; tests and benchmarks see the real (single) device.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import numpy as np

from repro.core.compat import make_mesh_compat as compat_make_mesh  # re-export


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over the locally-available devices (tests / examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return compat_make_mesh((data, model_axis), ("data", "model"))


def shard_axis_names(axis: str, ndim: int) -> Tuple[str, ...]:
    """Axis names of a shard mesh: the single historical ``axis`` for 1-D,
    ``(axis_x, axis_y[, axis_z])`` for multi-axis meshes."""
    if ndim == 1:
        return (axis,)
    return tuple(f"{axis}_{d}" for d in ("x", "y", "z")[:ndim])


def make_shard_mesh(n_shards: Optional[Union[int, Tuple[int, ...]]] = None,
                    axis: str = "shard"):
    """Mesh for the asynchronous shard runtime (runtime/shard_runtime.py):
    one block owner per device.

    ``n_shards`` is an int (the historical 1-D pencil mesh along ``axis``)
    or a mesh shape tuple ``(px,)``/``(px, py)``/``(px, py, pz)`` laying
    ``prod(shape)`` devices row-major over axes ``shard_axis_names(axis,
    ndim)`` — the shape ``ShardRuntimeConfig.mesh_shape`` declares and
    ``solvers.partition.MeshPartition`` tiles the grid by.

    Unlike the production meshes this may use a *prefix* of the available
    devices (a 2-shard runtime on a 4-device host is a valid experiment),
    so it builds ``jax.sharding.Mesh`` directly instead of going through
    ``make_mesh`` — which binds every device.
    """
    devices = jax.devices()
    if n_shards is None:
        shape: Tuple[int, ...] = (len(devices),)
    elif isinstance(n_shards, (tuple, list)):
        shape = tuple(int(s) for s in n_shards)
        if not 1 <= len(shape) <= 3:
            raise ValueError(f"mesh shape {shape} must be 1-D, 2-D, or 3-D")
    else:
        shape = (int(n_shards),)
    if any(s < 1 for s in shape):
        raise ValueError(f"n_shards={shape} must be >= 1 per axis")
    n = int(np.prod(shape))
    if n > len(devices):
        raise ValueError(
            f"n_shards={shape} needs {n} devices, which exceeds the "
            f"{len(devices)} available (set XLA_FLAGS=--xla_force_host_"
            "platform_device_count before the first jax import to emulate "
            "more)")
    names = shard_axis_names(axis, len(shape))
    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape), names)


def shard_axis_of(mesh) -> str:
    """The (single) axis of a shard-runtime mesh."""
    if len(mesh.axis_names) != 1:
        raise ValueError(f"expected a 1-D shard mesh, got axes {mesh.axis_names}")
    return mesh.axis_names[0]


def shard_axes_of(mesh) -> Tuple[str, ...]:
    """All shard axes of a (possibly multi-axis) shard-runtime mesh, in
    grid-axis order."""
    return tuple(mesh.axis_names)


def dp_axes_of(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")
