"""End-to-end training driver.

Integrates the paper's technique at the driver level: the train step carries
a PFAIT ``MonitorState`` (K-stale loss ring, core/detection.py) and the host
polls the on-device ``converged`` flag **asynchronously** — the loop never
blocks on a metric fetch, exactly as the paper replaces the blocking
residual reduction with successive non-blocking ones.

Also wires: sharded synthetic data (data/pipeline.py), async checkpointing
with elastic restore (checkpoint/), straggler tracking (runtime/).

Usage (CPU example run — reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 200 --batch 8 --seq 128 --target-loss 4.0
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ShapeConfig, reduced as reduced_cfg
from repro.configs.registry import get_arch
from repro.core import detection
from repro.data.pipeline import device_batches
from repro.models import Model
from repro.optim import AdamW, cosine_schedule
from repro.runtime.fault_tolerance import StragglerPolicy


def train(
    arch: str,
    steps: int = 200,
    batch: int = 8,
    seq: int = 128,
    use_reduced: bool = True,
    target_loss: Optional[float] = None,
    monitor_mode: str = "pfait",
    staleness: int = 4,
    margin: float = 10.0,
    monitor_metric: str = "loss",
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    seed: int = 0,
    mesh=None,
    log_every: int = 10,
):
    cfg = get_arch(arch)
    if use_reduced:
        cfg = reduced_cfg(cfg)
    shape = ShapeConfig("custom", seq_len=seq, global_batch=batch, kind="train")
    model = Model(cfg, mesh=mesh)
    opt = AdamW(cosine_schedule(3e-3, max(steps // 20, 1), steps))
    # the shared ε̃/margin convention (core/detection.for_mode): PFAIT
    # detects at the *tightened* threshold ε = ε̃ / margin, every other
    # mode at ε̃ itself
    monitor = detection.for_mode(
        monitor_mode,
        eps_tilde=target_loss if target_loss is not None else 0.0,
        margin=margin,
        staleness=0 if monitor_mode == "sync" else staleness,
        persistence=4,
        ord=1.0,   # scalar metric: σ = identity
    )
    step_fn, _ = model.make_train_step(opt, monitor=monitor,
                                       monitor_metric=monitor_metric)
    step_fn = jax.jit(step_fn, donate_argnums=(0,))

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    start_step = 0
    state = model.init_train_state(jax.random.PRNGKey(seed), opt, monitor=monitor)
    if ckpt and ckpt.latest_step() is not None:
        state, start_step = ckpt.restore(like=state)
        print(f"[train] restored checkpoint at step {start_step}")

    data = device_batches(cfg, shape, mesh=mesh, seed=seed, start_step=start_step)
    stragglers = StragglerPolicy()
    pending_metrics = None  # async (non-blocking) metric handle
    losses = []
    t0 = time.time()
    stop_step = None
    try:
        for step, batch_arrays in data:
            if step >= steps:
                break
            ts = time.time()
            state, metrics = step_fn(state, batch_arrays)
            # --- PFAIT-style non-blocking monitoring -------------------
            # metrics stay on device; we only *fetch* the previous step's
            # (already materialised) values — never a sync on this step.
            if pending_metrics is not None:
                prev_step, prev, prev_ts = pending_metrics
                loss = float(prev["loss"])
                # the fetch above materialised step ``prev_step``: its
                # dispatch→completion wall time is the step duration the
                # straggler policy needs (timing the async dispatch itself
                # measures ~0 ms of enqueue latency)
                stragglers.record(0, time.time() - prev_ts)
                losses.append(loss)
                if prev_step % log_every == 0:
                    print(f"[train] step {prev_step:5d} loss {loss:.4f} "
                          f"gnorm {float(prev['grad_norm']):.3f}")
                if target_loss is not None and bool(prev["converged"]):
                    stop_step = prev_step
                    print(f"[train] monitor fired at step {prev_step} "
                          f"(mode={monitor_mode}, K={monitor.staleness})")
                    break
            pending_metrics = (step, metrics, ts)
            if ckpt and step > 0 and step % ckpt_every == 0:
                # tag = next data step: resume replays nothing, skips nothing
                ckpt.save(state, step + 1)
    finally:
        data.close()
        if ckpt:
            ckpt.wait()
    wall = time.time() - t0
    return {
        "state": state,
        "losses": losses,
        "steps_run": int(state.step),
        "stop_step": stop_step,
        "wall_s": wall,
        "stragglers": stragglers,
        "monitor": monitor,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--target-loss", type=float, default=None)
    ap.add_argument("--monitor", default="pfait", choices=["sync", "pfait", "nfais2", "nfais5"])
    ap.add_argument("--staleness", type=int, default=4)
    ap.add_argument("--margin", type=float, default=10.0,
                    help="PFAIT threshold margin: detect at eps = target/margin")
    ap.add_argument("--monitor-metric", default="loss",
                    choices=["loss", "update_norm", "grad_norm"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        use_reduced=args.reduced, target_loss=args.target_loss,
        monitor_mode=args.monitor, staleness=args.staleness,
        margin=args.margin, monitor_metric=args.monitor_metric,
        ckpt_dir=args.ckpt_dir, seed=args.seed,
    )
    print(f"[train] done: {out['steps_run']} steps in {out['wall_s']:.1f}s; "
          f"final loss {out['losses'][-1] if out['losses'] else float('nan'):.4f}")


if __name__ == "__main__":
    main()
