import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the step the shape implies (train_step for
``train_*``, prefill for ``prefill_*``, serve/decode step for ``decode_*`` /
``long_*``) against the production mesh with ShapeDtypeStruct stand-ins (no
allocation), compiles it, and records:

  * ``memory_analysis()``  — per-device argument/output/temp bytes (fits?),
  * ``cost_analysis()``    — per-partition HLO FLOPs and bytes accessed,
  * collective traffic     — parsed from the compiled HLO (loop-aware),

into a JSON report consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single          # 16×16
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi           # 2×16×16
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --solver               # paper PDE cell
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ALL_SHAPES, ParallelConfig
from repro.configs.registry import ARCHS, cell_is_runnable, get_arch, get_shape
from repro.core import detection
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.optim import AdamW, cosine_schedule


def _sds(tree_struct, tree_spec, mesh):
    """Pair ShapeDtypeStructs with NamedShardings."""
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        tree_struct, tree_spec,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _moment_dtype(cfg) -> Optional[str]:
    # 100B+ models use bf16 moments so state fits one v5e pod (DESIGN §5)
    return "bfloat16" if cfg.num_params() > 100e9 else "float32"


def _microbatch_policy(cfg, shape, mesh) -> int:
    """Grad-accumulation depth: keep the remat activation carry
    (scan_steps × B_loc/m × S × D × 2 bytes) under ~2 GiB/device."""
    ndev_dp = int(np.prod([v for k, v in mesh.shape.items() if k != "model"]))
    b_loc = max(shape.global_batch // ndev_dp, 1)
    steps = cfg.num_layers // (cfg.moe_layer_period if cfg.is_moe else 1)
    target = 2 * 2**30
    m = 1
    while m < b_loc and steps * (b_loc // m) * shape.seq_len * cfg.d_model * 2 > target:
        m *= 2
    return m


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               parallel: Optional[ParallelConfig] = None,
               capacity_factor: float = 1.0,
               microbatch_override: Optional[int] = None,
               variant: str = "baseline") -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    parallel = parallel or ParallelConfig()
    model = Model(cfg, mesh=mesh, parallel=parallel, capacity_factor=capacity_factor)
    t0 = time.time()

    if shape.kind == "train":
        opt = AdamW(cosine_schedule(3e-4, 100, 10_000), moment_dtype=_moment_dtype(cfg))
        micro = microbatch_override or _microbatch_policy(cfg, shape, mesh)
        accum = "bfloat16" if cfg.num_params() > 100e9 else None
        step_fn, _ = model.make_train_step(opt, microbatches=micro, accum_dtype=accum)
        state_struct = jax.eval_shape(
            lambda k: model.init_train_state(k, opt), jax.random.PRNGKey(0)
        )
        state_specs = model.train_state_specs(opt)
        state_in = _sds(state_struct, state_specs, mesh)
        ispecs = model.input_specs(shape)
        batch_in = {k: _sds(v[0], v[1], mesh) for k, v in ispecs.items()}
        jitted = jax.jit(step_fn, donate_argnums=(0,))
        lowered = jitted.lower(state_in, batch_in)
    elif shape.kind == "prefill":
        fn = model.make_prefill()
        params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params_in = _sds(params_struct, model.param_specs(), mesh)
        ispecs = model.input_specs(shape)
        inputs_in = _sds(ispecs["inputs"][0], ispecs["inputs"][1], mesh)
        lowered = jax.jit(fn).lower(params_in, inputs_in)
    else:  # decode
        ring = shape.name == "long_500k" and cfg.attn_window > 0
        fn = model.make_decode_step(ring=ring)
        params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params_in = _sds(params_struct, model.param_specs(), mesh)
        ispecs = model.input_specs(shape)
        tokens_in = _sds(ispecs["inputs"][0], ispecs["inputs"][1], mesh)
        cache_struct, cache_specs = ispecs["cache"]
        cache_in = _sds(cache_struct, cache_specs, mesh)
        clen = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
        lowered = jax.jit(fn, donate_argnums=(1,)).lower(params_in, cache_in, tokens_in, clen)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    pstats = hlo_analysis.program_stats(
        text, default_group=int(np.prod(list(mesh.shape.values())))
    )
    coll = hlo_analysis.CollectiveStats(
        counts=dict(pstats.coll_counts),
        bytes_alg=dict(pstats.coll_bytes_alg),
        bytes_wire=dict(pstats.coll_bytes_wire),
    )
    rec = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_estimate_bytes": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        },
        "cost": {
            # cost_analysis counts while bodies once — kept for reference
            "xla_flops_per_device": float(ca.get("flops", 0.0)),
            "xla_bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
            # loop-aware parsed terms (used by the roofline)
            "flops_per_device": float(pstats.flops),
            "hbm_bytes_per_device": float(pstats.hbm_bytes),
        },
        "collectives": coll.as_dict(),
        "model_params": int(cfg.num_params()),
        "model_active_params": int(cfg.num_active_params()),
    }
    return rec


def lower_solver_cell(multi_pod: bool, n: int = 1024, mode: str = "pfait") -> Dict[str, Any]:
    """The paper's own workload: the device-resident shard runtime's
    convdiff solve, lowered at production shard counts through the unified
    ``runtime.api.RuntimeConfig`` (the same build path ``api.run_shard``
    executes — the dry-run sees the program that actually runs)."""
    from repro.launch.mesh import make_shard_mesh
    from repro.runtime.api import RuntimeConfig
    from repro.runtime.shard_runtime import make_runtime, state_spec
    from repro.solvers.convdiff import Stencil

    p = 512 if multi_pod else 256    # matches the 2x16x16 / 16x16 pods
    mesh = make_shard_mesh(p)
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.95)
    mon = detection.for_mode(mode, eps_tilde=1e-4, margin=10.0, staleness=4)
    rcfg = RuntimeConfig(monitor=mon, reduction="nonblocking",
                         inner_sweeps=4, max_outer=20_000)
    solve = make_runtime("convdiff", rcfg.to_shard_config(), mesh, n, stencil=st)
    xspec = state_spec("convdiff", "shard")
    aspec = P("shard", None, None)
    x0 = jax.ShapeDtypeStruct((n, n, n), jnp.float32, sharding=NamedSharding(mesh, xspec))
    b = jax.ShapeDtypeStruct((n, n, n), jnp.float32, sharding=NamedSharding(mesh, aspec))
    t0 = time.time()
    lowered = jax.jit(solve).lower(x0, b)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # some jax versions wrap in a list
        ca = ca[0] if ca else {}
    pstats = hlo_analysis.program_stats(
        compiled.as_text(), default_group=int(np.prod(list(mesh.shape.values())))
    )
    coll = hlo_analysis.CollectiveStats(
        counts=dict(pstats.coll_counts),
        bytes_alg=dict(pstats.coll_bytes_alg),
        bytes_wire=dict(pstats.coll_bytes_wire),
    )
    return {
        "arch": f"convdiff-n{n}-{mode}",
        "solver_max_outer": 20_000,  # loop-aware stats cover the full solve
        "shape": "solver",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "shards": p,
        "kind": "solver",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_estimate_bytes": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        },
        "cost": {
            "xla_flops_per_device": float(ca.get("flops", 0.0)),
            "xla_bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
            "flops_per_device": float(pstats.flops),
            "hbm_bytes_per_device": float(pstats.hbm_bytes),
        },
        "collectives": coll.as_dict(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--solver", action="store_true", help="also run the PDE solver cell")
    ap.add_argument("--solver-only", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in ALL_SHAPES] if args.shape == "all" else args.shape.split(",")

    records = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            records = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in records}

    t_start = time.time()
    for multi in meshes:
        mesh_name = "2x16x16" if multi else "16x16"
        if not args.solver_only:
            for a in archs:
                for s in shapes:
                    ok, why = cell_is_runnable(get_arch(a), get_shape(s))
                    key = (a, s, mesh_name)
                    if key in done:
                        continue
                    if not ok:
                        records.append({"arch": a, "shape": s, "mesh": mesh_name,
                                        "skipped": True, "reason": why})
                        print(f"[skip] {a} × {s} × {mesh_name}: {why}", flush=True)
                        continue
                    try:
                        rec = lower_cell(a, s, multi)
                        records.append(rec)
                        print(
                            f"[ok]   {a} × {s} × {mesh_name}: "
                            f"compile {rec['compile_s']}s, "
                            f"{rec['cost']['flops_per_device']/1e9:.1f} GFLOP/dev, "
                            f"peak {rec['memory']['peak_estimate_bytes']/2**30:.2f} GiB/dev, "
                            f"wire {rec['collectives']['total_wire_bytes']/2**20:.1f} MiB/dev",
                            flush=True,
                        )
                    except Exception as e:  # noqa: BLE001
                        records.append({"arch": a, "shape": s, "mesh": mesh_name,
                                        "error": f"{type(e).__name__}: {e}"})
                        print(f"[FAIL] {a} × {s} × {mesh_name}: {e}", flush=True)
                        traceback.print_exc()
                    _save(records, args.out)
        if args.solver or args.solver_only:
            try:
                rec = lower_solver_cell(multi)
                records.append(rec)
                print(f"[ok]   solver × {mesh_name}: compile {rec['compile_s']}s", flush=True)
            except Exception as e:  # noqa: BLE001
                records.append({"arch": "convdiff", "shape": "solver", "mesh": mesh_name,
                                "error": f"{type(e).__name__}: {e}"})
                print(f"[FAIL] solver × {mesh_name}: {e}", flush=True)
                traceback.print_exc()
            _save(records, args.out)

    n_ok = sum(1 for r in records if "error" not in r and not r.get("skipped"))
    n_fail = sum(1 for r in records if "error" in r)
    n_skip = sum(1 for r in records if r.get("skipped"))
    print(f"\ndry-run complete in {time.time()-t_start:.0f}s: "
          f"{n_ok} ok, {n_fail} failed, {n_skip} skipped (documented N/A)")
    _save(records, args.out)
    if n_fail:
        raise SystemExit(1)


def _save(records, path):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(records, f, indent=1)


if __name__ == "__main__":
    main()
