"""Batched serving driver: prefill + decode with KV/SSM caches.

Decode termination uses the paper's mechanism at the batch level: the
"all sequences finished" predicate is a reduction over per-sequence EOS
flags, evaluated K steps stale (non-blocking) — the decode loop never
fences on the termination check; at detection it rolls back nothing
(generated tokens past EOS are masked), trading ≤K wasted steps for an
un-fenced steady-state loop, exactly the PFAIT trade.

The stale predicate runs through ``core.detection``'s monitor (PFAIT
lane, ε = 0.5 on the indicator g = 1 − [all finished], ring depth K)
rather than a hand-rolled flag ring, so serving exercises the same
detection code path as the solvers and the trace/replay subsystem.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import reduced as reduced_cfg
from repro.configs.registry import get_arch
from repro.core import detection
from repro.models import Model


def serve(
    arch: str,
    batch: int = 4,
    prompt_len: int = 32,
    max_new: int = 32,
    use_reduced: bool = True,
    eos_id: int = 2,
    staleness: int = 4,
    seed: int = 0,
    greedy: bool = True,
):
    cfg = get_arch(arch)
    if use_reduced:
        cfg = reduced_cfg(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    prefill = jax.jit(model.make_prefill())
    decode = jax.jit(model.make_decode_step(), donate_argnums=(1,))

    rng = np.random.default_rng(seed)
    if cfg.frontend is None:
        prompts = jnp.asarray(
            rng.integers(3, cfg.vocab_size, (batch, prompt_len)), jnp.int32
        )
    else:
        prompts = jnp.asarray(
            rng.standard_normal((batch, prompt_len, cfg.frontend_dim)), jnp.float32
        )

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    # extend caches with room for max_new tokens
    def extend(u):
        out = []
        for entry in u:
            e = {}
            for k2, v2 in entry.items():
                if k2 == "kv":
                    e["kv"] = {kk: jnp.pad(vv, ((0, 0), (0, 0), (0, max_new),
                                                (0, 0), (0, 0)))
                               for kk, vv in v2.items()}
                else:
                    e[k2] = v2
            out.append(e)
        return tuple(out)

    cache = extend(cache)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # [B]
    finished = jnp.zeros((batch,), bool)
    generated = [tok]
    # K-stale termination (PFAIT monitor): g = 1 − [all finished] ∈ {0, 1},
    # ε = 0.5, so the monitor fires when the flag launched K steps ago was
    # set — the loop never fences on the fresh flag
    mon = detection.MonitorConfig(mode="pfait", eps=0.5,
                                  staleness=staleness, ord=float("inf"))
    mstate = detection.init_state(mon)
    steps_done = 0
    for i in range(max_new - 1):
        inp = tok[:, None]
        if cfg.frontend is not None:
            inp = jax.nn.one_hot(tok, cfg.frontend_dim, dtype=jnp.float32)[:, None, :]
        logits, cache = decode(params, cache, inp, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        finished = finished | (tok == eos_id)
        generated.append(tok)
        g = 1.0 - jnp.all(finished).astype(jnp.float32)
        mstate = detection.step(mon, mstate, g)
        steps_done = i + 1
        if bool(detection.should_stop(mstate)):   # stale view only
            break
    toks = jnp.stack(generated, axis=1)
    wall = time.time() - t0
    return {
        "tokens": np.asarray(toks),
        "finished": np.asarray(finished),
        "steps": steps_done,
        "wall_s": wall,
        "tok_per_s": batch * steps_done / max(wall, 1e-9),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()
    out = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                max_new=args.max_new, use_reduced=args.reduced)
    print(f"[serve] generated {out['tokens'].shape} in {out['wall_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()
