"""Detection-as-a-service: a multi-tenant batched solve server.

The paper's protocol-free detection makes the residual monitor a stateless
by-product of the iteration — cheap enough that *thousands* of independent
detections can share one device.  This module productionises that
observation into a continuous service:

* **Admission** — tenants submit independent fixed-point problems
  (ConvDiff, PageRank, mlfixed) with per-tenant ε̃, monitor mode,
  staleness K, and persistence m (``TenantSpec``).  Invalid requests are
  rejected at admission with a structured error record; they never reach a
  packed lane.
* **Lane packing** — compatible tenants (same family, shape bucket, and
  monitor mode) are binned into the lanes of one batched device executable:
  a ``detection.make_lane_runner`` program fusing the family's
  ``update_with_residual_batched`` step with the vmapped monitor update.
  Partially-filled batches run with inert *padding lanes* (ε = −1 on a
  non-negative residual never fires); tenants converging at different
  steps are retired and their lanes refilled from the queue via
  ``detection.reset_lanes`` — pure ``where`` ops, so the compiled
  executable is never rebuilt.
* **Warm-executable sharing** — executables are keyed by the content-
  addressing convention of the campaign cache (``benchmarks/campaign.py``):
  SHA-256 over the canonical signature JSON plus a fingerprint of the
  sources that define the program's semantics.  A new tenant whose
  (family, shape-bucket, monitor) signature matches a live executable
  skips compilation entirely — the service pays one compile per
  *signature*, not per tenant.
* **Reporting** — ``DetectionService.report()`` returns a
  ``runtime.api.ServeReport``: per-tenant certified detection
  (oracle-scored — the batched step is synchronous, so the σ-applied
  contribution series IS the exact residual trace) plus service-level
  throughput, queue wait, and nearest-rank p50/p95/p99 time-to-detection.
  Time is measured in deterministic service *ticks* (one tick = one
  ``chunk`` of device steps per bucket), so CI exact-gates the latency
  distribution; wall seconds are reported alongside but never gated.
* **Shutdown/drain** — ``shutdown(drain=True)`` stops admission, lets
  every in-flight lane complete (bounded by the per-tenant step budget),
  and sheds still-queued tenants with a structured status, so a stopping
  service always reports what it owes.

``benchmarks/bench_serve.py`` drives the service with an open-loop Poisson
arrival stream and sweeps the rate to find the saturation knee; the
``serve-smoke`` CI lane gates it (``check_regression.py serve_smoke``).

The LM decode driver (``serve``) that historically lived here is kept at
the bottom of the module: its K-stale "all sequences finished" predicate
through the PFAIT monitor is the same detection trade at the token level.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import detection
from repro.runtime.api import ServeReport, TenantReport

#: problem families the service admits (each has lane_x0/lane_operands
#: and an ``update_with_residual_batched`` batched step)
SERVE_FAMILIES = ("convdiff", "pagerank", "mlfixed")

#: padding-lane threshold: residual contributions are non-negative and the
#: ring initialises to +inf, so a lane with ε = −1 can never fire
_PAD_EPS = np.float32(-1.0)

_REJECT = "rejected"


def make_serve_problem(family: str, seed: int = 0, **kw):
    """Problem factory over the servable families (mirrors
    ``benchmarks.common.make_problem``, importable without the benchmarks
    tree)."""
    if family == "convdiff":
        from repro.solvers.convdiff import ConvDiffProblem

        return ConvDiffProblem(seed=seed, **kw)
    if family == "pagerank":
        from repro.solvers.pagerank import PageRankProblem

        return PageRankProblem(seed=seed, **kw)
    if family == "mlfixed":
        from repro.solvers.mlfixed import MLFixedPointProblem

        return MLFixedPointProblem(seed=seed, **kw)
    raise KeyError(f"family {family!r} not in {SERVE_FAMILIES}")


@dataclass(frozen=True)
class ServeConfig:
    """Service-level knobs (every tenant in a bucket shares them).

    ``lanes`` is the batch width of one lane executable, ``chunk`` the
    device steps per service tick, ``max_staleness`` the largest per-tenant
    K the service accepts (the shared monitor ring is padded to K+1 —
    padding slots are never read, so verdicts stay bitwise-identical to
    solo runs), and ``max_steps`` the per-tenant step budget before a
    non-converging tenant is retired with status ``"timeout"``.
    """

    lanes: int = 8
    chunk: int = 16
    max_staleness: int = 8
    max_steps: int = 4096
    margin: float = 10.0          # default PFAIT margin (ε = ε̃ / margin)
    oracle_factor: float = 10.0   # decade factor for false-detection scoring

    def __post_init__(self):
        if self.lanes < 1 or self.chunk < 1:
            raise ValueError(f"lanes={self.lanes}/chunk={self.chunk} must be >= 1")
        if self.max_staleness < 0:
            raise ValueError(f"max_staleness={self.max_staleness} must be >= 0")
        if self.max_steps < self.chunk:
            raise ValueError(
                f"max_steps={self.max_steps} must be >= chunk={self.chunk}")

    @property
    def ring_len(self) -> int:
        """Monitor ring length shared by every lane (max K + 1)."""
        return self.max_staleness + 1


@dataclass(frozen=True)
class TenantSpec:
    """One tenant's solve request.

    ``problem`` holds the family's constructor kwargs *minus* the seed
    (the seed is per-tenant data; everything else defines the shape
    bucket).  ``margin=None`` inherits the service default; the effective
    threshold follows ``detection.for_mode``: ε = ε̃/margin for pfait,
    ε = ε̃ otherwise.
    """

    tenant: str
    family: str
    problem: Mapping[str, Any] = field(default_factory=dict)
    seed: int = 0
    eps_tilde: float = 1e-6
    mode: str = "pfait"
    staleness: int = 2
    persistence: int = 4
    margin: Optional[float] = None


# ---------------------------------------------------------------------------
# Content-addressed executable signatures (the campaign cache convention)
# ---------------------------------------------------------------------------

_FINGERPRINT_CACHE: Dict[str, str] = {}


def executable_fingerprint() -> str:
    """SHA-256 over the sources that define a lane executable's semantics.

    Same convention as ``benchmarks/campaign.py:code_fingerprint``: the
    detection layer, the three solver families, and this module.  Editing
    any of them yields new keys, so a stale warm executable can never be
    confused with the current code's.
    """
    cached = _FINGERPRINT_CACHE.get("fp")
    if cached is not None:
        return cached
    from repro.solvers import convdiff, mlfixed, pagerank

    h = hashlib.sha256()
    for mod in (detection, convdiff, pagerank, mlfixed):
        with open(mod.__file__, "rb") as f:
            h.update(f.read())
    with open(__file__, "rb") as f:
        h.update(f.read())
    _FINGERPRINT_CACHE["fp"] = h.hexdigest()
    return _FINGERPRINT_CACHE["fp"]


def _canonical(obj: Any) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def signature_of(spec: TenantSpec, cfg: ServeConfig) -> Dict[str, Any]:
    """The shape-bucket signature a tenant packs under: family + problem
    kwargs (seed excluded) + monitor mode + the service batch geometry."""
    return {
        "family": spec.family,
        "problem": {k: spec.problem[k] for k in sorted(spec.problem)},
        "mode": spec.mode,
        "lanes": cfg.lanes,
        "chunk": cfg.chunk,
        "ring": cfg.ring_len,
    }


def signature_key(sig: Dict[str, Any]) -> str:
    """Content-addressed executable key: signature JSON + code fingerprint."""
    payload = {"sig": sig, "code": executable_fingerprint()}
    return hashlib.sha256(_canonical(payload).encode()).hexdigest()


def _sigma_np(raw: np.ndarray, ord_: float) -> np.ndarray:
    """Host-side σ of a raw contribution series (numpy twin of
    ``detection._sigma_lane``)."""
    raw = np.asarray(raw, dtype=np.float64)
    if np.isinf(ord_):
        return raw
    if ord_ == 2.0:
        return np.sqrt(raw)
    return raw ** (1.0 / ord_)


# ---------------------------------------------------------------------------
# Lane bucket — one warm executable, `lanes` resident detection lanes
# ---------------------------------------------------------------------------


class _ActiveTenant:
    """Book-keeping for a tenant occupying a lane."""

    __slots__ = ("spec", "arrival_tick", "admit_tick", "steps", "chunks",
                 "ord")

    def __init__(self, spec: TenantSpec, arrival_tick: int, admit_tick: int,
                 ord_: float):
        self.spec = spec
        self.arrival_tick = arrival_tick
        self.admit_tick = admit_tick
        self.steps = 0
        self.chunks: List[np.ndarray] = []   # raw per-chunk contributions
        self.ord = ord_


class _LaneBucket:
    """One live executable plus its resident lane state.

    Retire/refill never changes shapes, so the jitted runner built at
    construction (or inherited warm from the service registry) is reused
    for the bucket's whole life.
    """

    def __init__(self, key: str, sig: Dict[str, Any], runner, prob0,
                 cfg: ServeConfig):
        import jax.numpy as jnp

        self.key = key
        self.sig = sig
        self.runner = runner
        self.prob0 = prob0
        self.cfg = cfg
        self.ord = float(prob0.ord)
        L = cfg.lanes
        x0 = np.asarray(prob0.lane_x0())
        self.X = jnp.zeros((L,) + x0.shape, jnp.float32)
        ops0 = prob0.lane_operands()
        self.ops = {
            k: jnp.zeros((L,) + np.shape(v), jnp.float32)
            for k, v in ops0.items()
        }
        self.eps = np.full(L, _PAD_EPS, np.float32)
        self.epst = np.full(L, _PAD_EPS, np.float32)
        self.K = np.zeros(L, np.int32)
        self.m = np.ones(L, np.int32)
        self.state = detection.init_lanes(L, cfg.ring_len)
        self.active: List[Optional[_ActiveTenant]] = [None] * L

    @property
    def free_lanes(self) -> List[int]:
        return [i for i, a in enumerate(self.active) if a is None]

    @property
    def busy(self) -> bool:
        return any(a is not None for a in self.active)

    def admit(self, spec: TenantSpec, prob, arrival_tick: int,
              admit_tick: int, margin_default: float) -> None:
        """Pack one tenant into a free lane (caller guarantees one)."""
        import jax.numpy as jnp

        lane = self.free_lanes[0]
        margin = margin_default if spec.margin is None else spec.margin
        eps = detection.for_mode(
            spec.mode, spec.eps_tilde, margin=margin).eps
        K = 0 if spec.mode == "sync" else int(spec.staleness)
        self.X = self.X.at[lane].set(
            jnp.asarray(prob.lane_x0(), jnp.float32))
        for k, v in prob.lane_operands().items():
            self.ops[k] = self.ops[k].at[lane].set(
                jnp.asarray(v, jnp.float32))
        self.eps[lane] = np.float32(eps)
        self.epst[lane] = np.float32(spec.eps_tilde)
        self.K[lane] = K
        self.m[lane] = int(spec.persistence)
        mask = np.zeros(self.cfg.lanes, bool)
        mask[lane] = True
        self.state = detection.reset_lanes(self.state, mask)
        self.active[lane] = _ActiveTenant(spec, arrival_tick, admit_tick,
                                          self.ord)

    def run_chunk(self) -> Tuple[Any, np.ndarray]:
        """Advance every lane one chunk; returns (lane state, raw series)."""
        import jax.numpy as jnp

        self.X, self.state, cs = self.runner(
            self.X, self.ops, self.state,
            jnp.asarray(self.eps), jnp.asarray(self.epst),
            jnp.asarray(self.K), jnp.asarray(self.m))
        return self.state, np.asarray(cs)

    def release(self, lane: int) -> None:
        """Retire a lane back to inert padding (operand rows stay — ε = −1
        keeps the lane's monitor unfireable, and a refill overwrites them)."""
        self.eps[lane] = _PAD_EPS
        self.epst[lane] = _PAD_EPS
        self.K[lane] = 0
        self.m[lane] = 1
        mask = np.zeros(self.cfg.lanes, bool)
        mask[lane] = True
        self.state = detection.reset_lanes(self.state, mask)
        self.active[lane] = None


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------


class DetectionService:
    """Continuous multi-tenant detection service (see module docstring).

    Drive it with ``submit()`` + ``step_tick()`` (or the ``serve_detection``
    convenience loop), then ``report()``.  All scheduling is deterministic
    in the tick domain for a fixed submission sequence.
    """

    def __init__(self, cfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.tick_count = 0
        self.compile_count = 0
        self.warm_hits = 0
        self.reports: List[TenantReport] = []
        self._runners: Dict[str, Any] = {}      # warm-executable registry
        self._buckets: Dict[str, _LaneBucket] = {}
        self._queues: Dict[str, List[Tuple[TenantSpec, Any, int]]] = {}
        self._accepting = True
        self._wall_s = 0.0

    # -- admission -----------------------------------------------------------

    def submit(self, spec: TenantSpec,
               arrival_tick: Optional[int] = None) -> Dict[str, Any]:
        """Admit one tenant (validated) or reject it with a structured
        error record ``{"tenant", "admitted", "error", "reason"}``.

        A rejected tenant never reaches a packed lane: validation happens
        entirely at admission, including constructing the seeded problem,
        so a malformed spec cannot poison a running batch.
        """
        arrival = self.tick_count if arrival_tick is None else int(arrival_tick)
        err = self._validate(spec)
        if err is None and not self._accepting:
            err = ("shutdown", "service is no longer accepting tenants")
        prob = None
        if err is None:
            try:
                prob = make_serve_problem(spec.family, seed=int(spec.seed),
                                          **dict(spec.problem))
            except Exception as exc:  # constructor validation is the contract
                err = ("problem_invalid", f"{type(exc).__name__}: {exc}")
        if err is not None:
            code, reason = err
            self.reports.append(TenantReport(
                tenant=spec.tenant, status=_REJECT if code != "shutdown"
                else "shed",
                family=spec.family, mode=spec.mode,
                eps_tilde=float(spec.eps_tilde),
                arrival_tick=arrival, error=code, reason=reason))
            return {"tenant": spec.tenant, "admitted": False,
                    "error": code, "reason": reason}
        sig = signature_of(spec, self.cfg)
        key = signature_key(sig)
        self._queues.setdefault(key, []).append((spec, prob, arrival))
        return {"tenant": spec.tenant, "admitted": True, "error": None,
                "reason": None, "signature": key}

    def _validate(self, spec: TenantSpec) -> Optional[Tuple[str, str]]:
        if spec.family not in SERVE_FAMILIES:
            return ("unknown_family",
                    f"family {spec.family!r} not in {SERVE_FAMILIES}")
        if spec.mode not in detection.MODES:
            return ("unknown_mode",
                    f"mode {spec.mode!r} not in {detection.MODES}")
        if not (np.isfinite(spec.eps_tilde) and spec.eps_tilde > 0):
            return ("bad_eps", f"eps_tilde={spec.eps_tilde!r} must be finite > 0")
        if spec.mode != "sync" and not (
                0 <= int(spec.staleness) <= self.cfg.max_staleness):
            return ("bad_staleness",
                    f"staleness={spec.staleness} outside [0, "
                    f"{self.cfg.max_staleness}]")
        if int(spec.persistence) < 1:
            return ("bad_persistence",
                    f"persistence={spec.persistence} must be >= 1")
        if spec.margin is not None and spec.margin < 1.0:
            return ("bad_margin", f"margin={spec.margin} must be >= 1")
        return None

    # -- lane packing + the tick loop ----------------------------------------

    def _runner_for(self, key: str, sig: Dict[str, Any], prob0):
        """Warm-executable registry: compile once per signature, ever."""
        runner = self._runners.get(key)
        if runner is not None:
            self.warm_hits += 1
            return runner

        def step_fn(X, ops):
            return prob0.update_with_residual_batched(X, **ops)

        runner = detection.make_lane_runner(
            sig["mode"], step_fn, sig["chunk"], ord=float(prob0.ord))
        self._runners[key] = runner
        self.compile_count += 1
        return runner

    def _pack(self) -> None:
        for key, queue in self._queues.items():
            if not queue:
                continue
            bucket = self._buckets.get(key)
            if bucket is None:
                spec0, prob0, _ = queue[0]
                sig = signature_of(spec0, self.cfg)
                runner = self._runner_for(key, sig, prob0)
                bucket = _LaneBucket(key, sig, runner, prob0, self.cfg)
                self._buckets[key] = bucket
            else:
                # a live bucket IS the warm executable for its signature
                self.warm_hits += len(queue[:len(bucket.free_lanes)])
            while queue and bucket.free_lanes:
                spec, prob, arrival = queue.pop(0)
                bucket.admit(spec, prob, arrival, self.tick_count,
                             self.cfg.margin)

    def step_tick(self) -> None:
        """One service tick: pack free lanes from the queues, then advance
        every busy bucket one chunk and harvest converged/expired lanes."""
        t0 = time.perf_counter()
        self._pack()
        for bucket in self._buckets.values():
            if not bucket.busy:
                continue
            state, cs = bucket.run_chunk()
            conv = np.asarray(state.converged)
            dstep = np.asarray(state.detect_step)
            detected = np.asarray(state.detected)
            for lane, tenant in enumerate(bucket.active):
                if tenant is None:
                    continue
                tenant.chunks.append(cs[lane])
                tenant.steps += self.cfg.chunk
                if conv[lane]:
                    self._retire(bucket, lane, "served",
                                 int(dstep[lane]), float(detected[lane]))
                elif tenant.steps >= self.cfg.max_steps:
                    self._retire(bucket, lane, "timeout", None, None)
        self.tick_count += 1
        self._wall_s += time.perf_counter() - t0

    def _retire(self, bucket: _LaneBucket, lane: int, status: str,
                detect_step: Optional[int],
                detected: Optional[float]) -> None:
        tenant = bucket.active[lane]
        spec = tenant.spec
        raw = np.concatenate(tenant.chunks)[: tenant.steps]
        series = _sigma_np(raw, tenant.ord)
        from repro.core.termination import (
            detection_consistent,
            oracle_detect_step,
        )

        oracle = oracle_detect_step(series, spec.eps_tilde)
        false = False
        if status == "served":
            false = not detection_consistent(
                detect_step, series, spec.eps_tilde,
                factor=self.cfg.oracle_factor)
        done = self.tick_count + 1   # harvested at the end of this tick
        self.reports.append(TenantReport(
            tenant=spec.tenant, status=status, family=spec.family,
            mode=spec.mode, eps_tilde=float(spec.eps_tilde),
            converged=(status == "served"),
            detect_step=detect_step, detected_residual=detected,
            steps=tenant.steps,
            arrival_tick=tenant.arrival_tick,
            admit_tick=tenant.admit_tick, done_tick=done,
            queue_wait_ticks=tenant.admit_tick - tenant.arrival_tick,
            ttd_ticks=done - tenant.arrival_tick,
            oracle_step=oracle, false_detection=false,
            signature=bucket.key))
        bucket.release(lane)

    # -- lifecycle -----------------------------------------------------------

    @property
    def busy(self) -> bool:
        """True while any lane is occupied or any tenant is queued."""
        return (any(b.busy for b in self._buckets.values())
                or any(self._queues.values()))

    def run(self, max_ticks: Optional[int] = None) -> None:
        """Tick until drained (or ``max_ticks`` more ticks have elapsed)."""
        end = None if max_ticks is None else self.tick_count + int(max_ticks)
        while self.busy and (end is None or self.tick_count < end):
            self.step_tick()

    def shutdown(self, drain: bool = True) -> None:
        """Stop admission; optionally drain.

        With ``drain=True`` every in-flight lane completes (bounded by the
        per-tenant ``max_steps`` budget) and reports; queued-but-unpacked
        tenants are shed either way — a shutdown must not start new work.
        """
        self._accepting = False
        for queue in self._queues.values():
            for spec, _, arrival in queue:
                self.reports.append(TenantReport(
                    tenant=spec.tenant, status="shed", family=spec.family,
                    mode=spec.mode, eps_tilde=float(spec.eps_tilde),
                    arrival_tick=arrival, error="shutdown",
                    reason="queued at shutdown"))
            queue.clear()
        if drain:
            # max_steps bounds every lane, so this loop terminates
            while any(b.busy for b in self._buckets.values()):
                self.step_tick()

    # -- reporting -----------------------------------------------------------

    def report(self) -> ServeReport:
        """Assemble the service-level ``ServeReport``."""
        served = [r for r in self.reports if r.status == "served"]
        timeouts = sum(r.status == "timeout" for r in self.reports)
        ttd = [r.ttd_ticks for r in served]
        qw = [r.queue_wait_ticks for r in served]
        wall = self._wall_s
        return ServeReport(
            converged=bool(served) and timeouts == 0,
            detected_residual=None, detect_step=None,
            outer_iters=self.tick_count,
            residual_history=np.empty(0),
            wall_segments=[("serve", wall)],
            trace=None, membership_log=[], x=None, raw=None,
            tenants=list(self.reports),
            served=len(served),
            rejected=sum(r.status == _REJECT for r in self.reports),
            shed=sum(r.status == "shed" for r in self.reports),
            timeouts=timeouts,
            false_detections=sum(r.false_detection for r in self.reports),
            compile_count=self.compile_count,
            warm_hits=self.warm_hits,
            ticks=self.tick_count,
            queue_wait_ticks=_percentiles(qw),
            ttd_ticks=_percentiles(ttd),
            throughput={
                "tenants_per_tick": (len(served) / self.tick_count
                                     if self.tick_count else 0.0),
                "tenants_per_s": len(served) / wall if wall > 0 else 0.0,
            },
        )


def _percentiles(xs: Sequence[float]) -> Dict[str, float]:
    """Nearest-rank percentiles (deterministic integers in, integers out —
    CI exact-gates these)."""
    if not xs:
        return {}
    s = sorted(xs)
    out = {}
    for q in (50, 95, 99):
        rank = max(int(np.ceil(q / 100.0 * len(s))) - 1, 0)
        out[f"p{q}"] = float(s[rank])
    return out


def serve_detection(requests: Sequence[Tuple[TenantSpec, int]],
                    cfg: ServeConfig = ServeConfig()) -> ServeReport:
    """Open-loop convenience driver: play ``(spec, arrival_tick)`` requests
    into a fresh service, tick until everything (queue + lanes) drains, and
    return the ``ServeReport``.

    Arrivals are sorted by tick; the service idles (ticks with no busy
    bucket) through gaps in the schedule, so queue waits are measured
    against the *requested* arrival time — the open-loop convention a
    Poisson load generator needs (``benchmarks/bench_serve.py``).
    """
    pending = sorted(requests, key=lambda ra: (ra[1], ra[0].tenant))
    svc = DetectionService(cfg)
    i = 0
    while i < len(pending) or svc.busy:
        while i < len(pending) and pending[i][1] <= svc.tick_count:
            spec, arrival = pending[i]
            svc.submit(spec, arrival_tick=arrival)
            i += 1
        svc.step_tick()
    svc.shutdown(drain=True)
    return svc.report()


# ---------------------------------------------------------------------------
# LM decode serving (the historical driver — K-stale batch termination)
# ---------------------------------------------------------------------------


def serve(
    arch: str,
    batch: int = 4,
    prompt_len: int = 32,
    max_new: int = 32,
    use_reduced: bool = True,
    eos_id: int = 2,
    staleness: int = 4,
    seed: int = 0,
    greedy: bool = True,
):
    """Batched prefill + decode with the paper's detection at batch level.

    The "all sequences finished" predicate is a reduction over per-sequence
    EOS flags evaluated K steps stale (PFAIT lane, ε = 0.5 on the indicator
    g = 1 − [all finished], ring depth K): the decode loop never fences on
    the termination check, trading ≤K wasted steps for an un-fenced
    steady-state loop.  On exit the report is *drained*: tokens generated
    past a sequence's first EOS are masked back to ``eos_id``, and
    ``stopped_by`` records whether the stale detector fired or the
    ``max_new`` budget ran out with sequences still unfinished.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs.base import reduced as reduced_cfg
    from repro.configs.registry import get_arch
    from repro.models import Model

    cfg = get_arch(arch)
    if use_reduced:
        cfg = reduced_cfg(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    prefill = jax.jit(model.make_prefill())
    decode = jax.jit(model.make_decode_step(), donate_argnums=(1,))

    rng = np.random.default_rng(seed)
    if cfg.frontend is None:
        prompts = jnp.asarray(
            rng.integers(3, cfg.vocab_size, (batch, prompt_len)), jnp.int32
        )
    else:
        prompts = jnp.asarray(
            rng.standard_normal((batch, prompt_len, cfg.frontend_dim)), jnp.float32
        )

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    # extend caches with room for max_new tokens
    def extend(u):
        """Pad every layer's KV cache with room for max_new tokens."""
        out = []
        for entry in u:
            e = {}
            for k2, v2 in entry.items():
                if k2 == "kv":
                    e["kv"] = {kk: jnp.pad(vv, ((0, 0), (0, 0), (0, max_new),
                                                (0, 0), (0, 0)))
                               for kk, vv in v2.items()}
                else:
                    e[k2] = v2
            out.append(e)
        return tuple(out)

    cache = extend(cache)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # [B]
    finished = jnp.zeros((batch,), bool)
    generated = [tok]
    # K-stale termination (PFAIT monitor): g = 1 − [all finished] ∈ {0, 1},
    # ε = 0.5, so the monitor fires when the flag launched K steps ago was
    # set — the loop never fences on the fresh flag
    mon = detection.MonitorConfig(mode="pfait", eps=0.5,
                                  staleness=staleness, ord=float("inf"))
    mstate = detection.init_state(mon)
    steps_done = 0
    stopped_by = "budget"
    for i in range(max_new - 1):
        inp = tok[:, None]
        if cfg.frontend is not None:
            inp = jax.nn.one_hot(tok, cfg.frontend_dim, dtype=jnp.float32)[:, None, :]
        logits, cache = decode(params, cache, inp, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        finished = finished | (tok == eos_id)
        generated.append(tok)
        g = 1.0 - jnp.all(finished).astype(jnp.float32)
        mstate = detection.step(mon, mstate, g)
        steps_done = i + 1
        if bool(detection.should_stop(mstate)):   # stale view only
            stopped_by = "detector"
            break
    toks = np.asarray(jnp.stack(generated, axis=1))
    # drain: mask the ≤K tokens generated past each sequence's first EOS —
    # the stale detector deliberately over-runs, the report must not leak
    # the over-run tokens as real output
    eos_hits = toks == eos_id
    past_eos = np.cumsum(np.cumsum(eos_hits, axis=1), axis=1) > 1
    toks = np.where(past_eos, eos_id, toks)
    wall = time.time() - t0
    return {
        "tokens": toks,
        "finished": np.asarray(finished),
        "steps": steps_done,
        "stopped_by": stopped_by,
        "wall_s": wall,
        "tok_per_s": batch * steps_done / max(wall, 1e-9),
    }


def _demo_service() -> None:
    """Tiny mixed-tenant demo of the detection service (CLI)."""
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(12):
        fam = ("convdiff", "pagerank", "mlfixed")[i % 3]
        problem = {
            "convdiff": {"n": 8, "p": 4, "rho": 0.9},
            "pagerank": {"n": 64, "p": 4},
            "mlfixed": {"n": 16, "p": 4, "m_rows": 48, "cond": 10.0},
        }[fam]
        spec = TenantSpec(
            tenant=f"t{i:02d}", family=fam, problem=problem,
            seed=int(rng.integers(0, 4)),
            eps_tilde=float(rng.choice([1e-4, 1e-5])),
            mode=str(rng.choice(["pfait", "nfais5"])),
            staleness=int(rng.integers(0, 5)))
        reqs.append((spec, int(rng.integers(0, 6))))
    rep = serve_detection(reqs, ServeConfig(lanes=4, chunk=16,
                                            max_steps=2048))
    print(f"[serve] served={rep.served} rejected={rep.rejected} "
          f"false={rep.false_detections} compiles={rep.compile_count} "
          f"warm={rep.warm_hits} ticks={rep.ticks} "
          f"ttd={rep.ttd_ticks} wall={rep.wall_s:.2f}s")


def main() -> None:
    """CLI: LM decode serving (default) or the detection-service demo."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--detection-demo", action="store_true",
                    help="run the multi-tenant detection-service demo")
    args = ap.parse_args()
    if args.detection_demo:
        _demo_service()
        return
    if not args.arch:
        ap.error("--arch is required unless --detection-demo is given")
    out = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                max_new=args.max_new, use_reduced=args.reduced)
    print(f"[serve] generated {out['tokens'].shape} in {out['wall_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s, stopped by {out['stopped_by']})")


if __name__ == "__main__":
    main()
