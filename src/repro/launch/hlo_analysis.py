"""HLO-text analysis: loop-aware FLOP / HBM-byte / collective accounting.

Why not just ``compiled.cost_analysis()``?  On this backend it counts each
``while`` body **once**, but scan-over-layers puts ~all of the work inside a
while loop — flops would be understated by the layer count.  We therefore
parse the optimized HLO:

* computations are split and mapped to **execution multipliers** by walking
  ``while`` instructions (trip count extracted from the condition's
  ``compare(counter, constant(N)), direction=LT`` pattern) and propagating
  through ``calls=``/``to_apply=``/``body=``/``condition=`` edges;
* **FLOPs** are summed over ``dot``/``convolution`` instructions
  (2 · |result| · |contraction|) × multiplier;
* **HBM bytes** are estimated at the buffer level: operand + result sizes of
  instructions in HBM-level computations (ENTRY, loop bodies/conds, branches)
  — fusion-internal traffic is excluded, matching post-fusion HBM behaviour;
* **collective traffic** per op type with ring wire-byte factors.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_NAME_RE = re.compile(r"%([\w\.\-]+)")
# op name comes right after the result type(s): "<types> opname(...)"
_OP_RE = re.compile(r"(?:\}|\]|\))\s*([\w\-]+)\(")

# ops that move no HBM bytes of their own
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "custom-call", "iota",
    "get-dimension-size", "opt-barrier",
}


def shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> List[List[int]]:
    """All array shapes appearing in a type string."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append(dims)
    return out


@dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)  # name -> result type


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    header_re = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
    for raw in text.splitlines():
        s = raw.strip()
        if cur is None:
            if s.endswith("{"):
                m = header_re.match(s)
                if m and " = " not in s.split("(", 1)[0]:
                    cur = Computation(m.group(1))
                    comps[cur.name] = cur
            continue
        if s == "}" or s.startswith("} "):
            cur = None
            continue
        mi = _INSTR_RE.match(s)
        if not mi:
            continue
        name, rest = mi.group(1), mi.group(2)
        mo = _OP_RE.search(rest)
        if mo:
            op = mo.group(1)
            type_part = rest[: mo.start() + 1]
            args_part = rest[mo.end():]
        else:
            # "type opname(...)": fall back to word before '('
            mo2 = re.search(r"([\w\-]+)\(", rest)
            if not mo2:
                continue
            op = mo2.group(1)
            type_part = rest[: mo2.start()]
            args_part = rest[mo2.end():]
        # operands: names inside the first paren group
        depth, end = 1, 0
        for i, ch in enumerate(args_part):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _NAME_RE.findall(args_part[:end])
        instr = Instr(name=name, result_type=type_part, op=op,
                      operands=operands, line=s)
        cur.instrs.append(instr)
        cur.types[name] = type_part
    return comps


# ---------------------------------------------------------------------------
# Execution multipliers
# ---------------------------------------------------------------------------


def _trip_count(comp: Computation, comps: Dict[str, "Computation"]) -> int:
    """Trip count of a while condition computation.

    The loop bound is the (usually unique) integer constant in the condition;
    the compare itself may be wrapped in a kLoop fusion, so we accept any
    constant as the bound as long as a compare is reachable from here."""
    consts = []
    has_compare = False
    for ins in comp.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)", ins.line)
            if m:
                consts.append(int(m.group(1)))
        if ins.op == "compare":
            has_compare = True
        m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
        if m and m.group(1) in comps:
            # the bound and/or the compare may live inside a kLoop fusion
            # the condition merely calls — collect from there too
            for i2 in comps[m.group(1)].instrs:
                if i2.op == "compare":
                    has_compare = True
                if i2.op == "constant":
                    m2 = re.search(r"constant\((\d+)\)", i2.line)
                    if m2:
                        consts.append(int(m2.group(1)))
    if has_compare and consts:
        return max(consts)
    return 1


def execution_multipliers(comps: Dict[str, Computation]) -> Tuple[Dict[str, int], Set[str]]:
    """(multiplier per computation, HBM-level computation names)."""
    mult: Dict[str, int] = defaultdict(lambda: 1)
    hbm_level: Set[str] = set()
    # ENTRY = the computation literally named ENTRY or containing the root —
    # we detect it as any computation never referenced by others.
    referenced: Set[str] = set()
    edges: List[Tuple[str, str, int]] = []  # (parent, child, extra_mult)
    for cname, comp in comps.items():
        for ins in comp.instrs:
            if ins.op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                if mb and mc and mc.group(1) in comps:
                    tc = max(_trip_count(comps[mc.group(1)], comps), 1)
                    edges.append((cname, mb.group(1), tc))
                    edges.append((cname, mc.group(1), tc))
                    referenced.update([mb.group(1), mc.group(1)])
            for key in ("calls=", "to_apply=", "body=", "condition=",
                        "branch_computations={", "called_computations={"):
                for m in re.finditer(re.escape(key) + r"%?([\w\.\-,%]+)", ins.line):
                    for nm in re.findall(r"[\w\.\-]+", m.group(1)):
                        if nm in comps:
                            referenced.add(nm)
                            if key == "calls=" or (key == "to_apply=" and ins.op == "call"):
                                edges.append((cname, nm, 1))
    roots = [c for c in comps if c not in referenced]
    for r in roots:
        mult[r] = 1
        hbm_level.add(r)
    # propagate (few levels of nesting; fixpoint)
    for _ in range(8):
        changed = False
        for parent, child, extra in edges:
            m = mult[parent] * extra
            if mult[child] < m:
                mult[child] = m
                changed = True
        if not changed:
            break
    # HBM-level: roots + while bodies/conds + conditional branches + call
    # targets (shard_map wraps its body in a `call`) — fixpoint over nesting
    for _ in range(8):
        added = False
        for cname, comp in comps.items():
            if cname not in hbm_level and cname not in {r for r in roots}:
                pass
            for ins in comp.instrs:
                targets = []
                if ins.op == "while":
                    for key in ("body=", "condition="):
                        m = re.search(key + r"%?([\w\.\-]+)", ins.line)
                        if m:
                            targets.append(m.group(1))
                elif ins.op == "conditional":
                    m = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
                    if m:
                        targets.extend(re.findall(r"[\w\.\-]+", m.group(1)))
                elif ins.op == "call":
                    m = re.search(r"to_apply=%?([\w\.\-]+)", ins.line)
                    if m:
                        targets.append(m.group(1))
                else:
                    continue
                if cname in hbm_level:
                    for t in targets:
                        if t in comps and t not in hbm_level:
                            hbm_level.add(t)
                            added = True
        if not added:
            break
    return dict(mult), hbm_level


# ---------------------------------------------------------------------------
# FLOPs (dot/convolution with trip counts)
# ---------------------------------------------------------------------------


def _dot_flops(ins: Instr, comp: Computation) -> float:
    res_shapes = shape_elems(ins.result_type)
    if not res_shapes:
        return 0.0
    out_elems = 1
    for d in res_shapes[0]:
        out_elems *= d
    # contraction size from lhs operand shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    contract = 1
    if m and ins.operands:
        lhs_type = comp.types.get(ins.operands[0], "")
        lhs_shapes = shape_elems(lhs_type)
        if lhs_shapes:
            dims = lhs_shapes[0]
            for di in m.group(1).split(","):
                if di != "" and int(di) < len(dims):
                    contract *= dims[int(di)]
    return 2.0 * out_elems * contract


@dataclass
class ProgramStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_counts: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_bytes_alg: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_bytes_wire: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    flops_unscaled: float = 0.0     # without loop multipliers (sanity)
    loop_trip_max: float = 1.0      # largest while multiplier (per-iteration
                                    # normalisation for single-loop programs)

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.coll_bytes_wire.values())

    def as_dict(self) -> Dict:
        return {
            "flops": float(self.flops),
            "flops_unscaled": float(self.flops_unscaled),
            "hbm_bytes": float(self.hbm_bytes),
            "collective_counts": {k: float(v) for k, v in self.coll_counts.items()},
            "collective_bytes_alg": {k: float(v) for k, v in self.coll_bytes_alg.items()},
            "collective_bytes_wire": {k: float(v) for k, v in self.coll_bytes_wire.items()},
            "total_wire_bytes": float(self.total_wire_bytes),
        }


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_factor(op: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op == "all-gather":
        return (g - 1) / g
    if op == "reduce-scatter":
        return float(g - 1)  # result is the 1/g shard
    if op == "all-to-all":
        return (g - 1) / g
    return 1.0  # collective-permute


def program_stats(text: str, default_group: int = 256) -> ProgramStats:
    comps = parse_module(text)
    mult, hbm_level = execution_multipliers(comps)
    st = ProgramStats()
    st.loop_trip_max = float(max(mult.values(), default=1))
    for cname, comp in comps.items():
        m = mult.get(cname, 1)
        is_hbm = cname in hbm_level
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                f = _dot_flops(ins, comp)
                st.flops += m * f
                st.flops_unscaled += f
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in COLLECTIVES:
                nbytes = shape_bytes(ins.result_type)
                g = _group_size(ins.line, default_group)
                st.coll_counts[base] += m
                st.coll_bytes_alg[base] += m * nbytes
                st.coll_bytes_wire[base] += m * nbytes * _wire_factor(base, g)
            if is_hbm and ins.op not in _FREE_OPS and not ins.op.endswith("-done"):
                st.hbm_bytes += m * _instr_hbm_bytes(ins, comp, comps)
    return st


def _fusion_operand_bytes(ins: Instr, comp: Computation,
                          comps: Dict[str, Computation]) -> Optional[float]:
    """Slice-aware operand traffic of a fusion: a parameter consumed only by
    a dynamic-slice/gather inside the fusion body reads the *slice*, not the
    full (possibly layer-stacked, GiB-sized) buffer."""
    mm = re.search(r"calls=%?([\w\.\-]+)", ins.line)
    if not mm or mm.group(1) not in comps:
        return None
    body = comps[mm.group(1)]
    param_idx: Dict[str, int] = {}
    for i2 in body.instrs:
        if i2.op == "parameter":
            mp = re.search(r"parameter\((\d+)\)", i2.line)
            if mp:
                param_idx[i2.name] = int(mp.group(1))
    if not param_idx:
        return None
    consumed: Dict[int, float] = {}
    for i2 in body.instrs:
        for o in i2.operands:
            if o not in param_idx:
                continue
            idx = param_idx[o]
            if i2.op in ("dynamic-slice", "gather", "slice"):
                b = float(shape_bytes(i2.result_type))
            elif i2.op == "dynamic-update-slice":
                # big buffer operand of a dus: traffic ≈ update size
                others = [shape_bytes(body.types.get(oo, ""))
                          for oo in i2.operands if oo != o]
                b = float(min(others) if others else 0)
            else:
                b = float(shape_bytes(body.types.get(o, "")))
            consumed[idx] = max(consumed.get(idx, 0.0), b)
    total = 0.0
    for k, o in enumerate(ins.operands):
        full = float(shape_bytes(comp.types.get(o, "")))
        total += min(consumed.get(k, full), full)
    return total


def _instr_hbm_bytes(ins: Instr, comp: Computation,
                     comps: Dict[str, Computation]) -> float:
    """HBM-traffic estimate for one buffer-level instruction.

    In-place slice updates (scan writing per-layer activations/caches) touch
    only the slice, not the carried buffer; slicing/gather reads only what it
    returns; fusions are slice-aware (see _fusion_operand_bytes)."""
    res = shape_bytes(ins.result_type)
    ops = [shape_bytes(comp.types.get(o, "")) for o in ins.operands]
    key = ins.op + " " + ins.name
    if "dynamic-update-slice" in key or "scatter" in key:
        small = sum(ops) - (max(ops) if ops else 0)
        return 2.0 * small
    if "dynamic-slice" in key or "gather" in key or ins.op == "slice":
        return 2.0 * res
    if ins.op == "fusion":
        fb = _fusion_operand_bytes(ins, comp, comps)
        if fb is not None:
            return res + fb
    if ins.op in ("while", "call", "conditional"):
        # bodies are HBM-level computations counted on their own; charging
        # the call site too would double-count every shard_map body
        # (while-carry ping-pong is additionally aliased in place)
        return 0.0
    return res + sum(ops)


# Back-compat shim used by dryrun
@dataclass
class CollectiveStats:
    counts: Dict[str, float]
    bytes_alg: Dict[str, float]
    bytes_wire: Dict[str, float]

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.bytes_wire.values())

    def as_dict(self) -> Dict:
        return {
            "counts": dict(self.counts),
            "bytes_alg": dict(self.bytes_alg),
            "bytes_wire": dict(self.bytes_wire),
            "total_wire_bytes": float(self.total_wire_bytes),
        }


def collective_stats(hlo_text: str, default_group: int = 256) -> CollectiveStats:
    st = program_stats(hlo_text, default_group)
    return CollectiveStats(
        counts=dict(st.coll_counts),
        bytes_alg=dict(st.coll_bytes_alg),
        bytes_wire=dict(st.coll_bytes_wire),
    )
