"""Optimizers and distributed-optimization tricks."""
from repro.optim.adamw import (  # noqa: F401
    AdamState,
    AdamW,
    apply_updates,
    constant_schedule,
    cosine_schedule,
    global_norm,
)
