"""Error-feedback int8 gradient compression for cross-pod data parallelism.

At 2+ pods the inter-pod links are the scarcest resource (DESIGN §5); the
pod-axis gradient reduction is compressed ~4–8× by replacing the f32
all-reduce (wire = 2·(g−1)/g · 4B/elem) with an **all-gather of int8
payloads + per-row scales** followed by a local dequantized sum
(wire = (g−1)/g · 1B/elem) — exact for heterogeneous scales, no second
reduction round.  Error feedback carries the quantization residual into the
next step, keeping Adam convergence unbiased in practice
(Karimireddy et al., 2019).

``compressed_psum`` is the drop-in used inside shard_map for the pod axis;
intra-pod reductions stay full precision.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8 quantization; returns (q [r, c] i8, scale [r, 1])."""
    flat = x.reshape(x.shape[0] if x.ndim > 1 else 1, -1)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(shape)


def ef_compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback compression of one gradient leaf.

    Returns (q, scale, new_err) with g + err == deq(q, scale) + new_err."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    deq = dequantize_int8(q, scale, g.shape)
    new_err = corrected - deq
    return q, scale, new_err


def ef_init(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(
    g: jax.Array, err: jax.Array, axis_name: str = "pod"
) -> Tuple[jax.Array, jax.Array]:
    """Mean over ``axis_name`` with int8 wire traffic (inside shard_map).

    all-gathers the int8 payload + scales and sums locally — exact for
    per-participant scales; returns (mean gradient, new error state)."""
    q, scale, new_err = ef_compress(g, err)
    q_all = jax.lax.all_gather(q, axis_name)          # [g, r, c] int8 wire
    s_all = jax.lax.all_gather(scale, axis_name)      # [g, r, 1] f32 (tiny)
    total = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
    n = q_all.shape[0]
    return (total / n).reshape(g.shape), new_err


def compressed_tree_psum(grads: Any, err_state: Any, axis_name: str = "pod"):
    """Tree-mapped ``compressed_psum``; returns (mean grads, new err state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out, errs = [], []
    for g, e in zip(flat_g, flat_e):
        r, ne = compressed_psum(g, e, axis_name)
        out.append(r.astype(g.dtype))
        errs.append(ne)
    return jax.tree.unflatten(treedef, out), jax.tree.unflatten(treedef, errs)
