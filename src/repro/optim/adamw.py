"""AdamW + schedules — optax-style minimal implementation (no deps).

Moment dtype is configurable: the 400 B-class configs use bf16 moments so
param+optimizer state fits a single v5e pod (documented in DESIGN.md §5).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Optional[str] = None   # None => param dtype; "bfloat16"/"float32"

    def _mdtype(self, p):
        return jnp.dtype(self.moment_dtype) if self.moment_dtype else p.dtype

    def init(self, params) -> AdamState:
        def zeros(p):
            return jnp.zeros(p.shape, self._mdtype(p))
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def update(
        self, grads, state: AdamState, params
    ) -> Tuple[Any, AdamState, jax.Array]:
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
        lr = self.learning_rate(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            m_new = b1 * m32 + (1 - b1) * g
            v_new = b2 * v32 + (1 - b2) * g * g
            mhat, vhat = m_new / bc1, v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return {"u": (-lr * delta).astype(p.dtype),
                    "m": m_new.astype(m.dtype), "v": v_new.astype(v.dtype)}

        def is_rec(x):
            return isinstance(x, dict) and set(x) == {"u", "m", "v"}
        treedef = jax.tree.structure(grads)
        out = jax.tree.map(upd, grads, state.m, state.v, params)
        flat = jax.tree.leaves(out, is_leaf=is_rec)
        updates = jax.tree.unflatten(treedef, [t["u"] for t in flat])
        m = jax.tree.unflatten(treedef, [t["m"] for t in flat])
        v = jax.tree.unflatten(treedef, [t["v"] for t in flat])
        return updates, AdamState(step=step, m=m, v=v), gnorm


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(leaf.astype(jnp.float32) ** 2) for leaf in leaves))


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(math.pi * frac))
        return jnp.where(s < warmup, warm, cos)

    return lr


def constant_schedule(value: float):
    return lambda step: jnp.full((), value, jnp.float32)
