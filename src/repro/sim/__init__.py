"""Trace-driven replay + calibration.

* ``replay``    — per-worker partial-order replayer: re-executes a recorded
                  ``core.trace`` under modified assumptions (shard count,
                  reduction topology, stragglers) and predicts wall time,
                  detection step, and residual staleness at detection.
* ``calibrate`` — fit event-sim ``DelayModel`` distributions and replay
                  cost models from measured device traces, with a
                  goodness-of-fit report.
"""
from repro.sim.replay import (  # noqa: F401
    CostModel,
    ReplayVerdict,
    WhatIf,
    what_if_table,
)
from repro.sim.replay import replay as replay_trace  # noqa: F401
from repro.sim.calibrate import fit_cost_model, fit_delay_model  # noqa: F401
