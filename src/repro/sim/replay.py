"""Trace-driven replay — re-execute a recorded run under modified
assumptions and predict what a measurement cannot reach.

A recorded ``core.trace`` pins down everything the replayer needs: the
per-worker asynchrony knobs (inner sweeps, halo delay, contribution lag),
the reduction mode and its topology facts (``core.reduction``), the
effective detection-monitor parameters, and the launched global-residual
series.  Replay then runs two deterministic models over it:

* **Detection replay** — a numpy mirror of ``core.detection``'s monitor
  update (the ``_lane_step`` semantics: ring of K+1 in-flight reductions,
  visible value = the one launched K checks ago) consuming the recorded
  residual series under the *target* topology's staleness structure.  On a
  self-replay (same topology, same K) the predicted detection step is
  exact by construction — the device trace records precisely the series
  the device monitor consumed.
* **Wall-clock replay** — a per-worker partial-order virtual clock:
  worker w's step k starts when its own step k-1 and its neighbours'
  steps k-delay[w]-1 (the halo it consumes) have finished, pays
  ``inner[w] · sweep_cost · straggler[w]`` of compute, and then the
  topology's synchronisation cost (nothing for flat non-blocking, an
  XOR-partner pairwise sync per butterfly round, a full barrier +
  2·ceil(log2 p) hops for flat blocking / tree).  Wall time is the
  last worker's clock at the predicted detection step.

What-if knobs (``WhatIf``): scale the shard count (per-shard compute
scales by p_ref/p — the cells-per-shard model), swap the reduction
topology (``flat-nonblocking`` / ``flat-blocking`` / ``butterfly`` /
``tree``), inject stragglers.  Everything is pure numpy and RNG-free:
the same trace and the same what-if always produce the identical verdict.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.trace import Trace

#: replayable reduction topologies (what-if targets)
TOPOLOGIES = ("flat-nonblocking", "flat-blocking", "butterfly", "tree")

_MODE_TOPOLOGY = {
    "nonblocking": "flat-nonblocking",
    "blocking": "flat-blocking",
    "rdoubling": "butterfly",
}


@dataclass(frozen=True)
class CostModel:
    """Per-shard cost constants the virtual clock runs on.

    ``sweep_s`` is the compute cost of ONE inner sweep on one shard at the
    reference shard count ``p_ref``; scaling to p shards multiplies by
    ``p_ref / p`` (each shard owns proportionally fewer cells).  ``hop_s``
    is one message hop; ``residual_pass_s`` the blocking mode's extra
    residual-only pass (detection work on the critical path).

    ``sweep_s_per_worker`` (optional) carries heterogeneous per-worker
    sweep costs at ``p_ref`` — fitted by ``sim.calibrate.fit_cost_model``
    from per-worker sweep-event gaps when the trace resolves them (engine
    traces; device traces interpolate uniformly and carry no skew).  Its
    mean is ``sweep_s`` by construction, so scalar consumers are unchanged;
    the virtual clock uses the per-worker vector whenever the replayed
    shard count matches its length.
    """

    sweep_s: float
    hop_s: float
    residual_pass_s: float
    p_ref: int
    sweep_s_per_worker: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.sweep_s < 0 or self.hop_s < 0 or self.residual_pass_s < 0:
            raise ValueError("cost-model constants must be >= 0")
        if self.p_ref < 1:
            raise ValueError(f"p_ref={self.p_ref} must be >= 1")
        if self.sweep_s_per_worker is not None:
            spw = tuple(float(v) for v in self.sweep_s_per_worker)
            if not spw or any(v < 0 for v in spw):
                raise ValueError("sweep_s_per_worker must be non-empty "
                                 "with entries >= 0")
            object.__setattr__(self, "sweep_s_per_worker", spw)

    def sweep_at(self, p: int) -> float:
        return self.sweep_s * self.p_ref / max(int(p), 1)

    def sweep_vec_at(self, p: int) -> Optional[np.ndarray]:
        """Per-worker sweep costs at shard count p, or None when the model
        is uniform or the worker count no longer matches the fit."""
        if self.sweep_s_per_worker is None or len(
                self.sweep_s_per_worker) != int(p):
            return None
        return (np.asarray(self.sweep_s_per_worker, dtype=np.float64)
                * self.p_ref / max(int(p), 1))

    def residual_pass_at(self, p: int) -> float:
        return self.residual_pass_s * self.p_ref / max(int(p), 1)


@dataclass(frozen=True)
class WhatIf:
    """Modified assumptions to replay a trace under (all optional)."""

    p: Optional[int] = None                 # target shard count
    topology: Optional[str] = None          # TOPOLOGIES member
    stragglers: Mapping[int, float] = field(default_factory=dict)
    hop_s: Optional[float] = None           # override the cost model's hop

    def __post_init__(self):
        if self.topology is not None and self.topology not in TOPOLOGIES:
            raise ValueError(
                f"topology {self.topology!r} not in {TOPOLOGIES}")
        if self.p is not None and self.p < 1:
            raise ValueError(f"what-if p={self.p} must be >= 1")
        for w, f in self.stragglers.items():
            if f <= 0:
                raise ValueError(f"straggler factor {f} for worker {w} "
                                 "must be > 0")


@dataclass
class ReplayVerdict:
    """What the replayer predicts for one (trace, what-if) pair."""

    p: int
    topology: str
    converged: bool
    predicted_detect_step: Optional[int]   # outer step the claim fires at
    predicted_outer_iters: int
    predicted_wall_s: float
    staleness_steps: Optional[int]         # age of the detected value
    detected_residual: Optional[float]     # the (stale) value that fired
    fresh_residual: Optional[float]        # launched value at the same step
    approximate: bool                      # lossy topology conversion


# ---------------------------------------------------------------------------
# Detection replay (numpy mirror of core.detection's monitor update)
# ---------------------------------------------------------------------------


def visible_series(series: np.ndarray, topology: str, K: int,
                   p: int) -> np.ndarray:
    """What the monitor sees at each step, per topology.

    * flat topologies: the value launched K checks ago (the ring of K+1
      in-flight reductions; blocking forces K=0 upstream).
    * butterfly: a global value completes every R = log2(p) rounds and is
      sampled at its epoch's first round — visible at step k is the value
      launched at step R·floor((k+1)/R) − R, +inf before the first epoch
      completes (mirrors ``shard_runtime._butterfly_step``).
    """
    n = len(series)
    out = np.full(n, np.inf)
    if topology == "butterfly":
        R = max(p.bit_length() - 1, 1) if p > 1 else 1
        if p > 1 and p & (p - 1):
            raise ValueError(f"butterfly needs a power-of-two p, got {p}")
        for k in range(n):
            if p == 1:
                out[k] = series[k]
                continue
            if k >= R - 1:
                out[k] = series[R * ((k + 1) // R) - R]
        return out
    if K == 0:
        return np.asarray(series, dtype=np.float64).copy()
    out[K:] = series[:n - K]
    return out


def replay_monitor(series: np.ndarray, mode: str, eps: float,
                   eps_tilde: float, K: int, persistence: int,
                   topology: str = "flat-nonblocking", p: int = 1):
    """Replay the detection monitor over a launched-residual series.

    Numpy mirror of ``core.detection._lane_step`` (NFAIS2 uses the
    verifier-free fallback — a host replay cannot re-run the blocking
    verification).  Returns ``(detect_step | None, detected, fresh)``.
    """
    vis = visible_series(np.asarray(series, dtype=np.float64), topology,
                         int(K), int(p))
    m = int(persistence)
    persist = 0
    phase = 0
    confirm_at = None
    for k, v in enumerate(vis):
        below = v < eps
        if mode in ("sync", "pfait"):
            if below:
                return k, float(v), float(series[k])
            continue
        persist = persist + 1 if below else 0
        if mode == "nfais2":
            if persist >= m:                      # candidate fires
                if v < eps_tilde:                 # fallback acceptance
                    return k, float(v), float(series[k])
                persist = 0
            continue
        # nfais5 — two-phase persistence confirmation
        confirming = phase == 1 and confirm_at is not None and k >= confirm_at
        if confirming:
            if below and persist >= 2 * m:
                return k, float(v), float(series[k])
            phase, confirm_at = 0, None
        if persist >= m and phase == 0:
            phase, confirm_at = 1, k + m
    return None, None, None


# ---------------------------------------------------------------------------
# Wall-clock replay (per-worker partial-order virtual clock)
# ---------------------------------------------------------------------------


def predict_wall(steps: int, p: int, inner: np.ndarray, delay: np.ndarray,
                 straggler: np.ndarray, cost: CostModel, topology: str,
                 hop_s: Optional[float] = None) -> float:
    """Virtual-clock wall time of ``steps`` outer steps on ``p`` workers.

    Per-step structural model (``sim.calibrate.fit_cost_model`` inverts
    exactly this shape on uniform traces, so the constants round-trip):
    worker w's step k starts once its own step k-1 and the neighbour halos
    it consumes (published at step k-delay[w]-1, one hop old) are in; it
    pays ``inner[w]·sweep·straggler[w]`` of compute; the topology then adds
    its synchronisation — nothing (flat non-blocking), an XOR-partner
    pairwise sync + hop (butterfly round k mod log2 p), or a full barrier
    plus 2·ceil(log2 p) hops of allreduce (flat blocking, which also pays
    the extra residual-only pass / tree, which does not).
    """
    if steps <= 0:
        return 0.0
    hop = float(cost.hop_s if hop_s is None else hop_s)
    sweep_vec = cost.sweep_vec_at(p)
    sweep = sweep_vec if sweep_vec is not None else cost.sweep_at(p)
    comp = inner.astype(np.float64) * sweep * straggler.astype(np.float64)
    allreduce = 2.0 * math.ceil(math.log2(p)) * hop if p > 1 else 0.0
    R = max(p.bit_length() - 1, 1) if p > 1 else 1
    idx = np.arange(p)
    H = int(delay.max()) + 2       # history window the halo deps can reach
    hist = np.zeros((H, p))        # hist[k % H] = finish time of step k
    t = np.zeros(p)
    for k in range(steps):
        start = t.copy()
        if p > 1:
            dep = k - delay - 1    # halo published at step k - delay - 1
            row = np.mod(dep, H)
            left = np.where(idx > 0,
                            hist[row, np.maximum(idx - 1, 0)], -np.inf)
            right = np.where(idx < p - 1,
                             hist[row, np.minimum(idx + 1, p - 1)], -np.inf)
            nbr = np.where(dep >= 0, np.maximum(left, right) + hop, -np.inf)
            start = np.maximum(start, nbr)
        fin = start + comp
        if topology == "flat-blocking":
            fin = np.full(p, fin.max() + cost.residual_pass_at(p) + allreduce)
        elif topology == "tree":
            fin = np.full(p, fin.max() + allreduce)
        elif topology == "butterfly" and p > 1:
            partner = idx ^ (1 << (k % R))
            fin = np.maximum(fin, fin[partner]) + hop
        # flat-nonblocking: the collective stays off the critical path
        hist[k % H] = fin
        t = fin
    return float(t.max())


# ---------------------------------------------------------------------------
# Trace parsing + the replay entrypoint
# ---------------------------------------------------------------------------


def _per_worker(meta_val, p: int) -> np.ndarray:
    arr = np.asarray(meta_val if meta_val is not None else 1)
    if arr.ndim == 0:
        return np.full(p, float(arr))
    if len(arr) == p:
        return arr.astype(np.float64)
    # shard-count change: broadcast the mean knob
    return np.full(p, float(arr.mean()))


def replay(trace: Trace, cost: CostModel,
           what_if: Optional[WhatIf] = None) -> ReplayVerdict:
    """Re-execute a recorded trace under modified assumptions.

    Deterministic: the same ``(trace, cost, what_if)`` triple always
    produces an identical verdict.  The recorded residual series is held
    invariant under shard-count scaling (the fixed-point contraction is a
    problem property, not a topology property) — the knobs that *do* move
    the detection step are the topology's staleness structure and the
    monitor's pipeline depth, both replayed exactly.

    Topology conversions from a butterfly-recorded trace are flagged
    ``approximate``: its series already carries the log2(p) pipeline
    staleness, which a host replay cannot un-bake.
    """
    wi = what_if or WhatIf()
    meta = trace.meta
    p0 = trace.p
    p = int(wi.p if wi.p is not None else p0)
    src_topology = meta.get("topology")
    if src_topology is None:
        src_topology = _MODE_TOPOLOGY.get(meta.get("reduction", ""), "flat")
    if src_topology == "flat":
        src_topology = _MODE_TOPOLOGY[meta.get("reduction", "nonblocking")]
    topology = wi.topology or src_topology
    # a butterfly-recorded series already carries its pipeline staleness:
    # re-applying butterfly (or flattening) double/under-counts it, so the
    # self-replay consumes it flat and conversions are flagged approximate
    src_butterfly = src_topology == "butterfly"
    consume_topology = topology
    approximate = False
    if src_butterfly:
        if topology == src_topology and p == p0:
            consume_topology = "flat-nonblocking"   # staleness already baked
        else:
            approximate = True
    mon = dict(meta.get("monitor") or {})
    mode = mon.get("mode", "pfait")
    if mode == "nfais2":
        approximate = True   # verifier-free fallback semantics
    series = np.asarray(trace.residual_series(), dtype=np.float64)
    if series.size == 0:
        raise ValueError("trace carries no reduce-event residual series "
                         "(record with trace_len > 0 / record_trace=True)")
    K = int(mon.get("staleness", 0))
    if topology in ("flat-blocking", "tree", "butterfly"):
        K = 0   # barrier / pipelined topologies consume immediately
    detect_step, detected, fresh = replay_monitor(
        series, mode, float(mon.get("eps", 1e-6)),
        float(mon.get("eps_tilde", mon.get("eps", 1e-6))), K,
        int(mon.get("persistence", 4)), consume_topology, p)
    converged = detect_step is not None
    outer = detect_step + 1 if converged else len(series)

    inner = _per_worker(meta.get("inner_sweeps"), p)
    delay = _per_worker(meta.get("halo_delay"), p).astype(np.int64)
    straggler = np.ones(p)
    for w, f in wi.stragglers.items():
        if 0 <= int(w) < p:
            straggler[int(w)] = float(f)
    wall = predict_wall(outer, p, inner, delay, straggler, cost, topology,
                        hop_s=wi.hop_s)

    staleness_steps = None
    if converged:
        if consume_topology == "butterfly" and p > 1:
            R = max(p.bit_length() - 1, 1)
            staleness_steps = detect_step - (R * ((detect_step + 1) // R) - R)
        else:
            staleness_steps = K
    return ReplayVerdict(
        p=p, topology=topology, converged=converged,
        predicted_detect_step=detect_step, predicted_outer_iters=outer,
        predicted_wall_s=wall, staleness_steps=staleness_steps,
        detected_residual=detected, fresh_residual=fresh,
        approximate=approximate,
    )


def what_if_table(trace: Trace, cost: CostModel, shard_counts,
                  topologies=TOPOLOGIES) -> List[Dict]:
    """The extrapolation grid: one verdict row per (p, topology)."""
    rows = []
    for p in shard_counts:
        for topo in topologies:
            if topo == "butterfly" and int(p) & (int(p) - 1):
                continue
            v = replay(trace, cost, WhatIf(p=int(p), topology=topo))
            rows.append({
                "p": v.p, "topology": v.topology,
                "predicted_wall_s": v.predicted_wall_s,
                "predicted_detect_step": v.predicted_detect_step,
                "staleness_steps": v.staleness_steps,
                "approximate": v.approximate,
            })
    return rows
