"""Calibration — fit event-sim delay models and replay cost models from
measured device traces, with a goodness-of-fit report.

Two fits close the measurement → simulation loop:

* ``fit_delay_model`` — fit an ``async_engine.DelayModel`` to a sample of
  measured durations (repeated timed executions of the same compiled
  program — a jitted ``lax.while_loop`` admits no per-step timestamps, so
  the honest sampling unit is the whole short program).  Lognormal fit is
  moment matching in log space (median = exp(mean log), dispersion =
  std log — exactly the parameterisation ``DelayModel`` samples with);
  goodness of fit is a Kolmogorov–Smirnov statistic against the fitted
  CDF.  No scipy on the image: the normal CDF runs on ``math.erf``.
* ``fit_cost_model`` — extract the ``sim.replay.CostModel`` constants from
  a measured schema trace: per-sweep compute cost from the run's wall and
  its sweep ledger, hop/extra-pass defaults as documented fractions when
  the trace cannot separate them (flagged in the returned report).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.trace import Trace
from repro.sim.replay import CostModel

#: hop latency as a fraction of one sweep when no blocking trace pins it
DEFAULT_HOP_FRACTION = 0.05


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + np.vectorize(math.erf)(z / math.sqrt(2.0)))


def ks_statistic(samples: np.ndarray, cdf) -> float:
    """Two-sided Kolmogorov–Smirnov distance between the empirical CDF of
    ``samples`` and a model ``cdf`` callable."""
    x = np.sort(np.asarray(samples, dtype=np.float64))
    n = x.size
    if n == 0:
        raise ValueError("no samples")
    F = np.asarray(cdf(x), dtype=np.float64)
    lo = np.max(F - np.arange(n) / n)
    hi = np.max((np.arange(n) + 1) / n - F)
    return float(max(lo, hi))


def fit_delay_model(samples: Sequence[float], dist: str = "lognormal",
                    floor: float = 1e-6,
                    alpha: float = 0.05) -> Tuple[object, Dict]:
    """Fit a ``DelayModel`` of family ``dist`` to measured durations.

    Returns ``(model, report)`` where ``report`` carries the fitted
    parameters, the KS statistic against the fitted CDF, the
    level-``alpha`` critical value ``c(alpha)/sqrt(n)`` (asymptotic,
    with the standard two-sided coefficient), and a boolean ``ok``.
    """
    from repro.core.async_engine import DelayModel

    x = np.asarray(list(samples), dtype=np.float64)
    if x.size < 2:
        raise ValueError(f"need >= 2 samples to fit, got {x.size}")
    if (x <= 0).any():
        raise ValueError("durations must be > 0")

    if dist == "lognormal":
        logs = np.log(x)
        mu, sig = float(np.mean(logs)), float(np.std(logs))
        model = DelayModel(base=math.exp(mu), sigma=max(sig, 0.0),
                           floor=floor, dist="lognormal")
        if sig > 0:
            ks = ks_statistic(x, lambda v: _norm_cdf(
                (np.log(v) - mu) / sig))
        else:
            ks = ks_statistic(x, lambda v: (v >= math.exp(mu)).astype(float))
    elif dist == "fixed":
        base = float(np.median(x))
        model = DelayModel(base=base, sigma=0.0, floor=floor, dist="fixed")
        ks = ks_statistic(x, lambda v: (v >= base).astype(float))
    elif dist == "pareto":
        # DelayModel samples base·(1 + Pareto(shape)): support [base, ∞).
        base = float(np.min(x)) * (1.0 - 1e-12)
        ratio = np.log(x / base)
        shape = float(1.0 / max(np.mean(ratio), 1e-12))
        model = DelayModel(base=base, sigma=0.25, floor=floor,
                           dist="pareto", shape=shape)
        ks = ks_statistic(
            x, lambda v: 1.0 - np.power(np.maximum(v, base) / base, -shape))
    else:
        raise ValueError(f"dist {dist!r} not in ('lognormal', 'pareto', "
                         "'fixed')")

    n = x.size
    # asymptotic two-sided critical values: c(0.05)=1.358, c(0.01)=1.628
    c = {0.05: 1.358, 0.01: 1.628}.get(alpha, 1.358)
    crit = c / math.sqrt(n)
    report = {
        "dist": dist, "n": int(n), "base": float(model.base),
        "sigma": float(model.sigma), "shape": float(model.shape),
        "ks_statistic": float(ks), "ks_critical": float(crit),
        "alpha": float(alpha), "ok": bool(ks <= crit),
    }
    return model, report


def fit_cost_model(trace: Trace, hop_s: Optional[float] = None,
                   residual_pass_s: Optional[float] = None
                   ) -> Tuple[CostModel, Dict]:
    """Extract the replay cost constants from one measured schema trace.

    Inverts ``sim.replay.predict_wall``'s per-step structural model on the
    uniform-worker case: the measured step time decomposes into compute
    (``inner`` sweeps), the halo hop, and the recorded reduction's own
    synchronisation terms (extra residual pass + 2·ceil(log2 p)-hop
    allreduce for blocking, one partner hop for the butterfly, nothing for
    flat non-blocking).  With the defaults hop = 5% of a sweep and
    residual pass = one sweep, the decomposition is solved in closed form;
    a constant pinned by a second measurement is taken as given instead.
    The report flags which constants were measured and which defaulted,
    and a self-replay of the calibrating trace reproduces its wall
    exactly (up to run-to-run noise of the measurement itself).
    """
    meta = trace.meta
    p = trace.p
    wall = float(meta.get("wall_s", 0.0))
    outer = int(meta.get("outer_iters", 0))
    if wall <= 0 or outer <= 0:
        raise ValueError("trace has no measured wall/outer to calibrate from")
    inner = np.asarray(meta.get("inner_sweeps", 1), dtype=np.float64)
    max_inner = max(float(inner.max() if inner.ndim else inner), 1.0)
    step_s = wall / outer
    reduction = meta.get("reduction", "nonblocking")
    L2 = 2.0 * math.ceil(math.log2(p)) if p > 1 else 0.0
    delay = np.asarray(meta.get("halo_delay", 0), dtype=np.float64)
    min_delay = float(delay.min() if delay.ndim else delay)
    # a delayed neighbour view (delay >= 1) is already in flight when the
    # step starts, so its hop leaves the critical path
    halo_f = 1.0 if (p > 1 and min_delay == 0) else 0.0
    defaults = []
    f = DEFAULT_HOP_FRACTION
    if hop_s is None and residual_pass_s is None:
        # closed form: step = sweep·(inner + halo_f·f [+ mode terms])
        denom = max_inner + halo_f * f
        if reduction == "blocking":
            denom += 1.0 + L2 * f     # extra pass + allreduce
        elif reduction == "rdoubling" and p > 1:
            denom += f                # one partner hop per round
        sweep_s = step_s / denom
        hop_s = f * sweep_s
        residual_pass_s = sweep_s
        defaults += ["hop_s", "residual_pass_s"]
    else:
        if hop_s is None:
            hop_s = f * step_s / max_inner
            defaults.append("hop_s")
        if residual_pass_s is None:
            residual_pass_s = step_s / max_inner
            defaults.append("residual_pass_s")
        sync = halo_f * hop_s
        if reduction == "blocking":
            sync += residual_pass_s + L2 * hop_s
        elif reduction == "rdoubling" and p > 1:
            sync += hop_s
        sweep_s = max(step_s - sync, 1e-12) / max_inner
    rho = _per_worker_rates(trace, p)
    spw = None if rho is None else tuple(float(sweep_s) * rho)
    cost = CostModel(sweep_s=float(sweep_s), hop_s=float(hop_s),
                     residual_pass_s=float(residual_pass_s), p_ref=p,
                     sweep_s_per_worker=spw)
    report = {
        "p_ref": p, "reduction": reduction, "wall_s": wall, "outer": outer,
        "sweep_s": cost.sweep_s, "hop_s": cost.hop_s,
        "residual_pass_s": cost.residual_pass_s,
        "sweep_s_per_worker": (None if spw is None else list(spw)),
        "worker_rate_ratio": (None if rho is None else list(rho)),
        "defaulted": defaults,
    }
    return cost, report


def _per_worker_rates(trace: Trace, p: int) -> Optional[np.ndarray]:
    """Relative per-worker sweep rates from the trace's sweep-event gaps.

    For each worker the mean gap between its consecutive sweep events is
    its empirical per-step cost; normalising by the cross-worker mean gives
    unit-mean ratios ``ρ_w`` so ``sweep_s · ρ_w`` decomposes the fitted
    aggregate cost per worker (``CostModel.sweep_s_per_worker``).  Returns
    None when the trace carries no per-worker skew to fit — fewer than two
    sweep events for some worker, or uniform gaps (device traces timestamp
    all workers on one interpolated clock, so their skew is unresolvable
    by construction and the scalar model is the honest one).
    """
    gaps = np.full(p, np.nan)
    for w in range(p):
        ts = np.asarray(sorted(
            e["t"] for e in trace.events
            if e["kind"] == "sweep" and e["w"] == w), dtype=np.float64)
        if ts.size >= 2:
            d = np.diff(ts)
            d = d[d > 0]
            if d.size:
                gaps[w] = float(np.mean(d))
    if not np.isfinite(gaps).all() or gaps.size == 0:
        return None
    rho = gaps / gaps.mean()
    if np.allclose(rho, 1.0, rtol=1e-9, atol=1e-12):
        return None
    return rho


def engine_config_from_fit(model, hop_latency: Optional[float] = None):
    """Transfer a fitted compute ``DelayModel`` into an event-sim
    ``EngineConfig`` (channel defaults to the compute model scaled by the
    documented hop fraction unless pinned)."""
    import dataclasses

    from repro.core.async_engine import EngineConfig

    chan = dataclasses.replace(
        model, base=max(model.base * DEFAULT_HOP_FRACTION, model.floor))
    cfg = EngineConfig(compute=model, channel=chan)
    if hop_latency is not None:
        cfg = dataclasses.replace(cfg, hop_latency=float(hop_latency))
    return cfg
