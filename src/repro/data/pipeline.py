"""Synthetic-token data pipeline: deterministic, shardable, restartable.

Production posture on a real cluster: each host generates (or reads) only
its addressable shard of the global batch; batches are keyed by ``step`` so
a restarted job resumes *exactly* where the checkpoint left off (no data
replay/skip bookkeeping — determinism comes from hashing (seed, step)).
A double-buffered background thread keeps one batch ahead of the device.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32000
    frontend_dim: int = 0       # >0 → embedding inputs (modality stub)
    zipf_a: float = 1.2         # skewed token distribution (realistic-ish)


def _rng_for(seed: int, step: int, shard: int) -> np.random.Generator:
    # splitmix-style mix so (seed, step, shard) streams are independent
    key = (seed * 0x9E3779B97F4A7C15 + step * 0xBF58476D1CE4E5B9 + shard) % (2**63)
    return np.random.default_rng(key)


def synth_batch(cfg: DataConfig, step: int, batch: int, seq: int,
                shard: int = 0) -> Dict[str, np.ndarray]:
    """One host-shard of the global batch for ``step``."""
    rng = _rng_for(cfg.seed, step, shard)
    if cfg.frontend_dim > 0:
        inputs = rng.standard_normal((batch, seq, cfg.frontend_dim)).astype(np.float32)
        # embedding-frontend targets are synthetic classes: independent
        # draws, no next-token shift (rolling random labels is a no-op)
        labels = rng.integers(0, cfg.vocab_size, (batch, seq)).astype(np.int32)
    else:
        z = rng.zipf(cfg.zipf_a, size=(batch, seq)).astype(np.int64)
        inputs = np.minimum(z - 1, cfg.vocab_size - 1).astype(np.int32)
        labels = np.roll(inputs, -1, axis=-1).astype(np.int32)
        labels[:, -1] = -1   # wraparound position carries no target
    return {"inputs": inputs, "labels": labels}


class Prefetcher:
    """Double-buffered background batch producer (depth-1 lookahead)."""

    def __init__(self, make_batch, start_step: int = 0, depth: int = 2):
        self._make = make_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._error: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                item = (step, self._make(step))
            except BaseException as e:   # surface producer death to __next__
                self._error = e
                self._stop.set()
                return
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.2)
                    step += 1
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            try:
                return self._q.get(timeout=0.2)
            except queue.Empty:
                if self._error is not None:
                    raise RuntimeError(
                        "Prefetcher producer thread died") from self._error
                if self._stop.is_set():
                    raise StopIteration   # closed and drained
                # producer alive and queue momentarily empty: keep waiting

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)


def device_batches(
    model_cfg: ModelConfig,
    shape: ShapeConfig,
    mesh=None,
    seed: int = 0,
    start_step: int = 0,
):
    """Iterator of (step, device-ready batch) for a train shape."""
    dc = DataConfig(
        seed=seed,
        vocab_size=model_cfg.vocab_size,
        frontend_dim=model_cfg.frontend_dim if model_cfg.frontend else 0,
    )

    def make(step: int):
        host = synth_batch(dc, step, shape.global_batch, shape.seq_len)
        if mesh is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        from jax.sharding import NamedSharding, PartitionSpec as P

        dp = tuple(a for a in mesh.axis_names if a != "model")
        out = {}
        for k, v in host.items():
            spec = P(dp, *([None] * (v.ndim - 1)))
            out[k] = jax.device_put(v, NamedSharding(mesh, spec))
        return out

    return Prefetcher(make, start_step=start_step)
