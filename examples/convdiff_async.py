"""Asynchronous iterations + every detection protocol, event-faithful.

Reproduces the paper's experimental *methodology* end to end on the
event-level simulator:

  1. platform-stability probe at ε = ε̃ (paper §4.2, Table 1),
  2. margin calibration from the observed overshoot (core/termination.py),
  3. production run at ε = ε̃/margin with the protocol head-to-head
     (Tables 4–5 structure: PFAIT fastest, guarantee restored).

Run:  PYTHONPATH=src python examples/convdiff_async.py
"""
import dataclasses


from repro.core.async_engine import AsyncEngine, stable_platform
from repro.core.protocols import NFAIS2, NFAIS5, PFAIT
from repro.core.termination import calibrate_margin, stability_band
from repro.solvers.convdiff import ConvDiffProblem

EPS_TILDE = 1e-6
N, P = 16, 8


def solve_once(protocol_cls, eps, seed, **kw):
    prob = ConvDiffProblem(n=N, p=P, rho=0.93, seed=seed)
    cfg = dataclasses.replace(stable_platform(), seed=seed, max_iters=60_000)
    eng = AsyncEngine(prob, cfg, protocol_cls(eps, ord=prob.ord, **kw))
    return eng.run()


def main() -> None:
    # -- 1. stability probe -------------------------------------------------
    print("== stability probe: PFAIT at ε = ε̃ ==")
    rs = [solve_once(PFAIT, EPS_TILDE, seed).r_star for seed in range(5)]
    lo, hi = stability_band(rs, EPS_TILDE)
    print(f"   r* band: ε{lo:+.1e} … ε{hi:+.1e}")

    # -- 2. margin calibration ---------------------------------------------
    seeds = iter(range(100, 200))
    rep = calibrate_margin(
        lambda eps: solve_once(PFAIT, eps, next(seeds)).r_star,
        EPS_TILDE, runs=5,
    )
    print(f"== calibration: overshoot {rep.overshoot:.2f}× → margin "
          f"{rep.margin:.0f} → production ε = {rep.eps_production:.1e} ==")

    # -- 3. production head-to-head ------------------------------------------
    print("== production: PFAIT(ε̃/margin) vs snapshot protocols(ε̃) ==")
    print(f"{'protocol':10s} {'r*':>10s} {'wtime':>8s} {'k_max':>6s} "
          f"{'msgs':>22s}")
    for name, cls, eps, kw in (
        ("pfait", PFAIT, rep.eps_production, {}),
        ("nfais2", NFAIS2, EPS_TILDE, {}),
        ("nfais5", NFAIS5, EPS_TILDE, {"m": 4}),
    ):
        r = solve_once(cls, eps, seed=7, **kw)
        proto_msgs = {k: v for k, v in r.msg_counts.items() if k != "data"}
        print(f"{name:10s} {r.r_star:10.2e} {r.wtime:8.4f} {r.k_max:6d} "
              f"{str(proto_msgs):>22s}")
        assert r.r_star < EPS_TILDE

    print("\nall protocols meet ε̃; PFAIT does it with zero protocol messages.")


if __name__ == "__main__":
    main()
