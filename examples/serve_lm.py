"""Serving example: batched prefill + decode across cache families.

Runs three backbone families (GQA transformer, pure SSM, hybrid windowed-
attention+SSM) through the same serving driver — the decode loop's
termination check is K-stale (PFAIT-style, see launch/serve.py).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import serve


def main() -> None:
    for arch in ("qwen2-1.5b", "mamba2-130m", "hymba-1.5b"):
        out = serve(arch, batch=4, prompt_len=32, max_new=24, use_reduced=True)
        print(f"{arch:14s} tokens {out['tokens'].shape} "
              f"steps={out['steps']:3d} {out['tok_per_s']:7.1f} tok/s "
              f"finished={out['finished'].sum()}/{len(out['finished'])}")


if __name__ == "__main__":
    main()
