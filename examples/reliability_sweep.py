"""Reliability-lab quickstart: when does a detected residual lie?

Sweeps a handful of adversarial platform scenarios over PFAIT (the paper's
protocol-free detection) and NFAIS2 (data-carrying snapshots) on both
problem families, scoring every run with the false/late-detection oracle.
The punchline reproduces the paper's reliability claim *and* its limits:

  * on stable/unstable/bursty platforms PFAIT's claim holds (overshoot
    within the ε-margin the paper calibrates),
  * under an interface blackout PFAIT confidently reports convergence
    while the true residual is orders of magnitude above ε — a false
    detection — whereas NFAIS2 refuses to fire.

Runs in well under 30 s.

Run:  PYTHONPATH=src python examples/reliability_sweep.py
"""
import dataclasses

from repro.core.async_engine import PLATFORMS
from repro.core.protocols import NFAIS2, PFAIT
from repro.core.reliability import detection_report, platform_health, run_traced
from repro.core.scenarios import standard_scenarios
from repro.solvers.convdiff import ConvDiffProblem
from repro.solvers.pagerank import PageRankProblem

BASE = 1e-3
SCENARIOS = ("stable", "burst", "straggler", "pause_resume", "blackout")
PROBLEMS = {
    "convdiff": (lambda seed: ConvDiffProblem(n=12, p=4, rho=0.9, seed=seed),
                 1e-6),
    "pagerank": (lambda seed: PageRankProblem(n=128, p=4, seed=seed), 1e-8),
}


def main() -> None:
    specs = standard_scenarios(BASE)
    print(f"{'problem':9s} {'scenario':13s} {'protocol':8s} {'verdict':11s} "
          f"{'detected':>10s} {'true@detect':>11s} {'overshoot':>9s}")
    for pname, (mk, eps) in PROBLEMS.items():
        for sname in SCENARIOS:
            spec = specs[sname]
            for proto_name, proto_mk in (
                ("pfait", lambda pr: PFAIT(eps, ord=pr.ord)),
                ("nfais2", lambda pr: NFAIS2(eps, ord=pr.ord)),
            ):
                cfg = dataclasses.replace(
                    PLATFORMS[spec.platform](BASE), seed=0, max_iters=1500,
                    scenario=spec.scenario,
                )
                res, rec = run_traced(lambda: mk(0), cfg, proto_mk,
                                      residual_stride=25)
                rep = detection_report(rec, eps)
                verdict = ("FALSE-DETECT" if rep.false_detection
                           else "ok" if res.terminated else "undetected")
                print(f"{pname:9s} {sname:13s} {proto_name:8s} {verdict:11s} "
                      f"{rep.detected_residual:10.2e} "
                      f"{rep.true_at_detect:11.2e} {rep.overshoot:9.1f}")
            health = platform_health(rec, mk(0).p, BASE)
            if health.silent_workers or health.stragglers:
                print(f"{'':9s} {sname:13s} platform-health: "
                      f"silent={health.silent_workers} "
                      f"stragglers={health.stragglers}")

    print("\nPFAIT lies exactly where the platform starves its reductions of"
          "\nfresh interface data; the snapshot protocol goes silent instead."
          "\nFull matrix: PYTHONPATH=src:. python benchmarks/reliability_matrix.py")


if __name__ == "__main__":
    main()
