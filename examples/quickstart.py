"""Quickstart: the paper's technique in 60 lines.

Solves the paper's convection–diffusion problem with the TPU-native
distributed fixed-point driver under all four detection modes, and shows
the PFAIT trade: no protocol cost, stale detection, margin restores the
precision guarantee.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)  # residuals below 1e-7 need f64

import jax.numpy as jnp

from repro.core import detection
from repro.solvers import jacobi
from repro.solvers.convdiff import Stencil, make_rhs
from repro.solvers.fixed_point import SolverConfig, _zero_ghosts, ghosted, solve_single

EPS_TILDE = 1e-6   # desired precision for ‖Ax − b‖∞
N = 20             # 20³ interior grid


def main() -> None:
    st = Stencil.for_contraction(N, nu=1.0, a=(1.0, 1.0, 1.0), rho=0.95)
    b = jnp.asarray(make_rhs(N, seed=0))

    print(f"convection–diffusion {N}³, target ε̃ = {EPS_TILDE:.0e}\n")
    print(f"{'mode':8s} {'ε used':>9s} {'outer':>6s} {'detected r':>11s} "
          f"{'exact r*':>11s} {'r* < ε̃':>7s}")
    for mode in ("sync", "pfait", "nfais2", "nfais5"):
        mon = detection.for_mode(
            mode, eps_tilde=EPS_TILDE, margin=10.0,   # PFAIT: ε = ε̃/10
            staleness=0 if mode == "sync" else 4,      # K-stale reduction
            persistence=4, ord=float("inf"),
        )
        cfg = SolverConfig(stencil=st, monitor=mon, inner_sweeps=2,
                           max_outer=50_000)
        r = solve_single(cfg, b)
        g = ghosted(r.x, _zero_ghosts(r.x))
        exact = float(jnp.max(jnp.abs(jacobi.residual_block(st, g, b))))
        print(f"{mode:8s} {mon.eps:9.1e} {int(r.outer_iters):6d} "
              f"{float(r.residual):11.2e} {exact:11.2e} "
              f"{'yes' if exact < EPS_TILDE else 'NO':>7s}")

    print("\nPFAIT pays extra iterations (tighter ε) but removes every\n"
          "protocol synchronisation — on hardware that's the whole win.")


if __name__ == "__main__":
    main()
