"""End-to-end driver: train a ~100M-param LM for a few hundred steps on CPU
with PFAIT train-until-target termination, async checkpointing, and a
restart demonstration.

The model is a genuinely ~100M-param member of the qwen2 family (12 layers,
d_model 512, GQA kv=2, vocab 32k) — not the full 1.5B — so a few hundred
steps run in CPU-minutes.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import tempfile

from repro.configs.registry import get_arch
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--target-loss", type=float, default=1.5)
    args = ap.parse_args()

    # ~100M-param qwen2-family config
    base = get_arch("qwen2-1.5b")
    cfg100m = dataclasses.replace(
        base, num_layers=12, d_model=512, num_heads=8, num_kv_heads=2,
        head_dim=64, d_ff=2048, vocab_size=32_000,
    )
    print(f"model: {cfg100m.num_params()/1e6:.0f}M params "
          f"({cfg100m.num_layers}L d={cfg100m.d_model})")

    import repro.configs.registry as registry

    registry.ARCHS["qwen2-100m"] = cfg100m
    with tempfile.TemporaryDirectory() as ckdir:
        out = train(
            "qwen2-100m", steps=args.steps, batch=args.batch, seq=args.seq,
            use_reduced=False, target_loss=args.target_loss,
            monitor_mode="pfait", staleness=4,
            ckpt_dir=ckdir, ckpt_every=100, log_every=20,
        )
        print(f"\nran {out['steps_run']} steps in {out['wall_s']:.0f}s "
              f"({out['steps_run']/max(out['wall_s'],1e-9):.2f} steps/s)")
        if out["stop_step"] is not None:
            print(f"PFAIT monitor stopped training at step {out['stop_step']} "
                  f"(target loss {args.target_loss})")
        else:
            print(f"final loss {out['losses'][-1]:.4f} "
                  f"(target {args.target_loss} not reached in {args.steps} steps)")


if __name__ == "__main__":
    main()
