"""Fault-tolerance walkthrough: failure detection → restart plan → elastic
restore → resume with zero data replay.

Simulates the control-plane path a 1000-node deployment would take:
  1. heartbeats stop for some workers → `HeartbeatMonitor` flags them from
     a *stale* view (no liveness barrier — the PFAIT principle),
  2. `plan_restart` shrinks the mesh to the survivors and pins the data
     stream to the checkpoint step,
  3. the topology-free checkpoint restores onto the new mesh
     (`runtime/elastic.py`), and training resumes — the step-keyed data
     pipeline replays nothing and skips nothing.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""
import tempfile

from repro.checkpoint.checkpointer import Checkpointer
from repro.launch.train import train
from repro.runtime.fault_tolerance import HeartbeatMonitor, plan_restart
from repro.runtime.elastic import remesh


def main() -> None:
    with tempfile.TemporaryDirectory() as ckdir:
        # phase 1: train to step 30 with checkpoints every 10
        out1 = train("qwen2-1.5b", steps=30, batch=4, seq=64, use_reduced=True,
                     ckpt_dir=ckdir, ckpt_every=10, log_every=10)
        print(f"phase 1: trained to step {out1['steps_run']}, "
              f"loss {out1['losses'][-1]:.3f}")

        # phase 2: membership change — heartbeats stop for workers 3, 7
        hb = HeartbeatMonitor(timeout=10.0)
        for w in range(32):
            hb.beat(w, t=0.0)
        for w in range(32):
            if w not in (3, 7):
                hb.beat(w, t=20.0)
        failed = hb.failed(t=25.0)
        print(f"phase 2: failure detector flags workers {failed} "
              f"(stale-view, no barrier)")

        ck = Checkpointer(ckdir)
        plan = plan_restart(ck.latest_step(), workers=range(32), failed=failed,
                            model_axis=4)
        print(f"phase 3: restart plan — mesh {plan.new_mesh_shape}, "
              f"{plan.world_size} workers, resume data at step "
              f"{plan.data_resume_step}")

        # phase 4: rebuild a (shrunken) mesh and validate the checkpoint
        # reshards onto it (1 real device here; the validation logic is the
        # same at any scale because the checkpoint is topology-free)
        mesh = remesh(1, model_axis=1)
        print(f"phase 4: restored mesh {dict(mesh.shape)} — "
              f"resuming training from the checkpoint")

        out2 = train("qwen2-1.5b", steps=45, batch=4, seq=64, use_reduced=True,
                     ckpt_dir=ckdir, ckpt_every=10, log_every=10)
        assert out2["steps_run"] == 45
        print(f"phase 5: resumed {out1['steps_run']}→45 with no data replay; "
              f"final loss {out2['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
