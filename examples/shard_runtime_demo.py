"""Shard-runtime quickstart: the paper's detection head-to-head on real
(host-emulated) JAX shards.

Four device shards each own an x-pencil of the convection–diffusion state
and free-run with stale halos, lagged reduction lanes and heterogeneous
sweep rates.  The same monitor (core/detection.py) consumes the global
residual produced three ways:

  blocking     — barrier semantics + an extra exact residual pass (the
                 protocol-style baseline),
  nonblocking  — the paper: fused contribution, K-stale consumption,
  rdoubling    — modified recursive doubling (Zou & Magoulès 2019), one
                 butterfly round per outer step.

Run:  PYTHONPATH=src python examples/shard_runtime_demo.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import detection
from repro.launch.mesh import make_shard_mesh
from repro.runtime.shard_runtime import ShardRuntimeConfig, make_convdiff_runtime
from repro.solvers import jacobi
from repro.solvers.convdiff import Stencil, make_rhs
from repro.solvers.fixed_point import _zero_ghosts, ghosted

N = 16
EPS_TILDE = 1e-6


def exact_residual(st, x, b) -> float:
    r = np.asarray(jacobi.residual_block(st, ghosted(x, _zero_ghosts(x)), b),
                   dtype=np.float64)
    return float(np.linalg.norm(r.ravel()))


def main() -> None:
    mesh = make_shard_mesh(4)
    st = Stencil.for_contraction(N, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    b = jnp.asarray(make_rhs(N, seed=0))
    x0 = jnp.zeros_like(b)

    print(f"convection–diffusion {N}³ over {mesh.shape['shard']} shards, "
          f"ε̃ = {EPS_TILDE:.0e}\n")
    print(f"{'reduction':12s} {'ε used':>9s} {'outer':>6s} {'sweeps/shard':>14s} "
          f"{'detected r':>11s} {'exact r*':>11s} {'r* < ε̃':>7s}")
    for reduction, mode, margin in (
        ("blocking", "sync", 1.0),       # barrier + exact residual: no margin
        ("nonblocking", "pfait", 10.0),  # the paper: stale + tightened ε
        ("rdoubling", "pfait", 10.0),    # protocol baseline: butterfly rounds
    ):
        mon = detection.for_mode(mode, eps_tilde=EPS_TILDE, margin=margin,
                                 staleness=0 if mode == "sync" else 2)
        asym = {} if reduction == "blocking" else dict(
            inner_sweeps=(1, 2, 1, 3), halo_delay=(0, 1, 2, 1),
            contrib_lag=(0, 1, 0, 1))
        cfg = ShardRuntimeConfig(monitor=mon, reduction=reduction,
                                 max_outer=5000, **asym)
        run = jax.jit(make_convdiff_runtime(cfg, mesh, st, N))
        r = run(x0, b)
        r_star = exact_residual(st, r.x, b)
        sweeps = "/".join(str(int(s)) for s in r.local_sweeps)
        print(f"{reduction:12s} {mon.eps:9.1e} {int(r.outer_iters):6d} "
              f"{sweeps:>14s} {float(r.residual):11.2e} {r_star:11.2e} "
              f"{'yes' if r_star < EPS_TILDE else 'NO':>7s}")

    print("\nnon-blocking detection leaves the reduction off the critical\n"
          "path (zero extra passes); the ε-margin restores the guarantee\n"
          "the barrier used to buy — exactly the paper's trade, on device.")


if __name__ == "__main__":
    main()
