"""Unit tests for the loop-aware HLO analyzer (the roofline's foundation)."""
import pytest

from repro.launch import hlo_analysis as H

SYNTH = """
HloModule synth

%add_red (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%cond (p: (s32[], f32[8,128])) -> pred[] {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[128,128]{1,0} constant({...})
  %y = f32[8,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128]{1,0} all-reduce(%y), replica_groups=[16,16]<=[256], to_apply=%add_red
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,128]) tuple(%i2, %ar)
}

ENTRY %main (arg: f32[8,128]) -> f32[8,128] {
  %arg = f32[8,128]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,128]) tuple(%zero, %arg)
  %w = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert H.shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert H.shape_bytes("bf16[2,4096,5120]") == 2 * 4096 * 5120 * 2
    assert H.shape_bytes("(f32[4], bf16[4])") == 16 + 8
    assert H.shape_bytes("pred[]") == 1


def test_wire_factors():
    assert H._wire_factor("all-reduce", 16) == pytest.approx(2 * 15 / 16)
    assert H._wire_factor("all-gather", 16) == pytest.approx(15 / 16)
    assert H._wire_factor("reduce-scatter", 16) == 15.0
    assert H._wire_factor("collective-permute", 16) == 1.0
    assert H._wire_factor("all-reduce", 1) == 0.0


def test_group_size_parsing():
    assert H._group_size("replica_groups=[16,16]<=[256]", 256) == 16
    assert H._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}", 256) == 4
    assert H._group_size("no groups here", 99) == 99


def test_parse_module_and_while_multiplier():
    comps = H.parse_module(SYNTH)
    assert set(comps) >= {"add_red", "cond", "body", "main"}
    mult, hbm = H.execution_multipliers(comps)
    assert mult.get("body") == 24  # trip count from the condition constant
    assert "main" in hbm and "body" in hbm and "cond" in hbm


def test_program_stats_scales_flops_and_collectives_by_trip_count():
    st = H.program_stats(SYNTH, default_group=256)
    # dot: 2 * 8 * 128 * 128 flops, executed 24 times
    assert st.flops == pytest.approx(24 * 2 * 8 * 128 * 128)
    assert st.flops_unscaled == pytest.approx(2 * 8 * 128 * 128)
    assert st.coll_counts["all-reduce"] == 24
    expected_wire = 24 * (8 * 128 * 4) * (2 * 15 / 16)
    assert st.total_wire_bytes == pytest.approx(expected_wire)


def test_dynamic_slice_and_dus_heuristics():
    comps = H.parse_module(SYNTH)
    comp = comps["body"]
    ins = H.Instr(name="d", result_type="f32[1,128]{1,0} ", op="dynamic-slice",
                  operands=["x"], line="")
    assert H._instr_hbm_bytes(ins, comp, comps) == 2 * 128 * 4
"""Gather-style reads count the slice, not the buffer."""
