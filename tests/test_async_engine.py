"""Engine semantics + hypothesis properties of the asynchronous model (2).

Runs without the optional ``hypothesis`` dep: the property tests then
degrade to a fixed set of seeded-random cases instead of being skipped.
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to seeded-random cases
    HAVE_HYPOTHESIS = False


def given_seed(max_examples, fallback_seeds):
    """``@given(seed=...)`` with hypothesis, parametrized seeds without."""
    if HAVE_HYPOTHESIS:
        def deco(fn):
            return settings(max_examples=max_examples, deadline=None)(
                given(seed=st.integers(0, 10_000))(fn)
            )
    else:
        def deco(fn):
            return pytest.mark.parametrize("seed", fallback_seeds)(fn)
    return deco

from repro.core.async_engine import AsyncEngine, DelayModel, EngineConfig
from repro.core.protocols import PFAIT
from repro.solvers.convdiff import ConvDiffProblem


def _cfg(seed, fifo=False, het=0.3):
    return EngineConfig(
        compute=DelayModel(1e-3, sigma=0.4),
        channel=DelayModel(5e-4, sigma=0.8),
        fifo=fifo,
        het_factor=het,
        seed=seed,
        max_iters=30_000,
    )


@given_seed(max_examples=10, fallback_seeds=(0, 17, 424, 3133, 9041))
def test_termination_under_random_delays(seed):
    prob = ConvDiffProblem(n=8, p=4, rho=0.85, seed=seed % 7)
    eng = AsyncEngine(prob, _cfg(seed), PFAIT(1e-5, ord=prob.ord))
    r = eng.run()
    assert r.terminated
    assert r.r_star < 1e-3  # margin holds loosely even with wild delays


@given_seed(max_examples=8, fallback_seeds=(1, 23, 512, 7713))
def test_fifo_channels_deliver_in_order(seed):
    """Property: with fifo=True, per-channel delivery order == send order."""
    prob = ConvDiffProblem(n=8, p=4, rho=0.85, seed=1)
    eng = AsyncEngine(prob, _cfg(seed, fifo=True), PFAIT(1e-5, ord=prob.ord))
    deliveries = []
    orig = eng.protocol.on_data

    def spy(engine, msg, t):
        deliveries.append((msg.src, msg.dst, msg.send_time, t))
        return orig(engine, msg, t)

    eng.protocol.on_data = spy
    eng.run()
    per_chan = {}
    for src, dst, ts, td in deliveries:
        per_chan.setdefault((src, dst), []).append((ts, td))
    for chan, events in per_chan.items():
        send_order = [e[0] for e in events]
        assert send_order == sorted(send_order), "engine delivered out of send order"
        deliver_order = [e[1] for e in events]
        assert deliver_order == sorted(deliver_order)


def test_non_fifo_can_reorder():
    prob = ConvDiffProblem(n=8, p=4, rho=0.85, seed=1)
    cfg = dataclasses.replace(_cfg(3), channel=DelayModel(5e-4, sigma=2.0))
    eng = AsyncEngine(prob, cfg, PFAIT(1e-5, ord=prob.ord))
    deliveries = []
    orig = eng.protocol.on_data

    def spy(engine, msg, t):
        deliveries.append((msg.src, msg.dst, msg.send_time))
        return orig(engine, msg, t)

    eng.protocol.on_data = spy
    eng.run()
    reordered = 0
    per_chan = {}
    for src, dst, ts in deliveries:
        k = (src, dst)
        if k in per_chan and ts < per_chan[k]:
            reordered += 1
        per_chan[k] = max(per_chan.get(k, -1.0), ts)
    assert reordered > 0  # heavy-tailed delays overtake


def test_exhausted_max_iters_returns_undetected_instead_of_hanging():
    """With an unreachable ε and all workers at max_iters, the engine must
    return (terminated=False) — PFAIT's reduction relaunch loop previously
    spun forever on the frozen state."""
    prob = ConvDiffProblem(n=8, p=4, rho=0.85, seed=0)
    cfg = dataclasses.replace(_cfg(0), max_iters=30)
    r = AsyncEngine(prob, cfg, PFAIT(1e-15, ord=prob.ord)).run()
    assert not r.terminated
    assert r.k_max == 30
    assert np.isfinite(r.r_star)


def test_heterogeneous_progress():
    """card{k : i ∈ P(k)} grows for every worker, at different rates."""
    prob = ConvDiffProblem(n=8, p=4, rho=0.85, seed=2)
    eng = AsyncEngine(prob, _cfg(11, het=1.0), PFAIT(1e-7, ord=prob.ord))
    eng.run()
    assert int(np.min(eng.k)) > 0
    assert int(np.max(eng.k)) > int(np.min(eng.k))  # genuinely asynchronous


def test_exact_residual_decreases_with_iterations():
    prob = ConvDiffProblem(n=8, p=4, rho=0.85, seed=3)
    eng1 = AsyncEngine(prob, _cfg(5), PFAIT(1e-3, ord=prob.ord))
    r1 = eng1.run()
    prob2 = ConvDiffProblem(n=8, p=4, rho=0.85, seed=3)
    eng2 = AsyncEngine(prob2, _cfg(5), PFAIT(1e-8, ord=prob2.ord))
    r2 = eng2.run()
    assert r2.k_max > r1.k_max
    assert r2.r_star < r1.r_star
