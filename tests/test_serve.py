"""Detection service: lane packing, warm reuse, drain, admission.

The parity anchor: a tenant served through the packed multi-tenant lanes
must reach the SAME verdict (detect step, detected residual) as a solo
``detection.batched_monitor`` run over the tenant's recorded contribution
series — bitwise, because padding ring slots are never read and
``reset_lanes`` is pure ``where`` ops.
"""
import numpy as np
import pytest

from repro.core import detection
from repro.launch.serve import (
    DetectionService,
    ServeConfig,
    TenantSpec,
    serve_detection,
    signature_key,
    signature_of,
)

CFG = ServeConfig(lanes=4, chunk=16, max_steps=1024, max_staleness=8)


def spec(tenant="t0", family="convdiff", eps_tilde=1e-4, mode="pfait",
         K=2, m=4, seed=0, **problem):
    problem = problem or {"n": 8, "p": 4, "rho": 0.9}
    return TenantSpec(tenant=tenant, family=family, problem=problem,
                      seed=seed, eps_tilde=eps_tilde, mode=mode,
                      staleness=K, persistence=m)


def serve_specs(specs, cfg=CFG, arrivals=None):
    reqs = [(s, 0 if arrivals is None else arrivals[i])
            for i, s in enumerate(specs)]
    return serve_detection(reqs, cfg)


def tenant_reports(rep):
    return {t.tenant: t for t in rep.tenants}


# ---------------------------------------------------------------------------
# parity vs solo batched_monitor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["pfait", "nfais5", "sync"])
def test_packed_verdict_matches_solo_monitor(mode):
    """A packed tenant's (detect_step, residual) is bitwise what a solo
    batched_monitor produces on the recorded series."""
    eps_tilde = 1e-4
    K = 0 if mode == "sync" else 3
    specs = [spec(f"t{i}", mode=mode, eps_tilde=eps_tilde, K=K, seed=i)
             for i in range(3)]
    rep = serve_specs(specs)
    mon = detection.for_mode(mode, eps_tilde)
    for t in rep.tenants:
        assert t.status == "served", t
        # reconstruct the solo verdict from the exact per-seed series the
        # grid cell would produce for this tenant's problem
        from repro.launch.serve import make_serve_problem

        import jax.numpy as jnp

        pr = make_serve_problem(t.family, seed=int(t.tenant[1:]),
                                **dict(specs[0].problem))
        x0 = jnp.asarray(np.asarray(pr.lane_x0())[None], jnp.float32)
        ops = {k: jnp.asarray(np.asarray(v)[None], jnp.float32)
               for k, v in pr.lane_operands().items()}
        series = detection.contribution_series(
            lambda X: pr.update_with_residual_batched(X, **ops), x0,
            t.steps)
        v = detection.batched_monitor(
            mode, np.asarray(series), [mon.eps], [K], [4],
            ord=float(pr.ord), eps_tilde=[eps_tilde])
        assert bool(np.asarray(v.converged)[0, 0, 0, 0])
        assert int(np.asarray(v.detect_step)[0, 0, 0, 0]) == t.detect_step
        assert float(np.asarray(
            v.detected_residual)[0, 0, 0, 0]) == t.detected_residual


def test_retire_refill_preserves_later_tenant_verdicts():
    """More tenants than lanes: later tenants ride recycled lanes and must
    get the same verdict as when served alone."""
    cfg = ServeConfig(lanes=2, chunk=16, max_steps=1024)
    specs = [spec(f"t{i}", eps_tilde=(1e-3 if i % 2 else 1e-4), seed=i)
             for i in range(6)]
    packed = tenant_reports(serve_specs(specs, cfg))
    for s in specs:
        solo = tenant_reports(serve_specs([s], cfg))[s.tenant]
        assert packed[s.tenant].status == solo.status == "served"
        assert packed[s.tenant].detect_step == solo.detect_step
        assert packed[s.tenant].detected_residual == solo.detected_residual


def test_mixed_eps_lanes_detect_at_different_steps():
    """Lanes with different ε̃ in ONE bucket fire at different steps."""
    specs = [spec("loose", eps_tilde=1e-3), spec("tight", eps_tilde=1e-5)]
    rep = tenant_reports(serve_specs(specs))
    assert rep["loose"].status == rep["tight"].status == "served"
    assert rep["loose"].detect_step < rep["tight"].detect_step
    # same signature: one executable served both
    assert rep["loose"].signature == rep["tight"].signature


def test_padding_lanes_inert():
    """One tenant in a 4-lane bucket: the 3 padding lanes never converge
    and produce no reports."""
    rep = serve_specs([spec("only")])
    assert rep.served == 1 and len(rep.tenants) == 1
    assert rep.false_detections == 0


def test_mixed_families_and_zero_false_detections():
    specs = [
        spec("cd", family="convdiff", eps_tilde=1e-4, n=8, p=4, rho=0.9),
        spec("pr", family="pagerank", eps_tilde=1e-6, n=64, p=4),
        spec("ml", family="mlfixed", eps_tilde=1e-4, n=16, p=4, m_rows=48,
             cond=10.0),
    ]
    rep = serve_specs(specs)
    assert rep.served == 3
    assert rep.false_detections == 0
    assert sorted(t.family for t in rep.tenants) == [
        "convdiff", "mlfixed", "pagerank"]


# ---------------------------------------------------------------------------
# warm-executable sharing
# ---------------------------------------------------------------------------


def test_warm_cache_hit_on_signature_identical_tenants():
    """Signature-identical tenants (different seed/ε̃) share one compile."""
    svc = DetectionService(CFG)
    for i in range(6):
        out = svc.submit(spec(f"t{i}", seed=i,
                              eps_tilde=(1e-3, 1e-4)[i % 2]))
        assert out["admitted"]
    svc.run()
    rep = svc.report()
    assert rep.served == 6
    assert rep.compile_count == 1          # one signature, one executable
    assert rep.warm_hits >= 2              # refills rode the live executable


def test_distinct_signatures_compile_separately():
    svc = DetectionService(CFG)
    svc.submit(spec("a", family="convdiff"))
    svc.submit(spec("b", family="pagerank", eps_tilde=1e-6, n=64, p=4))
    svc.submit(spec("c", family="convdiff", mode="nfais5"))
    svc.run()
    rep = svc.report()
    assert rep.served == 3
    assert rep.compile_count == 3


def test_signature_key_ignores_seed_and_eps():
    a = spec("a", seed=0, eps_tilde=1e-3)
    b = spec("b", seed=7, eps_tilde=1e-5, K=5, m=2)
    assert signature_key(signature_of(a, CFG)) == \
        signature_key(signature_of(b, CFG))
    c = spec("c", mode="nfais5")
    assert signature_key(signature_of(a, CFG)) != \
        signature_key(signature_of(c, CFG))


# ---------------------------------------------------------------------------
# admission + shutdown/drain
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad,code", [
    (dict(family="heat"), "unknown_family"),
    (dict(mode="magic"), "unknown_mode"),
    (dict(eps_tilde=-1.0), "bad_eps"),
    (dict(eps_tilde=float("nan")), "bad_eps"),
    (dict(K=99), "bad_staleness"),
    (dict(m=0), "bad_persistence"),
    (dict(n=7, p=4, rho=0.9), "problem_invalid"),   # 7 % 4 != 0
])
def test_admission_rejects_structured(bad, code):
    svc = DetectionService(CFG)
    out = svc.submit(spec("bad", **bad))
    assert out["admitted"] is False
    assert out["error"] == code
    assert out["reason"]
    rep = svc.report()
    assert rep.rejected == 1
    assert rep.tenants[0].status == "rejected"
    assert rep.tenants[0].error == code


def test_rejected_tenant_never_blocks_valid_ones():
    svc = DetectionService(CFG)
    svc.submit(spec("bad", family="heat"))
    svc.submit(spec("good"))
    svc.run()
    rep = svc.report()
    assert rep.served == 1 and rep.rejected == 1


def test_shutdown_drains_inflight_and_sheds_queued():
    """In-flight lanes complete and report on shutdown; tenants still in
    the admission queue are shed with a structured status."""
    cfg = ServeConfig(lanes=1, chunk=16, max_steps=1024)
    svc = DetectionService(cfg)
    for i in range(3):        # 1 lane: t1/t2 queue behind t0
        svc.submit(spec(f"t{i}", seed=i))
    svc.step_tick()           # t0 packed and in flight
    svc.shutdown(drain=True)
    rep = tenant_reports(svc.report())
    assert rep["t0"].status == "served"            # in-flight drained
    assert {rep["t1"].status, rep["t2"].status} == {"shed"}
    assert rep["t1"].error == "shutdown"


def test_submit_after_shutdown_is_shed():
    svc = DetectionService(CFG)
    svc.shutdown()
    out = svc.submit(spec("late"))
    assert out["admitted"] is False and out["error"] == "shutdown"
    assert svc.report().shed == 1


def test_open_loop_queue_wait_measured_from_arrival():
    """With 1 lane, the second tenant's queue wait spans the first's
    service time."""
    cfg = ServeConfig(lanes=1, chunk=16, max_steps=1024)
    rep = tenant_reports(serve_specs(
        [spec("t0"), spec("t1", seed=1)], cfg, arrivals=[0, 0]))
    assert rep["t0"].queue_wait_ticks == 0
    assert rep["t1"].queue_wait_ticks > 0
    assert rep["t1"].ttd_ticks > rep["t0"].ttd_ticks


def test_report_percentiles_and_throughput():
    rep = serve_specs([spec(f"t{i}", seed=i) for i in range(4)])
    assert rep.served == 4 and rep.converged
    for q in ("p50", "p95", "p99"):
        assert q in rep.ttd_ticks and q in rep.queue_wait_ticks
    assert rep.throughput["tenants_per_tick"] > 0
    assert rep.ticks == rep.outer_iters > 0
