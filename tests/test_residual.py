"""Distributed residual evaluation r = σ(r_1, …, r_p)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import residual as res


@pytest.mark.parametrize("ord", [2.0, float("inf"), 1.0, 4.0])
def test_sigma_of_contributions_matches_global_norm(ord):
    rng = np.random.default_rng(0)
    parts = [rng.standard_normal((13, 7)) for _ in range(5)]
    full = np.concatenate([p.ravel() for p in parts])
    contribs = jnp.asarray([res.local_contribution(jnp.asarray(p), ord) for p in parts])
    got = float(res.sigma(contribs, ord))
    if np.isinf(ord):
        want = np.abs(full).max()
    else:
        want = (np.abs(full) ** ord).sum() ** (1.0 / ord)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_global_residual_reference():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(100))
    fx = jnp.asarray(rng.standard_normal(100))
    np.testing.assert_allclose(
        float(res.global_residual(x, fx, 2)),
        np.linalg.norm(np.asarray(x) - np.asarray(fx)),
        rtol=1e-6,
    )
    np.testing.assert_allclose(
        float(res.global_residual(x, fx, float("inf"))),
        np.abs(np.asarray(x) - np.asarray(fx)).max(),
        rtol=1e-6,
    )


def test_combine_contributions_host():
    parts = [4.0, 9.0, 16.0]
    assert res.combine_contributions(parts, 2) == pytest.approx(np.sqrt(29.0))
    assert res.combine_contributions(parts, float("inf")) == 16.0
