"""Coverage for core/termination.py (the paper's §4.2 calibration recipe)
and the event-level RecursiveDoublingProtocol the shard runtime mirrors."""
import dataclasses
import math

import pytest

from repro.core.async_engine import AsyncEngine, stable_platform
from repro.core.protocols import PFAIT, PROTOCOLS, RecursiveDoublingProtocol
from repro.core.termination import (
    CalibrationReport,
    calibrate_margin,
    decade_margin,
    stability_band,
)
from repro.solvers.convdiff import ConvDiffProblem


# ---------------------------------------------------------------------------
# decade_margin / stability_band
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ratio,expected", [
    (0.3, 1.0),
    (1.0, 1.0),
    (1.01, 10.0),
    (9.99, 10.0),
    (10.0, 10.0),
    (10.1, 100.0),
    (437.0, 1000.0),
])
def test_decade_margin_quantises_up(ratio, expected):
    assert decade_margin(ratio) == expected


def test_stability_band_is_minmax_offset():
    lo, hi = stability_band([2e-7, 5e-7, 9e-7], 1e-6)
    assert lo == pytest.approx(2e-7 - 1e-6)
    assert hi == pytest.approx(9e-7 - 1e-6)


# ---------------------------------------------------------------------------
# calibrate_margin
# ---------------------------------------------------------------------------


def test_calibrate_margin_report_fields():
    # synthetic solver: overshoots ε by at most 3.2×
    residuals = iter([1.2e-6, 3.2e-6, 0.8e-6])
    rep = calibrate_margin(lambda eps: next(residuals), 1e-6, runs=3,
                           safety=2.0)
    assert isinstance(rep, CalibrationReport)
    assert rep.eps_probe == 1e-6
    assert rep.residuals == (1.2e-6, 3.2e-6, 0.8e-6)
    assert rep.min_r == 0.8e-6
    assert rep.max_r == 3.2e-6
    assert rep.overshoot == pytest.approx(3.2)
    # 3.2 × safety 2.0 = 6.4 → next decade is 10
    assert rep.margin == 10.0
    assert rep.eps_production == pytest.approx(1e-7)


def test_calibrate_margin_stable_solver_needs_no_margin():
    rep = calibrate_margin(lambda eps: 0.4 * eps, 1e-6, runs=2, safety=1.0)
    assert rep.margin == 1.0
    assert rep.eps_production == pytest.approx(1e-6)


def test_calibrate_margin_on_real_engine():
    """End-to-end: the recipe run on the actual simulator, PFAIT at ε = ε̃."""
    seeds = iter(range(100, 110))

    def solve(eps):
        prob = ConvDiffProblem(n=8, p=4, rho=0.85, seed=next(seeds))
        cfg = dataclasses.replace(stable_platform(), seed=7,
                                  max_iters=20_000)
        res = AsyncEngine(prob, cfg, PFAIT(eps, ord=prob.ord)).run()
        assert res.terminated
        return res.r_star

    rep = calibrate_margin(solve, 1e-5, runs=2)
    assert rep.margin >= 1.0
    assert math.log10(rep.margin) == pytest.approx(
        round(math.log10(rep.margin)))  # decade-quantised
    assert rep.eps_production == pytest.approx(1e-5 / rep.margin)


# ---------------------------------------------------------------------------
# RecursiveDoublingProtocol (event level)
# ---------------------------------------------------------------------------


def _run_rdub(p=4, eps=1e-6, seed=0, n=8, max_iters=40_000):
    prob = ConvDiffProblem(n=n, p=p, rho=0.85, seed=seed)
    cfg = dataclasses.replace(stable_platform(), seed=seed,
                              max_iters=max_iters)
    eng = AsyncEngine(prob, cfg, RecursiveDoublingProtocol(eps, ord=prob.ord))
    return eng, eng.run()


def test_rdub_registered():
    assert PROTOCOLS["rdub"] is RecursiveDoublingProtocol


def test_rdub_terminates_within_margin():
    eng, res = _run_rdub()
    assert res.terminated
    assert res.detected_residual < 1e-6
    # live claim: final exact residual within the usual decade of ε
    assert res.r_star < 1e-5


def test_rdub_rejects_non_power_of_two():
    prob = ConvDiffProblem(n=9, p=3, rho=0.85, seed=0)
    cfg = dataclasses.replace(stable_platform(), seed=0, max_iters=100)
    eng = AsyncEngine(prob, cfg, RecursiveDoublingProtocol(1e-6, ord=prob.ord))
    with pytest.raises(ValueError, match="power-of-two"):
        eng.run()


def test_rdub_message_overhead_is_butterfly_shaped():
    """log2(p) rdub messages per per-worker epoch, nothing else
    protocol-borne."""
    eng, res = _run_rdub(p=4)
    assert set(res.msg_counts) == {"data", "rdub"}
    rounds = int(math.log2(4))
    msgs = res.msg_counts["rdub"]
    # each started per-worker epoch (== one reductions_started tick) sends
    # at most `rounds` messages, and all but the in-flight final epochs
    # send exactly `rounds`
    assert res.reductions >= 4
    assert msgs <= rounds * res.reductions
    assert msgs >= rounds * (res.reductions - 4)


def test_rdub_single_worker_decides_alone():
    eng, res = _run_rdub(p=1)
    assert res.terminated
    assert res.msg_counts.get("rdub", 0) == 0  # no partners to talk to
    assert res.r_star < 1e-5


def test_rdub_skips_per_iteration_residuals():
    """Like PFAIT, the protocol samples live state — the engine's fused
    path must skip every per-sweep residual evaluation."""
    prob = ConvDiffProblem(n=8, p=2, rho=0.85, seed=0)
    cfg = dataclasses.replace(stable_platform(), seed=0, max_iters=40_000)
    proto = RecursiveDoublingProtocol(1e-6, ord=prob.ord)
    eng = AsyncEngine(prob, cfg, proto)
    assert proto.wants_residual(eng, 0) is False
    res = eng.run()
    assert res.terminated


def test_rdub_oracle_scores_live_claim():
    """The reliability oracle must accept the protocol unchanged (claim
    semantics identical to PFAIT's)."""
    from repro.core.reliability import detection_report, run_traced

    def prob_fn():
        return ConvDiffProblem(n=8, p=4, rho=0.85, seed=3)

    cfg = dataclasses.replace(stable_platform(), seed=3, max_iters=40_000)
    res, rec = run_traced(
        prob_fn, cfg,
        lambda pr: RecursiveDoublingProtocol(1e-6, ord=pr.ord),
        residual_stride=10)
    rep = detection_report(rec, 1e-6, factor=10.0)
    assert rep.claim == "live"
    assert res.terminated
    assert not rep.false_detection
