"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep"
)
from hypothesis import given, settings, strategies as st

from repro.core import detection
from repro.core.residual import combine_contributions, local_contribution
from repro.models.moe import moe_init
from repro.models import moe as moe_mod


# ---------------------------------------------------------------------------
# Detection ring semantics: the monitor sees exactly the K-stale value
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    K=st.integers(0, 5),
    series=st.lists(st.floats(0.01, 100.0), min_size=1, max_size=30),
    eps=st.floats(0.05, 50.0),
)
def test_pfait_fires_iff_stale_value_below_eps(K, series, eps):
    cfg = detection.MonitorConfig(mode="pfait", eps=eps, ord=1.0, staleness=K)
    stt = detection.init_state(cfg)
    fired_at = None
    for i, v in enumerate(series):
        stt = detection.step(cfg, stt, jnp.float32(v))
        if fired_at is None and bool(stt.converged):
            fired_at = i
    # model: visible at step i is series[i-K]; fires at first i with
    # series[i-K] < eps
    expect = None
    for i in range(len(series)):
        if i - K >= 0 and series[i - K] < eps:
            expect = i
            break
    assert fired_at == expect


# ---------------------------------------------------------------------------
# σ properties
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    parts=st.lists(
        st.lists(st.floats(-100, 100), min_size=1, max_size=8),
        min_size=1, max_size=5,
    ),
    ordv=st.sampled_from([1.0, 2.0, 4.0, float("inf")]),
)
def test_sigma_partition_invariance(parts, ordv):
    """σ over any partition of the data equals the norm of the whole."""
    full = np.concatenate([np.asarray(p) for p in parts])
    contribs = [float(local_contribution(jnp.asarray(np.asarray(p)), ordv))
                for p in parts]
    got = combine_contributions(contribs, ordv)
    if np.isinf(ordv):
        want = np.abs(full).max()
    else:
        want = (np.abs(full) ** ordv).sum() ** (1 / ordv)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-9)


# ---------------------------------------------------------------------------
# MoE pack/unpack roundtrip
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), E=st.sampled_from([2, 4, 8]),
       k=st.sampled_from([1, 2]))
def test_moe_pack_positions_unique_and_bounded(seed, E, k):
    from repro.configs.base import ModelConfig

    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=8,
                      vocab_size=32, num_heads=2, num_kv_heads=1, d_ff=16,
                      num_experts=E, experts_per_token=min(k, E))
    plan = moe_mod.plan_moe(cfg, tp=1, capacity_factor=1.0)
    key = jax.random.PRNGKey(seed)
    w = moe_init(key, plan, gated=True, dtype=jnp.float32)
    t = 12
    tokens = jax.random.normal(jax.random.fold_in(key, 1), (t, 8))
    C = plan.capacity(t)
    send, (slots, pos, wts), _ = moe_mod._route_and_pack(
        tokens, w["router"], plan, C, jnp.ones((t,))
    )
    slots_n, pos_n, w_n = map(np.asarray, (slots, pos, wts))
    kept = w_n > 0
    assert np.all(pos_n[kept] < C)
    assert np.all(slots_n[kept] < plan.virtual_experts)
    coords = list(zip(slots_n[kept], pos_n[kept]))
    assert len(coords) == len(set(coords))
    # kept tokens' buffer rows equal the token values
    send_n = np.asarray(send)
    tok_n = np.asarray(tokens)
    ti, ki = np.nonzero(kept)
    for a, b in zip(ti[:8], ki[:8]):
        np.testing.assert_allclose(send_n[slots_n[a, b], pos_n[a, b]], tok_n[a],
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# Checkpoint ↔ restore identity for arbitrary pytrees
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_checkpoint_restore_identity(seed, tmp_path_factory):
    from repro.checkpoint.checkpointer import Checkpointer

    rng = np.random.default_rng(seed)
    tree = {
        "a": jnp.asarray(rng.standard_normal((3, 4)), jnp.float32),
        "nest": (jnp.asarray(rng.integers(0, 9, (5,))),
                 {"b": jnp.asarray(rng.standard_normal(7), jnp.float32)}),
    }
    d = tmp_path_factory.mktemp(f"ck{seed}")
    ck = Checkpointer(str(d))
    ck.save(tree, 1, blocking=True)
    back, _ = ck.restore(like=tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
