"""Mamba2 SSD: chunked scan vs sequential recurrence oracle; decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (
    causal_conv,
    ssd_chunked,
    ssd_decode_step,
    ssm_apply,
    plan_ssm,
    ssm_init,
)
from repro.configs.base import ModelConfig


def sequential_ssd(x, dt, A, Bm, Cm, h0=None):
    """O(S) reference recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t⊗x_t."""
    Bsz, S, nh, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = nh // G
    h = np.zeros((Bsz, nh, P, N)) if h0 is None else np.asarray(h0).copy()
    ys = []
    for t in range(S):
        for b in range(Bsz):
            for hh in range(nh):
                a = np.exp(float(dt[b, t, hh]) * float(A[hh]))
                Bv = np.asarray(Bm[b, t, hh // rep])
                Cv = np.asarray(Cm[b, t, hh // rep])
                xv = np.asarray(x[b, t, hh])
                h[b, hh] = a * h[b, hh] + float(dt[b, t, hh]) * np.outer(xv, Bv)
                ys.append(h[b, hh] @ Cv)
    y = np.asarray(ys).reshape(S, Bsz, nh, P).transpose(1, 0, 2, 3)
    return y, h


@pytest.mark.parametrize("S,chunk", [(8, 4), (16, 8), (12, 12)])
def test_ssd_chunked_matches_sequential(S, chunk):
    rng = np.random.default_rng(0)
    Bsz, nh, P, G, N = 2, 4, 8, 1, 16
    x = jnp.asarray(rng.standard_normal((Bsz, S, nh, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.5, (Bsz, S, nh)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (nh,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((Bsz, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((Bsz, S, G, N)), jnp.float32)
    y, h = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y_ref, h_ref = sequential_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h), h_ref, atol=2e-4, rtol=1e-3)


def test_ssd_chunked_with_initial_state_continuation():
    """Processing [first half] then [second half | h] == processing whole."""
    rng = np.random.default_rng(1)
    Bsz, S, nh, P, G, N = 1, 16, 2, 4, 1, 8
    x = jnp.asarray(rng.standard_normal((Bsz, S, nh, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.5, (Bsz, S, nh)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (nh,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((Bsz, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((Bsz, S, G, N)), jnp.float32)
    y_full, h_full = ssd_chunked(x, dt, A, Bm, Cm, chunk=4)
    y1, h1 = ssd_chunked(x[:, :8], dt[:, :8], A, Bm[:, :8], Cm[:, :8], chunk=4)
    y2, h2 = ssd_chunked(x[:, 8:], dt[:, 8:], A, Bm[:, 8:], Cm[:, 8:], chunk=4, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-4)


def test_ssd_decode_steps_match_chunked():
    rng = np.random.default_rng(2)
    Bsz, S, nh, P, G, N = 1, 6, 2, 4, 1, 8
    x = jnp.asarray(rng.standard_normal((Bsz, S, nh, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.5, (Bsz, S, nh)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (nh,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((Bsz, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((Bsz, S, G, N)), jnp.float32)
    y_full, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=6)
    h = jnp.zeros((Bsz, nh, P, N))
    ys = []
    for t in range(S):
        y, h = ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h)
        ys.append(y)
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               atol=1e-4, rtol=1e-3)


def test_causal_conv_state_continuation():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 10, 6)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 6)), jnp.float32)
    y_full, st_full = causal_conv(x, w)
    y1, st1 = causal_conv(x[:, :4], w)
    y2, st2 = causal_conv(x[:, 4:], w, state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full), atol=1e-6)


@pytest.mark.slow
def test_ssm_block_prefill_then_decode_matches_full():
    cfg = ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                      vocab_size=64, ssm_state=8, ssm_head_dim=8, ssm_expand=2)
    plan = plan_ssm(cfg, tp=1)
    p = ssm_init(jax.random.PRNGKey(0), plan, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, 32), jnp.float32)
    # full (chunk=3 divides 9)
    y_full, _ = ssm_apply(p, x, plan, chunk=3)
    # prefill 8 then decode 1
    y1, cache = ssm_apply(p, x[:, :8], plan, chunk=4)
    y2, _ = ssm_apply(p, x[:, 8:9], plan, chunk=1, cache=cache)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 8:9]),
                               atol=1e-4, rtol=1e-3)
