"""Property tests for the scenario algebra (hypothesis-optional, PR 1
pattern: degrades to seeded-random cases without the dep).

Invariants:
  * composition preserves event-time sanity — transformed delays stay
    positive and finite, pause resumption never travels back in time, and
    the recorded engine trace is time-monotone under any composition;
  * drop/reorder never loses protocol-termination *liveness* while the
    engine's max_iters grace window is active: the run always returns
    (terminated or undetected), never hangs.
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to seeded-random cases
    HAVE_HYPOTHESIS = False


def given_seed(max_examples, fallback_seeds):
    """``@given(seed=...)`` with hypothesis, parametrized seeds without."""
    if HAVE_HYPOTHESIS:
        def deco(fn):
            return settings(max_examples=max_examples, deadline=None)(
                given(seed=st.integers(0, 10_000))(fn)
            )
    else:
        def deco(fn):
            return pytest.mark.parametrize("seed", fallback_seeds)(fn)
    return deco


from repro.core.async_engine import stable_platform
from repro.core.protocols import NFAIS2, NFAIS5, PFAIT
from repro.core.reliability import run_traced
from repro.core.scenarios import (
    DropMessages,
    JitterBurst,
    Pause,
    Scenario,
    Straggler,
    TailSpike,
)
from repro.solvers.convdiff import ConvDiffProblem

BASE = 1e-3


def random_scenario(rng: np.random.Generator) -> Scenario:
    """A random composition drawn from the whole effect algebra."""
    pool = [
        TailSpike(prob=float(rng.uniform(0, 0.4)),
                  mult=float(rng.uniform(1, 50))),
        JitterBurst(period=float(rng.uniform(10, 80)) * BASE,
                    duration=float(rng.uniform(1, 9)) * BASE,
                    mult=float(rng.uniform(1, 40))),
        DropMessages(prob=float(rng.uniform(0, 0.9)),
                     after=float(rng.uniform(0, 50)) * BASE),
        Straggler(workers=(int(rng.integers(0, 4)),),
                  factor=float(rng.uniform(1, 12))),
        Pause(worker=int(rng.integers(0, 4)),
              at=float(rng.uniform(0, 80)) * BASE,
              duration=float(rng.uniform(10, 200)) * BASE),
    ]
    k = int(rng.integers(1, len(pool) + 1))
    picks = rng.choice(len(pool), size=k, replace=False)
    return Scenario("random", tuple(pool[int(i)] for i in sorted(picks)))


@given_seed(max_examples=25, fallback_seeds=(0, 7, 99, 1234, 5555))
def test_composition_preserves_delay_sanity(seed):
    rng = np.random.default_rng(seed)
    sc = random_scenario(rng)
    for _ in range(200):
        t = float(rng.uniform(0, 0.5))
        kind = ["data", "snap2", "marker", "reduce"][int(rng.integers(0, 4))]
        d_in = float(rng.uniform(1e-6, 1e-2))
        d = sc.channel_delay(t, kind, d_in, rng)
        if d is not None:
            assert np.isfinite(d) and d > 0.0
            assert d >= d_in  # effects only inflate, never rewind time
        else:
            assert kind == "data"  # only data kinds are droppable here
        w = int(rng.integers(0, 4))
        c = sc.compute_delay(t, w, d_in, rng)
        assert np.isfinite(c) and c >= d_in
        resume = sc.paused_until(t, w)
        if resume is not None:
            assert resume > t  # resumption strictly in the future


@given_seed(max_examples=6, fallback_seeds=(1, 42, 777))
def test_trace_event_times_monotone_under_random_scenario(seed):
    rng = np.random.default_rng(seed)
    sc = random_scenario(rng)
    cfg = dataclasses.replace(stable_platform(BASE), seed=seed,
                              max_iters=200, scenario=sc)
    _, rec = run_traced(lambda: ConvDiffProblem(n=8, p=4, rho=0.9, seed=0),
                        cfg, lambda pr: PFAIT(1e-6, ord=pr.ord))
    ts = [e[1] for e in rec.events]
    assert ts == sorted(ts)
    assert rec.events[-1][0] == "finish"


@given_seed(max_examples=6, fallback_seeds=(3, 17, 2024))
def test_drop_reorder_preserves_liveness(seed):
    """However lossy/reordered the channels, a run with max_iters grace
    always returns: either a detection or a graceful undetected exit with
    every worker at the iteration cap."""
    rng = np.random.default_rng(seed)
    sc = Scenario("lossy", (
        DropMessages(prob=float(rng.uniform(0.3, 1.0))),
        TailSpike(prob=0.3, mult=float(rng.uniform(5, 40))),
    ))
    proto = [lambda pr: PFAIT(1e-6, ord=pr.ord),
             lambda pr: NFAIS2(1e-6, ord=pr.ord),
             lambda pr: NFAIS5(1e-6, ord=pr.ord, m=3)][seed % 3]
    cfg = dataclasses.replace(stable_platform(BASE), seed=seed,
                              max_iters=250, scenario=sc)
    res, rec = run_traced(lambda: ConvDiffProblem(n=8, p=4, rho=0.9, seed=1),
                          cfg, proto)
    assert res.terminated or res.k_min == 250
    assert rec.events[-1][0] == "finish"
