"""Fused sweep+residual hot path: parity + structure regression tests.

Covers the three layers of the fusion:
  * numpy event-sim problem  — ``update_with_residual`` ≡ (update, local_residual)
  * jnp/Pallas driver ops    — ``sweep_with_contribution`` ≡ sweep + residual pass
  * solver drivers           — one fused grid pass per outer iteration, no
                               residual-only second pass (PASS_COUNTS + HLO bytes)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import detection
from repro.kernels.jacobi3d import ops as jac_ops
from repro.kernels.jacobi3d.jacobi3d import fused_rbgs_sweep_residual
from repro.kernels.jacobi3d.ref import residual_partials
from repro.solvers import gauss_seidel, jacobi
from repro.solvers.convdiff import ConvDiffProblem, Stencil, make_rhs
from repro.solvers.fixed_point import SolverConfig, make_sharded_solver, solve_single

RNG = np.random.default_rng(0)


def _random_state(prob):
    xs = [prob.init_local(i) + RNG.standard_normal(prob.part.block)
          for i in range(prob.p)]
    deps = [{j: prob.interface(j, xs[j], i) for j in prob.neighbors(i)}
            for i in range(prob.p)]
    return xs, deps


# ---------------------------------------------------------------------------
# Event-sim problem parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sweep", ["hybrid", "jacobi"])
@pytest.mark.parametrize("ordv", [float("inf"), 2.0])
def test_update_with_residual_matches_pair(sweep, ordv):
    prob = ConvDiffProblem(n=12, p=4, rho=0.9, seed=1, ord=ordv, sweep=sweep)
    xs, deps = _random_state(prob)
    for i in range(prob.p):
        x_ref = prob.update(i, xs[i], deps[i])
        r_ref = prob.local_residual(i, xs[i], deps[i])
        x_new, r_i = prob.update_with_residual(i, xs[i], deps[i])
        np.testing.assert_allclose(x_new, x_ref, atol=1e-13)
        assert r_i == pytest.approx(r_ref, rel=1e-12)
        # the residual-skipping (checkerboard-sliced) path must produce the
        # identical sweep
        x_new2, r2 = prob.update_with_residual(i, xs[i], deps[i],
                                               need_residual=False)
        assert r2 is None
        np.testing.assert_allclose(x_new2, x_ref, atol=1e-13)


def test_local_residual_fast_matches():
    prob = ConvDiffProblem(n=12, p=4, rho=0.9, seed=2)
    xs, deps = _random_state(prob)
    for i in range(prob.p):
        assert prob.local_residual_fast(i, xs[i], deps[i]) == pytest.approx(
            prob.local_residual(i, xs[i], deps[i]), rel=1e-12)


# ---------------------------------------------------------------------------
# Driver ops parity (ref mode — off-TPU dispatch)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sweep", ["hybrid", "jacobi"])
@pytest.mark.parametrize("ordv", [float("inf"), 2.0])
def test_sweep_with_contribution_matches_separate_passes(sweep, ordv):
    st = Stencil.for_contraction(8, 1.0, (1.0, 1.0, 1.0), 0.9)
    bx, by, bz = 8, 8, 8
    x = jnp.asarray(RNG.standard_normal((bx, by, bz)))
    b = jnp.asarray(RNG.standard_normal((bx, by, bz)))
    ghosts = (jnp.asarray(RNG.standard_normal((by, bz))),
              jnp.asarray(RNG.standard_normal((by, bz))),
              jnp.asarray(RNG.standard_normal((bx, bz))),
              jnp.asarray(RNG.standard_normal((bx, bz))))
    new_f, contrib = jac_ops.sweep_with_contribution(
        st, x, ghosts, b, sweep=sweep, ox=3, oy=5, ord=ordv)
    new_s = jac_ops.sweep(st, x, ghosts, b, sweep=sweep, ox=3, oy=5)
    # the fused contribution measures the *input* state's residual
    contrib_s = jac_ops.residual_contribution(
        st, jac_ops.ghost_pad1(x, ghosts), b, ord=ordv)
    np.testing.assert_allclose(np.asarray(new_f), np.asarray(new_s), atol=1e-12)
    assert float(contrib) == pytest.approx(float(contrib_s), rel=1e-5)


@pytest.mark.parametrize("ox,oy", [(0, 0), (3, 5), (6, 2)])
@pytest.mark.parametrize("linf", [True, False])
def test_rbgs_kernel_interpret_matches_oracle(ox, oy, linf):
    """Pallas single-pass hybrid kernel (±2 halo window, interpret=True) vs
    the pure-jnp oracle — tiles smaller than the block exercise the
    cross-tile color dependency."""
    st = Stencil.for_contraction(8, 1.0, (1.0, 1.0, 1.0), 0.9)
    bx, by, bz = 8, 8, 8
    x = jnp.asarray(RNG.standard_normal((bx, by, bz)))
    b = jnp.asarray(RNG.standard_normal((bx, by, bz)))
    ghosts = tuple(jnp.asarray(RNG.standard_normal(s))
                   for s in ((by, bz), (by, bz), (bx, bz), (bx, bz)))
    g1 = jac_ops.ghost_pad1(x, ghosts)
    new_ref, r_ref = gauss_seidel.redblack_gs_sweep_residual(st, g1, b, ox, oy)
    parts_ref = residual_partials(r_ref, tile=(4, 4), linf=linf)
    new_k, parts_k = fused_rbgs_sweep_residual(
        jac_ops.ghost_pad2(x, ghosts), jnp.pad(b, ((1, 1), (1, 1), (0, 0))),
        jac_ops._coefs(st).astype(b.dtype), jnp.int32(ox + oy),
        tile=(4, 4), linf=linf, interpret=True)
    np.testing.assert_allclose(np.asarray(new_k), np.asarray(new_ref), atol=1e-12)
    np.testing.assert_allclose(np.asarray(parts_k), np.asarray(parts_ref),
                               rtol=1e-5, atol=1e-9)
    # the fused partials reduce the residual of the input state
    r_in = jacobi.residual_block(st, g1, b)
    np.testing.assert_allclose(np.asarray(r_ref), np.asarray(r_in), atol=1e-12)


# ---------------------------------------------------------------------------
# Solver structure regression: no residual-only second pass
# ---------------------------------------------------------------------------


def _solver_cfg(n, inner_sweeps, fuse, sweep="hybrid"):
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    mon = detection.for_mode("pfait", eps_tilde=1e-8, margin=10.0,
                             staleness=2, ord=float("inf"))
    return SolverConfig(stencil=st, monitor=mon, inner_sweeps=inner_sweeps,
                        max_outer=500, sweep=sweep, use_kernel=True,
                        fuse_residual=fuse)


@pytest.mark.parametrize("inner_sweeps", [1, 3])
def test_sharded_solver_single_fused_pass_per_outer(inner_sweeps):
    """With use_kernel + fuse_residual, each outer iteration lowers to
    exactly one fused sweep+residual kernel invocation (the last inner
    sweep) and no residual-only pass — counted at trace time."""
    from repro.launch.mesh import compat_make_mesh

    n = 8
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    b = jax.ShapeDtypeStruct((n, n, n), jnp.float32)
    cfg = _solver_cfg(n, inner_sweeps, fuse=True)
    jac_ops.reset_pass_counts()
    jax.jit(make_sharded_solver(cfg, mesh)).lower(b, b)
    counts = dict(jac_ops.PASS_COUNTS)
    assert counts["residual"] == 0, counts  # no residual-only second pass
    assert counts["fused"] > 0, counts
    # per outer iteration: inner_sweeps−1 plain sweeps + 1 fused pass,
    # regardless of how many times jax traced the loop body
    assert counts["sweep"] == (inner_sweeps - 1) * counts["fused"], counts


def test_sharded_solver_unfused_baseline_has_residual_pass():
    from repro.launch.mesh import compat_make_mesh

    n = 8
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    b = jax.ShapeDtypeStruct((n, n, n), jnp.float32)
    cfg = _solver_cfg(n, 1, fuse=False)
    jac_ops.reset_pass_counts()
    jax.jit(make_sharded_solver(cfg, mesh)).lower(b, b)
    counts = dict(jac_ops.PASS_COUNTS)
    assert counts["fused"] == 0, counts
    assert counts["residual"] == counts["sweep"] > 0, counts


def test_solve_single_fused_pass_counts():
    n = 8
    cfg = _solver_cfg(n, 2, fuse=True)
    jac_ops.reset_pass_counts()
    jax.jit(lambda b: solve_single(cfg, b)).lower(
        jax.ShapeDtypeStruct((n, n, n), jnp.float32))
    counts = dict(jac_ops.PASS_COUNTS)
    assert counts["residual"] == 0 and counts["fused"] > 0
    assert counts["sweep"] == counts["fused"]  # inner_sweeps−1 == 1


def test_fused_sharded_solver_reduces_hbo_bytes():
    """HLO-derived HBM traffic per sweep drops when the residual is fused
    (jacobi flavour: the residual-only pass is a full second grid pass)."""
    from repro.launch import hlo_analysis
    from repro.launch.mesh import compat_make_mesh

    n = 16
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    b = jax.ShapeDtypeStruct((n, n, n), jnp.float32)
    bytes_per = {}
    for fuse in (False, True):
        st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
        mon = detection.for_mode("pfait", eps_tilde=1e-8, margin=10.0,
                                 staleness=2)
        cfg = SolverConfig(stencil=st, monitor=mon, inner_sweeps=1,
                           max_outer=500, sweep="jacobi", fuse_residual=fuse)
        text = jax.jit(make_sharded_solver(cfg, mesh)).lower(b, b).compile().as_text()
        stats = hlo_analysis.program_stats(text, default_group=1)
        bytes_per[fuse] = stats.hbm_bytes / max(stats.loop_trip_max, 1.0)
    assert bytes_per[True] < bytes_per[False], bytes_per


# ---------------------------------------------------------------------------
# Fused solves still converge to the right answer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sweep", ["hybrid", "jacobi"])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_solve_single_fused_reaches_threshold(sweep, use_kernel):
    n = 12
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    b = jnp.asarray(make_rhs(n, 0))
    mon = detection.for_mode("pfait", eps_tilde=1e-8, margin=10.0,
                             staleness=3, ord=float("inf"))
    cfg = SolverConfig(stencil=st, monitor=mon, inner_sweeps=1,
                       max_outer=20_000, sweep=sweep, use_kernel=use_kernel,
                       fuse_residual=True)
    r = solve_single(cfg, b)
    assert bool(r.converged)
    from repro.solvers.fixed_point import _zero_ghosts, ghosted
    g = ghosted(r.x, _zero_ghosts(r.x))
    assert float(jnp.max(jnp.abs(jacobi.residual_block(st, g, b)))) < 1e-8


# ---------------------------------------------------------------------------
# Engine-level equivalence
# ---------------------------------------------------------------------------


def test_engine_fused_matches_unfused_pfait():
    """PFAIT never consumes per-iteration residuals, so the fused engine run
    is numerically the same trajectory (modulo contraction-order rounding)."""
    from repro.core.async_engine import AsyncEngine, stable_platform
    from repro.core.protocols import PFAIT

    res = {}
    for fused in (False, True):
        prob = ConvDiffProblem(n=12, p=4, rho=0.9, seed=3)
        cfg = dataclasses.replace(stable_platform(), seed=3, max_iters=30_000,
                                  fused=fused)
        res[fused] = AsyncEngine(prob, cfg, PFAIT(1e-6, ord=prob.ord)).run()
    assert res[True].terminated and res[False].terminated
    assert res[True].r_star == pytest.approx(res[False].r_star, rel=1e-6)
    assert res[True].k_max == res[False].k_max
    assert res[True].wtime == pytest.approx(res[False].wtime, rel=1e-9)


@pytest.mark.parametrize("proto", ["nfais2", "nfais5", "exact"])
def test_engine_fused_snapshot_protocols_terminate_correctly(proto):
    from benchmarks.common import run_cell

    cell = run_cell(proto, 1e-5, n=12, p=4, seeds=(0, 1), fused=True)
    assert cell["max_r"] < 1e-4  # detection guarantee holds on the fused path
