"""Mesh-partitioned runtime tests: the pluggable 2-D/3-D partitioner, the
halo-consuming fused kernels, 1-shard mesh parity against the reference
driver, mesh-aware config validation, per-face trace schema, and
(subprocess) real 4-device 2-D behaviour.

The pytest session runs on ONE device (tests/conftest.py), so in-process
mesh tests use 1-shard meshes of every dimensionality — which still route
through the block-decomposed mesh runtime (``MeshPartition``, per-face
ghost assembly, the overlap face-slab path) with boundary zeros on every
face.  Genuinely multi-device 2-D behaviour (per-axis ppermute rings,
overlap bitwise parity under heterogeneous knobs, the detect matrix
across mesh shapes) runs in a forced-4-device subprocess, marked
``slow``; the mesh-runtime CI lane covers it at full size.
"""
import os
import subprocess
import sys
import textwrap
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import detection
from repro.launch.mesh import make_shard_mesh, shard_axes_of
from repro.runtime import shard_runtime as sr
from repro.solvers.convdiff import Stencil, make_rhs
from repro.solvers.partition import FACES, MeshPartition

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

RNG = np.random.default_rng(0)


def _mon(mode="pfait", eps=1e-7, staleness=0, ord=float("inf"),
         persistence=4):
    return detection.MonitorConfig(mode=mode, eps=eps, staleness=staleness,
                                   ord=ord, persistence=persistence)


# ---------------------------------------------------------------------------
# MeshPartition: tiling, topology, ring geometry
# ---------------------------------------------------------------------------


SHAPES = [(1,), (4,), (2, 2), (4, 2), (1, 2), (2, 2, 2), (2, 1, 2)]


@pytest.mark.parametrize("shape", SHAPES)
def test_partition_tiles_exactly(shape):
    """Every cell of the global cube is owned by exactly one shard."""
    n = 8
    part = MeshPartition(n, shape)
    covered = np.zeros((n, n, n), np.int32)
    for i in range(part.p):
        sl = tuple(slice(o, o + e) for o, e in part.block_spec(i))
        covered[sl] += 1
    assert (covered == 1).all()


@pytest.mark.parametrize("shape", SHAPES)
def test_partition_rank_coords_roundtrip(shape):
    part = MeshPartition(8, shape)
    assert part.p == int(np.prod(shape))
    for i in range(part.p):
        assert part.rank(*part.coords(i)) == i


@pytest.mark.parametrize("shape", SHAPES)
def test_partition_neighbours_symmetric_with_opposed_faces(shape):
    part = MeshPartition(8, shape)
    for i in range(part.p):
        for j in part.neighbors(i):
            assert i in part.neighbors(j), (shape, i, j)
            fi, fj = part.face(i, j), part.face(j, i)
            # the faces across one link are the two sides of the same axis
            assert fi[0] == fj[0] and fi != fj, (fi, fj)


def test_partition_face_labels_and_shapes():
    part = MeshPartition(8, (2, 2))
    assert FACES[0] == ("x-", "x+")
    # rank 0 = coords (0, 0): neighbours are x+ (rank 2) and y+ (rank 1)
    assert set(part.neighbors(0)) == {1, 2}
    assert part.face(0, 2) == "x+" and part.face(0, 1) == "y+"
    shapes = part.face_shapes()
    # a (2,2) mesh of n=8 has 4x8 blocks: x-faces are (4, 8), y-faces (4, 8)
    assert shapes["x+"] == (4, 8) and shapes["y+"] == (4, 8)


def test_partition_ring_slots_and_buffer_elems():
    part = MeshPartition(8, (2, 2))
    # double buffering floor: even delay 0 needs 2 slots (write k+1, read k)
    assert part.ring_slots(0) == 2
    assert part.ring_slots(3) == 4
    with pytest.raises(ValueError, match=">= 0"):
        part.ring_slots(-1)
    # 2 slots x 4 exchanged faces (x-,x+,y-,y+) of 4x8 elements each
    assert part.buffer_elems(0) == 2 * 4 * (4 * 8)


def test_partition_validates():
    with pytest.raises(ValueError, match="1-D, 2-D, or 3-D"):
        MeshPartition(8, (2, 2, 2, 2))
    with pytest.raises(ValueError, match=">= 1"):
        MeshPartition(8, (2, 0))
    with pytest.raises(ValueError, match="divisible"):
        MeshPartition(9, (2,))
    with pytest.raises(ValueError, match="out of range"):
        MeshPartition(8, (2,)).coords(5)


def test_make_shard_mesh_accepts_tuples():
    mesh = make_shard_mesh((1, 1))
    assert shard_axes_of(mesh) == ("shard_x", "shard_y")
    mesh1 = make_shard_mesh((1,))
    assert shard_axes_of(mesh1) == ("shard",)
    with pytest.raises(ValueError, match="exceeds"):
        make_shard_mesh((len(jax.devices()) + 1, 1))


# ---------------------------------------------------------------------------
# Halo-consuming fused kernels vs the ghosted oracle (interpret mode)
# ---------------------------------------------------------------------------


def _halo_setup(bx=8, by=8, bz=8, dtype=jnp.float64):
    st = Stencil.for_contraction(bx, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    coefs = jnp.asarray([st.diag, st.xm, st.xp, st.ym, st.yp, st.zm, st.zp],
                        dtype)
    x = jnp.asarray(RNG.standard_normal((bx, by, bz)), dtype)
    b = jnp.asarray(RNG.standard_normal((bx, by, bz)), dtype)
    halos = tuple(jnp.asarray(RNG.standard_normal(s), dtype) for s in
                  [(by, bz), (by, bz), (bx, bz), (bx, bz), (bx, by),
                   (bx, by)])
    return st, coefs, x, b, halos


@pytest.mark.parametrize("tile", [(4, 4), (8, 8), (4, 8)])
@pytest.mark.parametrize("op", ["sweep", "residual"])
def test_halo_kernel_matches_oracle(tile, op):
    from repro.kernels.jacobi3d.jacobi3d import fused_sweep_residual_halo
    from repro.kernels.jacobi3d.ref import fused_sweep_residual_halo_ref

    _, coefs, x, b, halos = _halo_setup()
    new_k, parts_k = fused_sweep_residual_halo(
        x, halos, b, coefs, tile=tile, op=op, linf=True, interpret=True)
    new_r, parts_r = fused_sweep_residual_halo_ref(
        x, halos, b, coefs, tile=tile, op=op, linf=True)
    np.testing.assert_allclose(np.asarray(new_k), np.asarray(new_r),
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(parts_k), np.asarray(parts_r),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("oxyz", [0, 1, 5])
def test_rbgs_halo_kernel_matches_oracle(oxyz):
    from repro.kernels.jacobi3d.jacobi3d import fused_rbgs_sweep_residual_halo
    from repro.kernels.jacobi3d.ref import ghosted6_ref, residual_partials
    from repro.solvers import gauss_seidel

    st, coefs, x, b, halos = _halo_setup()
    new_k, parts_k = fused_rbgs_sweep_residual_halo(
        x, halos, b, coefs, jnp.int32(oxyz), tile=(4, 8), linf=True,
        interpret=True)
    g = ghosted6_ref(x, halos)
    new_r, rr = gauss_seidel.redblack_gs_sweep_residual(st, g, b, oxyz, 0, 0)
    parts_r = residual_partials(rr, tile=(4, 8), linf=True)
    np.testing.assert_allclose(np.asarray(new_k), np.asarray(new_r),
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(parts_k), np.asarray(parts_r),
                               rtol=1e-12, atol=1e-12)


def test_ops_halo_entries_match_ghosted_solvers_bitwise():
    """The jnp dispatch path of the halo ops must be the exact expression
    trees of ghosted6 + solvers — this is the bitwise-parity basis the
    mesh runtime's equivalence to ``solve_single`` rests on."""
    from repro.kernels.jacobi3d import ops as jac_ops
    from repro.solvers import gauss_seidel, jacobi
    from repro.solvers.fixed_point import ghosted6

    st, _, x, b, halos = _halo_setup()
    new = jac_ops.sweep_halo(st, x, halos, b)
    ref = jacobi.jacobi_sweep(st, ghosted6(x, halos), b)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(ref))

    new2, c = jac_ops.sweep_with_contribution_halo(st, x, halos, b,
                                                   ord=float("inf"))
    ref2, rr = jacobi.jacobi_sweep_residual(st, ghosted6(x, halos), b)
    np.testing.assert_array_equal(np.asarray(new2), np.asarray(ref2))
    # partials accumulate in f32 (the kernel layout); the cast is monotone,
    # so the contribution is exactly the f32 cast of the oracle's max
    assert float(c) == float(jnp.max(jnp.abs(rr)).astype(jnp.float32))

    c2 = jac_ops.residual_contribution_halo(st, x, halos, b,
                                            ord=float("inf"))
    assert float(c2) == float(jnp.max(jnp.abs(jacobi.residual_block(
        st, ghosted6(x, halos), b))).astype(jnp.float32))

    newh = jac_ops.sweep_halo(st, x, halos, b, sweep="hybrid",
                              ox=3, oy=1, oz=2)
    refh = gauss_seidel.redblack_gs_sweep(st, ghosted6(x, halos), b, 3, 1, 2)
    np.testing.assert_array_equal(np.asarray(newh), np.asarray(refh))


# ---------------------------------------------------------------------------
# Mesh-aware config validation
# ---------------------------------------------------------------------------


def test_config_validates_mesh_shape():
    with pytest.raises(ValueError, match="mesh_shape"):
        sr.ShardRuntimeConfig(monitor=_mon(), mesh_shape=(2, 2, 2, 2))
    with pytest.raises(ValueError, match="mesh_shape"):
        sr.ShardRuntimeConfig(monitor=_mon(), mesh_shape=(2, 0))
    cfg = sr.ShardRuntimeConfig(monitor=_mon(), mesh_shape=[2, 2])
    assert cfg.mesh_shape == (2, 2)   # normalised to an int tuple


def test_overlap_requires_jacobi_nonblocking():
    with pytest.raises(ValueError, match="red-black"):
        sr.ShardRuntimeConfig(monitor=_mon(), sweep="hybrid", overlap=True)
    with pytest.raises(ValueError, match="blocking"):
        sr.ShardRuntimeConfig(monitor=_mon(), reduction="blocking",
                              overlap=True)


def test_per_shard_error_names_mesh_shape():
    """A wrong-length per-shard sequence on a 2-D mesh names the mesh shape
    and the row-major total, not just a bare length."""
    mesh = types.SimpleNamespace(shape={"shard_x": 2, "shard_y": 2},
                                 axis_names=("shard_x", "shard_y"))
    st = Stencil.for_contraction(8, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    cfg = sr.ShardRuntimeConfig(monitor=_mon(), inner_sweeps=(1, 2),
                                mesh_shape=(2, 2))
    with pytest.raises(ValueError, match=r"mesh shape \(2, 2\)"):
        sr.make_convdiff_runtime(cfg, mesh, st, 8)


def test_mesh_shape_must_match_mesh():
    mesh = types.SimpleNamespace(shape={"shard_x": 2, "shard_y": 2},
                                 axis_names=("shard_x", "shard_y"))
    st = Stencil.for_contraction(8, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    cfg = sr.ShardRuntimeConfig(monitor=_mon(), mesh_shape=(2, 1))
    with pytest.raises(ValueError, match="does not match"):
        sr.make_convdiff_runtime(cfg, mesh, st, 8)


def test_overlap_needs_block_extent_two():
    # a 2-wide axis at n=2 leaves 1-plane blocks: no interior to overlap
    mesh = types.SimpleNamespace(shape={"shard_x": 2, "shard_y": 1},
                                 axis_names=("shard_x", "shard_y"))
    st = Stencil.for_contraction(2, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    cfg = sr.ShardRuntimeConfig(monitor=_mon(), overlap=True,
                                mesh_shape=(2, 1))
    with pytest.raises(ValueError, match="block extent"):
        sr.make_convdiff_runtime(cfg, mesh, st, 2)


def test_pagerank_rejects_multi_axis_and_overlap():
    mesh = types.SimpleNamespace(shape={"shard_x": 2, "shard_y": 2},
                                 axis_names=("shard_x", "shard_y"))
    cfg = sr.ShardRuntimeConfig(monitor=_mon())
    with pytest.raises(ValueError, match="1-D"):
        sr.make_pagerank_runtime(cfg, mesh, 8)
    mesh1 = make_shard_mesh(1)
    cfg_ov = sr.ShardRuntimeConfig(monitor=_mon(), overlap=True)
    with pytest.raises(ValueError, match="convdiff-only"):
        sr.make_pagerank_runtime(cfg_ov, mesh1, 8)


def test_mesh_state_spec_per_family():
    from jax.sharding import PartitionSpec as P

    mesh1 = make_shard_mesh(1)
    assert sr.mesh_state_spec("convdiff", mesh1) == P("shard", None, None)
    assert sr.mesh_state_spec("pagerank", mesh1) == P("shard")
    mesh2 = make_shard_mesh((1, 1))
    assert sr.mesh_state_spec("convdiff", mesh2) == P("shard_x", "shard_y",
                                                      None)
    with pytest.raises(ValueError, match="1-D"):
        sr.mesh_state_spec("pagerank", mesh2)


# ---------------------------------------------------------------------------
# 1-shard mesh parity: every dimensionality reproduces solve_single bitwise
# ---------------------------------------------------------------------------


N = 8


def _setup(n=N, seed=0, rho=0.9):
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=rho)
    b = jnp.asarray(make_rhs(n, seed=seed))
    return st, b, jnp.zeros_like(b)


def _reference(st, b, sweep="jacobi", mon=None):
    from repro.solvers.fixed_point import SolverConfig, solve_single

    # default fuse_residual: the fused sweep+residual expression tree is
    # exactly what the mesh runtime's halo ops build — bitwise comparable
    mon = mon or _mon()
    return solve_single(
        SolverConfig(stencil=st, monitor=mon, inner_sweeps=1, max_outer=400,
                     sweep=sweep), b)


@pytest.mark.parametrize("shape", [(1,), (1, 1), (1, 1, 1)])
def test_one_shard_mesh_bitwise_matches_solve_single(shape):
    """The mesh runtime on a 1-shard mesh of any dimensionality — with the
    overlap path forced on — is bitwise the reference driver: identical
    iteration count, identical solution array."""
    st, b, x0 = _setup()
    ref = _reference(st, b)
    mesh = make_shard_mesh(shape)
    cfg = sr.ShardRuntimeConfig(monitor=_mon(), reduction="nonblocking",
                                max_outer=400, mesh_shape=shape,
                                overlap=True)
    r = jax.jit(sr.make_convdiff_runtime(cfg, mesh, st, N))(x0, b)
    assert bool(r.converged)
    assert int(r.outer_iters) == int(ref.outer_iters)
    np.testing.assert_array_equal(np.asarray(r.x), np.asarray(ref.x))


def test_one_shard_mesh_hybrid_bitwise_matches_solve_single():
    st, b, x0 = _setup()
    ref = _reference(st, b, sweep="hybrid")
    mesh = make_shard_mesh((1, 1))
    cfg = sr.ShardRuntimeConfig(monitor=_mon(), reduction="nonblocking",
                                max_outer=400, sweep="hybrid",
                                mesh_shape=(1, 1))
    r = jax.jit(sr.make_convdiff_runtime(cfg, mesh, st, N))(x0, b)
    assert bool(r.converged)
    assert int(r.outer_iters) == int(ref.outer_iters)
    np.testing.assert_array_equal(np.asarray(r.x), np.asarray(ref.x))


def test_unified_api_runs_mesh_shape():
    """run_shard accepts a 2-D mesh + mesh_shape/overlap through
    RuntimeConfig and returns a truthful report."""
    from repro.runtime import api

    st, b, _ = _setup()
    cfg = api.RuntimeConfig(monitor=_mon(), reduction="nonblocking",
                            max_outer=400, mesh_shape=(1, 1), overlap=True,
                            record_trace=True)
    rep = api.run_shard("convdiff", cfg, make_shard_mesh((1, 1)), N,
                        np.zeros_like(np.asarray(b)), np.asarray(b),
                        stencil=st)
    assert rep.converged
    assert rep.trace.meta["mesh_shape"] == [1, 1]


# ---------------------------------------------------------------------------
# Trace schema: mesh shape + per-face halo events
# ---------------------------------------------------------------------------


def _fake_result(outer=3):
    return types.SimpleNamespace(
        outer_iters=outer, converged=True, residual=0.25,
        trace=np.asarray([1.0, 0.5, 0.25]))


def test_trace_records_mesh_shape_and_per_face_halos():
    from repro.core.trace import trace_from_shard_run

    cfg = sr.ShardRuntimeConfig(monitor=_mon(), trace_len=3,
                                mesh_shape=(2, 2))
    tr = trace_from_shard_run(_fake_result(), cfg, 4, wall_s=1.0)
    tr.validate()
    assert tr.meta["mesh_shape"] == [2, 2]
    halos = [e for e in tr.events if e["kind"] == "halo"]
    # every worker of a (2,2) mesh exchanges exactly 2 faces per step
    per_step_w0 = [e for e in halos if e["w"] == 0 and e["step"] == 0]
    assert len(per_step_w0) == 2
    assert {e["face"] for e in per_step_w0} == {"x+", "y+"}
    assert {e["peer"] for e in per_step_w0} == {1, 2}


def test_trace_1d_keeps_single_halo_event():
    from repro.core.trace import trace_from_shard_run

    cfg = sr.ShardRuntimeConfig(monitor=_mon(), trace_len=3)
    tr = trace_from_shard_run(_fake_result(), cfg, 4, wall_s=1.0)
    tr.validate()
    assert tr.meta["mesh_shape"] == [4]
    halos = [e for e in tr.events
             if e["kind"] == "halo" and e["w"] == 0 and e["step"] == 0]
    assert len(halos) == 1 and "face" not in halos[0]


# ---------------------------------------------------------------------------
# Multi-device 2-D behaviour (forced 4-device subprocess)
# ---------------------------------------------------------------------------


_SUBPROCESS_PROGRAM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from repro.core import detection
    from repro.launch.mesh import make_shard_mesh
    from repro.runtime import shard_runtime as sr
    from repro.solvers.convdiff import Stencil, make_rhs

    n = 16
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    b = jnp.asarray(make_rhs(n, seed=0))
    x0 = jnp.zeros_like(b)

    # 1. blocking (2,2) parity vs the synchronous reference trace
    mesh22 = make_shard_mesh((2, 2))
    mon = detection.MonitorConfig(mode="sync", eps=1e-7, staleness=0)
    cfg = sr.ShardRuntimeConfig(monitor=mon, reduction="blocking",
                                max_outer=400, trace_len=256,
                                mesh_shape=(2, 2))
    r = jax.jit(sr.make_convdiff_runtime(cfg, mesh22, st, n))(x0, b)
    assert bool(r.converged)
    T = min(int(r.outer_iters), 256)
    ref = np.asarray(sr.convdiff_reference_trace(st, b, T))
    np.testing.assert_allclose(np.asarray(r.trace)[:T], ref, rtol=5e-5)

    # 2. overlap vs non-overlap: bitwise-identical trajectory under
    #    heterogeneous per-shard knobs
    monp = detection.MonitorConfig(mode="pfait", eps=1e-7, staleness=2,
                                   persistence=4)
    base = dict(monitor=monp, reduction="nonblocking", max_outer=2000,
                inner_sweeps=(1, 2, 1, 3), halo_delay=(0, 1, 2, 1),
                contrib_lag=(0, 1, 0, 1), trace_len=64, mesh_shape=(2, 2))
    r0 = jax.jit(sr.make_convdiff_runtime(
        sr.ShardRuntimeConfig(overlap=False, **base), mesh22, st, n))(x0, b)
    r1 = jax.jit(sr.make_convdiff_runtime(
        sr.ShardRuntimeConfig(overlap=True, **base), mesh22, st, n))(x0, b)
    assert bool(r0.converged) and bool(r1.converged)
    assert int(r0.outer_iters) == int(r1.outer_iters)
    np.testing.assert_array_equal(np.asarray(r0.x), np.asarray(r1.x))
    np.testing.assert_array_equal(np.asarray(r0.trace), np.asarray(r1.trace))
    sweeps = np.asarray(r1.local_sweeps); k = int(r1.outer_iters)
    assert list(sweeps) == [k, 2*k, k, 3*k], sweeps

    # 3. truthful detection across mesh shapes x reductions
    from repro.solvers import jacobi
    from repro.solvers.fixed_point import _zero_ghosts, ghosted
    for shape in [(4,), (2, 2), (1, 4)]:
        mesh = make_shard_mesh(shape)
        for red, mode in (("nonblocking", "pfait"),
                          ("nonblocking", "nfais2"),
                          ("rdoubling", "pfait")):
            m = detection.for_mode(mode, eps_tilde=1e-6, margin=10.0,
                                   staleness=2, persistence=4)
            c = sr.ShardRuntimeConfig(
                monitor=m, reduction=red, max_outer=2000, mesh_shape=shape,
                inner_sweeps=(1, 2, 1, 3), halo_delay=(0, 1, 2, 1),
                contrib_lag=(0, 1, 0, 1), overlap=(len(shape) > 1))
            rr = jax.jit(sr.make_convdiff_runtime(c, mesh, st, n))(x0, b)
            assert bool(rr.converged), (shape, red, mode)
            res = np.asarray(jacobi.residual_block(
                st, ghosted(rr.x, _zero_ghosts(rr.x)), b), np.float64)
            r_star = float(np.linalg.norm(res.ravel()))
            assert r_star < 10.0 * 1e-6, (shape, red, mode, r_star)

    # 4. red-black hybrid on (2,2) converges truthfully
    mh = detection.for_mode("pfait", eps_tilde=1e-6, margin=10.0,
                            staleness=1, persistence=4)
    ch = sr.ShardRuntimeConfig(monitor=mh, reduction="nonblocking",
                               sweep="hybrid", max_outer=2000,
                               mesh_shape=(2, 2), halo_delay=(0, 1, 0, 1))
    rh = jax.jit(sr.make_convdiff_runtime(ch, mesh22, st, n))(x0, b)
    assert bool(rh.converged)
    res = np.asarray(jacobi.residual_block(
        st, ghosted(rh.x, _zero_ghosts(rh.x)), b), np.float64)
    assert float(np.linalg.norm(res.ravel())) < 1e-5
    print("MULTIDEVICE_MESH_OK")
""")


@pytest.mark.slow
def test_multidevice_mesh_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROGRAM], env=env,
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTIDEVICE_MESH_OK" in out.stdout
