"""Test session config.

x64 is enabled for the whole session: the PDE solver substrate needs f64
residuals below 1e-7 (paper thresholds); model code is dtype-explicit
(bf16/f32 params) so it is unaffected.  Device count stays at 1 — only
launch/dryrun.py forces 512 host devices, never tests.
"""
import jax

jax.config.update("jax_enable_x64", True)
