"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret=True."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_flat
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.jacobi3d.jacobi3d import fused_sweep_residual
from repro.kernels.jacobi3d.ref import fused_sweep_residual_ref
from repro.kernels.residual_norm.ops import diff_norm
from repro.kernels.residual_norm.ref import diff_norm_partials_ref
from repro.kernels.residual_norm.residual_norm import diff_norm_partials
from repro.solvers.convdiff import Stencil

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# jacobi3d
# ---------------------------------------------------------------------------

JACOBI_CASES = [
    # (bx, by, bz, tile, dtype)
    (8, 8, 8, (4, 4), jnp.float32),
    (8, 128, 32, (8, 128), jnp.float32),
    (16, 64, 16, (8, 32), jnp.float32),
    (8, 8, 8, (4, 4), jnp.float64),
]


@pytest.mark.parametrize("bx,by,bz,tile,dtype", JACOBI_CASES)
@pytest.mark.parametrize("op", ["sweep", "residual"])
@pytest.mark.parametrize("linf", [True, False])
def test_jacobi3d_matches_oracle(bx, by, bz, tile, dtype, op, linf):
    st = Stencil.for_contraction(bx, 1.0, (1.0, 1.0, 1.0), 0.9)
    coefs = jnp.asarray([st.diag, st.xm, st.xp, st.ym, st.yp, st.zm, st.zp], dtype)
    g = jnp.asarray(RNG.standard_normal((bx + 2, by + 2, bz + 2)), dtype)
    b = jnp.asarray(RNG.standard_normal((bx, by, bz)), dtype)
    new_k, res_k = fused_sweep_residual(g, b, coefs, tile=tile, op=op,
                                        linf=linf, interpret=True)
    new_r, res_r = fused_sweep_residual_ref(g, b, coefs, tile=tile, op=op, linf=linf)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(new_k), np.asarray(new_r), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(res_k), np.asarray(res_r), rtol=1e-4, atol=tol)


def test_jacobi3d_sweep_equals_solver_sweep():
    """Kernel sweep == solvers.jacobi.jacobi_sweep (the production oracle)."""
    from repro.solvers import jacobi

    st = Stencil.for_contraction(8, 1.0, (1.0, 1.0, 1.0), 0.9)
    coefs = jnp.asarray([st.diag, st.xm, st.xp, st.ym, st.yp, st.zm, st.zp])
    g = jnp.asarray(RNG.standard_normal((10, 10, 10)))
    b = jnp.asarray(RNG.standard_normal((8, 8, 8)))
    new_k, _ = fused_sweep_residual(g, b, coefs, tile=(4, 4), interpret=True)
    np.testing.assert_allclose(np.asarray(new_k),
                               np.asarray(jacobi.jacobi_sweep(st, g, b)),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (BH, BN, Sq, H, causal, window, dtype)
    (8, 4, 256, 64, True, 0, jnp.float32),
    (4, 4, 256, 128, False, 0, jnp.float32),
    (6, 2, 384, 64, True, 128, jnp.float32),
    (4, 2, 128, 64, True, 64, jnp.float32),
    (4, 2, 256, 64, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("BH,BN,Sq,H,causal,window,dtype", FLASH_CASES)
def test_flash_attention_matches_oracle(BH, BN, Sq, H, causal, window, dtype):
    q = jnp.asarray(RNG.standard_normal((BH, Sq, H)), dtype)
    k = jnp.asarray(RNG.standard_normal((BN, Sq, H)), dtype)
    v = jnp.asarray(RNG.standard_normal((BN, Sq, H)), dtype)
    out_k = flash_attention_flat(q, k, v, causal=causal, window=window, interpret=True)
    out_r = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=tol, rtol=tol)


def test_flash_matches_model_blocked_attention():
    """Kernel == models.attention.attention_fwd (grouped GQA layout)."""
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.models.attention import attention_fwd

    B, S, N, P, H = 2, 128, 2, 3, 32
    q = jnp.asarray(RNG.standard_normal((B, S, N, P, H)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, N, H)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, N, H)), jnp.float32)
    out_k = flash_attention(q, k, v, causal=True, interpret=True)
    out_b = attention_fwd(q, k, v, causal=True, block_kv=64)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_b),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# residual_norm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(1000,), (128, 130), (7, 33, 65)])
@pytest.mark.parametrize("linf", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64, jnp.bfloat16])
def test_residual_norm_matches_oracle(shape, linf, dtype):
    a = jnp.asarray(RNG.standard_normal(shape), dtype)
    b = jnp.asarray(RNG.standard_normal(shape), dtype)
    pk = diff_norm_partials(a, b, block=256, linf=linf, interpret=True)
    pr = diff_norm_partials_ref(a, b, block=256, linf=linf)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), rtol=1e-5, atol=1e-5)


def test_diff_norm_wrapper():
    a = jnp.asarray(RNG.standard_normal((64, 64)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((64, 64)), jnp.float32)
    np.testing.assert_allclose(
        float(diff_norm(a, b, ord=2, interpret=True)),
        float(jnp.linalg.norm((a - b).ravel())), rtol=1e-5,
    )
    np.testing.assert_allclose(
        float(diff_norm(a, b, ord=float("inf"), interpret=True)),
        float(jnp.max(jnp.abs(a - b))), rtol=1e-6,
    )
