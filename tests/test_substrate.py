"""Data pipeline, checkpointing, optimizer, compression, runtime policies."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, Prefetcher, synth_batch
from repro.optim import AdamW, apply_updates, constant_schedule, cosine_schedule
from repro.optim.grad_compression import (
    ef_compress,
    quantize_int8,
    dequantize_int8,
)
from repro.runtime.fault_tolerance import HeartbeatMonitor, StragglerPolicy, plan_restart
from repro.runtime.elastic import remesh, validate_specs


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_determinism_and_stream_independence():
    dc = DataConfig(seed=7, vocab_size=1000)
    a = synth_batch(dc, step=3, batch=4, seq=16)
    b = synth_batch(dc, step=3, batch=4, seq=16)
    c = synth_batch(dc, step=4, batch=4, seq=16)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    assert not np.array_equal(a["inputs"], c["inputs"])
    assert a["inputs"].max() < 1000
    np.testing.assert_array_equal(a["labels"][:, :-1], a["inputs"][:, 1:])


def test_prefetcher_orders_steps_and_resumes():
    pf = Prefetcher(lambda s: {"step": s}, start_step=5)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"a": jnp.arange(6).reshape(2, 3), "b": (jnp.ones(4), jnp.zeros(2))}
    for step in [10, 20, 30]:
        ck.save(jax.tree.map(lambda x: x + step, state), step)
    ck.wait()
    assert ck.latest_step() == 30
    restored, step = ck.restore(like=state)
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]) + 30)
    # gc kept only 2
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) == 2


def test_checkpoint_ignores_uncommitted(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = {"a": jnp.ones(3)}
    ck.save(state, 5, blocking=True)
    # fake a partial checkpoint at a later step
    os.makedirs(tmp_path / "step_000009")
    assert ck.latest_step() == 5


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_optimizes_quadratic():
    opt = AdamW(constant_schedule(0.1), weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    st = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        upd, st, _ = opt.update(grads, st, params)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_grad_clipping_and_moment_dtype():
    opt = AdamW(constant_schedule(0.1), clip_norm=1.0, moment_dtype="bfloat16")
    params = {"w": jnp.ones(3, jnp.bfloat16)}
    st = opt.init(params)
    assert st.m["w"].dtype == jnp.bfloat16
    upd, st2, gnorm = opt.update({"w": jnp.full(3, 100.0)}, st, params)
    assert float(gnorm) > 1.0  # reported pre-clip norm


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s, x.shape)
    err = np.abs(np.asarray(deq - x))
    bound = np.asarray(s).ravel()[:, None] * 0.5 + 1e-9
    assert np.all(err <= bound + 1e-6)


def test_error_feedback_identity():
    """g + err == deq + new_err exactly (the EF invariant)."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    err = jnp.asarray(rng.standard_normal((4, 32)) * 0.01, jnp.float32)
    q, s, new_err = ef_compress(g, err)
    deq = dequantize_int8(q, s, g.shape)
    np.testing.assert_allclose(np.asarray(g + err),
                               np.asarray(deq + new_err), atol=1e-6)


def test_error_feedback_converges_on_repeated_use():
    """Accumulated EF-compressed sum approaches the true sum."""
    rng = np.random.default_rng(2)
    true_sum = np.zeros((4, 16))
    comp_sum = np.zeros((4, 16))
    err = jnp.zeros((4, 16))
    for i in range(50):
        g = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
        q, s, err = ef_compress(g, err)
        comp_sum += np.asarray(dequantize_int8(q, s, g.shape))
        true_sum += np.asarray(g)
    # residual error stays bounded (doesn't accumulate)
    assert np.abs(true_sum - comp_sum).max() < 0.2


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------


def test_heartbeat_failure_detection():
    hb = HeartbeatMonitor(timeout=10.0)
    hb.beat(0, t=0.0)
    hb.beat(1, t=0.0)
    hb.beat(0, t=8.0)
    assert hb.failed(t=12.0) == [1]
    assert hb.alive(t=12.0) == [0]


def test_straggler_policy_persistence():
    sp = StragglerPolicy(factor=2.0, persistence=2)
    for step in range(5):
        for w in range(4):
            sp.record(w, 1.0 if w != 3 else 5.0)
        flagged = sp.check()
    assert flagged == [3]
    # a single slow step does not flag
    sp2 = StragglerPolicy(factor=2.0, persistence=3)
    sp2.record(0, 1.0)
    sp2.record(1, 9.0)
    assert sp2.check() == []


def test_restart_plan_shrinks_mesh():
    plan = plan_restart(checkpoint_step=120, workers=range(64),
                        failed=[3, 7, 11], model_axis=16)
    assert plan.checkpoint_step == 120
    assert plan.new_mesh_shape == (3, 16)  # 61 survivors → 3×16 usable
    assert plan.world_size == 48
    assert plan.data_resume_step == 120


def test_remesh_and_validate_specs():
    from jax.sharding import PartitionSpec as P

    mesh = remesh(1, model_axis=1)
    ok = validate_specs(
        {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)},
        {"w": P("model", None)}, mesh,
    )
    assert ok
