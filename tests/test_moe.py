"""MoE: routing/packing invariants + distributed vs local-reference parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models.moe import moe_init, plan_moe
from repro.models.transformer import moe_local_reference
import pytest


def _cfg(E=4, k=2, d=32, f=64):
    return ModelConfig(
        name="t", family="moe", num_layers=2, d_model=d, vocab_size=128,
        num_heads=4, num_kv_heads=2, d_ff=f, num_experts=E, experts_per_token=k,
    )


def test_plan_virtual_experts_when_E_lt_tp():
    plan = plan_moe(_cfg(E=8, f=64), tp=16)
    assert plan.virt_per_expert == 2
    assert plan.virtual_experts == 16
    assert plan.d_ff_virtual == 32
    assert plan.per_rank_slots == 1


def test_plan_direct_when_E_ge_tp():
    plan = plan_moe(_cfg(E=32), tp=16)
    assert plan.virt_per_expert == 1
    assert plan.per_rank_slots == 2


def test_virtual_split_is_exact():
    """A gated FFN split along d_ff into r virtual experts sums exactly."""
    key = jax.random.PRNGKey(0)
    d, f, r = 16, 32, 2
    w1 = jax.random.normal(key, (d, f))
    w3 = jax.random.normal(jax.random.fold_in(key, 1), (d, f))
    w2 = jax.random.normal(jax.random.fold_in(key, 2), (f, d))
    x = jax.random.normal(jax.random.fold_in(key, 3), (5, d))
    full = (jax.nn.silu(x @ w1) * (x @ w3)) @ w2
    parts = 0
    for i in range(r):
        sl = slice(i * f // r, (i + 1) * f // r)
        parts = parts + (jax.nn.silu(x @ w1[:, sl]) * (x @ w3[:, sl])) @ w2[sl]
    np.testing.assert_allclose(np.asarray(full), np.asarray(parts), atol=1e-5)


def test_route_and_pack_capacity_invariants():
    plan = plan_moe(_cfg(E=4, k=2), tp=1)
    key = jax.random.PRNGKey(0)
    weights = moe_init(key, plan, gated=True, dtype=jnp.float32)
    t = 16
    tokens = jax.random.normal(jax.random.fold_in(key, 5), (t, plan.d_model))
    C = plan.capacity(t)
    send, (slots, pos, w), aux = moe_mod._route_and_pack(
        tokens, weights["router"], plan, C, jnp.ones((t,))
    )
    assert send.shape == (plan.virtual_experts, C, plan.d_model)
    pos_np, slots_np, w_np = map(np.asarray, (pos, slots, w))
    # every kept entry has a unique (slot, pos) and pos < C
    kept = w_np > 0
    assert np.all(pos_np[kept] < C)
    coords = list(zip(slots_np[kept].ravel(), pos_np[kept].ravel()))
    assert len(coords) == len(set(coords))
    assert np.isfinite(float(aux))


def test_shard_map_moe_matches_local_reference_single_device():
    """On a 1×1 mesh the a2a/AG collapse; with ample capacity the packed
    path must equal the dense one-hot reference exactly."""
    cfg = _cfg(E=4, k=2, d=32, f=64)
    plan = plan_moe(cfg, tp=1, capacity_factor=float(cfg.num_experts))  # no drops
    key = jax.random.PRNGKey(0)
    weights = moe_init(key, plan, gated=True, dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 7), (2, 8, cfg.d_model))
    from repro.core.compat import make_mesh_compat

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    y_ref, aux_ref = moe_local_reference(x, weights, plan, gated=True)
    y_sm, aux_sm = jax.jit(
        lambda xx, ww: moe_mod.moe_apply(xx, ww, plan, True, mesh, dp_axes=("data",))
    )(x, weights)
    np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(float(aux_sm), float(aux_ref), rtol=1e-5)


@pytest.mark.slow
def test_moe_is_differentiable_through_dispatch():
    cfg = _cfg(E=4, k=1, d=16, f=32)
    plan = plan_moe(cfg, tp=1, capacity_factor=4.0)
    key = jax.random.PRNGKey(0)
    weights = moe_init(key, plan, gated=True, dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 9), (1, 8, cfg.d_model))
    from repro.core.compat import make_mesh_compat

    mesh = make_mesh_compat((1, 1), ("data", "model"))

    def loss(w):
        y, aux = moe_mod.moe_apply(x, w, plan, True, mesh, dp_axes=("data",))
        return jnp.sum(y**2) + 0.01 * aux

    g = jax.grad(loss)(weights)
    gn = sum(float(jnp.sum(jnp.abs(leaf))) for leaf in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
