"""Elastic restart machinery: remesh/reshard spec validation, restart
planning edge cases, Checkpointer round-trips (view-dtype encoding,
topology-changing restore, async failure propagation) and the
fault-injected driver (`runtime.elastic.run_elastic`).

The pytest session runs on ONE device (tests/conftest.py): in-process
driver tests use a 1-shard mesh; the real crash -> heartbeat -> shrink ->
restore -> resume cycle needs >= 2 surviving shards and runs in a
forced-4-device subprocess, marked ``slow`` (the elastic-smoke CI lane
covers it at full size).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer, _parse_step
from repro.core import detection
from repro.runtime import elastic
from repro.runtime.elastic import (
    FaultPlan,
    remesh,
    reshard,
    run_elastic,
    shrink_to_fit,
    validate_specs,
)
from repro.runtime.fault_tolerance import HeartbeatMonitor, plan_restart
from repro.runtime.shard_runtime import ShardRuntimeConfig
from repro.solvers.convdiff import Stencil, make_rhs

P = jax.sharding.PartitionSpec
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# remesh / reshard spec validation
# ---------------------------------------------------------------------------


def test_remesh_shapes():
    mesh = remesh(1, model_axis=1)
    assert dict(mesh.shape) == {"data": 1, "model": 1}


def test_validate_specs_accepts_and_rejects_divisibility():
    mesh = remesh(1, model_axis=1)
    ok = jax.ShapeDtypeStruct((8, 4), jnp.float32)
    bad = jax.ShapeDtypeStruct((7, 4), jnp.float32)
    assert validate_specs(ok, P("model", None), mesh)
    assert validate_specs(bad, P("model", None), mesh)  # 7 % 1 == 0
    # a 1-device session cannot build a 2-wide mesh; validate_specs only
    # reads mesh.shape, so a stand-in exercises the rejection branch
    class TwoWide:
        shape = {"data": 1, "model": 2}
    assert not validate_specs(bad, P("model", None), TwoWide())
    assert validate_specs(jax.ShapeDtypeStruct((8, 4), jnp.float32),
                          P("model", None), TwoWide())


def test_reshard_places_host_arrays():
    mesh = remesh(1, model_axis=1)
    tree = {"w": np.arange(8.0).reshape(8, 1)}
    out = reshard(tree, {"w": P("model", None)}, mesh)
    assert isinstance(out["w"], jax.Array)
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])


# ---------------------------------------------------------------------------
# plan_restart edge cases + shrink_to_fit
# ---------------------------------------------------------------------------


def test_plan_restart_fewer_survivors_than_model_axis():
    plan = plan_restart(checkpoint_step=10, workers=range(8),
                        failed=[0, 1, 2, 3, 4], model_axis=16)
    assert plan.surviving_workers == (5, 6, 7)
    assert plan.new_mesh_shape == (1, 3)  # model axis collapses to fit
    assert plan.world_size == 3
    assert plan.data_resume_step == 10


def test_plan_restart_zero_survivors_raises():
    with pytest.raises(RuntimeError, match="no survivors"):
        plan_restart(checkpoint_step=5, workers=[0, 1], failed=[0, 1])


def test_plan_restart_none_checkpoint_resumes_from_zero():
    plan = plan_restart(checkpoint_step=None, workers=[0, 1, 2],
                        failed=[2], model_axis=1)
    assert plan.checkpoint_step == 0 and plan.data_resume_step == 0


def test_shrink_to_fit_divisibility_and_butterfly():
    assert shrink_to_fit(24, 4) == 4
    assert shrink_to_fit(24, 5) == 4          # 5 does not divide 24
    assert shrink_to_fit(24, 3) == 3
    assert shrink_to_fit(24, 3, "rdoubling") == 2   # power-of-two only
    assert shrink_to_fit(24, 7, "rdoubling") == 4
    with pytest.raises(ValueError, match="survivors"):
        shrink_to_fit(24, 0)


# ---------------------------------------------------------------------------
# Checkpointer: view dtypes, topology change, failure propagation, GC
# ---------------------------------------------------------------------------


def test_checkpoint_view_dtype_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = {
        "bf16": np.arange(6, dtype=ml_dtypes.bfloat16).reshape(2, 3),
        "fp8": np.linspace(-2, 2, 8).astype(ml_dtypes.float8_e4m3fn),
        "f32": np.ones((3,), np.float32),
    }
    ck.save(state, step=1, blocking=True)
    out, step = ck.restore(like=state)
    assert step == 1
    for k in state:
        assert out[k].dtype == state[k].dtype
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(state[k]))


def test_checkpoint_topology_changing_restore(tmp_path):
    """Save from one layout, restore onto another mesh's shardings — the
    checkpoint itself is topology-free host data."""
    ck = Checkpointer(str(tmp_path))
    x = jnp.arange(12.0).reshape(12, 1)
    ck.save({"x": x}, step=3, blocking=True)
    mesh = remesh(1, model_axis=1)
    sharding = {"x": jax.sharding.NamedSharding(mesh, P("model", None))}
    out, step = ck.restore(like={"x": x}, shardings=sharding)
    assert step == 3
    assert out["x"].sharding.is_equivalent_to(sharding["x"], ndim=2)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))


def test_async_save_failure_raises_from_wait(tmp_path, monkeypatch):
    ck = Checkpointer(str(tmp_path))

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr("repro.checkpoint.checkpointer.np.save", boom)
    ck.save({"x": np.ones(3)}, step=1)  # async: failure lands on the thread
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        ck.wait()
    # the error is consumed: a subsequent save/wait cycle works again
    monkeypatch.undo()
    ck.save({"x": np.ones(3)}, step=2, blocking=True)
    assert ck.latest_step() == 2


def test_async_save_failure_raises_from_next_save(tmp_path, monkeypatch):
    ck = Checkpointer(str(tmp_path))
    monkeypatch.setattr("repro.checkpoint.checkpointer.np.save",
                        lambda *a, **kw: (_ for _ in ()).throw(OSError("x")))
    ck.save({"x": np.ones(3)}, step=1)
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        ck.save({"x": np.ones(3)}, step=2)


def test_malformed_step_dirs_are_ignored(tmp_path):
    assert _parse_step("step_000010") == 10
    for name in ("step_abc", "step_", "notastep", "step_00002.tmp"):
        assert _parse_step(name) is None
    ck = Checkpointer(str(tmp_path), keep=1)
    for name in ("step_abc", "notastep", "step_00002.tmp"):
        os.makedirs(tmp_path / name)
    (tmp_path / "README").write_text("stray file")
    assert ck.latest_step() is None
    ck.save({"x": np.ones(2)}, step=1, blocking=True)
    ck.save({"x": np.ones(2)}, step=2, blocking=True)  # triggers _gc
    assert ck.latest_step() == 2
    # foreign entries survive GC untouched
    assert (tmp_path / "step_abc").exists()
    assert (tmp_path / "README").exists()


# ---------------------------------------------------------------------------
# HeartbeatMonitor.register
# ---------------------------------------------------------------------------


def test_heartbeat_register_counts_as_enrollment_beat():
    hb = HeartbeatMonitor(timeout=2.0)
    hb.register([0, 1], t=0.0)
    assert hb.failed(1.0) == []          # within timeout, never beat
    assert sorted(hb.failed(5.0)) == [0, 1]   # silent past timeout
    hb.beat(1, 5.0)
    assert hb.failed(6.0) == [0]


def test_heartbeat_register_preserves_existing_beats():
    hb = HeartbeatMonitor(timeout=2.0)
    hb.beat(0, 10.0)
    hb.register([0, 1], t=0.0)           # must not rewind worker 0
    assert hb.failed(11.0) == [1]


# ---------------------------------------------------------------------------
# FaultPlan validation + run_elastic (1-device in-process)
# ---------------------------------------------------------------------------


def test_fault_plan_validation():
    with pytest.raises(ValueError, match=">= 0"):
        FaultPlan(crash_at={-1: 3})
    with pytest.raises(ValueError, match="repair must"):
        FaultPlan(crash_at={1: 5}, join_at={1: 2})
    FaultPlan(crash_at={1: 2}, join_at={1: 6})  # repair after crash: ok


def _elastic_cfg(mode="pfait", eps_tilde=1e-6):
    mon = detection.for_mode(mode, eps_tilde=eps_tilde, margin=10.0,
                             staleness=1, persistence=2, ord=2.0)
    return ShardRuntimeConfig(monitor=mon, reduction="nonblocking",
                              inner_sweeps=1, halo_delay=0, contrib_lag=1)


def test_run_elastic_rejects_per_shard_sequences(tmp_path):
    mon = detection.for_mode("pfait", eps_tilde=1e-6, ord=2.0)
    cfg = ShardRuntimeConfig(monitor=mon, inner_sweeps=(1, 2, 1, 2))
    with pytest.raises(ValueError, match="scalar inner_sweeps"):
        run_elastic("convdiff", cfg, 8, np.zeros((8, 8, 8)),
                    np.zeros((8, 8, 8)), FaultPlan(), str(tmp_path), p0=1)


def test_run_elastic_uninterrupted_converges(tmp_path):
    n = 8
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    b = make_rhs(n, seed=0)
    rep = run_elastic("convdiff", _elastic_cfg(), n, np.zeros_like(b), b,
                      FaultPlan(), str(tmp_path), stencil=st, p0=1,
                      segment_len=25, max_segments=40)
    assert rep.converged and rep.restarts == 0 and rep.stall_segments == 0
    assert rep.detected_residual < 1e-5
    assert rep.mesh_history == [(0, 1)]
    assert rep.checkpoint_saves >= 1      # the synchronous recovery floor
    assert rep.x.shape == b.shape


def test_run_elastic_spare_join_keeps_mesh(tmp_path):
    """A joiner beyond the host's device budget becomes a control-plane
    spare: membership grows, the mesh cannot."""
    n = 8
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    b = make_rhs(n, seed=0)
    rep = run_elastic("convdiff", _elastic_cfg(), n, np.zeros_like(b), b,
                      FaultPlan(join_at={1: 1}), str(tmp_path), stencil=st,
                      p0=1, segment_len=25, max_segments=40)
    assert rep.converged
    assert rep.members_final == (0, 1)
    assert rep.mesh_history == [(0, 1)]
    assert any(ev[1] == "join" for ev in rep.events)


# ---------------------------------------------------------------------------
# The real crash -> heartbeat -> shrink -> restore cycle (4 devices)
# ---------------------------------------------------------------------------

_SUBPROCESS_PROGRAM = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=4")
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro.core import detection
    from repro.runtime.elastic import FaultPlan, run_elastic
    from repro.runtime.shard_runtime import ShardRuntimeConfig
    from repro.solvers.convdiff import Stencil, make_rhs

    assert len(jax.devices()) == 4
    n = 24
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    b = make_rhs(n, seed=0)
    mon = detection.for_mode("pfait", eps_tilde=1e-6, margin=10.0,
                             staleness=2, persistence=4, ord=2.0)
    cfg = ShardRuntimeConfig(monitor=mon, reduction="nonblocking",
                             inner_sweeps=2, halo_delay=1, contrib_lag=1)
    plan = FaultPlan(crash_at={1: 3}, join_at={1: 8})
    with tempfile.TemporaryDirectory() as d:
        rep = run_elastic("convdiff", cfg, n, np.zeros_like(b), b, plan, d,
                          stencil=st, p0=4, segment_len=10, ckpt_every=2,
                          max_segments=60)
    assert rep.converged, "never detected after restart"
    assert rep.restarts == 1, rep.restarts
    assert rep.stall_segments >= 1, "crash did not stall the collective"
    assert rep.lost_iters > 0, "restart did not roll back"
    assert rep.detect_latency and rep.detect_latency[0] > 0
    ps = [p for _, p in rep.mesh_history]
    assert ps[0] == 4 and 3 in ps and ps[-1] == 4, ps  # shrink then regrow
    assert rep.members_final == (0, 1, 2, 3)
    print("ELASTIC_SUBPROCESS_OK")
""")


@pytest.mark.slow
def test_crash_restart_resume_on_four_devices():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROGRAM],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "ELASTIC_SUBPROCESS_OK" in proc.stdout
