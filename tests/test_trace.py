"""Trace schema tests: event validation, JSONL round-trip, and the two
emitters (engine observer + device-runtime adapter) producing schema-valid
traces.

The session runs on ONE device (tests/conftest.py), so device-adapter
tests use a 1-shard mesh — the trace machinery (per-step events, reduce
series, monitor metadata) is shard-count independent.
"""
import numpy as np
import pytest

from repro.core import detection, trace as tracemod
from repro.core.trace import (
    EVENT_KINDS,
    EngineTraceObserver,
    Trace,
    event,
    validate_trace,
)


# ---------------------------------------------------------------------------
# Event / trace construction and validation
# ---------------------------------------------------------------------------


def test_event_rejects_unknown_kind():
    with pytest.raises(ValueError, match="kind"):
        event("barrier", 0.0)


def test_event_schema_keys_cannot_be_shadowed():
    # the schema keys are named parameters: a payload dict carrying one is
    # a duplicate keyword, rejected by the call itself
    with pytest.raises(TypeError):
        event("reduce", 0.0, **{"kind": "halo"})
    with pytest.raises(TypeError):
        event("reduce", 0.0, **{"t": 1.0})


def test_events_of_rejects_unknown_kind():
    tr = Trace("test", 1)
    with pytest.raises(ValueError, match="kind"):
        tr.events_of("barrier")


def test_validate_catches_bad_header_and_events():
    tr = Trace("test", 1)
    tr.header["p"] = 0
    with pytest.raises(ValueError, match="worker count"):
        tr.validate()

    tr = Trace("test", 1)
    tr.append({"kind": "sweep", "t": 0.0, "w": 0})   # missing "step"
    with pytest.raises(ValueError, match="step"):
        tr.validate()
    assert not validate_trace(tr)

    tr = Trace("test", 1)
    tr.append({"kind": "sweep", "t": float("nan"), "w": 0, "step": 0})
    with pytest.raises(ValueError, match="timestamp"):
        tr.validate()


def test_jsonl_round_trip_preserves_fingerprint():
    tr = Trace("test", 4, {"reduction": "nonblocking", "wall_s": 0.5})
    for k in range(5):
        for w in range(4):
            tr.add("sweep", 0.1 * (k + 1), w=w, step=k, inner=2)
        tr.add("reduce", 0.1 * (k + 1), step=k, residual=0.9 ** k)
    tr.add("finish", 0.5, step=4, terminated=True)
    tr.validate()

    back = Trace.loads(tr.dumps())
    back.validate()
    assert back.fingerprint() == tr.fingerprint()
    assert back.header == tr.header
    assert back.events == tr.events


def test_load_dump_file_round_trip(tmp_path):
    tr = Trace("test", 2)
    tr.add("reduce", 1.0, step=0, residual=0.5)
    path = tmp_path / "trace.jsonl"
    tr.dump(path)
    assert Trace.load(path).fingerprint() == tr.fingerprint()


def test_loads_rejects_foreign_schema():
    tr = Trace("test", 1)
    text = tr.dumps().replace(tracemod.SCHEMA, "other-schema/9")
    with pytest.raises(ValueError, match="schema"):
        Trace.loads(text)


def test_residual_series_keeps_inf_gaps():
    """Steps with no completed reduction (butterfly warm-up) stay +inf so
    replay sees the same step indexing the device monitor did."""
    tr = Trace("test", 4)
    tr.add("reduce", 1.0, step=1, residual=0.5)
    tr.add("reduce", 2.0, step=3, residual=0.25)
    series = tr.residual_series()
    assert len(series) == 4
    assert np.isinf(series[0]) and np.isinf(series[2])
    assert series[1] == 0.5 and series[3] == 0.25


# ---------------------------------------------------------------------------
# Engine observer emitter
# ---------------------------------------------------------------------------


def test_engine_observer_emits_schema_valid_trace():
    from repro.core.async_engine import AsyncEngine, DelayModel, EngineConfig
    from repro.core.protocols import PFAIT
    from repro.solvers.convdiff import ConvDiffProblem

    prob = ConvDiffProblem(n=8, p=4, rho=0.85, seed=0)
    obs = EngineTraceObserver(p=4)
    cfg = EngineConfig(compute=DelayModel(1e-3, sigma=0.3),
                       channel=DelayModel(5e-4, sigma=0.5),
                       seed=0, max_iters=30_000)
    result = AsyncEngine(prob, cfg, PFAIT(1e-5, ord=prob.ord),
                         recorder=obs).run()
    assert result.terminated

    tr = obs.trace
    tr.validate()
    assert tr.source == "engine" and tr.p == 4
    kinds = {e["kind"] for e in tr.events}
    # PFAIT is protocol-free: contributions ride the halo ("data")
    # messages, so no separate reduce sends appear — exactly the paper
    assert {"sweep", "halo", "detect", "finish"} <= kinds
    # virtual timestamps are the engine clock: non-negative, finite
    assert all(e["t"] >= 0 for e in tr.events)
    fin = tr.events_of("finish")
    assert len(fin) == 1 and fin[0]["terminated"]
    # round-trips like any other schema trace
    assert Trace.loads(tr.dumps()).fingerprint() == tr.fingerprint()


def test_engine_observer_record_sends_off_drops_message_events():
    from repro.core.async_engine import AsyncEngine, DelayModel, EngineConfig
    from repro.core.protocols import PFAIT
    from repro.solvers.convdiff import ConvDiffProblem

    prob = ConvDiffProblem(n=8, p=4, rho=0.85, seed=0)
    obs = EngineTraceObserver(p=4, record_sends=False)
    cfg = EngineConfig(compute=DelayModel(1e-3, sigma=0.3),
                       channel=DelayModel(5e-4, sigma=0.5),
                       seed=0, max_iters=30_000)
    AsyncEngine(prob, cfg, PFAIT(1e-5, ord=prob.ord), recorder=obs).run()
    assert not obs.trace.events_of("halo")
    assert not obs.trace.events_of("reduce")
    assert obs.trace.events_of("sweep")   # sweeps still recorded


# ---------------------------------------------------------------------------
# Device-runtime adapter (through the unified API, 1-shard mesh)
# ---------------------------------------------------------------------------


def _device_trace(reduction="nonblocking", staleness=2):
    from repro.launch.mesh import make_shard_mesh
    from repro.runtime import api
    from repro.solvers.convdiff import Stencil, make_rhs

    n = 8
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    b = make_rhs(n, seed=0)
    mon = detection.for_mode("pfait", eps_tilde=1e-6, staleness=staleness)
    cfg = api.RuntimeConfig(monitor=mon, reduction=reduction,
                            max_outer=500, record_trace=True)
    rep = api.run_shard("convdiff", cfg, make_shard_mesh(1), n,
                        np.zeros_like(b), b, stencil=st)
    return rep


def test_shard_adapter_emits_schema_valid_trace():
    rep = _device_trace()
    assert rep.converged
    tr = rep.trace
    tr.validate()
    assert tr.source == "shard" and tr.p == 1
    assert tr.meta["reduction"] == "nonblocking"
    assert tr.meta["synthetic_t"] is True   # jitted loop: interpolated t
    mon = tr.meta["monitor"]
    assert mon["mode"] == "pfait" and mon["staleness"] == 2
    # the reduce series is the launched-residual ledger, step-indexed
    series = tr.residual_series()
    assert len(series) == rep.outer_iters
    finite = [v for v in series if np.isfinite(v)]
    assert finite and finite[-1] < 1e-5
    # detection landed and is on the trace
    det = tr.events_of("detect")
    assert len(det) == 1 and det[0]["step"] == rep.detect_step
    assert all(e["kind"] in EVENT_KINDS for e in tr.events)


def test_shard_adapter_trace_round_trips():
    tr = _device_trace().trace
    assert Trace.loads(tr.dumps()).fingerprint() == tr.fingerprint()
