"""Async data-parallel training on real JAX shards: convergence across
reduction modes, heterogeneous local SGD, and oracle-consistent detection.

Multi-device behaviour follows the repo convention (test_shard_runtime.py):
a forced-4-device subprocess, since the main test session pins 1 device.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import detection
from repro.core.termination import detection_consistent, oracle_detect_step
from repro.runtime import train_async as ta
from repro.solvers.mlfixed import MLFixedPointProblem

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _problem(task="lstsq", seed=3):
    return MLFixedPointProblem(n=16, p=4, m_rows=64, task=task, seed=seed)


# ---------------------------------------------------------------------------
# Host-side pieces (no mesh needed)
# ---------------------------------------------------------------------------


def test_effective_monitor_forces_k0_for_blocking_modes():
    mon = detection.for_mode("pfait", eps_tilde=1e-6, staleness=3)
    for red in ("blocking", "rdoubling"):
        cfg = ta.TrainAsyncConfig(monitor=mon, reduction=red)
        assert cfg.effective_monitor().staleness == 0
    cfg = ta.TrainAsyncConfig(monitor=mon, reduction="nonblocking")
    assert cfg.effective_monitor().staleness == 3


def test_config_and_shape_validation():
    prob = _problem()
    mon = detection.for_mode("pfait", eps_tilde=1e-6)
    with pytest.raises(ValueError):
        ta.TrainAsyncConfig(monitor=mon, reduction="gossip")
    with pytest.raises(ValueError):
        ta.TrainAsyncConfig(monitor=mon, num_batches=0)
    with pytest.raises(ValueError):
        ta.safe_gamma(prob, 3)               # 64 rows % 3 != 0
    with pytest.raises(ValueError):
        ta.safe_gamma(prob, 4, num_batches=5)  # 16 local rows % 5 != 0


def test_safe_gamma_tighter_than_full_batch():
    """Minibatch curvature ≥ full-batch curvature per shard, so the safe
    step shrinks (or stays) as batches get smaller."""
    prob = _problem()
    g1 = ta.safe_gamma(prob, 4, num_batches=1)
    g4 = ta.safe_gamma(prob, 4, num_batches=4)
    assert g4 <= g1 * (1 + 1e-12)
    assert 0 < g4 < 2.0 / prob.mu


def test_reference_trace_converges_and_oracle_scores_it():
    """The host reference of the lifted map: residual decreasing to 0 for
    deterministic rotation (s multiple of num_batches), and the oracle
    helpers agree on the crossing."""
    prob = _problem()
    gamma = ta.safe_gamma(prob, 4, num_batches=2)
    X, ref = ta.reference_trace(prob, 4, inner_steps=2, num_batches=2,
                                gamma=gamma, rounds=3000)
    eps = 1e-6
    k = oracle_detect_step(ref, eps)
    assert k is not None and 0 < k < 3000
    assert ref[k] < eps <= ref[k - 1]
    assert detection_consistent(k, ref, eps)
    assert not detection_consistent(None, ref, eps)
    assert oracle_detect_step(ref, 1e-300) is None
    # endpoint matches exact_train_residual on the final stack
    endpoint = ta.exact_train_residual(prob, X, 2, gamma, num_batches=2,
                                       phase=3000)
    assert endpoint == pytest.approx(ref[-1], rel=1e-2)


def test_heterogeneous_inner_steps_bias_stays_below_plateau():
    """Workers doing different step counts converge to a lifted fixed
    point whose replicas differ (local-SGD objective inconsistency), yet
    the residual still → 0 — the certificate is about the *map*, not
    about replica agreement."""
    prob = _problem()
    gamma = ta.safe_gamma(prob, 4, num_batches=1)
    X, ref = ta.reference_trace(prob, 4, inner_steps=[1, 2, 1, 3],
                                num_batches=1, gamma=gamma, rounds=4000)
    assert ref[-1] < 1e-10
    spread = np.max(np.abs(X - X.mean(axis=0)))
    assert spread > 1e-8      # replicas genuinely offset at the fixed point
    x_star = prob.solve_reference()
    # the consensus mean sits near (not at) the minimiser: O(γ) bias
    assert np.linalg.norm(X.mean(axis=0) - x_star) < 10 * gamma


# ---------------------------------------------------------------------------
# Multi-device behaviour (forced 4-device subprocess)
# ---------------------------------------------------------------------------


_SUBPROCESS_PROGRAM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro.core import detection
    from repro.core.termination import detection_consistent, oracle_detect_step
    from repro.launch.mesh import make_shard_mesh
    from repro.runtime import train_async as ta
    from repro.solvers.mlfixed import MLFixedPointProblem

    mesh = make_shard_mesh(4)
    eps_tilde = 1e-6
    nb = 2

    # 1. every reduction mode converges on both tasks; the exact lifted
    #    residual (the synchronized eval the run never paid) certifies ε̃
    for task in ("lstsq", "logistic"):
        prob = MLFixedPointProblem(n=16, p=4, m_rows=64, task=task, seed=3)
        gamma = ta.safe_gamma(prob, 4, num_batches=nb)
        for red in ("blocking", "nonblocking", "rdoubling"):
            hetero = red != "blocking"
            cfg = ta.TrainAsyncConfig(
                monitor=detection.for_mode("pfait", eps_tilde=eps_tilde,
                                           staleness=2),
                reduction=red,
                inner_steps=[2, 4, 2, 4] if hetero else 2,
                view_delay=[0, 1, 2, 1] if hetero else 0,
                contrib_lag=[0, 1, 0, 2] if hetero else 0,
                num_batches=nb, gamma=gamma, max_rounds=20000)
            r = ta.make_train_runtime(prob, cfg, mesh)(
                ta.init_replicas(prob, 4), prob.A, prob.y)
            assert bool(r.converged), (task, red)
            exact = ta.exact_train_residual(prob, np.asarray(r.x),
                                            cfg.inner_steps, gamma,
                                            num_batches=nb)
            assert exact < 10 * eps_tilde, (task, red, exact)
            steps = np.asarray(r.local_steps)
            if hetero:
                assert steps.max() == 2 * steps.min(), (task, red)
            assert float(r.residual) < eps_tilde / 10 * 1.01, (task, red)

    # 2. zero-delay nonblocking trace == host reference, round for round
    prob = MLFixedPointProblem(n=16, p=4, m_rows=64, task="lstsq", seed=3)
    gamma = ta.safe_gamma(prob, 4, num_batches=nb)
    cfg = ta.TrainAsyncConfig(
        monitor=detection.for_mode("sync", eps_tilde=1e-8),
        reduction="nonblocking", inner_steps=2, num_batches=nb,
        gamma=gamma, max_rounds=5000, trace_len=32)
    r = ta.make_train_runtime(prob, cfg, mesh)(
        ta.init_replicas(prob, 4), prob.A, prob.y)
    _, ref = ta.reference_trace(prob, 4, 2, nb, gamma, rounds=32)
    np.testing.assert_allclose(np.asarray(r.trace)[:30], ref[:30],
                               rtol=1e-5)   # f32 trace storage

    # 3. the async detection round is decade-consistent with the
    #    synchronized-eval oracle
    cfg = ta.TrainAsyncConfig(
        monitor=detection.for_mode("pfait", eps_tilde=eps_tilde, staleness=2),
        reduction="nonblocking", inner_steps=2, num_batches=nb,
        gamma=gamma, max_rounds=20000)
    r = ta.make_train_runtime(prob, cfg, mesh)(
        ta.init_replicas(prob, 4), prob.A, prob.y)
    assert bool(r.converged)
    detected = int(r.rounds)
    _, ref = ta.reference_trace(prob, 4, 2, nb, gamma, rounds=detected + 16)
    oracle = oracle_detect_step(ref, eps_tilde)
    assert oracle is not None and detected >= oracle, (detected, oracle)
    assert detection_consistent(detected, ref, eps_tilde)

    # 4. NFAIS2 pays its blocking verification and certifies ε̃ itself
    cfg = ta.TrainAsyncConfig(
        monitor=detection.for_mode("nfais2", eps_tilde=eps_tilde,
                                   staleness=2, persistence=3),
        reduction="nonblocking", inner_steps=2, view_delay=[0, 1, 0, 1],
        num_batches=nb, gamma=gamma, max_rounds=20000)
    r = ta.make_train_runtime(prob, cfg, mesh)(
        ta.init_replicas(prob, 4), prob.A, prob.y)
    assert bool(r.converged)
    assert int(r.verifications) >= 1
    assert float(r.residual) < eps_tilde
    print("TRAIN_ASYNC_OK")
""")


@pytest.mark.slow
def test_multidevice_train_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROGRAM], env=env,
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "TRAIN_ASYNC_OK" in out.stdout
