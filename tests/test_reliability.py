"""Reliability lab: trace determinism, oracle correctness, per-protocol
false/late-detection invariants, and the PR-1 coverage backfill
(``wants_residual`` gating for ExactSnapshotFIFO, grace-path trace).
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.async_engine import (
    AsyncEngine,
    DelayModel,
    PLATFORMS,
    stable_platform,
)
from repro.core.protocols import NFAIS2, NFAIS5, PFAIT, ExactSnapshotFIFO
from repro.core.reliability import (
    TraceRecorder,
    detection_report,
    nfais5_slack,
    platform_health,
    replay_matches,
    run_traced,
)
from repro.core.scenarios import standard_scenarios
from repro.solvers.convdiff import ConvDiffProblem
from repro.solvers.pagerank import PageRankProblem

EPS = 1e-6
BASE = 1e-3


def _cfg(spec, seed=0, max_iters=4000, fifo=False):
    return dataclasses.replace(
        PLATFORMS[spec.platform](BASE), seed=seed, max_iters=max_iters,
        fifo=fifo, scenario=spec.scenario,
    )


def _convdiff(seed=0):
    return ConvDiffProblem(n=12, p=4, rho=0.9, seed=seed)


# ---------------------------------------------------------------------------
# Seeded scenario determinism
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", ["drop_reorder", "burst", "heavy_tail",
                                      "pause_resume"])
def test_same_seed_same_trace(scenario):
    spec = standard_scenarios(BASE)[scenario]
    assert replay_matches(
        lambda: _convdiff(seed=5), _cfg(spec, seed=5),
        lambda pr: PFAIT(EPS, ord=pr.ord), residual_stride=25,
    )


def test_different_seed_different_trace():
    spec = standard_scenarios(BASE)["drop_reorder"]
    traces = {}
    for seed in (0, 1):
        _, rec = run_traced(lambda: _convdiff(seed=0), _cfg(spec, seed=seed),
                            lambda pr: PFAIT(EPS, ord=pr.ord))
        traces[seed] = rec.fingerprint()
    assert traces[0] != traces[1]


# ---------------------------------------------------------------------------
# Oracle correctness on a hand-built 2-worker trace
# ---------------------------------------------------------------------------


def test_oracle_on_hand_built_trace():
    """2 workers, residual trajectory crossing ε at t=2.0, detection at
    t=4.0 claiming 5e-7 while the true state sits at 2e-5: the oracle must
    call this a false detection with latency overhead 2.0."""
    rec = TraceRecorder()
    rec.events = [("sweep", 0.0, 0, 1), ("sweep", 0.5, 1, 1)]
    rec.residual_samples = [(0.0, 1.0), (1.0, 1e-3), (2.0, 9e-7), (3.0, 1e-8)]
    rec.detect = (4.0, 5e-7)
    rec.true_at_detect = 2e-5
    rep = detection_report(rec, eps=1e-6, factor=10.0)
    assert rep.terminated
    assert rep.detected_residual == 5e-7
    assert rep.true_at_detect == 2e-5
    assert rep.overshoot == pytest.approx(20.0)
    assert rep.false_detection  # 2e-5 > 10 × 1e-6
    assert rep.t_first_below == 2.0
    assert rep.latency_overhead == pytest.approx(2.0)


def test_oracle_sound_detection_and_undetected():
    rec = TraceRecorder()
    rec.residual_samples = [(0.0, 1.0), (1.0, 5e-7)]
    rec.detect = (1.5, 8e-7)
    rec.true_at_detect = 9e-7
    rep = detection_report(rec, eps=1e-6)
    assert rep.terminated and not rep.false_detection
    assert rep.overshoot == pytest.approx(0.9)
    assert rep.latency_overhead == pytest.approx(0.5)

    rec2 = TraceRecorder()
    rec2.residual_samples = [(0.0, 1.0)]
    rep2 = detection_report(rec2, eps=1e-6)
    assert not rep2.terminated
    assert not rep2.false_detection
    assert math.isinf(rep2.overshoot)
    assert rep2.latency_overhead is None


def test_oracle_true_at_detect_matches_live_state():
    """Engine-integrated: the recorder's detection-instant residual equals
    the exact residual of the engine state frozen at that moment (tiny
    2-worker problem so the sweep-event trace is fully inspectable)."""
    def prob_mk():
        return ConvDiffProblem(n=8, p=2, rho=0.9, seed=1)
    cfg = dataclasses.replace(stable_platform(BASE), seed=1, max_iters=4000)
    res, rec = run_traced(prob_mk, cfg, lambda pr: NFAIS2(EPS, ord=pr.ord),
                          residual_stride=10)
    assert res.terminated
    assert rec.detect is not None
    assert rec.true_at_detect < 10 * EPS
    # trace sanity: 2 workers, monotone times, detect event present
    assert {e[2] for e in rec.events if e[0] == "sweep"} == {0, 1}
    ts = [e[1] for e in rec.events]
    assert ts == sorted(ts)


# ---------------------------------------------------------------------------
# Per-protocol invariant suite
# ---------------------------------------------------------------------------


LOSSLESS_FIFO_SCENARIOS = ("stable", "unstable", "burst", "straggler",
                           "pause_resume")


@pytest.mark.parametrize("scenario", LOSSLESS_FIFO_SCENARIOS)
@pytest.mark.parametrize("proto", ["nfais2", "exact"])
def test_exact_snapshot_protocols_never_false_detect(proto, scenario):
    """Consistent-cut residuals are exact for the recorded vector: under
    every lossless scenario (FIFO for the marker protocol), detection is
    never off by the oracle's decade factor."""
    spec = standard_scenarios(BASE)[scenario]
    mk = (lambda pr: ExactSnapshotFIFO(EPS, ord=pr.ord)) if proto == "exact" \
        else (lambda pr: NFAIS2(EPS, ord=pr.ord))
    res, rec = run_traced(lambda: _convdiff(0),
                          _cfg(spec, seed=0, fifo=(proto == "exact")), mk,
                          residual_stride=25)
    rep = detection_report(rec, EPS)
    assert not rep.false_detection
    if res.terminated:
        assert rep.detected_residual < EPS


def test_pfait_false_detects_under_blackout():
    """The constructed adversarial regime: interface data stops flowing,
    every worker converges to its frozen-boundary subproblem, PFAIT's live
    local residuals all drop below ε while the true global residual is
    orders of magnitude above — a false detection, deterministically."""
    spec = standard_scenarios(BASE)["blackout"]
    res, rec = run_traced(lambda: _convdiff(0), _cfg(spec, seed=0),
                          lambda pr: PFAIT(EPS, ord=pr.ord),
                          residual_stride=25)
    rep = detection_report(rec, EPS)
    assert res.terminated
    assert rep.detected_residual < EPS       # the protocol *claimed* success
    assert rep.false_detection               # ... and the claim is a lie
    assert rep.overshoot > 100.0
    assert res.msg_dropped.get("data", 0) > 0


def test_nfais2_survives_blackout_without_false_detection():
    """NFAIS2 snapshot messages carry the interface data, so its records
    stay consistent even on a lossy platform: it goes undetected rather
    than lying."""
    spec = standard_scenarios(BASE)["blackout"]
    res, rec = run_traced(lambda: _convdiff(0),
                          _cfg(spec, seed=0, max_iters=400),
                          lambda pr: NFAIS2(EPS, ord=pr.ord))
    rep = detection_report(rec, EPS)
    assert not res.terminated
    assert not rep.false_detection


def test_nfais5_error_bounded_by_slack():
    """NFAIS5's approximate records guarantee the true residual at
    detection within (1 + c(p, m))·ε on a platform that honours its
    staleness assumption."""
    for seed in range(3):
        def prob_mk(seed=seed):
            return _convdiff(seed)
        cfg = dataclasses.replace(stable_platform(BASE), seed=seed,
                                  max_iters=30_000)
        m = 4
        res, rec = run_traced(prob_mk, cfg,
                              lambda pr: NFAIS5(EPS, ord=pr.ord, m=m))
        assert res.terminated
        rep = detection_report(rec, EPS)
        prob = prob_mk()
        assert rep.true_at_detect <= nfais5_slack(prob.p, m) * EPS


# ---------------------------------------------------------------------------
# PageRank family under the lab
# ---------------------------------------------------------------------------


def test_pagerank_pfait_false_detects_under_blackout_too():
    spec = standard_scenarios(BASE)["blackout"]
    res, rec = run_traced(lambda: PageRankProblem(n=128, p=4, seed=0),
                          _cfg(spec, seed=0),
                          lambda pr: PFAIT(1e-8, ord=pr.ord),
                          residual_stride=25)
    rep = detection_report(rec, 1e-8)
    assert res.terminated and rep.false_detection


@pytest.mark.parametrize("proto_mk", [
    lambda pr: PFAIT(1e-8, ord=pr.ord),
    lambda pr: NFAIS2(1e-8, ord=pr.ord),
    lambda pr: NFAIS5(1e-8, ord=pr.ord, m=4),
])
def test_pagerank_sound_detection_on_stable_platform(proto_mk):
    spec = standard_scenarios(BASE)["stable"]
    res, rec = run_traced(lambda: PageRankProblem(n=128, p=4, seed=0),
                          _cfg(spec, seed=2), proto_mk, residual_stride=20)
    rep = detection_report(rec, 1e-8)
    assert res.terminated
    assert not rep.false_detection


# ---------------------------------------------------------------------------
# Platform-health wiring (runtime/fault_tolerance.py)
# ---------------------------------------------------------------------------


def test_health_flags_straggler_and_pause():
    specs = standard_scenarios(BASE)
    _, rec = run_traced(lambda: _convdiff(0), _cfg(specs["straggler"], seed=0),
                        lambda pr: PFAIT(EPS, ord=pr.ord))
    health = platform_health(rec, 4, BASE)
    assert 0 in health.stragglers

    _, rec = run_traced(lambda: _convdiff(0),
                        _cfg(specs["pause_resume"], seed=0),
                        lambda pr: PFAIT(EPS, ord=pr.ord))
    health = platform_health(rec, 4, BASE)
    assert health.silent_workers == (1,)
    assert health.max_silence >= 200 * BASE


def test_health_clean_on_stable_platform():
    spec = standard_scenarios(BASE)["stable"]
    _, rec = run_traced(lambda: _convdiff(0), _cfg(spec, seed=0),
                        lambda pr: PFAIT(EPS, ord=pr.ord))
    health = platform_health(rec, 4, BASE)
    assert health.silent_workers == ()
    assert health.stragglers == ()


# ---------------------------------------------------------------------------
# DelayModel construction-time validation (satellite fix)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    {"base": -1e-3},
    {"base": 0.0},
    {"base": float("nan")},
    {"base": 1e-3, "sigma": -0.5},
    {"base": 1e-3, "floor": -1.0},
    {"base": 1e-3, "dist": "cauchy"},
    {"base": 1e-3, "dist": "pareto", "shape": 0.0},
    {"base": 1e-3, "dist": "pareto", "shape": -2.0},
])
def test_delay_model_rejects_bad_params_at_construction(kw):
    with pytest.raises(ValueError):
        DelayModel(**kw)


def test_delay_model_valid_families_sample_positive():
    rng = np.random.default_rng(0)
    for dm in (DelayModel(1e-3), DelayModel(1e-3, dist="pareto", shape=1.2),
               DelayModel(1e-3, dist="fixed")):
        s = dm.sample(rng)
        assert s >= dm.floor and np.isfinite(s)
        v = dm.sample(rng, 16)
        assert np.all(v >= dm.floor) and np.all(np.isfinite(v))


# ---------------------------------------------------------------------------
# Backfill: wants_residual gating for ExactSnapshotFIFO (PR 1 flag)
# ---------------------------------------------------------------------------


def test_exact_snapshot_wants_residual_gating():
    """Once a worker's record is taken, the fused engine must stop
    evaluating its residual (protocol receives NaN) — and the protocol must
    still terminate correctly off the recorded cut."""
    calls = []

    class SpyExact(ExactSnapshotFIFO):
        def on_iteration(self, eng, i, t, r_i):
            calls.append((i, self.rec_own[i] is not None, math.isnan(r_i)))
            super().on_iteration(eng, i, t, r_i)

    prob = _convdiff(0)
    cfg = dataclasses.replace(stable_platform(BASE), seed=0, fifo=True,
                              max_iters=30_000, fused=True)
    proto = SpyExact(EPS, ord=prob.ord)
    res = AsyncEngine(prob, cfg, proto).run()
    assert res.terminated
    recorded_calls = [c for c in calls if c[1]]
    assert recorded_calls, "no post-record iterations observed"
    # every post-record iteration was gated to NaN...
    assert all(nan for _, _, nan in recorded_calls)
    # ...and no unrecorded worker ever got a gated residual
    assert all(not nan for _, rec, nan in calls if not rec)


def test_grace_path_returns_undetected_with_trace_intact():
    """Backfill: the engine's no-hang grace window (all workers at
    max_iters, no detection) must return undetected AND leave a complete,
    scorable trace behind."""
    spec = standard_scenarios(BASE)["blackout"]
    res, rec = run_traced(lambda: _convdiff(0),
                          _cfg(spec, seed=0, max_iters=60),
                          lambda pr: NFAIS2(1e-12, ord=pr.ord),
                          residual_stride=10)
    assert not res.terminated
    assert res.k_max == 60
    assert rec.detect is None
    assert rec.result is res
    sweeps = rec.sweep_events()
    assert len(sweeps) == 4 * 60
    assert rec.residual_samples, "trajectory sampling survived the grace path"
    assert rec.events[-1][0] == "finish"
    rep = detection_report(rec, 1e-12)
    assert not rep.terminated and not rep.false_detection
