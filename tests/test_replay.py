"""Replay + calibration tests: monitor-replay parity with the device
monitor, self-replay exactness on a recorded device run, virtual-clock
structure (topology ordering, stragglers), cost/delay-model fitting, and
the ReductionMode registry edges.

All in-process on the session's single device (tests/conftest.py); the
multi-shard replay accuracy claims run in the gated ``replay-smoke`` CI
lane (benchmarks/bench_replay.py).
"""
import math

import numpy as np
import pytest

from repro.core import detection
from repro.core.reduction import REDUCTIONS, get_reduction
from repro.core.trace import Trace
from repro.sim.calibrate import (
    DEFAULT_HOP_FRACTION,
    fit_cost_model,
    fit_delay_model,
    ks_statistic,
)
from repro.sim.replay import (
    TOPOLOGIES,
    CostModel,
    WhatIf,
    predict_wall,
    replay,
    replay_monitor,
    visible_series,
    what_if_table,
)


def _synthetic_trace(p=8, rho=0.9, steps=120, eps=1e-4, staleness=2,
                     mode="pfait", reduction="nonblocking",
                     topology="flat", wall_s=1.0):
    tr = Trace("synthetic", p, {
        "reduction": reduction, "topology": topology,
        "monitor": {"mode": mode, "eps": eps, "eps_tilde": eps,
                    "staleness": staleness, "persistence": 4, "ord": 2.0,
                    "check_every": 1},
        "inner_sweeps": [1] * p, "halo_delay": [0] * p,
        "contrib_lag": [0] * p, "wall_s": wall_s, "outer_iters": steps,
        "synthetic_t": True,
    })
    for k in range(steps):
        tr.add("reduce", float(k + 1), step=k, residual=rho ** k)
    return tr


_COST = CostModel(sweep_s=1e-3, hop_s=5e-5, residual_pass_s=1e-3, p_ref=8)


# ---------------------------------------------------------------------------
# ReductionMode registry edges
# ---------------------------------------------------------------------------


def test_get_reduction_rejects_unknown_name():
    with pytest.raises(ValueError, match="reduction"):
        get_reduction("gossip")


def test_registry_topology_facts():
    assert set(REDUCTIONS) == {"blocking", "nonblocking", "rdoubling"}
    rd = get_reduction("rdoubling")
    assert rd.requires_power_of_two and rd.topology == "butterfly"
    assert rd.rounds_per_value(8) == 3
    with pytest.raises(ValueError, match="power-of-two"):
        rd.rounds_per_value(6)
    assert rd.usable_shard_count(4) and not rd.usable_shard_count(6)
    nb = get_reduction("nonblocking")
    assert nb.rounds_per_value(8) == 1 and nb.usable_shard_count(6)
    assert get_reduction("blocking").forces_zero_staleness


def test_shrink_to_fit_respects_power_of_two():
    from repro.runtime.elastic import shrink_to_fit

    # n=16: divisors 1,2,4,8,16.  rdoubling cannot use 6 or 3 survivors
    # beyond the largest power-of-two divisor below them.
    assert shrink_to_fit(16, 6, "nonblocking") == 4   # 6,5 don't divide 16
    assert shrink_to_fit(16, 6, "rdoubling") == 4
    assert shrink_to_fit(12, 6, "nonblocking") == 6
    assert shrink_to_fit(12, 6, "rdoubling") == 4     # 6 is not a power of 2
    with pytest.raises(ValueError, match="reduction"):
        shrink_to_fit(16, 4, "gossip")


# ---------------------------------------------------------------------------
# Monitor replay: parity with core.detection on the same series
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["pfait", "nfais5", "sync"])
@pytest.mark.parametrize("staleness", [0, 2])
def test_replay_monitor_matches_batched_monitor(mode, staleness):
    """The numpy mirror must land on the device monitor's exact step."""
    if mode == "sync":
        staleness = 0
    rng = np.random.default_rng(7)
    # contraction with noise: crosses eps, wobbles, then stays below
    series = 0.9 ** np.arange(160) * np.exp(0.3 * rng.standard_normal(160))
    eps = 1e-4
    # batched_monitor applies sigma to contributions: feed squares (ord=2)
    verdict = detection.batched_monitor(mode, series[None, :] ** 2,
                                        eps=[eps], staleness=[staleness],
                                        persistence=[4], ord=2.0)
    dev_step = int(verdict.detect_step[0, 0, 0, 0])
    dev_conv = bool(verdict.converged[0, 0, 0, 0])

    step, detected, _ = replay_monitor(series, mode, eps, eps, staleness, 4)
    assert (step is not None) == dev_conv
    if dev_conv:
        assert step == dev_step
        assert detected == pytest.approx(
            float(verdict.detected_residual[0, 0, 0, 0]), rel=1e-5)


def test_visible_series_flat_and_butterfly():
    series = np.arange(10, dtype=np.float64)
    flat = visible_series(series, "flat-nonblocking", K=2, p=4)
    assert np.isinf(flat[:2]).all()
    np.testing.assert_array_equal(flat[2:], series[:-2])

    # p=4 butterfly: R=2, value launched at 2*floor((k+1)/2)-2 visible at k
    bfly = visible_series(series, "butterfly", K=0, p=4)
    assert np.isinf(bfly[0])
    assert bfly[1] == series[0] and bfly[2] == series[0]
    assert bfly[3] == series[2] and bfly[4] == series[2]
    with pytest.raises(ValueError, match="power-of-two"):
        visible_series(series, "butterfly", K=0, p=6)


# ---------------------------------------------------------------------------
# Replay determinism + structure
# ---------------------------------------------------------------------------


def test_replay_is_deterministic():
    tr = _synthetic_trace()
    wi = WhatIf(p=64, topology="tree", stragglers={3: 2.5})
    a = replay(tr, _COST, wi)
    b = replay(tr, _COST, wi)
    assert a == b


def test_replay_requires_a_residual_series():
    tr = Trace("empty", 4, {"monitor": {"mode": "pfait", "eps": 1e-6}})
    with pytest.raises(ValueError, match="reduce-event"):
        replay(tr, _COST)


def test_whatif_validation():
    with pytest.raises(ValueError, match="topology"):
        WhatIf(topology="ring")
    with pytest.raises(ValueError, match="p="):
        WhatIf(p=0)
    with pytest.raises(ValueError, match="straggler"):
        WhatIf(stragglers={0: -1.0})


def test_staleness_moves_the_detection_step():
    """More pipeline depth → later detection (the paper's K-step lag),
    replayed from the same series."""
    v0 = replay(_synthetic_trace(staleness=0), _COST)
    v3 = replay(_synthetic_trace(staleness=3), _COST)
    assert v0.converged and v3.converged
    assert v3.predicted_detect_step == v0.predicted_detect_step + 3
    assert v3.staleness_steps == 3


def test_topology_wall_ordering():
    """Same trace, same constants: barriered topologies cannot be cheaper
    than flat non-blocking, and blocking also pays the residual pass."""
    tr = _synthetic_trace()
    walls = {t: replay(tr, _COST, WhatIf(topology=t)).predicted_wall_s
             for t in TOPOLOGIES}
    assert walls["flat-nonblocking"] < walls["tree"]
    assert walls["tree"] < walls["flat-blocking"]
    assert walls["flat-nonblocking"] < walls["butterfly"]


def test_straggler_slows_the_whole_clock():
    tr = _synthetic_trace()
    base = replay(tr, _COST).predicted_wall_s
    slow = replay(tr, _COST,
                  WhatIf(stragglers={0: 4.0})).predicted_wall_s
    assert slow > base * 1.5   # neighbour coupling drags everyone


def test_shard_scaling_shrinks_per_step_compute():
    """p_ref/p scaling: 4x the shards ≈ 1/4 the compute per step on the
    non-blocking path (same step count — the series is held invariant)."""
    tr = _synthetic_trace()
    w8 = replay(tr, _COST, WhatIf(p=8)).predicted_wall_s
    w32 = replay(tr, _COST, WhatIf(p=32)).predicted_wall_s
    assert w32 < w8
    v8 = replay(tr, _COST, WhatIf(p=8))
    v32 = replay(tr, _COST, WhatIf(p=32))
    assert v8.predicted_detect_step == v32.predicted_detect_step


def test_butterfly_source_self_replay_not_approximate():
    tr = _synthetic_trace(reduction="rdoubling", topology="butterfly",
                          staleness=0)
    v = replay(tr, _COST)
    assert v.topology == "butterfly" and not v.approximate
    # conversion away from the baked-in staleness is flagged
    v2 = replay(tr, _COST, WhatIf(topology="flat-nonblocking"))
    assert v2.approximate


def test_what_if_table_skips_non_power_of_two_butterfly():
    tr = _synthetic_trace()
    rows = what_if_table(tr, _COST, [6, 8])
    topos = {(r["p"], r["topology"]) for r in rows}
    assert (8, "butterfly") in topos
    assert (6, "butterfly") not in topos
    assert (6, "tree") in topos


def test_predict_wall_zero_steps_is_free():
    assert predict_wall(0, 4, np.ones(4), np.zeros(4, np.int64),
                        np.ones(4), _COST, "flat-nonblocking") == 0.0


# ---------------------------------------------------------------------------
# Self-replay on a real recorded device run (1 shard)
# ---------------------------------------------------------------------------


def test_device_self_replay_is_exact_on_detect_step():
    from repro.launch.mesh import make_shard_mesh
    from repro.runtime import api
    from repro.solvers.convdiff import Stencil, make_rhs

    n = 8
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    b = make_rhs(n, seed=0)
    mon = detection.for_mode("pfait", eps_tilde=1e-6, staleness=2)
    cfg = api.RuntimeConfig(monitor=mon, reduction="nonblocking",
                            max_outer=500, record_trace=True)
    rep = api.run_shard("convdiff", cfg, make_shard_mesh(1), n,
                        np.zeros_like(b), b, stencil=st)
    assert rep.converged

    cost, report = fit_cost_model(rep.trace)
    v = replay(rep.trace, cost)
    assert v.converged
    assert v.predicted_detect_step == rep.detect_step
    assert v.staleness_steps == 2
    assert not v.approximate
    # self-replay wall reproduces the calibrating wall by construction
    assert v.predicted_wall_s == pytest.approx(
        rep.trace.meta["wall_s"], rel=0.02)
    assert report["p_ref"] == 1 and "hop_s" in report["defaulted"]


# ---------------------------------------------------------------------------
# Calibration fits
# ---------------------------------------------------------------------------


def test_fit_cost_model_inverts_predict_wall():
    """Closed-form round trip: a synthetic trace whose wall was produced
    by predict_wall's own structural model recovers sweep_s exactly."""
    for reduction, topology in (("nonblocking", "flat-nonblocking"),
                                ("blocking", "flat-blocking")):
        p, steps, sweep_s = 4, 50, 2e-3
        f = DEFAULT_HOP_FRACTION
        cost0 = CostModel(sweep_s=sweep_s, hop_s=f * sweep_s,
                          residual_pass_s=sweep_s, p_ref=p)
        wall = predict_wall(steps, p, np.ones(p), np.zeros(p, np.int64),
                            np.ones(p), cost0, topology)
        tr = _synthetic_trace(p=p, steps=steps, reduction=reduction,
                              wall_s=wall)
        fit, _ = fit_cost_model(tr)
        assert fit.sweep_s == pytest.approx(cost0.sweep_s, rel=0.02), \
            reduction
        assert fit.hop_s == pytest.approx(cost0.hop_s, rel=0.02)


def _skewed_engine_trace(p=4, steps=40, base=1e-3, skew=0.5, wall_s=None):
    """An engine-style trace whose per-worker sweep timestamps carry real
    skew: worker w's gap is ``base * (1 + skew * w / (p - 1))``."""
    gaps = base * (1.0 + skew * np.arange(p) / max(p - 1, 1))
    wall = float(wall_s if wall_s is not None else steps * gaps.max())
    tr = Trace("engine", p, {
        "reduction": "nonblocking", "topology": "flat",
        "monitor": {"mode": "pfait", "eps": 1e-4, "eps_tilde": 1e-4,
                    "staleness": 2, "persistence": 4, "ord": 2.0,
                    "check_every": 1},
        "inner_sweeps": [1] * p, "halo_delay": [0] * p,
        "contrib_lag": [0] * p, "wall_s": wall, "outer_iters": steps,
    })
    for k in range(steps):
        for w in range(p):
            tr.add("sweep", float((k + 1) * gaps[w]), w=w, step=k, inner=1)
        tr.add("reduce", float((k + 1) * gaps.max()), step=k,
               residual=0.9 ** k)
    return tr, gaps


def test_fit_cost_model_per_worker_rates_from_skewed_trace():
    """Engine traces with real per-worker timestamps resolve the skew: the
    fitted per-worker vector is sweep_s scaled by each worker's unit-mean
    gap ratio, and its mean stays the scalar sweep_s."""
    p = 4
    tr, gaps = _skewed_engine_trace(p=p)
    cost, report = fit_cost_model(tr)
    assert cost.sweep_s_per_worker is not None
    spw = np.asarray(cost.sweep_s_per_worker)
    rho = gaps / gaps.mean()
    np.testing.assert_allclose(spw, cost.sweep_s * rho, rtol=1e-9)
    assert np.mean(spw) == pytest.approx(cost.sweep_s, rel=1e-9)
    np.testing.assert_allclose(report["worker_rate_ratio"], rho, rtol=1e-9)
    assert report["sweep_s_per_worker"] == pytest.approx(list(spw))


def test_fit_cost_model_uniform_trace_keeps_scalar_model():
    # no sweep events at all (reduce-only synthetic trace) -> scalar
    tr = _synthetic_trace()
    cost, report = fit_cost_model(tr)
    assert cost.sweep_s_per_worker is None
    assert report["worker_rate_ratio"] is None
    # device-style uniform interpolation (identical gaps per worker) is
    # unresolvable skew by construction -> scalar too
    tr2, _ = _skewed_engine_trace(skew=0.0)
    cost2, _ = fit_cost_model(tr2)
    assert cost2.sweep_s_per_worker is None


def test_cost_model_sweep_vec_scales_and_gates_on_p():
    cost = CostModel(sweep_s=2e-3, hop_s=1e-4, residual_pass_s=2e-3,
                     p_ref=4, sweep_s_per_worker=(1e-3, 2e-3, 3e-3, 2e-3))
    vec = cost.sweep_vec_at(4)
    np.testing.assert_allclose(vec, [1e-3, 2e-3, 3e-3, 2e-3])
    # halving the per-shard work at p=8... but the fit no longer matches
    # the worker count, so the vector gates off and scalar scaling applies
    assert cost.sweep_vec_at(8) is None
    assert cost.sweep_at(8) == pytest.approx(1e-3)
    with pytest.raises(ValueError, match="sweep_s_per_worker"):
        CostModel(sweep_s=1e-3, hop_s=1e-4, residual_pass_s=1e-3, p_ref=2,
                  sweep_s_per_worker=(1e-3, -1e-3))


def test_predict_wall_consumes_per_worker_vector():
    """With halo deps pushed out of reach (huge delay), the virtual clock
    is exactly steps x the slowest worker's sweep cost."""
    p, steps = 2, 10
    cost = CostModel(sweep_s=2e-3, hop_s=0.0, residual_pass_s=0.0, p_ref=p,
                     sweep_s_per_worker=(1e-3, 3e-3))
    wall = predict_wall(steps, p, np.ones(p), np.full(p, 10 * steps),
                        np.ones(p), cost, "flat-nonblocking")
    assert wall == pytest.approx(steps * 3e-3)
    # scalar model on the same inputs: every worker pays the mean cost
    scalar = CostModel(sweep_s=2e-3, hop_s=0.0, residual_pass_s=0.0, p_ref=p)
    wall_s = predict_wall(steps, p, np.ones(p), np.full(p, 10 * steps),
                          np.ones(p), scalar, "flat-nonblocking")
    assert wall_s == pytest.approx(steps * 2e-3)


def test_fit_cost_model_needs_a_wall():
    tr = _synthetic_trace()
    tr.meta["wall_s"] = 0.0
    with pytest.raises(ValueError, match="wall"):
        fit_cost_model(tr)


def test_fit_delay_model_recovers_lognormal():
    rng = np.random.default_rng(0)
    base, sigma = 2e-3, 0.3
    samples = base * np.exp(sigma * rng.standard_normal(400))
    model, report = fit_delay_model(samples, dist="lognormal")
    assert model.base == pytest.approx(base, rel=0.05)
    assert model.sigma == pytest.approx(sigma, rel=0.15)
    assert report["ok"], report   # KS accepts its own generating family


def test_fit_delay_model_rejects_bad_input():
    with pytest.raises(ValueError, match="samples"):
        fit_delay_model([1e-3])
    with pytest.raises(ValueError, match="> 0"):
        fit_delay_model([1e-3, -1e-3])
    with pytest.raises(ValueError, match="dist"):
        fit_delay_model([1e-3, 2e-3], dist="gamma")


def test_ks_statistic_bounded_by_discretisation_on_own_ecdf():
    x = np.linspace(0.1, 1.0, 10)
    # the right-continuous ECDF of the same points differs from the
    # step-function comparison by at most one step height 1/n
    ks = ks_statistic(x, lambda v: np.searchsorted(x, v, "right") / x.size)
    assert ks <= 1.0 / x.size + 1e-12


def test_engine_config_from_fit_scales_channel():
    from repro.sim.calibrate import engine_config_from_fit

    model, _ = fit_delay_model([1e-3, 1.1e-3, 0.9e-3, 1.05e-3])
    cfg = engine_config_from_fit(model)
    assert cfg.compute.base == model.base
    assert cfg.channel.base == pytest.approx(
        max(model.base * DEFAULT_HOP_FRACTION, model.floor))


def test_fit_round_trips_into_whatif_consistency():
    """The calibrate → replay loop is self-consistent: predicting the
    calibrating configuration itself reproduces the measured wall."""
    p, steps = 4, 80
    tr = _synthetic_trace(p=p, steps=steps, wall_s=0.25)
    cost, _ = fit_cost_model(tr)
    v = replay(tr, cost)
    expected = 0.25 * (v.predicted_outer_iters / steps)
    assert v.predicted_wall_s == pytest.approx(expected, rel=0.03)
    assert math.isfinite(v.predicted_wall_s)
