"""TPU-native convergence monitor: staleness ring + the four modes."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import detection, termination


def run_monitor(cfg, series):
    st = detection.init_state(cfg)
    fired_at = None
    for i, v in enumerate(series):
        st = detection.step(cfg, st, jnp.float32(v),
                            exact_residual_fn=lambda v=v: jnp.float32(v))
        if fired_at is None and bool(st.converged):
            fired_at = i
    return st, fired_at


def test_sync_fires_immediately():
    cfg = detection.MonitorConfig(mode="sync", eps=1.0, ord=1.0, staleness=0)
    series = [5.0, 3.0, 0.5, 0.1]
    _, fired = run_monitor(cfg, series)
    assert fired == 2  # first value < 1.0


@pytest.mark.parametrize("K", [1, 2, 4])
def test_pfait_fires_exactly_K_late_on_monotone_series(K):
    cfg = detection.MonitorConfig(mode="pfait", eps=1.0, ord=1.0, staleness=K)
    series = [5.0, 3.0, 0.5, 0.2, 0.1, 0.05, 0.02, 0.01, 0.005]
    _, fired = run_monitor(cfg, series)
    assert fired == 2 + K  # value at index 2 becomes visible K steps later


def test_pfait_detected_residual_is_the_stale_value():
    cfg = detection.MonitorConfig(mode="pfait", eps=1.0, ord=1.0, staleness=2)
    series = [5.0, 0.5, 0.4, 0.3, 0.2]
    st, fired = run_monitor(cfg, series)
    assert fired == 3
    assert float(st.detected_residual) == pytest.approx(0.5)


def test_nfais2_requires_persistence_and_exact_verification():
    cfg = detection.MonitorConfig(mode="nfais2", eps=1.0, eps_tilde=1.0,
                                  ord=1.0, staleness=0, persistence=3)
    # two sub-eps checks then a spike: no fire
    _, fired = run_monitor(cfg, [0.5, 0.5, 3.0, 0.5, 0.5])
    assert fired is None
    _, fired = run_monitor(cfg, [0.5, 0.5, 0.5, 0.5])
    assert fired == 2  # third consecutive check fires + verifies


def test_nfais2_exact_verification_rejects():
    cfg = detection.MonitorConfig(mode="nfais2", eps=1.0, eps_tilde=1.0,
                                  ord=1.0, staleness=0, persistence=2)
    st = detection.init_state(cfg)
    # stale value below eps but exact value above eps_tilde → reject
    for v in [0.5, 0.5, 0.5]:
        st = detection.step(cfg, st, jnp.float32(v),
                            exact_residual_fn=lambda: jnp.float32(5.0))
    assert not bool(st.converged)
    assert int(st.verifications) >= 1


def test_nfais5_two_phase_confirmation():
    cfg = detection.MonitorConfig(mode="nfais5", eps=1.0, ord=1.0,
                                  staleness=0, persistence=2)
    # needs persistence 2, then confirm window of 2 more, still below
    _, fired = run_monitor(cfg, [0.5] * 10)
    assert fired is not None and fired >= 3
    # convergence lost during confirmation window → no fire
    _, fired = run_monitor(cfg, [0.5, 0.5, 9.0, 9.0, 9.0, 9.0])
    assert fired is None


def test_monitor_is_jittable_inside_while_loop():
    cfg = detection.MonitorConfig(mode="pfait", eps=1e-3, ord=1.0, staleness=2)

    def solve():
        def body(state):
            mon, k, v = state
            mon = detection.step(cfg, mon, v)
            return mon, k + 1, v * 0.5

        def cond(state):
            mon, k, _ = state
            return (~mon.converged) & (k < 100)

        mon, k, _ = jax.lax.while_loop(
            cond, body, (detection.init_state(cfg), jnp.int32(0), jnp.float32(1.0))
        )
        return k

    k = jax.jit(solve)()
    assert 0 < int(k) < 100


def test_threshold_helpers():
    assert detection.pfait_threshold(1e-6, 10.0) == pytest.approx(1e-7)
    assert termination.decade_margin(2.9) == 10.0
    assert termination.decade_margin(12.0) == 100.0
    assert termination.decade_margin(0.5) == 1.0


def test_calibration_report():
    vals = iter([1.3e-6, 1.9e-6, 0.8e-6])
    rep = termination.calibrate_margin(lambda eps: next(vals), 1e-6, runs=3, safety=2.0)
    assert rep.max_r == pytest.approx(1.9e-6)
    assert rep.margin == 10.0
    assert rep.eps_production == pytest.approx(1e-7)
