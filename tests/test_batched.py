"""Batched detection sweeps: bitwise parity with the per-run monitor, and
batched problem entry points vs their per-worker numpy references.

The headline invariant (PR-3 acceptance): ``detection.batched_monitor``
verdicts — converged flag, detection step, detected residual bits — are
IDENTICAL to driving ``detection.step`` one configuration at a time over
the same contribution series.
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import detection
from repro.solvers.convdiff import ConvDiffProblem
from repro.solvers.pagerank import PageRankProblem

EPS_GRID = [3e-3, 1e-4]
K_GRID = [0, 1, 3]
M_GRID = [1, 2, 4]


def _series(S=3, T=160, seed=0):
    """Decaying contribution series with noise and eps-crossing jitter."""
    rng = np.random.default_rng(seed)
    base = np.exp(-0.06 * np.arange(T))[None, :]
    noise = 1.0 + 0.5 * rng.random((S, T))
    return (base * noise * 1e-1).astype(np.float32)


@partial(jax.jit, static_argnames=("cfg",))
def _reference_loop(cfg, series):
    """Per-run monitor over one config: scan of ``detection.step``."""

    def body(st, g):
        st2 = detection.step(cfg, st, g)
        return st2, st2.converged & ~st.converged

    st, newly = jax.lax.scan(body, detection.init_state(cfg), series)
    detect_step = jnp.where(newly.any(), jnp.argmax(newly), -1)
    return st.converged, detect_step.astype(jnp.int32), st.detected_residual


@pytest.mark.parametrize("mode", detection.MODES)
def test_batched_monitor_bitwise_matches_per_run_loop(mode):
    contribs = _series()
    v = detection.batched_monitor(
        mode, contribs, EPS_GRID, K_GRID, M_GRID, ord=2.0
    )
    for si in range(contribs.shape[0]):
        for ei, eps in enumerate(EPS_GRID):
            for ki, K in enumerate(K_GRID):
                for mi, m in enumerate(M_GRID):
                    cfg = detection.MonitorConfig(
                        mode=mode, eps=float(eps), eps_tilde=float(eps),
                        staleness=int(K), persistence=int(m), ord=2.0,
                    )
                    conv, dstep, detected = _reference_loop(
                        cfg, jnp.asarray(contribs[si])
                    )
                    lane = (si, ei, ki, mi)
                    assert bool(v.converged[lane]) == bool(conv), lane
                    assert int(v.detect_step[lane]) == int(dstep), lane
                    # bitwise: f32 payloads identical (inf == inf included)
                    a = np.float32(v.detected_residual[lane])
                    b = np.float32(detected)
                    assert a.tobytes() == b.tobytes(), (lane, a, b)


def test_batched_monitor_grid_covers_convergence_transition():
    """Sanity on the verdict structure: tighter ε detects later (or not at
    all), and every converged lane carries a finite detected residual."""
    contribs = _series(S=2, T=200, seed=3)
    v = detection.batched_monitor(
        "pfait", contribs, EPS_GRID, K_GRID, M_GRID, ord=2.0
    )
    conv = np.asarray(v.converged)
    dstep = np.asarray(v.detect_step)
    detected = np.asarray(v.detected_residual)
    assert conv.any(), "no lane converged — series too short for the grid"
    assert np.isfinite(detected[conv]).all()
    assert (dstep[conv] >= 0).all() and (dstep[~conv] == -1).all()
    # eps axis 1: EPS_GRID[0] > EPS_GRID[1] ⇒ looser detects no later
    both = conv[:, 0] & conv[:, 1]
    assert (dstep[:, 0][both] <= dstep[:, 1][both]).all()


def test_sync_mode_forces_zero_staleness_lanes():
    contribs = _series(S=1, T=80, seed=1)
    v = detection.batched_monitor(
        "sync", contribs, [1e-3], [0, 2, 5], [1], ord=2.0
    )
    # every K lane behaves as K=0 (MonitorConfig coerces sync to blocking)
    assert np.unique(np.asarray(v.detect_step)).size == 1


# ---------------------------------------------------------------------------
# batched problem entry points vs per-worker references
# ---------------------------------------------------------------------------


def test_convdiff_batched_step_matches_global_sweep_jacobi():
    prob = ConvDiffProblem(n=10, p=1, rho=0.9, seed=0, sweep="jacobi")
    rng = np.random.default_rng(0)
    X = rng.standard_normal((3, 10, 10, 10))
    Xn, contrib = prob.update_with_residual_batched(jnp.asarray(X))
    for b in range(3):
        ref_new, ref_r = prob.update_with_residual(0, X[b], {})
        assert np.allclose(np.asarray(Xn[b]), ref_new, atol=1e-12)
        assert np.isclose(float(contrib[b]), ref_r, rtol=1e-12)


def test_convdiff_batched_step_matches_global_sweep_hybrid():
    prob = ConvDiffProblem(n=8, p=1, rho=0.9, seed=1, sweep="hybrid")
    rng = np.random.default_rng(1)
    X = rng.standard_normal((2, 8, 8, 8))
    Xn, contrib = prob.update_with_residual_batched(jnp.asarray(X))
    for b in range(2):
        ref_new, ref_r = prob.update_with_residual(0, X[b].copy(), {})
        assert np.allclose(np.asarray(Xn[b]), ref_new, atol=1e-12)
        assert np.isclose(float(contrib[b]), ref_r, rtol=1e-12)


def test_convdiff_batched_seed_lanes_use_their_own_rhs():
    probs = [ConvDiffProblem(n=8, p=1, rho=0.9, seed=s) for s in (0, 1)]
    b = jnp.asarray(np.stack([p.b_global for p in probs]))
    X = jnp.zeros((2, 8, 8, 8))
    _, contrib = probs[0].update_with_residual_batched(X, b=b)
    for s, p in enumerate(probs):
        _, ref_r = p.update_with_residual(0, np.zeros((8, 8, 8)), {})
        assert np.isclose(float(contrib[s]), ref_r, rtol=1e-12)


def test_pagerank_batched_step_matches_global_apply():
    prob = PageRankProblem(n=64, p=1, seed=0)
    rng = np.random.default_rng(2)
    X = np.abs(rng.standard_normal((3, 64))) / 64
    Xn, contrib = prob.update_with_residual_batched(jnp.asarray(X))
    for b in range(3):
        ref_new, ref_r = prob.update_with_residual(0, X[b], {})
        assert np.allclose(np.asarray(Xn[b]), ref_new, atol=1e-12)
        assert np.isclose(float(contrib[b]), ref_r, rtol=1e-12)


def test_pagerank_batched_seed_lanes_with_stacked_graphs():
    probs = [PageRankProblem(n=64, p=1, seed=s) for s in (0, 1)]
    P = jnp.asarray(np.stack([p.to_dense() for p in probs]))
    X = jnp.full((2, 64), 1.0 / 64)
    _, contrib = probs[0].update_with_residual_batched(X, P=P)
    for s, p in enumerate(probs):
        _, ref_r = p.update_with_residual(0, np.full(64, 1.0 / 64), {})
        assert np.isclose(float(contrib[s]), ref_r, rtol=1e-12)


def test_contribution_series_matches_stepwise_loop():
    prob = PageRankProblem(n=64, p=1, seed=0)
    X0 = jnp.full((2, 64), 1.0 / 64)

    def step_fn(X):
        return prob.update_with_residual_batched(X)

    series = detection.contribution_series(step_fn, X0, T=10)
    assert series.shape == (2, 10)
    X, expect = X0, []
    for _ in range(10):
        X, c = step_fn(X)
        expect.append(np.asarray(c))
    assert np.allclose(np.asarray(series), np.stack(expect, axis=1), rtol=1e-12)


def test_detection_grid_feeds_batched_monitor_end_to_end():
    """Sweep-grid pipeline: problem scan → monitor grid, one device program
    per stage; detection tightens monotonically along the eps axis."""
    prob = ConvDiffProblem(n=8, p=1, rho=0.85, seed=0, sweep="jacobi")

    def step_fn(X):
        return prob.update_with_residual_batched(X)

    series = detection.contribution_series(
        step_fn, jnp.zeros((1, 8, 8, 8)), T=300
    )
    v = detection.batched_monitor(
        "pfait", series, [1e-3, 1e-5], [0, 2], [1], ord=prob.ord
    )
    conv = np.asarray(v.converged)[0]
    assert conv.all(), "contraction should cross both thresholds in 300 sweeps"
    dstep = np.asarray(v.detect_step)[0]
    assert (dstep[0] <= dstep[1]).all()  # looser eps fires no later
