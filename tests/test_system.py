"""End-to-end behaviour: train-until-target with each detection mode,
checkpoint/restart continuity, serving, and the paper's protocol ordering."""

import pytest

from repro.launch.serve import serve
from repro.launch.train import train


def test_train_loss_decreases():
    out = train("qwen2-1.5b", steps=25, batch=4, seq=64, use_reduced=True,
                log_every=1000)
    assert len(out["losses"]) >= 20
    assert out["losses"][-1] < out["losses"][0]


@pytest.mark.parametrize("mode", ["sync", "pfait"])
def test_train_until_target_loss(mode):
    # margin=1 detects at the target itself; the default margin=10 is the
    # PFAIT tightened-threshold convention (covered in test_train_loop.py)
    out = train("qwen2-1.5b", steps=120, batch=4, seq=64, use_reduced=True,
                target_loss=3.8, monitor_mode=mode, staleness=3, margin=1.0,
                log_every=1000)
    assert out["stop_step"] is not None, f"{mode} never fired"
    # the monitored (stale) loss must have crossed the target
    assert min(out["losses"]) < 3.8


def test_pfait_fires_later_than_sync_by_staleness():
    common = dict(steps=150, batch=4, seq=64, use_reduced=True,
                  target_loss=3.8, margin=1.0, log_every=1000, seed=1)
    sync = train("qwen2-1.5b", monitor_mode="sync", **common)
    pfait = train("qwen2-1.5b", monitor_mode="pfait", staleness=4, **common)
    assert sync["stop_step"] is not None and pfait["stop_step"] is not None
    # same data/model/seed → PFAIT fires exactly K steps after sync
    assert pfait["stop_step"] == sync["stop_step"] + 4


@pytest.mark.slow
def test_checkpoint_restart_continues(tmp_path):
    d = str(tmp_path / "ck")
    out1 = train("qwen2-1.5b", steps=30, batch=4, seq=64, use_reduced=True,
                 ckpt_dir=d, ckpt_every=10, log_every=1000, seed=2)
    assert out1["steps_run"] == 30
    # resume: should restore at step 20 and continue to 40
    out2 = train("qwen2-1.5b", steps=40, batch=4, seq=64, use_reduced=True,
                 ckpt_dir=d, ckpt_every=10, log_every=1000, seed=2)
    assert out2["steps_run"] == 40


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-130m", "hymba-1.5b"])
def test_serve_generates(arch):
    out = serve(arch, batch=2, prompt_len=12, max_new=6, use_reduced=True)
    assert out["tokens"].shape == (2, 6)
    assert out["steps"] >= 1


@pytest.mark.slow
def test_train_all_monitor_modes_run():
    for mode in ["sync", "pfait", "nfais2", "nfais5"]:
        out = train("qwen2-1.5b", steps=12, batch=2, seq=32, use_reduced=True,
                    target_loss=0.001, monitor_mode=mode, log_every=1000)
        assert out["steps_run"] >= 12  # target unreachable → runs to the end
