"""Model substrate: per-arch smokes, decode==full, plan invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCHS, get_arch
from repro.models import Model
from repro.models import layers as L
from repro.models.attention import plan_attention, q_valid_mask
from repro.models.transformer import forward
from repro.optim import AdamW, constant_schedule

B, S = 2, 32


def make_inputs(cfg, key):
    if cfg.frontend is None:
        return jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return jax.random.normal(key, (B, S, cfg.frontend_dim), jnp.float32)


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.slow
def test_arch_smoke_train_step(arch):
    """Reduced same-family config: one train step, finite loss/grads,
    correct output shapes, no NaNs."""
    cfg = reduced(get_arch(arch))
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    opt = AdamW(constant_schedule(1e-3))
    ts = m.init_train_state(key, opt)
    step_fn, _ = m.make_train_step(opt)
    batch = {
        "inputs": make_inputs(cfg, jax.random.PRNGKey(1)),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size),
    }
    ts2, metrics = jax.jit(step_fn)(ts, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    for leaf in jax.tree.leaves(ts2.params):
        assert not bool(jnp.any(jnp.isnan(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_forward_shapes(arch):
    cfg = reduced(get_arch(arch))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    x, head, _, aux = forward(params, make_inputs(cfg, jax.random.PRNGKey(1)),
                              m.plan, m._ctx("train"))
    assert x.shape == (B, S, cfg.d_model)
    logits = L.lm_head(x, head)
    assert logits.shape == (B, S, m.plan.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_decode_matches_full_forward(arch):
    cfg = reduced(get_arch(arch))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    inputs = make_inputs(cfg, jax.random.PRNGKey(1))
    x, head, _, _ = forward(params, inputs, m.plan, m._ctx("train"))
    full_logits = L.lm_head(x, head)
    prefill = jax.jit(m.make_prefill())
    decode = jax.jit(m.make_decode_step())
    _, cache = prefill(params, inputs[:, : S - 1])

    def extend(u):
        out = []
        for entry in u:
            e = {}
            for k2, v2 in entry.items():
                if k2 == "kv":
                    e["kv"] = {kk: jnp.pad(vv, ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0)))
                               for kk, vv in v2.items()}
                else:
                    e[k2] = v2
            out.append(e)
        return tuple(out)

    cache = extend(cache)
    last = inputs[:, S - 1:] if cfg.frontend is None else inputs[:, S - 1:, :]
    dl, _ = decode(params, cache, last, jnp.int32(S - 1))
    np.testing.assert_allclose(
        np.asarray(dl[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        atol=5e-2, rtol=1e-2,
    )


# ---------------------------------------------------------------------------
# Attention TP plan invariants (all 10 archs at the production TP width)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", sorted(a for a in ARCHS if get_arch(a).has_attention))
def test_attention_plan_preserves_gqa_mapping_at_tp16(arch):
    cfg = get_arch(arch)
    p = plan_attention(cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, 16)
    assert p.slots % 16 == 0
    assert p.q_heads_padded % 16 == 0
    assert p.q_heads_padded >= cfg.num_heads
    # every original q head lands in a slot holding a copy of ITS kv group
    for h in range(cfg.num_heads):
        slot, pos = p.q_slot_pos(h)
        assert 0 <= pos < p.q_per_slot
        assert p.kv_slot_group(slot) == h // (cfg.num_heads // cfg.num_kv_heads)
    # mask marks exactly the original heads
    mask = np.asarray(q_valid_mask(p))
    assert int(mask.sum()) == cfg.num_heads


def test_q_padding_is_neutral():
    """Padded q heads must not affect outputs (zero wo rows)."""
    cfg = reduced(get_arch("qwen2-1.5b"))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    inputs = make_inputs(cfg, jax.random.PRNGKey(1))
    x1, head, _, _ = forward(params, inputs, m.plan, m._ctx("train"))

    # corrupt the padded wq positions wildly: outputs must be unchanged
    qmask = np.asarray(q_valid_mask(m.plan.attn))  # [slots, qps]
    def corrupt(unit):
        unit = dict(unit)
        a = dict(unit["attn"])
        noise = 37.0 * (1.0 - qmask)[None, None, :, :, None]
        a["wq"] = a["wq"] + noise.astype(a["wq"].dtype)
        unit["attn"] = a
        return unit

    params2 = dict(params)
    params2["layers"] = tuple(corrupt(u) for u in params["layers"])
    x2, _, _, _ = forward(params2, inputs, m.plan, m._ctx("train"))
    np.testing.assert_allclose(np.asarray(x1, np.float32),
                               np.asarray(x2, np.float32), atol=1e-5)


def test_grad_fixups_tie_kv_and_mask_padding():
    cfg = reduced(get_arch("qwen2-1.5b"), num_heads=4, num_kv_heads=2, head_dim=16)
    # force a replicated-kv plan by constructing at tp>1 via plan override
    from repro.models.transformer import make_plan
    m = Model(cfg)
    m.plan = make_plan(cfg, tp=4)  # kv=2 < tp=4 → repl=2
    assert m.plan.attn.kv_repl == 2
    params = m.init(jax.random.PRNGKey(0))
    grads = jax.tree.map(lambda p: jnp.ones_like(p), params)
    fixed = m.apply_grad_fixups(grads)
    for u in fixed["layers"]:
        wk = np.asarray(u["attn"]["wk"], np.float32)
        s = wk.shape
        wkr = wk.reshape(s[0], s[1], m.plan.attn.groups, m.plan.attn.kv_repl, s[3])
        # replicas carry identical (summed) gradients
        np.testing.assert_allclose(wkr[:, :, :, 0], wkr[:, :, :, 1])
        # padded wo rows zeroed
        qmask = np.asarray(q_valid_mask(m.plan.attn))
        wo = np.asarray(u["attn"]["wo"], np.float32)
        assert np.all(wo[:, qmask == 0] == 0)  # [steps, slots, qps, H, D]


@pytest.mark.slow
def test_microbatched_train_step_matches_plain():
    cfg = reduced(get_arch("qwen2-1.5b"))
    m = Model(cfg)
    opt = AdamW(constant_schedule(1e-3))
    batch = {
        "inputs": make_inputs(cfg, jax.random.PRNGKey(1)),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size),
    }
    ts = m.init_train_state(jax.random.PRNGKey(0), opt)
    s1, _ = m.make_train_step(opt, microbatches=1)
    s2, _ = m.make_train_step(opt, microbatches=2)
    t1, m1 = jax.jit(s1)(ts, batch)
    ts_b = m.init_train_state(jax.random.PRNGKey(0), opt)
    t2, m2 = jax.jit(s2)(ts_b, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-3)
    for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-2)
