"""Train-loop detection wiring: the ε̃/margin convention, straggler
timing, bitwise monitor-ring checkpointing, oracle-consistent firing, and
the data/optimizer bugfix regressions."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import reduced as reduced_cfg
from repro.configs.registry import get_arch
from repro.core import detection
from repro.data.pipeline import DataConfig, Prefetcher, synth_batch
from repro.launch.train import train
from repro.models import Model
from repro.optim import AdamW, constant_schedule


def _replay_fire_step(losses, eps, K, mode, m=4):
    """Host replay of core/detection.step on a recorded metric series:
    the step the monitor must fire at (visible value is K-stale)."""
    persist = 0
    for k in range(len(losses)):
        vis = losses[k - K] if k >= K else float("inf")
        below = vis < eps
        if mode in ("sync", "pfait"):
            if below:
                return k
        else:   # nfais2, no external verifier: stale-value fallback
            persist = persist + 1 if below else 0
            if persist >= m:
                return k
    return None


# ---------------------------------------------------------------------------
# Satellite 1 + 3: threshold convention and straggler timing
# ---------------------------------------------------------------------------


def test_pfait_monitor_uses_tightened_threshold():
    """Regression: train() must route through detection.for_mode — PFAIT
    detects at ε = ε̃ / margin, not at ε̃ itself."""
    out = train("qwen2-1.5b", steps=8, batch=2, seq=32, use_reduced=True,
                target_loss=2.0, monitor_mode="pfait", staleness=2,
                log_every=1000)
    mon = out["monitor"]
    assert mon.eps == pytest.approx(mon.eps_tilde / 10.0)
    assert mon.eps == pytest.approx(2.0 / 10.0)
    # non-default margin respected; sync detects at ε̃ itself
    out = train("qwen2-1.5b", steps=2, batch=2, seq=32, use_reduced=True,
                target_loss=2.0, monitor_mode="pfait", margin=100.0,
                log_every=1000)
    assert out["monitor"].eps == pytest.approx(2.0 / 100.0)
    out = train("qwen2-1.5b", steps=2, batch=2, seq=32, use_reduced=True,
                target_loss=2.0, monitor_mode="sync", log_every=1000)
    assert out["monitor"].eps == pytest.approx(2.0)


def test_straggler_records_nontrivial_step_durations():
    """Regression: timing the async dispatch measured ~0 ms; durations
    must now reflect step wall time (recorded at the metric-fetch point)."""
    out = train("qwen2-1.5b", steps=10, batch=2, seq=32, use_reduced=True,
                log_every=1000)
    recorded = out["stragglers"]._hist.get(0, [])
    assert len(recorded) >= 8
    # a reduced-arch transformer step on CPU is far above dispatch latency
    assert float(np.median(recorded)) > 1e-3
    assert all(d > 0 for d in recorded)


# ---------------------------------------------------------------------------
# Satellite 5: e2e detection behaviour of the loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,staleness", [("sync", 0), ("pfait", 3),
                                            ("nfais2", 3)])
def test_monitor_fires_at_oracle_consistent_step(mode, staleness):
    """The firing step must equal a host replay of the detection logic on
    the recorded loss series (margin=1 so every mode targets the same ε)."""
    out = train("qwen2-1.5b", steps=120, batch=4, seq=64, use_reduced=True,
                target_loss=3.8, monitor_mode=mode, staleness=staleness,
                margin=1.0, log_every=1000)
    assert out["stop_step"] is not None, f"{mode} never fired"
    expected = _replay_fire_step(out["losses"], 3.8, staleness, mode,
                                 m=out["monitor"].persistence)
    assert out["stop_step"] == expected


def test_checkpoint_restores_monitor_ring_bitwise(tmp_path):
    """The PFAIT ring is part of training state: restore must resume the
    stale-reduction pipeline bitwise, not re-init it."""
    cfg = reduced_cfg(get_arch("qwen2-1.5b"))
    model = Model(cfg)
    opt = AdamW(constant_schedule(1e-3))
    monitor = detection.for_mode("pfait", eps_tilde=3.8, staleness=3,
                                 persistence=4, ord=1.0)
    step_fn, _ = model.make_train_step(opt, monitor=monitor)
    step_fn = jax.jit(step_fn)
    state = model.init_train_state(jax.random.PRNGKey(0), opt,
                                   monitor=monitor)
    dc = DataConfig(seed=0, vocab_size=cfg.vocab_size)
    for step in range(6):
        batch = {k: jnp.asarray(v)
                 for k, v in synth_batch(dc, step, 2, 32).items()}
        state, _ = step_fn(state, batch)
    ring = np.asarray(state.monitor.ring)
    assert np.isfinite(ring).sum() >= monitor.ring_len  # ring fully primed

    ckpt = Checkpointer(str(tmp_path / "ck"))
    ckpt.save(state, 6)
    ckpt.wait()
    restored, step = ckpt.restore(like=state)
    assert step == 6
    np.testing.assert_array_equal(np.asarray(restored.monitor.ring), ring)
    for leaf, ref in zip(jax.tree.leaves(restored.monitor),
                         jax.tree.leaves(state.monitor)):
        np.testing.assert_array_equal(np.asarray(leaf), np.asarray(ref))


# ---------------------------------------------------------------------------
# Satellite 2: data pipeline regressions
# ---------------------------------------------------------------------------


def test_synth_batch_token_labels_shifted_once_and_masked():
    dc = DataConfig(seed=0, vocab_size=128)
    b = synth_batch(dc, step=0, batch=3, seq=16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["inputs"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()   # wraparound carries no target
    assert b["labels"].dtype == np.int32


def test_synth_batch_frontend_labels_are_plain_random():
    dc = DataConfig(seed=0, vocab_size=64, frontend_dim=8)
    b = synth_batch(dc, step=0, batch=4, seq=32)
    assert b["inputs"].shape == (4, 32, 8)
    labels = b["labels"]
    assert labels.shape == (4, 32)
    assert labels.min() >= 0 and labels.max() < 64   # none masked, in-range
    # not a rolled copy of anything: rolling changes the sequence
    assert not np.array_equal(labels, np.roll(labels, -1, axis=-1))


def test_prefetcher_stops_iteration_after_close():
    pf = Prefetcher(lambda step: step * 10, depth=2)
    step, item = next(pf)
    assert item == step * 10
    pf.close()
    with pytest.raises(StopIteration):
        for _ in range(8):   # drain whatever was buffered, then stop
            next(pf)


def test_prefetcher_surfaces_producer_death():
    def boom(step):
        if step >= 2:
            raise RuntimeError("synthetic producer failure")
        return step

    pf = Prefetcher(boom, depth=1)
    with pytest.raises((RuntimeError, StopIteration)) as exc_info:
        for _ in range(8):
            next(pf)
    if exc_info.type is RuntimeError:
        assert "producer" in str(exc_info.value)
    pf.close()


def test_prefetcher_is_deterministic_and_ordered():
    pf = Prefetcher(lambda step: step * step, start_step=5, depth=2)
    got = [next(pf) for _ in range(4)]
    pf.close()
    assert got == [(5, 25), (6, 36), (7, 49), (8, 64)]


# ---------------------------------------------------------------------------
# Satellite 4: AdamW contract
# ---------------------------------------------------------------------------


def test_adamw_update_returns_triple_with_bf16_moments():
    opt = AdamW(constant_schedule(1e-2), moment_dtype="bfloat16")
    params = {"w": jnp.ones((4, 3), jnp.float32),
              "b": jnp.zeros((3,), jnp.bfloat16)}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    assert state.v["b"].dtype == jnp.bfloat16
    grads = jax.tree.map(lambda p: jnp.full(p.shape, 0.5, p.dtype), params)
    out = opt.update(grads, state, params)
    assert isinstance(out, tuple) and len(out) == 3
    updates, new_state, gnorm = out
    # annotation contract: (updates, AdamState, gnorm)
    hints = AdamW.update.__annotations__["return"]
    assert "AdamState" in str(hints) and str(hints).count(",") >= 2
    for k in params:
        assert updates[k].shape == params[k].shape
        assert updates[k].dtype == params[k].dtype
        assert new_state.m[k].dtype == jnp.bfloat16
        assert new_state.v[k].dtype == jnp.bfloat16
    assert gnorm.shape == () and gnorm.dtype == jnp.float32
    assert int(new_state.step) == 1
    assert float(gnorm) > 0


def test_adamw_bf16_moments_accumulate_in_f32():
    """Moment math happens in f32 then casts back: repeated identical
    grads drive m toward g without bf16 stagnation at the first step."""
    opt = AdamW(constant_schedule(1e-2), b1=0.5, moment_dtype="bfloat16",
                clip_norm=1e9)
    params = {"w": jnp.ones((8,), jnp.float32)}
    state = opt.init(params)
    g = {"w": jnp.full((8,), 0.125, jnp.float32)}
    for _ in range(20):
        _, state, _ = opt.update(g, state, params)
    m = np.asarray(state.m["w"], np.float32)
    np.testing.assert_allclose(m, 0.125, rtol=0.02)
