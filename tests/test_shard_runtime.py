"""Shard-runtime tests: config validation, single-shard parity against the
reference drivers, detection-mode semantics, and (subprocess) the real
multi-device paths the in-process session cannot host.

The pytest session runs on ONE device (tests/conftest.py), so in-process
tests use a 1-shard mesh — which still exercises the full ring/monitor
machinery (ppermute on a single rank delivers the boundary zeros).  The
genuinely multi-device behaviours (halo exchange between ranks, butterfly
partners, psum lanes) run in a forced-4-device subprocess, marked
``slow``; the shard-runtime CI lane covers them at full size.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import detection
from repro.launch.mesh import make_shard_mesh, shard_axis_of
from repro.runtime import shard_runtime as sr
from repro.solvers.convdiff import Stencil, make_rhs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mon(mode="sync", eps=1e-7, staleness=0, ord=2.0, persistence=4):
    return detection.MonitorConfig(mode=mode, eps=eps, staleness=staleness,
                                   ord=ord, persistence=persistence)


# ---------------------------------------------------------------------------
# Config / mesh validation
# ---------------------------------------------------------------------------


def test_config_rejects_unknown_reduction():
    with pytest.raises(ValueError, match="reduction"):
        sr.ShardRuntimeConfig(monitor=_mon(), reduction="psum")


def test_config_rejects_unknown_sweep():
    with pytest.raises(ValueError, match="sweep"):
        sr.ShardRuntimeConfig(monitor=_mon(), sweep="sor")


def test_blocking_mode_forbids_staleness_knobs():
    mesh = make_shard_mesh(1)
    st = Stencil.for_contraction(8, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    cfg = sr.ShardRuntimeConfig(monitor=_mon(), reduction="blocking",
                                halo_delay=1)
    with pytest.raises(ValueError, match="blocking"):
        sr.make_convdiff_runtime(cfg, mesh, st, 8)


def test_per_shard_params_validated():
    mesh = make_shard_mesh(1)
    st = Stencil.for_contraction(8, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    cfg = sr.ShardRuntimeConfig(monitor=_mon(), inner_sweeps=(1, 2))
    with pytest.raises(ValueError, match="inner_sweeps"):
        sr.make_convdiff_runtime(cfg, mesh, st, 8)
    cfg0 = sr.ShardRuntimeConfig(monitor=_mon(), inner_sweeps=0)
    with pytest.raises(ValueError, match="inner_sweeps"):
        sr.make_convdiff_runtime(cfg0, mesh, st, 8)


def test_effective_monitor_forces_staleness():
    mon = _mon(mode="pfait", staleness=3)
    blocking = sr.ShardRuntimeConfig(monitor=mon, reduction="blocking")
    assert blocking.effective_monitor().staleness == 0
    rd = sr.ShardRuntimeConfig(monitor=mon, reduction="rdoubling")
    assert rd.effective_monitor().staleness == 0
    nb = sr.ShardRuntimeConfig(monitor=mon, reduction="nonblocking")
    assert nb.effective_monitor().staleness == 3


def test_rdoubling_requires_power_of_two_shards():
    with pytest.raises(ValueError, match="power-of-two"):
        sr._butterfly_rounds(3)
    assert sr._butterfly_rounds(1) == 0
    assert sr._butterfly_rounds(8) == 3


def test_make_shard_mesh_validates():
    with pytest.raises(ValueError, match="exceeds"):
        make_shard_mesh(len(jax.devices()) + 1)
    with pytest.raises(ValueError, match=">= 1"):
        make_shard_mesh(0)
    mesh = make_shard_mesh(1)
    assert shard_axis_of(mesh) == "shard"


def test_shard_axis_of_rejects_2d_mesh():
    from repro.launch.mesh import compat_make_mesh

    with pytest.raises(ValueError, match="1-D"):
        shard_axis_of(compat_make_mesh((1, 1), ("data", "model")))


def test_convdiff_runtime_requires_divisible_n():
    # a 2-shard mesh shape is enough to hit the (pre-shard_map) validation
    # without owning 2 devices
    import types

    mesh = types.SimpleNamespace(shape={"shard": 2})
    st = Stencil.for_contraction(9, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    cfg = sr.ShardRuntimeConfig(monitor=_mon())
    with pytest.raises(ValueError, match="divisible"):
        sr.make_convdiff_runtime(cfg, mesh, st, 9)
    with pytest.raises(ValueError, match="divisible"):
        sr.make_pagerank_runtime(cfg, mesh, 9)


# ---------------------------------------------------------------------------
# Single-shard parity (full machinery, one rank)
# ---------------------------------------------------------------------------


N = 10


def _setup(n=N, seed=0, rho=0.9):
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=rho)
    b = jnp.asarray(make_rhs(n, seed=seed))
    return st, b, jnp.zeros_like(b)


def test_blocking_trajectory_matches_reference():
    st, b, x0 = _setup()
    mesh = make_shard_mesh(1)
    cfg = sr.ShardRuntimeConfig(monitor=_mon(eps=1e-7), reduction="blocking",
                                max_outer=400, trace_len=256)
    r = jax.jit(sr.make_convdiff_runtime(cfg, mesh, st, N))(x0, b)
    assert bool(r.converged)
    T = min(int(r.outer_iters), 256)
    ref = np.asarray(sr.convdiff_reference_trace(st, b, T))
    trace = np.asarray(r.trace)[:T]
    np.testing.assert_allclose(trace, ref, rtol=5e-5)


def test_blocking_matches_solve_single_detection_point():
    st, b, x0 = _setup()
    from repro.solvers.fixed_point import SolverConfig, solve_single

    mesh = make_shard_mesh(1)
    mon = _mon(eps=1e-7)
    cfg = sr.ShardRuntimeConfig(monitor=mon, reduction="blocking",
                                max_outer=400)
    r = jax.jit(sr.make_convdiff_runtime(cfg, mesh, st, N))(x0, b)
    ref = solve_single(
        SolverConfig(stencil=st, monitor=mon, inner_sweeps=1, max_outer=400,
                     sweep="jacobi", fuse_residual=False), b)
    assert int(r.outer_iters) == int(ref.outer_iters)
    assert float(r.residual) == pytest.approx(float(ref.residual), rel=1e-5)
    np.testing.assert_allclose(np.asarray(r.x), np.asarray(ref.x),
                               rtol=1e-10, atol=1e-12)


def test_nonblocking_staleness_delays_detection():
    st, b, x0 = _setup()
    mesh = make_shard_mesh(1)
    outers = {}
    for K in (0, 4):
        mon = _mon(mode="pfait", eps=1e-7, staleness=K)
        cfg = sr.ShardRuntimeConfig(monitor=mon, reduction="nonblocking",
                                    max_outer=600)
        r = jax.jit(sr.make_convdiff_runtime(cfg, mesh, st, N))(x0, b)
        assert bool(r.converged)
        outers[K] = int(r.outer_iters)
    # a K-stale ring consumes the value launched K checks earlier: detection
    # fires exactly K checks later on a monotone trajectory
    assert outers[4] == outers[0] + 4


def test_inner_sweeps_accelerate_outer_convergence():
    st, b, x0 = _setup()
    mesh = make_shard_mesh(1)
    outers = {}
    for s in (1, 3):
        cfg = sr.ShardRuntimeConfig(monitor=_mon(eps=1e-7),
                                    reduction="blocking", inner_sweeps=s,
                                    max_outer=400)
        r = jax.jit(sr.make_convdiff_runtime(cfg, mesh, st, N))(x0, b)
        outers[s] = int(r.outer_iters)
        assert int(r.local_sweeps[0]) == s * outers[s]
    assert outers[3] < outers[1]


def test_rdoubling_single_shard_detects():
    st, b, x0 = _setup()
    mesh = make_shard_mesh(1)
    cfg = sr.ShardRuntimeConfig(monitor=_mon(mode="pfait", eps=1e-7),
                                reduction="rdoubling", max_outer=400)
    r = jax.jit(sr.make_convdiff_runtime(cfg, mesh, st, N))(x0, b)
    assert bool(r.converged)
    assert float(r.residual) < 1e-7


def test_nfais2_verification_counts():
    st, b, x0 = _setup()
    mesh = make_shard_mesh(1)
    mon = detection.for_mode("nfais2", eps_tilde=1e-6, staleness=2,
                             persistence=2)
    cfg = sr.ShardRuntimeConfig(monitor=mon, reduction="nonblocking",
                                max_outer=600)
    r = jax.jit(sr.make_convdiff_runtime(cfg, mesh, st, N))(x0, b)
    assert bool(r.converged)
    assert int(r.verifications) >= 1


def test_max_outer_exhaustion_reports_unconverged():
    st, b, x0 = _setup()
    mesh = make_shard_mesh(1)
    cfg = sr.ShardRuntimeConfig(monitor=_mon(eps=1e-30),
                                reduction="blocking", max_outer=7)
    r = jax.jit(sr.make_convdiff_runtime(cfg, mesh, st, N))(x0, b)
    assert not bool(r.converged)
    assert int(r.outer_iters) == 7
    assert not np.isfinite(float(r.residual))


def test_pagerank_runtime_single_shard():
    from repro.solvers.pagerank import PageRankProblem

    n = 64
    prob = PageRankProblem(n=n, p=4, seed=0)
    P_dense = jnp.asarray(prob.to_dense())
    x0 = jnp.full((n,), 1.0 / n)
    mesh = make_shard_mesh(1)
    mon = _mon(mode="pfait", eps=1e-9, ord=1.0)
    cfg = sr.ShardRuntimeConfig(monitor=mon, reduction="nonblocking",
                                max_outer=500, trace_len=64)
    r = jax.jit(sr.make_pagerank_runtime(cfg, mesh, n, prob.d))(x0, P_dense)
    assert bool(r.converged)
    # final exact residual (f64) must be at/under the detected one's decade
    xs = np.asarray(r.x, np.float64)
    rv = prob.d * (np.asarray(P_dense, np.float64) @ xs) + prob.v - xs
    assert float(np.sum(np.abs(rv))) < 1e-8


def test_pagerank_trace_matches_reference():
    from repro.solvers.pagerank import PageRankProblem

    n = 64
    prob = PageRankProblem(n=n, p=4, seed=1)
    P_dense = jnp.asarray(prob.to_dense())
    x0 = jnp.full((n,), 1.0 / n)
    mesh = make_shard_mesh(1)
    cfg = sr.ShardRuntimeConfig(monitor=_mon(eps=1e-10, ord=1.0),
                                reduction="blocking", max_outer=300,
                                trace_len=128)
    r = jax.jit(sr.make_pagerank_runtime(cfg, mesh, n, prob.d))(x0, P_dense)
    T = min(int(r.outer_iters), 128)
    ref = np.asarray(sr.pagerank_reference_trace(P_dense, n, T,
                                                 damping=prob.d, ord=1.0))
    np.testing.assert_allclose(np.asarray(r.trace)[:T], ref, rtol=5e-5)


# ---------------------------------------------------------------------------
# Ring-buffer semantics (pure helpers)
# ---------------------------------------------------------------------------


def test_ring_write_read_roundtrip():
    ring = sr._ring_fill(jnp.zeros((2,)), 3)
    for k in range(5):
        ring = sr._ring_write(ring, jnp.full((2,), float(k)), k)
    # slot k mod 3 holds the value written at the latest such k
    assert float(sr._ring_read(ring, 4)[0]) == 4.0
    assert float(sr._ring_read(ring, 3)[0]) == 3.0
    assert float(sr._ring_read(ring, 2)[0]) == 2.0
    # negative steps clamp to slot 0
    assert float(sr._ring_read(ring, -2)[0]) == 3.0  # slot 0 last wrote k=3


def test_ring_fill_broadcasts_initial_view():
    ring = sr._ring_fill({"a": jnp.arange(4.0)}, 5)
    assert ring["a"].shape == (5, 4)
    for s in range(5):
        np.testing.assert_array_equal(np.asarray(ring["a"][s]),
                                      np.arange(4.0))


# ---------------------------------------------------------------------------
# Multi-device behaviour (forced 4-device subprocess)
# ---------------------------------------------------------------------------


_SUBPROCESS_PROGRAM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from repro.core import detection
    from repro.launch.mesh import make_shard_mesh
    from repro.runtime import shard_runtime as sr
    from repro.solvers.convdiff import Stencil, make_rhs

    n = 12
    mesh = make_shard_mesh(4)
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    b = jnp.asarray(make_rhs(n, seed=0))
    x0 = jnp.zeros_like(b)

    # 1. blocking parity across 4 real shards
    mon = detection.MonitorConfig(mode="sync", eps=1e-7, staleness=0)
    cfg = sr.ShardRuntimeConfig(monitor=mon, reduction="blocking",
                                max_outer=400, trace_len=256)
    r = jax.jit(sr.make_convdiff_runtime(cfg, mesh, st, n))(x0, b)
    assert bool(r.converged)
    T = min(int(r.outer_iters), 256)
    ref = np.asarray(sr.convdiff_reference_trace(st, b, T))
    np.testing.assert_allclose(np.asarray(r.trace)[:T], ref, rtol=5e-5)

    # 2. asynchronous modes detect truthfully under staleness
    from repro.solvers import jacobi
    from repro.solvers.fixed_point import _zero_ghosts, ghosted
    for red, mode in (("nonblocking", "pfait"), ("nonblocking", "nfais2"),
                      ("rdoubling", "pfait")):
        m = detection.for_mode(mode, eps_tilde=1e-6, margin=10.0,
                               staleness=2, persistence=4)
        c = sr.ShardRuntimeConfig(
            monitor=m, reduction=red, max_outer=2000,
            inner_sweeps=(1, 2, 1, 3), halo_delay=(0, 1, 2, 1),
            contrib_lag=(0, 1, 0, 1))
        rr = jax.jit(sr.make_convdiff_runtime(c, mesh, st, n))(x0, b)
        assert bool(rr.converged), (red, mode)
        res = np.asarray(jacobi.residual_block(
            st, ghosted(rr.x, _zero_ghosts(rr.x)), b), np.float64)
        r_star = float(np.linalg.norm(res.ravel()))
        assert r_star < 10.0 * 1e-6, (red, mode, r_star)
        sweeps = np.asarray(rr.local_sweeps)
        k = int(rr.outer_iters)
        assert list(sweeps) == [k, 2 * k, k, 3 * k]
    print("MULTIDEVICE_OK")
""")


@pytest.mark.slow
def test_multidevice_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROGRAM], env=env,
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTIDEVICE_OK" in out.stdout
