"""ML fixed-point problem family (async gradient descent): decomposition
correctness, fused-path parity, batched-lane parity, and engine runs."""
import dataclasses

import numpy as np
import pytest

from repro.core.async_engine import AsyncEngine, stable_platform
from repro.core.protocols import NFAIS2, NFAIS5, PFAIT, ExactSnapshotFIFO
from repro.solvers.mlfixed import MLFixedPointProblem


def _full_deps(prob, xs):
    return [
        {j: prob.interface(j, xs[j], i) for j in prob.neighbors(i)}
        for i in range(prob.p)
    ]


@pytest.mark.parametrize("task", ["lstsq", "logistic"])
def test_reference_solution_is_fixed_point(task):
    prob = MLFixedPointProblem(n=32, p=4, m_rows=128, task=task, seed=0)
    x = prob.solve_reference()
    # minimiser ⇒ ∇F ≈ 0 ⇒ the update difference −γ∇F vanishes
    assert np.max(np.abs(prob.grad(x))) < 1e-10
    assert prob.exact_residual(prob.split(x)) < 1e-9
    # strictly better objective than the planted model (noise/regularised)
    assert prob.objective(x) <= prob.objective(prob.x_true) + 1e-12


@pytest.mark.parametrize("task", ["lstsq", "logistic"])
def test_synchronous_sweeps_contract(task):
    prob = MLFixedPointProblem(n=32, p=4, m_rows=128, task=task, seed=1)
    xs = [prob.init_local(i) for i in range(prob.p)]
    r0 = prob.exact_residual(xs)
    factor = 1.0 - prob.mu / prob.L   # GD contraction at γ = 1/L
    for _ in range(5):
        deps = _full_deps(prob, xs)
        xs = [prob.update(i, xs[i], deps[i]) for i in range(prob.p)]
    assert prob.exact_residual(xs) < r0 * factor ** 2  # loose: 5 sweeps


@pytest.mark.parametrize("ordv", [1.0, 2.0, float("inf")])
def test_update_with_residual_matches_pair(ordv):
    prob = MLFixedPointProblem(n=16, p=4, m_rows=64, ord=ordv, seed=2)
    rng = np.random.default_rng(3)
    xs = [prob.init_local(i) + 0.1 * rng.standard_normal(prob.block)
          for i in range(prob.p)]
    deps = _full_deps(prob, xs)
    for i in range(prob.p):
        x_ref = prob.update(i, xs[i], deps[i])
        r_ref = prob.local_residual(i, xs[i], deps[i])
        x_new, r_i = prob.update_with_residual(i, xs[i], deps[i])
        np.testing.assert_allclose(x_new, x_ref, atol=1e-15)
        assert r_i == pytest.approx(r_ref, rel=1e-12)
        x_skip, r_none = prob.update_with_residual(i, xs[i], deps[i],
                                                   need_residual=False)
        assert r_none is None
        np.testing.assert_allclose(x_skip, x_ref, atol=1e-15)


def test_dependency_graph_is_complete():
    prob = MLFixedPointProblem(n=32, p=4, m_rows=128, seed=0)
    for i in range(prob.p):
        assert sorted(prob.neighbors(i)) == [j for j in range(prob.p)
                                             if j != i]


def test_validates_construction_params():
    with pytest.raises(ValueError):
        MLFixedPointProblem(n=10, p=4)
    with pytest.raises(ValueError):
        MLFixedPointProblem(n=16, p=4, task="svm")
    with pytest.raises(ValueError):
        MLFixedPointProblem(n=32, p=4, m_rows=16)
    with pytest.raises(ValueError):
        MLFixedPointProblem(n=16, p=4, m_rows=64, l2=-1.0)
    with pytest.raises(ValueError):
        MLFixedPointProblem(n=16, p=4, m_rows=64, cond=0.5)
    prob = MLFixedPointProblem(n=16, p=4, m_rows=64)
    with pytest.raises(ValueError):
        MLFixedPointProblem(n=16, p=4, m_rows=64, gamma=3.0 / prob.L)


@pytest.mark.parametrize("task", ["lstsq", "logistic"])
@pytest.mark.parametrize("proto_name", ["pfait", "nfais2", "nfais5", "exact"])
def test_all_protocols_terminate_on_mlfixed(proto_name, task):
    prob = MLFixedPointProblem(n=16, p=4, m_rows=64, task=task, seed=0)
    eps = 1e-8
    proto = {
        "pfait": lambda: PFAIT(eps, ord=prob.ord),
        "nfais2": lambda: NFAIS2(eps, ord=prob.ord),
        "nfais5": lambda: NFAIS5(eps, ord=prob.ord, m=4),
        "exact": lambda: ExactSnapshotFIFO(eps, ord=prob.ord),
    }[proto_name]()
    cfg = dataclasses.replace(stable_platform(), seed=0, max_iters=20000,
                              fifo=(proto_name == "exact"))
    r = AsyncEngine(prob, cfg, proto).run()
    assert r.terminated
    assert r.r_star < 10 * eps
    assert r.k_max > 0


def test_engine_fused_matches_unfused_on_mlfixed():
    res = {}
    for fused in (False, True):
        prob = MLFixedPointProblem(n=16, p=4, m_rows=64, seed=0)
        cfg = dataclasses.replace(stable_platform(), seed=2, max_iters=20000,
                                  fused=fused)
        res[fused] = AsyncEngine(prob, cfg, PFAIT(1e-8, ord=prob.ord)).run()
    assert res[True].terminated and res[False].terminated
    assert res[True].r_star == pytest.approx(res[False].r_star, rel=1e-6)
    assert res[True].k_max == res[False].k_max


@pytest.mark.parametrize("task", ["lstsq", "logistic"])
def test_batched_path_matches_sequential(task):
    """One vmapped-lane step == the synchronous numpy sweep, for both the
    single-lane default path and stacked per-seed operators."""
    probs = [MLFixedPointProblem(n=16, p=4, m_rows=64, task=task, seed=s)
             for s in (0, 1, 2)]
    rng = np.random.default_rng(7)
    X = rng.standard_normal((3, 16))

    # reference: full synchronous sweep of each lane's own problem
    refs, contribs = [], []
    for prob, x in zip(probs, X):
        xs = prob.split(x)
        deps = _full_deps(prob, xs)
        out = [prob.update_with_residual(i, xs[i], deps[i])
               for i in range(prob.p)]
        refs.append(prob.assemble([o[0] for o in out]))
        contribs.append(sum(o[1] for o in out))

    p0 = probs[0]
    if task == "lstsq":
        Y, C = p0.update_with_residual_batched(
            X, H=np.stack([pr.H for pr in probs]),
            c=np.stack([pr.c for pr in probs]),
            gamma=np.array([pr.gamma for pr in probs]))
    else:
        Y, C = p0.update_with_residual_batched(
            X, A=np.stack([pr.A for pr in probs]),
            s=np.stack([pr.s for pr in probs]),
            gamma=np.array([pr.gamma for pr in probs]))
    np.testing.assert_allclose(np.asarray(Y), np.stack(refs), atol=1e-12)
    np.testing.assert_allclose(np.asarray(C), np.array(contribs), rtol=1e-10)

    # single-lane default path evaluates this instance
    Y0, C0 = p0.update_with_residual_batched(X[:1])
    np.testing.assert_allclose(np.asarray(Y0)[0], refs[0], atol=1e-12)
    assert float(np.asarray(C0)[0]) == pytest.approx(contribs[0], rel=1e-10)
