"""Faithful event-level protocols on the asynchronous-iterations engine."""
import dataclasses

import numpy as np
import pytest

from repro.core.async_engine import AsyncEngine, stable_platform, unstable_platform
from repro.core.protocols import NFAIS2, NFAIS5, PFAIT, ExactSnapshotFIFO
from repro.solvers.convdiff import ConvDiffProblem

EPS = 1e-6


def run(proto_name, seed=0, n=12, p=4, fifo=None, eps=EPS, platform=stable_platform):
    prob = ConvDiffProblem(n=n, p=p, rho=0.9, seed=seed)
    cfg = platform()
    if proto_name == "exact":
        cfg = dataclasses.replace(cfg, fifo=True)
        proto = ExactSnapshotFIFO(eps, ord=prob.ord)
    elif proto_name == "pfait":
        proto = PFAIT(eps, ord=prob.ord)
    elif proto_name == "nfais2":
        proto = NFAIS2(eps, ord=prob.ord)
    else:
        proto = NFAIS5(eps, ord=prob.ord, m=4)
    if fifo is not None:
        cfg = dataclasses.replace(cfg, fifo=fifo)
    eng = AsyncEngine(prob, dataclasses.replace(cfg, seed=seed, max_iters=30_000), proto)
    return eng, eng.run()


@pytest.mark.parametrize("proto", ["pfait", "nfais2", "nfais5", "exact"])
def test_all_protocols_terminate(proto):
    _, r = run(proto)
    assert r.terminated
    assert np.isfinite(r.r_star)
    assert r.k_max > 0


def test_pfait_sends_no_protocol_messages():
    _, r = run("pfait")
    assert set(r.msg_counts) == {"data"}
    assert r.reductions > 1  # successive non-blocking reductions


def test_nfais2_carries_interface_data_nfais5_does_not():
    _, r2 = run("nfais2")
    _, r5 = run("nfais5")
    bytes2 = r2.msg_bytes.get("snap2", 0) / max(r2.msg_counts.get("snap2", 1), 1)
    bytes5 = r5.msg_bytes.get("snap5", 0) / max(r5.msg_counts.get("snap5", 1), 1)
    # O(interface) vs O(1): 6×12 f64 plane = 576 B vs 16 B empty message
    assert bytes2 > 20 * bytes5


def test_detection_guarantees_nfais2():
    """NFAIS2 records are consistent → detected residual is exact for the
    snapshot vector, hence below ε."""
    for seed in range(3):
        _, r = run("nfais2", seed=seed)
        assert r.detected_residual < EPS


def test_exact_snapshot_consistency_invariant():
    """CL+FIFO: recorded deps equal the interface of the recorded owner
    component (the cut is consistent)."""
    prob = ConvDiffProblem(n=12, p=4, rho=0.9, seed=5)
    cfg = dataclasses.replace(stable_platform(), fifo=True, seed=5, max_iters=30_000)
    proto = ExactSnapshotFIFO(EPS, ord=prob.ord)
    eng = AsyncEngine(prob, cfg, proto)
    r = eng.run()
    assert r.terminated
    for i in range(prob.p):
        for j in prob.neighbors(i):
            want = prob.interface(j, proto.rec_own[j], i)
            got = proto.rec_deps[i][j]
            np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_exact_snapshot_sigma_equals_global_residual_of_cut():
    from repro.core.residual import combine_contributions

    prob = ConvDiffProblem(n=12, p=4, rho=0.9, seed=7)
    cfg = dataclasses.replace(stable_platform(), fifo=True, seed=7, max_iters=30_000)
    proto = ExactSnapshotFIFO(EPS, ord=prob.ord)
    eng = AsyncEngine(prob, cfg, proto)
    r = eng.run()
    assert r.terminated
    contribs = [prob.local_residual(i, proto.rec_own[i], proto.rec_deps[i])
                for i in range(prob.p)]
    sigma = combine_contributions(contribs, prob.ord)
    exact = prob.exact_residual(proto.rec_own)
    np.testing.assert_allclose(sigma, exact, rtol=1e-10)


def test_pfait_faster_than_snapshot_protocols():
    """Table 2/5 structure: PFAIT saves the snapshot/confirmation phases."""
    wt = {}
    for proto in ["pfait", "nfais2", "nfais5"]:
        ts = []
        for seed in range(3):
            _, r = run(proto, seed=seed)
            assert r.terminated
            ts.append(r.wtime)
        wt[proto] = np.mean(ts)
    assert wt["pfait"] <= wt["nfais2"] * 1.05
    assert wt["pfait"] <= wt["nfais5"] * 1.05


def test_pfait_margin_restores_guarantee():
    """Table 4 structure: PFAIT at ε = ε̃/10 keeps r* < ε̃ even when PFAIT
    at ε = ε̃ may overshoot."""
    for seed in range(3):
        _, r = run("pfait", seed=seed, eps=EPS / 10, platform=unstable_platform)
        assert r.terminated
        assert r.r_star < EPS
