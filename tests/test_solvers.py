"""Convection–diffusion solver substrate: numpy sim + JAX distributed."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import detection
from repro.solvers.convdiff import ConvDiffProblem, Stencil, make_rhs
from repro.solvers.fixed_point import (
    SolverConfig,
    _zero_ghosts,
    ghosted,
    make_sharded_solver,
    solve_single,
)
from repro.solvers import jacobi


def test_stencil_contraction_rate():
    st = Stencil.for_contraction(16, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    h = 1.0 / 17
    d = 1.0 / h**2
    assert (6 * d) / st.diag == pytest.approx(0.9)


def test_sim_problem_converges_to_reference():
    prob = ConvDiffProblem(n=10, p=4, rho=0.85, seed=0)
    ref = prob.solve_reference(tol=1e-13)
    # drive every subdomain synchronously (round-robin sweeps, fresh deps)
    xs = [prob.init_local(i) for i in range(prob.p)]
    for _ in range(400):
        deps = [
            {j: prob.interface(j, xs[j], i) for j in prob.neighbors(i)}
            for i in range(prob.p)
        ]
        xs = [prob.update(i, xs[i], deps[i]) for i in range(prob.p)]
    np.testing.assert_allclose(prob.assemble(xs), ref, atol=1e-8)


def test_sim_local_residuals_consistent_with_global():
    prob = ConvDiffProblem(n=10, p=4, rho=0.85, seed=1)
    xs = [prob.init_local(i) + np.random.default_rng(i).standard_normal(prob.part.block)
          for i in range(prob.p)]
    deps = [
        {j: prob.interface(j, xs[j], i) for j in prob.neighbors(i)}
        for i in range(prob.p)
    ]
    local_max = max(prob.local_residual(i, xs[i], deps[i]) for i in range(prob.p))
    assert local_max == pytest.approx(prob.exact_residual(xs), rel=1e-12)


@pytest.mark.parametrize("sweep", ["jacobi", "hybrid"])
def test_solve_single_reaches_threshold(sweep):
    n = 12
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    b = jnp.asarray(make_rhs(n, 0))
    mon = detection.for_mode("pfait", eps_tilde=1e-8, margin=10.0,
                             staleness=3, ord=float("inf"))
    cfg = SolverConfig(stencil=st, monitor=mon, inner_sweeps=1,
                       max_outer=20_000, sweep=sweep)
    r = solve_single(cfg, b)
    assert bool(r.converged)
    g = ghosted(r.x, _zero_ghosts(r.x))
    exact = float(jnp.max(jnp.abs(jacobi.residual_block(st, g, b))))
    assert exact < 1e-8


def test_hybrid_gs_converges_faster_than_jacobi():
    n = 12
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    b = jnp.asarray(make_rhs(n, 0))
    mon = detection.for_mode("sync", eps_tilde=1e-8, ord=float("inf"))
    out = {}
    for sweep in ["jacobi", "hybrid"]:
        cfg = SolverConfig(stencil=st, monitor=mon, max_outer=20_000, sweep=sweep)
        out[sweep] = int(solve_single(cfg, b).outer_iters)
    assert out["hybrid"] < out["jacobi"]


@pytest.mark.slow
def test_sharded_solver_single_device_mesh_matches_single():
    from repro.launch.mesh import compat_make_mesh

    n = 12
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    b = jnp.asarray(make_rhs(n, 0))
    mon = detection.for_mode("pfait", eps_tilde=1e-8, margin=10.0,
                             staleness=2, ord=float("inf"))
    cfg = SolverConfig(stencil=st, monitor=mon, inner_sweeps=2, max_outer=20_000)
    solve = make_sharded_solver(cfg, mesh)  # mesh passed explicitly
    r_mesh = solve(jnp.zeros_like(b), b)
    r_single = solve_single(cfg, b)
    assert bool(r_mesh.converged)
    np.testing.assert_allclose(np.asarray(r_mesh.x), np.asarray(r_single.x), atol=1e-12)
    assert int(r_mesh.outer_iters) == int(r_single.outer_iters)


def test_inner_sweeps_reduce_outer_iterations():
    """Communication-avoiding asynchrony: more local sweeps per exchange →
    fewer outer iterations (halo exchanges + reductions)."""
    n = 12
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.9)
    b = jnp.asarray(make_rhs(n, 0))
    mon = detection.for_mode("sync", eps_tilde=1e-8, ord=float("inf"))
    outer = {}
    for s in [1, 4]:
        cfg = SolverConfig(stencil=st, monitor=mon, inner_sweeps=s, max_outer=20_000)
        outer[s] = int(solve_single(cfg, b).outer_iters)
    assert outer[4] < outer[1]
