"""Dynamic-membership semantics of the event engine and the protocols'
survival of crash / join / checkpoint-restart (the elastic matrix of
benchmarks/bench_elastic.py at test scale).

Ground-truth convention (Daggitt & Griffin): once membership changes, a
crashed worker's block is frozen boundary data — detection claims are
scored against the *active subsystem's* residual, with inactive
neighbours pinned at each receiver's last *delivered* view (over non-FIFO
channels the dead worker's final state is unobservable to any detector,
oracle included).
"""
import dataclasses

import pytest

from repro.core.async_engine import PLATFORMS, AsyncEngine
from repro.core.protocols import PROTOCOLS
from repro.core.reliability import (
    TraceRecorder,
    detection_report,
    replay_matches,
)
from repro.core.scenarios import elastic_scenarios, scenario_registry
from repro.solvers.convdiff import ConvDiffProblem

BASE = 1e-3
EPS = 1e-6
#: membership changes each scenario must land *before* detection fires
EXPECTED_CHANGES = {"crash_early": 1, "crash_late": 1, "crash_two": 2,
                    "join_late": 1, "crash_restart": 2, "churn": 3}


def _problem(seed=0):
    return ConvDiffProblem(n=12, p=4, rho=0.9, seed=seed)


def _cfg(spec, seed=0, fifo=False, max_iters=6000):
    return dataclasses.replace(
        PLATFORMS[spec.platform](BASE), seed=seed, max_iters=max_iters,
        scenario=spec.scenario, fifo=fifo)


def _run(scenario, protocol, seed=0):
    spec = elastic_scenarios(BASE)[scenario]
    cfg = _cfg(spec, seed=seed, fifo=(protocol == "exact_snapshot"))
    rec = TraceRecorder(residual_stride=25, record_sends=False)
    prob = _problem(seed)
    eng = AsyncEngine(prob, cfg, PROTOCOLS[protocol](eps=EPS, ord=prob.ord),
                      recorder=rec)
    res = eng.run()
    return eng, res, rec


# ---------------------------------------------------------------------------
# Engine membership mechanics
# ---------------------------------------------------------------------------


def test_registry_contains_elastic_scenarios():
    names = set(elastic_scenarios(BASE))
    assert names == set(EXPECTED_CHANGES)
    merged = set(scenario_registry(BASE))
    assert names <= merged  # merged with the PR-2 standard regimes


def test_crash_retires_worker_and_freezes_block():
    eng, res, rec = _run("crash_early", "pfait")
    assert res.terminated
    assert [(k, w) for _, k, w in rec.membership] == [("crash", 2)]
    assert not eng.active[2] and eng.active_workers() == [0, 1, 3]
    # the survivors' detection is honest for the active subsystem even
    # though the frozen block leaves the *full* residual far above eps
    rep = detection_report(rec, EPS)
    assert not rep.false_detection
    assert rep.active_residual < 10 * EPS
    assert eng.exact_active_residual() < eng.problem.exact_residual(eng.x)


def test_join_admits_worker_and_starts_its_chain():
    eng, res, rec = _run("join_late", "pfait")
    assert res.terminated
    assert [(k, w) for _, k, w in rec.membership] == [("join", 3)]
    assert eng.active[3] and eng.k[3] > 0  # the joiner actually iterated
    # after admission the joiner is an unknown again: the run may only
    # detect once the FULL system re-converged
    assert eng.problem.exact_residual(eng.x) < 10 * EPS


def test_restore_rolls_back_and_detection_waits():
    eng, res, rec = _run("crash_restart", "pfait")
    assert res.terminated
    kinds = [(k, w) for _, k, w in rec.membership]
    assert kinds == [("crash", 1), ("restore", 1)]
    t_restore = rec.membership[1][0]
    # detection must postdate the restore: the rollback reopens the gap,
    # and PFAIT flushes reduction chains sampled under the old membership
    assert rec.detect is not None and rec.detect[0] > t_restore
    assert not detection_report(rec, EPS).false_detection


def test_active_residual_equals_exact_when_membership_static():
    prob = _problem()
    cfg = dataclasses.replace(PLATFORMS["stable"](BASE), seed=0,
                              max_iters=6000)
    eng = AsyncEngine(prob, cfg, PROTOCOLS["pfait"](eps=EPS, ord=prob.ord))
    res = eng.run()
    assert res.terminated
    full = prob.exact_residual(eng.x)
    active = eng.exact_active_residual()
    assert active == pytest.approx(full, rel=1e-12)


# ---------------------------------------------------------------------------
# Protocol survival (every detector, the compound scenarios)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("protocol", sorted(PROTOCOLS))
@pytest.mark.parametrize("scenario", ["crash_two", "churn"])
def test_protocols_survive_compound_membership(protocol, scenario):
    eng, res, rec = _run(scenario, protocol)
    rep = detection_report(rec, EPS)
    assert res.terminated, f"{protocol} never detected under {scenario}"
    assert not rep.false_detection
    assert rep.membership_changes == EXPECTED_CHANGES[scenario]


def test_snapshot_vector_has_boundary_holes_after_crash():
    eng, res, rec = _run("crash_early", "nfais2")
    rep = detection_report(rec, EPS)
    assert res.terminated and not rep.false_detection
    assert rep.claim == "recorded"
    # the certified (recorded) vector is scored against the active
    # subsystem with the dead worker's block as boundary data
    assert rep.certified_residual is not None
    assert rep.certified_residual < 10 * EPS


def test_rdub_refolds_after_crash_to_odd_membership():
    # 4 workers -> crash -> 3: the butterfly must fold the remainder rank
    # (q=2, rem=1) under a fresh generation, with epoch counters restarted
    # from a common base
    eng, res, rec = _run("crash_early", "rdub")
    assert res.terminated
    assert not detection_report(rec, EPS).false_detection
    assert len(eng.protocol.members) == 3


def test_elastic_run_replays_deterministically():
    spec = elastic_scenarios(BASE)["churn"]
    cfg = _cfg(spec, seed=2)
    assert replay_matches(
        lambda: _problem(2), cfg,
        lambda pr: PROTOCOLS["pfait"](eps=EPS, ord=pr.ord),
        residual_stride=25)


def test_static_timeline_unchanged_by_elastic_effects():
    """Membership events draw nothing from the RNG stream: a scenario's
    fault timeline is static, so two scenarios with the same initial
    membership share every compute/communication draw until the first
    fault lands (the PR-2 no-detection-protocol invariant extended to
    membership — crash_early fires at 30·base, crash_late at 80·base)."""
    _, res_a, rec_a = _run("crash_early", "pfait", seed=3)
    _, res_b, rec_b = _run("crash_late", "pfait", seed=3)
    t_first_fault = 30 * BASE
    sweeps_a = [e for e in rec_a.events
                if e[0] == "sweep" and e[1] < t_first_fault]
    sweeps_b = [e for e in rec_b.events
                if e[0] == "sweep" and e[1] < t_first_fault]
    assert sweeps_a and sweeps_a == sweeps_b
