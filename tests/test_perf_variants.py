"""§Perf optimization variants must be numerically faithful to the baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ParallelConfig, reduced
from repro.configs.registry import get_arch
from repro.models import Model
from repro.models.attention import attention_fwd, attention_fwd_pairs

B, S = 2, 64


def _batch(cfg, key=3):
    return {
        "inputs": jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(key + 1), (B, S), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("window", [0, 48])
def test_pairs_attention_exact_vs_blocked(window):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 128, 2, 3, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 2, 32)), jnp.float32)
    a = attention_fwd(q, k, v, causal=True, window=window, block_kv=32)
    b = attention_fwd_pairs(q, k, v, causal=True, window=window,
                            block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_pairs_skips_work():
    """The pair list drops ~half the blocks for causal, more with a window."""
    # indirectly: gradients still flow and loss matches blocked impl
    cfg = reduced(get_arch("qwen2-1.5b"))
    m1 = Model(cfg)
    m2 = Model(cfg, parallel=ParallelConfig(attn_impl="pairs"))
    params = m1.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l1, _ = jax.jit(m1.loss_fn)(params, batch)
    l2, _ = jax.jit(m2.loss_fn)(params, batch)
    assert abs(float(l1) - float(l2)) < 5e-3


@pytest.mark.slow
def test_save_mixer_remat_grad_parity():
    cfg = reduced(get_arch("qwen2-1.5b"))
    m1 = Model(cfg)
    m2 = Model(cfg, parallel=ParallelConfig(remat="save_mixer"))
    params = m1.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    g1 = jax.grad(lambda p: m1.loss_fn(p, batch)[0])(params)
    g2 = jax.grad(lambda p: m2.loss_fn(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=2e-2,  # bf16 recompute-order rounding
        )


def test_tp_reduce_bf16_loss_parity_single_device_mesh():
    from repro.core.compat import make_mesh_compat

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    cfg = reduced(get_arch("qwen2-1.5b"))
    m1 = Model(cfg, mesh=mesh)
    m2 = Model(cfg, mesh=mesh, parallel=ParallelConfig(tp_reduce_bf16=True))
    params = m1.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l1, _ = jax.jit(m1.loss_fn)(params, batch)
    l2, _ = jax.jit(m2.loss_fn)(params, batch)
    assert abs(float(l1) - float(l2)) < 5e-3


def test_variant_train_step_runs_end_to_end():
    from repro.optim import AdamW, constant_schedule

    cfg = reduced(get_arch("qwen2-1.5b"))
    m = Model(cfg, parallel=ParallelConfig(attn_impl="pairs", remat="save_mixer"))
    opt = AdamW(constant_schedule(1e-3))
    ts = m.init_train_state(jax.random.PRNGKey(0), opt)
    step, _ = m.make_train_step(opt, microbatches=2)
    ts2, metrics = jax.jit(step)(ts, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
