"""PageRank / D-iteration problem family: decomposition correctness,
fused-path parity, asymmetric dependency structure, and engine runs."""
import dataclasses

import numpy as np
import pytest

from repro.core.async_engine import AsyncEngine, stable_platform
from repro.core.protocols import NFAIS2, NFAIS5, PFAIT, ExactSnapshotFIFO
from repro.solvers.pagerank import PageRankProblem


def _full_deps(prob, xs):
    return [
        {j: prob.interface(j, xs[j], i) for j in prob.neighbors(i)}
        for i in range(prob.p)
    ]


def test_reference_solution_is_fixed_point_and_stochastic():
    prob = PageRankProblem(n=256, p=4, seed=0)
    x = prob.solve_reference()
    assert x.sum() == pytest.approx(1.0, abs=1e-10)  # P column-stochastic
    assert np.all(x > 0)
    xs = [x[i * prob.block:(i + 1) * prob.block] for i in range(prob.p)]
    assert prob.exact_residual(xs) < 1e-12


def test_update_contracts_in_l1():
    prob = PageRankProblem(n=256, p=4, damping=0.85, seed=1)
    rng = np.random.default_rng(0)
    xs = [prob.init_local(i) + 0.01 * rng.standard_normal(prob.block)
          for i in range(prob.p)]
    r0 = prob.exact_residual(xs)
    for _ in range(3):
        deps = _full_deps(prob, xs)
        xs = [prob.update(i, xs[i], deps[i]) for i in range(prob.p)]
    # 3 synchronous sweeps contract the l1 residual by ~d³
    assert prob.exact_residual(xs) < 0.85 ** 3 * r0 * 1.05


@pytest.mark.parametrize("ordv", [1.0, 2.0, float("inf")])
def test_update_with_residual_matches_pair(ordv):
    prob = PageRankProblem(n=128, p=4, ord=ordv, seed=2)
    rng = np.random.default_rng(3)
    xs = [prob.init_local(i) + 0.01 * rng.standard_normal(prob.block)
          for i in range(prob.p)]
    deps = _full_deps(prob, xs)
    for i in range(prob.p):
        x_ref = prob.update(i, xs[i], deps[i])
        r_ref = prob.local_residual(i, xs[i], deps[i])
        x_new, r_i = prob.update_with_residual(i, xs[i], deps[i])
        np.testing.assert_allclose(x_new, x_ref, atol=1e-15)
        assert r_i == pytest.approx(r_ref, rel=1e-12)
        x_skip, r_none = prob.update_with_residual(i, xs[i], deps[i],
                                                   need_residual=False)
        assert r_none is None
        np.testing.assert_allclose(x_skip, x_ref, atol=1e-15)


def test_dependency_structure_is_asymmetric():
    """Hub bias ⇒ some ordered pair (i, j) has i reading from j while j
    never reads from i (directed block graph), and interface sizes differ
    by direction."""
    prob = PageRankProblem(n=256, p=4, seed=0)
    sizes = {}
    for i in range(prob.p):
        for j in prob.neighbors(i):
            sizes[(j, i)] = prob.interface(j, prob.init_local(j), i).size
    assert any(sizes[(j, i)] != sizes[(i, j)] for (j, i) in sizes
               if (i, j) in sizes)
    assert any(v == 0 for v in sizes.values()) or \
        max(sizes.values()) > 2 * min(sizes.values())


def test_validates_construction_params():
    with pytest.raises(ValueError):
        PageRankProblem(n=10, p=4)
    with pytest.raises(ValueError):
        PageRankProblem(n=128, p=4, damping=1.5)


@pytest.mark.parametrize("proto_name", ["pfait", "nfais2", "nfais5", "exact"])
def test_all_protocols_terminate_on_pagerank(proto_name):
    prob = PageRankProblem(n=128, p=4, seed=0)
    eps = 1e-8
    proto = {
        "pfait": lambda: PFAIT(eps, ord=prob.ord),
        "nfais2": lambda: NFAIS2(eps, ord=prob.ord),
        "nfais5": lambda: NFAIS5(eps, ord=prob.ord, m=4),
        "exact": lambda: ExactSnapshotFIFO(eps, ord=prob.ord),
    }[proto_name]()
    cfg = dataclasses.replace(stable_platform(), seed=0, max_iters=5000,
                              fifo=(proto_name == "exact"))
    r = AsyncEngine(prob, cfg, proto).run()
    assert r.terminated
    assert r.r_star < 10 * eps
    assert r.k_max > 0


def test_engine_fused_matches_unfused_on_pagerank():
    res = {}
    for fused in (False, True):
        prob = PageRankProblem(n=128, p=4, seed=0)
        cfg = dataclasses.replace(stable_platform(), seed=2, max_iters=5000,
                                  fused=fused)
        res[fused] = AsyncEngine(prob, cfg, PFAIT(1e-8, ord=prob.ord)).run()
    assert res[True].terminated and res[False].terminated
    assert res[True].r_star == pytest.approx(res[False].r_star, rel=1e-6)
    assert res[True].k_max == res[False].k_max
