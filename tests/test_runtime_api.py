"""Unified runtime API tests: RuntimeConfig validation and conversion,
bitwise parity between the unified entrypoints and the historical shims,
and trace attachment through ``record_trace``.

All in-process on the session's single device (tests/conftest.py) — the
parity claims are shard-count independent (both paths run the identical
compiled program), and the multi-shard API paths run in the gated
``replay-smoke`` CI lane.
"""
import jax
import numpy as np
import pytest

from repro.core import detection
from repro.launch.mesh import make_shard_mesh
from repro.runtime import api
from repro.runtime.api import DEFAULT_TRACE_LEN, RunReport, RuntimeConfig
from repro.solvers.convdiff import Stencil, make_rhs


def _mon(mode="pfait", eps_tilde=1e-6, staleness=2):
    return detection.for_mode(mode, eps_tilde=eps_tilde, staleness=staleness,
                              ord=2.0)


def _convdiff(n=8, rho=0.9, seed=0):
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=rho)
    b = make_rhs(n, seed=seed)
    return st, b, np.zeros_like(b)


# ---------------------------------------------------------------------------
# RuntimeConfig validation + conversion
# ---------------------------------------------------------------------------


def test_config_validates_reduction_at_construction():
    with pytest.raises(ValueError, match="reduction"):
        RuntimeConfig(monitor=_mon(), reduction="gossip")


def test_config_validates_max_outer():
    with pytest.raises(ValueError, match="max_outer"):
        RuntimeConfig(monitor=_mon(), max_outer=0)


def test_to_shard_config_field_mapping():
    cfg = RuntimeConfig(monitor=_mon(), reduction="blocking",
                        inner_sweeps=3, halo_delay=1, contrib_lag=2,
                        max_outer=123, trace_len=7, sweep="jacobi")
    scfg = cfg.to_shard_config()
    assert scfg.reduction == "blocking"
    assert scfg.inner_sweeps == 3 and scfg.halo_delay == 1
    assert scfg.contrib_lag == 2 and scfg.max_outer == 123
    assert scfg.trace_len == 7
    # blocking forces the effective monitor's staleness to zero
    assert scfg.effective_monitor().staleness == 0


def test_to_train_config_renames_knobs():
    cfg = RuntimeConfig(monitor=_mon(), inner_sweeps=4, halo_delay=2,
                        max_outer=99, num_batches=2, gamma=0.5)
    tcfg = cfg.to_train_config()
    assert tcfg.inner_steps == 4        # inner_sweeps -> inner_steps
    assert tcfg.view_delay == 2         # halo_delay -> view_delay
    assert tcfg.max_rounds == 99        # max_outer -> max_rounds
    assert tcfg.num_batches == 2 and tcfg.gamma == 0.5


def test_record_trace_raises_trace_len():
    cfg = RuntimeConfig(monitor=_mon(), record_trace=True, max_outer=5000)
    assert cfg.to_shard_config().trace_len == DEFAULT_TRACE_LEN
    small = RuntimeConfig(monitor=_mon(), record_trace=True, max_outer=100)
    assert small.to_shard_config().trace_len == 100
    pinned = RuntimeConfig(monitor=_mon(), record_trace=True, trace_len=64)
    assert pinned.to_shard_config().trace_len == 64


def test_unknown_family_raises_keyerror():
    cfg = RuntimeConfig(monitor=_mon())
    with pytest.raises(KeyError, match="family"):
        api.run_shard("heat", cfg, make_shard_mesh(1), 8,
                      np.zeros((8, 8, 8)), np.zeros((8, 8, 8)))


# ---------------------------------------------------------------------------
# Shim parity: unified entrypoints vs the historical call paths
# ---------------------------------------------------------------------------


def test_run_shard_matches_legacy_make_runtime_bitwise():
    from repro.runtime import shard_runtime as sr

    n = 8
    st, b, x0 = _convdiff(n)
    mesh = make_shard_mesh(1)
    cfg = RuntimeConfig(monitor=_mon(), reduction="nonblocking",
                        max_outer=500, trace_len=512)
    rep = api.run_shard("convdiff", cfg, mesh, n, x0, b, stencil=st)

    legacy = jax.jit(sr.make_runtime("convdiff", cfg.to_shard_config(),
                                     mesh, n, stencil=st))(x0, b)
    assert isinstance(rep, RunReport)
    assert rep.converged == bool(legacy.converged)
    assert rep.outer_iters == int(legacy.outer_iters)
    np.testing.assert_array_equal(np.asarray(rep.x), np.asarray(legacy.x))
    np.testing.assert_array_equal(np.asarray(rep.raw.trace),
                                  np.asarray(legacy.trace))
    assert rep.detected_residual == float(legacy.residual)
    assert rep.detect_step == rep.outer_iters - 1
    # wall segments: build (compile) + run (steady-state)
    names = [nm for nm, _ in rep.wall_segments]
    assert names == ["build", "run"]
    assert rep.wall_s > 0


def test_run_train_matches_legacy_make_train_runtime_bitwise():
    from repro.runtime import train_async as ta
    from repro.solvers.mlfixed import MLFixedPointProblem

    prob = MLFixedPointProblem(n=8, p=1, m_rows=16, task="lstsq", seed=3)
    mesh = make_shard_mesh(1)
    cfg = RuntimeConfig(monitor=_mon(eps_tilde=1e-6, staleness=1),
                        reduction="nonblocking", inner_sweeps=2,
                        max_outer=5000)
    X0 = ta.init_replicas(prob, 1)
    rep = api.run_train(prob, cfg, mesh, X0, prob.A, prob.y)

    legacy = jax.jit(ta.make_train_runtime(prob, cfg.to_train_config(),
                                           mesh))(X0, prob.A, prob.y)
    assert rep.converged == bool(legacy.converged)
    assert rep.outer_iters == int(legacy.rounds)
    np.testing.assert_array_equal(np.asarray(rep.x), np.asarray(legacy.x))
    assert rep.detected_residual == float(legacy.residual)


def test_run_elastic_matches_legacy_run_elastic(tmp_path):
    from repro.runtime import elastic as el

    n = 8
    st, b, x0 = _convdiff(n)
    cfg = RuntimeConfig(monitor=_mon(staleness=1), reduction="nonblocking",
                        contrib_lag=1, record_trace=True)
    knobs = dict(stencil=st, p0=1, segment_len=25, max_segments=40)
    rep = api.run_elastic("convdiff", cfg, n, x0, b, el.FaultPlan(),
                          str(tmp_path / "a"), **knobs)
    legacy = el.run_elastic("convdiff", cfg.to_shard_config(), n, x0, b,
                            el.FaultPlan(), str(tmp_path / "b"), **knobs)
    assert rep.converged == legacy.converged
    assert rep.outer_iters == legacy.outer_iters
    assert rep.detected_residual == legacy.detected_residual
    np.testing.assert_array_equal(np.asarray(rep.x), np.asarray(legacy.x))
    assert rep.membership_log == list(legacy.events)
    # elastic trace: real segment boundaries + schema-valid events
    rep.trace.validate()
    assert rep.trace.source == "elastic"
    assert len(rep.trace.events_of("segment")) == legacy.segments_run


def test_timing_runs_append_rerun_segments():
    n = 8
    st, b, x0 = _convdiff(n)
    cfg = RuntimeConfig(monitor=_mon(), max_outer=500)
    rep = api.run_shard("convdiff", cfg, make_shard_mesh(1), n, x0, b,
                        stencil=st, timing_runs=2)
    names = [nm for nm, _ in rep.wall_segments]
    assert names == ["build", "run", "rerun", "rerun"]
    assert all(s > 0 for _, s in rep.wall_segments)


# ---------------------------------------------------------------------------
# Trace attachment through the unified API
# ---------------------------------------------------------------------------


def test_record_trace_attaches_schema_valid_trace():
    n = 8
    st, b, x0 = _convdiff(n)
    cfg = RuntimeConfig(monitor=_mon(), max_outer=500, record_trace=True)
    rep = api.run_shard("convdiff", cfg, make_shard_mesh(1), n, x0, b,
                        stencil=st)
    rep.trace.validate()
    assert rep.trace.meta["outer_iters"] == rep.outer_iters
    # the trace's wall is the steady-state run segment, not the compile
    assert rep.trace.meta["wall_s"] == dict(rep.wall_segments)["run"]
    # residual_history is the finite launched prefix
    assert rep.residual_history.size > 0
    assert np.isfinite(rep.residual_history).all()


def test_no_record_trace_means_no_trace():
    n = 8
    st, b, x0 = _convdiff(n)
    cfg = RuntimeConfig(monitor=_mon(), max_outer=500)
    rep = api.run_shard("convdiff", cfg, make_shard_mesh(1), n, x0, b,
                        stencil=st)
    assert rep.trace is None


def test_train_record_trace_source_is_train():
    from repro.runtime import train_async as ta
    from repro.solvers.mlfixed import MLFixedPointProblem

    prob = MLFixedPointProblem(n=8, p=1, m_rows=16, task="lstsq", seed=3)
    cfg = RuntimeConfig(monitor=_mon(staleness=1), inner_sweeps=2,
                        max_outer=5000, record_trace=True)
    rep = api.run_train(prob, cfg, make_shard_mesh(1),
                        ta.init_replicas(prob, 1), prob.A, prob.y)
    rep.trace.validate()
    assert rep.trace.source == "train"
    assert rep.trace.meta["reduction"] == "nonblocking"
