"""Campaign runner semantics: content-addressed caching, invalidation,
resume-after-interrupt, deterministic reports (benchmarks/campaign.py).

The cache contract under test:
  * same spec + same fingerprint ⇒ hit (zero recompute),
  * any spec key change ⇒ miss,
  * code-fingerprint change ⇒ every cell misses,
  * deleted/truncated cache files (an interrupted campaign) ⇒ only those
    cells recompute,
  * report cell order follows the input spec order regardless of worker
    completion order, and reports are strict JSON.
"""
import json
import time

import pytest

from benchmarks import campaign
from benchmarks.campaign import CampaignConfig, cell_key, code_fingerprint
from benchmarks.common import CELL_KINDS, cell_kind, spec_env

CALLS = []  # (kind, payload) per executed cell — inline/thread executors only


@cell_kind("t_echo")
def _t_echo(payload, sleep: float = 0.0):
    if sleep:
        time.sleep(sleep)
    CALLS.append(("t_echo", payload))
    return {"payload": payload, "doubled": payload * 2}


@cell_kind("t_nocache", cache=False)
def _t_nocache(payload):
    CALLS.append(("t_nocache", payload))
    return {"payload": payload}


def _cfg(tmp_path, **kw):
    kw.setdefault("cache_dir", str(tmp_path / "cache"))
    kw.setdefault("executor", "inline")
    return CampaignConfig(**kw)


def _specs(n):
    return [{"kind": "t_echo", "payload": i} for i in range(n)]


# ---------------------------------------------------------------------------
# hit / miss
# ---------------------------------------------------------------------------


def test_cold_run_computes_every_cell(tmp_path):
    CALLS.clear()
    out = campaign.run_campaign(_specs(3), _cfg(tmp_path), fingerprint="fp")
    assert [r["doubled"] for r in out.results] == [0, 2, 4]
    assert out.recomputed == 3 and out.hits == 0
    assert len(CALLS) == 3


def test_warm_rerun_recomputes_zero_cells(tmp_path):
    cfg = _cfg(tmp_path)
    campaign.run_campaign(_specs(3), cfg, fingerprint="fp")
    CALLS.clear()
    out = campaign.run_campaign(_specs(3), cfg, fingerprint="fp")
    assert out.hits == 3 and out.recomputed == 0
    assert CALLS == []
    assert [r["doubled"] for r in out.results] == [0, 2, 4]


def test_config_change_misses_only_changed_cell(tmp_path):
    cfg = _cfg(tmp_path)
    campaign.run_campaign(_specs(3), cfg, fingerprint="fp")
    CALLS.clear()
    specs = _specs(3)
    specs[1]["payload"] = 99  # one changed cell
    out = campaign.run_campaign(specs, cfg, fingerprint="fp")
    assert out.hits == 2 and out.recomputed == 1
    assert CALLS == [("t_echo", 99)]
    assert out.results[1]["doubled"] == 198


def test_code_fingerprint_change_invalidates_everything(tmp_path):
    cfg = _cfg(tmp_path)
    campaign.run_campaign(_specs(3), cfg, fingerprint="fp-old")
    CALLS.clear()
    out = campaign.run_campaign(_specs(3), cfg, fingerprint="fp-new")
    assert out.hits == 0 and out.recomputed == 3
    assert len(CALLS) == 3


def test_code_fingerprint_tracks_sources_not_docs(tmp_path):
    """The real fingerprint hashes result-defining sources only — a tree
    with identical sources but different docs fingerprints identically."""
    root = tmp_path / "repo"
    (root / "src" / "repro").mkdir(parents=True)
    (root / "benchmarks").mkdir()
    (root / "src" / "repro" / "a.py").write_text("x = 1\n")
    (root / "benchmarks" / "common.py").write_text("y = 2\n")
    (root / "benchmarks" / "bench_fused.py").write_text("z = 3\n")
    (root / "benchmarks" / "bench_shard_runtime.py").write_text("w = 4\n")
    (root / "README.md").write_text("v1")
    fp1 = code_fingerprint(root=root)
    (root / "README.md").write_text("v2 — docs only")
    assert code_fingerprint(root=root) == fp1
    (root / "src" / "repro" / "a.py").write_text("x = 2\n")
    assert code_fingerprint(root=root) != fp1


def test_uncacheable_kind_always_recomputes(tmp_path):
    cfg = _cfg(tmp_path)
    specs = [{"kind": "t_nocache", "payload": 7}]
    campaign.run_campaign(specs, cfg, fingerprint="fp")
    CALLS.clear()
    out = campaign.run_campaign(specs, cfg, fingerprint="fp")
    assert out.hits == 0 and len(CALLS) == 1


# ---------------------------------------------------------------------------
# resume after interrupt
# ---------------------------------------------------------------------------


def _cache_file(cfg, spec, fingerprint):
    key = cell_key(spec, fingerprint, spec_env(spec))
    return campaign._cache_path(cfg, key)


def test_resume_recomputes_only_missing_and_corrupt_cells(tmp_path):
    cfg = _cfg(tmp_path)
    specs = _specs(4)
    campaign.run_campaign(specs, cfg, fingerprint="fp")
    # simulate an interrupt: one cell never finished (file absent), one was
    # killed mid-write (truncated JSON)
    _cache_file(cfg, specs[0], "fp").unlink()
    _cache_file(cfg, specs[2], "fp").write_text('{"key": "trunc')
    CALLS.clear()
    out = campaign.run_campaign(specs, cfg, fingerprint="fp")
    assert out.hits == 2 and out.recomputed == 2
    assert sorted(p for _, p in CALLS) == [0, 2]
    assert [r["doubled"] for r in out.results] == [0, 2, 4, 6]


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------


def test_report_order_follows_specs_not_completion(tmp_path):
    """Thread executor + inverted sleep times: late specs complete first,
    the report must still list cells in spec order."""
    specs = [
        {"kind": "t_echo", "payload": i, "sleep": 0.05 * (4 - i)}
        for i in range(5)
    ]
    report_path = tmp_path / "report.json"
    out = campaign.run_campaign(
        specs,
        _cfg(tmp_path, executor="thread", workers=4,
             report_path=str(report_path), report_every_s=0.0),
        fingerprint="fp",
    )
    assert [c["spec"]["payload"] for c in out.report()["cells"]] == [0, 1, 2, 3, 4]
    on_disk = json.loads(report_path.read_text())
    assert [c["spec"]["payload"] for c in on_disk["cells"]] == [0, 1, 2, 3, 4]
    assert on_disk["meta"]["recomputed"] == 5


def test_report_is_strict_json(tmp_path):
    @cell_kind("t_inf")
    def _t_inf(payload):  # noqa: F811 — registered once per session
        return {"value": float("inf"), "nan": float("nan"), "ok": 1.0}

    try:
        report_path = tmp_path / "report.json"
        campaign.run_campaign(
            [{"kind": "t_inf", "payload": 0}],
            _cfg(tmp_path, report_path=str(report_path)),
            fingerprint="fp",
        )
        def reject(_):
            raise AssertionError("non-RFC8259 constant in report")

        rep = json.loads(report_path.read_text(), parse_constant=reject)
        assert rep["cells"][0]["result"] == {"value": None, "nan": None, "ok": 1.0}
    finally:
        CELL_KINDS.pop("t_inf", None)


def test_identical_reruns_produce_identical_cells(tmp_path):
    cfg = _cfg(tmp_path)
    a = campaign.run_campaign(_specs(4), cfg, fingerprint="fp").report()
    b = campaign.run_campaign(_specs(4), cfg, fingerprint="fp").report()

    def content(rep):  # the cached flag legitimately flips cold → warm
        return [{k: v for k, v in c.items() if k != "cached"}
                for c in rep["cells"]]

    assert content(a) == content(b)


def test_failing_cell_aborts_with_spec_named(tmp_path):
    @cell_kind("t_boom")
    def _t_boom(payload):
        raise ValueError("boom")

    try:
        with pytest.raises(ValueError, match="boom"):
            campaign.run_campaign(
                [{"kind": "t_boom", "payload": 1}], _cfg(tmp_path),
                fingerprint="fp",
            )
    finally:
        CELL_KINDS.pop("t_boom", None)


# ---------------------------------------------------------------------------
# process pool (real fork workers, real cell kind)
# ---------------------------------------------------------------------------


def test_process_pool_executes_and_caches_real_cells(tmp_path):
    specs = [
        {"kind": "reliability_run", "family": "pagerank",
         "protocol": "pfait", "scenario": "stable", "seed": s,
         "eps": 1e-4, "max_iters": 400, "problem": {"n": 64, "p": 4},
         "residual_stride": 0}
        for s in range(3)
    ]
    cfg = _cfg(tmp_path, executor="process", workers=2)
    out = campaign.run_campaign(specs, cfg)
    assert out.recomputed == 3
    assert all(r["status"] == "ok" for r in out.results)
    warm = campaign.run_campaign(specs, cfg)
    assert warm.hits == 3 and warm.recomputed == 0
    assert warm.results == out.results
