"""Elastic fault-injection matrix: crash, join and checkpoint-restart
survival of protocol-free detection, at both layers of the repo.

Two cell kinds, both via the campaign cell API (benchmarks/common.py):

1. **event** (``elastic_event``, cached) — the event-level simulator runs
   every termination protocol through dynamic-membership scenarios
   (``core.scenarios.elastic_scenarios``: crashes, late joins,
   checkpoint-restarts, churn) and the PR-2 oracle scores each detection
   against the *active-subsystem* residual (``exact_active_residual``):
   a crashed worker's block is frozen boundary data (Daggitt & Griffin),
   so the survivors' fixed point — not the original full-membership one —
   is the ground truth.  Acceptance: **zero false detections for the
   snapshot-class protocols in every cell**, and every cell terminates.
2. **device** (``elastic_device``, cached per jax version) — the shard
   runtime dies mid-solve: a `FaultPlan` kills real mesh shards, the live
   `HeartbeatMonitor` control loop detects the stall, `plan_restart` +
   `shrink_to_fit` rebuild a smaller mesh, the last committed checkpoint
   restores onto it and iteration resumes under the *unchanged* detection
   monitor (``runtime.elastic.run_elastic``).  Each cell reports detection
   reliability (oracle-scored final exact residual) **and** recovery cost
   (stalled segments, rolled-back iterations, heartbeat latency); the
   ``none`` scenario of each (family, reduction, mode, seed) lane is the
   uninterrupted reference the overhead summary is computed against.

Writes ``BENCH_elastic.json`` (repo root) or the smoke variant the
``elastic-smoke`` CI job gates against ``benchmarks/baselines/``.

Run:   PYTHONPATH=src:. python benchmarks/bench_elastic.py
Smoke: PYTHONPATH=src:. SHARD_DEVICES=4 python benchmarks/bench_elastic.py --smoke
"""
from __future__ import annotations

import os

# the device cells need >1 device; must be set before any jax import (see
# bench_shard_runtime.py for why this appends rather than setdefaults)
_DEV = int(os.environ.get("SHARD_DEVICES", "4"))
_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FLAG}={_DEV}").strip()
for _v in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS", "MKL_NUM_THREADS"):
    os.environ.setdefault(_v, "1")

import argparse
import dataclasses
import tempfile
import time
from typing import Dict

#: protocols of the event matrix — every detector in core.protocols
EVENT_PROTOCOLS = ("pfait", "rdub", "nfais2", "nfais5", "exact")
#: protocols whose detection carries a certified snapshot claim: these must
#: never fire falsely, crash or no crash (the headline acceptance bar)
SNAPSHOT_PROTOCOLS = ("nfais2", "nfais5", "exact", "rdub")


# ---------------------------------------------------------------------------
# Cell 1: event-level elastic matrix (protocol × scenario × seed)
# ---------------------------------------------------------------------------


def elastic_event(family: str, protocol: str, scenario: str, seed: int,
                  eps: float, max_iters: int, problem: Dict,
                  compute_base: float = 1e-3, residual_stride: int = 25,
                  factor: float = 10.0) -> Dict:
    """One traced engine run through a dynamic-membership scenario,
    oracle-scored against the active-subsystem residual."""
    from benchmarks.common import _finite, make_problem_cached, make_protocol
    from repro.core.async_engine import PLATFORMS
    from repro.core.reliability import detection_report, run_traced
    from repro.core.scenarios import elastic_scenarios

    spec = elastic_scenarios(compute_base)[scenario]
    cfg = dataclasses.replace(
        PLATFORMS[spec.platform](compute_base),
        seed=seed, max_iters=max_iters,
        fifo=(protocol == "exact"), scenario=spec.scenario,
    )
    res, rec = run_traced(
        lambda: make_problem_cached(family, seed=seed, **problem),
        cfg,
        lambda pr: make_protocol(protocol, eps, pr.ord),
        residual_stride=residual_stride,
        record_sends=False,
    )
    rep = detection_report(rec, eps, factor=factor)
    return {
        "status": "ok",
        "family": family, "protocol": protocol, "scenario": scenario,
        "seed": seed,
        "terminated": res.terminated,
        "membership_changes": int(rep.membership_changes),
        "detected_residual": _finite(rep.detected_residual),
        "true_at_detect": _finite(rep.true_at_detect),
        "active_residual": _finite(rep.active_residual),
        "certified_residual": _finite(rep.certified_residual),
        "claim": rep.claim,
        "overshoot": _finite(rep.overshoot),
        "false_detection": rep.false_detection,
        "latency_overhead": _finite(rep.latency_overhead),
        "k_max": res.k_max,
        "r_star": _finite(res.r_star),
    }


# ---------------------------------------------------------------------------
# Cell 2: device-level elastic runs (reduction × mode × fault plan × seed)
# ---------------------------------------------------------------------------


def device_plans(p0: int) -> Dict[str, "object"]:
    """Named fault plans of the device matrix, scaled to ``p0`` shards.
    Segments are the control-loop quantum of ``run_elastic``; the plans
    strike early enough that every solve is still far from converged."""
    from repro.runtime.elastic import FaultPlan

    last = p0 - 1
    return {
        # uninterrupted reference lane (recovery overhead baseline)
        "none": FaultPlan(),
        # kill one shard mid-solve: stall -> heartbeat -> shrink -> restore
        "crash": FaultPlan(crash_at={1: 3}),
        # standby shard arrives: hot scale-up from live state, no rollback
        "join": FaultPlan(join_at={p0: 2}),
        # crash, then the repaired worker returns: mesh p0 -> p' -> p0
        "crash_rejoin": FaultPlan(crash_at={1: 3}, join_at={1: 8}),
        # persistent straggler: flagged by the quantile policy, never killed
        "slow": FaultPlan(slow={last: 3.0}),
    }


def elastic_device(family: str, reduction: str, mode: str, scenario: str,
                   seed: int, n: int, p0: int, eps_tilde: float,
                   margin: float = 10.0, staleness: int = 2,
                   persistence: int = 4, segment_len: int = 10,
                   ckpt_every: int = 2, max_segments: int = 60,
                   factor: float = 10.0) -> Dict:
    """One elastic shard-runtime run through a named fault plan.  Detection
    is scored like the reliability oracle (final exact residual within
    ``factor × ε̃``); recovery cost comes from the driver's report."""
    from benchmarks.bench_shard_runtime import (
        _convdiff_exact_residual,
        _convdiff_setup,
        _ensure_x64,
        _monitor,
        _pagerank_setup,
    )

    _ensure_x64()
    import numpy as np

    from repro.runtime import elastic
    from repro.runtime.shard_runtime import ShardRuntimeConfig

    ord_ = 2.0 if family == "convdiff" else 1.0
    mon = _monitor(mode, eps_tilde, margin, staleness, persistence, ord_)
    cfg = ShardRuntimeConfig(
        monitor=mon, reduction=reduction,
        # scalar per-shard fields: the shard count changes mid-run
        inner_sweeps=2, halo_delay=1,
        contrib_lag=1 if reduction == "nonblocking" else 0,
    )
    plan = device_plans(p0)[scenario]
    st = damping = None
    if family == "convdiff":
        st, b, x0 = _convdiff_setup(n, seed=seed)
        arg = b
    else:
        prob, arg, x0 = _pagerank_setup(n, p0, seed=seed)
        damping = prob.d
    with tempfile.TemporaryDirectory(prefix="elastic_ckpt_") as ckpt_dir:
        rep = elastic.run_elastic(
            family, cfg, n, np.asarray(x0), np.asarray(arg), plan, ckpt_dir,
            stencil=st, damping=(damping if damping is not None else 0.85),
            p0=p0, segment_len=segment_len, ckpt_every=ckpt_every,
            max_segments=max_segments)
    if family == "convdiff":
        r_star = _convdiff_exact_residual(st, rep.x, b, ord_)
    else:
        xs = np.asarray(rep.x, dtype=np.float64)
        rv = prob.d * (np.asarray(arg, np.float64) @ xs) + prob.v - xs
        r_star = float(np.sum(np.abs(rv) ** ord_) ** (1.0 / ord_))
    return {
        "family": family, "reduction": reduction, "mode": mode,
        "scenario": scenario, "seed": seed, "n": n, "p0": p0,
        "eps_tilde": eps_tilde, "eps": mon.eps,
        "terminated": bool(rep.converged),
        "detected_residual": (float(rep.detected_residual)
                              if rep.converged else None),
        "r_star": r_star,
        "false_detection": bool(rep.converged
                                and r_star > factor * eps_tilde),
        "outer_iters": int(rep.outer_iters),
        "segments_run": int(rep.segments_run),
        "restarts": int(rep.restarts),
        "stall_segments": int(rep.stall_segments),
        "lost_iters": int(rep.lost_iters),
        "detect_latency": [float(v) for v in rep.detect_latency],
        "checkpoint_saves": int(rep.checkpoint_saves),
        "mesh_history": [[int(s), int(p)] for s, p in rep.mesh_history],
        "members_final": [int(w) for w in rep.members_final],
        "stragglers_flagged": [int(w) for w in rep.stragglers_flagged],
    }


# ---------------------------------------------------------------------------
# Campaign assembly
# ---------------------------------------------------------------------------


def _run(specs, runner=None):
    from benchmarks import campaign
    from benchmarks.campaign import CampaignConfig

    runner = runner or (lambda s: campaign.map_cells(
        s, CampaignConfig(executor="inline")))
    return runner(specs)


def _overhead(rows) -> Dict:
    """Recovery cost of each fault lane vs its uninterrupted reference:
    extra outer iterations to convergence (work overhead) and segments
    lost to stalls + rollback (availability overhead)."""
    ref = {(r["family"], r["reduction"], r["mode"], r["seed"]):
           r for r in rows if r["scenario"] == "none"}
    out = {}
    for r in rows:
        if r["scenario"] == "none" or not r["terminated"]:
            continue
        base = ref.get((r["family"], r["reduction"], r["mode"], r["seed"]))
        if base is None or not base["terminated"]:
            continue
        key = f"{r['family']}/{r['reduction']}/{r['mode']}/{r['scenario']}/s{r['seed']}"
        out[key] = {
            "extra_outer_iters": r["outer_iters"] - base["outer_iters"],
            "lost_iters": r["lost_iters"],
            "stall_segments": r["stall_segments"],
            "extra_segments": r["segments_run"] - base["segments_run"],
        }
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + reduced matrix (CI)")
    ap.add_argument("--out", default="BENCH_elastic.json")
    args = ap.parse_args()

    import jax

    jax.config.update("jax_enable_x64", True)
    p0 = len(jax.devices())
    if p0 != _DEV:
        raise SystemExit(
            f"expected {_DEV} devices (SHARD_DEVICES), jax sees {p0} — "
            f"XLA_FLAGS={os.environ.get('XLA_FLAGS')!r} was not honoured "
            "(set before any jax import?)")

    if args.smoke:
        event_scenarios = ("crash_early", "crash_restart", "join_late")
        event_seeds = (1,)
        device_families = ("convdiff",)
        device_scenarios = ("none", "crash", "crash_rejoin")
        device_reductions = ("nonblocking",)
        device_modes = ("pfait", "nfais2")
        device_seeds = (0,)
    else:
        event_scenarios = ("crash_early", "crash_late", "crash_two",
                           "join_late", "crash_restart", "churn")
        event_seeds = (0, 1, 2, 3)
        device_families = ("convdiff", "pagerank")
        device_scenarios = ("none", "crash", "join", "crash_rejoin", "slow")
        device_reductions = ("nonblocking", "rdoubling")
        device_modes = ("pfait", "nfais2")
        device_seeds = (0, 1)

    event_specs = [
        {"kind": "elastic_event", "family": "convdiff", "protocol": proto,
         "scenario": scen, "seed": seed, "eps": 1e-6, "max_iters": 6000,
         "problem": {"n": 12, "p": 4, "rho": 0.9}}
        for proto in EVENT_PROTOCOLS
        for scen in event_scenarios
        for seed in event_seeds
    ]
    event_rows = _run(event_specs)

    n_cd, n_pr = 24, 240
    device_specs = [
        {"kind": "elastic_device", "family": fam, "reduction": red,
         "mode": mode, "scenario": scen, "seed": seed,
         "n": (n_cd if fam == "convdiff" else n_pr), "p0": p0,
         "eps_tilde": 1e-6 if fam == "convdiff" else 1e-8,
         "margin": 10.0, "staleness": 2, "persistence": 4,
         "segment_len": 10, "ckpt_every": 2, "max_segments": 60}
        for fam in device_families
        for red in device_reductions
        for mode in device_modes
        for scen in device_scenarios
        for seed in device_seeds
    ]
    device_rows = _run(device_specs)
    overhead = _overhead(device_rows)

    report = {
        "event": event_rows,
        "device": device_rows,
        "recovery_overhead": overhead,
        "meta": {"smoke": bool(args.smoke), "devices": p0,
                 "jax": jax.__version__,
                 "timestamp": time.strftime("%Y-%m-%d %H:%M:%S")},
    }
    from benchmarks.campaign import write_json_atomic

    write_json_atomic(args.out, report)

    # -- summary + in-script acceptance ------------------------------------
    failures = []
    ev_undet = [r for r in event_rows if not r["terminated"]]
    ev_false = [r for r in event_rows if r["false_detection"]]
    ev_false_snap = [r for r in ev_false
                     if r["protocol"] in SNAPSHOT_PROTOCOLS]
    mem = sum(r["membership_changes"] for r in event_rows)
    print(f"event: {len(event_rows)} cells "
          f"({len(EVENT_PROTOCOLS)} protocols x {len(event_scenarios)} "
          f"scenarios x {len(event_seeds)} seeds), "
          f"{mem} membership changes scored, "
          f"{len(ev_false)} false ({len(ev_false_snap)} snapshot-class), "
          f"{len(ev_undet)} undetected")
    if ev_undet:
        failures.append(f"{len(ev_undet)} event cells undetected")
    if ev_false_snap:
        failures.append(
            f"{len(ev_false_snap)} snapshot-class false detections")
    dv_undet = [r for r in device_rows if not r["terminated"]]
    dv_false = [r for r in device_rows if r["false_detection"]]
    crashes = [r for r in device_rows
               if r["scenario"] in ("crash", "crash_rejoin")]
    no_restart = [r for r in crashes if r["restarts"] < 1]
    print(f"device: {len(device_rows)} cells, {len(dv_false)} false, "
          f"{len(dv_undet)} undetected; "
          f"{sum(r['restarts'] for r in device_rows)} restarts, "
          f"{sum(r['stall_segments'] for r in device_rows)} stall segments, "
          f"{sum(r['lost_iters'] for r in device_rows)} iters rolled back")
    for key, ov in sorted(overhead.items()):
        print(f"  overhead {key}: +{ov['extra_outer_iters']} outer, "
              f"{ov['stall_segments']} stalled, "
              f"{ov['lost_iters']} rolled back")
    if dv_undet:
        failures.append(f"{len(dv_undet)} device cells undetected")
    if dv_false:
        failures.append(f"{len(dv_false)} device false detections")
    if no_restart:
        failures.append(
            f"{len(no_restart)} crash cells never exercised restart")
    print(f"wrote {args.out}")
    if failures:
        raise SystemExit("elastic acceptance failed: " + "; ".join(failures))
    print("acceptance ok")


if __name__ == "__main__":
    main()
