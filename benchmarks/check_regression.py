"""Perf-regression gate: compare fresh smoke benchmarks against committed
baselines and fail (exit 1) when a metric regresses beyond its stated
tolerance.

Metrics and tolerances (the CI contract):

* ``fused_smoke`` (BENCH_fused_smoke.json):
  - ``event_sim.wall_speedup`` — the fused/unfused event-sim wall-time
    ratio, one-sided floor at −30%.  A *ratio* of two times measured in
    the same process, so it transfers across runner hardware; the floor
    covers shared-runner noise while still catching a lost fusion, and a
    runner measuring a *better* ratio than the baseline never fails.
  - ``sharded[*].{unfused,fused}.hbm_bytes_per_device_per_sweep`` — exact
    match.  HLO-derived byte counts are deterministic for a pinned jax
    version; ANY drift means the lowering changed and the baseline must be
    regenerated deliberately (the gate runs only on the pinned-jax CI leg).

* ``reliability_smoke`` (BENCH_reliability_smoke.json):
  - per-cell ``false_rate`` / ``undetected_rate`` — exact (seeded runs are
    deterministic), plus the acceptance invariants must hold.

* ``shard_smoke`` (BENCH_shard_smoke.json):
  - parity booleans (sync trajectory vs global reference, detection point
    vs the sharded driver) — exact,
  - per-cell ``terminated`` / ``false_detection`` of the asynchronous
    detection matrix — exact (seeded, deterministic device programs),
  - ``hbm.*.hbm_bytes_per_device_per_iter`` — exact (pinned-jax lowering),
  - ``walltime.saving_nonblocking_vs_blocking`` — one-sided floor at −30%
    (median-of-round ratios; shared-runner noise, same contract as
    ``fused_smoke``'s wall speedup).

* ``elastic_smoke`` (BENCH_elastic_smoke.json):
  - per-cell ``terminated`` / ``false_detection`` of the dynamic-membership
    event matrix AND the fault-injected device matrix — exact (seeded,
    deterministic runs), plus event ``membership_changes`` exact (the
    scenario's full crash/join/restore sequence must land before
    detection — a drift means the cell stopped exercising elasticity),
  - device ``restarts`` / ``stall_segments`` — exact (the crash → heartbeat
    → shrink → restore cycle is deterministic in segment time),
  - device ``lost_iters`` — one-sided *ceiling* at +30%: rolled-back work
    is the recovery cost; paying more than the baseline is the regression,
    recovering cheaper is not.

* ``ml_smoke`` (BENCH_ml_smoke.json):
  - per-cell ``terminated`` / ``false_detection`` of the ML event protocol
    matrix AND the async-SGD train matrix — exact (seeded, deterministic),
  - train ``oracle_consistent`` — exact: the protocol-free detection round
    must stay within the synchronized-eval oracle's decade,
  - train ``detected_round`` — exact (seeded device programs are
    deterministic; a drifting round means the monitor wiring changed).

* ``mesh_smoke`` (BENCH_mesh_smoke.json):
  - per-mesh parity booleans (``trajectory_ok`` vs the global reference,
    ``overlap_bitwise_ok`` — comm-overlapped run bitwise-identical to the
    non-overlapped one under heterogeneous knobs) — exact,
  - per-cell ``terminated`` / ``false_detection`` of the mesh-shape ×
    reduction × monitor detection matrix — exact (seeded, deterministic),
  - ``hbm.*.hbm_bytes_per_device_per_iter`` per variant — exact
    (pinned-jax lowering; the overlap variant must stay the cheapest,
    which the bench itself asserts before writing the report),
  - ``walltime.saving_2d_vs_1d`` and ``walltime.saving_overlap2d_vs_1d``
    — one-sided floors at −30%.  The 2-D saving is the tentpole perf
    claim; the overlap saving is < 1 on host-emulated devices (serial
    collectives leave no latency to hide) and is tracked as a regression
    floor against the committed baseline rather than an absolute target.

* ``replay_smoke`` (BENCH_replay_smoke.json):
  - measured ``detect_step_ok`` / ``wall_within_20pct`` booleans and both
    detection steps (recorded + predicted) — exact: the ISSUE acceptance
    (wall within ±20%, detection exact or ±1 round) must keep holding, and
    the seeded device programs pin the detection steps; the raw wall
    *values* are shared-runner noise and are never gated,
  - what-if rows (``predicted_wall_s`` rounded, detection step, outer
    iters, staleness) — exact: pure-numpy deterministic extrapolation,
  - calibration fit structure (``dist``, ``n``) — exact; the KS statistic
    itself is measurement noise and is not gated.

* ``serve_smoke`` (BENCH_serve_smoke.json):
  - load-cell counters ``served`` / ``rejected`` / ``shed`` / ``timeouts``
    / ``false_detections`` / ``compile_count`` / ``warm_hits`` / ``ticks``
    — exact: the detection service is deterministic in the tick domain for
    a seeded Poisson schedule, and a drifting compile count means the
    warm-executable signature sharing broke,
  - nearest-rank latency percentiles (``ttd_ticks`` p50/p95/p99,
    ``queue_wait_ticks`` p50/p95) and ``detect_steps_sum`` — exact: ticks
    and detection steps are device-program outputs under the pinned jax
    version; wall seconds / tenants-per-second are reported, never gated,
  - same contract per rate-sweep row, keyed by arrival rate.

Usage:
  python benchmarks/check_regression.py fused_smoke \
      --baseline benchmarks/baselines/BENCH_fused_smoke.json \
      --fresh /tmp/BENCH_fused_smoke.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterator, Tuple

Check = Tuple[str, float, float, str, float]  # name, base, fresh, mode, tol


def _fused_smoke(base: Dict, fresh: Dict) -> Iterator[Check]:
    # one-sided: only a LOSS of fused speedup is a regression — runner
    # hardware measuring a better ratio than the committed baseline must
    # not fail the gate (regenerate baselines from a CI-runner artifact if
    # the fleet drifts)
    yield (
        "event_sim.wall_speedup",
        base["event_sim"]["wall_speedup"],
        fresh["event_sim"]["wall_speedup"],
        "floor",
        0.30,
    )
    base_rows = {r["sweep"]: r for r in base["sharded"]}
    fresh_rows = {r["sweep"]: r for r in fresh["sharded"]}
    for sweep, brow in sorted(base_rows.items()):
        frow = fresh_rows[sweep]
        for leg in ("unfused", "fused"):
            yield (
                f"sharded.{sweep}.{leg}.hbm_bytes_per_device_per_sweep",
                brow[leg]["hbm_bytes_per_device_per_sweep"],
                frow[leg]["hbm_bytes_per_device_per_sweep"],
                "exact",
                0.0,
            )


def _reliability_smoke(base: Dict, fresh: Dict) -> Iterator[Check]:
    def cells(rep):
        return {(c["problem"], c["scenario"], c["protocol"]): c for c in rep["cells"]}

    fresh_cells = cells(fresh)
    for key, bcell in sorted(cells(base).items()):
        fcell = fresh_cells[key]
        name = "/".join(key)
        if bcell["status"] != "ok":
            continue
        yield (f"{name}.false_rate", bcell["false_rate"], fcell["false_rate"], "exact", 0.0)
        yield (
            f"{name}.undetected_rate",
            bcell["undetected_rate"],
            fcell["undetected_rate"],
            "exact",
            0.0,
        )
    yield (
        "acceptance.ok",
        float(base["acceptance"]["ok"]),
        float(fresh["acceptance"]["ok"]),
        "exact",
        0.0,
    )


def _shard_smoke(base: Dict, fresh: Dict) -> Iterator[Check]:
    for fam, brow in sorted(base["parity"].items()):
        frow = fresh["parity"][fam]
        yield (
            f"parity.{fam}.trajectory_ok",
            float(brow["trajectory_ok"]),
            float(frow["trajectory_ok"]),
            "exact",
            0.0,
        )
        if "driver_match" in brow:
            yield (
                f"parity.{fam}.driver_match",
                float(brow["driver_match"]),
                float(frow["driver_match"]),
                "exact",
                0.0,
            )

    def detect_cells(rep):
        return {
            (c["family"], c["reduction"], c["mode"], c["preset"], c["seed"]): c
            for c in rep["detect"]
        }

    fresh_cells = detect_cells(fresh)
    for key, bcell in sorted(detect_cells(base).items()):
        fcell = fresh_cells[key]
        name = "/".join(str(k) for k in key)
        yield (
            f"detect.{name}.terminated",
            float(bcell["terminated"]),
            float(fcell["terminated"]),
            "exact",
            0.0,
        )
        yield (
            f"detect.{name}.false_detection",
            float(bcell["false_detection"]),
            float(fcell["false_detection"]),
            "exact",
            0.0,
        )

    for red in ("blocking", "nonblocking", "rdoubling"):
        yield (
            f"hbm.{red}.hbm_bytes_per_device_per_iter",
            base["hbm"][red]["hbm_bytes_per_device_per_iter"],
            fresh["hbm"][red]["hbm_bytes_per_device_per_iter"],
            "exact",
            0.0,
        )
    yield (
        "walltime.saving_nonblocking_vs_blocking",
        base["walltime"]["saving_nonblocking_vs_blocking"],
        fresh["walltime"]["saving_nonblocking_vs_blocking"],
        "floor",
        0.30,
    )


def _mesh_smoke(base: Dict, fresh: Dict) -> Iterator[Check]:
    for name, brow in sorted(base["parity"].items()):
        frow = fresh["parity"][name]
        yield (f"parity.{name}.trajectory_ok", float(brow["trajectory_ok"]),
               float(frow["trajectory_ok"]), "exact", 0.0)
        yield (f"parity.{name}.overlap_bitwise_ok",
               float(brow["overlap_bitwise_ok"]),
               float(frow["overlap_bitwise_ok"]), "exact", 0.0)

    def detect_cells(rep):
        return {
            ("x".join(str(s) for s in c["mesh_shape"]), c["reduction"],
             c["mode"], c["seed"]): c
            for c in rep["detect"]
        }

    fresh_cells = detect_cells(fresh)
    for key, bcell in sorted(detect_cells(base).items()):
        fcell = fresh_cells[key]
        name = "/".join(str(k) for k in key)
        yield (f"detect.{name}.terminated", float(bcell["terminated"]),
               float(fcell["terminated"]), "exact", 0.0)
        yield (f"detect.{name}.false_detection",
               float(bcell["false_detection"]),
               float(fcell["false_detection"]), "exact", 0.0)

    for variant in ("1d", "2d", "2d_overlap"):
        yield (
            f"hbm.{variant}.hbm_bytes_per_device_per_iter",
            base["hbm"][variant]["hbm_bytes_per_device_per_iter"],
            fresh["hbm"][variant]["hbm_bytes_per_device_per_iter"],
            "exact",
            0.0,
        )
    # the tentpole wall claim (2-D beats the 1-D pencil) plus the tracked
    # overlap ratio — both median-of-round ratios, so they transfer across
    # runner hardware; only a LOSS vs the baseline fails
    for metric in ("saving_2d_vs_1d", "saving_overlap2d_vs_1d"):
        yield (
            f"walltime.{metric}",
            base["walltime"][metric],
            fresh["walltime"][metric],
            "floor",
            0.30,
        )


def _elastic_smoke(base: Dict, fresh: Dict) -> Iterator[Check]:
    def event_cells(rep):
        return {(c["protocol"], c["scenario"], c["seed"]): c
                for c in rep["event"]}

    fresh_ev = event_cells(fresh)
    for key, bcell in sorted(event_cells(base).items()):
        fcell = fresh_ev[key]
        name = "/".join(str(k) for k in key)
        yield (f"event.{name}.terminated", float(bcell["terminated"]),
               float(fcell["terminated"]), "exact", 0.0)
        yield (f"event.{name}.false_detection",
               float(bcell["false_detection"]),
               float(fcell["false_detection"]), "exact", 0.0)
        # the scenario's whole membership sequence must still land before
        # detection — fewer changes means the cell degenerated into a
        # static run and stopped testing elasticity
        yield (f"event.{name}.membership_changes",
               float(bcell["membership_changes"]),
               float(fcell["membership_changes"]), "exact", 0.0)

    def device_cells(rep):
        return {(c["family"], c["reduction"], c["mode"], c["scenario"],
                 c["seed"]): c for c in rep["device"]}

    fresh_dv = device_cells(fresh)
    for key, bcell in sorted(device_cells(base).items()):
        fcell = fresh_dv[key]
        name = "/".join(str(k) for k in key)
        yield (f"device.{name}.terminated", float(bcell["terminated"]),
               float(fcell["terminated"]), "exact", 0.0)
        yield (f"device.{name}.false_detection",
               float(bcell["false_detection"]),
               float(fcell["false_detection"]), "exact", 0.0)
        yield (f"device.{name}.restarts", float(bcell["restarts"]),
               float(fcell["restarts"]), "exact", 0.0)
        yield (f"device.{name}.stall_segments",
               float(bcell["stall_segments"]),
               float(fcell["stall_segments"]), "exact", 0.0)
        if bcell["restarts"]:
            # recovery cost: rolling back MORE work than the baseline is
            # the regression; recovering cheaper never fails the gate
            yield (f"device.{name}.lost_iters", float(bcell["lost_iters"]),
                   float(fcell["lost_iters"]), "ceil", 0.30)


def _ml_smoke(base: Dict, fresh: Dict) -> Iterator[Check]:
    def event_cells(rep):
        return {(c["task"], c["protocol"], c["seed"]): c
                for c in rep["event"]}

    fresh_ev = event_cells(fresh)
    for key, bcell in sorted(event_cells(base).items()):
        fcell = fresh_ev[key]
        name = "/".join(str(k) for k in key)
        yield (f"event.{name}.terminated", float(bcell["terminated"]),
               float(fcell["terminated"]), "exact", 0.0)
        yield (f"event.{name}.false_detection",
               float(bcell["false_detection"]),
               float(fcell["false_detection"]), "exact", 0.0)

    def train_cells(rep):
        return {(c["task"], c["reduction"], c["mode"], c["seed"]): c
                for c in rep["train"]}

    fresh_tr = train_cells(fresh)
    for key, bcell in sorted(train_cells(base).items()):
        fcell = fresh_tr[key]
        name = "/".join(str(k) for k in key)
        yield (f"train.{name}.terminated", float(bcell["terminated"]),
               float(fcell["terminated"]), "exact", 0.0)
        yield (f"train.{name}.false_detection",
               float(bcell["false_detection"]),
               float(fcell["false_detection"]), "exact", 0.0)
        # the headline claim: the protocol-free detection round stays
        # within the synchronized-eval oracle's decade
        yield (f"train.{name}.oracle_consistent",
               float(bcell["oracle_consistent"]),
               float(fcell["oracle_consistent"]), "exact", 0.0)
        # seeded device programs are deterministic: the detection round
        # itself must not drift
        yield (f"train.{name}.detected_round",
               float(bcell["detected_round"] or -1),
               float(fcell["detected_round"] or -1), "exact", 0.0)


def _replay_smoke(base: Dict, fresh: Dict) -> Iterator[Check]:
    def measured_cells(rep):
        return {(c["reduction"], c["p"]): c for c in rep["measured"]}

    fresh_ms = measured_cells(fresh)
    for key, bcell in sorted(measured_cells(base).items()):
        fcell = fresh_ms[key]
        name = "/".join(str(k) for k in key)
        # the ISSUE acceptance booleans must keep holding; the raw walls
        # are shared-runner noise and are reported but never gated
        yield (
            f"measured.{name}.detect_step_ok",
            float(bcell["detect_step_ok"]),
            float(fcell["detect_step_ok"]),
            "exact",
            0.0,
        )
        yield (
            f"measured.{name}.wall_within_20pct",
            float(bcell["wall_within_20pct"]),
            float(fcell["wall_within_20pct"]),
            "exact",
            0.0,
        )
        # seeded device programs: the detection step itself must not drift,
        # and the replay must keep reproducing it
        yield (
            f"measured.{name}.recorded_detect_step",
            float(bcell["recorded_detect_step"] or -1),
            float(fcell["recorded_detect_step"] or -1),
            "exact",
            0.0,
        )
        yield (
            f"measured.{name}.predicted_detect_step",
            float(bcell["predicted_detect_step"] or -1),
            float(fcell["predicted_detect_step"] or -1),
            "exact",
            0.0,
        )

    def whatif_rows(rep):
        return {(r["p"], r["topology"], r.get("straggler")): r for r in rep["whatif"]}

    fresh_wi = whatif_rows(fresh)
    for key, brow in sorted(whatif_rows(base).items(), key=lambda kv: str(kv[0])):
        frow = fresh_wi[key]
        name = "/".join(str(k) for k in key)
        # pure-numpy deterministic extrapolation: exact down to rounding
        yield (
            f"whatif.{name}.predicted_wall_s",
            brow["predicted_wall_s"],
            frow["predicted_wall_s"],
            "exact",
            0.0,
        )
        yield (
            f"whatif.{name}.predicted_detect_step",
            float(brow["predicted_detect_step"] or -1),
            float(frow["predicted_detect_step"] or -1),
            "exact",
            0.0,
        )
        yield (
            f"whatif.{name}.predicted_outer_iters",
            float(brow["predicted_outer_iters"]),
            float(frow["predicted_outer_iters"]),
            "exact",
            0.0,
        )
        yield (
            f"whatif.{name}.staleness_steps_at_detect",
            float(brow["staleness_steps_at_detect"] or 0),
            float(frow["staleness_steps_at_detect"] or 0),
            "exact",
            0.0,
        )

    bfit, ffit = base["calibration"]["fit"], fresh["calibration"]["fit"]
    # structure only — the KS statistic is measurement noise
    yield (
        "calibration.fit.dist",
        float(bfit["dist"] == ffit["dist"]),
        1.0,
        "exact",
        0.0,
    )
    yield ("calibration.fit.n", float(bfit["n"]), float(ffit["n"]), "exact", 0.0)


def _serve_row(prefix: str, brow: Dict, frow: Dict) -> Iterator[Check]:
    for counter in ("served", "rejected", "shed", "timeouts",
                    "false_detections", "compile_count", "warm_hits",
                    "ticks", "detect_steps_sum", "steps_sum"):
        yield (f"{prefix}.{counter}", float(brow[counter]),
               float(frow[counter]), "exact", 0.0)
    for dist, quantiles in (("ttd_ticks", ("p50", "p95", "p99")),
                            ("queue_wait_ticks", ("p50", "p95"))):
        for q in quantiles:
            yield (f"{prefix}.{dist}.{q}",
                   float(brow[dist].get(q, -1.0)),
                   float(frow[dist].get(q, -1.0)), "exact", 0.0)


def _serve_smoke(base: Dict, fresh: Dict) -> Iterator[Check]:
    # tick-domain service metrics are deterministic for a seeded schedule
    # under the pinned jax version — everything gates exact; wall seconds
    # and tenants-per-second are shared-runner noise, reported never gated
    yield from _serve_row("load", base["load"], fresh["load"])
    fresh_rows = {r["rate"]: r for r in fresh["sweep"]}
    for brow in sorted(base["sweep"], key=lambda r: r["rate"]):
        yield from _serve_row(f"sweep.rate{brow['rate']:g}",
                              brow, fresh_rows[brow["rate"]])
    yield ("knee.knee_rate",
           float(base["knee"]["knee_rate"] or -1),
           float(fresh["knee"]["knee_rate"] or -1), "exact", 0.0)


BENCHES = {
    "fused_smoke": _fused_smoke,
    "serve_smoke": _serve_smoke,
    "reliability_smoke": _reliability_smoke,
    "shard_smoke": _shard_smoke,
    "mesh_smoke": _mesh_smoke,
    "elastic_smoke": _elastic_smoke,
    "ml_smoke": _ml_smoke,
    "replay_smoke": _replay_smoke,
}


def run_checks(bench: str, base: Dict, fresh: Dict) -> int:
    """Evaluate one bench's checks; print verdicts, return failure count."""
    failures = 0
    for name, b, f, mode, tol in BENCHES[bench](base, fresh):
        if mode == "exact":
            ok = b == f
            detail = f"baseline={b!r} fresh={f!r} (exact)"
        elif mode == "floor":
            ok = f >= b * (1.0 - tol)
            detail = f"baseline={b:.4g} fresh={f:.4g} (floor {b * (1.0 - tol):.4g}, -{tol:.0%})"
        elif mode == "ceil":
            ok = f <= b * (1.0 + tol)
            detail = f"baseline={b:.4g} fresh={f:.4g} (ceil {b * (1.0 + tol):.4g}, +{tol:.0%})"
        else:
            rel = abs(f - b) / abs(b) if b else float("inf")
            ok = rel <= tol
            detail = f"baseline={b:.4g} fresh={f:.4g} drift={rel:.1%} (tol ±{tol:.0%})"
        print(f"{'ok  ' if ok else 'FAIL'} {name}: {detail}")
        failures += not ok
    return failures


def main() -> None:
    """CLI: gate a fresh smoke report against its committed baseline."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", choices=sorted(BENCHES))
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    args = ap.parse_args()
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    failures = run_checks(args.bench, base, fresh)
    if failures:
        sys.exit(
            f"{failures} metric(s) regressed beyond tolerance "
            f"(regenerate benchmarks/baselines/ deliberately if the "
            f"change is intended)"
        )
    print("no regressions")


if __name__ == "__main__":
    main()
