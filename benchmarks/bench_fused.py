"""Fused sweep+residual head-to-head — the proof for the fused hot path.

Two cells, both measured fused vs. unfused **in the same run**:

1. **Event-level simulator** (the paper-table cell): ``run_cell`` at
   (n=24, p=8, pfait) with ``EngineConfig.fused`` on/off.  Fused means
   ``ConvDiffProblem.update_with_residual`` (one ghost assembly, shared /
   checkerboard-sliced off-diagonal) plus protocol-gated residual skipping.
   Reported: wall-time and sweep-throughput speedup (target ≥1.5×).

2. **Sharded JAX driver**: ``make_sharded_solver`` lowered on a forced
   multi-device host platform with ``SolverConfig.fuse_residual`` on/off;
   HLO-derived ``hbm_bytes_per_device`` per sweep (launch/hlo_analysis).
   Fused means the residual is a by-product of the last inner sweep — no
   residual-only second grid pass (target ~½ traffic for Jacobi, reduced
   for hybrid).

Writes ``BENCH_fused.json`` (repo root by default).

Run:   PYTHONPATH=src:. python benchmarks/bench_fused.py
Smoke: PYTHONPATH=src:. python benchmarks/bench_fused.py --smoke
"""
from __future__ import annotations

import os

# the sharded cell needs >1 device; must be set before any jax import
_DEV = int(os.environ.get("BENCH_DEVICES", "8"))
os.environ.setdefault("XLA_FLAGS",
                      f"--xla_force_host_platform_device_count={_DEV}")
# one BLAS thread per process (see reliability_matrix.py)
for _v in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS", "MKL_NUM_THREADS"):
    os.environ.setdefault(_v, "1")

import argparse
import json
import time



# ---------------------------------------------------------------------------
# Cell 1: event-level simulator
# ---------------------------------------------------------------------------


def bench_event_sim(n: int, p: int, protocol: str = "pfait", eps: float = 1e-6,
                    seeds=(0, 1, 2, 3), repeats: int = 3, runner=None):
    """Fused/unfused head-to-head via ``fused_event`` campaign cells.

    Timing cells are never cached (``cache=False`` on the kind) but still
    run through the campaign runner — serially, in ONE worker: co-scheduling
    the two legs would let pool contention pollute the wall-clock ratio.
    """
    from benchmarks import campaign
    from benchmarks.campaign import CampaignConfig

    specs = [
        {"kind": "fused_event", "protocol": protocol, "eps": eps, "n": n,
         "p": p, "seeds": list(seeds), "fused": fused, "repeat": rep}
        for fused in (False, True)
        for rep in range(repeats)
    ]
    runner = runner or (lambda s: campaign.map_cells(
        s, CampaignConfig(executor="inline")))
    rows = runner(specs)
    out = {}
    for fused in (False, True):
        cells = [r for s, r in zip(specs, rows) if s["fused"] == fused]
        walls = [c["wall_s"] for c in cells]
        key = "fused" if fused else "unfused"
        out[key] = {
            "wall_s_best": float(min(walls)),
            "wall_s_all": [float(w) for w in walls],
            "sim_iters": int(cells[0]["sim_iters"]),
            "iters_per_s": float(cells[0]["sim_iters"] / min(walls)),
            "r_star_max": max(c["max_r"] for c in cells),
        }
    out["cell"] = {"protocol": protocol, "eps": eps, "n": n, "p": p,
                   "seeds": list(seeds), "repeats": repeats}
    out["wall_speedup"] = out["unfused"]["wall_s_best"] / out["fused"]["wall_s_best"]
    out["throughput_speedup"] = (out["fused"]["iters_per_s"]
                                 / out["unfused"]["iters_per_s"])
    return out


# ---------------------------------------------------------------------------
# Cell 2: sharded JAX driver (HLO-derived HBM traffic per sweep)
# ---------------------------------------------------------------------------


def measure_sharded(n: int, sweep: str, fuse_residual: bool,
                    inner_sweeps: int = 1, use_kernel: bool = False):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import detection
    from repro.launch import hlo_analysis
    from repro.launch.mesh import compat_make_mesh
    from repro.solvers.convdiff import Stencil
    from repro.solvers.fixed_point import SolverConfig, make_sharded_solver
    from repro.solvers.partition import process_grid

    ndev = len(jax.devices())
    px, py = process_grid(ndev)
    mesh = compat_make_mesh((px, py), ("data", "model"))
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.95)
    mon = detection.for_mode("pfait", eps_tilde=1e-6, margin=10.0, staleness=2)
    cfg = SolverConfig(stencil=st, monitor=mon, inner_sweeps=inner_sweeps,
                       max_outer=1000, sweep=sweep, use_kernel=use_kernel,
                       fuse_residual=fuse_residual)
    solve = make_sharded_solver(cfg, mesh)
    spec = P("data", "model", None)
    arr = jax.ShapeDtypeStruct((n, n, n), jnp.float32,
                               sharding=NamedSharding(mesh, spec))
    compiled = jax.jit(solve).lower(arr, arr).compile()
    pstats = hlo_analysis.program_stats(compiled.as_text(), default_group=ndev)
    # normalise per sweep with the analyzer's own loop multiplier (the
    # permute-count heuristic hillclimb uses is jax-version dependent: 4
    # faces lower to 4 or 8 one-directional permutes per outer iteration)
    sweeps = max(pstats.loop_trip_max, 1.0) * inner_sweeps
    return {
        "sweep": sweep,
        "inner_sweeps": inner_sweeps,
        "fuse_residual": fuse_residual,
        "devices": ndev,
        "hbm_bytes_per_device_per_sweep": pstats.hbm_bytes / sweeps,
        "wire_bytes_per_sweep": pstats.total_wire_bytes / sweeps,
    }


def bench_sharded(n: int, inner_sweeps: int = 1, runner=None):
    """HLO-derived traffic cells via the campaign (content-addressed: the
    lowering is deterministic per jax version, so warm re-runs cost zero)."""
    from benchmarks import campaign
    from benchmarks.campaign import CampaignConfig

    specs = [
        {"kind": "fused_sharded", "n": n, "sweep": sweep,
         "fuse_residual": fuse, "inner_sweeps": inner_sweeps}
        for sweep in ("jacobi", "hybrid")
        for fuse in (False, True)
    ]
    runner = runner or (lambda s: campaign.map_cells(
        s, CampaignConfig(executor="inline")))
    results = {(s["sweep"], s["fuse_residual"]): r
               for s, r in zip(specs, runner(specs))}
    rows = []
    for sweep in ("jacobi", "hybrid"):
        pair = {"unfused": results[(sweep, False)],
                "fused": results[(sweep, True)]}
        ratio = (pair["fused"]["hbm_bytes_per_device_per_sweep"]
                 / pair["unfused"]["hbm_bytes_per_device_per_sweep"])
        rows.append({"sweep": sweep, "n": n, "inner_sweeps": inner_sweeps,
                     "unfused": pair["unfused"], "fused": pair["fused"],
                     "hbm_ratio_fused_over_unfused": ratio})
    return rows


# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + relaxed thresholds (CI)")
    ap.add_argument("--out", default="BENCH_fused.json")
    args = ap.parse_args()

    if args.smoke:
        # best-of-3 over 4 seeds: at smoke scale a single ~0.1 s leg is
        # noise-dominated and the fused/unfused ratio (the regression-gate
        # metric) swings ±2×; three repeats keep the gate's ±30% meaningful
        ev = bench_event_sim(n=16, p=4, seeds=(0, 1, 2, 3), repeats=3)
        sh = bench_sharded(n=16)
        min_speedup = 1.0
    else:
        ev = bench_event_sim(n=24, p=8, seeds=(0, 1, 2, 3), repeats=3)
        sh = bench_sharded(n=64, inner_sweeps=1)
        min_speedup = 1.5

    report = {
        "event_sim": ev,
        "sharded": sh,
        "meta": {"smoke": bool(args.smoke),
                 "timestamp": time.strftime("%Y-%m-%d %H:%M:%S")},
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)

    print(f"event-sim ({ev['cell']['protocol']} n={ev['cell']['n']} "
          f"p={ev['cell']['p']}): wall speedup {ev['wall_speedup']:.2f}x, "
          f"throughput {ev['throughput_speedup']:.2f}x "
          f"(unfused {ev['unfused']['wall_s_best']:.3f}s → "
          f"fused {ev['fused']['wall_s_best']:.3f}s)")
    for row in sh:
        print(f"sharded {row['sweep']:7s}: hbm/sweep "
              f"{row['unfused']['hbm_bytes_per_device_per_sweep']:.3e} → "
              f"{row['fused']['hbm_bytes_per_device_per_sweep']:.3e} "
              f"({row['hbm_ratio_fused_over_unfused']:.2f}x)")

    ok = ev["wall_speedup"] >= min_speedup and all(
        r["hbm_ratio_fused_over_unfused"] < 1.0 for r in sh)
    if not ok:
        raise SystemExit(
            f"targets missed: wall_speedup={ev['wall_speedup']:.2f} "
            f"(need ≥{min_speedup}), hbm ratios="
            f"{[round(r['hbm_ratio_fused_over_unfused'], 3) for r in sh]} "
            f"(need <1.0)")
    print("targets met")


if __name__ == "__main__":
    main()
