"""Open-loop Poisson load test of the multi-tenant detection service.

One cell kind via the campaign cell API (``serve_load`` in
benchmarks/common.py): a seeded Poisson arrival stream of independent
fixed-point tenants — mixed across the three problem families
(ConvDiff, PageRank, mlfixed), the four monitor modes, and a per-family
ε̃ grid — is played into ``launch/serve.py``'s ``DetectionService``
through the open-loop ``serve_detection`` driver.  Each cell reports

* per-tenant certified detection, **oracle-scored** from the exact
  σ-applied residual series (the batched lane step is synchronous, so
  the recorded contribution IS the true residual) — acceptance is zero
  false detections, the same bar every other subsystem meets;
* **warm-executable reuse**: ``compile_count`` (distinct lane
  executables built) vs tenants served — signature-identical tenants
  skip compilation, so the count stays ≪ the tenant count;
* deterministic tick-domain latency: nearest-rank p50/p95/p99
  time-to-detection and queue wait (1 tick = one ``chunk`` of device
  steps per lane bucket).  Tick metrics are exact-gated in CI
  (``check_regression.py serve_smoke``); wall seconds are reported
  alongside but never gated.

The **rate sweep** replays the same tenant mix at increasing arrival
rates to locate the saturation knee: the first rate whose p95 queue wait
exceeds the unloaded p50 time-to-detection (tenants then wait longer for
a lane than an unloaded solve takes end-to-end).

Writes ``BENCH_serve.json`` (repo root) or the smoke variant the
``serve-smoke`` CI job gates against ``benchmarks/baselines/``.

Run:   PYTHONPATH=src:. python benchmarks/bench_serve.py
Smoke: PYTHONPATH=src:. python benchmarks/bench_serve.py --smoke
"""
from __future__ import annotations

import os

for _v in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS", "MKL_NUM_THREADS"):
    os.environ.setdefault(_v, "1")

import argparse
import time
from typing import Dict, List, Tuple

import numpy as np

#: the tenant mix: (family, problem kwargs, ε̃ grid) — shapes small enough
#: that a full 256-tenant campaign runs in CI, large enough that every
#: family converges well inside the service step budget.  The ε̃ grids sit
#: ≥3× above each family's measured f32 residual floor *after* the PFAIT
#: margin tightening (ε = ε̃/10): convdiff's ∞-norm floors at ~6e-7 over
#: the tenant seeds, mlfixed's 2-norm at ~1.4e-7, pagerank's l1 reaches
#: exactly 0 — a tighter grid would stall PFAIT tenants at the float
#: floor and time them out rather than converge them.
FAMILIES: Tuple[Tuple[str, Dict, Tuple[float, ...]], ...] = (
    ("convdiff", {"n": 8, "p": 4, "rho": 0.9}, (1e-3, 1e-4)),
    ("pagerank", {"n": 96, "p": 4}, (1e-5, 1e-6, 1e-7)),
    ("mlfixed", {"n": 16, "p": 4, "m_rows": 48, "cond": 10.0},
     (1e-4, 1e-5)),
)

MODES = ("pfait", "nfais5", "nfais2", "sync")

#: deterministic malformed specs exercising every admission-rejection code
_INVALID = (
    {"family": "heat", "reason": "unknown_family"},
    {"mode": "magic", "reason": "unknown_mode"},
    {"eps_tilde": -1.0, "reason": "bad_eps"},
    {"staleness": 99, "reason": "bad_staleness"},
    {"persistence": 0, "reason": "bad_persistence"},
    {"problem": {"n": 7, "p": 4, "rho": 0.9}, "family": "convdiff",
     "reason": "problem_invalid"},   # 7 % 4 != 0 → constructor raises
)


def poisson_requests(tenants: int, rate: float, seed: int,
                     inject_invalid: int = 0) -> List[Tuple]:
    """Seeded open-loop request schedule: ``tenants`` specs with Poisson
    arrivals at ``rate`` tenants/tick (exponential inter-arrivals, floored
    to integer ticks), mixed round-robin over families and seeded-random
    over modes/ε̃/staleness.  ``inject_invalid`` appends deterministic
    malformed specs (admission-rejection coverage) on the same clock.
    """
    from repro.launch.serve import TenantSpec

    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(
        rng.exponential(1.0 / rate, tenants + inject_invalid))).astype(int)
    reqs: List[Tuple] = []
    for i in range(tenants):
        family, problem, eps_grid = FAMILIES[i % len(FAMILIES)]
        mode = MODES[int(rng.integers(0, len(MODES)))]
        spec = TenantSpec(
            tenant=f"t{i:04d}",
            family=family,
            problem=problem,
            seed=int(rng.integers(0, 8)),
            eps_tilde=float(eps_grid[int(rng.integers(0, len(eps_grid)))]),
            mode=mode,
            staleness=int(rng.integers(0, 5)),
            persistence=int(rng.choice((2, 4))),
        )
        reqs.append((spec, int(arrivals[i])))
    for j in range(inject_invalid):
        bad = _INVALID[j % len(_INVALID)]
        spec = TenantSpec(
            tenant=f"bad{j:02d}",
            family=bad.get("family", "convdiff"),
            problem=bad.get("problem", {"n": 8, "p": 4, "rho": 0.9}),
            eps_tilde=bad.get("eps_tilde", 1e-5),
            mode=bad.get("mode", "pfait"),
            staleness=bad.get("staleness", 2),
            persistence=bad.get("persistence", 4),
        )
        reqs.append((spec, int(arrivals[tenants + j])))
    return reqs


def serve_load(tenants: int, rate: float, seed: int, lanes: int = 8,
               chunk: int = 16, max_steps: int = 2048,
               max_staleness: int = 8, inject_invalid: int = 0) -> Dict:
    """One load campaign: generate the schedule, serve it to drain, and
    summarise the ``ServeReport`` as a JSON-able, exact-gateable row
    (``wall_s``/``tenants_per_s`` are measured — reported, never gated)."""
    from repro.launch.serve import ServeConfig, serve_detection

    reqs = poisson_requests(tenants, rate, seed,
                            inject_invalid=inject_invalid)
    t0 = time.time()
    rep = serve_detection(reqs, ServeConfig(
        lanes=lanes, chunk=chunk, max_steps=max_steps,
        max_staleness=max_staleness))
    wall = time.time() - t0
    served = [t for t in rep.tenants if t.status == "served"]
    rejected = [t for t in rep.tenants if t.status == "rejected"]
    return {
        "tenants": tenants,
        "rate": rate,
        "seed": seed,
        "lanes": lanes,
        "chunk": chunk,
        "served": rep.served,
        "rejected": rep.rejected,
        "rejected_codes": sorted(t.error for t in rejected),
        "shed": rep.shed,
        "timeouts": rep.timeouts,
        "false_detections": rep.false_detections,
        "families_served": sorted({t.family for t in served}),
        "modes_served": sorted({t.mode for t in served}),
        "compile_count": rep.compile_count,
        "warm_hits": rep.warm_hits,
        "ticks": rep.ticks,
        "ttd_ticks": rep.ttd_ticks,
        "queue_wait_ticks": rep.queue_wait_ticks,
        "tenants_per_tick": rep.throughput["tenants_per_tick"],
        "detect_steps_sum": int(sum(t.detect_step for t in served)),
        "steps_sum": int(sum(t.steps for t in served)),
        "wall_s": wall,
        "tenants_per_s": rep.throughput["tenants_per_s"],
    }


def find_knee(sweep_rows: List[Dict]) -> Dict:
    """Saturation knee of a rate sweep (rows sorted by rate): the first
    rate whose p95 queue wait exceeds the lowest rate's p50 ttd — from
    there on, waiting for a lane costs more than an unloaded solve."""
    rows = sorted(sweep_rows, key=lambda r: r["rate"])
    if not rows:
        return {"knee_rate": None}
    unloaded_ttd = rows[0]["ttd_ticks"].get("p50", 0.0)
    for r in rows:
        if r["queue_wait_ticks"].get("p95", 0.0) > unloaded_ttd:
            return {"knee_rate": r["rate"], "unloaded_p50_ttd": unloaded_ttd,
                    "knee_p95_wait": r["queue_wait_ticks"]["p95"]}
    return {"knee_rate": None, "unloaded_p50_ttd": unloaded_ttd}


def _run(specs):
    from benchmarks import campaign
    from benchmarks.campaign import CampaignConfig

    return campaign.map_cells(specs, CampaignConfig(executor="inline"))


def main():
    """CLI: run the load cell + rate sweep, write the report, assert."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small load + 2-point sweep (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    import jax

    if args.smoke:
        main_spec = {"kind": "serve_load", "tenants": 36, "rate": 2.0,
                     "seed": 0, "lanes": 4, "chunk": 16, "max_steps": 2048,
                     "inject_invalid": 3}
        sweep_rates = (1.0, 4.0)
        sweep_tenants = 18
    else:
        main_spec = {"kind": "serve_load", "tenants": 264, "rate": 2.0,
                     "seed": 0, "lanes": 8, "chunk": 16, "max_steps": 2048,
                     "inject_invalid": 6}
        sweep_rates = (0.5, 1.0, 2.0, 4.0, 8.0)
        sweep_tenants = 72

    # the sweep runs lean (2 lanes/bucket) so the knee is reachable: with
    # the main config's lane budget, aggregate capacity (lanes × live
    # signatures) exceeds every swept rate and queues never form
    sweep_specs = [
        {"kind": "serve_load", "tenants": sweep_tenants, "rate": r,
         "seed": 1, "lanes": 2, "chunk": 16, "max_steps": 2048}
        for r in sweep_rates
    ]
    rows = _run([main_spec] + sweep_specs)
    load_row, sweep_rows = rows[0], rows[1:]
    knee = find_knee(sweep_rows)

    report = {
        "load": load_row,
        "sweep": sweep_rows,
        "knee": knee,
        "meta": {"smoke": bool(args.smoke), "jax": jax.__version__,
                 "numpy": np.__version__,
                 "timestamp": time.strftime("%Y-%m-%d %H:%M:%S")},
    }
    from benchmarks.campaign import write_json_atomic

    write_json_atomic(args.out, report)

    # -- summary + in-script acceptance ------------------------------------
    print(f"load: served={load_row['served']}/{load_row['tenants']} "
          f"rejected={load_row['rejected']} timeouts={load_row['timeouts']} "
          f"false={load_row['false_detections']} "
          f"compiles={load_row['compile_count']} "
          f"warm={load_row['warm_hits']} ticks={load_row['ticks']} "
          f"ttd={load_row['ttd_ticks']} wall={load_row['wall_s']:.1f}s")
    for r in sweep_rows:
        print(f"sweep rate={r['rate']:>4}: served={r['served']} "
              f"queue_wait={r['queue_wait_ticks']} ttd={r['ttd_ticks']}")
    print(f"knee: {knee}")

    failures = []
    all_rows = [load_row] + sweep_rows
    if any(r["false_detections"] for r in all_rows):
        failures.append("false detections under load")
    if any(r["timeouts"] for r in all_rows):
        failures.append("tenant timeouts (step budget too small?)")
    if len(load_row["families_served"]) < 3:
        failures.append(f"families {load_row['families_served']} < 3")
    reuse_factor = 2 if args.smoke else 8   # signatures ≤ families × modes
    if load_row["compile_count"] * reuse_factor > load_row["served"]:
        failures.append(
            f"warm reuse not observed: {load_row['compile_count']} compiles "
            f"for {load_row['served']} tenants")
    if not args.smoke and load_row["served"] < 256:
        failures.append(f"served {load_row['served']} < 256")
    if load_row["rejected"] != main_spec["inject_invalid"]:
        failures.append(
            f"rejected {load_row['rejected']} != injected "
            f"{main_spec['inject_invalid']}")
    if failures:
        raise SystemExit("ACCEPTANCE FAIL: " + "; ".join(failures))
    print("acceptance: OK")


if __name__ == "__main__":
    main()
