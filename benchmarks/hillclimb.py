"""§Perf hillclimb driver: lower optimization variants of the three chosen
cells, measure the roofline terms, append to experiments/perf_iterations.json.

Run (one variant at a time — each re-lowers at 512 host devices):
  PYTHONPATH=src:. python -m benchmarks.hillclimb --cell qwen --variant pairs
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import sys

def measure_lm(arch: str, shape: str, variant: str, attn_impl: str = "blocked",
               microbatch=None, tp_reduce_bf16: bool = False,
               remat: str = "block"):
    from repro.configs.base import ParallelConfig
    from repro.launch.dryrun import lower_cell

    par = ParallelConfig(attn_impl=attn_impl, tp_reduce_bf16=tp_reduce_bf16,
                         remat=remat)
    rec = lower_cell(arch, shape, multi_pod=False, parallel=par,
                     microbatch_override=microbatch, variant=variant)
    return rec


def measure_solver(variant: str, inner_sweeps: int = 4, n: int = 1024,
                   staleness: int = 4, use_kernel: bool = False):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import detection
    from repro.launch import hlo_analysis
    from repro.launch.mesh import make_production_mesh
    from repro.solvers.convdiff import Stencil
    from repro.solvers.fixed_point import SolverConfig, make_sharded_solver

    mesh = make_production_mesh()
    st = Stencil.for_contraction(n, 1.0, (1.0, 1.0, 1.0), rho=0.95)
    mon = detection.for_mode("pfait", eps_tilde=1e-4, margin=10.0,
                             staleness=staleness)
    max_outer = 20_000 // inner_sweeps
    cfg = SolverConfig(stencil=st, monitor=mon, inner_sweeps=inner_sweeps,
                       max_outer=max_outer, use_kernel=use_kernel)
    solve = make_sharded_solver(cfg, mesh)
    spec = P("data", "model", None)
    x0 = jax.ShapeDtypeStruct((n, n, n), jnp.float32, sharding=NamedSharding(mesh, spec))
    b = jax.ShapeDtypeStruct((n, n, n), jnp.float32, sharding=NamedSharding(mesh, spec))
    compiled = jax.jit(solve).lower(x0, b).compile()
    pstats = hlo_analysis.program_stats(compiled.as_text(), default_group=256)
    # Normalise per sweep with the analyzer's loop multiplier (permute-count
    # inference is jax-version dependent: 4 faces lower to 4 or 8
    # one-directional shifts per outer iteration).
    outers_counted = max(pstats.loop_trip_max, 1.0)
    sweeps_counted = outers_counted * inner_sweeps
    cells = n * n * n / 256  # per device
    stencil_flops = 14.0 * cells  # 7-pt stencil: 6 mul + 6 add + sub + div
    return {
        "arch": f"convdiff-n{n}", "shape": "solver", "variant": variant,
        "inner_sweeps": inner_sweeps,
        "cost": {
            # stencils have no dots — analytic FLOPs per sweep
            "flops_per_device": stencil_flops,
            "hbm_bytes_per_device": pstats.hbm_bytes / sweeps_counted,
        },
        "collectives": {
            "total_wire_bytes": pstats.total_wire_bytes / sweeps_counted,
            "counts": {k: v / sweeps_counted
                       for k, v in pstats.coll_counts.items()},
        },
        "per": "sweep",
    }


def report(rec, chips=256):
    PEAK, HBM, LINK = 197e12, 819e9, 50e9
    c = rec["cost"]["flops_per_device"] / PEAK
    m = rec["cost"]["hbm_bytes_per_device"] / HBM
    w = rec["collectives"]["total_wire_bytes"] / LINK
    dom = max((c, "compute"), (m, "memory"), (w, "collective"))[1]
    print(f"{rec.get('arch')}/{rec.get('shape')}/{rec['variant']}: "
          f"compute {c*1e3:.2f}ms  memory {m*1e3:.2f}ms  collective {w*1e3:.2f}ms "
          f"→ dominant {dom}")
    return {"compute_s": c, "memory_s": m, "collective_s": w, "dominant": dom}


def append(rec, path="experiments/perf_iterations.json"):
    rows = []
    if os.path.exists(path):
        rows = json.load(open(path))
    rows.append(rec)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    json.dump(rows, open(path, "w"), indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    choices=["qwen", "llama4", "grok", "solver"])
    ap.add_argument("--variant", required=True)
    ap.add_argument("--attn-impl", default="blocked")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--tp-bf16", action="store_true")
    ap.add_argument("--remat", default="block")
    ap.add_argument("--inner-sweeps", type=int, default=4)
    args = ap.parse_args()

    if args.cell == "solver":
        rec = measure_solver(args.variant, inner_sweeps=args.inner_sweeps)
    else:
        arch = {"qwen": "qwen2.5-32b", "llama4": "llama4-maverick-400b-a17b",
                "grok": "grok-1-314b"}[args.cell]
        rec = measure_lm(arch, "train_4k", args.variant,
                         attn_impl=args.attn_impl, microbatch=args.microbatch,
                         tp_reduce_bf16=args.tp_bf16, remat=args.remat)
    rec["terms"] = report(rec)
    append(rec)


if __name__ == "__main__":
    main()
